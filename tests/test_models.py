"""Per-architecture smoke tests: every assigned arch instantiates a
reduced same-family config, runs forward/train/prefill/decode on CPU, and
the single-step decode agrees with the full-forward oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_smoke
from repro.models import build_model

ARCHS = ASSIGNED_ARCHS + ["sparkv-qwen3-4b"]


def _batch(cfg, b=2, s=32, seed=1):
    if cfg.family == "encdec":
        return {"frames": jnp.ones((b, s, cfg.d_model), jnp.bfloat16),
                "dec_tokens": jnp.ones((b, cfg.dec_len + 1), jnp.int32)}
    return {"tokens": jax.random.randint(jax.random.PRNGKey(seed),
                                         (b, s + 1), 0, cfg.vocab_size)}


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_finite(arch):
    cfg = get_smoke(arch)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    loss = jax.jit(model.loss)(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch))(params)
    gsum = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
               for g in jax.tree.leaves(grads))
    assert np.isfinite(gsum) and gsum > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill(arch):
    cfg = get_smoke(arch)
    if cfg.family == "encdec":
        pytest.skip("enc-dec decode tested in test_encdec_decode")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    tokens = _batch(cfg, b, s)["tokens"]
    out = model.prefill(params, {"tokens": tokens[:, :s]})
    logits0, caches = out
    if cfg.family in ("dense", "moe"):
        cache = model.init_cache(b, s)
        cache["k"], cache["v"] = caches["k"], caches["v"]
    elif cfg.family == "ssm":
        cache = {"conv": caches["conv"].astype(jnp.bfloat16),
                 "state": caches["state"].astype(jnp.float32)}
    else:  # hybrid
        cache = model.init_cache(b, s)
        cache["ssm"]["conv"] = caches["ssm"]["conv"].astype(jnp.bfloat16)
        cache["ssm"]["state"] = caches["ssm"]["state"].astype(jnp.float32)
        cache["attn_k"], cache["attn_v"] = (caches["attn_k"],
                                            caches["attn_v"])
    logits, _ = jax.jit(model.decode_step)(
        params, cache, tokens[:, s], jnp.int32(s))
    ref, _ = model.prefill(params, {"tokens": tokens[:, :s + 1]})
    diff = float(jnp.abs(logits.astype(jnp.float32)
                         - ref.astype(jnp.float32)).max())
    assert diff < 0.15, f"decode/prefill mismatch {diff}"


def test_encdec_decode():
    cfg = get_smoke("whisper-tiny")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 32
    frames = jnp.ones((b, s, cfg.d_model), jnp.bfloat16)
    pf = model.prefill(params, {"frames": frames})
    cache = model.init_cache(b, s)
    cache = dict(cache, cross_k=pf["cross_k"], cross_v=pf["cross_v"])
    logits, cache = jax.jit(model.decode_step)(
        params, cache, jnp.zeros((b,), jnp.int32), jnp.int32(0))
    assert np.isfinite(np.asarray(logits, np.float32)).all()


def test_param_counts_match_analytic():
    # materialized parameter count tracks the analytic one (pad excluded)
    for arch in ("qwen2.5-3b", "mamba2-130m", "zamba2-2.7b"):
        cfg = get_smoke(arch)
        model = build_model(cfg)
        n_real = sum(int(np.prod(s.shape))
                     for s in jax.tree.leaves(model.abstract_params()))
        n_pred = cfg.param_count()
        pad = (cfg.padded_vocab - cfg.vocab_size) * cfg.d_model
        # norms/biases/dt etc. are not in the analytic count: allow 5%
        assert abs(n_real - pad - n_pred) / n_pred < 0.12, arch


def test_moe_aux_loss_nonzero():
    cfg = get_smoke("qwen3-moe-235b-a22b")
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    from repro.models import transformer as T
    tokens = _batch(cfg)["tokens"]
    _, _, aux = T.forward(cfg, params, tokens[:, :-1])
    assert float(aux) > 0
