"""Benchmark results guard: ``--quick`` smoke runs must never overwrite
checked-in full-run results (they use reduced workloads, so their
numbers are not comparable — see benchmarks/common.py), and every
checked-in ``results/benchmarks/*.json`` must validate against the
benchmark registry (produced by a registered module, full-run, carrying
the required metadata keys). Runs as its own CI job."""
import ast
import glob
import json
import os

import pytest

import benchmarks.common as common
from benchmarks.run import BENCHES

BENCH_DIR = os.path.dirname(common.__file__)
RESULTS = sorted(glob.glob(os.path.join(os.path.normpath(common.RESULTS_DIR),
                                        "*.json")))


def _registered_save_names() -> set:
    """String literals reachable as the first argument of ``save(...)``
    in every module registered in benchmarks/run.py. Computed-name saves
    (e.g. ``save("fig13_interference" + suffix)``) contribute their
    constant parts, so a checked-in name must *start with* one of
    these."""
    names = set()
    for _, module in BENCHES:
        path = os.path.join(BENCH_DIR, module.split(".")[-1] + ".py")
        with open(path) as f:
            tree = ast.parse(f.read())
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else \
                fn.attr if isinstance(fn, ast.Attribute) else None
            if name != "save" or not node.args:
                continue
            for sub in ast.walk(node.args[0]):
                if isinstance(sub, ast.Constant) and \
                        isinstance(sub.value, str):
                    names.add(sub.value)
                    break           # leftmost constant = the base name
    return names


def test_quick_save_routes_to_quick_dir(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    monkeypatch.setattr(common, "QUICK_DIR", str(tmp_path / "quick"))
    full = common.save("x", {"rows": [1]})
    quick = common.save("x", {"rows": [1]}, quick=True)
    assert os.path.normpath(full) != os.path.normpath(quick)
    assert os.sep + "quick" + os.sep in quick
    assert os.path.exists(full) and os.path.exists(quick)
    # a quick re-run never touches the full-run file
    before = os.path.getmtime(full)
    common.save("x", {"rows": [2]}, quick=True)
    assert os.path.getmtime(full) == before


def test_quick_results_never_alias_checked_in_paths(tmp_path, monkeypatch):
    """Same bench name, quick vs full: distinct directories, and the
    quick directory is git-ignored so nothing under it can be checked
    in by accident."""
    root = os.path.dirname(BENCH_DIR)
    with open(os.path.join(root, ".gitignore")) as f:
        assert "results/benchmarks/quick/" in f.read()
    assert os.path.normpath(common.QUICK_DIR).startswith(
        os.path.normpath(common.RESULTS_DIR))


def test_every_bench_threads_quick_through_save():
    """Static guard: every ``save(...)`` call in benchmarks/ passes the
    ``quick`` flag, so no future bench silently reverts to clobbering
    full-run results on --quick."""
    offenders = []
    for fname in sorted(os.listdir(BENCH_DIR)):
        if not fname.startswith("bench_") or not fname.endswith(".py"):
            continue
        path = os.path.join(BENCH_DIR, fname)
        with open(path) as f:
            tree = ast.parse(f.read())
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else \
                fn.attr if isinstance(fn, ast.Attribute) else None
            if name != "save":
                continue
            if not any(kw.arg == "quick" for kw in node.keywords):
                offenders.append(f"{fname}:{node.lineno}")
    assert not offenders, \
        f"save() calls missing quick= passthrough: {offenders}"


# ---------------------------------------------------------------------------
# checked-in results validate against the registry
# ---------------------------------------------------------------------------

def test_some_results_are_checked_in():
    assert RESULTS, "results/benchmarks/ has no checked-in JSONs"


def test_memory_bench_registered():
    """The KV memory bench is wired into the runner under the ``memory``
    name and its ``kv_memory`` save literal is discoverable by the
    checked-in-results validator."""
    assert ("memory", "benchmarks.bench_kv_memory") in BENCHES
    assert "kv_memory" in _registered_save_names()


def test_reuse_bench_registered():
    """The cross-request KV reuse bench is wired into the runner under
    the ``reuse`` name and its save literal is discoverable by the
    checked-in-results validator."""
    assert ("reuse", "benchmarks.bench_reuse") in BENCHES
    assert "reuse" in _registered_save_names()


def test_quant_bench_registered():
    """The per-chunk adaptive quantization bench is wired into the
    runner under the ``quant`` name and its save literal is
    discoverable by the checked-in-results validator."""
    assert ("quant", "benchmarks.bench_quant") in BENCHES
    assert "quant" in _registered_save_names()


def test_simcore_bench_registered():
    """The simulator-throughput bench is wired into the runner and its
    results file validates against the registry."""
    assert ("simcore", "benchmarks.bench_simcore") in BENCHES
    assert "simcore" in _registered_save_names()


def test_profile_stamp_routes_through_save(tmp_path, monkeypatch):
    """--profile adds a ``_profile`` block (bench wall-clock + simulator
    event counters) to saved payloads, and leaves unprofiled saves
    untouched."""
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    monkeypatch.setattr(common, "QUICK_DIR", str(tmp_path / "quick"))
    plain = common.save("x", {"rows": [1]})
    with open(plain) as f:
        assert "_profile" not in json.load(f)
    monkeypatch.setattr(common, "PROFILE", True)
    common.begin_bench()
    prof = common.save("x", {"rows": [1]})
    with open(prof) as f:
        block = json.load(f)["_profile"]
    assert block["bench_wall_s"] >= 0
    assert "sim_events" in block and "sim_events_per_s" in block


@pytest.mark.parametrize("path", RESULTS,
                         ids=[os.path.basename(p) for p in RESULTS])
def test_checked_in_result_validates_against_registry(path):
    """Every checked-in result JSON was produced by a module registered
    in benchmarks/run.py (its ``_bench`` name extends a registered
    ``save()`` literal), is a *full* run (quick artifacts live under the
    git-ignored quick/ dir and must never be committed), and carries
    the metadata keys ``save()`` stamps plus printable rows."""
    with open(path) as f:
        payload = json.load(f)
    fname = os.path.splitext(os.path.basename(path))[0]
    for key in ("_bench", "_time"):
        assert key in payload, f"{fname}: missing {key}"
    assert payload["_bench"] == fname, \
        f"{fname}: _bench stamp {payload['_bench']!r} != file name"
    assert not payload.get("_quick", False), \
        f"{fname}: quick-run artifact checked in"
    names = _registered_save_names()
    assert any(fname == n or fname.startswith(n) for n in names), \
        f"{fname}: not produced by any bench registered in run.py " \
        f"(known save names: {sorted(names)})"
    rows = payload.get("rows")
    if rows is None:                 # multi-table benches nest their rows
        rows = [r for v in payload.values() if isinstance(v, list)
                for r in v]
    assert rows and all(isinstance(r, dict) for r in rows), \
        f"{fname}: no row dicts found"


def test_no_quick_artifacts_under_version_control():
    """The quick/ subdirectory is git-ignored wholesale; nothing below
    it may carry a full-run stamp either (belt and braces: a file moved
    out of quick/ into the checked-in dir keeps its _quick flag)."""
    for path in RESULTS:
        with open(path) as f:
            assert not json.load(f).get("_quick", False), path
