"""Benchmark quick-run output guard: ``--quick`` smoke runs must never
overwrite checked-in full-run results (they use reduced workloads, so
their numbers are not comparable — see benchmarks/common.py)."""
import ast
import os
import re

import benchmarks.common as common

BENCH_DIR = os.path.dirname(common.__file__)


def test_quick_save_routes_to_quick_dir(tmp_path, monkeypatch):
    monkeypatch.setattr(common, "RESULTS_DIR", str(tmp_path))
    monkeypatch.setattr(common, "QUICK_DIR", str(tmp_path / "quick"))
    full = common.save("x", {"rows": [1]})
    quick = common.save("x", {"rows": [1]}, quick=True)
    assert os.path.normpath(full) != os.path.normpath(quick)
    assert os.sep + "quick" + os.sep in quick
    assert os.path.exists(full) and os.path.exists(quick)
    # a quick re-run never touches the full-run file
    before = os.path.getmtime(full)
    common.save("x", {"rows": [2]}, quick=True)
    assert os.path.getmtime(full) == before


def test_quick_results_never_alias_checked_in_paths(tmp_path, monkeypatch):
    """Same bench name, quick vs full: distinct directories, and the
    quick directory is git-ignored so nothing under it can be checked
    in by accident."""
    root = os.path.dirname(BENCH_DIR)
    with open(os.path.join(root, ".gitignore")) as f:
        assert "results/benchmarks/quick/" in f.read()
    assert os.path.normpath(common.QUICK_DIR).startswith(
        os.path.normpath(common.RESULTS_DIR))


def test_every_bench_threads_quick_through_save():
    """Static guard: every ``save(...)`` call in benchmarks/ passes the
    ``quick`` flag, so no future bench silently reverts to clobbering
    full-run results on --quick."""
    offenders = []
    for fname in sorted(os.listdir(BENCH_DIR)):
        if not fname.startswith("bench_") or not fname.endswith(".py"):
            continue
        path = os.path.join(BENCH_DIR, fname)
        with open(path) as f:
            tree = ast.parse(f.read())
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            fn = node.func
            name = fn.id if isinstance(fn, ast.Name) else \
                fn.attr if isinstance(fn, ast.Attribute) else None
            if name != "save":
                continue
            if not any(kw.arg == "quick" for kw in node.keywords):
                offenders.append(f"{fname}:{node.lineno}")
    assert not offenders, \
        f"save() calls missing quick= passthrough: {offenders}"
