"""Minimal deterministic fallback for the slice of the `hypothesis` API
this test-suite uses.

Activated by ``tests/conftest.py`` ONLY when the real package is absent:
CI installs real hypothesis and never sees this module; some dev
containers don't ship it, and the suites used to silently skip there
(``pytest.importorskip``) — hiding regressions in exactly the code the
property tests guard. This stub is *not* a property-testing engine (no
shrinking, no example database, no coverage-guided generation): it
replays a fixed number of deterministic pseudo-random examples, boundary
values first, seeded from the test's qualified name, so the same
assertions run everywhere and a failure reproduces bit-for-bit.
"""
from __future__ import annotations


import zlib

import numpy as np

from . import strategies  # noqa: F401  (import-surface parity)

__version__ = "0.0.stub"
HYPOTHESIS_STUB = True

_DEFAULT_MAX_EXAMPLES = 20


def settings(**kwargs):
    """Decorator recording example-count knobs; ``deadline`` and
    ``derandomize`` are accepted for API parity (the stub is always
    deadline-free and derandomized)."""
    def deco(f):
        f._stub_settings = kwargs
        return f
    return deco


def given(*strats, **kwstrats):
    assert not kwstrats, "stub supports positional strategies only"

    def deco(f):
        # no functools.wraps: it would expose f's parameters through
        # __wrapped__ and pytest would resolve them as fixtures
        def wrapper(*args, **kwargs):
            cfg = getattr(wrapper, "_stub_settings", {})
            n = cfg.get("max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.adler32(f.__qualname__.encode()))
            examples = []
            if all(s.edges for s in strats):
                examples.append(tuple(s.edges[0] for s in strats))
                examples.append(tuple(s.edges[-1] for s in strats))
            while len(examples) < n:
                examples.append(tuple(s.sample(rng) for s in strats))
            for ex in examples[:n]:
                try:
                    f(*args, *ex, **kwargs)
                except BaseException:
                    print(f"Falsifying example: {f.__name__}{ex!r}")
                    raise
        wrapper.__name__ = f.__name__
        wrapper.__qualname__ = f.__qualname__
        wrapper.__doc__ = f.__doc__
        wrapper.__module__ = f.__module__
        wrapper.hypothesis_stub = True
        return wrapper
    return deco
