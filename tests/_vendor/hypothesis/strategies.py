"""Strategy objects for the deterministic hypothesis stub: each carries
a ``sample(rng)`` draw plus explicit ``edges`` (boundary values tried
first by ``given``). Only the strategies the repo's suites use."""
from __future__ import annotations


class _Strategy:
    def __init__(self, sample, edges=()):
        self._sample = sample
        self.edges = tuple(edges)

    def sample(self, rng):
        return self._sample(rng)


def integers(min_value: int, max_value: int) -> _Strategy:
    return _Strategy(
        lambda rng: int(rng.integers(min_value, max_value + 1)),
        edges=(min_value, max_value))


def floats(min_value: float, max_value: float, **_kw) -> _Strategy:
    return _Strategy(
        lambda rng: float(rng.uniform(min_value, max_value)),
        edges=(min_value, max_value))


def booleans() -> _Strategy:
    return _Strategy(lambda rng: bool(rng.integers(0, 2)),
                     edges=(False, True))


def sampled_from(seq) -> _Strategy:
    seq = list(seq)
    assert seq, "sampled_from needs a non-empty sequence"
    return _Strategy(lambda rng: seq[int(rng.integers(len(seq)))],
                     edges=(seq[0], seq[-1]))


def lists(elements: _Strategy, *, min_size: int = 0,
          max_size: int = 10) -> _Strategy:
    def sample(rng):
        n = int(rng.integers(min_size, max_size + 1))
        return [elements.sample(rng) for _ in range(n)]
    return _Strategy(sample)


def tuples(*elements: _Strategy) -> _Strategy:
    return _Strategy(lambda rng: tuple(e.sample(rng) for e in elements))
