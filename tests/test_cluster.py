"""Multi-request serving cluster: arbiter fair-sharing, contention
coupling, admission queueing, single-request equivalence, run-queue
disciplines, two-stage topologies and telemetry-driven policy."""
import numpy as np
import pytest

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import (NETWORKS, NetworkProfile,
                              RunQueueModel, SharedLinkModel)
from repro.core.engine import BandwidthIntegrator, LinkStarvedError
from repro.data.workloads import DATASETS, synthesize
from repro.serving.cluster import (FleetReport, RequestSpec,
                                   ServingCluster, SharedLinkArbiter,
                                   telemetry_policy)
from repro.serving.resources import DeviceRunQueue, single_link
from repro.serving.traffic import TrafficProfile, generate_trace

CFG = get_config("sparkv-qwen3-4b")
SP = SparKVConfig(scheduler_mode="engine")
NET = NETWORKS["campus-wifi"]
CTX = 4096


def make_cluster(**kw):
    kw.setdefault("max_concurrency", 8)
    return ServingCluster(CFG, SP, "jetson-orin", "campus-wifi", **kw)


# ---------------------------------------------------------------------------
# arbiter unit behaviour
# ---------------------------------------------------------------------------

def test_arbiter_fair_share_halves_rate():
    bw = BandwidthIntegrator(np.full(5000, 100e6), 0.01)
    arb = SharedLinkArbiter(bw, link=None)
    arb.add(0, 50e6)
    t_solo, k = arb.next_completion()
    assert k == 0 and abs(t_solo - 0.5) < 1e-6
    arb.add(1, 50e6)
    t_shared, _ = arb.next_completion()
    assert abs(t_shared - 1.0) < 1e-6          # two flows, half rate each
    arb.advance(t_shared)
    arb.complete(0)
    t_last, k = arb.next_completion()
    assert k == 1 and abs(t_last - t_shared) < 1e-6   # also fully delivered


def test_arbiter_contention_overhead_shaves_aggregate():
    bw = BandwidthIntegrator(np.full(5000, 100e6), 0.01)
    link = SharedLinkModel(NET, contention_overhead=0.1)
    arb = SharedLinkArbiter(bw, link=link)
    arb.add(0, 45e6)
    arb.add(1, 45e6)
    # eta(2) = 0.9 -> per-flow rate 45e6 -> each needs 1.0s
    t, _ = arb.next_completion()
    assert abs(t - 1.0) < 1e-6


def test_finish_time_raises_on_starved_link():
    bw = BandwidthIntegrator(np.zeros(100), 0.01)
    with pytest.raises(LinkStarvedError):
        bw.finish_time(0.0, 1e6)
    assert bw.finish_time(0.0, 0.0) == 0.0      # zero bytes: immediate


# ---------------------------------------------------------------------------
# cluster end-to-end
# ---------------------------------------------------------------------------

def test_single_request_matches_classic_pipeline():
    """With one request the arbiter degenerates to the exclusive link and
    the cluster must reproduce the classic engine run."""
    wl = synthesize(CFG, CTX, DATASETS["triviaqa"],
                    chunk_tokens=SP.chunk_tokens, quant_bits=SP.quant_bits)
    seed = 0
    total = sum(float(wl.chunk_bytes[t, l].sum())
                for t in range(wl.n_t) for l in range(wl.n_l))
    horizon = max(20.0, 4 * total / NET.mean_bw + 10)
    trace = NET.trace(np.random.default_rng(seed + 991), horizon)
    ref = B.run_strong_hybrid(CFG, wl, "jetson-orin", NET, SP, seed=seed)
    rep = make_cluster(closed_loop=False, static_util=0.0,
                       bw_trace=trace, seed=seed).run(
        [RequestSpec(arrival_s=0.0, policy="strong_hybrid", seed=0, wl=wl)])
    r = rep.records[0]
    assert r.n_streamed == ref.engine.n_streamed
    assert r.n_computed == ref.engine.n_computed
    assert np.isclose(r.ttft_s, ref.ttft_s, rtol=1e-5)
    assert np.isclose(r.energy_j, ref.energy_j, rtol=1e-5)


def test_two_concurrent_streams_slow_each_other():
    """Acceptance: aggregate stream time under the shared-link arbiter
    exceeds the single-request stream time."""
    specs = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="cachegen",
                         seed=i) for i in range(2)]
    solo = make_cluster().run(specs[:1]).records[0]
    pair = make_cluster().run(specs)
    per_req = [r.stream_busy_s for r in pair.records]
    assert min(per_req) > solo.stream_busy_s * 1.3
    assert sum(per_req) > solo.stream_busy_s * 2.0
    # but the shared link still beats strict serialization of the pair
    assert pair.makespan_s < 2 * solo.done_s * 1.5


def test_poisson_fleet_completes_with_queueing():
    prof = TrafficProfile(rate_rps=2.0, arrival="poisson",
                          policy_mix=(("sparkv", 0.5),
                                      ("strong_hybrid", 0.3),
                                      ("local_prefill", 0.2)),
                          max_context=CTX)
    specs = generate_trace(prof, 8, seed=3)
    rep = make_cluster(max_concurrency=3).run(specs)
    assert isinstance(rep, FleetReport)
    assert rep.n_arrived == 8 and len(rep.records) == 8
    s = rep.summary()
    assert s["ttft_p50_s"] <= s["ttft_p99_s"]
    assert s["goodput_rps"] > 0
    assert len({r.policy for r in rep.records}) >= 2   # mixed-policy fleet
    # admission limit of 3 with burst arrivals must queue someone
    assert max(r.queue_s for r in rep.records) > 0


def test_closed_loop_contention_changes_migrations():
    """Acceptance: utilization from actual in-flight compute produces a
    different migration/compute mix than the static util path."""
    specs = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                         seed=i) for i in range(6)]
    closed = make_cluster(closed_loop=True).run(specs)
    static = make_cluster(closed_loop=False, static_util=0.0).run(specs)
    mc = sum(r.n_migrations for r in closed.records)
    ms = sum(r.n_migrations for r in static.records)
    nc = sum(r.n_computed for r in closed.records)
    ns = sum(r.n_computed for r in static.records)
    assert (mc, nc) != (ms, ns)
    # contention slows compute, so closed-loop should not compute more
    assert nc <= ns


def test_admission_queue_serializes_when_concurrency_1():
    specs = [RequestSpec(arrival_s=0.0, context_len=CTX,
                         policy="local_prefill", seed=i) for i in range(3)]
    rep = make_cluster(max_concurrency=1).run(specs)
    recs = rep.records
    assert recs[1].queue_s > 0 and recs[2].queue_s > recs[1].queue_s
    # strictly one in service: admission waits for predecessor's context
    assert recs[1].admit_s >= recs[0].context_done_s - 1e-9
    assert recs[2].admit_s >= recs[1].context_done_s - 1e-9


def test_deterministic_given_seeds():
    specs = [RequestSpec(arrival_s=0.3 * i, context_len=CTX,
                         policy="sparkv", seed=i) for i in range(3)]
    a = make_cluster().run(specs).summary()
    b = make_cluster().run(specs).summary()
    assert a == b


# ---------------------------------------------------------------------------
# explicit device run queue
# ---------------------------------------------------------------------------

def test_idle_runqueue_matches_classic_run():
    """Degenerate parity: a single request on a capacity-1 FIFO run queue
    never waits, so the cluster must reproduce HybridEngine.run() exactly
    (rtol 1e-5) — the run-queue protocol adds no timing skew."""
    wl = synthesize(CFG, CTX, DATASETS["triviaqa"],
                    chunk_tokens=SP.chunk_tokens, quant_bits=SP.quant_bits)
    seed = 0
    total = sum(float(wl.chunk_bytes[t, l].sum())
                for t in range(wl.n_t) for l in range(wl.n_l))
    horizon = max(20.0, 4 * total / NET.mean_bw + 10)
    trace = NET.trace(np.random.default_rng(seed + 991), horizon)
    for policy in ("strong_hybrid", "sparkv"):
        ref = B.PIPELINES[policy](CFG, wl, "jetson-orin", NET, SP, seed=seed)
        rep = make_cluster(closed_loop=False, static_util=0.0,
                           run_queue=RunQueueModel(1, "fifo"),
                           bw_trace=trace, seed=seed).run(
            [RequestSpec(arrival_s=0.0, policy=policy, seed=0, wl=wl)])
        r = rep.records[0]
        assert r.n_streamed == ref.engine.n_streamed, policy
        assert r.n_computed == ref.engine.n_computed, policy
        assert np.isclose(r.ttft_s, ref.ttft_s, rtol=1e-5), policy
        assert np.isclose(r.energy_j, ref.energy_j, rtol=1e-5), policy
        assert r.compute_wait_s == 0.0 and r.n_compute_queued == 0


def test_runqueue_contention_waits_not_dilates():
    """Concurrent compute-bound requests on a capacity-1 run queue wait
    in the explicit queue; the report's queue-wait breakdown captures it."""
    specs = [RequestSpec(arrival_s=0.0, context_len=CTX,
                         policy="local_prefill", seed=i) for i in range(3)]
    rep = make_cluster(run_queue=RunQueueModel(1, "fifo")).run(specs)
    s = rep.summary()
    assert s["queue_wait_p99_s"] > 0
    assert sum(r.n_compute_queued for r in rep.records) > 0
    # legacy closed loop has no run queue: wait breakdown is identically 0
    s0 = make_cluster(closed_loop=True).run(specs).summary()
    assert s0["queue_wait_p99_s"] == 0.0 and s0["queue_wait_mean_s"] == 0.0


def test_fifo_vs_wfq_changes_tail_latency():
    """Acceptance: the scheduling discipline is observable end-to-end —
    a weighted interactive class plus a background bulk load produce
    different p99 TTFT (and better interactive tails under WFQ)."""
    specs = [RequestSpec(arrival_s=0.0, context_len=8192,
                         policy="sparkv", seed=0, weight=1.0)]
    specs += [RequestSpec(arrival_s=0.3 * i, context_len=2048,
                          policy="sparkv", seed=i, weight=8.0)
              for i in range(1, 6)]
    out = {}
    for disc in ("fifo", "wfq"):
        rep = make_cluster(run_queue=RunQueueModel(1, disc)).run(specs)
        shorts = [r.ttft_s for r in rep.records if r.spec.weight > 1]
        out[disc] = (rep.summary()["ttft_p99_s"],
                     float(np.percentile(shorts, 99)))
    p99_f, int_f = out["fifo"]
    p99_w, int_w = out["wfq"]
    assert abs(p99_f - p99_w) / max(p99_f, p99_w) > 0.005
    assert int_w < int_f * 0.99          # WFQ protects the weighted class


# ---------------------------------------------------------------------------
# two-stage NIC -> uplink topology
# ---------------------------------------------------------------------------

def test_two_stage_topology_end_to_end():
    specs = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="cachegen",
                         seed=i, device=i) for i in range(3)]
    rep = make_cluster(n_devices=3, nic="device-nic").run(specs)
    assert len(rep.records) == 3
    # three flows share the uplink: mean share must reflect contention
    assert all(r.uplink_share < 1.0 for r in rep.records)
    # NIC stage caps the single-flow rate: slower than the same fleet on
    # the bare uplink (deterministic given seeds)
    solo = make_cluster().run(specs[:1]).records[0]
    nic_solo = make_cluster(n_devices=1, nic="device-nic").run(
        [specs[0]]).records[0]
    assert nic_solo.stream_busy_s > solo.stream_busy_s


def test_device_out_of_range_rejected():
    with pytest.raises(AssertionError):
        make_cluster(n_devices=2).run(
            [RequestSpec(arrival_s=0.0, context_len=CTX, device=5)])


# ---------------------------------------------------------------------------
# telemetry-driven admission policy
# ---------------------------------------------------------------------------

def test_telemetry_policy_reads_live_servers():
    cl = make_cluster(run_queue=RunQueueModel(2, "fifo"))
    bw = BandwidthIntegrator(np.full(1000, 100e6), 0.01)
    cl._link_server = single_link(bw, cl.link)
    cl._run_queues = {0: DeviceRunQueue(2, "fifo")}
    spec = RequestSpec(arrival_s=0.0)
    assert telemetry_policy(spec, cl) == "sparkv"          # idle link
    for i in range(4):                                     # contended link
        cl._link_server.add(i, 1e6)
    assert telemetry_policy(spec, cl) == "local_prefill"
    for j in range(3):                                     # busy device too
        cl._run_queues[0].submit(("x", j), 1.0, 0.0)
    assert telemetry_policy(spec, cl) == "sparkv"


# ---------------------------------------------------------------------------
# SLO-aware admission (deadlines, shedding, downgrades, weight mapping)
# ---------------------------------------------------------------------------

def test_slo_noop_without_deadlines():
    """Arming the SLO policy must be bit-identical to slo=None when no
    request carries a deadline — for both FIFO and WFQ queues."""
    from repro.serving.slo import SLOPolicy
    specs = [RequestSpec(arrival_s=0.3 * i, context_len=CTX,
                         policy="sparkv", seed=i) for i in range(3)]
    for disc in ("fifo", "wfq"):
        base = make_cluster(run_queue=RunQueueModel(1, disc)).run(specs)
        slo = make_cluster(run_queue=RunQueueModel(1, disc),
                           slo=SLOPolicy()).run(specs)
        assert base.summary() == slo.summary(), disc
        assert [r.ttft_s for r in base.records] \
            == [r.ttft_s for r in slo.records], disc
        assert slo.summary()["slo_attainment"] is None
        assert slo.summary()["n_shed"] == 0


def test_slo_sheds_under_overload_and_reports():
    """Overload with tight deadlines: predicted violations are shed at
    admission, every shed is accounted for, and attainment over served
    deadline requests beats the FIFO-without-SLO fleet."""
    from repro.serving.slo import SLOPolicy
    specs = [RequestSpec(arrival_s=0.0, context_len=2 * CTX,
                         policy="sparkv", seed=0, slo_class="batch")]
    specs += [RequestSpec(arrival_s=0.4 * i, context_len=CTX,
                          policy="sparkv", seed=i, deadline_s=5.0,
                          slo_class="interactive")
              for i in range(1, 8)]
    plain = make_cluster(run_queue=RunQueueModel(1, "fifo")).run(specs)
    rep = make_cluster(run_queue=RunQueueModel(1, "srpt"),
                       slo=SLOPolicy()).run(specs)
    s = rep.summary()
    assert s["n_shed"] > 0
    assert len(rep.records) + s["n_shed"] == rep.n_arrived
    for sh in rep.shed:
        assert sh.spec.deadline_s is not None
        assert sh.pred_ttft_s > sh.spec.deadline_s   # a predicted miss
    served_dl = [r for r in rep.records if r.deadline_s is not None]
    if served_dl:
        att = s["slo_attainment"]
        assert att == sum(r.slo_met for r in served_dl) / len(served_dl)
        assert att >= plain.summary()["slo_attainment"]
        # arrived-denominator attainment counts shed as misses
        n_met = sum(r.slo_met for r in served_dl)
        assert s["slo_attainment_arrived"] == pytest.approx(
            n_met / (len(served_dl) + s["n_shed"]))
        assert s["slo_attainment_arrived"] <= att
    # goodput-under-SLO only counts in-contract work
    assert s["goodput_slo_rps"] <= s["goodput_rps"] + 1e-12


def test_slo_downgrade_marks_records_and_quality():
    """A stream-bound fleet under deadline pressure downgrades some
    requests to coarser bits: records carry the effective width and the
    fidelity hit shows up in the quality score."""
    from repro.serving.slo import SLOPolicy
    specs = [RequestSpec(arrival_s=0.2 * i, context_len=2 * CTX,
                         policy="strong_hybrid", seed=i, deadline_s=9.0,
                         slo_class="interactive") for i in range(8)]
    rep = make_cluster(run_queue=RunQueueModel(2, "wfq"),
                       slo=SLOPolicy()).run(specs)
    down = [r for r in rep.records if r.downgraded]
    assert down, "scenario produced no downgrades"
    assert rep.summary()["n_downgraded"] == len(down)
    full = [r for r in rep.records if not r.downgraded]
    for r in down:
        assert r.quant_bits < SP.quant_bits
        assert r.quant_bits in (4, 3)
    if full and any(r.n_streamed for r in down):
        assert min(r.quality for r in down) \
            < max(r.quality for r in full) + 1e-12


def test_slo_deadline_weight_mapping_protects_interactive():
    """With WFQ, deadline slack maps to the weight class: the same trace
    with the mapping disabled (empty bins -> weight 1) gives the
    deadline class worse TTFTs."""
    from repro.serving.slo import SLOPolicy
    specs = [RequestSpec(arrival_s=0.0, context_len=2 * CTX,
                         policy="sparkv", seed=0)]
    specs += [RequestSpec(arrival_s=0.3 * i, context_len=CTX,
                          policy="sparkv", seed=i, deadline_s=8.0)
              for i in range(1, 6)]
    out = {}
    for label, bins in (("mapped", ((10.0, 8.0),)), ("flat", ())):
        pol = SLOPolicy(shed=False, downgrade=False, weight_bins=bins)
        rep = make_cluster(run_queue=RunQueueModel(1, "wfq"),
                           slo=pol).run(specs)
        ints = [r.ttft_s for r in rep.records if r.deadline_s is not None]
        assert len(ints) == 5, label                 # nothing shed
        out[label] = float(np.mean(ints))
    assert out["mapped"] < out["flat"]


def test_slo_met_flag_consistent():
    from repro.serving.slo import SLOPolicy
    specs = [RequestSpec(arrival_s=0.2 * i, context_len=CTX,
                         policy="sparkv", seed=i, deadline_s=20.0)
             for i in range(3)]
    rep = make_cluster(run_queue=RunQueueModel(2, "fifo"),
                       slo=SLOPolicy()).run(specs)
    for r in rep.records:
        assert r.slo_met == (r.ttft_s <= r.deadline_s)
        assert r.deadline_s == 20.0
    assert rep.summary()["slo_attainment"] == \
        sum(r.slo_met for r in rep.records) / len(rep.records)


# ---------------------------------------------------------------------------
# three-hop cloud-egress tree + asymmetric NICs
# ---------------------------------------------------------------------------

FAT_EGRESS = NetworkProfile("egress-fat", 1e15, 0.0)   # never binds


def _tree_specs(n):
    return [RequestSpec(arrival_s=0.2 * i, context_len=CTX,
                        policy="cachegen", seed=i, device=i % 3)
            for i in range(n)]


def test_three_hop_unconstrained_egress_bit_identical():
    """Cluster-level degenerate parity: a three-hop tree whose egress
    can never bind reproduces the two-stage fleet bit-for-bit."""
    specs = _tree_specs(4)
    base = make_cluster(n_devices=3, nic="device-nic").run(specs)
    tree = make_cluster(n_devices=3, nic="device-nic",
                        egress=FAT_EGRESS).run(specs)
    assert [r.ttft_s for r in base.records] \
        == [r.ttft_s for r in tree.records]
    assert [r.energy_j for r in base.records] \
        == [r.energy_j for r in tree.records]
    # the egress share telemetry exists on the tree run only
    assert all("egress" in r.stage_shares for r in tree.records
               if r.n_streamed)
    assert all("egress" not in r.stage_shares for r in base.records)


def test_asymmetric_identical_nic_profiles_bit_identical():
    """`nic=[p, p, p]` is the symmetric `nic=p` path bit-for-bit."""
    specs = _tree_specs(4)
    sym = make_cluster(n_devices=3, nic="device-nic").run(specs)
    asym = make_cluster(n_devices=3, nic=["device-nic"] * 3).run(specs)
    assert sym.summary() == asym.summary()
    assert [r.ttft_s for r in sym.records] \
        == [r.ttft_s for r in asym.records]


def test_asymmetric_nics_slow_class_streams_slower():
    """A genuinely slower NIC class shows up in per-device stream time."""
    slow = NetworkProfile("nic-slow", 150e6 / 8, 20e6 / 8)
    specs = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="cachegen",
                         seed=i, device=i) for i in range(2)]
    rep = make_cluster(n_devices=2,
                       nic=["device-nic", slow]).run(specs)
    fast_r, slow_r = rep.records
    assert slow_r.stream_busy_s > fast_r.stream_busy_s * 1.5


def test_starved_egress_slows_fleet_vs_generous():
    starved = NetworkProfile("egress-starved", 160e6 / 8, 20e6 / 8)
    specs = _tree_specs(5)
    fat = make_cluster(n_devices=3, nic="device-nic", n_aps=2,
                       egress=FAT_EGRESS).run(specs)
    thin = make_cluster(n_devices=3, nic="device-nic", n_aps=2,
                        egress=starved).run(specs)
    assert thin.summary()["ttft_mean_s"] > fat.summary()["ttft_mean_s"]
    shares = [r.stage_shares["egress"] for r in thin.records
              if "egress" in r.stage_shares]
    assert shares and all(s <= 1.0 for s in shares)


def test_multi_ap_splits_uplink_contention():
    """Two APs serve a NIC'd fleet faster than one congested AP."""
    specs = _tree_specs(4)
    one = make_cluster(n_devices=3, nic="device-nic", n_aps=1).run(specs)
    two = make_cluster(n_devices=3, nic="device-nic", n_aps=2).run(specs)
    assert two.summary()["ttft_mean_s"] < one.summary()["ttft_mean_s"]


def test_ap_assignment_validation():
    with pytest.raises(AssertionError):
        make_cluster(n_devices=2, n_aps=2, ap_of_device=(0, 5))
    with pytest.raises(AssertionError):
        make_cluster(n_devices=2, n_aps=2, ap_of_device=(0,))
    cl = make_cluster(n_devices=4, n_aps=2)
    assert cl.ap_of_device == (0, 1, 0, 1)    # round-robin default


def test_telemetry_policy_end_to_end_mixes_fleet():
    specs = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                         seed=i) for i in range(6)]
    rep = make_cluster(run_queue=RunQueueModel(4, "fifo"),
                       policy_fn=telemetry_policy).run(specs)
    pols = [r.policy for r in rep.records]
    assert pols[0] == "sparkv"                  # first admit sees idle link
    assert "local_prefill" in pols              # later admits see contention
    assert len(rep.records) == 6
