"""Resource-server layer: DeviceRunQueue disciplines, LinkTopology
stage composition, and degenerate parity with the PR 1 arbiter."""
import numpy as np
import pytest

from repro.core.costs import NETWORKS, RunQueueModel, SharedLinkModel
from repro.core.engine import BandwidthIntegrator, LinkStarvedError
from repro.serving.cluster import SharedLinkArbiter
from repro.serving.resources import (DeviceRunQueue, LinkStage, LinkTopology,
                                     nic_uplink_topology, single_link,
                                     tree_path, tree_topology,
                                     uplink_stage_name)

NET = NETWORKS["campus-wifi"]


def flat_bw(bps, n=5000, dt=0.01):
    return BandwidthIntegrator(np.full(n, bps), dt)


# ---------------------------------------------------------------------------
# DeviceRunQueue
# ---------------------------------------------------------------------------

def test_runqueue_fifo_waits_and_order():
    rq = DeviceRunQueue(capacity=1, discipline="fifo")
    assert rq.submit("a", 1.0, 0.0, flow=0) == 0.0        # starts at once
    assert rq.submit("b", 1.0, 0.1, flow=1) is None       # queued
    assert rq.submit("c", 1.0, 0.2, flow=2) is None
    assert rq.depth() == 2 and rq.in_service() == 1 and rq.load() == 3
    t_end, key = rq.next_completion()
    assert (t_end, key) == (1.0, "a")
    started = rq.complete("a", 1.0)
    assert started == [("b", 1.0, 1.0)]                   # FIFO: b before c
    assert rq.complete("b", 2.0) == [("c", 2.0, 1.0)]
    assert rq.complete("c", 3.0) == []
    # waits: a started immediately, b waited 0.9, c waited 1.8
    assert np.allclose(rq.waits, [0.0, 0.9, 1.8])
    assert rq.busy_s == 3.0


def test_runqueue_capacity_parallel_slots():
    rq = DeviceRunQueue(capacity=2)
    assert rq.submit("a", 2.0, 0.0) == 0.0
    assert rq.submit("b", 1.0, 0.0) == 0.0                # second slot
    assert rq.submit("c", 1.0, 0.0) is None
    t_end, key = rq.next_completion()
    assert (t_end, key) == (1.0, "b")                     # earliest finish
    assert rq.complete("b", 1.0) == [("c", 1.0, 1.0)]


def test_runqueue_wfq_weight_share():
    """Under backlog (>= 2 competing flows queued at every completion, as
    engine sessions do: one outstanding chunk each) a weight-3 flow gets
    3x the device time of each weight-1 flow."""
    rq = DeviceRunQueue(capacity=1, discipline="wfq")
    nxt = {0: 0, 1: 0, 2: 0}

    def resubmit(flow, t):
        key = (flow, nxt[flow])
        nxt[flow] += 1
        return rq.submit(key, 0.1, t, flow=flow,
                         weight=3.0 if flow == 0 else 1.0)

    for f in (0, 1, 2):
        resubmit(f, 0.0)
    served = {0: 0, 1: 0, 2: 0}
    for _ in range(60):
        t_end, key = rq.next_completion()
        served[key[0]] += 1
        rq.complete(key, t_end)
        resubmit(key[0], t_end)              # keep the flow backlogged
    assert served == {0: 30, 1: 15, 2: 15}   # exact 3:1:1 WFQ shares


def test_runqueue_wfq_newcomer_does_not_starve_veteran():
    """A flow that ran alone must not be starved when new flows arrive:
    idle time is not banked as credit (the newcomers' attained service is
    floored near the veteran's level), so shares equalize immediately."""
    rq = DeviceRunQueue(capacity=1, discipline="wfq")
    nxt: dict = {}

    def resubmit(flow, t):
        key = (flow, nxt.get(flow, 0))
        nxt[flow] = nxt.get(flow, 0) + 1
        return rq.submit(key, 1.0, t, flow=flow, weight=1.0)

    resubmit(0, 0.0)
    t = 0.0
    for _ in range(100):                      # flow 0 runs alone for 100 s
        t, key = rq.next_completion()
        rq.complete(key, t)
        resubmit(0, t)
    resubmit(1, t)
    resubmit(2, t)
    served = {0: 0, 1: 0, 2: 0}
    for _ in range(300):
        t, key = rq.next_completion()
        served[key[0]] += 1
        rq.complete(key, t)
        resubmit(key[0], t)
    assert min(served.values()) >= 90         # ~100 each, no starvation


def test_runqueue_fifo_ignores_weights():
    rq = DeviceRunQueue(capacity=1, discipline="fifo")
    rq.submit("a", 1.0, 0.0, flow=0, weight=1.0)
    rq.submit("b", 1.0, 0.0, flow=1, weight=100.0)
    rq.submit("c", 1.0, 0.0, flow=2, weight=10.0)
    assert rq.complete("a", 1.0)[0][0] == "b"             # submit order


def test_runqueue_model_validation():
    with pytest.raises(AssertionError):
        RunQueueModel(capacity=0)
    with pytest.raises(AssertionError):
        RunQueueModel(discipline="lifo")
    assert RunQueueModel(2, "wfq").capacity == 2
    assert RunQueueModel(1, "srpt").discipline == "srpt"


def test_runqueue_backlog_telemetry():
    rq = DeviceRunQueue(capacity=1, discipline="fifo")
    assert rq.backlog_s() == 0.0
    rq.submit("a", 2.0, 0.0)                              # in service
    rq.submit("b", 1.0, 0.0)                              # queued
    rq.submit("c", 0.5, 0.0)                              # queued
    assert rq.backlog_s() == pytest.approx(3.5)
    rq.complete("a", 2.0)                                 # b starts
    assert rq.backlog_s() == pytest.approx(1.5)


def test_runqueue_srpt_shortest_remaining_first():
    """At every dispatch the queued job whose flow has the least
    remaining service starts next, regardless of submit order."""
    rq = DeviceRunQueue(capacity=1, discipline="srpt")
    assert rq.submit("first", 1.0, 0.0, flow=0, remaining_s=3.0) == 0.0
    rq.submit("long", 1.0, 0.1, flow=1, remaining_s=10.0)
    rq.submit("short", 1.0, 0.2, flow=2, remaining_s=2.0)
    rq.submit("mid", 1.0, 0.3, flow=3, remaining_s=5.0)
    assert rq.complete("first", 1.0)[0][0] == "short"
    assert rq.complete("short", 2.0)[0][0] == "mid"
    assert rq.complete("mid", 3.0)[0][0] == "long"


def test_runqueue_srpt_remaining_defaults_to_duration():
    rq = DeviceRunQueue(capacity=1, discipline="srpt")
    rq.submit("a", 1.0, 0.0, flow=0)
    rq.submit("slow", 3.0, 0.0, flow=1)
    rq.submit("quick", 0.5, 0.0, flow=2)
    assert rq.complete("a", 1.0)[0][0] == "quick"


def test_runqueue_srpt_deadline_floor_prevents_starvation():
    """Pure SRPT starves a long flow behind an endless supply of short
    ones; the deadline floor promotes it (EDF) once its deadline is
    within `deadline_floor_s` of now — never past the deadline."""

    def drain(rq, t_long_must_start_by):
        """Feed short jobs forever; return when the long job starts."""
        rq.submit(("s", 0), 0.5, 0.0, flow="s0", remaining_s=0.5)
        rq.submit(("L", 0), 0.5, 0.0, flow="L", remaining_s=20.0,
                  deadline_s=4.0)                    # queued behind s0
        t, i = 0.0, 0
        while True:
            i += 1
            rq.submit(("s", i), 0.5, t, flow=f"s{i}", remaining_s=0.5)
            t, key = rq.next_completion()
            started = rq.complete(key, t)
            if any(k == ("L", 0) for k, _, _ in started):
                return t
            assert t < t_long_must_start_by, \
                f"long job not started by t={t}"

    # floor 1.0 s: the long job must be dispatched once t >= 3.0 (slack
    # hits the floor), well before its t=4.0 deadline
    t_start = drain(DeviceRunQueue(1, "srpt", deadline_floor_s=1.0),
                    t_long_must_start_by=4.0)
    assert 2.5 <= t_start <= 4.0


def test_runqueue_srpt_starves_without_deadline():
    """Control for the floor test: the same long flow with no deadline
    is still waiting after the horizon the floored queue met."""
    rq = DeviceRunQueue(1, "srpt", deadline_floor_s=1.0)
    rq.submit(("s", 0), 0.5, 0.0, flow="s0", remaining_s=0.5)
    rq.submit(("L", 0), 0.5, 0.0, flow="L", remaining_s=20.0)  # queued
    t = 0.0
    for i in range(1, 20):
        rq.submit(("s", i), 0.5, t, flow=f"s{i}", remaining_s=0.5)
        t, key = rq.next_completion()
        started = rq.complete(key, t)
        assert all(k != ("L", 0) for k, _, _ in started)
    assert t >= 4.0                       # starved well past the horizon


def test_runqueue_srpt_urgent_ties_break_by_earliest_deadline():
    rq = DeviceRunQueue(1, "srpt", deadline_floor_s=10.0)
    rq.submit("run", 1.0, 0.0, flow=0)
    rq.submit("late", 1.0, 0.0, flow=1, remaining_s=1.0, deadline_s=8.0)
    rq.submit("soon", 1.0, 0.0, flow=2, remaining_s=9.0, deadline_s=3.0)
    # both queued jobs are inside the (wide) floor -> EDF order wins
    # even though "late" has the shorter remaining time
    assert rq.complete("run", 1.0)[0][0] == "soon"


# ---------------------------------------------------------------------------
# LinkTopology: degenerate single-stage parity with SharedLinkArbiter
# ---------------------------------------------------------------------------

def test_single_stage_topology_matches_arbiter():
    """Same flows, same trace, same link model: identical completion
    times and remaining-byte trajectories (rtol 1e-5)."""
    link = SharedLinkModel(NET, contention_overhead=0.07)
    arb = SharedLinkArbiter(flat_bw(100e6), link=link)
    topo = single_link(flat_bw(100e6), link=link)
    rng = np.random.default_rng(5)
    events = [(0.0, "add", 0, 40e6), (0.1, "add", 1, 25e6),
              (0.25, "add", 2, 60e6)]
    for t, _, key, nbytes in events:
        for srv in (arb, topo):
            srv.advance(t)
            srv.add(key, nbytes)
    # drain both and compare completion sequences
    done_a, done_t = [], []
    for srv, out in ((arb, done_a), (topo, done_t)):
        while srv.n_active():
            t_done, key = srv.next_completion()
            srv.advance(t_done)
            srv.complete(key)
            out.append((key, t_done))
    assert [k for k, _ in done_a] == [k for k, _ in done_t]
    for (_, ta), (_, tt) in zip(done_a, done_t):
        assert np.isclose(ta, tt, rtol=1e-5)


def test_single_flow_single_stage_exact_rate():
    topo = single_link(flat_bw(100e6), link=SharedLinkModel(NET))
    topo.add(0, 50e6)
    t, k = topo.next_completion()
    assert k == 0 and abs(t - 0.5) < 1e-6                 # eta(1) == 1


# ---------------------------------------------------------------------------
# LinkTopology: two-stage composition
# ---------------------------------------------------------------------------

def test_two_stage_bottleneck_governs():
    """One flow through a slow NIC and a fast uplink drains at the NIC
    rate; two flows on distinct NICs sharing the uplink drain at the
    uplink fair share once it becomes the bottleneck."""
    nic_a, nic_b = flat_bw(40e6), flat_bw(40e6)
    uplink = flat_bw(60e6)
    topo = nic_uplink_topology([nic_a, nic_b], uplink, uplink_link=None)
    topo.add(0, 20e6, path=("nic0", "uplink"))
    t, k = topo.next_completion()
    assert k == 0 and abs(t - 0.5) < 1e-3                 # 40 MB/s NIC-bound
    # add a second flow: per-flow uplink share 30 MB/s < NIC 40 MB/s
    topo.add(1, 30e6, path=("nic1", "uplink"))
    t2, k2 = topo.next_completion()
    # flow 0 has 20e6 left at 30 MB/s -> ~0.667s total
    assert k2 == 0 and abs(t2 - 20e6 / 30e6) < 2e-2


def test_two_stage_advance_conserves_bytes():
    topo = nic_uplink_topology([flat_bw(40e6)], flat_bw(60e6))
    topo.add(0, 10e6, path=("nic0", "uplink"))
    topo.advance(0.1)                                     # 4 MB at NIC rate
    assert abs(topo._rem[0] - 6e6) < 1e4
    t, _ = topo.next_completion()
    assert abs(t - 0.25) < 1e-3


def test_topology_uplink_share_telemetry():
    topo = single_link(flat_bw(100e6), link=None)
    topo.add(0, 50e6)
    topo.advance(0.2)                                     # alone: share 1.0
    topo.add(1, 100e6)
    topo.advance(0.4)                                     # shared: 0.5
    assert abs(topo.mean_share(0) - (0.2 * 1.0 + 0.2 * 0.5) / 0.4) < 1e-9
    assert abs(topo.mean_share(1) - 0.5) < 1e-9


def test_topology_starved_raises():
    topo = nic_uplink_topology([flat_bw(0.0, n=100)], flat_bw(100e6))
    topo.add(0, 1e6, path=("nic0", "uplink"))
    with pytest.raises(LinkStarvedError):
        topo.next_completion()


def test_topology_rejects_mismatched_dt():
    with pytest.raises(AssertionError):
        LinkTopology({
            "a": LinkStage("a", BandwidthIntegrator(np.full(10, 1e6), 0.01)),
            "b": LinkStage("b", BandwidthIntegrator(np.full(10, 1e6), 0.02)),
        })


# ---------------------------------------------------------------------------
# LinkTopology: three-hop cloud-egress tree
# ---------------------------------------------------------------------------

def _drain_all(topo, flows):
    """Add (key, nbytes, path) flows at t=0, run to empty; returns the
    completion sequence [(key, t_done), ...]."""
    for key, nbytes, path in flows:
        topo.add(key, nbytes, path=path)
    done = []
    while topo.n_active():
        t, key = topo.next_completion()
        topo.advance(t)
        topo.complete(key)
        done.append((key, t))
    return done


def test_tree_stage_names_and_paths():
    assert uplink_stage_name(0, 1) == "uplink"        # single-AP: old name
    assert uplink_stage_name(1, 3) == "uplink1"
    assert tree_path(2, 1, 2, has_nic=True, has_egress=True) \
        == ("nic2", "uplink1", "egress")
    assert tree_path(0, 0, 1, has_nic=True, has_egress=False) \
        == ("nic0", "uplink")                         # two-stage parity
    assert tree_path(0, 0, 1, has_nic=False, has_egress=False) \
        == ("uplink",)                                # single-stage parity
    tree = tree_topology([flat_bw(40e6)] * 2, [flat_bw(60e6)] * 2, [0, 1],
                         flat_bw(80e6))
    assert set(tree.stages) == {"nic0", "nic1", "uplink0", "uplink1",
                                "egress"}
    with pytest.raises(AssertionError):
        tree_topology([flat_bw(40e6)], [flat_bw(60e6)], [1])  # AP range


def test_tree_unconstrained_egress_reproduces_two_stage_trace():
    """Satellite parity: the three-hop tree with an egress stage far
    above every per-flow share yields the exact two-stage completion
    trace — same order, same times, bit-for-bit."""
    flows = [(0, 30e6, ("nic0", "uplink")), (1, 45e6, ("nic1", "uplink")),
             (2, 20e6, ("nic0", "uplink"))]
    two = nic_uplink_topology([flat_bw(40e6), flat_bw(40e6)],
                              flat_bw(60e6),
                              uplink_link=SharedLinkModel(NET))
    tree = tree_topology([flat_bw(40e6), flat_bw(40e6)], [flat_bw(60e6)],
                         [0, 0], flat_bw(1e15),
                         uplink_link=SharedLinkModel(NET))
    done_two = _drain_all(two, flows)
    done_tree = _drain_all(
        tree, [(k, b, p + ("egress",)) for k, b, p in flows])
    assert [k for k, _ in done_two] == [k for k, _ in done_tree]
    for (_, ta), (_, tb) in zip(done_two, done_tree):
        assert ta == tb                               # bit-for-bit


def test_tree_starved_egress_governs_every_flow():
    """Two flows on distinct NICs and distinct AP uplinks still drain at
    the shared egress fair share when the egress is the bottleneck."""
    tree = tree_topology([flat_bw(40e6)] * 2, [flat_bw(60e6)] * 2, [0, 1],
                         flat_bw(20e6))
    tree.add(0, 10e6, path=("nic0", "uplink0", "egress"))
    tree.add(1, 10e6, path=("nic1", "uplink1", "egress"))
    t, _ = tree.next_completion()
    # egress share 10 MB/s each (ideal sharing): 10 MB in ~1 s, not the
    # 0.25 s the NICs alone would take
    assert abs(t - 1.0) < 2e-2


def test_tree_multi_ap_isolates_uplink_contention():
    """Same two flows: one congested AP vs one AP each (no binding
    egress) — per-AP uplinks remove the cross-flow contention."""
    one_ap = tree_topology([flat_bw(100e6)] * 2, [flat_bw(50e6)], [0, 0])
    for k in range(2):
        one_ap.add(k, 25e6, path=("nic" + str(k), "uplink"))
    t_shared, _ = one_ap.next_completion()
    two_ap = tree_topology([flat_bw(100e6)] * 2, [flat_bw(50e6)] * 2,
                           [0, 1])
    for k in range(2):
        two_ap.add(k, 25e6, path=(f"nic{k}", f"uplink{k}"))
    t_split, _ = two_ap.next_completion()
    assert abs(t_shared - 1.0) < 2e-2                 # 25 MB/s fair share
    assert abs(t_split - 0.5) < 2e-2                  # full 50 MB/s each


def test_tree_stage_share_telemetry_per_stage():
    """stage_shares breaks the flow's received fraction down by stage;
    the egress entry reflects the fleet-wide crowd, the NIC entry stays
    exclusive (1.0)."""
    tree = tree_topology([flat_bw(40e6)] * 2, [flat_bw(60e6)] * 2, [0, 1],
                         flat_bw(30e6))
    tree.add(0, 5e6, path=("nic0", "uplink0", "egress"))
    tree.add(1, 5e6, path=("nic1", "uplink1", "egress"))
    t, key = tree.next_completion()
    tree.advance(t)
    shares = tree.stage_shares(key)
    assert set(shares) == {f"nic{key}", f"uplink{key}", "egress"}
    assert shares[f"nic{key}"] == 1.0                 # exclusive stage
    assert shares[f"uplink{key}"] == 1.0              # own AP
    assert abs(shares["egress"] - 0.5) < 1e-9         # two-flow crowd
    assert tree.mean_share(key) == shares["egress"]   # last stage
