"""End-to-end serving: concrete KV assembly through the real
quantize->Huffman->dequant path; response fidelity vs the exact cache."""
import numpy as np
import jax
import pytest

from repro.configs import SparKVConfig, get_smoke
from repro.models import build_model
from repro.serving.engine import SparKVServer


@pytest.fixture(scope="module")
def server():
    cfg = get_smoke("sparkv-qwen3-4b", layers=3, d_model=64, heads=4,
                    d_ff=128, vocab=256)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    spcfg = SparKVConfig(chunk_tokens=32, q_block=16, kv_block=16,
                         quant_group=32)
    srv = SparKVServer(model, params, spcfg, chunk_tokens=32)
    rng = np.random.default_rng(0)
    ctx = rng.integers(0, cfg.vocab_size, size=(1, 96))
    cid = srv.register_context(ctx)
    return srv, cid, rng


def test_register_context_compresses(server):
    srv, cid, _ = server
    st = srv.contexts[cid]
    raw = st.exact_k.nbytes + st.exact_v.nbytes
    assert st.wl.total_bytes() < raw / 3       # 5-bit + entropy < fp32/3


@pytest.mark.parametrize("policy", ["sparkv", "cachegen", "local_prefill",
                                    "strong_hybrid"])
def test_serve_fidelity(server, policy):
    srv, cid, rng = server
    prompt = rng.integers(0, 256, size=3)
    res = srv.generate(cid, prompt, max_new=5, policy=policy, seed=1)
    assert res.top1_agreement >= 0.8
    assert res.mean_kl < 0.5
    n = srv.contexts[cid].n_chunks
    assert res.n_streamed + res.n_computed == n
    if policy == "local_prefill":
        assert res.n_streamed == 0 and res.top1_agreement == 1.0


def test_streamed_bitstreams_roundtrip_exactly(server):
    """Every streamed chunk decodes to exactly the quantized codes."""
    srv, cid, _ = server
    # load_context asserts bitstream equality internally
    cache, res = srv.load_context(cid, policy="cachegen")
    assert res.engine.n_streamed == srv.contexts[cid].n_chunks
    # quantization error bound: cache vs exact within 5-bit step
    st = srv.contexts[cid]
    err = np.abs(np.asarray(cache["k"], np.float32) - st.exact_k).max()
    scale_bound = max(np.abs(st.exact_k).max(),
                      np.abs(st.exact_v).max()) / 31
    assert err <= scale_bound * 2 + 1e-4


def test_utilization_tracking(server):
    srv, _, _ = server
    assert srv.utilization() == 0.0


def test_serve_fleet_concurrent_contexts(server):
    """Registered contexts submitted into the multi-request cluster."""
    srv, cid, _ = server
    jobs = [(cid, 0.0, "sparkv"), (cid, 0.0, "cachegen"),
            (cid, 0.05, "local_prefill")]
    rep = srv.serve_fleet(jobs, closed_loop=True)
    assert len(rep.records) == 3
    n = srv.contexts[cid].n_chunks
    for r in rep.records:
        assert r.n_streamed + r.n_computed == n
        assert r.ttft_s > 0 and r.energy_j > 0
    s = rep.summary()
    assert s["goodput_rps"] > 0 and s["ttft_p50_s"] <= s["ttft_p99_s"]
