"""Pallas kernel validation (interpret=True) against pure-jnp oracles:
shape/dtype sweeps + hypothesis-driven randomized cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# real hypothesis in CI; deterministic stub from tests/_vendor otherwise
# (wired by conftest.py) — the suite never skips
from hypothesis import given, settings, strategies as st

from repro.compression.quantize import dequantize, quantize
from repro.kernels.block_sparse_attn.kernel import block_sparse_attention
from repro.kernels.block_sparse_attn.ref import block_sparse_attention_ref
from repro.kernels.decode_attn.kernel import decode_attention
from repro.kernels.decode_attn.ref import decode_attention_ref
from repro.kernels.kv_dequant.kernel import kv_dequant
from repro.kernels.kv_dequant.ref import kv_dequant_ref
from repro.kernels.kv_dequant.ops import dequantize_chunk
from repro.sparse.mask import block_scores, select_blocks

KEYS = jax.random.split(jax.random.PRNGKey(7), 8)


def _mask_for(q, k, mass, qb, kb, causal=True):
    sc = block_scores(q, k, q_block=qb, kv_block=kb, causal=causal)
    return select_blocks(sc, mass=mass, q_block=qb, kv_block=kb)


@pytest.mark.parametrize("bh,s,d,dtype", [
    (4, 512, 64, jnp.float32),
    (2, 1024, 128, jnp.float32),
    (2, 256, 128, jnp.bfloat16),
    (6, 384, 64, jnp.float32),
])
def test_block_sparse_attention_vs_ref(bh, s, d, dtype):
    q = jax.random.normal(KEYS[0], (bh, s, d), dtype)
    k = jax.random.normal(KEYS[1], (bh, s, d), dtype)
    v = jax.random.normal(KEYS[2], (bh, s, d), dtype)
    qb = kb = 128
    idx, cnt = _mask_for(q, k, 0.9, qb, kb)
    out = block_sparse_attention(q, k, v, idx, cnt, causal=True,
                                 q_block=qb, kv_block=kb, interpret=True)
    ref = block_sparse_attention_ref(q, k, v, idx, cnt, causal=True,
                                     q_block=qb, kv_block=kb)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("g", [2, 4, 8])
def test_block_sparse_attention_gqa(g):
    bh_kv, s, d = 2, 256, 64
    q = jax.random.normal(KEYS[3], (bh_kv * g, s, d), jnp.float32)
    k = jax.random.normal(KEYS[4], (bh_kv, s, d), jnp.float32)
    v = jax.random.normal(KEYS[5], (bh_kv, s, d), jnp.float32)
    kr = jnp.repeat(k, g, axis=0)
    vr = jnp.repeat(v, g, axis=0)
    idx, cnt = _mask_for(q, kr, 0.95, 128, 128)
    out = block_sparse_attention(q, k, v, idx, cnt, causal=True,
                                 kv_group=g, interpret=True)
    ref = block_sparse_attention_ref(q, kr, vr, idx, cnt, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_block_sparse_full_mask_equals_dense():
    """With every block active, the kernel reduces to causal attention."""
    bh, s, d = 2, 256, 64
    q = jax.random.normal(KEYS[0], (bh, s, d), jnp.float32)
    k = jax.random.normal(KEYS[1], (bh, s, d), jnp.float32)
    v = jax.random.normal(KEYS[2], (bh, s, d), jnp.float32)
    n_b = s // 128
    idx = jnp.broadcast_to(jnp.arange(n_b), (bh, n_b, n_b)).astype(jnp.int32)
    cnt = jnp.broadcast_to(jnp.arange(1, n_b + 1), (bh, n_b)).astype(jnp.int32)
    out = block_sparse_attention(q, k, v, idx, cnt, causal=True,
                                 interpret=True)
    # dense causal oracle
    sc = jnp.einsum("bqd,bkd->bqk", q, k) * d ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask, sc, -jnp.inf)
    ref = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(sc, axis=-1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,skv,d,klen,blk", [
    (2, 8, 2, 512, 64, 400, 256),
    (1, 4, 4, 1024, 128, 1024, 256),
    (3, 16, 2, 768, 128, 700, 128),
    (2, 8, 1, 512, 256, 333, 512),
])
def test_decode_attention_vs_ref(b, hq, hkv, skv, d, klen, blk):
    q = jax.random.normal(KEYS[0], (b, hq, d), jnp.float32)
    k = jax.random.normal(KEYS[1], (b, skv, hkv, d), jnp.float32)
    v = jax.random.normal(KEYS[2], (b, skv, hkv, d), jnp.float32)
    out = decode_attention(q, k, v, klen, kv_block=blk, interpret=True)
    ref = decode_attention_ref(q, k, v, klen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("n,width,group,bits", [
    (64, 128, 64, 5), (100, 512, 64, 4), (7, 256, 128, 8), (1024, 128, 32, 3),
])
def test_kv_dequant_vs_ref(n, width, group, bits, rng):
    codes = rng.integers(0, 1 << bits, size=(n, width)).astype(np.uint8)
    g = width // group
    scales = rng.uniform(0.01, 0.2, (n, g)).astype(np.float32)
    zeros = rng.normal(size=(n, g)).astype(np.float32)
    out = kv_dequant(jnp.asarray(codes), jnp.asarray(scales),
                     jnp.asarray(zeros), group=group, interpret=True,
                     out_dtype=jnp.float32)
    ref = kv_dequant_ref(jnp.asarray(codes), jnp.asarray(scales),
                         jnp.asarray(zeros), group=group,
                         out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@settings(max_examples=15, deadline=None, derandomize=True)
@given(st.integers(50, 4000), st.integers(2, 8), st.sampled_from([32, 64]))
def test_dequant_roundtrip_hypothesis(n_vals, bits, group):
    rng = np.random.default_rng(n_vals * 31 + bits)
    x = rng.normal(size=n_vals).astype(np.float32)
    qt = quantize(x, bits, group)
    host = dequantize(qt)
    dev = np.asarray(dequantize_chunk(qt, out_dtype=jnp.float32))
    np.testing.assert_allclose(dev, host, atol=1e-5)
    # quantization error bounded by half a step per group
    assert np.abs(host - x).max() <= qt.scales.max() * 0.51 + 1e-6


@settings(max_examples=10, deadline=None, derandomize=True)
@given(st.integers(1, 3), st.integers(128, 512), st.booleans())
def test_block_sparse_hypothesis(bh, s, causal):
    s = (s // 128) * 128
    if s == 0:
        return
    kk = jax.random.split(jax.random.PRNGKey(s * bh), 3)
    q = jax.random.normal(kk[0], (bh, s, 64), jnp.float32)
    k = jax.random.normal(kk[1], (bh, s, 64), jnp.float32)
    v = jax.random.normal(kk[2], (bh, s, 64), jnp.float32)
    idx, cnt = _mask_for(q, k, 0.85, 128, 128, causal=causal)
    out = block_sparse_attention(q, k, v, idx, cnt, causal=causal,
                                 interpret=True)
    ref = block_sparse_attention_ref(q, k, v, idx, cnt, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
