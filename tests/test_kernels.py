"""Pallas kernel validation (interpret=True) against pure-jnp oracles:
shape/dtype sweeps + hypothesis-driven randomized cases."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
# real hypothesis in CI; deterministic stub from tests/_vendor otherwise
# (wired by conftest.py) — the suite never skips
from hypothesis import given, settings, strategies as st

from repro.compression.quantize import dequantize, quantize
from repro.kernels.block_sparse_attn.kernel import block_sparse_attention
from repro.kernels.block_sparse_attn.ref import block_sparse_attention_ref
from repro.kernels.decode_attn.kernel import decode_attention
from repro.kernels.decode_attn.ref import decode_attention_ref
from repro.kernels.kv_dequant.kernel import kv_dequant, kv_dequant_mixed
from repro.kernels.kv_dequant.ref import kv_dequant_mixed_ref, kv_dequant_ref
from repro.kernels.kv_dequant.ops import (dequantize_chunk,
                                          dequantize_chunks_mixed)
from repro.sparse.mask import block_scores, select_blocks

KEYS = jax.random.split(jax.random.PRNGKey(7), 8)


def _mask_for(q, k, mass, qb, kb, causal=True):
    sc = block_scores(q, k, q_block=qb, kv_block=kb, causal=causal)
    return select_blocks(sc, mass=mass, q_block=qb, kv_block=kb)


@pytest.mark.parametrize("bh,s,d,dtype", [
    (4, 512, 64, jnp.float32),
    (2, 1024, 128, jnp.float32),
    (2, 256, 128, jnp.bfloat16),
    (6, 384, 64, jnp.float32),
])
def test_block_sparse_attention_vs_ref(bh, s, d, dtype):
    q = jax.random.normal(KEYS[0], (bh, s, d), dtype)
    k = jax.random.normal(KEYS[1], (bh, s, d), dtype)
    v = jax.random.normal(KEYS[2], (bh, s, d), dtype)
    qb = kb = 128
    idx, cnt = _mask_for(q, k, 0.9, qb, kb)
    out = block_sparse_attention(q, k, v, idx, cnt, causal=True,
                                 q_block=qb, kv_block=kb, interpret=True)
    ref = block_sparse_attention_ref(q, k, v, idx, cnt, causal=True,
                                     q_block=qb, kv_block=kb)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol)


@pytest.mark.parametrize("g", [2, 4, 8])
def test_block_sparse_attention_gqa(g):
    bh_kv, s, d = 2, 256, 64
    q = jax.random.normal(KEYS[3], (bh_kv * g, s, d), jnp.float32)
    k = jax.random.normal(KEYS[4], (bh_kv, s, d), jnp.float32)
    v = jax.random.normal(KEYS[5], (bh_kv, s, d), jnp.float32)
    kr = jnp.repeat(k, g, axis=0)
    vr = jnp.repeat(v, g, axis=0)
    idx, cnt = _mask_for(q, kr, 0.95, 128, 128)
    out = block_sparse_attention(q, k, v, idx, cnt, causal=True,
                                 kv_group=g, interpret=True)
    ref = block_sparse_attention_ref(q, kr, vr, idx, cnt, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_block_sparse_full_mask_equals_dense():
    """With every block active, the kernel reduces to causal attention."""
    bh, s, d = 2, 256, 64
    q = jax.random.normal(KEYS[0], (bh, s, d), jnp.float32)
    k = jax.random.normal(KEYS[1], (bh, s, d), jnp.float32)
    v = jax.random.normal(KEYS[2], (bh, s, d), jnp.float32)
    n_b = s // 128
    idx = jnp.broadcast_to(jnp.arange(n_b), (bh, n_b, n_b)).astype(jnp.int32)
    cnt = jnp.broadcast_to(jnp.arange(1, n_b + 1), (bh, n_b)).astype(jnp.int32)
    out = block_sparse_attention(q, k, v, idx, cnt, causal=True,
                                 interpret=True)
    # dense causal oracle
    sc = jnp.einsum("bqd,bkd->bqk", q, k) * d ** -0.5
    mask = jnp.tril(jnp.ones((s, s), bool))
    sc = jnp.where(mask, sc, -jnp.inf)
    ref = jnp.einsum("bqk,bkd->bqd", jax.nn.softmax(sc, axis=-1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("b,hq,hkv,skv,d,klen,blk", [
    (2, 8, 2, 512, 64, 400, 256),
    (1, 4, 4, 1024, 128, 1024, 256),
    (3, 16, 2, 768, 128, 700, 128),
    (2, 8, 1, 512, 256, 333, 512),
])
def test_decode_attention_vs_ref(b, hq, hkv, skv, d, klen, blk):
    q = jax.random.normal(KEYS[0], (b, hq, d), jnp.float32)
    k = jax.random.normal(KEYS[1], (b, skv, hkv, d), jnp.float32)
    v = jax.random.normal(KEYS[2], (b, skv, hkv, d), jnp.float32)
    out = decode_attention(q, k, v, klen, kv_block=blk, interpret=True)
    ref = decode_attention_ref(q, k, v, klen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("n,width,group,bits", [
    (64, 128, 64, 5), (100, 512, 64, 4), (7, 256, 128, 8), (1024, 128, 32, 3),
])
def test_kv_dequant_vs_ref(n, width, group, bits, rng):
    codes = rng.integers(0, 1 << bits, size=(n, width)).astype(np.uint8)
    g = width // group
    scales = rng.uniform(0.01, 0.2, (n, g)).astype(np.float32)
    zeros = rng.normal(size=(n, g)).astype(np.float32)
    out = kv_dequant(jnp.asarray(codes), jnp.asarray(scales),
                     jnp.asarray(zeros), group=group, interpret=True,
                     out_dtype=jnp.float32)
    ref = kv_dequant_ref(jnp.asarray(codes), jnp.asarray(scales),
                         jnp.asarray(zeros), group=group,
                         out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)


@pytest.mark.parametrize("n,width,group,rows_blk", [
    (37, 128, 64, 16),    # 37 % 16 = 5: ragged final grid block
    (255, 256, 64, 64),   # 255 % 64 = 63
    (129, 128, 32, 128),  # one full block + a single ragged row
    (5, 192, 64, 8),      # n < rows_blk entirely (rows_blk clamped)
])
def test_kv_dequant_ragged_grid(n, width, group, rows_blk, rng):
    """n % rows_blk != 0: the final grid block is ragged; the kernel must
    still match the oracle exactly on every row."""
    bits = 5
    codes = rng.integers(0, 1 << bits, size=(n, width)).astype(np.uint8)
    g = width // group
    scales = rng.uniform(0.01, 0.2, (n, g)).astype(np.float32)
    zeros = rng.normal(size=(n, g)).astype(np.float32)
    out = kv_dequant(jnp.asarray(codes), jnp.asarray(scales),
                     jnp.asarray(zeros), group=group, rows_blk=rows_blk,
                     interpret=True, out_dtype=jnp.float32)
    ref = kv_dequant_ref(jnp.asarray(codes), jnp.asarray(scales),
                         jnp.asarray(zeros), group=group,
                         out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    # ragged tiling must be exactly the single-block launch (same kernel,
    # no padding leakage into valid rows)
    whole = kv_dequant(jnp.asarray(codes), jnp.asarray(scales),
                       jnp.asarray(zeros), group=group, rows_blk=n,
                       interpret=True, out_dtype=jnp.float32)
    assert np.array_equal(np.asarray(out), np.asarray(whole))


@pytest.mark.parametrize("n,width,group,rows_blk", [
    (64, 128, 64, 32), (53, 256, 64, 16), (7, 128, 32, 256),
])
def test_kv_dequant_mixed_vs_ref(n, width, group, rows_blk, rng):
    """Mixed-bitwidth kernel vs numpy/jnp oracle: heterogeneous per-row
    widths, exact equality in fp32 (ragged grids included)."""
    g = width // group
    bits = rng.choice([3, 4, 5, 6, 8], size=(n, 1)).astype(np.int32)
    codes = (rng.integers(0, 256, size=(n, width)) %
             (1 << bits)).astype(np.uint8)
    spans = rng.uniform(0.1, 4.0, (n, g)).astype(np.float32)
    zeros = rng.normal(size=(n, g)).astype(np.float32)
    out = kv_dequant_mixed(jnp.asarray(codes), jnp.asarray(spans),
                           jnp.asarray(zeros), jnp.asarray(bits),
                           group=group, rows_blk=rows_blk, interpret=True,
                           out_dtype=jnp.float32)
    ref = kv_dequant_mixed_ref(jnp.asarray(codes), jnp.asarray(spans),
                               jnp.asarray(zeros), jnp.asarray(bits),
                               group=group, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=1e-6)
    whole = kv_dequant_mixed(jnp.asarray(codes), jnp.asarray(spans),
                             jnp.asarray(zeros), jnp.asarray(bits),
                             group=group, rows_blk=n, interpret=True,
                             out_dtype=jnp.float32)
    assert np.array_equal(np.asarray(out), np.asarray(whole))


def test_kv_dequant_mixed_uniform_bits_parity(rng):
    """A uniform-bits mixed launch is BIT-IDENTICAL to the single-bits
    kernel fed the host-computed scales: the kernel's span / (2^b - 1)
    is the same IEEE fp32 division quantize() performed on the host."""
    n, width, group, b = 96, 256, 64, 5
    g = width // group
    codes = rng.integers(0, 1 << b, size=(n, width)).astype(np.uint8)
    spans = rng.uniform(0.1, 4.0, (n, g)).astype(np.float32)
    zeros = rng.normal(size=(n, g)).astype(np.float32)
    scales = (spans / np.float32((1 << b) - 1)).astype(np.float32)
    bits = np.full((n, 1), b, np.int32)
    mixed = kv_dequant_mixed(jnp.asarray(codes), jnp.asarray(spans),
                             jnp.asarray(zeros), jnp.asarray(bits),
                             group=group, interpret=True,
                             out_dtype=jnp.float32)
    single = kv_dequant(jnp.asarray(codes), jnp.asarray(scales),
                        jnp.asarray(zeros), group=group, interpret=True,
                        out_dtype=jnp.float32)
    assert np.array_equal(np.asarray(mixed), np.asarray(single))


def test_dequantize_chunks_mixed_parity(rng):
    """One mixed launch over chunks of heterogeneous widths returns, per
    chunk, exactly the per-chunk single-bits launch (fp32) and stays
    within rtol 1e-5 of the host dequantize at bf16."""
    shapes = [(64, 48), (7, 33), (128, 64), (19, 5)]
    widths = [8, 3, 5, 4]
    qts = [quantize(rng.normal(size=s).astype(np.float32), b, 64)
           for s, b in zip(shapes, widths)]
    mixed = dequantize_chunks_mixed(qts, out_dtype=jnp.float32)
    for qt, m in zip(qts, mixed):
        single = np.asarray(dequantize_chunk(qt, out_dtype=jnp.float32))
        assert np.array_equal(np.asarray(m), single)
        np.testing.assert_allclose(np.asarray(m), dequantize(qt),
                                   atol=1e-5)
    mixed_bf = dequantize_chunks_mixed(qts, out_dtype=jnp.bfloat16)
    for qt, m in zip(qts, mixed_bf):
        np.testing.assert_allclose(np.asarray(m, np.float32),
                                   dequantize(qt), rtol=1e-5,
                                   atol=qt.scales.max() * 0.02 + 1e-2)


def test_dequantize_chunks_mixed_legacy_spans(rng):
    """Pre-spans QuantizedTensors (spans=None) still go through the
    mixed path via reconstruction from scales."""
    import dataclasses
    qt = quantize(rng.normal(size=(32, 32)).astype(np.float32), 4, 64)
    legacy = dataclasses.replace(qt, spans=None)
    (m,) = dequantize_chunks_mixed([legacy], out_dtype=jnp.float32)
    single = np.asarray(dequantize_chunk(qt, out_dtype=jnp.float32))
    assert np.array_equal(np.asarray(m), single)


@settings(max_examples=15, deadline=None, derandomize=True)
@given(st.integers(50, 4000), st.integers(2, 8), st.sampled_from([32, 64]))
def test_dequant_roundtrip_hypothesis(n_vals, bits, group):
    rng = np.random.default_rng(n_vals * 31 + bits)
    x = rng.normal(size=n_vals).astype(np.float32)
    qt = quantize(x, bits, group)
    host = dequantize(qt)
    dev = np.asarray(dequantize_chunk(qt, out_dtype=jnp.float32))
    np.testing.assert_allclose(dev, host, atol=1e-5)
    # quantization error bounded by half a step per group
    assert np.abs(host - x).max() <= qt.scales.max() * 0.51 + 1e-6


@settings(max_examples=10, deadline=None, derandomize=True)
@given(st.integers(1, 3), st.integers(128, 512), st.booleans())
def test_block_sparse_hypothesis(bh, s, causal):
    s = (s // 128) * 128
    if s == 0:
        return
    kk = jax.random.split(jax.random.PRNGKey(s * bh), 3)
    q = jax.random.normal(kk[0], (bh, s, 64), jnp.float32)
    k = jax.random.normal(kk[1], (bh, s, 64), jnp.float32)
    v = jax.random.normal(kk[2], (bh, s, 64), jnp.float32)
    idx, cnt = _mask_for(q, k, 0.85, 128, 128, causal=causal)
    out = block_sparse_attention(q, k, v, idx, cnt, causal=causal,
                                 interpret=True)
    ref = block_sparse_attention_ref(q, k, v, idx, cnt, causal=causal)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=3e-5)
