"""Scheduler + MILP properties: every schedule is dependency-legal and
complete (hypothesis over random instances); the B&B oracle matches brute
force on tiny instances; greedy is sandwiched between LP bound and naive
baselines."""
import numpy as np
import pytest  # noqa: F401
# real hypothesis in CI; deterministic stub from tests/_vendor otherwise
# (wired by conftest.py) — the suite never skips
from hypothesis import given, settings, strategies as st

from repro.core.chunks import Chunk, ChunkGrid, State
from repro.core.lp import solve_lp
from repro.core.milp import MILPProblem, brute_force, solve_bnb
from repro.core import scheduler as S


def _rand_instance(seed, n_t=3, n_l=4, n_h=1):
    rng = np.random.default_rng(seed)
    g = ChunkGrid(n_t, n_l, n_h)
    ts = rng.uniform(0.2, 2.0, g.size)
    tc = rng.uniform(0.1, 1.5, g.size)
    return g, ts, tc


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.integers(0, 10_000), st.integers(1, 5), st.integers(1, 5),
       st.integers(1, 3))
def test_greedy_schedule_legal_and_complete(seed, n_t, n_l, n_h):
    g, ts, tc = _rand_instance(seed, n_t, n_l, n_h)
    sched = S.GreedyScheduler(g, ts, tc, stage_budget_s=float(
        np.random.default_rng(seed).uniform(0.3, 3.0))).run()
    assert g.validate_schedule(sched.events())
    assert sched.n_computed() + sched.n_streamed() == g.size


@settings(max_examples=15, deadline=None, derandomize=True)
@given(st.integers(0, 10_000))
def test_positional_hybrid_legal(seed):
    g, ts, tc = _rand_instance(seed, n_t=4, n_l=3)
    sched = S.positional_hybrid(g, ts, tc)
    assert g.validate_schedule(sched.events())


def test_compute_only_and_stream_only_legal():
    g, ts, tc = _rand_instance(0, n_t=4, n_l=5, n_h=2)
    assert g.validate_schedule(S.compute_only(g, ts, tc).events())
    assert g.validate_schedule(S.stream_only(g, ts, tc).events())


def test_greedy_beats_naive_latency_only():
    """Potential-aware >= latency-only greedy on makespan (on average)."""
    wins = ties = losses = 0
    for seed in range(12):
        g, ts, tc = _rand_instance(seed, n_t=4, n_l=4)
        dt = max(ts.sum(), tc.sum()) / 6
        pa = S.GreedyScheduler(g, ts, tc, stage_budget_s=dt).run().makespan
        lo = S.latency_only_greedy(g, ts, tc, stage_budget_s=dt).makespan
        if pa < lo - 1e-9:
            wins += 1
        elif pa > lo + 1e-9:
            losses += 1
        else:
            ties += 1
    assert wins >= losses


def test_bnb_matches_bruteforce():
    for seed in range(3):
        g, ts, tc = _rand_instance(seed, n_t=2, n_l=3)
        prob = MILPProblem(g, ts, tc, n_stages=2)
        bf, _ = brute_force(prob)
        res = solve_bnb(prob)
        assert abs(res.objective - bf) < 1e-6


def test_bnb_lower_bound_sandwich():
    g, ts, tc = _rand_instance(5, n_t=3, n_l=3)
    prob = MILPProblem(g, ts, tc, n_stages=3)
    res = solve_bnb(prob, max_nodes=800)
    dt = max(ts.sum(), tc.sum()) / 3
    greedy = S.GreedyScheduler(g, ts, tc, stage_budget_s=dt).run()
    assert res.lp_bound <= res.objective + 1e-6
    assert res.objective <= greedy.makespan + 1e-6 or \
        res.status == "node_limit"


def test_milp_assignment_feasibility_checker():
    g, ts, tc = _rand_instance(1, n_t=2, n_l=2)
    prob = MILPProblem(g, ts, tc, n_stages=2)
    # computing (t=0, l=1) at stage 0 requires (0, 0) computed <= stage 0
    a = {g.index(Chunk(0, 0, 0)): ("s", 0),
         g.index(Chunk(0, 1, 0)): ("c", 0),
         g.index(Chunk(1, 0, 0)): ("s", 1),
         g.index(Chunk(1, 1, 0)): ("s", 1)}
    assert not prob.feasible(a)          # layer pred streamed, not computed
    a[g.index(Chunk(0, 0, 0))] = ("c", 0)
    assert prob.feasible(a)


def test_simplex_known_solutions():
    r = solve_lp([-3, -5], A_ub=[[1, 0], [0, 2], [3, 2]], b_ub=[4, 12, 18])
    assert r.status == "optimal" and abs(r.fun + 36) < 1e-7


def test_chunk_dependency_structure():
    g = ChunkGrid(3, 4, 2)
    state = np.zeros(g.size, np.int8)
    # initially only (0, 0, h) ready
    ready = [c for c in g.chunks() if g.compute_ready(c, state)]
    assert set(ready) == {Chunk(0, 0, 0), Chunk(0, 0, 1)}
    # streaming (0, L-1) never enables anything (final layer exempt)
    assert g.enabled_by_stream(Chunk(0, g.n_l - 1, 0), state) == []
    # computing (0,0,0) enables (0,1,0) and (1,0,0)
    state[g.index(Chunk(0, 0, 0))] = State.COMPUTED
    en = set(g.enabled_by_compute(Chunk(0, 0, 0), state))
    # (recompute from pre-state: pass the pre-update state)
    state[g.index(Chunk(0, 0, 0))] = State.PENDING
    en = set(g.enabled_by_compute(Chunk(0, 0, 0), state))
    assert en == {Chunk(1, 0, 0), Chunk(0, 1, 0)}
