"""Continuous batched decode: step cost model, DecodeBatcher unit
behaviour, decode-off parity with the pre-decode fleet, and end-to-end
cluster runs where decode contends with prefill on the run queue."""
import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core.costs import PROFILES, RunQueueModel
from repro.core.engine import (decode_first_token_seconds,
                               decode_step_seconds)
from repro.serving.cluster import RequestSpec, ServingCluster
from repro.serving.decode import DecodeBatcher, DecodeConfig
from repro.serving.traffic import TrafficProfile, generate_trace

CFG = get_config("sparkv-qwen3-4b")
SP = SparKVConfig(scheduler_mode="engine")
PROF = PROFILES["jetson-orin"]
CTX = 4096


def make_cluster(**kw):
    kw.setdefault("max_concurrency", 8)
    return ServingCluster(CFG, SP, "jetson-orin", "campus-wifi", **kw)


# ---------------------------------------------------------------------------
# batched-step cost model
# ---------------------------------------------------------------------------

def test_step_cost_batch_of_one_matches_first_token():
    """The batched model is calibrated to the analytic first-token cost:
    a batch of one at the assembled context length is the same forward."""
    for ctx in (1024, 4096, 16384):
        assert np.isclose(decode_step_seconds(CFG, [ctx], PROF),
                          decode_first_token_seconds(CFG, ctx, PROF),
                          rtol=1e-9)


def test_step_cost_amortizes_weights_across_batch():
    """Per-token cost strictly improves with batching (weight reads are
    paid once per step), while the step itself grows with every member's
    KV reads and compute."""
    solo = decode_step_seconds(CFG, [CTX], PROF)
    for b in (2, 4, 8):
        step = decode_step_seconds(CFG, [CTX] * b, PROF)
        assert step > solo                      # more work per step
        assert step / b < solo                  # cheaper per token
    # longer contexts cost more (KV reads scale with length)
    assert decode_step_seconds(CFG, [2 * CTX], PROF) > solo


# ---------------------------------------------------------------------------
# DecodeBatcher
# ---------------------------------------------------------------------------

def test_batcher_token_boundary_join_and_leave():
    bat = DecodeBatcher(CFG, PROF, DecodeConfig(max_batch=2))
    bat.enroll(0, CTX, n_tokens=2)
    d0 = bat.next_dispatch()
    assert d0.batch_size == 1 and bat.next_dispatch() is None  # one in flight
    bat.enroll(1, CTX, n_tokens=3)            # joins at next boundary
    bat.enroll(2, CTX, n_tokens=1)            # batch full -> waits
    assert bat.occupancy() == 3
    bat.dispatch_done()
    d1 = bat.next_dispatch()                  # rid 1 joined; rid 0 finishes
    assert d1.batch_size == 2 and set(d1.token_offsets) == {0, 1}
    assert d1.finished == (0,)
    bat.dispatch_done()
    d2 = bat.next_dispatch()                  # rid 2 promoted into the slot
    assert set(d2.token_offsets) == {1, 2} and d2.finished == (2,)
    bat.dispatch_done()
    d3 = bat.next_dispatch()
    assert set(d3.token_offsets) == {1} and d3.finished == (1,)
    bat.dispatch_done()
    assert bat.idle() and bat.next_dispatch() is None


def test_batcher_multi_token_dispatch_shrinks_batch():
    """tokens_per_dispatch > 1: members who hit their quota mid-dispatch
    stop contributing to later sub-steps; offsets stay monotone and the
    busy shares tile the dispatch duration exactly."""
    bat = DecodeBatcher(CFG, PROF, DecodeConfig(max_batch=4,
                                                tokens_per_dispatch=3))
    bat.enroll(0, CTX, n_tokens=1)
    bat.enroll(1, CTX, n_tokens=3)
    d = bat.next_dispatch()
    assert len(d.token_offsets[0]) == 1 and len(d.token_offsets[1]) == 3
    offs = d.token_offsets[1]
    assert all(b > a for a, b in zip(offs, offs[1:]))
    assert d.finished == (0, 1)
    assert np.isclose(sum(d.busy_share.values()), d.duration_s)
    # sub-step 1 shared by two members, later ones solo: rid 1 pays more
    assert d.busy_share[1] > d.busy_share[0]


# ---------------------------------------------------------------------------
# decode-off parity regression (guards the whole refactor)
# ---------------------------------------------------------------------------

def test_decode_off_traces_bit_identical():
    """With max_new_tokens == 0 everywhere, arming the decode layer (any
    DecodeConfig) must leave the fleet trace bit-identical to a cluster
    that never heard of decoding — records, TTFTs, summaries, shed and
    downgrade counts. Same pattern as PR 3's no-deadline parity test."""
    from repro.serving.slo import SLOPolicy
    specs = [RequestSpec(arrival_s=0.0, context_len=2 * CTX,
                         policy="sparkv", seed=0, slo_class="batch")]
    specs += [RequestSpec(arrival_s=0.4 * i, context_len=CTX,
                          policy="sparkv", seed=i, deadline_s=5.0,
                          slo_class="interactive")
              for i in range(1, 5)]
    for kw in ({"run_queue": RunQueueModel(1, "fifo")},
               {"run_queue": RunQueueModel(2, "wfq"),
                "slo": SLOPolicy()},
               {"closed_loop": True}):
        base = make_cluster(**kw).run(specs)
        armed = make_cluster(decode=DecodeConfig(max_batch=4,
                                                 tokens_per_dispatch=2),
                             **kw).run(specs)
        assert base.records == armed.records, kw
        assert base.summary() == armed.summary(), kw
        assert base.shed == armed.shed, kw
        assert [r.ttft_s for r in base.records] \
            == [r.ttft_s for r in armed.records], kw
        # first-token-only accounting: exactly one token per response
        assert all(r.n_tokens_out == 1 and r.ttlt_s == r.ttft_s
                   for r in armed.records), kw


# ---------------------------------------------------------------------------
# cluster end-to-end
# ---------------------------------------------------------------------------

def test_decode_fleet_delivers_full_responses():
    n_tok = 12
    specs = [RequestSpec(arrival_s=0.3 * i, context_len=CTX,
                         policy="sparkv", seed=i, max_new_tokens=n_tok)
             for i in range(4)]
    rep = make_cluster(run_queue=RunQueueModel(1, "fifo"),
                       decode=DecodeConfig(max_batch=4)).run(specs)
    assert len(rep.records) == 4
    for r in rep.records:
        assert r.n_tokens_out == n_tok
        assert r.ttlt_s > r.ttft_s            # decode tail is real time
        assert r.tpot_s > 0
    s = rep.summary()
    assert s["tokens_out_total"] == 4 * n_tok
    assert np.isclose(s["goodput_tok_s"],
                      4 * n_tok / rep.makespan_s)
    assert s["tpot_p50_s"] is not None and s["ttlt_p99_s"] > 0
    # makespan covers the decode tail: last token, not first
    assert rep.makespan_s >= max(r.spec.arrival_s + r.ttlt_s
                                 for r in rep.records) - 1e-9


def test_decode_energy_covers_tail():
    """The decode phase consumes device time, so a decoding fleet spends
    strictly more energy per request than its first-token-only twin."""
    base = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                        seed=0)]
    dec = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                       seed=0, max_new_tokens=32)]
    kw = dict(run_queue=RunQueueModel(1, "fifo"))
    e0 = make_cluster(**kw).run(base).records[0].energy_j
    e1 = make_cluster(**kw).run(dec).records[0].energy_j
    assert e1 > e0


def test_continuous_batching_beats_serial_goodput():
    """Overloaded device, simultaneous arrivals: sharing decode steps
    (max_batch > 1) must deliver more tokens/s than serializing whole
    responses (max_batch == 1) — the amortization the batcher exists
    for."""
    specs = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                         seed=i, max_new_tokens=24) for i in range(6)]
    kw = dict(run_queue=RunQueueModel(1, "fifo"))
    serial = make_cluster(decode=DecodeConfig(max_batch=1), **kw).run(specs)
    batched = make_cluster(decode=DecodeConfig(max_batch=8), **kw).run(specs)
    assert batched.summary()["goodput_tok_s"] \
        > serial.summary()["goodput_tok_s"]
    assert batched.makespan_s < serial.makespan_s


def test_decode_contends_with_prefill_on_run_queue():
    """A long decode stream on the device delays a later request's
    prefill chunks (they share the FIFO run queue), compared to the same
    arrival on a device with no decode load."""
    early = RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                        seed=0, max_new_tokens=64)
    late = RequestSpec(arrival_s=1.0, context_len=CTX,
                       policy="local_prefill", seed=1)
    kw = dict(run_queue=RunQueueModel(1, "fifo"))
    with_decode = make_cluster(**kw).run([early, late])
    no_decode = make_cluster(**kw).run(
        [RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                     seed=0), late])
    t_with = [r for r in with_decode.records if r.rid == 1][0]
    t_wo = [r for r in no_decode.records if r.rid == 1][0]
    assert t_with.compute_wait_s > t_wo.compute_wait_s
    assert t_with.ttft_s > t_wo.ttft_s


def test_single_request_run_decodes_serially():
    """HybridEngine.run() (exclusive device) serves the decode phase as
    back-to-back batch-of-1 steps over the growing context."""
    from repro.core import baselines as B
    from repro.core.costs import NETWORKS
    from repro.data.workloads import DATASETS, synthesize
    wl = synthesize(CFG, CTX, DATASETS["triviaqa"],
                    chunk_tokens=SP.chunk_tokens, quant_bits=SP.quant_bits)
    net = NETWORKS["campus-wifi"]
    ref = B.run_strong_hybrid(CFG, wl, "jetson-orin", net, SP, seed=0)
    plan = B.plan_policy("strong_hybrid", CFG, wl, "jetson-orin", net, SP)
    eng = ref.engine  # result object; rebuild an engine from the plan
    from repro.core.costs import GroundTruthLatency
    from repro.core.engine import BandwidthIntegrator, HybridEngine
    rng = np.random.default_rng(991)
    trace = net.trace(rng, 60.0)
    n_tok = 8
    eng2 = HybridEngine(
        grid=plan.grid, chunk_bytes=plan.bytes_map,
        active_blocks=plan.active_map,
        t_comp_pred={c: plan.planner.tc[i]
                     for i, c in enumerate(plan.grid.chunks())},
        gt=GroundTruthLatency(PROF, CFG.resolved_head_dim),
        profile=PROF, bw=BandwidthIntegrator(trace, 0.01),
        cfg_model=CFG, max_new_tokens=n_tok)
    res = eng2.run(plan.schedule, context_len=plan.context_len)
    assert res.n_tokens_out == n_tok
    assert len(res.token_times) == n_tok
    # token 0 lands one first-token-equivalent step after context done
    assert np.isclose(res.ttft_s - res.context_done_s,
                      decode_step_seconds(CFG, [plan.context_len], PROF))
    gaps = np.diff(res.token_times)
    assert (gaps > 0).all()
    assert res.ttlt_s == res.token_times[-1]


# ---------------------------------------------------------------------------
# traffic + SLO integration
# ---------------------------------------------------------------------------

def test_traffic_out_len_mix_draws_lengths():
    prof = TrafficProfile(rate_rps=1.0, arrival="poisson",
                          out_len_mix=((8, 0.5), (64, 0.5)),
                          slo_mix=(("interactive", 5.0, 0.08, 0.5),
                                   ("batch", None, 0.5)))
    specs = generate_trace(prof, 40, seed=7)
    lens = {s.max_new_tokens for s in specs}
    assert lens == {8, 64}
    ints = [s for s in specs if s.slo_class == "interactive"]
    bats = [s for s in specs if s.slo_class == "batch"]
    assert ints and bats
    assert all(s.deadline_s == 5.0 and s.tpot_slo_s == 0.08 for s in ints)
    assert all(s.deadline_s is None and s.tpot_slo_s is None for s in bats)


def test_tpot_slo_sheds_when_step_too_slow():
    """A TPOT SLO below the single-sequence step time is unmeetable: the
    admission layer must shed rather than admit a guaranteed violator;
    a loose TPOT SLO admits and the verdict covers the decode phase."""
    from repro.serving.slo import SLOPolicy
    step = decode_step_seconds(CFG, [CTX], PROF)
    tight = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                         seed=0, max_new_tokens=8, tpot_slo_s=step / 10)]
    rep = make_cluster(run_queue=RunQueueModel(1, "fifo"),
                       slo=SLOPolicy()).run(tight)
    assert len(rep.shed) == 1 and not rep.records
    loose = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                         seed=0, max_new_tokens=8, tpot_slo_s=step * 50)]
    rep2 = make_cluster(run_queue=RunQueueModel(1, "fifo"),
                        slo=SLOPolicy()).run(loose)
    r = rep2.records[0]
    assert r.slo_met is True and r.tpot_slo_s == step * 50
    assert rep2.summary()["slo_attainment"] == 1.0


def test_decode_respects_wfq_weight():
    """The decode flow competes under WFQ with its configured weight: a
    tiny decode weight lets a later prefill burst through faster than a
    heavy decode weight does. (Needs a queue deeper than one: several
    prefill flows keep multiple candidates queued at each dispatch, so
    the weighted pick is actually exercised.)"""
    specs = [RequestSpec(arrival_s=0.0, context_len=CTX, policy="sparkv",
                         seed=0, max_new_tokens=96)]
    specs += [RequestSpec(arrival_s=1.0, context_len=CTX,
                          policy="local_prefill", seed=i, weight=1.0)
              for i in range(1, 4)]
    out = {}
    for w in (0.1, 8.0):
        rep = make_cluster(run_queue=RunQueueModel(1, "wfq"),
                           decode=DecodeConfig(max_batch=4, weight=w)
                           ).run(specs)
        out[w] = float(np.mean([r.ttft_s for r in rep.records
                                if r.rid >= 1]))
    assert out[0.1] < out[8.0]
