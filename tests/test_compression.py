"""Huffman codec + quantization properties (hypothesis)."""
import numpy as np
import pytest  # noqa: F401
# real hypothesis in CI; deterministic stub from tests/_vendor otherwise
# (wired by conftest.py) — the suite never skips
from hypothesis import given, settings, strategies as st

from repro.compression import huffman as H
from repro.compression.allocate import (SCHEDULES, allocate_bits,
                                        chunk_saliency, ladder_shift,
                                        saliency_ranks, schedule_of)
from repro.compression.quantize import (BITRATE_LEVELS, dequantize,
                                        layerwise_bits, quant_error,
                                        quantize, snap_to_ladder)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.integers(1, 20000), st.integers(2, 6), st.integers(1, 64),
       st.floats(0.2, 6.0))
def test_huffman_roundtrip(n, bits, streams, skew):
    rng = np.random.default_rng(n * 7 + bits)
    alpha = 1 << bits
    # skewed multinomial like quantized KV
    p = np.exp(-skew * np.abs(np.arange(alpha) - alpha / 2) / alpha)
    p /= p.sum()
    x = rng.choice(alpha, size=n, p=p).astype(np.uint16)
    enc = H.encode(x, alpha, n_streams=streams)
    dec = H.decode(enc)
    assert np.array_equal(dec, x)


def test_huffman_near_entropy(rng):
    x = np.clip(rng.normal(16, 3, 200_000), 0, 31).astype(np.uint16)
    enc = H.encode(x, 32, n_streams=256)
    ent = H.entropy_bits(x, 32)
    actual = enc.payload_bytes() * 8 / len(x)
    # within 8% of the entropy bound at this scale
    assert actual < ent * 1.08 + 0.1


def test_huffman_constant_sequence():
    x = np.full(5000, 7, np.uint16)
    enc = H.encode(x, 32, n_streams=16)
    assert np.array_equal(H.decode(enc), x)
    assert enc.payload_bytes() * 8 / len(x) < 1.5  # ~1 bit/sym + overhead


def test_huffman_empty():
    enc = H.encode(np.zeros(0, np.uint16), 32)
    assert len(H.decode(enc)) == 0


@settings(max_examples=20, deadline=None, derandomize=True)
@given(st.integers(2, 8), st.sampled_from([16, 32, 64, 128]))
def test_quantize_error_bound(bits, group):
    rng = np.random.default_rng(bits * group)
    x = rng.normal(size=(64, 64)).astype(np.float32)
    qt = quantize(x, bits, group)
    from repro.compression.quantize import dequantize
    xr = dequantize(qt)
    # max error <= half step of the worst group
    assert np.abs(xr - x).max() <= qt.scales.max() / 2 + 1e-6
    # monotone: more bits -> lower error
    if bits < 8:
        assert quant_error(x, bits + 1, group) <= \
            quant_error(x, bits, group) + 1e-9


def test_layerwise_bits_ladder():
    for lvl in range(len(BITRATE_LEVELS)):
        for layer in (0, 10, 30):
            bk = layerwise_bits(lvl, layer, 32, is_key=True)
            bv = layerwise_bits(lvl, layer, 32, is_key=False)
            assert 2 <= bv <= bk <= 8  # keys get >= bits than values


def test_layerwise_bits_on_ladder_grid():
    """Regression: layerwise_bits used to emit off-ladder widths (7 from
    level 1 + key bonus -> KeyError in QUALITY_OF_BITS; 2 below the
    memory server's 3-bit floor). Every (level, layer, is_key) cell of
    the grid must now be a BITRATE_LEVELS width, keys still >= values."""
    for lvl in range(len(BITRATE_LEVELS)):
        for n_layers in (16, 32, 48):
            for layer in range(n_layers):
                bk = layerwise_bits(lvl, layer, n_layers, is_key=True)
                bv = layerwise_bits(lvl, layer, n_layers, is_key=False)
                assert bk in BITRATE_LEVELS, (lvl, layer, n_layers, bk)
                assert bv in BITRATE_LEVELS, (lvl, layer, n_layers, bv)
                assert bv <= bk


def test_snap_to_ladder():
    assert [snap_to_ladder(b) for b in range(2, 9)] == \
        [3, 3, 4, 5, 6, 8, 8]  # nearest rung, ties break finer
    # monotone: never reorders two widths
    snapped = [snap_to_ladder(b) for b in range(2, 9)]
    assert snapped == sorted(snapped)


def test_quantize_tail_group_regression(rng):
    """Regression: quantize() zero-padded BEFORE per-group min/max, so a
    non-divisible all-positive tensor's tail group got lo pulled to 0.0
    and a widened step. Edge-padding keeps the tail group's affine
    params on its real values: the non-divisible round-trip error must
    stay within the divisible-length bound."""
    for n, group in [(97, 32), (1000, 64), (33, 32), (130, 128)]:
        x = rng.uniform(5.0, 6.0, n).astype(np.float32)
        qt = quantize(x, 4, group)
        err = np.abs(dequantize(qt) - x).max()
        # divisible-length reference on the same distribution
        xd = rng.uniform(5.0, 6.0, (n // group + 1) * group)
        xd = xd.astype(np.float32)
        err_div = np.abs(dequantize(quantize(xd, 4, group)) - xd).max()
        # pre-fix the tail error was ~5x the step (lo dragged to 0.0)
        assert err <= err_div * 1.25 + 1e-6, (n, group, err, err_div)
        # and the universal half-step bound still holds
        assert err <= qt.scales.max() / 2 + 1e-6


def test_quantize_spans_field(rng):
    """spans is the bit-width-independent value range: scales must equal
    spans / (2^bits - 1) bitwise (same fp32 division the mixed kernel
    performs on-device)."""
    for bits in (3, 5, 8):
        x = rng.normal(size=500).astype(np.float32)
        qt = quantize(x, bits, 64)
        assert qt.spans is not None and qt.spans.dtype == np.float32
        re = (qt.spans / np.float32((1 << bits) - 1)).astype(np.float32)
        assert np.array_equal(re, qt.scales)


def test_allocation_schedules(rng):
    act = rng.uniform(1.0, 20.0, (8, 4, 2))
    ent = rng.uniform(0.5, 4.0, (4, 2))
    for name in ("uniform", "flat"):
        out = allocate_bits(act, ent, 5, schedule_of(name))
        assert (out == 5).all()  # empty-rule schedules: base everywhere
    out = allocate_bits(act, ent, 5, schedule_of("attention"))
    assert out.shape == act.shape
    assert set(np.unique(out)) <= set(BITRATE_LEVELS)
    # hot band finer, cold band coarser, and both non-empty
    assert (out == 6).any() and (out == 4).any()
    # saliency order respected: every 6-bit chunk outranks every 4-bit
    sal = chunk_saliency(act, ent)
    assert sal[out == 6].min() >= sal[out == 4].max()
    # off-ladder base snaps before shifting
    out7 = allocate_bits(act, ent, 7, schedule_of("flat"))
    assert (out7 == 8).all()


def test_allocation_ranks_and_shift():
    r = saliency_ranks(np.array([3.0, 1.0, 2.0, 2.0]))
    assert np.array_equal(r, [0.75, 0.0, 0.25, 0.5])  # stable ties
    assert ladder_shift(5, +1) == 6 and ladder_shift(5, -1) == 4
    assert ladder_shift(8, +2) == 8 and ladder_shift(3, -2) == 3  # clamp
    assert ladder_shift(7, 0) == 8  # snapped first


def test_allocation_entropy_tilt():
    """With equal attention mass, higher-entropy layers get the finer
    rungs; zero entropy degenerates to pure attention ranking."""
    act = np.ones((6, 2, 1))
    ent = np.array([[4.0], [0.5]])
    out = allocate_bits(act, ent, 5, SCHEDULES["attention"])
    assert out[:, 0, :].min() >= out[:, 1, :].max()
    out0 = allocate_bits(act, np.zeros((2, 1)), 5, SCHEDULES["attention"])
    assert set(np.unique(out0)) <= set(BITRATE_LEVELS)


def test_schedule_of_unknown():
    with pytest.raises(KeyError):
        schedule_of("nope")
