"""Huffman codec + quantization properties (hypothesis)."""
import numpy as np
import pytest  # noqa: F401
# real hypothesis in CI; deterministic stub from tests/_vendor otherwise
# (wired by conftest.py) — the suite never skips
from hypothesis import given, settings, strategies as st

from repro.compression import huffman as H
from repro.compression.quantize import (BITRATE_LEVELS, layerwise_bits,
                                        quant_error, quantize)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.integers(1, 20000), st.integers(2, 6), st.integers(1, 64),
       st.floats(0.2, 6.0))
def test_huffman_roundtrip(n, bits, streams, skew):
    rng = np.random.default_rng(n * 7 + bits)
    alpha = 1 << bits
    # skewed multinomial like quantized KV
    p = np.exp(-skew * np.abs(np.arange(alpha) - alpha / 2) / alpha)
    p /= p.sum()
    x = rng.choice(alpha, size=n, p=p).astype(np.uint16)
    enc = H.encode(x, alpha, n_streams=streams)
    dec = H.decode(enc)
    assert np.array_equal(dec, x)


def test_huffman_near_entropy(rng):
    x = np.clip(rng.normal(16, 3, 200_000), 0, 31).astype(np.uint16)
    enc = H.encode(x, 32, n_streams=256)
    ent = H.entropy_bits(x, 32)
    actual = enc.payload_bytes() * 8 / len(x)
    # within 8% of the entropy bound at this scale
    assert actual < ent * 1.08 + 0.1


def test_huffman_constant_sequence():
    x = np.full(5000, 7, np.uint16)
    enc = H.encode(x, 32, n_streams=16)
    assert np.array_equal(H.decode(enc), x)
    assert enc.payload_bytes() * 8 / len(x) < 1.5  # ~1 bit/sym + overhead


def test_huffman_empty():
    enc = H.encode(np.zeros(0, np.uint16), 32)
    assert len(H.decode(enc)) == 0


@settings(max_examples=20, deadline=None, derandomize=True)
@given(st.integers(2, 8), st.sampled_from([16, 32, 64, 128]))
def test_quantize_error_bound(bits, group):
    rng = np.random.default_rng(bits * group)
    x = rng.normal(size=(64, 64)).astype(np.float32)
    qt = quantize(x, bits, group)
    from repro.compression.quantize import dequantize
    xr = dequantize(qt)
    # max error <= half step of the worst group
    assert np.abs(xr - x).max() <= qt.scales.max() / 2 + 1e-6
    # monotone: more bits -> lower error
    if bits < 8:
        assert quant_error(x, bits + 1, group) <= \
            quant_error(x, bits, group) + 1e-9


def test_layerwise_bits_ladder():
    for lvl in range(len(BITRATE_LEVELS)):
        for layer in (0, 10, 30):
            bk = layerwise_bits(lvl, layer, 32, is_key=True)
            bv = layerwise_bits(lvl, layer, 32, is_key=False)
            assert 2 <= bv <= bk <= 8  # keys get >= bits than values
