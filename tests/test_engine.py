"""Discrete-event engine + runtime controller behaviour."""
from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS, NetworkProfile
from repro.data.workloads import DATASETS, synthesize

CFG = get_config("sparkv-qwen3-4b")
SP = SparKVConfig()
WL = synthesize(CFG, 6_144, DATASETS["triviaqa"])
NET = NETWORKS["campus-wifi"]


def test_all_pipelines_complete_all_chunks():
    for name, fn in B.PIPELINES.items():
        r = fn(CFG, WL, "jetson-orin", NET, SP, seed=0)
        e = r.engine
        assert e.n_streamed + e.n_computed == WL.n_t * WL.n_l * WL.n_h, name
        assert r.ttft_s > 0 and r.energy_j > 0


def test_hybrid_not_worse_than_best_single_path():
    r_sp = B.run_sparkv(CFG, WL, "jetson-orin", NET, SP, seed=0)
    r_lo = B.run_local_prefill(CFG, WL, "jetson-orin", NET, SP, seed=0)
    r_ki = B.run_kivi(CFG, WL, "jetson-orin", NET, SP,
                      bits=SP.quant_bits, seed=0)
    best_single = min(r_lo.ttft_s, r_ki.ttft_s)
    assert r_sp.ttft_s <= best_single * 1.10  # within noise of dominating


def test_ttft_above_physical_lower_bound():
    """TTFT >= total work / combined service rate (perfect overlap)."""
    r = B.run_sparkv(CFG, WL, "jetson-orin", NET, SP, seed=1)
    e = r.engine
    stream_all = sum(
        b / NET.mean_bw for b in [WL.chunk_bytes.sum()])
    comp_all = B.run_local_prefill(CFG, WL, "jetson-orin", NET, SP,
                                   seed=1).engine.compute_busy_s
    perfect = 1.0 / (1.0 / max(stream_all, 1e-9)
                     + 1.0 / max(comp_all, 1e-9))
    assert r.ttft_s >= perfect * 0.9


def test_controller_migrates_under_bandwidth_drop():
    bad = NetworkProfile("bad", 120e6 / 8, 80e6 / 8)
    r_adapt = B.run_sparkv(CFG, WL, "jetson-orin", bad, SP, seed=0)
    r_static = B.run_sparkv(CFG, WL, "jetson-orin", bad, SP, seed=0,
                            adapt=False)
    assert r_adapt.extras["migrations"] > 0
    assert r_adapt.ttft_s <= r_static.ttft_s * 1.05


def test_contention_shifts_work_to_streaming():
    r0 = B.run_sparkv(CFG, WL, "jetson-orin", NET, SP, util=0.0, seed=0)
    r8 = B.run_sparkv(CFG, WL, "jetson-orin", NET, SP, util=0.8, seed=0)
    # heavy contention -> fewer chunks computed locally
    assert r8.engine.n_computed <= r0.engine.n_computed
    # and energy under contention stays bounded vs local prefill
    r_local = B.run_local_prefill(CFG, WL, "jetson-orin", NET, SP,
                                  util=0.8, seed=0)
    assert r8.energy_j < r_local.energy_j


def test_quality_ordering():
    r_sp = B.run_sparkv(CFG, WL, "jetson-orin", NET, SP, seed=0)
    r_cg = B.run_cachegen(CFG, WL, "jetson-orin", NET, SP, seed=0)
    r_lo = B.run_local_prefill(CFG, WL, "jetson-orin", NET, SP, seed=0)
    assert r_lo.quality == 1.0
    # mixing exact computed chunks lifts SparKV above pure streaming at
    # the same bit width (CacheGen may exceed it only by picking 8-bit)
    assert r_sp.quality >= B.QUALITY_OF_BITS[SP.quant_bits]
    assert r_cg.quality >= 0.9  # quality bar respected by the ladder


def test_energy_breakdown_consistency():
    r = B.run_sparkv(CFG, WL, "jetson-orin", NET, SP, seed=0)
    e = r.engine.energy
    assert abs(e["total_j"] - (e["compute_j"] + e["nic_j"] + e["idle_j"])) \
        < 1e-6


def test_deterministic_given_seed():
    a = B.run_sparkv(CFG, WL, "jetson-orin", NET, SP, seed=3).ttft_s
    b = B.run_sparkv(CFG, WL, "jetson-orin", NET, SP, seed=3).ttft_s
    assert a == b
