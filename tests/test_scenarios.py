"""Hostile-world scenario layer (`serving/scenarios` + cluster wiring).

Covers the four contracts the hostile machinery must honour:

  - **bit-parity when disarmed**: an empty ``ScenarioTrace`` (and an
    idle ``FleetRebalancer``) must leave fleet reports bit-identical to
    ``scenario=None`` on BOTH link cores, and armed fleets must agree
    bitwise across cores too (losses and all);
  - **loss/resume conservation**: the engine's ``StreamLost`` leg rolls
    back exactly the optimistic accounting of the aborted attempt —
    checked chunk-by-chunk over randomized loss injections (hypothesis,
    vendored-stub compatible);
  - **boundary semantics**: a handoff landing during the final chunk
    still serves the request (re-streamed or flipped to compute), a
    same-AP handoff is a counted no-op with untouched results, and an
    outage opening exactly at the stream-complete boundary loses zero
    bytes;
  - **rebalancer mechanics**: the FleetLP relaxation solves, moves
    devices off a collapsed AP, and re-solves warm from the previous
    basis.
"""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS, RunQueueModel
from repro.core.engine import (ComputeStart, StartAck, StoreHit, StreamLost,
                               StreamStart, Wait)
from repro.data.workloads import DATASETS, synthesize
from repro.serving.cluster import ServingCluster
from repro.serving.scenarios import (ChurnEvent, FleetRebalancer, FleetState,
                                     HandoffEvent, OutageWindow,
                                     ScenarioTrace, apply_outages,
                                     handoff_storm, markov_bw_trace)
from repro.serving.slo import SLOPolicy
from repro.serving.traffic import poisson_trace

CFG = get_config("sparkv-qwen3-4b")
SP = SparKVConfig(scheduler_mode="engine")
NET = NETWORKS["campus-wifi"]


def _fleet_fingerprint(report):
    """Every per-request observable the scenario layer could perturb,
    exactly as produced (no rounding) — mirrors test_simcore's oracle."""
    return [(r.spec.arrival_s, r.ttft_s, r.ttlt_s, r.energy_j,
             r.uplink_share, r.compute_wait_s, r.bytes_streamed, r.policy,
             tuple(sorted(r.stage_shares.items())))
            for r in report.records]


def _cluster(*, n_devices=2, n_aps=2, scenario=None, rebalancer=None,
             core="vectorized", max_context=2048):
    del max_context
    return ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                          n_devices=n_devices, n_aps=n_aps,
                          run_queue=RunQueueModel(2, "wfq"),
                          max_concurrency=8, slo=SLOPolicy(),
                          link_core=core, scenario=scenario,
                          rebalancer=rebalancer)


# ---------------------------------------------------------------------------
# disarmed parity + armed cross-core parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("core", ["vectorized", "scalar"])
def test_disarmed_scenario_is_bit_identical(core):
    """An empty ScenarioTrace + idle rebalancer must push zero events,
    consume no extra randomness, and reproduce the scenario-free fleet
    report bit-for-bit — the hostile machinery is free when unused."""
    specs = poisson_trace(10, 2.0, max_context=2048, seed=5)
    plain = _cluster(core=core).run(specs)
    disarmed = _cluster(core=core, scenario=ScenarioTrace(),
                        rebalancer=FleetRebalancer()).run(specs)
    assert _fleet_fingerprint(plain) == _fleet_fingerprint(disarmed)
    assert disarmed.scenario is None
    assert plain.summary() == disarmed.summary()


def test_hostile_fleet_parity_across_cores():
    """Armed scenario (handoffs mid-stream + an outage): the vectorized
    and scalar link cores must agree bitwise on every record AND on the
    loss telemetry — aborts hit both cores at identical instants."""
    specs = poisson_trace(8, 2.0, max_context=16384, seed=7)
    scen = ScenarioTrace(
        handoffs=handoff_storm(2, 2, t_start_s=0.4, spacing_s=0.2),
        outages=(OutageWindow(ap=1, t_start_s=2.0, t_end_s=4.0),))
    reports = {core: _cluster(core=core, scenario=scen).run(specs)
               for core in ("vectorized", "scalar")}
    assert _fleet_fingerprint(reports["vectorized"]) == \
        _fleet_fingerprint(reports["scalar"])
    assert reports["vectorized"].scenario == reports["scalar"].scenario
    assert reports["vectorized"].scenario["n_handoffs"] >= 1


# ---------------------------------------------------------------------------
# boundary semantics on a single request
# ---------------------------------------------------------------------------

def _single_spec(max_context=16384):
    return poisson_trace(1, 1.0, max_context=max_context, seed=3)


def test_handoff_during_final_chunk_still_serves():
    """Handoffs swept across the tail of the stream window (including
    the final chunk's transfer) must always deliver the full context:
    the lost chunk re-enters the backlog and is re-streamed on the new
    path or flipped to compute — never dropped. At least one sweep
    point must land mid-transfer and register a loss."""
    specs = _single_spec()
    r0 = _cluster().run(specs).records[0]
    window = r0.context_done_s - r0.admit_s
    any_loss = False
    for frac in (0.55, 0.7, 0.85, 0.97):
        t_h = r0.admit_s + frac * window
        scen = ScenarioTrace(handoffs=(
            HandoffEvent(t_s=t_h, device=0, new_ap=1),))
        rep = _cluster(scenario=scen).run(specs)
        assert rep.summary()["n_done"] == 1
        rec = rep.records[0]
        # full context assembled: a loss re-streams (possibly on the new
        # AP's independent — maybe faster — trace) or flips to compute;
        # it never drops a chunk
        assert rec.n_streamed + rec.n_computed == \
            r0.n_streamed + r0.n_computed
        assert rec.ttft_s > 0 and rec.bytes_streamed >= 0
        scen_tele = rep.scenario
        assert scen_tele["n_handoffs"] == 1
        if scen_tele["n_streams_lost"]:
            any_loss = True
            assert scen_tele["bytes_lost"] > 0
    assert any_loss, "no sweep point aborted an in-flight transfer"


def test_same_ap_handoff_is_counted_noop():
    """A handoff onto the AP the device already holds must not touch
    any flow: results stay bit-identical to the scenario-free run and
    the no-op lands in telemetry."""
    specs = _single_spec(max_context=8192)
    plain = _cluster().run(specs)
    # device 0's static AP is 0 (round-robin d % n_aps)
    scen = ScenarioTrace(handoffs=(
        HandoffEvent(t_s=0.2, device=0, new_ap=0),))
    rep = _cluster(scenario=scen).run(specs)
    assert _fleet_fingerprint(rep) == _fleet_fingerprint(plain)
    assert rep.scenario["n_handoffs"] == 0        # no actual move
    assert rep.scenario["n_handoff_noop"] == 1
    assert rep.scenario["n_streams_lost"] == 0


def test_outage_at_stream_complete_boundary_loses_nothing():
    """An outage window opening exactly at the chunk boundary where the
    last transfer completed finds nothing in flight: zero aborts, zero
    bytes lost, and the records stay bit-identical (every transfer
    integrated over the pre-window trace)."""
    specs = _single_spec(max_context=8192)
    cl0 = _cluster()
    plain = cl0.run(specs)
    r0 = plain.records[0]
    # first dt-grid point at/after stream completion: a boundary, not
    # mid-transfer (context_done_s includes the final dequant tail)
    dt = cl0.bw_dt
    t0 = (np.floor(r0.context_done_s / dt) + 1) * dt
    scen = ScenarioTrace(outages=(
        OutageWindow(ap=0, t_start_s=float(t0), t_end_s=float(t0) + 5.0),))
    rep = _cluster(scenario=scen).run(specs)
    assert _fleet_fingerprint(rep) == _fleet_fingerprint(plain)
    assert rep.scenario["n_outages"] == 1
    assert rep.scenario["n_streams_lost"] == 0
    assert rep.scenario["bytes_lost"] == 0.0


def test_churn_replaces_prefilling_request():
    """A device failing mid-prefill re-admits its request on a live
    device under a fresh rid with the ORIGINAL arrival time (TTFT keeps
    the lost work); nothing is silently dropped."""
    specs = _single_spec()
    r0 = _cluster().run(specs).records[0]
    t_mid = r0.admit_s + 0.4 * (r0.context_done_s - r0.admit_s)
    scen = ScenarioTrace(churn=(ChurnEvent(t_s=t_mid, device=0),))
    rep = _cluster(scenario=scen).run(specs)
    assert rep.scenario["n_churned"] == 1
    s = rep.summary()
    assert s["n_done"] + s["n_shed"] >= 1
    if s["n_done"]:
        rec = rep.records[0]
        assert rec.spec.device != 0           # re-placed off the dead box
        assert rec.spec.arrival_s == specs[0].arrival_s
        assert rec.ttft_s > r0.ttft_s         # lost work is paid for


# ---------------------------------------------------------------------------
# engine loss/resume byte conservation (hypothesis)
# ---------------------------------------------------------------------------

_WL = synthesize(CFG, 4096, DATASETS["triviaqa"])
_PLAN = B.plan_policy("sparkv", CFG, _WL, "jetson-orin", NET, SP)


def _drive_with_losses(loss_attempts, bw=25e6):
    """Drive one engine session with a fixed-rate synchronous driver,
    aborting the stream attempts numbered in ``loss_attempts`` (attempt
    index -> delivered fraction) mid-transfer. Returns (EngineResult,
    expected_bytes_lost, n_injected)."""
    plan = _PLAN
    from repro.core.costs import GroundTruthLatency, PROFILES
    from repro.core.engine import BandwidthIntegrator, Completion, HybridEngine
    profile = PROFILES["jetson-orin"]
    eng = HybridEngine(
        grid=plan.grid, chunk_bytes=plan.bytes_map,
        active_blocks=plan.active_map,
        t_comp_pred={c: plan.planner.tc[i]
                     for i, c in enumerate(plan.grid.chunks())},
        gt=GroundTruthLatency(profile, CFG.resolved_head_dim
                              if CFG.num_heads else 64),
        profile=profile,
        bw=BandwidthIntegrator(np.full(4000, bw), 0.01),
        cfg_model=CFG, controller=plan.controller, seed=0)
    gen = eng.session(plan.schedule, context_len=_WL.context_len)
    now = 0.0
    attempt = 0
    pend_s = None                    # (t_end, chunk, nbytes, t_begin, idx)
    pend_c = None                    # (t_end, chunk, t_begin)
    expected_lost = 0.0
    n_injected = 0
    ev = next(gen)
    try:
        while True:
            if isinstance(ev, (StreamStart, StoreHit)):
                dur = ev.nbytes / bw + ev.t_proc
                pend_s = (now + dur, ev.chunk, ev.nbytes, now, attempt)
                attempt += 1
                ev = gen.send(None)
            elif isinstance(ev, ComputeStart):
                pend_c = (now + ev.duration_s, ev.chunk, now)
                ev = gen.send(StartAck(t_start=now))
            else:
                assert isinstance(ev, Wait)
                if pend_s is not None and pend_s[4] in loss_attempts:
                    frac = loss_attempts.pop(pend_s[4])
                    t_end, c, nbytes, t_b, _ = pend_s
                    t_abort = t_b + frac * (t_end - t_b)
                    delivered = frac * nbytes
                    expected_lost += delivered
                    n_injected += 1
                    pend_s = None
                    now = max(now, t_abort)
                    ev = gen.send(StreamLost(c, t_abort, delivered))
                    continue
                assert pend_s is not None or pend_c is not None, \
                    "engine waited with nothing in flight"
                take_stream = pend_c is None or (
                    pend_s is not None and pend_s[0] <= pend_c[0])
                if take_stream:
                    t_end, c, _, t_b, _ = pend_s
                    pend_s = None
                    path = "stream"
                else:
                    t_end, c, t_b = pend_c
                    pend_c = None
                    path = "compute"
                now = max(now, t_end)
                ev = gen.send(Completion(path=path, chunk=c,
                                         t_start=t_b, t_end=t_end))
    except StopIteration as stop:
        return stop.value, expected_lost, n_injected


# up to 4 losses per run: (attempt index, delivered fraction)
_LOSS = st.tuples(st.integers(0, 30), st.floats(0.05, 0.95))


@settings(max_examples=12, deadline=None, derandomize=True)
@given(st.lists(_LOSS, min_size=0, max_size=4))
def test_stream_loss_conserves_bytes(losses):
    """For ANY injection of mid-transfer losses: every chunk still ends
    exactly once in streamed or computed, ``bytes_streamed`` equals the
    bytes of the chunks that actually arrived (each loss rolled back
    exactly), ``bytes_lost`` sums the wasted deliveries, and the loss
    count matches the injections."""
    loss_map = {}
    for idx, frac in losses:
        loss_map.setdefault(idx, frac)
    res, expected_lost, n_injected = _drive_with_losses(dict(loss_map))
    allc = set(_PLAN.grid.chunks())
    assert res.streamed_set | res.computed_set == allc
    assert not (res.streamed_set & res.computed_set)
    assert np.isclose(
        res.bytes_streamed,
        sum(_PLAN.bytes_map[c] for c in res.streamed_set), rtol=1e-12)
    assert res.n_lost == n_injected
    assert np.isclose(res.bytes_lost, expected_lost, rtol=1e-12, atol=0.0)
    if n_injected == 0:
        assert res.bytes_lost == 0.0 and res.bytes_restreamed == 0.0
    # re-issued bytes only ever cover previously-attempted chunks
    assert res.bytes_restreamed <= res.bytes_streamed + res.bytes_lost


# ---------------------------------------------------------------------------
# trace generators + rebalancer mechanics
# ---------------------------------------------------------------------------

def test_markov_trace_levels_and_shape():
    rng = np.random.default_rng(0)
    tr = markov_bw_trace(40e6, 30.0, 0.01, rng)
    assert len(tr) == 3000
    assert set(np.unique(tr / 40e6).round(6)) <= {1.0, 0.4, 0.08}
    assert len(np.unique(tr)) >= 2               # it actually modulates


def test_apply_outages_noop_returns_same_object():
    tr = np.full(100, 5e6)
    w = (OutageWindow(ap=1, t_start_s=0.1, t_end_s=0.3),)
    assert apply_outages(tr, 0.01, w, ap=0) is tr
    masked = apply_outages(tr, 0.01, w, ap=1)
    assert masked is not tr
    assert np.all(masked[10:30] == 5e6 * 0.02)
    assert np.all(masked[:10] == 5e6) and np.all(masked[30:] == 5e6)


def _fleet_state(ap_health, ap_of_device=(0, 0), demand=(8e6, 8e6)):
    d = len(ap_of_device)
    a = len(ap_health)
    return FleetState(
        now=1.0, demand=np.array(demand, float),
        ap_of_device=list(ap_of_device),
        ap_health=np.array(ap_health, float),
        ap_flows=np.ones(a), mean_bw=5e6,
        comp_rate=np.full(d, 2e6),
        reach=[tuple(range(a))] * d)


def test_rebalancer_moves_off_collapsed_ap_and_warm_resolves():
    """Both devices sit on a dying AP 0: the LP must move at least one
    onto the healthy AP and hint `cachegen` for anyone left starved.
    The immediate re-solve reuses the previous basis (warm hit)."""
    rb = FleetRebalancer()
    dec = rb.decide(_fleet_state(ap_health=(0.02, 1.0)))
    assert dec is not None
    assert 1 in dec.placement.values()           # someone escapes AP 0
    assert dec.makespan_s > 0
    assert set(dec.policy_hint.values()) <= \
        {"sparkv", "cachegen", "local_prefill"}
    dec2 = rb.decide(_fleet_state(ap_health=(0.02, 1.0),
                                  demand=(9e6, 7e6)))
    assert dec2 is not None and rb.n_warm_hits >= 1
    assert rb.n_solves == 2


def test_rebalancer_idle_cases():
    rb = FleetRebalancer(min_interval_s=10.0)
    st0 = _fleet_state(ap_health=(1.0, 1.0))
    assert rb.decide(st0) is not None            # first solve passes
    assert rb.decide(st0) is None                # throttled
    rb2 = FleetRebalancer()
    assert rb2.decide(_fleet_state(ap_health=(1.0, 1.0),
                                   demand=(0.0, 0.0))) is None
