"""Property-based tests for the resource-server layer (hypothesis).

Invariants the serving stack leans on, checked over randomized job/flow
mixes rather than hand-picked examples:

  - work conservation: the device run queue never idles (all slots free)
    while a job waits, and total busy time equals the sum of service
    durations for any submit pattern;
  - SRPT anti-starvation: a deadline-carrying job is dispatched before
    its deadline under an endless storm of shorter jobs, provided jobs
    are short enough that a dispatch boundary falls inside the EDF
    floor window;
  - link topology monotonicity: adding a stage to a flow's path never
    makes it finish earlier (the bottleneck governs);
  - wait-telemetry consistency: recorded waits + service times tile the
    makespan exactly on a capacity-1 FIFO queue;
  - KV-store byte conservation: every byte the content-addressed store
    ever accepts is exactly one of resident or evicted, lookups
    partition into hits + misses, and residency never exceeds capacity
    under any lookup/insert/remove interleaving (LRU and LFU).
"""
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.costs import (KVStoreModel, MemoryModel, SharedLinkModel,
                              NETWORKS)
from repro.core.engine import BandwidthIntegrator
from repro.serving.kvstore import CloudKVStore
from repro.serving.memory import KVMemoryServer
from repro.serving.resources import (DeviceRunQueue, LinkStage, LinkTopology,
                                     ScalarLinkTopology, single_link,
                                     tree_topology)

# durations in [0.05, 2.0] s: realistic chunk scale, no degenerate zeros
DUR = st.floats(0.05, 2.0)


def _drain(rq: DeviceRunQueue, jobs):
    """Submit (t_submit, duration) jobs in time order, run to empty.
    Returns {key: (t_submit, t_start, duration)}."""
    trace = {}
    pending = sorted(enumerate(jobs), key=lambda kv: kv[1][0])
    i = 0
    while i < len(pending) or rq.load():
        nc = rq.next_completion()
        t_next_sub = pending[i][1][0] if i < len(pending) else float("inf")
        if nc is not None and nc[0] <= t_next_sub:
            t, key = nc
            for k2, t0, dur in rq.complete(key, t):
                trace[k2] = (trace[k2][0], t0, dur)
            continue
        assert i < len(pending), "idle queue with no arrivals left"
        key, (t_sub, dur) = pending[i]
        i += 1
        trace[key] = (t_sub, None, dur)
        t0 = rq.submit(key, dur, t_sub, flow=key % 3,
                       weight=float(1 + key % 2))
        if t0 is not None:
            trace[key] = (t_sub, t0, dur)
    return trace


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.lists(st.tuples(st.floats(0.0, 5.0), DUR), min_size=1,
                max_size=12),
       st.integers(1, 3), st.sampled_from(["fifo", "wfq", "srpt"]))
def test_runqueue_work_conservation(jobs, capacity, discipline):
    """For any job mix and discipline: every job runs exactly once after
    its submit, total busy time is the sum of durations, and the server
    is never fully idle while a job waits."""
    rq = DeviceRunQueue(capacity, discipline)
    trace = _drain(rq, jobs)
    assert len(trace) == len(jobs)
    assert np.isclose(rq.busy_s, sum(d for _, d in jobs))
    ivals = []
    for t_sub, t0, dur in trace.values():
        assert t0 is not None and t0 >= t_sub - 1e-12
        ivals.append((t0, t0 + dur))
    # merged service union: any gap is genuine idleness, so no job may be
    # waiting (submitted, not yet started) inside it — work conservation
    ivals.sort()
    merged = [list(ivals[0])]
    for a, b in ivals[1:]:
        if a <= merged[-1][1] + 1e-12:
            merged[-1][1] = max(merged[-1][1], b)
        else:
            merged.append([a, b])
    gaps = [(merged[k][1], merged[k + 1][0])
            for k in range(len(merged) - 1)]
    for g0, g1 in gaps:
        for t_sub, t0, _ in trace.values():
            overlap = min(t0, g1) - max(t_sub, g0)
            assert overlap <= 1e-9, \
                f"job waited [{t_sub},{t0}) across idle gap [{g0},{g1})"


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.lists(DUR, min_size=1, max_size=10), st.integers(1, 4))
def test_runqueue_waits_tile_makespan(durs, capacity):
    """Telemetry consistency: starts = submit + recorded wait; on a
    capacity-1 FIFO queue with simultaneous arrivals the waits are the
    duration prefix-sums and the makespan is their total."""
    rq = DeviceRunQueue(1, "fifo")
    starts = {}
    for k, d in enumerate(durs):
        starts[k] = rq.submit(k, d, 0.0)
    t = 0.0
    while rq.load():
        t, key = rq.next_completion()
        for k2, t0, _ in rq.complete(key, t):
            starts[k2] = t0
    prefix = np.concatenate([[0.0], np.cumsum(durs)[:-1]])
    assert np.allclose(sorted(rq.waits), sorted(prefix))
    assert np.allclose([starts[k] for k in range(len(durs))], prefix)
    assert np.isclose(t, sum(durs))          # makespan == total service
    assert np.isclose(rq.busy_s, sum(durs))
    # multi-slot sanity: busy time can exceed makespan by at most xcap
    rq2 = DeviceRunQueue(capacity, "fifo")
    tr = _drain(rq2, [(0.0, d) for d in durs])
    makespan = max(t0 + d for _, t0, d in tr.values())
    assert rq2.busy_s <= capacity * makespan + 1e-9


@settings(max_examples=20, deadline=None, derandomize=True)
@given(st.floats(2.0, 8.0), st.floats(0.1, 0.4), st.integers(0, 1000))
def test_srpt_deadline_floor_bounds_starvation(deadline, short_dur, seed):
    """Pure SRPT would defer a 100-token-long flow forever behind an
    endless storm of short jobs; with the EDF floor it must be
    dispatched no later than its deadline (jobs are shorter than the
    floor window, so a dispatch boundary always lands inside it)."""
    rng = np.random.default_rng(seed)
    rq = DeviceRunQueue(1, "srpt", deadline_floor_s=1.0)
    rq.submit(("s", 0), short_dur, 0.0, flow="s0", remaining_s=short_dur)
    rq.submit(("L", 0), 0.5, 0.0, flow="L", remaining_s=100.0,
              deadline_s=deadline)
    t, i = 0.0, 0
    t_start = None
    while t_start is None:
        i += 1
        d = float(rng.uniform(0.1, short_dur))
        rq.submit(("s", i), d, t, flow=f"s{i}", remaining_s=d)
        t, key = rq.next_completion()
        for k2, t0, _ in rq.complete(key, t):
            if k2 == ("L", 0):
                t_start = t0
        assert t <= deadline + 1.0, "long job starved past its deadline"
    assert t_start <= deadline


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.integers(0, 10_000), st.floats(1e6, 100e6), st.floats(0.5, 1.0),
       st.floats(1e5, 50e6))
def test_topology_extra_stage_never_speeds_flow(seed, nbytes, jitter,
                                                extra_rate):
    """Bottleneck monotonicity: routing the same flow through an
    additional stage can only delay (or preserve) its finish time —
    whatever the extra stage's rate."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(jitter, 1.0, 4000) * 80e6
    one = single_link(BandwidthIntegrator(base, 0.01))
    one.add(0, nbytes)
    t1, _ = one.next_completion()
    two = LinkTopology({
        "nic": LinkStage("nic", BandwidthIntegrator(
            np.full(4000, extra_rate), 0.01)),
        "uplink": LinkStage("uplink", BandwidthIntegrator(base, 0.01)),
    })
    two.add(0, nbytes, path=("nic", "uplink"))
    t2, _ = two.next_completion()
    assert t2 >= t1 * (1 - 1e-6)
    # and a non-binding extra stage (much faster than the bottleneck)
    # leaves the finish time unchanged
    fat = LinkTopology({
        "nic": LinkStage("nic", BandwidthIntegrator(
            np.full(4000, 10e9), 0.01)),
        "uplink": LinkStage("uplink", BandwidthIntegrator(base, 0.01)),
    })
    fat.add(0, nbytes, path=("nic", "uplink"))
    t3, _ = fat.next_completion()
    assert np.isclose(t3, t1, rtol=1e-4)


@settings(max_examples=15, deadline=None, derandomize=True)
@given(st.integers(1, 5), st.floats(8e6, 60e6), st.floats(8e6, 60e6),
       st.floats(8e6, 60e6))
def test_three_stage_path_conserves_bytes(n_flows, nic_rate, up_rate,
                                          eg_rate):
    """Byte conservation across the full NIC -> AP uplink -> egress
    path: every flow's per-interval drains sum exactly to its demand,
    no flow finishes with bytes left, and the shared egress stage never
    carries more than its capacity in any interval."""
    tree = tree_topology([BandwidthIntegrator(np.full(6000, nic_rate),
                                              0.01)
                          for _ in range(n_flows)],
                         [BandwidthIntegrator(np.full(6000, up_rate),
                                              0.01) for _ in range(2)],
                         [k % 2 for k in range(n_flows)],
                         BandwidthIntegrator(np.full(6000, eg_rate), 0.01))
    demands = {k: 1e6 * (k + 2) for k in range(n_flows)}
    for k, nb in demands.items():
        tree.add(k, nb, path=(f"nic{k}", f"uplink{k % 2}", "egress"))
    drained = {k: 0.0 for k in demands}
    t_prev, rem_prev = 0.0, dict(tree._rem)
    while tree.n_active():
        t, key = tree.next_completion()
        tree.advance(t)
        step = {k: rem_prev[k] - tree._rem[k] for k in tree._rem}
        for k, v in step.items():
            assert v >= -1e-6                 # flows never gain bytes
            drained[k] += v
        # the shared egress carries every flow: aggregate drain over the
        # interval is bounded by its capacity
        assert sum(step.values()) <= eg_rate * (t - t_prev) * (1 + 1e-6) \
            + 1e-3
        assert tree._rem[key] <= 1.0          # completing flow is spent
        tree.complete(key)
        t_prev, rem_prev = t, dict(tree._rem)
    for k, nb in demands.items():
        assert np.isclose(drained[k], nb, rtol=1e-5)


@settings(max_examples=15, deadline=None, derandomize=True)
@given(st.integers(1, 6), st.floats(10e6, 90e6))
def test_topology_advance_conserves_total_bytes(n_flows, rate):
    """Fluid conservation, stepped the way the cluster drives the server
    (always to the earliest completion, so active sets are piecewise
    constant): over each interval the flows together drain exactly the
    stage's delivered bytes, and the completing flow's demand is spent."""
    topo = single_link(BandwidthIntegrator(np.full(8000, rate), 0.01),
                       link=None)
    demands = {k: 1e6 * (k + 1) for k in range(n_flows)}
    for k, nb in demands.items():
        topo.add(k, nb)
    t_prev, rem_prev = 0.0, dict(demands)
    while topo.n_active():
        t, key = topo.next_completion()
        topo.advance(t)
        drained = sum(rem_prev[k] - topo._rem[k] for k in topo._rem)
        assert np.isclose(drained, rate * (t - t_prev), rtol=1e-5)
        assert topo._rem[key] <= 1.0          # bytes: demand fully spent
        topo.complete(key)
        t_prev, rem_prev = t, dict(topo._rem)


# ---------------------------------------------------------------------------
# Vectorized vs scalar link core: lockstep equivalence
# ---------------------------------------------------------------------------

# op stream: (selector, nbytes). 0 -> add a flow, 1 -> run the next
# completion to its end, 2 -> advance halfway to it (interior advance,
# exercises the completion cache surviving `advance`)
_FLOW_OP = st.tuples(st.integers(0, 2), st.floats(0.3e6, 6e6))


def _paired_topologies(seed: int, shape: int):
    """One (vectorized, scalar) topology pair over identical traces:
    shape 0 = single shared uplink, 1 = per-device NICs -> uplink,
    2 = NICs -> 2 AP uplinks -> cloud egress. Returns (vec, sca, paths),
    `paths` the distinct routes flows may take."""
    rng = np.random.default_rng(seed)

    def bw(scale=80e6):
        return BandwidthIntegrator(rng.uniform(0.4, 1.0, 3000) * scale,
                                   0.01)

    link = SharedLinkModel(NETWORKS["campus-wifi"])
    if shape == 0:
        mk = lambda cls: single_link(bw(), link, cls=cls)  # noqa: E731
        paths = [("uplink",)]
    elif shape == 1:
        nics, up = [bw(40e6) for _ in range(2)], bw()
        mk = lambda cls: tree_topology(          # noqa: E731
            nics, [up], [0, 0], uplink_link=link, cls=cls)
        paths = [("nic0", "uplink"), ("nic1", "uplink")]
    else:
        nics = [bw(40e6) for _ in range(3)]
        ups, eg = [bw(60e6) for _ in range(2)], bw(50e6)
        mk = lambda cls: tree_topology(          # noqa: E731
            nics, ups, [0, 1, 0], eg, uplink_link=link, cls=cls)
        paths = [("nic0", "uplink0", "egress"),
                 ("nic1", "uplink1", "egress"),
                 ("nic2", "uplink0", "egress")]
    # the rng is consumed by the first mk(); rebuild identical traces for
    # the second core by re-seeding
    vec = mk(LinkTopology)
    rng = np.random.default_rng(seed)
    sca = mk(ScalarLinkTopology)
    return vec, sca, paths


def _assert_lockstep(vec, sca):
    """Full observable-state agreement at rtol 1e-9 (the cores share
    their integration helpers, so in practice they agree bitwise)."""
    assert set(vec._rem) == set(sca._rem)
    for k, r in sca._rem.items():
        assert np.isclose(vec._rem[k], r, rtol=1e-9, atol=1e-3)
    assert vec._path == sca._path
    ncv, ncs = vec.next_completion(), sca.next_completion()
    if ncs is None:
        assert ncv is None
    else:
        assert ncv[1] == ncs[1]
        assert np.isclose(ncv[0], ncs[0], rtol=1e-9, atol=0)
    for k in sca._rem:
        assert np.isclose(vec.mean_share(k), sca.mean_share(k),
                          rtol=1e-9, atol=0)
        shv, shs = vec.stage_shares(k), sca.stage_shares(k)
        assert set(shv) == set(shs)
        for s, v in shs.items():
            assert np.isclose(shv[s], v, rtol=1e-9, atol=0)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.integers(0, 10_000), st.integers(0, 2),
       st.lists(_FLOW_OP, min_size=2, max_size=18))
def test_vectorized_core_matches_scalar_reference(seed, shape, ops):
    """Drive the vectorized and scalar cores through the same random
    add / interior-advance / complete interleaving on the same traces:
    remaining bytes, next completions (time and identity), and share /
    per-stage telemetry must agree at every step (rtol 1e-9)."""
    vec, sca, paths = _paired_topologies(seed, shape)
    next_key, done = 0, []
    for op, nbytes in ops:
        if op == 0 or not vec.n_active():
            p = paths[next_key % len(paths)]
            vec.add(next_key, nbytes, p)
            sca.add(next_key, nbytes, p)
            next_key += 1
        elif op == 1:
            t, key = sca.next_completion()
            for topo in (vec, sca):
                topo.advance(t)
                topo.complete(key)
            done.append(key)
        else:                                  # interior advance
            t, _ = sca.next_completion()
            t_mid = sca.t + 0.5 * (t - sca.t)
            vec.advance(t_mid)
            sca.advance(t_mid)
        _assert_lockstep(vec, sca)
    while vec.n_active():                      # drain to empty
        t, key = sca.next_completion()
        for topo in (vec, sca):
            topo.advance(t)
            topo.complete(key)
        done.append(key)
        _assert_lockstep(vec, sca)
    # completed flows keep identical telemetry through the dict API
    for k in done:
        assert np.isclose(vec.mean_share(k), sca.mean_share(k), rtol=1e-9)
        assert vec.stage_shares(k).keys() == sca.stage_shares(k).keys()
        for s, v in sca.stage_shares(k).items():
            assert np.isclose(vec.stage_shares(k)[s], v, rtol=1e-9)


@settings(max_examples=15, deadline=None, derandomize=True)
@given(st.integers(0, 10_000), st.integers(0, 2),
       st.lists(st.floats(0.3e6, 4e6), min_size=2, max_size=8))
def test_vectorized_core_readd_continues_telemetry(seed, shape, sizes):
    """Re-adding a completed key (per-chunk stream flows, reload
    restreams) must continue its share/stage accumulation exactly where
    the previous activation left off — on both cores, identically."""
    vec, sca, paths = _paired_topologies(seed, shape)
    for rep in range(2):                       # two activations per key
        for k, nb in enumerate(sizes):
            p = paths[k % len(paths)]
            vec.add(k, nb, p)
            sca.add(k, nb, p)
        while vec.n_active():
            t, key = sca.next_completion()
            for topo in (vec, sca):
                topo.advance(t)
                topo.complete(key)
            _assert_lockstep(vec, sca)
    for k in range(len(sizes)):
        assert np.isclose(vec.mean_share(k), sca.mean_share(k), rtol=1e-9)
        for s, v in sca.stage_shares(k).items():
            assert np.isclose(vec.stage_shares(k)[s], v, rtol=1e-9)


@settings(max_examples=10, deadline=None, derandomize=True)
@given(st.integers(0, 10_000), st.integers(0, 2),
       st.lists(st.floats(0.3e6, 4e6), min_size=1, max_size=6))
def test_telemetry_off_preserves_dynamics(seed, shape, sizes):
    """`telemetry=False` skips share accumulation but must not perturb
    the fluid dynamics: completion times/keys match the telemetry=True
    run bitwise, and the telemetry API degrades to its documented
    defaults (mean_share 1.0, stage_shares {})."""
    rng = np.random.default_rng(seed)
    trace = rng.uniform(0.4, 1.0, 3000) * 80e6
    link = SharedLinkModel(NETWORKS["campus-wifi"])
    for cls in (LinkTopology, ScalarLinkTopology):
        on = single_link(BandwidthIntegrator(trace, 0.01), link, cls=cls)
        off = single_link(BandwidthIntegrator(trace, 0.01), link, cls=cls,
                          telemetry=False)
        for k, nb in enumerate(sizes):
            on.add(k, nb)
            off.add(k, nb)
        while on.n_active():
            (t1, k1), (t2, k2) = on.next_completion(), off.next_completion()
            assert (t1, k1) == (t2, k2)
            for topo in (on, off):
                topo.advance(t1)
                topo.complete(k1)
            assert off.mean_share(k1) == 1.0
            assert off.stage_shares(k1) == {}


# ---------------------------------------------------------------------------
# KV memory server: byte conservation over arbitrary legal op sequences
# ---------------------------------------------------------------------------

_MEM_OP = st.tuples(st.integers(0, 5),       # op selector
                    st.integers(0, 7),       # rid selector (mod live set)
                    st.floats(0.01, 2.0))    # charge size (GB)


@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.lists(_MEM_OP, min_size=1, max_size=40),
       st.sampled_from(["lru", "idle", "bits"]),
       st.sampled_from([None, "ufs-3.1", "emmc-5.1"]),
       st.floats(0.5, 4.0))
def test_memory_ledger_conservation(ops, policy, disk, cap_gb):
    """For any legal interleaving of admit/charge/ready/evict/reload/
    release under any policy and disk tier, every byte ever charged is
    exactly one of resident, on disk, dropped, or freed — checked after
    every single operation — and per-rid residency sums to the total."""
    GB = 1e9
    m = KVMemoryServer(MemoryModel(capacity_bytes=cap_gb * GB,
                                   policy=policy, disk=disk))
    t, next_rid, live = 0.0, 0, []

    def check():
        assert abs(m.ledger_balance()) < 1.0
        assert np.isclose(m.resident_total,
                          sum(r.bytes for r in m._res.values()), atol=1.0)
        assert np.isclose(m.disk_total,
                          sum(r.disk_bytes for r in m._res.values()),
                          atol=1.0)
        assert m.resident_total >= -1.0 and m.disk_total >= -1.0

    for op, pick, size in ops:
        t += 0.1
        if op == 0 or not live:                 # admit a new rid
            m.admit(next_rid, t)
            live.append(next_rid)
            next_rid += 1
        elif op == 1:                           # charge growth
            rid = live[pick % len(live)]
            if not m._res[rid].evicted:
                m.charge(rid, size * GB, t)
        elif op == 2:                           # assembly complete
            m.mark_ready(live[pick % len(live)], t)
        elif op == 3:                           # reload an evicted rid
            rid = live[pick % len(live)]
            if m.needs_reload(rid):
                ev = m.begin_reload(rid, t)
                check()
                assert ev.nbytes >= 0
                m.finish_reload(rid, t + 0.05)
        elif op == 4:                           # finalize
            rid = live[pick % len(live)]
            if not m._res[rid].reloading:
                m.release(rid, t)
                live.remove(rid)
        else:                                   # touch (LRU reordering)
            m.touch(live[pick % len(live)], t)
        check()
    for rid in list(live):                      # drain: all bytes settle
        m.release(rid, t)
        check()
    assert abs(m.resident_total) < 1.0 and abs(m.disk_total) < 1.0
    assert np.isclose(m.charged_total, m.freed_total + m.dropped_total,
                      atol=1.0)


# ---------------------------------------------------------------------------
# CloudKVStore: byte-conservation ledger + counter consistency
# ---------------------------------------------------------------------------

_STORE_OP = st.tuples(st.integers(0, 3),      # insert/lookup/remove/look+ins
                      st.integers(0, 15),     # content key
                      st.floats(0.01, 2.0))   # artifact size (GB)


@settings(max_examples=40, deadline=None, derandomize=True)
@given(st.lists(_STORE_OP, min_size=1, max_size=60),
       st.sampled_from(["lru", "lfu"]),
       st.floats(0.5, 4.0),
       st.booleans())
def test_kvstore_ledger_and_counters(ops, policy, cap_gb, bounded):
    """For any interleaving of lookup/insert/remove under LRU or LFU,
    bounded or not: every byte ever accepted is exactly one of resident
    or evicted (checked after every call), lookups partition into
    hits + misses, residency never exceeds capacity, and the three
    bookkeeping maps never drift apart."""
    GB = 1e9
    cap = cap_gb * GB if bounded else None
    store = CloudKVStore(KVStoreModel(capacity_bytes=cap, policy=policy))
    t = 0.0

    def check():
        assert abs(store.ledger_balance()) < 1.0
        assert store.n_lookups == store.n_hits + store.n_misses
        assert np.isclose(store.resident_bytes,
                          sum(store._res.values()), atol=1.0)
        if cap is not None:
            assert store.resident_bytes <= cap + 1.0
        assert len(store) == len(store._seq) == len(store._freq)
        assert store.n_inserts - store.n_evictions - len(store) == 0

    for op, key, size in ops:
        t += 0.1
        if op in (0, 3):
            if op == 3:                     # miss-then-fill protocol
                store.lookup(key, t)
            was_resident = key in store
            evicted = store.insert(key, size * GB, t)
            for k in evicted:
                assert k not in store
            if not was_resident and cap is not None and size * GB > cap:
                assert key not in store     # refused, counted
        elif op == 1:
            assert store.lookup(key, t) == (key in store)
        else:
            store.remove(key)
            assert key not in store
        check()
    for k in list(store._res):              # drain: all bytes settle
        store.remove(k)
        check()
    assert abs(store.resident_bytes) < 1.0
    assert np.isclose(store.inserted_total, store.evicted_total, atol=1.0)


@settings(max_examples=25, deadline=None, derandomize=True)
@given(st.lists(st.integers(0, 9), min_size=1, max_size=30),
       st.integers(2, 5))
def test_kvstore_lru_keeps_most_recent(touches, keep):
    """Unit-size LRU at capacity `keep`: after any touch sequence the
    resident set is exactly the last `keep` distinct keys touched."""
    store = CloudKVStore(KVStoreModel(capacity_bytes=float(keep),
                                      policy="lru"))
    for t, key in enumerate(touches):
        if not store.lookup(key, float(t)):
            store.insert(key, 1.0, float(t))
    expect = []
    for key in reversed(touches):
        if key not in expect:
            expect.append(key)
        if len(expect) == keep:
            break
    assert set(store._res) == set(expect)
