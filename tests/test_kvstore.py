"""Content-addressed KV reuse layer: key identity, store behavior, and
hit fidelity.

Identity: `span_content_id` chains must be prefix-closed (same leading
blocks <=> same leading ids, one divergent block poisons every later
id), and `chunk_content_key` must separate artifacts that differ in any
byte-shaping parameter — the same token span at different quantization
bits or chunk sizes is a different artifact and must never alias.

Store: LRU/LFU victim order, oversized-insert refusal, and the
`DevicePrefixCache.match` accounting the cluster's admission leans on.

Fidelity: a store hit serves the *encoded bitstream*, so the device-side
decode is the same `kernels/kv_dequant` kernel as a cold stream — a hit
round-tripped through it must match the numpy dequantize reference.
"""
import numpy as np
import pytest

from repro.compression.quantize import dequantize, quantize
from repro.core.chunks import chunk_content_key, span_content_id
from repro.core.costs import KVStoreModel, t_store_hit, t_store_miss_encode
from repro.serving.kvstore import CloudKVStore, DevicePrefixCache


# ---------------------------------------------------------------------------
# content identity
# ---------------------------------------------------------------------------

def _chain(blocks):
    ids, prev = [], 0
    for b in blocks:
        prev = span_content_id(b, prev)
        ids.append(prev)
    return ids


def test_span_ids_are_prefix_closed():
    """Two requests sharing their first k blocks share exactly their
    first k span ids; one divergent block changes every id after it."""
    a = _chain([b"sys-prompt", b"doc-1", b"turn-a"])
    b = _chain([b"sys-prompt", b"doc-1", b"turn-b"])
    assert a[:2] == b[:2]
    assert a[2] != b[2]
    c = _chain([b"sys-prompt", b"doc-2", b"turn-a"])
    assert c[0] == a[0]
    assert c[1] != a[1] and c[2] != a[2]    # divergence poisons the tail


def test_span_id_depends_on_position_via_chain():
    """The same block bytes at a different chain position get a
    different id (position is encoded by the predecessor hash)."""
    assert _chain([b"x", b"x"])[0] != _chain([b"x", b"x"])[1]
    assert span_content_id(b"x", 0) == span_content_id(b"x", 0)


def test_chunk_keys_distinct_across_every_shaping_param():
    """Identical token spans encoded at different bits / chunkings /
    layers / heads / models are different artifacts: no two of the
    perturbed keys may alias the base key or each other."""
    sid = span_content_id(b"shared-prefix")
    base = dict(model="sparkv-qwen3-4b", bits=8, chunk_tokens=1024, head=0)
    keys = {
        "base": chunk_content_key(sid, 3, **base),
        "bits": chunk_content_key(sid, 3, **{**base, "bits": 4}),
        "chunking": chunk_content_key(sid, 3, **{**base,
                                                 "chunk_tokens": 512}),
        "layer": chunk_content_key(sid, 4, **base),
        "head": chunk_content_key(sid, 3, **{**base, "head": 1}),
        "model": chunk_content_key(sid, 3, **{**base,
                                              "model": "sparkv-llama-8b"}),
        "span": chunk_content_key(span_content_id(b"other-prefix"), 3,
                                  **base),
    }
    assert len(set(keys.values())) == len(keys)
    # and the key function itself is deterministic across calls
    assert keys["base"] == chunk_content_key(sid, 3, **base)


# ---------------------------------------------------------------------------
# store behavior
# ---------------------------------------------------------------------------

def test_store_lru_evicts_least_recently_used():
    s = CloudKVStore(KVStoreModel(capacity_bytes=3.0, policy="lru"))
    for k in (1, 2, 3):
        assert s.insert(k, 1.0) == []
    assert s.lookup(1)                      # refresh 1: 2 is now coldest
    assert s.insert(4, 1.0) == [2]
    assert set(s._res) == {1, 3, 4}


def test_store_lfu_evicts_least_frequently_used():
    s = CloudKVStore(KVStoreModel(capacity_bytes=3.0, policy="lfu"))
    for k in (1, 2, 3):
        s.insert(k, 1.0)
    for _ in range(3):
        s.lookup(1)
    s.lookup(3)
    assert s.insert(4, 1.0) == [2]          # 2 has the lowest use count
    assert set(s._res) == {1, 3, 4}


def test_store_refuses_oversized_and_counts_it():
    s = CloudKVStore(KVStoreModel(capacity_bytes=2.0))
    assert s.insert(1, 5.0) == []
    assert 1 not in s and s.n_refused == 1
    assert s.ledger_balance() == 0.0


def test_prefix_cache_match_counts_lookups():
    c = DevicePrefixCache(capacity_bytes=None)
    c.insert(10, 1.0)
    c.insert(11, 1.0)
    got = c.match([10, 11, 12])
    assert got == {10, 11}
    assert c.n_lookups == 3 and c.n_hits == 2 and c.n_misses == 1


def test_hit_and_miss_cost_model():
    """Hit cost = hit latency + transfer + device decode; the miss-side
    encode surcharge is exactly zero at defaults (the bit-parity
    guarantee) and positive once an encode stage is modeled."""
    from repro.core.costs import PROFILES
    prof = PROFILES["jetson-orin"]
    store = KVStoreModel()
    nbytes, bw = 2e6, 10e6
    t = t_store_hit(nbytes, bw, prof, store)
    assert t == pytest.approx(store.hit_latency_s + nbytes / bw
                              + prof.t_proc(nbytes))
    assert t_store_miss_encode(nbytes, store) == 0.0
    slow = KVStoreModel(encode_fixed_s=0.01, encode_bw=100e6)
    assert t_store_miss_encode(nbytes, slow) == \
        pytest.approx(0.01 + nbytes / 100e6)


# ---------------------------------------------------------------------------
# hit fidelity: served bitstream decodes on the kv_dequant kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", [8, 4, 3])
def test_store_hit_roundtrips_through_dequant_kernel(bits):
    """The artifact a hit serves is the encoded QuantizedTensor; the
    device decodes it with the same Pallas kernel as a cold stream, so
    kernel output must match the numpy dequantize reference."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.kv_dequant.ops import dequantize_chunk

    rng = np.random.default_rng(7 + bits)
    x = rng.standard_normal((64, 32)).astype(np.float32)
    qt = quantize(x, bits, group=32)
    ref = dequantize(qt)
    out = np.asarray(dequantize_chunk(qt, out_dtype=jnp.float32))
    np.testing.assert_allclose(out, ref, rtol=1e-5, atol=1e-6)
