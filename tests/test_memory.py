"""KV memory server: residency accounting, eviction policy ordering,
reload-planner cost parity with the core cost models, evict-to-lower-bits
round-trips through the quantizer and the fused dequant kernel, and
end-to-end cluster behaviour — unbounded tracking is bit-identical to a
memory-less fleet, finite budgets evict and reload without losing
requests, and admission gating queues rather than deadlocks."""
import numpy as np
import pytest

from repro.compression.quantize import (BITRATE_LEVELS, dequantize,
                                        quant_error, quantize)
from repro.configs import SparKVConfig, get_config
from repro.core.costs import (DISK_TIERS, MemoryModel, PROFILES,
                              RunQueueModel, t_disk_read)
from repro.core.engine import context_kv_bytes, token_kv_bytes
from repro.serving.cluster import (RequestSpec, ServingCluster,
                                   telemetry_policy)
from repro.serving.decode import DecodeConfig
from repro.serving.memory import KVMemoryServer, plan_reload
from repro.serving.resources import DiskServer

CFG = get_config("sparkv-qwen3-4b")
SP = SparKVConfig(scheduler_mode="engine")
PROF = PROFILES["jetson-orin"]
GB = 1e9


def make_cluster(**kw):
    kw.setdefault("max_concurrency", 8)
    return ServingCluster(CFG, SP, "jetson-orin", "campus-wifi", **kw)


def _specs(n, out=24, ctx=4096):
    return [RequestSpec(context_len=ctx, arrival_s=0.1 * i, device=0,
                        max_new_tokens=out) for i in range(n)]


# ---------------------------------------------------------------------------
# residency accounting
# ---------------------------------------------------------------------------

def test_charge_release_accounting():
    m = KVMemoryServer(MemoryModel(capacity_bytes=None))
    m.admit(0, 0.0)
    m.admit(1, 0.0)
    m.charge(0, 1.0 * GB, 1.0)
    m.charge(1, 0.5 * GB, 2.0)
    m.charge(0, 0.25 * GB, 3.0)
    assert np.isclose(m.resident_bytes(), 1.75 * GB)
    assert np.isclose(m.peak_resident, 1.75 * GB)
    assert m.pressure() == 0.0                    # unbounded
    m.release(0, 4.0)
    assert np.isclose(m.resident_bytes(), 0.5 * GB)
    m.release(1, 5.0)
    assert m.resident_bytes() == 0.0
    assert np.isclose(m.freed_total, m.charged_total)
    assert abs(m.ledger_balance()) < 1.0


def test_kv_byte_model_matches_config():
    """context/token KV byte helpers: per-token bytes times context
    equals the context total, and SSM-style configs pin decode growth
    to zero."""
    ctx = 8192
    total = context_kv_bytes(CFG, ctx)
    per_tok = token_kv_bytes(CFG)
    assert total > 0 and per_tok > 0
    assert np.isclose(total, per_tok * ctx, rtol=1e-9)


def test_time_weighted_percentile():
    m = KVMemoryServer(MemoryModel(capacity_bytes=None))
    m.admit(0, 0.0)
    m.charge(0, 1.0 * GB, 0.0)      # 1 GB held for 99 s
    m.charge(0, 9.0 * GB, 99.0)     # 10 GB held for 1 s
    m.release(0, 100.0)
    assert np.isclose(m.resident_percentile(50), 1.0 * GB)
    assert m.resident_percentile(99.9) >= 9.0 * GB


# ---------------------------------------------------------------------------
# eviction policies
# ---------------------------------------------------------------------------

def _loaded_server(model, n=3, each=1.0 * GB):
    m = KVMemoryServer(model)
    for r in range(n):
        m.admit(r, float(r))
        m.charge(r, each, float(r))
        m.mark_ready(r, float(r))    # t_last_use = r: rid 0 is LRU
    return m


def test_lru_evicts_least_recently_used():
    m = _loaded_server(MemoryModel(capacity_bytes=3.0 * GB, policy="lru",
                                   disk=None))
    m.touch(0, 10.0)                 # rid 1 becomes the LRU victim
    m.admit(3, 11.0)
    evs = m.charge(3, 1.0 * GB, 11.0)
    assert [e.rid for e in evs] == [1]
    assert evs[0].action == "drop"   # no disk tier configured
    assert m.needs_reload(1)
    assert m.resident_total <= 3.0 * GB + 1.0
    assert abs(m.ledger_balance()) < 1.0


def test_idle_policy_prefers_parked_sequences():
    """With an idle set, the most-recently-used parked sequence still
    loses to any member of the active batch."""
    m = _loaded_server(MemoryModel(capacity_bytes=3.0 * GB, policy="idle",
                                   disk=None))
    m.touch(0, 10.0)                 # rid 0 is the *most* recent
    m.admit(3, 11.0)
    evs = m.charge(3, 1.0 * GB, 11.0, idle=frozenset({0}))
    assert [e.rid for e in evs] == [0]


def test_pinned_rids_are_never_victims():
    m = _loaded_server(MemoryModel(capacity_bytes=3.0 * GB, policy="lru",
                                   disk=None))
    m.admit(3, 11.0)
    evs = m.charge(3, 1.0 * GB, 11.0, pinned=frozenset({0, 1}))
    assert [e.rid for e in evs] == [2]
    # pin everyone: the server over-commits rather than deadlocking
    m2 = _loaded_server(MemoryModel(capacity_bytes=3.0 * GB, policy="lru",
                                    disk=None))
    m2.admit(3, 11.0)
    evs2 = m2.charge(3, 1.0 * GB, 11.0, pinned=frozenset({0, 1, 2}))
    assert evs2 == [] and m2.resident_total > 3.0 * GB


def test_bits_policy_downgrades_in_place_then_demotes():
    """Evict-to-lower-bits walks the victim down the quantization ladder
    without suspending it; only at the ladder floor does it demote."""
    m = _loaded_server(MemoryModel(capacity_bytes=3.0 * GB, policy="bits"),
                       n=3)
    m.admit(3, 11.0)
    evs = m.charge(3, 1.0 * GB, 11.0)
    assert evs and all(e.action == "downgrade" for e in evs)
    assert not any(m.needs_reload(r) for r in range(3))   # nobody parked
    first = evs[0]
    assert first.bits == BITRATE_LEVELS[0]                # 16 -> 8
    assert np.isclose(m.bits_of(first.rid) / 16.0,
                      (1.0 * GB - first.freed_bytes) / (1.0 * GB))
    # crush the budget: ladders bottom out at 3 bits, then demote/drop
    evs = m.charge(3, 3.0 * GB, 12.0)
    assert any(e.action in ("demote", "drop") for e in evs) \
        or m.resident_total > m.capacity   # or everyone is at the floor
    assert abs(m.ledger_balance()) < 1.0


def test_bits_cold_pool_downgrades_cold_share_first():
    """cold_frac < 1: pressure requantizes only the victim's cold pool
    until it floors; the hot remainder keeps its width (and the record's
    bits_of stays the hot width)."""
    m = _loaded_server(MemoryModel(capacity_bytes=3.0 * GB, policy="bits",
                                   cold_frac=0.5, disk=None), n=3)
    m.admit(3, 11.0)
    evs = m.charge(3, 0.2 * GB, 11.0)
    assert evs and evs[0].action == "downgrade"
    # cold pool = 0.5 GB at 16 bits -> 8 bits frees exactly 0.25 GB
    assert np.isclose(evs[0].freed_bytes, 0.25 * GB)
    assert evs[0].bits == BITRATE_LEVELS[0]
    assert m.bits_of(evs[0].rid) == 16     # hot pool untouched
    assert abs(m.ledger_balance()) < 1.0
    # keep crushing: the cold pool floors at 3 bits before any hot-pool
    # downgrade, then the hot pool walks, then demote/drop
    evs = m.charge(3, 3.5 * GB, 12.0)
    seen_hot = [e for e in evs
                if e.action == "downgrade" and m.bits_of(e.rid) < 16]
    floored = [e for e in evs if e.action in ("demote", "drop")]
    assert seen_hot or floored or m.resident_total > m.capacity
    assert abs(m.ledger_balance()) < 1.0


def test_bits_cold_frac_default_is_whole_resident():
    """cold_frac defaults to 1.0 = the legacy whole-resident downgrade:
    first eviction frees bytes * (1 - 8/16) in one step."""
    assert MemoryModel().cold_frac == 1.0
    m = _loaded_server(MemoryModel(capacity_bytes=3.0 * GB, policy="bits",
                                   disk=None), n=3)
    m.admit(3, 11.0)
    evs = m.charge(3, 0.2 * GB, 11.0)
    assert np.isclose(evs[0].freed_bytes, 0.5 * GB)
    assert m.bits_of(evs[0].rid) == 8


def test_bits_cold_pool_conservation_under_pressure_storm():
    """The charged == resident + disk + dropped + freed ledger holds
    through interleaved cold-pool downgrades, demotions and reloads."""
    m = _loaded_server(MemoryModel(capacity_bytes=2.0 * GB, policy="bits",
                                   cold_frac=0.3, disk="ufs-3.1"), n=2)
    for i, extra in enumerate([0.5, 1.0, 2.0, 4.0]):
        rid = 10 + i
        m.admit(rid, 20.0 + i)
        m.charge(rid, extra * GB, 20.0 + i)
        m.mark_ready(rid, 20.0 + i)
        assert abs(m.ledger_balance()) < 1.0
    for rid in list(m._res):
        if m.needs_reload(rid):
            m.begin_reload(rid, 30.0)
            m.finish_reload(rid, 31.0)
            assert abs(m.ledger_balance()) < 1.0


def test_bits_growth_lands_at_downgraded_width():
    m = _loaded_server(MemoryModel(capacity_bytes=3.0 * GB, policy="bits",
                                   disk=None), n=3)
    m.admit(3, 11.0)
    m.charge(3, 1.0 * GB, 11.0)      # downgrades rid 0 to 8 bits
    assert m.bits_of(0) == 8
    before = m.resident_total
    m.charge(0, 1.0 * GB, 12.0)      # decode growth: charged at 8/16
    assert np.isclose(m.resident_total - before, 0.5 * GB, rtol=1e-6) \
        or m.resident_total <= m.capacity + 1.0   # unless it re-evicted


# ---------------------------------------------------------------------------
# demote / reload through the disk tier
# ---------------------------------------------------------------------------

def test_demote_reload_roundtrip_through_disk():
    m = _loaded_server(MemoryModel(capacity_bytes=3.0 * GB, policy="lru",
                                   disk="ufs-3.1"))
    m.admit(3, 11.0)
    evs = m.charge(3, 1.0 * GB, 11.0)
    assert evs[0].action == "demote"
    rid = evs[0].rid
    assert np.isclose(m.disk_total, 1.0 * GB)
    assert m.disk.bytes_written == pytest.approx(1.0 * GB)
    ev = m.begin_reload(rid, 12.0)
    assert ev.from_disk and np.isclose(ev.nbytes, 1.0 * GB)
    m.release(3, 13.0)               # make room for the restore
    m.finish_reload(rid, 14.0)
    assert m.disk_total == 0.0 and not m.needs_reload(rid)
    assert np.isclose(m._res[rid].bytes, 1.0 * GB)
    assert abs(m.ledger_balance()) < 1.0


def test_disk_server_serializes():
    prof = MemoryModel(disk="ufs-3.1").disk_profile
    d = DiskServer(prof)
    t1 = d.submit(1.0 * GB, 0.0, op="write")
    t2 = d.submit(1.0 * GB, 0.0, op="read")
    assert t1 == pytest.approx(prof.latency_s + 1.0 * GB / prof.write_bw)
    assert t2 == pytest.approx(t1 + prof.latency_s + 1.0 * GB / prof.read_bw)
    assert d.backlog_s(0.0) == pytest.approx(t2)
    assert d.backlog_s(t2 + 1.0) == 0.0


# ---------------------------------------------------------------------------
# reload planner: cost parity with the core models
# ---------------------------------------------------------------------------

# sized so the three paths cost the same order of magnitude (disk read
# bandwidth is ~100x the radio link, so the resident bytes dominate)
WIRE, RES, COMP = 8e6, 0.8e9, 0.7
DISK_PROF = MemoryModel(disk="ufs-3.1").disk_profile


def test_plan_reload_pure_mode_cost_parity():
    bw = 20e6
    chunk = (WIRE, RES, COMP)
    p = plan_reload([chunk], mode="restream", profile=PROF, stream_bw=bw)
    assert p.makespan_s == pytest.approx(WIRE / bw + PROF.t_proc(WIRE))
    assert p.n_stream == 1 and p.stream_bytes == WIRE
    p = plan_reload([chunk], mode="recompute", profile=PROF, stream_bw=bw,
                    comp_wait_s=0.3)
    assert p.makespan_s == pytest.approx(0.3 + COMP)
    assert p.n_comp == 1 and p.comp_s == COMP
    p = plan_reload([chunk], mode="disk", profile=PROF, stream_bw=bw,
                    disk=DISK_PROF, has_disk_copy=True)
    assert p.makespan_s == pytest.approx(t_disk_read(RES, DISK_PROF))
    assert p.n_disk == 1 and p.disk_bytes == RES
    # disk mode without a demoted copy falls back to restream
    p = plan_reload([chunk], mode="disk", profile=PROF, stream_bw=bw,
                    disk=None, has_disk_copy=False)
    assert p.n_stream == 1 and p.n_disk == 0


def test_planner_beats_single_paths_on_balanced_chunks():
    """With several identical chunks, spreading across the overlapping
    paths always projects a shorter makespan than any single path."""
    bw = 20e6
    chunks = [(WIRE, RES, COMP)] * 8
    kw = dict(profile=PROF, stream_bw=bw, disk=DISK_PROF,
              has_disk_copy=True)
    full = plan_reload(chunks, mode="planner", **kw)
    for mode in ("restream", "recompute", "disk"):
        pure = plan_reload(chunks, mode=mode, **kw)
        assert full.makespan_s <= pure.makespan_s + 1e-9
    assert full.n_stream + full.n_comp + full.n_disk == 8
    # at least two paths genuinely used
    assert sum(1 for n in (full.n_stream, full.n_comp, full.n_disk)
               if n > 0) >= 2


def test_planner_respects_backlog_seeds():
    """A path's live backlog steers chunks away from it: seed the comp
    path heavily and the planner must stop assigning to it."""
    bw = 20e6
    chunks = [(WIRE, RES, 0.01)] * 4          # compute looks very cheap
    free = plan_reload(chunks, mode="planner", profile=PROF, stream_bw=bw)
    assert free.n_comp == 4
    busy = plan_reload(chunks, mode="planner", profile=PROF, stream_bw=bw,
                       comp_wait_s=100.0)
    assert busy.n_comp == 0


# ---------------------------------------------------------------------------
# evict-to-lower-bits fidelity: ladder round-trip + fused dequant kernel
# ---------------------------------------------------------------------------

def test_bits_ladder_roundtrip_and_kernel():
    """Requantizing down the ladder degrades monotonically, and the
    fused kv_dequant kernel reproduces the quantizer's reconstruction
    for the resident codes at every ladder level."""
    jnp = pytest.importorskip("jax.numpy")
    from repro.kernels.kv_dequant.kernel import kv_dequant
    from repro.kernels.kv_dequant.ref import kv_dequant_ref
    rng = np.random.default_rng(7)
    group, rows, width = 64, 8, 256
    kv = rng.standard_normal((rows, width)).astype(np.float32)
    errs = []
    for bits in BITRATE_LEVELS:
        qt = quantize(kv, bits, group)
        errs.append(np.sqrt(np.mean((dequantize(qt) - kv) ** 2)))
        codes = qt.codes.reshape(rows, width)
        scales = qt.scales.reshape(rows, width // group)
        zeros = qt.zeros.reshape(rows, width // group)
        out = np.asarray(kv_dequant(codes, scales, zeros, group=group,
                                    interpret=True), np.float32)
        ref = np.asarray(kv_dequant_ref(jnp.asarray(codes),
                                        jnp.asarray(scales),
                                        jnp.asarray(zeros), group=group),
                         np.float32)
        # one bf16 ulp: interpret-mode rounding at the cast boundary
        np.testing.assert_allclose(out, ref, rtol=2 ** -7, atol=1e-6)
        np.testing.assert_allclose(out, dequantize(qt).reshape(rows, width),
                                   atol=0.05)    # bf16 rounding only
    # coarser resident bits -> strictly worse reconstruction
    assert all(a <= b + 1e-9 for a, b in zip(errs, errs[1:]))
    assert quant_error(kv, 3, group) > quant_error(kv, 8, group)


# ---------------------------------------------------------------------------
# end-to-end cluster behaviour
# ---------------------------------------------------------------------------

def test_unbounded_tracking_is_bit_identical():
    """A passive (capacity=None) memory server must not perturb the
    fleet: every record field identical, only the telemetry block is
    added to the summary."""
    specs = _specs(4)
    r0 = make_cluster().run(specs)
    r1 = make_cluster(memory=MemoryModel(capacity_bytes=None)).run(specs)
    for a, b in zip(r0.records, r1.records):
        assert a.ttft_s == b.ttft_s
        assert a.ttlt_s == b.ttlt_s
        assert a.energy_j == b.energy_j
        assert a.n_streamed == b.n_streamed
        assert b.n_evictions == 0 and b.reload_s == 0.0
        assert b.kv_bits == 16
    s0, s1 = r0.summary(), r1.summary()
    assert "peak_resident_bytes" not in s0
    assert s1["peak_resident_bytes"] > 0
    assert s1["goodput_tok_s"] == s0["goodput_tok_s"]
    assert r0.memory is None and r1.memory is not None


def test_finite_budget_evicts_reloads_and_completes():
    specs = _specs(5, out=32)
    peak = make_cluster(memory=MemoryModel(capacity_bytes=None)) \
        .run(specs).summary()["peak_resident_bytes"]
    rep = make_cluster(
        memory=MemoryModel(capacity_bytes=0.5 * peak)).run(specs)
    s = rep.summary()
    assert len(rep.records) == len(specs)
    assert s["n_evictions"] > 0 and s["n_reloads"] > 0
    assert s["reload_s_total"] > 0
    assert any(r.reload_s > 0 for r in rep.records)
    assert any(r.n_evictions > 0 for r in rep.records)
    assert rep.memory["peak_resident_bytes"] <= peak + 1.0
    # eviction stalls show up where they belong: the tail got slower
    assert s["ttlt_p99_s"] >= make_cluster().run(specs) \
        .summary()["ttlt_p99_s"] - 1e-9


def test_finite_budget_with_run_queue_and_bits():
    specs = _specs(5, out=32)
    peak = make_cluster(memory=MemoryModel(capacity_bytes=None)) \
        .run(specs).summary()["peak_resident_bytes"]
    rep = make_cluster(
        run_queue=RunQueueModel(1, "fifo"),
        decode=DecodeConfig(max_batch=4),
        memory=MemoryModel(capacity_bytes=0.5 * peak,
                           policy="bits")).run(specs)
    assert len(rep.records) == len(specs)
    assert rep.memory["n_downgrades"] > 0
    assert any(r.kv_bits < 16 for r in rep.records)


def test_admission_gate_queues_then_drains():
    """A tight gate_frac holds arrivals while residency is projected
    over budget but never deadlocks: the fleet still finishes every
    request, and the gate never holds an empty fleet."""
    specs = _specs(5)
    peak = make_cluster(memory=MemoryModel(capacity_bytes=None)) \
        .run(specs).summary()["peak_resident_bytes"]
    gated = make_cluster(
        memory=MemoryModel(capacity_bytes=0.6 * peak,
                           gate_frac=0.8)).run(specs)
    free = make_cluster(
        memory=MemoryModel(capacity_bytes=0.6 * peak)).run(specs)
    assert len(gated.records) == len(specs)
    # gating trades queue wait for eviction churn
    assert gated.summary()["n_evictions"] \
        <= free.summary()["n_evictions"]
    assert gated.summary()["queue_wait_p99_s"] \
        >= free.summary()["queue_wait_p99_s"] - 1e-9


def test_memory_budget_sugar():
    specs = _specs(3)
    r = make_cluster(memory_budget=2.0 * GB).run(specs)
    assert r.memory is not None
    assert r.memory["peak_resident_bytes"] > 0


# ---------------------------------------------------------------------------
# decode-aware telemetry policy
# ---------------------------------------------------------------------------

class _StubCluster:
    """Duck-typed stand-in exposing exactly the live signals
    telemetry_policy reads."""
    capacity = 2
    decode_cfg = DecodeConfig(max_batch=8)

    def __init__(self, frac=0.1, load=0, occ=0, pressure=0.0):
        self._frac, self._load = frac, load
        self._occ, self._pressure = occ, pressure

    def projected_flow_frac(self, device):
        return self._frac

    def device_load(self, device):
        return self._load

    def decode_occupancy(self, device):
        return self._occ

    def memory_pressure(self, device):
        return self._pressure


def test_telemetry_policy_memory_and_decode_vetoes():
    spec = RequestSpec(context_len=4096, arrival_s=0.0)
    # starved link + idle device: local prefill
    assert telemetry_policy(spec, _StubCluster()) == "local_prefill"
    # memory pressure above the ceiling vetoes the switch
    assert telemetry_policy(
        spec, _StubCluster(pressure=0.95)) == "sparkv"
    # a full decode batch vetoes it too
    assert telemetry_policy(spec, _StubCluster(occ=8)) == "sparkv"
    # both signals below their ceilings: the veto lifts
    assert telemetry_policy(
        spec, _StubCluster(pressure=0.5, occ=3)) == "local_prefill"


def test_disk_tier_catalog():
    for name, _ in DISK_TIERS.items():
        prof = MemoryModel(disk=name).disk_profile
        assert prof.read_bw > 0 and prof.write_bw > 0
        assert t_disk_read(1.0 * GB, prof) > 1.0 * GB / prof.read_bw
