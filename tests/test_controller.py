"""RuntimeController migration paths + the engine-side guards.

Covers the previously-untested paths: stream->compute and compute->stream
migrations, the dependency-stranding guard (a chunk whose compute-assigned
dependent needs it *computed* must not be migrated to streaming), and the
starved-compute fallback (engine moves dependency-dead compute chunks to
the always-feasible stream path).
"""
import numpy as np
import pytest

from repro.configs import SparKVConfig, get_config
from repro.core.chunks import Chunk, ChunkGrid
from repro.core.controller import Migration, RuntimeController
from repro.core.costs import GroundTruthLatency, PROFILES
from repro.core.engine import BandwidthIntegrator, HybridEngine
from repro.core.scheduler import Schedule, Stage

CFG = get_config("sparkv-qwen3-4b")
PROFILE = PROFILES["jetson-orin"]


class ScriptedController:
    """Stands in for RuntimeController: returns queued migrations once."""

    def __init__(self, migrations):
        self._pending = list(migrations)

    def record_stream(self, t, nbytes):
        pass

    def record_compute(self, t, actual_s, predicted_s):
        pass

    def decide(self, now, **kw):
        out, self._pending = self._pending, []
        return out


def make_engine(n_t, n_l, *, controller=None, bw_bps=50e6, seed=0):
    grid = ChunkGrid(n_t=n_t, n_l=n_l, n_h=1)
    chunks = list(grid.chunks())
    bytes_map = {c: 2e5 for c in chunks}
    active_map = {c: 40.0 for c in chunks}
    t_pred = {c: 5e-3 for c in chunks}
    bw = BandwidthIntegrator(np.full(5000, bw_bps), 0.01)
    gt = GroundTruthLatency(PROFILE, CFG.resolved_head_dim)
    return grid, HybridEngine(
        grid=grid, chunk_bytes=bytes_map, active_blocks=active_map,
        t_comp_pred=t_pred, gt=gt, profile=PROFILE, bw=bw, cfg_model=CFG,
        controller=controller, seed=seed)


def schedule_of(grid, *, comp=(), stream=()):
    st = Stage(stream=list(stream), comp=list(comp))
    return Schedule(stages=[st], grid=grid)


def test_stream_to_compute_migration_executes():
    c_target = Chunk(1, 1, 0)
    ctrl = ScriptedController([Migration(c_target, "compute", "bw_drop")])
    grid, eng = make_engine(2, 2, controller=ctrl)
    # (0,1) streams first, so the target is still queued (not in flight)
    # when the controller fires at the first completion boundary
    sched = schedule_of(grid,
                        comp=[Chunk(0, 0, 0), Chunk(1, 0, 0)],
                        stream=[Chunk(0, 1, 0), Chunk(1, 1, 0)])
    res = eng.run(sched, context_len=2048)
    assert res.n_migrations == 1
    assert c_target in res.computed_set
    assert res.n_streamed + res.n_computed == grid.size


def test_compute_to_stream_stranding_guard():
    """(0,1) must NOT migrate to stream while its dependent (0,2) is
    compute-assigned; (0,2) itself (no dependent) may migrate."""
    strand = Migration(Chunk(0, 1, 0), "stream", "contention")
    ok = Migration(Chunk(0, 2, 0), "stream", "contention")
    ctrl = ScriptedController([strand, ok])
    grid, eng = make_engine(1, 3, controller=ctrl)
    sched = schedule_of(grid, comp=[Chunk(0, 0, 0), Chunk(0, 1, 0),
                                    Chunk(0, 2, 0)])
    res = eng.run(sched, context_len=1024)
    assert res.n_migrations == 1
    assert Chunk(0, 2, 0) in res.streamed_set        # migrated tail
    assert Chunk(0, 1, 0) in res.computed_set        # guard held it back
    assert res.n_streamed + res.n_computed == grid.size


def test_starved_compute_falls_back_to_stream():
    """A compute chunk whose layer dep was *streamed* can never become
    ready; with nothing in flight the engine must re-path it to stream
    instead of stalling."""
    grid, eng = make_engine(1, 2)
    sched = schedule_of(grid, stream=[Chunk(0, 0, 0)],
                        comp=[Chunk(0, 1, 0)])
    res = eng.run(sched, context_len=1024)
    assert res.n_streamed == 2 and res.n_computed == 0
    assert res.n_migrations == 0     # fallback is a re-path, not a decision


def test_controller_decides_compute_pull_on_bandwidth_drop():
    sp = SparKVConfig()
    ctrl = RuntimeController(sp, plan_bw=100e6)
    c0, c1 = Chunk(0, 0, 0), Chunk(1, 0, 0)
    # terrible measured bandwidth: 1 KB delivered in the whole window
    ctrl.record_stream(0.1, 1e3)
    migr = ctrl.decide(0.1, stream_queue=[c0, c1], comp_queue=[],
                       ready={c0, c1},
                       chunk_bytes={c0: 5e6, c1: 5e6},
                       t_comp_pred={c0: 1e-3, c1: 2e-3})
    assert migr and all(m.to_path == "compute" for m in migr)
    # cheapest-compute first
    assert migr[0].chunk == c0


def test_controller_decides_shed_on_compute_contention():
    sp = SparKVConfig()
    ctrl = RuntimeController(sp, plan_bw=100e6)
    chunks = [Chunk(0, l, 0) for l in range(4)]
    # compute running 3x slower than predicted
    ctrl.record_compute(0.05, actual_s=0.03, predicted_s=0.01)
    migr = ctrl.decide(0.05, stream_queue=[], comp_queue=chunks,
                       ready=set(),
                       chunk_bytes={c: 1e4 for c in chunks},
                       t_comp_pred={c: 0.5 for c in chunks})
    assert migr and all(m.to_path == "stream" for m in migr)
    # tail-first: the last compute chunk sheds first
    assert migr[0].chunk == chunks[-1]


def test_queue_pressure_triggers_shed():
    """Queue waits alone (undilated service) must push the compute-path
    estimate over the shed threshold: waiting work is a bottleneck even
    when each chunk runs exactly as predicted."""
    sp = SparKVConfig()
    chunks = [Chunk(0, l, 0) for l in range(4)]
    s_chunks = [Chunk(1, l, 0) for l in range(2)]
    # measured bw falls back to plan_bw (100 MB/s): stream backlog 2e8 B
    # -> t_s = 2.0 s, exactly balancing the 4 x 0.5 s compute backlog
    kw = dict(stream_queue=s_chunks, comp_queue=chunks, ready=set(),
              chunk_bytes={**{c: 1e4 for c in chunks},
                           **{c: 1e8 for c in s_chunks}},
              t_comp_pred={**{c: 0.5 for c in chunks},
                           **{c: 0.1 for c in s_chunks}})
    base = RuntimeController(sp, plan_bw=100e6)
    base.record_compute(0.05, actual_s=0.01, predicted_s=0.01)
    assert base.decide(0.05, **kw) == []          # balanced, no queue
    ctrl = RuntimeController(sp, plan_bw=100e6)
    ctrl.record_compute(0.05, actual_s=0.01, predicted_s=0.01)
    ctrl.record_queue_wait(0.05, wait_s=0.05, service_s=0.01)  # 5x wait
    assert ctrl.queue_pressure(0.05) == pytest.approx(5.0)
    migr = ctrl.decide(0.05, **kw)
    assert migr and all(m.to_path == "stream" for m in migr)


def test_deadline_guard_blocks_stream_shed_on_congested_link():
    """A near-deadline flow on a congested link must not shed compute
    chunks to streaming; a far deadline or a healthy link lifts the
    guard (SLO layer: don't migrate imminent work onto a starved hop)."""
    sp = SparKVConfig()
    chunks = [Chunk(0, l, 0) for l in range(4)]
    kw = dict(stream_queue=[], comp_queue=chunks, ready=set(),
              chunk_bytes={c: 1e4 for c in chunks},
              t_comp_pred={c: 0.5 for c in chunks})

    def contended(deadline=None, congested=True):
        ctrl = RuntimeController(sp, plan_bw=100e6)
        ctrl.record_compute(0.05, actual_s=0.03, predicted_s=0.01)
        if congested:
            ctrl.record_stream(0.05, 1e3)     # ~5 KB/s << 100 MB/s plan
        if deadline is not None:
            ctrl.set_deadline(deadline)
        return ctrl.decide(0.05, **kw)

    assert contended(deadline=None) != []               # no SLO: sheds
    assert contended(deadline=1.0) == []                # guard holds
    assert contended(deadline=100.0) != []              # slack is ample
    assert contended(deadline=1.0, congested=False) != []   # link healthy


def test_migration_budget_bounded_per_window():
    sp = SparKVConfig(max_migrations_per_stage=2)
    ctrl = RuntimeController(sp, plan_bw=100e6)
    chunks = [Chunk(0, l, 0) for l in range(8)]
    ctrl.record_compute(0.05, actual_s=0.05, predicted_s=0.01)
    migr = ctrl.decide(0.05, stream_queue=[], comp_queue=chunks,
                       ready=set(), chunk_bytes={c: 1e4 for c in chunks},
                       t_comp_pred={c: 0.5 for c in chunks})
    assert len(migr) <= 2
    # budget exhausted within the same window
    assert ctrl.decide(0.06, stream_queue=[], comp_queue=chunks,
                       ready=set(), chunk_bytes={c: 1e4 for c in chunks},
                       t_comp_pred={c: 0.5 for c in chunks}) == []
