"""Typed event core (`serving/simcore`) and vectorized-vs-scalar fleet
parity: the EventQueue must reproduce bare-heapq semantics exactly
(including batched insertion), SimStats must account run throughput, and
`ServingCluster(link_core=...)` must produce *bit-identical* fleet
reports on either core across disciplines × memory pressure."""
import dataclasses
import heapq
import random

import pytest

from repro.configs import SparKVConfig, get_config
from repro.core.costs import MemoryModel, RunQueueModel
from repro.serving.cluster import ServingCluster
from repro.serving.simcore import STATS, Event, EventKind, EventQueue, SimStats
from repro.serving.traffic import poisson_trace

CFG = get_config("sparkv-qwen3-4b")
SP = SparKVConfig(scheduler_mode="engine")


# ---------------------------------------------------------------------------
# EventQueue semantics
# ---------------------------------------------------------------------------

def test_event_queue_pop_order_matches_bare_heapq():
    """Same (t, seq) records, pushed one by one: EventQueue pops in
    exactly the order a bare tuple heap would (ties broken by push
    order), with unorderable payloads never compared."""
    rng = random.Random(3)
    times = [round(rng.uniform(0, 5), 2) for _ in range(200)]
    q = EventQueue()
    ref = []
    for i, t in enumerate(times):
        q.push(t, EventKind.ARRIVAL, i, payload={"rid": i})  # dict: unorderable
        heapq.heappush(ref, (t, i))
    got = []
    while q:
        ev = q.pop()
        got.append((ev.t, ev.seq))
        assert ev.payload == {"rid": ev.rid}
    assert got == [heapq.heappop(ref) for _ in range(len(times))]
    assert q.n_pushed == q.n_popped == len(times)


def test_push_many_batched_equals_sequential_pushes():
    """push_many's heapify fast path (batch > heap) and its fallback
    must both pop identically to k sequential pushes — including ties,
    which resolve by record order."""
    rng = random.Random(11)
    recs = [(round(rng.uniform(0, 3), 1), EventKind.COMPUTE_DONE, i, None)
            for i in range(150)]
    seq_q, bulk_q, mixed_q = EventQueue(), EventQueue(), EventQueue()
    for t, k, rid, p in recs:
        seq_q.push(t, k, rid, p)
    bulk_q.push_many(recs)                       # heapify path (empty heap)
    mixed_q.push_many(recs[:100])                # then a small batch:
    mixed_q.push_many(recs[100:])                # push-loop fallback path
    orders = []
    for q in (seq_q, bulk_q, mixed_q):
        order = []
        while q:
            ev = q.pop()
            order.append((ev.t, ev.seq, ev.kind, ev.rid))
        orders.append(order)
    assert orders[0] == orders[1] == orders[2]


def test_peek_t_and_empty_behaviour():
    q = EventQueue()
    assert q.peek_t() == float("inf")
    assert not q and len(q) == 0
    q.push(2.0, EventKind.DECODE_DONE, 0)
    q.push(1.0, EventKind.ARRIVAL, 1)
    assert q.peek_t() == 1.0                     # peek does not pop
    assert q.peek_t() == 1.0 and len(q) == 2
    ev = q.pop()
    assert (ev.t, ev.kind, ev.rid) == (1.0, EventKind.ARRIVAL, 1)
    assert isinstance(ev, Event)


def test_event_ordering_never_compares_payloads():
    """Identical timestamps with unorderable payloads: seq breaks the
    tie before comparison ever reaches kind/payload."""
    q = EventQueue()
    q.push(1.0, EventKind.ARRIVAL, 0, payload=object())
    q.push(1.0, EventKind.ARRIVAL, 1, payload=object())
    assert q.pop().rid == 0 and q.pop().rid == 1


def test_sim_stats_accumulates_and_resets():
    s = SimStats()
    assert s.events_per_s() is None
    s.record(100, 0.5)
    s.record(50, 0.5)
    assert s.n_events == 150 and s.n_runs == 2
    assert s.events_per_s() == pytest.approx(150.0)
    snap = s.snapshot()
    assert snap["sim_events"] == 150 and snap["sim_runs"] == 2
    s.reset()
    assert s.n_events == 0 and s.events_per_s() is None


# ---------------------------------------------------------------------------
# fleet bit-parity: vectorized vs scalar link core
# ---------------------------------------------------------------------------

def _fleet_fingerprint(report):
    """Every per-request observable that the link server can influence,
    exactly as produced (no rounding)."""
    return [(r.spec.arrival_s, r.ttft_s, r.ttlt_s, r.energy_j,
             r.uplink_share,
             r.compute_wait_s, r.bytes_streamed, r.policy,
             tuple(sorted(r.stage_shares.items())))
            for r in report.records]


@pytest.mark.parametrize("discipline", ["fifo", "wfq", "srpt"])
@pytest.mark.parametrize("mem_cap", [None, 2e8])
def test_fleet_bit_parity_across_cores(discipline, mem_cap):
    """Fixed-seed fleets at N ≤ 32: the vectorized core's run report is
    bit-identical to the scalar core's, across run-queue disciplines and
    with/without KV memory pressure (reload flows re-add keys, the
    telemetry-continuation path)."""
    specs = poisson_trace(16, 2.0, max_context=2048, seed=5)
    reports = {}
    for core in ("vectorized", "scalar"):
        cluster = ServingCluster(
            CFG, SP, "jetson-orin", "campus-wifi", n_devices=2,
            run_queue=RunQueueModel(2, discipline),
            memory=(MemoryModel(capacity_bytes=mem_cap)
                    if mem_cap else None),
            max_concurrency=8, link_core=core)
        reports[core] = cluster.run(specs)
        assert cluster.last_sim_stats["n_events"] > 0
        assert cluster.last_sim_stats["wall_s"] >= 0
    assert _fleet_fingerprint(reports["vectorized"]) == \
        _fleet_fingerprint(reports["scalar"])


def test_link_core_param_validated():
    with pytest.raises(AssertionError):
        ServingCluster(CFG, SP, link_core="simd")


def test_cluster_records_sim_stats_globally():
    """Every run contributes its event count to the process-wide STATS
    accumulator that --profile snapshots."""
    specs = poisson_trace(6, 2.0, max_context=2048, seed=9)
    before = STATS.n_events, STATS.n_runs
    cluster = ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                             max_concurrency=4)
    cluster.run(specs)
    assert STATS.n_runs == before[1] + 1
    assert STATS.n_events == before[0] + cluster.last_sim_stats["n_events"]
    st = cluster.last_sim_stats
    assert st["n_heap_events"] + st["n_link_completions"] == st["n_events"]


@pytest.mark.parametrize("core", ["vectorized", "scalar"])
def test_kvstore_zero_overlap_is_bit_identical_to_disabled(core):
    """Arming the content-addressed KV store on a trace whose content
    ids never repeat (prefix_frac=0.0: every chain request-unique) must
    leave the fleet report bit-identical to the store-disabled run on
    either link core — the reuse layer prices misses at exactly zero.
    The store still observes the traffic: all lookups count as misses."""
    from repro.core.costs import KVStoreModel
    from repro.serving.traffic import TrafficProfile, generate_trace

    prof = TrafficProfile(rate_rps=2.0, n_devices=2, max_context=2048)
    plain = generate_trace(prof, 12, seed=5)
    zero = generate_trace(
        dataclasses.replace(prof, prefix_pool=8, prefix_frac=0.0),
        12, seed=5)
    assert all(s.content_ids for s in zero)

    def fleet(specs, kv):
        return ServingCluster(
            CFG, SP, "jetson-orin", "campus-wifi", n_devices=2,
            max_concurrency=8, link_core=core, kvstore=kv).run(specs)

    off = fleet(plain, None)
    on = fleet(zero, KVStoreModel(capacity_bytes=1e9))
    assert _fleet_fingerprint(off) == _fleet_fingerprint(on)
    assert off.reuse is None
    assert on.reuse["store"]["n_hits"] == 0
    assert on.reuse["store"]["n_misses"] > 0
    assert on.reuse["local_hits_total"] == 0


def test_link_telemetry_off_preserves_latency_results():
    """`link_telemetry=False` must leave every latency/energy observable
    bit-identical and only blank the share telemetry (mean_share -> 1.0
    convention, stage_shares -> {})."""
    specs = poisson_trace(10, 2.0, max_context=2048, seed=7)
    on = ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                        max_concurrency=8).run(specs)
    off = ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                         max_concurrency=8, link_telemetry=False).run(specs)
    for a, b in zip(on.records, off.records):
        assert (a.ttft_s, a.ttlt_s, a.energy_j, a.bytes_streamed) == \
            (b.ttft_s, b.ttlt_s, b.energy_j, b.bytes_streamed)
        assert b.stage_shares == {}
