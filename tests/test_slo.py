"""SLO admission layer: deadline->weight mapping, TTFT prediction,
the exact shed boundary, the quantization downgrade walk (including the
concrete kv_dequant round-trip at coarser bits), and deadline-class
traffic generation."""
import numpy as np
import pytest

from repro.compression.quantize import (dequantize, downgrade_ladder,
                                        quantize)
from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS, RunQueueModel
from repro.core.engine import BandwidthIntegrator
from repro.core.predictor import backlog_delay_s
from repro.data.workloads import DATASETS, synthesize
from repro.kernels.kv_dequant.ops import dequantize_chunk
from repro.serving.cluster import RequestSpec, ServingCluster
from repro.serving.resources import DeviceRunQueue, single_link
from repro.serving.slo import SLOPolicy, decide_admission, predict_ttft
from repro.serving.traffic import TrafficProfile, generate_trace

CFG = get_config("sparkv-qwen3-4b")
SP = SparKVConfig(scheduler_mode="engine")
NET = NETWORKS["campus-wifi"]


# ---------------------------------------------------------------------------
# policy knobs
# ---------------------------------------------------------------------------

def test_weight_for_slack_bins():
    pol = SLOPolicy(weight_bins=((2.0, 8.0), (5.0, 4.0)), base_weight=1.0)
    assert pol.weight_for_slack(0.5) == 8.0       # tightest bin
    assert pol.weight_for_slack(2.0) == 8.0       # inclusive threshold
    assert pol.weight_for_slack(3.0) == 4.0
    assert pol.weight_for_slack(10.0) == 1.0      # beyond every bin


def test_downgrade_ladder_is_coarser_finest_first():
    assert downgrade_ladder(5) == (4, 3)
    assert downgrade_ladder(8) == (6, 5, 4, 3)
    assert downgrade_ladder(3) == ()


def test_backlog_delay_drains_by_capacity():
    assert backlog_delay_s(4.0, 1) == 4.0
    assert backlog_delay_s(4.0, 2) == 2.0
    assert backlog_delay_s(4.0, 0) == 4.0         # capacity floor of 1


# ---------------------------------------------------------------------------
# prediction + admission decision against live servers
# ---------------------------------------------------------------------------

def _idle_cluster(**kw):
    kw.setdefault("run_queue", RunQueueModel(1, "fifo"))
    cl = ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                        max_concurrency=8, **kw)
    bw = BandwidthIntegrator(np.full(2000, NET.mean_bw), 0.01)
    cl._link_server = single_link(bw, cl.link)
    cl._run_queues = {0: DeviceRunQueue(cl.capacity,
                                        cl.run_queue.discipline)}
    return cl


def _plan(policy="cachegen", ctx=2048):
    wl = synthesize(CFG, ctx, DATASETS["longchat"],
                    chunk_tokens=SP.chunk_tokens, quant_bits=SP.quant_bits)
    return B.plan_policy(policy, CFG, wl, "jetson-orin", NET, SP, util=0.0)


def test_predict_ttft_grows_with_contention():
    cl = _idle_cluster()
    plan = _plan("cachegen")
    spec = RequestSpec(arrival_s=0.0, context_len=2048, deadline_s=5.0)
    idle = predict_ttft(plan, cl, spec, 0.0)
    assert idle > 0
    for i in range(3):                            # three competing flows
        cl._link_server.add(i, 1e7)
    assert predict_ttft(plan, cl, spec, 0.0) > idle
    # elapsed admission-queue wait counts against the deadline budget
    assert predict_ttft(plan, cl, spec, 2.0) == pytest.approx(
        predict_ttft(plan, cl, spec, 0.0) + 2.0)


def test_predict_ttft_caps_at_nic_bandwidth():
    """Two-stage topologies: the projection drains at the slower of the
    NIC mean and the uplink fair share (device-nic mean 75 MB/s < the
    campus-wifi uplink's 106 MB/s, so an idle NIC-capped cluster must
    predict a longer stream path than the bare uplink)."""
    plan = _plan("cachegen")
    spec = RequestSpec(arrival_s=0.0, context_len=2048, deadline_s=5.0)
    bare = predict_ttft(plan, _idle_cluster(), spec, 0.0)
    nic = predict_ttft(plan, _idle_cluster(nic="device-nic"), spec, 0.0)
    assert nic > bare


def test_predict_ttft_counts_device_backlog():
    cl = _idle_cluster()
    plan = _plan("local_prefill")
    spec = RequestSpec(arrival_s=0.0, context_len=2048, deadline_s=5.0)
    idle = predict_ttft(plan, cl, spec, 0.0)
    cl._run_queues[0].submit("x", 3.0, 0.0)       # 3 s of committed work
    assert predict_ttft(plan, cl, spec, 0.0) > idle + 2.9


def test_shed_boundary_is_exactly_the_prediction():
    """With downgrade off, the admit/shed flip happens exactly where the
    predicted TTFT crosses the deadline."""
    cl = _idle_cluster()
    plan = _plan("cachegen")
    pol = SLOPolicy(downgrade=False, shed=True)
    spec = RequestSpec(arrival_s=0.0, context_len=2048, deadline_s=0.0)
    pred = predict_ttft(plan, cl, spec, 0.0)
    spec.deadline_s = pred * 1.001
    dec = decide_admission(pol, plan, cl, spec, 0.0)
    assert dec.action == "admit" and not dec.downgraded
    assert dec.bits == plan.quality_bits
    spec.deadline_s = pred * 0.999
    dec = decide_admission(pol, plan, cl, spec, 0.0)
    assert dec.action == "shed"
    assert dec.pred_ttft_s == pytest.approx(pred)


def test_downgrade_walks_ladder_finest_first():
    """A stream-bound plan whose full-bits prediction misses but whose
    next-coarser prediction fits must admit at exactly that width; one
    level further down for the next deadline band; below the coarsest
    prediction it sheds."""
    cl = _idle_cluster()
    plan = _plan("cachegen")                      # stream-only plan
    pol = SLOPolicy(downgrade=True, shed=True)
    spec = RequestSpec(arrival_s=0.0, context_len=2048, deadline_s=1.0)
    ladder = downgrade_ladder(plan.quality_bits)
    b1, b2, b_min = ladder[0], ladder[1], ladder[-1]
    p0 = predict_ttft(plan, cl, spec, 0.0)
    p1 = predict_ttft(plan, cl, spec, 0.0, bits=b1)
    p2 = predict_ttft(plan, cl, spec, 0.0, bits=b2)
    p_min = predict_ttft(plan, cl, spec, 0.0, bits=b_min)
    assert p_min <= p2 < p1 < p0                  # fewer bits, fewer bytes

    spec.deadline_s = (p1 + p0) / 2
    dec = decide_admission(pol, plan, cl, spec, 0.0)
    assert (dec.action, dec.bits, dec.downgraded) == ("admit", b1, True)

    spec.deadline_s = (p2 + p1) / 2
    dec = decide_admission(pol, plan, cl, spec, 0.0)
    assert (dec.action, dec.bits, dec.downgraded) == ("admit", b2, True)

    spec.deadline_s = p_min * 0.9
    assert decide_admission(pol, plan, cl, spec, 0.0).action == "shed"
    # shed=False: best-effort admission at the coarsest level instead
    dec = decide_admission(SLOPolicy(shed=False), plan, cl, spec, 0.0)
    assert (dec.action, dec.bits) == ("admit", b_min)


# ---------------------------------------------------------------------------
# downgraded bits round-trip through the concrete dequant kernel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("bits", downgrade_ladder(5))
def test_downgraded_bits_roundtrip_kv_dequant(bits):
    """A KV chunk quantized at the coarser ladder width must assemble
    through the Pallas kv_dequant kernel exactly as the numpy reference
    dequantizes it (the shedding downgrade changes bits on the wire, not
    the assembly path)."""
    rng = np.random.default_rng(bits)
    x = rng.normal(size=(64, 4, 32)).astype(np.float32)
    qt = quantize(x, bits, group=64)
    assert qt.bits == bits and qt.codes.max() < (1 << bits)
    import jax.numpy as jnp
    kernel = np.asarray(dequantize_chunk(qt, out_dtype=jnp.float32))
    ref = dequantize(qt)
    np.testing.assert_allclose(kernel, ref, atol=1e-6)
    # coarser bits lose more fidelity but stay a faithful reconstruction
    rel = np.sqrt(np.mean((ref - x) ** 2)) / np.sqrt(np.mean(x ** 2))
    assert rel < 0.2


# ---------------------------------------------------------------------------
# deadline-class traffic
# ---------------------------------------------------------------------------

def test_traffic_slo_mix_draws_classes_and_deadlines():
    prof = TrafficProfile(rate_rps=1.0,
                          slo_mix=(("interactive", 4.0, 0.5),
                                   ("batch", None, 0.5)))
    specs = generate_trace(prof, 40, seed=3)
    classes = {s.slo_class for s in specs}
    assert classes == {"interactive", "batch"}
    for s in specs:
        if s.slo_class == "interactive":
            assert s.deadline_s == 4.0
        else:
            assert s.deadline_s is None


def test_traffic_without_slo_mix_has_no_deadlines():
    specs = generate_trace(TrafficProfile(rate_rps=1.0), 10, seed=3)
    assert all(s.deadline_s is None and s.slo_class == "default"
               for s in specs)
