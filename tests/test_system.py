"""System-level invariants tying the layers together."""
from repro.configs import (ASSIGNED_ARCHS, SHAPES, get_config,
                           shape_applicable)
from repro.core.predictor import LatencyPredictor
from repro.core.costs import PROFILES
from repro.data.workloads import DATASETS, synthesize


def test_assignment_matrix_covers_40_cells():
    cells = [(a, s) for a in ASSIGNED_ARCHS for s in SHAPES]
    assert len(cells) == 40
    skips = [c for c in cells
             if not shape_applicable(get_config(c[0]), SHAPES[c[1]])[0]]
    # long_500k skipped exactly for the 8 full-attention archs
    assert len(skips) == 8
    assert all(s == "long_500k" for _, s in skips)
    runnable = {a for a, s in cells
                if shape_applicable(get_config(a), SHAPES[s])[0]
                and s == "long_500k"}
    assert runnable == {"zamba2-2.7b", "mamba2-130m"}


def test_workload_heterogeneity_matches_paper():
    cfg = get_config("sparkv-qwen3-4b")
    wl = synthesize(cfg, 11_264, DATASETS["triviaqa"])
    # Fig 3: compute heterogeneity (>= 4x active-block spread at same t)
    a = wl.active_blocks[-1]
    assert a.max() / a.min() > 4
    # Fig 4: entropy spread 0-4+ bits
    assert wl.entropy_bits.min() < 0.5
    assert wl.entropy_bits.max() > 3.0
    # bytes follow entropy
    assert wl.chunk_bytes.max() / wl.chunk_bytes.min() > 4


def test_predictor_beats_roofline_baseline():
    cfg = get_config("sparkv-qwen3-4b")
    pred = LatencyPredictor(cfg, PROFILES["jetson-orin"])
    rep = pred.fit(3000, epochs=120)
    # paper Fig. 8: 4.8x-5.6x error reduction; require >= 2.5x here
    assert rep["test"]["improvement"] > 2.5
    assert rep["test"]["mlp_mape"] < 0.35


def test_videomme_denser_than_text():
    cfg = get_config("sparkv-qwen3-4b")
    wl_t = synthesize(cfg, 10_240, DATASETS["triviaqa"])
    wl_v = synthesize(cfg, 10_240, DATASETS["videomme"])
    assert wl_v.active_blocks.mean() > wl_t.active_blocks.mean()
    assert wl_v.chunk_bytes.mean() > wl_t.chunk_bytes.mean()


def test_energy_model_orders_paths():
    """NIC streaming is more energy-efficient than GPU compute (paper's
    Table I premise)."""
    from repro.core.costs import EnergyMeter
    p = PROFILES["jetson-orin"]
    stream = EnergyMeter(p, compute_busy_s=0, nic_busy_s=10, wall_s=10)
    comp = EnergyMeter(p, compute_busy_s=10, nic_busy_s=0, wall_s=10)
    assert stream.energy_j() < comp.energy_j()


def test_roofline_collective_parser():
    from repro.distributed.roofline import parse_collectives
    hlo = """
  %ar = f32[128,256]{1,0} all-reduce(f32[128,256]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = (bf16[64,64]{1,0}, bf16[256,64]{1,0}) all-gather-start(bf16[64,64]{1,0} %y), replica_groups=[4,4]<=[16], dimensions={0}
  %cp = u32[8]{0} collective-permute(u32[8]{0} %z), source_target_pairs={{0,1}}, replica_groups={{0,1}}
"""
    st = parse_collectives(hlo)
    assert st.count == 3
    ar = 2 * 128 * 256 * 4 * 3 / 4          # 2*bytes*(n-1)/n
    ag = 256 * 64 * 2 * 3 / 4               # out*(n-1)/n
    assert abs(st.by_kind["all-reduce"][1] - ar) < 1
    assert abs(st.by_kind["all-gather"][1] - ag) < 1
