"""Training substrate: checkpoint/restart bit-exactness, grad-accum
equivalence, loss improvement, int8 gradient compression, elastic restore."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.configs import TrainConfig, get_smoke
from repro.models import build_model
from repro.training.optimizer import AdamW, warmup_cosine
from repro.training.trainer import (FaultInjector, build_train_step,
                                    data_batch, train_loop)


@pytest.fixture(scope="module")
def small_model():
    cfg = get_smoke("qwen2.5-3b")
    return build_model(cfg)


def test_loss_improves(small_model, tmp_path):
    tcfg = TrainConfig(learning_rate=1e-3, total_steps=30, warmup_steps=3)
    out = train_loop(small_model, tcfg, batch=4, seq=32, steps=30,
                     log_every=1)
    first, last = out["history"][0][1], out["final_loss"]
    assert last < first


def test_fault_restart_bit_exact(small_model, tmp_path):
    tcfg = TrainConfig(total_steps=12, warmup_steps=2, checkpoint_every=4)
    cm1 = CheckpointManager(str(tmp_path / "a"))
    r1 = train_loop(small_model, tcfg, batch=2, seq=32, steps=12,
                    ckpt_manager=cm1, log_every=1)
    cm2 = CheckpointManager(str(tmp_path / "b"))
    fault = FaultInjector(fail_steps=(7,))
    with pytest.raises(RuntimeError):
        train_loop(small_model, tcfg, batch=2, seq=32, steps=12,
                   ckpt_manager=cm2, fault=fault, log_every=1)
    r2 = train_loop(small_model, tcfg, batch=2, seq=32, steps=12,
                    ckpt_manager=cm2, fault=fault, log_every=1)
    for a, b in zip(jax.tree.leaves(r1["params"]),
                    jax.tree.leaves(r2["params"])):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_grad_accum_equivalence(small_model):
    """microbatches=2 must match a single large batch (same grads)."""
    tcfg1 = TrainConfig(microbatches=1)
    tcfg2 = TrainConfig(microbatches=2)
    step1, opt1 = build_train_step(small_model, tcfg1)
    step2, opt2 = build_train_step(small_model, tcfg2)
    params = small_model.init(jax.random.PRNGKey(0))
    batch = data_batch(small_model.cfg, tcfg1, 0, 4, 32)
    p1, _, m1 = jax.jit(step1)(params, opt1.init(params), batch)
    p2, _, m2 = jax.jit(step2)(params, opt2.init(params), batch)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 5e-3
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-2)


def test_checkpoint_gc_and_atomicity(tmp_path, small_model):
    cm = CheckpointManager(str(tmp_path), keep=2)
    params = small_model.init(jax.random.PRNGKey(0))
    for step in (1, 2, 3, 4):
        cm.save({"params": params}, step, block=True)
    assert cm.all_steps() == [3, 4]
    restored, step = cm.restore_latest(like={"params": params})
    assert step == 4
    for a, b in zip(jax.tree.leaves(restored["params"]),
                    jax.tree.leaves(params)):
        assert np.array_equal(np.asarray(a, np.float32),
                              np.asarray(b, np.float32))


def test_schedule_shapes():
    sched = warmup_cosine(1e-3, warmup=10, total=100)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1e-3) < 1e-9
    assert float(sched(jnp.int32(100))) < 1e-3


def test_int8_grad_compression_accuracy():
    from repro.training.compression import (_dequant_int8, _quant_int8)
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.normal(0, 0.01, (256, 128)).astype(np.float32))
    q, scale = _quant_int8(g)
    back = _dequant_int8(q, scale)
    rel = float(jnp.abs(back - g).max() / jnp.abs(g).max())
    assert rel < 0.01  # 1/127 quantization grid


def test_optimizer_convergence_quadratic():
    """AdamW minimizes a quadratic (sanity of the from-scratch optimizer)."""
    tcfg = TrainConfig(learning_rate=0.1, warmup_steps=1, total_steps=200,
                       weight_decay=0.0, grad_clip=100.0)
    opt = AdamW(tcfg)
    params = {"w": jnp.ones((8,), jnp.float32) * 5}
    state = opt.init(params)
    target = jnp.arange(8, dtype=jnp.float32)

    @jax.jit
    def step(params, state):
        g = jax.grad(lambda p: jnp.sum((p["w"] - target) ** 2))(params)
        return opt.update(g, state, params)

    for _ in range(150):
        params, state, _ = step(params, state)
    assert float(jnp.abs(params["w"] - target).max()) < 0.3
