"""Sharding resolver properties + multi-device semantics (subprocess with
fake host devices so the main test process keeps 1 device)."""
import os
import subprocess
import sys

import pytest

from repro.configs import ASSIGNED_ARCHS, get_config

REPO = os.path.join(os.path.dirname(__file__), "..")


def _mesh_stub():
    class Mesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    return Mesh()


def test_resolver_divisibility_fallback():
    from repro.distributed.sharding import make_rules
    mesh = _mesh_stub()
    cfg = get_config("phi3-medium-14b")          # 40 heads % 16 != 0
    rules = make_rules(cfg, mesh)
    spec = rules.spec(("batch", None, "heads", None), (256, 1, 40, 128))
    assert spec[2] is None                        # heads fell back
    assert rules.table["heads"] == ()             # decided at rule build
    # sequence-parallel attention activated instead
    spec2 = rules.spec(("batch", "seq", None, None), (256, 4096, 40, 128))
    assert spec2[1] == "model"


def test_resolver_no_axis_reuse():
    from repro.distributed.sharding import make_rules
    mesh = _mesh_stub()
    cfg = get_config("qwen2.5-3b")
    rules = make_rules(cfg, mesh)
    # batch and expert_cap both want ("pod","data"): second dim must not
    # collide with axes already used
    spec = rules.spec(("batch", "expert_cap", None), (256, 512, 64))
    used = [a for part in spec if part for a in
            (part if isinstance(part, tuple) else (part,))]
    assert len(used) == len(set(used))


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_param_specs_resolve_for_all_archs(arch):
    from repro.distributed.sharding import make_rules
    from repro.models import build_model
    from repro.models.layers import pspec_tree
    mesh = _mesh_stub()
    cfg = get_config(arch)
    model = build_model(cfg)
    rules = make_rules(cfg, mesh)
    specs = pspec_tree(model.param_defs(), rules)
    import jax
    defs = model.param_defs()
    from repro.models.layers import is_def
    flat_defs = jax.tree.leaves(defs, is_leaf=is_def)
    flat_specs = jax.tree.leaves(
        specs, is_leaf=lambda x: hasattr(x, "_normalized_spec")
        or type(x).__name__ == "PartitionSpec")
    assert len(flat_defs) == len(flat_specs)
    for d, s in zip(flat_defs, flat_specs):
        # every sharded dim divides
        for dim, part in zip(d.shape, tuple(s) + (None,) * 8):
            if part is None:
                continue
            axes = part if isinstance(part, tuple) else (part,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            assert dim % size == 0, (arch, d.shape, s)


MOE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import sys; sys.path.insert(0, sys.argv[1] + "/src")
import jax, jax.numpy as jnp, numpy as np
try:
    from jax.sharding import AxisType
    MESH_KW = {"axis_types": (AxisType.Auto,) * 2}
except ImportError:
    MESH_KW = {}
from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed.sharding import make_rules, use_rules
from repro.models import layers as L

for n_exp in (8, 6):
    cfg = ModelConfig(name="t", family="moe", num_layers=2, d_model=32,
                      num_heads=4, num_kv_heads=2, d_ff=16, vocab_size=128,
                      moe=MoEConfig(num_experts=n_exp, experts_per_token=2,
                                    capacity_factor=8.0))
    p = L.init_params(L.moe_defs(cfg), jax.random.PRNGKey(0))
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 8, 32), jnp.float32)
    y_ref, _ = jax.jit(lambda p, x: L.moe_block_local(cfg, p, x))(p, x)
    mesh = jax.make_mesh((2, 4), ("data", "model"), **MESH_KW)
    rules = make_rules(cfg, mesh)
    def f(p, x):
        with use_rules(rules):
            return L.moe_block_sharded(cfg, p, x, rules)
    y_sh, _ = jax.jit(f)(p, x)
    assert float(jnp.abs(y_ref - y_sh).max()) < 1e-4, n_exp
print("MOE_SHARDED_OK")
"""


def test_moe_sharded_matches_local_subprocess():
    r = subprocess.run([sys.executable, "-c", MOE_SCRIPT, REPO],
                       capture_output=True, text=True, timeout=600)
    assert "MOE_SHARDED_OK" in r.stdout, r.stderr[-2000:]


DRYRUN_SCRIPT = r"""
import sys, os
sys.path.insert(0, sys.argv[1] + "/src")
from repro.launch.dryrun import run_cell
rec = run_cell("mamba2-130m", "decode_32k", False, sys.argv[2],
               verbose=False)
assert rec["status"] == "OK", rec
print("DRYRUN_CELL_OK", rec["compile_s"])
"""


def test_dryrun_cell_compiles_on_production_mesh(tmp_path):
    """One real 256-fake-chip lower+compile round trip."""
    r = subprocess.run([sys.executable, "-c", DRYRUN_SCRIPT, REPO,
                        str(tmp_path)],
                       capture_output=True, text=True, timeout=600)
    assert "DRYRUN_CELL_OK" in r.stdout, (r.stdout[-1000:], r.stderr[-2000:])
