"""Per-chunk adaptive quantization through the serving stack: allocation
schedules in plan_policy (bytes/chunk_bits threading), saliency-weighted
quality, cold-chunk SLO admission, per-chunk content keys, the
bit-parity guarantees of the "uniform"/"flat" schedules, and the mixed
dequant path in concrete KV assembly."""
import dataclasses

import numpy as np

from repro.compression.quantize import BITRATE_LEVELS
from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.baselines import QUALITY_OF_BITS
from repro.core.chunks import ChunkGrid
from repro.core.costs import NETWORKS, RunQueueModel
from repro.core.engine import BandwidthIntegrator
from repro.data.workloads import DATASETS, synthesize
from repro.serving.cluster import RequestSpec, ServingCluster
from repro.serving.resources import DeviceRunQueue, single_link
from repro.serving.slo import (SLOPolicy, cold_chunk_set, decide_admission,
                               predict_ttft)

CFG = get_config("sparkv-qwen3-4b")
SP = SparKVConfig(scheduler_mode="engine")
SP_FLAT = dataclasses.replace(SP, alloc_schedule="flat")
SP_ATT = dataclasses.replace(SP, alloc_schedule="attention")
NET = NETWORKS["campus-wifi"]
CTX = 4096


def _wl(ctx=CTX, seed=None):
    rng = np.random.default_rng(seed) if seed is not None else None
    return synthesize(CFG, ctx, DATASETS["longchat"],
                      chunk_tokens=SP.chunk_tokens,
                      quant_bits=SP.quant_bits, rng=rng)


def _plan(spcfg, policy="cachegen", wl=None):
    return B.plan_policy(policy, CFG, wl if wl is not None else _wl(),
                         "jetson-orin", NET, spcfg, util=0.0)


# ---------------------------------------------------------------------------
# plan_policy threading
# ---------------------------------------------------------------------------

def test_uniform_schedule_builds_no_chunk_bits():
    plan = _plan(SP)
    assert plan.chunk_bits is None


def test_flat_schedule_is_byte_identical_to_uniform():
    """"flat" arms the accounting (chunk_bits everywhere) but allocates
    the base width — wire bytes and stream costs must be bitwise equal
    to the uniform plan's."""
    wl = _wl()
    pu = _plan(SP, wl=wl)
    pf = _plan(SP_FLAT, wl=wl)
    assert pf.chunk_bits is not None
    assert all(b == pu.quality_bits for b in pf.chunk_bits.values())
    assert pu.bytes_map == pf.bytes_map
    assert np.array_equal(pu.planner.ts, pf.planner.ts)
    assert pu.quality_bits == pf.quality_bits


def test_attention_schedule_scales_bytes_per_chunk():
    wl = _wl()
    pu = _plan(SP, wl=wl)
    pa = _plan(SP_ATT, wl=wl)
    cb = pa.chunk_bits
    assert cb is not None and set(cb) == set(pa.bytes_map)
    assert set(cb.values()) <= set(BITRATE_LEVELS)
    assert len(set(cb.values())) > 1          # actually heterogeneous
    base = pu.quality_bits
    for c, v in pa.bytes_map.items():
        assert np.isclose(v, pu.bytes_map[c] * cb[c] / base, rtol=1e-12)
    # hot chunks (most attention mass) got the finer widths
    hot = max(cb, key=lambda c: pa.active_map[c])
    cold = min(cb, key=lambda c: pa.active_map[c])
    assert cb[hot] >= cb[cold]


def test_weighted_quality_legacy_when_chunk_bits_none():
    class R:
        n_streamed, n_computed, n_reused = 3, 5, 0
    q = B._mixed_quality(R(), 5)
    assert np.isclose(q, (5 + 3 * QUALITY_OF_BITS[5]) / 8)


def test_weighted_quality_favors_hot_chunks():
    """Saliency-weighted quality: a plan that keeps its hot chunks fine
    scores above the same chunks' unweighted mean."""
    grid = ChunkGrid(n_t=2, n_l=2, n_h=1)
    chunks = list(grid.chunks())
    weights = {c: (10.0 if i < 1 else 1.0) for i, c in enumerate(chunks)}
    cb = {c: (6 if weights[c] > 1 else 4) for c in chunks}

    class R:
        n_streamed, n_computed, n_reused = 4, 0, 0
        computed_set = set()
    qw = B._mixed_quality(R(), 5, chunk_bits=cb, active_map=weights)
    flat = np.mean([QUALITY_OF_BITS[b] for b in cb.values()])
    assert qw > flat
    # computed chunks are exact regardless of their allocated width
    class R2(R):
        computed_set = set(chunks)
    assert B._mixed_quality(R2(), 5, chunk_bits=cb,
                            active_map=weights) == 1.0


# ---------------------------------------------------------------------------
# cold-chunk SLO admission
# ---------------------------------------------------------------------------

def _idle_cluster(spcfg=SP, **kw):
    kw.setdefault("run_queue", RunQueueModel(1, "fifo"))
    cl = ServingCluster(CFG, spcfg, "jetson-orin", "campus-wifi",
                        max_concurrency=8, **kw)
    bw = BandwidthIntegrator(np.full(2000, NET.mean_bw), 0.01)
    cl._link_server = single_link(bw, cl.link)
    cl._run_queues = {0: DeviceRunQueue(cl.capacity,
                                        cl.run_queue.discipline)}
    return cl


def test_cold_chunk_set_orders_by_attention_mass():
    plan = _plan(SP)
    cold = cold_chunk_set(plan, 0.4)
    n = len(plan.active_map)
    assert len(cold) == int(n * 0.4)
    hottest = max(plan.active_map, key=lambda c: plan.active_map[c])
    assert hottest not in cold
    assert max(plan.active_map[c] for c in cold) <= \
        min(plan.active_map[c] for c in set(plan.active_map) - cold)
    assert cold_chunk_set(plan, 0.0) == frozenset()


def test_predict_ttft_cold_saves_less_than_whole():
    """Downgrading only the cold chunks leaves more bytes on the wire
    than the whole-request downgrade at the same rung, but fewer than no
    downgrade at all — and with cold=None the prediction is bitwise the
    legacy one."""
    cl = _idle_cluster()
    plan = _plan(SP)
    spec = RequestSpec(arrival_s=0.0, context_len=CTX, deadline_s=5.0)
    base = predict_ttft(plan, cl, spec, 0.0)
    whole = predict_ttft(plan, cl, spec, 0.0, bits=3)
    cold = predict_ttft(plan, cl, spec, 0.0, bits=3,
                        cold=cold_chunk_set(plan, 0.5))
    assert whole < cold < base
    assert predict_ttft(plan, cl, spec, 0.0, bits=3, cold=None) == whole


def test_decide_admission_downgrades_cold_chunks_only():
    """With cold_frac armed, a deadline between the cold-only and
    full-fidelity predictions admits with a cold_chunks set; the legacy
    policy (cold_frac=0) downgrades the whole request."""
    cl = _idle_cluster()
    plan = _plan(SP)
    spec0 = RequestSpec(arrival_s=0.0, context_len=CTX, deadline_s=5.0)
    base = predict_ttft(plan, cl, spec0, 0.0)
    cold = cold_chunk_set(plan, 0.5)
    cold5 = predict_ttft(plan, cl, spec0, 0.0, bits=5, cold=cold)
    cold4 = predict_ttft(plan, cl, spec0, 0.0, bits=4, cold=cold)
    assert cold4 < cold5 < base
    # finest-first walk: 5 must miss the deadline, 4 must make it
    deadline = (cold4 + cold5) / 2
    spec = RequestSpec(arrival_s=0.0, context_len=CTX, deadline_s=deadline)
    pol = SLOPolicy(cold_frac=0.5)
    dec = decide_admission(pol, plan, cl, spec, 0.0)
    assert dec.action == "admit" and dec.downgraded
    assert dec.cold_chunks == cold and dec.bits == 4
    legacy = decide_admission(SLOPolicy(), plan, cl, spec, 0.0)
    assert legacy.action == "admit" and legacy.downgraded
    assert legacy.cold_chunks is None


def test_cold_frac_zero_policy_is_default():
    assert SLOPolicy().cold_frac == 0.0


# ---------------------------------------------------------------------------
# fleet-level parity and integration
# ---------------------------------------------------------------------------

def _fleet(spcfg, slo=None, n=6, deadline_s=None, seed=5):
    wl = _wl(seed=seed)
    specs = [RequestSpec(arrival_s=0.2 * i, policy="sparkv", seed=i,
                         wl=wl, deadline_s=deadline_s) for i in range(n)]
    cl = ServingCluster(CFG, spcfg, "jetson-orin", "campus-wifi",
                        max_concurrency=3, slo=slo, seed=0)
    return cl.run(specs)


def test_flat_fleet_bit_identical_timing_to_uniform():
    """The "flat" schedule must not perturb a fleet's timing at all:
    identical wire bytes -> identical TTFT/energy traces (quality is
    re-weighted, fidelity unchanged at base width everywhere)."""
    ru = _fleet(SP)
    rf = _fleet(SP_FLAT)
    for a, b in zip(ru.records, rf.records):
        assert a.ttft_s == b.ttft_s
        assert a.bytes_streamed == b.bytes_streamed
        assert a.energy_j == b.energy_j
        assert a.n_streamed == b.n_streamed
        # fidelity is the base width everywhere in both fleets; the
        # flat arm re-weights the mix by attention mass, so quality may
        # drift slightly but stays pinned between the base-width floor
        # and exact
        assert QUALITY_OF_BITS[a.quant_bits] - 1e-12 <= b.quality <= 1.0
        assert abs(a.quality - b.quality) < 0.01


def test_attention_fleet_trades_bytes_for_weighted_quality():
    """The attention schedule's planned wire footprint shrinks (40% of
    chunks drop a rung, 30% gain one: 0.4*4/5 + 0.3*6/5 + 0.3 = 0.98 of
    uniform); the fleet still completes with quality pinned above the
    coarsest allocated rung. Streamed bytes are NOT compared — cheaper
    cold chunks legitimately shift the hybrid stream/compute split."""
    wl = _wl(seed=5)
    pu = _plan(SP, policy="sparkv", wl=wl)
    pa = _plan(SP_ATT, policy="sparkv", wl=wl)
    assert sum(pa.bytes_map.values()) < sum(pu.bytes_map.values())
    ra = _fleet(SP_ATT)
    assert ra.records
    floor = QUALITY_OF_BITS[min(pa.chunk_bits.values())]
    for r in ra.records:
        assert floor - 1e-12 <= r.quality <= 1.0


def test_cold_chunk_fleet_completes_with_higher_floor():
    """End-to-end: overloaded deadline fleet under cold-chunk admission
    completes, downgrades someone, and never reports a quality below the
    whole-request ladder floor."""
    slo = SLOPolicy(cold_frac=0.6)
    rep = _fleet(SP_FLAT, slo=slo, n=8, deadline_s=2.0)
    done = rep.records
    assert done, "everyone shed"
    floor = QUALITY_OF_BITS[BITRATE_LEVELS[-1]]
    for r in done:
        assert r.quality >= floor - 1e-9
    down = [r for r in done if r.downgraded]
    if down:
        # cold-chunk downgrade keeps the base width on hot chunks: the
        # record's quant_bits anchor never drops
        assert all(r.quant_bits == SP.quant_bits for r in down)
