"""Online predictor refresh: observe()/refresh() on serving telemetry.

The contention models the refresh trains (wait from occupancy/backlog,
link efficiency from observed bottleneck shares) replace the analytic
terms of ``slo.predict_ttft``; everything here pins the contract:

  - below ``min_samples`` nothing fits — predictions stay None and the
    analytic path is untouched;
  - refresh() on synthetic queue-wait observations reduces *held-out*
    wait (-> TTFT) prediction error vs the analytic occupancy-dilation
    term;
  - the share model recovers a known link efficiency;
  - predict_ttft flips from the analytic to the learned terms exactly
    when a refreshed predictor is on the cluster;
  - refresh-off cluster runs (predictor armed but never refreshed, or
    no predictor) are bit-identical to the PR 4 analytic behaviour.
"""
import numpy as np
import pytest

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS, PROFILES, RunQueueModel
from repro.core.engine import BandwidthIntegrator
from repro.core.predictor import LatencyPredictor, backlog_delay_s
from repro.data.workloads import DATASETS, synthesize
from repro.serving.cluster import RequestSpec, ServingCluster
from repro.serving.resources import DeviceRunQueue, single_link
from repro.serving.slo import SLOPolicy, predict_ttft
from repro.serving.traffic import TrafficProfile, generate_trace

CFG = get_config("sparkv-qwen3-4b")
SP = SparKVConfig(scheduler_mode="engine")
NET = NETWORKS["campus-wifi"]
PROF = PROFILES["jetson-orin"]


def _predictor():
    return LatencyPredictor(CFG, PROF)


def _synthetic_waits(n, rng, cap=1):
    """Queue-wait ground truth the analytic term cannot express: the
    realized wait tracks 0.8 x backlog drain + 0.5 s per queued job,
    with lognormal-ish noise."""
    load = rng.integers(0, 6, n)
    backlog = rng.uniform(0.0, 8.0, n)
    wait = 0.8 * backlog / cap + 0.5 * load + rng.normal(0.0, 0.1, n)
    return load, backlog, np.maximum(wait, 0.0)


def test_below_min_samples_keeps_analytic():
    p = _predictor()
    for _ in range(4):
        p.observe(load=2, capacity=1, backlog_s=1.0, wait_s=0.5,
                  n_flows=2, share=0.4)
    assert p.refresh() is None
    assert not p.refreshed
    assert p.predict_wait_s(2, 1, 1.0) is None
    assert p.predict_share(3) is None


def test_refresh_reduces_heldout_wait_error_vs_analytic():
    """The satellite acceptance: trained on synthetic queue-wait
    observations, the learned wait model beats the analytic
    max(occupancy dilation, backlog drain) term on held-out samples —
    the exact quantity predict_ttft adds to the compute path."""
    rng = np.random.default_rng(7)
    cap = 1
    p = _predictor()
    lo, bo, wo = _synthetic_waits(64, rng, cap)
    for args in zip(lo, bo, wo):
        p.observe(load=int(args[0]), capacity=cap, backlog_s=args[1],
                  wait_s=args[2])
    report = p.refresh()
    assert p.refreshed and report["n_wait_obs"] == 64
    lh, bh, wh = _synthetic_waits(32, rng, cap)
    t_comp = 1.0                              # planned compute seconds
    learned_err, analytic_err = [], []
    for load, backlog, wait in zip(lh, bh, wh):
        learned = p.predict_wait_s(int(load), cap, backlog)
        analytic = max(t_comp * (1.0 + load / cap),
                       t_comp + backlog_delay_s(backlog, cap)) - t_comp
        learned_err.append(abs(learned - wait))
        analytic_err.append(abs(analytic - wait))
    assert np.mean(learned_err) < 0.5 * np.mean(analytic_err)


def test_share_model_recovers_link_efficiency():
    """Observed bottleneck shares drawn from eta/n with eta = 0.72:
    refresh must recover eta and project eta/(n+1) for admission."""
    p = _predictor()
    rng = np.random.default_rng(3)
    for _ in range(32):
        n = int(rng.integers(1, 7))
        p.observe(load=0, capacity=1, backlog_s=0.0, wait_s=0.0,
                  n_flows=n, share=0.72 / n)
    p.refresh()
    assert p.predict_share(1) == pytest.approx(0.72, abs=0.02)
    assert p.predict_share(4) == pytest.approx(0.18, abs=0.01)


def test_observation_window_bounds_memory():
    p = _predictor()
    p.obs_window = 16
    for i in range(100):
        p.observe(load=1, capacity=1, backlog_s=0.0, wait_s=float(i),
                  n_flows=1, share=1.0)
    assert len(p._wait_obs) == 16 and len(p._share_obs) == 16
    assert p._wait_obs[-1][3] == 99.0         # newest kept


def _idle_cluster(**kw):
    kw.setdefault("run_queue", RunQueueModel(1, "fifo"))
    cl = ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                        max_concurrency=8, **kw)
    bw = BandwidthIntegrator(np.full(2000, NET.mean_bw), 0.01)
    cl._link_server = single_link(bw, cl.link)
    cl._run_queues = {0: DeviceRunQueue(cl.capacity,
                                        cl.run_queue.discipline)}
    return cl


def _plan(policy="cachegen", ctx=2048):
    wl = synthesize(CFG, ctx, DATASETS["longchat"],
                    chunk_tokens=SP.chunk_tokens, quant_bits=SP.quant_bits)
    return B.plan_policy(policy, CFG, wl, "jetson-orin", NET, SP, util=0.0)


def test_predict_ttft_prefers_refreshed_models():
    """Same cluster, same plan: an unrefreshed predictor leaves the
    analytic prediction untouched; after a refresh on heavy-wait /
    starved-share observations the projection moves accordingly."""
    plan = _plan("cachegen")
    spec = RequestSpec(arrival_s=0.0, context_len=2048, deadline_s=5.0)
    p = _predictor()
    cl = _idle_cluster(predictor=p)
    analytic = predict_ttft(plan, cl, spec, 0.0)
    assert analytic == predict_ttft(plan, _idle_cluster(), spec, 0.0)
    for _ in range(16):                       # starved link, long waits
        p.observe(load=0, capacity=1, backlog_s=0.0, wait_s=4.0,
                  n_flows=1, share=0.25)
    p.refresh()
    refreshed = predict_ttft(plan, cl, spec, 0.0)
    assert refreshed > analytic               # both terms got worse
    # compute-path term: learned constant wait of ~4 s
    lp_plan = _plan("local_prefill")
    assert predict_ttft(lp_plan, cl, spec, 0.0) == pytest.approx(
        predict_ttft(lp_plan, _idle_cluster(), spec, 0.0) + 4.0, rel=0.1)


def test_cluster_feeds_observations_and_refreshes():
    prof = TrafficProfile(rate_rps=1.5, arrival="uniform",
                          policy_mix=(("sparkv", 1.0),),
                          max_context=4096)
    specs = generate_trace(prof, 6, seed=5)
    p = _predictor()
    ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                   run_queue=RunQueueModel(1, "fifo"), predictor=p,
                   refresh_every=0, max_concurrency=8).run(specs)
    assert len(p._wait_obs) == 6              # one per finalized request
    assert p._share_obs                       # streamed flows observed
    assert not p.refreshed                    # refresh_every=0: never
    p2 = _predictor()
    p2.obs_window = 1024
    ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                   run_queue=RunQueueModel(1, "fifo"), predictor=p2,
                   refresh_every=3, max_concurrency=8).run(specs)
    # 6 finalizes at cadence 3 -> refreshed mid-run (min_samples not yet
    # reached at the first tick, reached by the second on share obs)
    assert len(p2._wait_obs) == 6


def test_refresh_off_runs_bit_identical():
    """PR 4 parity: predictor armed but never refreshed changes nothing
    — records match a predictor-free run exactly, SLO or not."""
    specs = [RequestSpec(arrival_s=0.3 * i, context_len=4096,
                         policy="sparkv", seed=i, deadline_s=12.0)
             for i in range(4)]
    base = ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                          run_queue=RunQueueModel(1, "fifo"),
                          slo=SLOPolicy(), max_concurrency=8).run(specs)
    armed = ServingCluster(CFG, SP, "jetson-orin", "campus-wifi",
                           run_queue=RunQueueModel(1, "fifo"),
                           slo=SLOPolicy(), predictor=_predictor(),
                           refresh_every=0, max_concurrency=8).run(specs)
    assert base.summary() == armed.summary()
    assert [r.ttft_s for r in base.records] \
        == [r.ttft_s for r in armed.records]
    assert [r.energy_j for r in base.records] \
        == [r.energy_j for r in armed.records]
