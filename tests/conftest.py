import os
import sys

# tests run on the single real CPU device; the 512-device dry-run sets its
# own XLA_FLAGS in its subprocess (never globally — see system docs)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
