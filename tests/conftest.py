import os
import sys

# tests run on the single real CPU device; the 512-device dry-run sets its
# own XLA_FLAGS in its subprocess (never globally — see system docs)
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# hypothesis suites must run everywhere: CI installs the real package;
# containers without it fall back to the deterministic stub under
# tests/_vendor (same API slice, fixed seeds, boundary examples first)
try:
    import hypothesis  # noqa: F401
except ImportError:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_vendor"))

import numpy as np  # noqa: E402
import pytest  # noqa: E402


@pytest.fixture
def rng():
    return np.random.default_rng(0)
