"""Example: a fleet of concurrent context loads on one shared link.

Generates a bursty arrival trace with a mixed policy population, runs it
through the multi-request serving cluster, and prints per-request and
fleet metrics. Three device-contention models over the same trace:

  - closed-loop: in-flight compute dilates everyone's service time;
  - static util=0: contention coupling off (what single-request modeling
    hides);
  - WFQ run queue: compute *waits* in an explicit weighted-fair device
    queue instead of dilating — queue-wait shows up in the breakdown;
  - SRPT + SLO: deadline-aware admission (predicted TTFT violations are
    downgraded to coarser quant bits or shed) on the preemptive
    shortest-remaining-first queue — attainment and shed counts appear
    in the summary.

  PYTHONPATH=src python examples/serve_fleet.py
"""
from repro.configs import SparKVConfig, get_config
from repro.core.costs import RunQueueModel
from repro.serving.cluster import ServingCluster
from repro.serving.slo import SLOPolicy
from repro.serving.traffic import TrafficProfile, generate_trace

cfg = get_config("sparkv-qwen3-4b")
spcfg = SparKVConfig(scheduler_mode="engine")

profile = TrafficProfile(
    rate_rps=0.8, arrival="bursty", burst_factor=6.0,
    context_mix=(("longchat", 0.6), ("triviaqa", 0.4)),
    policy_mix=(("sparkv", 0.6), ("strong_hybrid", 0.25),
                ("local_prefill", 0.15)),
    max_context=8192,
    # 60% of requests are interactive with an 8 s TTFT SLO; the rest are
    # best-effort batch (deadlines only bind in the SLO-armed mode below)
    slo_mix=(("interactive", 8.0, 0.6), ("batch", None, 0.4)))
specs = generate_trace(profile, 10, seed=42)
print(f"trace: {len(specs)} requests over "
      f"{specs[-1].arrival_s:.1f}s (bursty), contexts "
      f"{min(s.context_len for s in specs)}-"
      f"{max(s.context_len for s in specs)} tokens")

for mode, kw in [("closed-loop", dict(closed_loop=True)),
                 ("static u=0 ", dict(closed_loop=False, static_util=0.0)),
                 ("wfq queue  ", dict(run_queue=RunQueueModel(2, "wfq"))),
                 ("srpt + slo ", dict(run_queue=RunQueueModel(2, "srpt"),
                                      slo=SLOPolicy()))]:
    cluster = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                             max_concurrency=4, **kw)
    rep = cluster.run(specs)
    s = rep.summary()
    print(f"\n[{mode}] p50 TTFT {s['ttft_p50_s']:.2f}s  "
          f"p99 {s['ttft_p99_s']:.2f}s  goodput {s['goodput_rps']:.2f} "
          f"req/s  {s['energy_per_req_j']:.0f} J/req  "
          f"{s['migrations_total']} migrations  "
          f"queue-wait p99 {s['queue_wait_p99_s']:.2f}s")
    if kw.get("slo") is not None and s["slo_attainment"] is not None:
        print(f"             SLO attainment {s['slo_attainment']:.0%}  "
              f"shed {s['n_shed']}  downgraded {s['n_downgraded']}  "
              f"goodput-under-SLO {s['goodput_slo_rps']:.2f} req/s")
    if mode == "closed-loop":
        print(f"{'rid':>3} {'policy':15s} {'arr':>6} {'queue':>6} "
              f"{'ttft':>7} {'str/cmp':>8} {'migr':>4}")
        for r in rep.records:
            print(f"{r.rid:>3} {r.policy:15s} {r.spec.arrival_s:6.2f} "
                  f"{r.queue_s:6.2f} {r.ttft_s:6.2f}s "
                  f"{r.n_streamed:>4}/{r.n_computed:<3} "
                  f"{r.n_migrations:>4}")
