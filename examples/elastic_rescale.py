"""Example: elastic scaling — train on a (1, N) device mesh, checkpoint,
then restore the same state onto a differently-shaped mesh and continue.
On the production pods this is the 256-chip -> 512-chip rescale path
(checkpoints are mesh-agnostic; shardings are reapplied on restore).

Run with several fake host devices to make the resharding real:
  XLA_FLAGS=--xla_force_host_platform_device_count=8 \
  PYTHONPATH=src python examples/elastic_rescale.py
"""
import shutil

import jax

from repro.configs import TrainConfig, get_smoke
from repro.checkpoint.manager import CheckpointManager
from repro.distributed.sharding import make_rules
from repro.launch.mesh import make_local_mesh
from repro.models import build_model
from repro.models.layers import sharding_tree
from repro.training.trainer import train_loop
from repro.training.optimizer import AdamW

n_dev = len(jax.devices())
print(f"{n_dev} devices visible")

cfg = get_smoke("qwen2.5-3b", d_model=64, heads=4, d_ff=128)
model = build_model(cfg)
tcfg = TrainConfig(total_steps=40, warmup_steps=4, checkpoint_every=10)
ckpt_dir = "/tmp/repro_elastic_example"
shutil.rmtree(ckpt_dir, ignore_errors=True)
cm = CheckpointManager(ckpt_dir)

# phase 1: train 20 steps on a (1, n) mesh
out1 = train_loop(model, tcfg, batch=4, seq=64, steps=20,
                  ckpt_manager=cm, log_every=5)
print("phase 1 final loss:", out1["final_loss"])

# phase 2: 'rescale' — restore the same checkpoint onto a (n, 1) mesh
if n_dev > 1:
    mesh2 = make_local_mesh((n_dev, 1))
else:
    mesh2 = make_local_mesh((1, 1))
rules2 = make_rules(cfg, mesh2)
shardings = sharding_tree(model.param_defs(), rules2)
opt = AdamW(tcfg, cfg.moment_dtype)
params0 = model.init(jax.random.PRNGKey(0))
like = {"params": params0, "opt": opt.init(params0)}
state, step = cm.restore_latest(like=like)
params = jax.device_put(state["params"], shardings)
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.training.optimizer import AdamWState
opt_state = jax.device_put(
    state["opt"],
    AdamWState(m=shardings, v=shardings,
               count=NamedSharding(mesh2, P())))
print(f"restored step {step} onto mesh {dict(mesh2.shape)}; "
      f"params resharded for {n_dev} devices")

# phase 3: continue training from the restored state
out2 = train_loop(model, tcfg, batch=4, seq=64, steps=40,
                  ckpt_manager=cm, log_every=5)
print("phase 3 final loss:", out2["final_loss"])
assert out2["final_loss"] < out1["final_loss"]
print("elastic rescale OK: loss continued to improve after resharding")
