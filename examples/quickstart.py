"""Quickstart: SparKV in ~60 lines.

Builds a small LM, registers a reusable context in the 'cloud' tier,
and serves a request with SparKV hybrid loading — comparing TTFT, energy
and response fidelity against compute-only and stream-only loading.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.configs import SparKVConfig, get_smoke
from repro.models import build_model
from repro.serving.engine import SparKVServer

# 1. a small decoder-only LM (same family as the paper's Qwen3-4B)
cfg = get_smoke("sparkv-qwen3-4b", layers=4, d_model=64, heads=4,
                d_ff=128, vocab=512)
model = build_model(cfg)
params = model.init(jax.random.PRNGKey(0))

# 2. SparKV configuration: 1024-token chunks in production; small here
spcfg = SparKVConfig(chunk_tokens=64, q_block=32, kv_block=32,
                     quant_group=32)
server = SparKVServer(model, params, spcfg, profile="jetson-orin",
                      network="campus-wifi", chunk_tokens=64)

# 3. register a reusable context (cloud precomputes + compresses KV)
rng = np.random.default_rng(0)
context = rng.integers(0, cfg.vocab_size, size=(1, 512))
cid = server.register_context(context)
stored = server.contexts[cid]
print(f"context: {context.shape[1]} tokens -> {stored.n_chunks} KV chunks, "
      f"{stored.wl.total_bytes() / 1e6:.2f} MB compressed")

# 4. serve one request under each loading policy
prompt = rng.integers(0, cfg.vocab_size, size=4)
for policy in ("sparkv", "local_prefill", "cachegen"):
    r = server.generate(cid, prompt, max_new=8, policy=policy)
    print(f"{policy:14s} TTFT={r.ttft_s:6.3f}s energy={r.energy_j:7.1f}J "
          f"fidelity={r.top1_agreement:.2f} "
          f"(streamed {r.n_streamed} / computed {r.n_computed} chunks)")
