"""Example: end-to-end training driver — train a ~100M-param dense LM for
a few hundred steps with checkpointing and a mid-run injected failure
(supervisor restarts from the last commit; loss curve is continuous).

  PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import dataclasses
import shutil

from repro.configs import TrainConfig, get_config
from repro.checkpoint.manager import CheckpointManager
from repro.models import build_model
from repro.training.trainer import FaultInjector, train_loop

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--batch", type=int, default=4)
ap.add_argument("--seq", type=int, default=128)
ap.add_argument("--fail-at", type=int, default=None,
                help="inject a node failure at this step")
args = ap.parse_args()

# ~100M params: 16L x 640d x 10H, 16k vocab (qwen2.5 family, shrunk)
base = get_config("qwen2.5-3b")
cfg = dataclasses.replace(
    base, name="qwen2.5-100m", num_layers=16, d_model=640, num_heads=10,
    num_kv_heads=2, head_dim=64, d_ff=2560, vocab_size=16384, remat="none")
model = build_model(cfg)
print(f"model: {cfg.name}, {cfg.param_count() / 1e6:.0f}M params")

tcfg = TrainConfig(learning_rate=2e-3, total_steps=args.steps,
                   warmup_steps=20, checkpoint_every=50, seed=0)
ckpt_dir = "/tmp/repro_train_lm_example"
shutil.rmtree(ckpt_dir, ignore_errors=True)
cm = CheckpointManager(ckpt_dir)
fault = FaultInjector((args.fail_at,)) if args.fail_at else None

restarts = 0
while True:
    try:
        out = train_loop(model, tcfg, batch=args.batch, seq=args.seq,
                         steps=args.steps, ckpt_manager=cm, fault=fault,
                         log_every=10)
        break
    except RuntimeError as e:
        restarts += 1
        print(f"[supervisor] {e}; restarting from last checkpoint "
              f"(restart {restarts})")
        if restarts > 3:
            raise

print(f"\n{args.steps} steps, {restarts} restarts, "
      f"{out['wall_s']:.0f}s wall")
for step, loss in out["history"]:
    print(f"  step {step:4d}  loss {loss:.4f}")
first, last = out["history"][0][1], out["final_loss"]
assert last < first, "loss did not improve"
print(f"loss {first:.3f} -> {last:.3f}  [improved]")
