"""Example: SparKV's runtime controller under bandwidth volatility.

Loads the same reusable context across increasingly congested wireless
conditions (the paper's Fig. 13 scenario) and shows how the adaptive
controller migrates chunks from the starved streaming path to local
compute, holding TTFT roughly flat while static schedules degrade.

  PYTHONPATH=src python examples/serve_under_volatility.py
"""

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

cfg = get_config("sparkv-qwen3-4b")
spcfg = SparKVConfig()
wl = synthesize(cfg, 12_000, DATASETS["longchat"])

print(f"workload: {wl.context_len} tokens, {wl.n_t}x{wl.n_l}x{wl.n_h} "
      f"chunks, {wl.total_bytes() / 1e6:.0f} MB compressed")
print(f"{'network':18s} {'sparkv':>10s} {'sparkv(-adapt)':>14s} "
      f"{'strong_hybrid':>14s} {'cachegen':>10s}")

for net_name in ("campus-wifi", "congested-2dev", "congested-5dev"):
    net = NETWORKS[net_name]
    row = []
    r = B.run_sparkv(cfg, wl, "jetson-orin", net, spcfg, seed=1)
    row.append(f"{r.ttft_s:9.2f}s")
    r_na = B.run_sparkv(cfg, wl, "jetson-orin", net, spcfg, seed=1,
                        adapt=False)
    row.append(f"{r_na.ttft_s:13.2f}s")
    r_sh = B.run_strong_hybrid(cfg, wl, "jetson-orin", net, spcfg, seed=1)
    row.append(f"{r_sh.ttft_s:13.2f}s")
    r_cg = B.run_cachegen(cfg, wl, "jetson-orin", net, spcfg, seed=1)
    row.append(f"{r_cg.ttft_s:9.2f}s")
    print(f"{net_name:18s} {' '.join(row)}  "
          f"(migrations: {r.extras.get('migrations', 0)})")
