"""Fig. 8: MLP latency predictor vs analytical roofline baseline —
prediction error and per-chunk inference overhead (paper: 4.8-5.6x error
reduction at comparable overhead)."""
from __future__ import annotations

import time

import numpy as np

from repro.configs import get_config
from repro.core.costs import PROFILES
from repro.core.predictor import LatencyPredictor

from benchmarks.common import save, table


def run(quick: bool = False):
    rows = []
    for profile in (["jetson-orin"] if quick
                    else ["jetson-orin", "jetson-agx"]):
        cfg = get_config("sparkv-qwen3-4b")
        pred = LatencyPredictor(cfg, PROFILES[profile])
        t0 = time.time()
        rep = pred.fit(6000, epochs=150 if quick else 400)
        fit_s = time.time() - t0
        # per-chunk inference overhead
        x = np.array([[3, 200, 0.2]], np.float32)
        t0 = time.time()
        for _ in range(100):
            pred.predict_ms(x)
        infer_ms = (time.time() - t0) / 100 * 1e3
        rows.append({
            "profile": profile,
            "train_s": fit_s,
            "infer_overhead_ms": infer_ms,
            "mlp_mae_ms": rep["test"]["mlp_mae_ms"],
            "roofline_mae_ms": rep["test"]["roofline_mae_ms"],
            "mlp_mape": rep["test"]["mlp_mape"],
            "roofline_mape": rep["test"]["roofline_mape"],
            "error_reduction_x": rep["test"]["improvement"],
        })
    print(table(rows, list(rows[0].keys()),
                title="\n[Fig 8] latency predictor vs roofline baseline"))
    save("fig8_predictor", {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    run()
