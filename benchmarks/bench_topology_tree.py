"""Three-hop cloud-egress topology: what a deeper tree does to the
stream/compute decision, and what the online predictor refresh buys.

Three studies on the tree link topology (``resources.tree_topology``:
per-device NICs -> per-AP uplinks -> one cloud-egress stage shared by
*all* APs):

  - **egress-starvation** — the same telemetry-driven fleet
    (``telemetry_policy`` picks sparkv vs. local_prefill per admission)
    on the two-stage NIC->uplink tree, a three-hop tree with a
    generously provisioned egress, and a three-hop tree whose egress is
    starved. The CacheGen-style hybrid observation ("Compute Or Load KV
    Cache? Why Not Both?"): an upstream bottleneck shared by all APs
    flips the load/compute decision — the policy mix must shift toward
    local compute as the egress starves.
  - **nic-asymmetry** — symmetric NIC fleets vs. a fast/slow NIC split
    (``nic=[...]`` per device) at round-robin routing, and the same
    asymmetric fleet with traffic skewed toward the fast-NIC devices
    (``TrafficProfile.device_mix``).
  - **predictor-refresh** — SLO admission under bursty overload on the
    starved-egress tree, analytic contention terms
    (``slo.predict_ttft``'s occupancy-dilation fallback) vs. the online
    refresh (``ServingCluster(predictor=..., refresh_every=...)``,
    warmed on one prior epoch of the same traffic under analytic
    admission): the learned wait/share models should admit more
    accurately — higher attainment over served deadline requests
    and/or more in-contract goodput in at least one overload scenario.
"""
from __future__ import annotations

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core.costs import PROFILES, NetworkProfile, RunQueueModel
from repro.core.predictor import LatencyPredictor
from repro.serving.cluster import ServingCluster, telemetry_policy
from repro.serving.slo import SLOPolicy
from repro.serving.traffic import TrafficProfile, generate_trace

from benchmarks.common import save, table

# a cloud trunk that cannot carry the fleet: well below the aggregate
# NIC/uplink capacity (even a lone flow's projected egress share sits
# under telemetry_policy's 0.4 floor), so the shared third hop is the
# bottleneck the two-stage model cannot see
STARVED_EGRESS = NetworkProfile("egress-starved", 280e6 / 8, 60e6 / 8,
                                corr_tau_s=0.5)
FAST_NIC = NetworkProfile("nic-fast", 900e6 / 8, 70e6 / 8, corr_tau_s=1.5)
SLOW_NIC = NetworkProfile("nic-slow", 280e6 / 8, 40e6 / 8, corr_tau_s=1.5)


def _mean(vals):
    return float(np.mean(vals)) if vals else None


def _egress_starvation_rows(cfg, spcfg, n_req: int) -> list[dict]:
    """Policy-mix shift: two-stage vs three-hop under a starved egress,
    telemetry-driven policy selection on identical traffic."""
    n_dev = 6
    prof = TrafficProfile(rate_rps=1.5, arrival="poisson",
                          policy_mix=(("sparkv", 1.0),),
                          max_context=8192, n_devices=n_dev)
    specs = generate_trace(prof, n_req, seed=23)
    configs = [
        ("two-stage", dict()),
        ("three-hop", dict(n_aps=2, egress="cloud-egress")),
        ("three-hop-starved", dict(n_aps=2, egress=STARVED_EGRESS)),
    ]
    rows = []
    for label, kw in configs:
        rep = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                             n_devices=n_dev, nic="device-nic",
                             run_queue=RunQueueModel(2, "fifo"),
                             policy_fn=telemetry_policy,
                             max_concurrency=n_dev, **kw).run(specs)
        s = rep.summary()
        pols = [r.policy for r in rep.records]
        egress = [r.stage_shares.get("egress") for r in rep.records
                  if "egress" in r.stage_shares]
        rows.append({
            "config": label,
            "n_sparkv": pols.count("sparkv"),
            "n_local_prefill": pols.count("local_prefill"),
            "ttft_mean_s": s["ttft_mean_s"],
            "ttft_p99_s": s["ttft_p99_s"],
            "bytes_streamed_MB": sum(r.bytes_streamed
                                     for r in rep.records) / 1e6,
            "uplink_share_p50": s["uplink_share_p50"],
            "egress_share_mean": _mean(egress),
        })
    return rows


def _nic_asymmetry_rows(cfg, spcfg, n_req: int) -> list[dict]:
    """Fast/slow NIC split vs the symmetric fleet, round-robin and
    skewed (device_mix) routing on the same three-hop tree."""
    n_dev = 4
    asym = [FAST_NIC, SLOW_NIC, FAST_NIC, SLOW_NIC]
    base = dict(rate_rps=1.2, arrival="poisson",
                policy_mix=(("cachegen", 1.0),),
                max_context=8192, n_devices=n_dev)
    rr = generate_trace(TrafficProfile(**base), n_req, seed=29)
    skewed = generate_trace(
        TrafficProfile(**base, device_mix=((0, 3.0), (1, 1.0),
                                           (2, 3.0), (3, 1.0))),
        n_req, seed=29)
    configs = [
        ("symmetric", "device-nic", rr),
        ("asymmetric", asym, rr),
        ("asymmetric+skewed", asym, skewed),
    ]
    rows = []
    for label, nic, specs in configs:
        rep = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                             n_devices=n_dev, nic=nic, n_aps=2,
                             egress="cloud-egress",
                             run_queue=RunQueueModel(2, "fifo"),
                             max_concurrency=n_dev).run(specs)
        s = rep.summary()
        fast = [r.ttft_s for r in rep.records if r.spec.device in (0, 2)]
        slow = [r.ttft_s for r in rep.records if r.spec.device in (1, 3)]
        rows.append({
            "config": label,
            "ttft_mean_s": s["ttft_mean_s"],
            "ttft_p99_s": s["ttft_p99_s"],
            "fast_nic_ttft_s": _mean(fast),
            "slow_nic_ttft_s": _mean(slow),
            "n_fast": len(fast), "n_slow": len(slow),
            "goodput_rps": s["goodput_rps"],
        })
    return rows


def _predictor_refresh_rows(cfg, spcfg, n_req: int) -> list[dict]:
    """SLO admission under bursty compute overload on the starved-egress
    tree: the analytic contention projection vs the online-refreshed
    predictor, epoch style. Both configurations serve identical eval
    specs; the refreshed one first serves a warmup epoch under the
    *analytic* admission with ``predictor.observe`` recording realized
    queue waits and per-stage link shares, then ``refresh()`` fits the
    learned wait/share models and keeps refining online
    (``refresh_every``) through the eval epoch. Bursts are exactly what
    the analytic snapshot terms mispredict: admission at burst onset
    sees an empty queue, while the burst's later arrivals compound every
    in-flight request's waits — a history-trained intercept sees it
    coming."""
    n_dev = 2
    prof = TrafficProfile(rate_rps=1.0, arrival="bursty",
                          burst_factor=7.0, mean_on_s=5.0,
                          mean_off_s=10.0,
                          policy_mix=(("sparkv", 0.5),
                                      ("local_prefill", 0.5)),
                          max_context=8192, n_devices=n_dev,
                          slo_mix=(("interactive", 8.0, 0.7),
                                   ("batch", None, 0.3)))
    eval_specs = generate_trace(prof, n_req, seed=31)
    warm_specs = generate_trace(prof, max(n_req - 6, 6), seed=5)

    def serve(predictor, refresh_every):
        return ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                              n_devices=n_dev, nic="device-nic", n_aps=2,
                              egress=STARVED_EGRESS,
                              run_queue=RunQueueModel(1, "fifo"),
                              slo=SLOPolicy(), predictor=predictor,
                              refresh_every=refresh_every,
                              max_concurrency=8)

    pred = LatencyPredictor(cfg, PROFILES["jetson-orin"])
    serve(pred, 0).run(warm_specs)            # warmup epoch: observe only
    fit = pred.refresh()
    rows = []
    for label, predictor, refresh_every in (("analytic", None, 0),
                                            ("refreshed", pred, 4)):
        rep = serve(predictor, refresh_every).run(eval_specs)
        s = rep.summary()
        ints = [r.ttft_s for r in rep.records if r.deadline_s is not None]
        rows.append({
            "config": label,
            "slo_attainment": s["slo_attainment"],
            "attainment_arrived": s["slo_attainment_arrived"],
            "n_served": s["n_done"],
            "n_shed": s["n_shed"],
            "n_downgraded": s["n_downgraded"],
            "goodput_slo_rps": s["goodput_slo_rps"],
            "interactive_p99_s": (float(np.percentile(ints, 99))
                                  if ints else None),
            "wait_model_mae_s": (fit or {}).get("wait_mae_s")
            if label == "refreshed" else None,
        })
    return rows


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig(scheduler_mode="engine")
    n_req = 8 if quick else 18
    out = {}
    out["egress_starvation"] = _egress_starvation_rows(cfg, spcfg, n_req)
    print(table(out["egress_starvation"],
                list(out["egress_starvation"][0].keys()),
                title="\n[topology] telemetry-policy fleet, two-stage vs "
                      "three-hop (starved cloud egress)"))
    out["nic_asymmetry"] = _nic_asymmetry_rows(cfg, spcfg, n_req)
    print(table(out["nic_asymmetry"], list(out["nic_asymmetry"][0].keys()),
                title="\n[topology] symmetric vs asymmetric NIC fleets "
                      "(three-hop tree)"))
    out["predictor_refresh"] = _predictor_refresh_rows(
        cfg, spcfg, 10 if quick else 26)
    print(table(out["predictor_refresh"],
                list(out["predictor_refresh"][0].keys()),
                title="\n[topology] SLO admission on the starved-egress "
                      "tree: analytic vs refreshed predictor"))

    two, _, starved = out["egress_starvation"]
    mix_shifted = (starved["n_local_prefill"] > two["n_local_prefill"])
    ana, ref = out["predictor_refresh"]

    def score(r):
        return (r["slo_attainment"] or 0.0, r["goodput_slo_rps"])

    refresh_wins = score(ref) >= score(ana)
    print(f"\npolicy mix shift (starved egress -> local compute): "
          f"{two['n_local_prefill']} -> {starved['n_local_prefill']} "
          f"local_prefill"
          + ("  [acceptance met]" if mix_shifted else ""))
    att = {r["config"]: r["slo_attainment"] for r in
           out["predictor_refresh"]}
    print(f"refresh attainment: analytic {att['analytic']} -> "
          f"refreshed {att['refreshed']}"
          + ("  [acceptance met]" if refresh_wins else ""))
    save("topology_tree", {**out,
                           "acceptance": {"mix_shifted": mix_shifted,
                                          "refresh_wins": refresh_wins}},
         quick=quick)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
