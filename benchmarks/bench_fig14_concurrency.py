"""Fig. 14: concurrent requests — TTFT and energy/request as edge compute
is shared (device utilization rises); SparKV sheds compute-path work to
streaming when the device is contended."""
from __future__ import annotations

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig()
    wl = synthesize(cfg, 12_288, DATASETS["longchat"])
    net = NETWORKS["campus-wifi"]
    rows = []
    levels = [0.0, 0.3, 0.6, 0.8]
    for util in levels[:2] if quick else levels:
        agg = {}
        for pol in ["sparkv", "strong_hybrid", "local_prefill"]:
            r = B.PIPELINES[pol](cfg, wl, "jetson-orin", net, spcfg,
                                 util=util, seed=0)
            agg[pol] = r
        rows.append({
            "concurrency_util": util,
            "sparkv_ttft": agg["sparkv"].ttft_s,
            "hybrid_ttft": agg["strong_hybrid"].ttft_s,
            "local_ttft": agg["local_prefill"].ttft_s,
            "sparkv_J": agg["sparkv"].energy_j,
            "hybrid_J": agg["strong_hybrid"].energy_j,
            "local_J": agg["local_prefill"].energy_j,
            "vs_hybrid_x": agg["strong_hybrid"].ttft_s
            / agg["sparkv"].ttft_s,
            "vs_local_x": agg["local_prefill"].ttft_s
            / agg["sparkv"].ttft_s,
        })
    print(table(rows, list(rows[0].keys()),
                title="\n[Fig 14] concurrent-request contention"))
    save("fig14_concurrency", {"rows": rows})
    return rows


if __name__ == "__main__":
    run()
