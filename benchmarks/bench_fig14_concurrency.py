"""Fig. 14: concurrent requests — TTFT and energy/request as edge compute
is shared (device utilization rises); SparKV sheds compute-path work to
streaming when the device is contended.

Two utilization sources:

  - static (default, paper-figure parity): each level is a hand-set
    `util` scalar fed to an isolated single-request engine;
  - closed-loop (`closed_loop=True`): each level is N actually-concurrent
    requests in the serving cluster — utilization emerges from in-flight
    compute chunks and the shared link, not from a dial.
"""
from __future__ import annotations

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table

POLICIES = ["sparkv", "strong_hybrid", "local_prefill"]


def _row(label, ttft, energy):
    return {
        "concurrency": label,
        "sparkv_ttft": ttft["sparkv"],
        "hybrid_ttft": ttft["strong_hybrid"],
        "local_ttft": ttft["local_prefill"],
        "sparkv_J": energy["sparkv"],
        "hybrid_J": energy["strong_hybrid"],
        "local_J": energy["local_prefill"],
        "vs_hybrid_x": ttft["strong_hybrid"] / ttft["sparkv"],
        "vs_local_x": ttft["local_prefill"] / ttft["sparkv"],
    }


def _static_rows(cfg, spcfg, wl, net, levels):
    rows = []
    for util in levels:
        ttft, energy = {}, {}
        for pol in POLICIES:
            r = B.PIPELINES[pol](cfg, wl, "jetson-orin", net, spcfg,
                                 util=util, seed=0)
            ttft[pol], energy[pol] = r.ttft_s, r.energy_j
        rows.append(_row(util, ttft, energy))
    return rows


def _closed_loop_rows(cfg, context_len, levels_n):
    """Utilization from N genuinely-concurrent requests in the cluster."""
    from repro.serving.cluster import RequestSpec, ServingCluster
    spcfg = SparKVConfig(scheduler_mode="engine")
    rows = []
    for n in levels_n:
        ttft, energy = {}, {}
        for pol in POLICIES:
            specs = [RequestSpec(arrival_s=0.0, context_len=context_len,
                                 policy=pol, seed=i) for i in range(n)]
            rep = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                                 max_concurrency=n, closed_loop=True
                                 ).run(specs)
            s = rep.summary()
            ttft[pol] = s["ttft_mean_s"]
            energy[pol] = s["energy_per_req_j"]
        rows.append(_row(f"N={n}", ttft, energy))
    return rows


def run(quick: bool = False, closed_loop: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    net = NETWORKS["campus-wifi"]
    rows = []
    if closed_loop:
        levels_n = [1, 2] if quick else [1, 2, 4, 8]
        rows = _closed_loop_rows(cfg, 4096 if quick else 8192, levels_n)
        title = "\n[Fig 14] concurrent-request contention (closed-loop N)"
    else:
        spcfg = SparKVConfig()
        wl = synthesize(cfg, 12_288, DATASETS["longchat"])
        levels = [0.0, 0.3, 0.6, 0.8]
        rows = _static_rows(cfg, spcfg, wl, net,
                            levels[:2] if quick else levels)
        title = "\n[Fig 14] concurrent-request contention"
    print(table(rows, list(rows[0].keys()), title=title))
    save("fig14_concurrency" + ("_closed_loop" if closed_loop else ""),
         {"rows": rows})
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--closed-loop", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick, closed_loop=a.closed_loop)
