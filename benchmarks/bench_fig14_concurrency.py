"""Fig. 14: concurrent requests — TTFT and energy/request as edge compute
is shared (device utilization rises); SparKV sheds compute-path work to
streaming when the device is contended.

Two utilization sources:

  - static (default, paper-figure parity): each level is a hand-set
    `util` scalar fed to an isolated single-request engine;
  - closed-loop (`closed_loop=True`): each level is N actually-concurrent
    requests in the serving cluster — utilization emerges from in-flight
    compute chunks and the shared link, not from a dial.

The closed-loop mode additionally compares device scheduling disciplines
on the explicit run queue (FIFO vs. WFQ with a weighted interactive class
plus a background bulk load): per-request queue-wait breakdowns and the
p99 TTFT divergence between disciplines under contention.
"""
from __future__ import annotations

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table

POLICIES = ["sparkv", "strong_hybrid", "local_prefill"]


def _row(label, ttft, energy):
    return {
        "concurrency": label,
        "sparkv_ttft": ttft["sparkv"],
        "hybrid_ttft": ttft["strong_hybrid"],
        "local_ttft": ttft["local_prefill"],
        "sparkv_J": energy["sparkv"],
        "hybrid_J": energy["strong_hybrid"],
        "local_J": energy["local_prefill"],
        "vs_hybrid_x": ttft["strong_hybrid"] / ttft["sparkv"],
        "vs_local_x": ttft["local_prefill"] / ttft["sparkv"],
    }


def _static_rows(cfg, spcfg, wl, net, levels):
    rows = []
    for util in levels:
        ttft, energy = {}, {}
        for pol in POLICIES:
            r = B.PIPELINES[pol](cfg, wl, "jetson-orin", net, spcfg,
                                 util=util, seed=0)
            ttft[pol], energy[pol] = r.ttft_s, r.energy_j
        rows.append(_row(util, ttft, energy))
    return rows


def _closed_loop_rows(cfg, context_len, levels_n):
    """Utilization from N genuinely-concurrent requests in the cluster."""
    from repro.serving.cluster import RequestSpec, ServingCluster
    spcfg = SparKVConfig(scheduler_mode="engine")
    rows = []
    for n in levels_n:
        ttft, energy = {}, {}
        for pol in POLICIES:
            specs = [RequestSpec(arrival_s=0.0, context_len=context_len,
                                 policy=pol, seed=i) for i in range(n)]
            rep = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                                 max_concurrency=n, closed_loop=True
                                 ).run(specs)
            s = rep.summary()
            ttft[pol] = s["ttft_mean_s"]
            energy[pol] = s["energy_per_req_j"]
        rows.append(_row(f"N={n}", ttft, energy))
    return rows


def _discipline_rows(cfg, context_len, n_interactive):
    """FIFO vs. WFQ vs. SRPT on the explicit capacity-1 run queue: one
    background bulk load (weight 1, no deadline) + n weighted interactive
    requests (weight 8, TTFT deadline so SRPT's anti-starvation floor is
    armed), all sparkv so queue telemetry also drives migrations. Same
    offered load for every discipline — the interactive-class p99 TTFT
    column is the comparison the SLO layer exists for."""
    from repro.core.costs import RunQueueModel
    from repro.serving.cluster import RequestSpec, ServingCluster
    spcfg = SparKVConfig(scheduler_mode="engine")
    specs = [RequestSpec(arrival_s=0.0, context_len=2 * context_len,
                         policy="sparkv", seed=0, weight=1.0)]
    specs += [RequestSpec(arrival_s=0.3 * i, context_len=context_len,
                          policy="sparkv", seed=i, weight=8.0,
                          deadline_s=4.0, slo_class="interactive")
              for i in range(1, n_interactive + 1)]
    rows = []
    for disc in ("fifo", "wfq", "srpt"):
        rep = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                             max_concurrency=len(specs),
                             run_queue=RunQueueModel(1, disc)).run(specs)
        s = rep.summary()
        shorts = [r.ttft_s for r in rep.records if r.spec.weight > 1]
        rows.append({
            "discipline": disc,
            "ttft_p50_s": s["ttft_p50_s"],
            "ttft_p99_s": s["ttft_p99_s"],
            "interactive_p99_s": float(np.percentile(shorts, 99)),
            "interactive_attainment": s["slo_attainment"],
            "queue_wait_p50_s": s["queue_wait_p50_s"],
            "queue_wait_p99_s": s["queue_wait_p99_s"],
            "migrations": s["migrations_total"],
        })
    return rows


def run(quick: bool = False, closed_loop: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    net = NETWORKS["campus-wifi"]
    rows = []
    if closed_loop:
        levels_n = [1, 2] if quick else [1, 2, 4, 8]
        ctx = 4096 if quick else 8192
        rows = _closed_loop_rows(cfg, ctx, levels_n)
        title = "\n[Fig 14] concurrent-request contention (closed-loop N)"
        disc_rows = _discipline_rows(cfg, 2048, 3 if quick else 5)
        print(table(disc_rows, list(disc_rows[0].keys()),
                    title="\n[Fig 14b] run-queue discipline: FIFO vs WFQ "
                          "vs SRPT (background + deadline interactive)"))
        p99 = {r["discipline"]: r["ttft_p99_s"] for r in disc_rows}
        print(f"p99 TTFT divergence fifo vs wfq: "
              f"{abs(p99['fifo'] - p99['wfq']) / max(p99['fifo'], p99['wfq']):.1%}")
        ip99 = {r["discipline"]: r["interactive_p99_s"] for r in disc_rows}
        best = min(("wfq", "srpt"), key=lambda d: ip99[d])
        delta = 1 - ip99[best] / ip99["fifo"]
        word = "better" if delta >= 0 else "worse"
        print(f"interactive p99: fifo {ip99['fifo']:.3f}s -> {best} "
              f"{ip99[best]:.3f}s ({abs(delta):.1%} {word})")
    else:
        disc_rows = []
        spcfg = SparKVConfig()
        wl = synthesize(cfg, 12_288, DATASETS["longchat"])
        levels = [0.0, 0.3, 0.6, 0.8]
        rows = _static_rows(cfg, spcfg, wl, net,
                            levels[:2] if quick else levels)
        title = "\n[Fig 14] concurrent-request contention"
    print(table(rows, list(rows[0].keys()), title=title))
    save("fig14_concurrency" + ("_closed_loop" if closed_loop else ""),
         {"rows": rows, "disciplines": disc_rows}, quick=quick)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--closed-loop", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick, closed_loop=a.closed_loop)
