"""Fig. 16: overhead breakdown — streaming path (transmission vs entropy
decode + device transfer) and compute path share, from the engine
timeline of a SparKV run."""
from __future__ import annotations

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig()
    wl = synthesize(cfg, 11_264, DATASETS["triviaqa"])
    net = NETWORKS["campus-wifi"]
    r = B.run_sparkv(cfg, wl, "laptop-5080", net, spcfg, seed=0)
    eng = r.engine
    bd = eng.breakdown()
    stream_total = eng.stream_busy_s
    rows = [{
        "transmission_s": bd["transmission_s"],
        "decode_proc_s": bd["decode_proc_s"],
        "transmission_pct": 100 * bd["transmission_s"]
        / max(stream_total, 1e-9),
        "decode_pct": 100 * bd["decode_proc_s"] / max(stream_total, 1e-9),
        "compute_s": bd["compute_s"],
        "ttft_s": r.ttft_s,
        "bytes_MB": eng.bytes_streamed / 1e6,
    }]
    print(table(rows, list(rows[0].keys()),
                title="\n[Fig 16] SparKV overhead breakdown "
                      "(laptop, TriviaQA-like)"))
    save("fig16_breakdown", {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    run()
