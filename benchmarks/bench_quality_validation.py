"""Validates the bits->fidelity curve (QUALITY_OF_BITS) used by the
simulation pipelines against *real* model behaviour: a small LM's context
KV is quantized at each bit width, streamed through the actual
Huffman+dequant path, and greedy decoding is compared token-by-token
against the exact cache."""
from __future__ import annotations

import numpy as np
import jax

from repro.configs import SparKVConfig, get_smoke
from repro.core.baselines import QUALITY_OF_BITS
from repro.models import build_model
from repro.serving.engine import SparKVServer

from benchmarks.common import save, table


def run(quick: bool = False):
    cfg = get_smoke("sparkv-qwen3-4b", layers=4, d_model=128, heads=8,
                    kv_heads=4, d_ff=256, vocab=1024)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    from repro.data.workloads import lm_token_batch
    ctx = lm_token_batch(rng, cfg.vocab_size, 1, 256)
    rows = []
    bit_list = [5, 3] if quick else [8, 5, 4, 3]
    n_req = 2 if quick else 4
    for bits in bit_list:
        spcfg = SparKVConfig(chunk_tokens=64, q_block=32, kv_block=32,
                             quant_bits=bits, quant_group=32)
        srv = SparKVServer(model, params, spcfg, chunk_tokens=64)
        cid = srv.register_context(ctx)
        agrees, kls = [], []
        for r_i in range(n_req):
            prompt = rng.integers(0, cfg.vocab_size, size=4)
            res = srv.generate(cid, prompt, max_new=8, policy="cachegen",
                               seed=r_i)
            agrees.append(res.top1_agreement)
            kls.append(res.mean_kl)
        rows.append({
            "bits": bits,
            "measured_top1": float(np.mean(agrees)),
            "measured_kl": float(np.mean(kls)),
            "table_quality": QUALITY_OF_BITS[bits],
        })
    print(table(rows, list(rows[0].keys()),
                title="\n[quality validation] real-model fidelity vs the "
                      "bits->quality table used in simulation"))
    save("quality_validation", {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    run()
