"""Simulator-throughput benchmark: the vectorized event core vs the
scalar reference, events/s vs fleet size.

Four studies:

  - **single-uplink head-to-head** — N flows fair-sharing one OU-traced
    uplink (the fleet benches' dominant topology), drained to empty on
    both cores from identical state. Finish times must agree *bitwise*
    (the two cores share ``_delivered_on``/``_finish_on`` and the same
    completion-cache discipline); the acceptance claim is the events/s
    ratio at N=5000.
  - **tree head-to-head** — a NIC -> AP-uplink -> cloud-egress tree
    (multi-stage paths, several path groups) at small N, where the
    scalar core's O(N) per-event completion re-search is still
    tractable. Parity gate: max |Δt| / t ≤ 1e-9 over all completions.
  - **vectorized scaling** — vectorized core only, N up to 100k
    concurrent flows on one shared uplink, telemetry on and off. The
    tentpole target is that a 100k-flow drain *completes*; the
    telemetry=False rows show what fleets that never read
    ``stage_shares`` save.
  - **fleet end-to-end** — identical ``ServingCluster.run`` traffic
    under ``link_core="vectorized"`` vs ``"scalar"``, comparing the
    cluster's own ``last_sim_stats`` (events/s of the whole event loop,
    not just the link server) and asserting the run reports match.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core.costs import (NETWORKS, MemoryModel, RunQueueModel,
                              SharedLinkModel)
from repro.core.engine import BandwidthIntegrator
from repro.serving.cluster import ServingCluster
from repro.serving.resources import (LinkTopology, ScalarLinkTopology,
                                     single_link, tree_topology)
from repro.serving.traffic import poisson_trace

from benchmarks.common import save, table

NET = NETWORKS["campus-wifi"]


def _integrator(seed: int, duration_s: float = 60.0,
                profile=NET) -> BandwidthIntegrator:
    rng = np.random.default_rng(seed)
    return BandwidthIntegrator(profile.trace(rng, duration_s), dt=0.01)


def _flow_sizes(n: int, seed: int) -> np.ndarray:
    rng = np.random.default_rng(seed + 1)
    return rng.uniform(0.5e6, 8e6, size=n)


def _drain(topo) -> tuple[list, float]:
    """Drain a pre-loaded topology to empty; returns (finish trace as
    [(t, key)] in completion order, wall seconds). One event = one
    next_completion + advance + complete round."""
    finishes = []
    t0 = time.perf_counter()
    while topo.n_active():
        t, key = topo.next_completion()
        topo.advance(t)
        topo.complete(key)
        finishes.append((t, key))
    return finishes, time.perf_counter() - t0


def _load_uplink(cls, n: int, seed: int, *, telemetry: bool = True):
    topo = single_link(_integrator(seed),
                       SharedLinkModel(NET), cls=cls, telemetry=telemetry)
    for i, b in enumerate(_flow_sizes(n, seed)):
        topo.add(i, float(b))
    return topo


def _single_uplink_rows(n_grid: list[int], seed: int = 7) -> list[dict]:
    rows = []
    for n in n_grid:
        fin_s, wall_s = _drain(_load_uplink(ScalarLinkTopology, n, seed))
        fin_v, wall_v = _drain(_load_uplink(LinkTopology, n, seed))
        assert fin_s == fin_v, \
            f"scalar/vectorized drains diverged at N={n}"
        rows.append({
            "n_flows": n,
            "scalar_ev_per_s": n / wall_s,
            "vec_ev_per_s": n / wall_v,
            "speedup": wall_s / wall_v,
            "scalar_wall_s": wall_s,
            "vec_wall_s": wall_v,
            "bitwise_equal": True,
        })
    return rows


def _load_tree(cls, n: int, seed: int):
    n_dev, n_aps = 8, 2
    nics = [_integrator(seed + 10 + d) for d in range(n_dev)]
    ups = [_integrator(seed + 30 + a) for a in range(n_aps)]
    egress = _integrator(seed + 50)
    topo = tree_topology(nics, ups, [d % n_aps for d in range(n_dev)],
                         egress, uplink_link=SharedLinkModel(NET), cls=cls)
    sizes = _flow_sizes(n, seed)
    for i, b in enumerate(sizes):
        d = i % n_dev
        path = (f"nic{d}", f"uplink{d % n_aps}", "egress")
        topo.add(i, float(b), path)
    return topo


def _tree_rows(n: int, seed: int = 11) -> list[dict]:
    fin_s, wall_s = _drain(_load_tree(ScalarLinkTopology, n, seed))
    fin_v, wall_v = _drain(_load_tree(LinkTopology, n, seed))
    assert [k for _, k in fin_s] == [k for _, k in fin_v], \
        "tree drains completed flows in different orders"
    rel = max(abs(ts - tv) / max(ts, 1e-12)
              for (ts, _), (tv, _) in zip(fin_s, fin_v))
    assert rel <= 1e-9, f"tree finish times diverged: rel={rel:.3e}"
    return [{
        "n_flows": n,
        "scalar_ev_per_s": n / wall_s,
        "vec_ev_per_s": n / wall_v,
        "speedup": wall_s / wall_v,
        "max_rel_dt": rel,
    }]


def _scaling_rows(n_grid: list[int], seed: int = 13) -> list[dict]:
    rows = []
    for n in n_grid:
        for telemetry in (True, False):
            if telemetry and n > 20_000:
                continue                     # headline 100k row: lean core
            fin, wall = _drain(_load_uplink(LinkTopology, n, seed,
                                            telemetry=telemetry))
            assert len(fin) == n
            rows.append({
                "n_flows": n,
                "telemetry": telemetry,
                "vec_ev_per_s": n / wall,
                "vec_wall_s": wall,
                "completed": len(fin) == n,
            })
    return rows


def _fleet_rows(n_req: int, seed: int = 17, *,
                rate_rps: float = 2.5,
                max_concurrency: int = 8) -> list[dict]:
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig(scheduler_mode="engine")
    specs = poisson_trace(n_req, rate_rps, max_context=4096, seed=seed)
    rows, summaries = [], []
    for core in ("vectorized", "scalar"):
        cluster = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                                 n_devices=4,
                                 run_queue=RunQueueModel(2, "fifo"),
                                 memory=MemoryModel(capacity_bytes=2e8),
                                 max_concurrency=max_concurrency,
                                 link_core=core)
        report = cluster.run(specs)
        s = report.summary()
        summaries.append((s["ttft_mean_s"], s["goodput_rps"]))
        st = cluster.last_sim_stats
        rows.append({
            "link_core": core,
            "n_events": st["n_events"],
            "events_per_s": st["events_per_s"],
            "wall_s": st["wall_s"],
            "ttft_mean_s": s["ttft_mean_s"],
            "goodput_rps": s["goodput_rps"],
        })
    assert summaries[0] == summaries[1], \
        "vectorized and scalar fleet runs diverged"
    return rows


def run(quick: bool = False):
    out = {}
    h2h_grid = [200, 500] if quick else [500, 2000, 5000]
    out["single_uplink"] = _single_uplink_rows(h2h_grid)
    print(table(out["single_uplink"], list(out["single_uplink"][0].keys()),
                title="\n[simcore] single shared uplink drain: scalar vs "
                      "vectorized (bitwise-locked)"))

    out["tree"] = _tree_rows(48 if quick else 128)
    print(table(out["tree"], list(out["tree"][0].keys()),
                title="\n[simcore] three-hop tree drain: scalar vs "
                      "vectorized (rtol 1e-9)"))

    scale_grid = [500, 2000] if quick else [5000, 20000, 100000]
    out["scaling"] = _scaling_rows(scale_grid)
    print(table(out["scaling"], list(out["scaling"][0].keys()),
                title="\n[simcore] vectorized-core scaling, single uplink"))

    out["fleet"] = _fleet_rows(24, max_concurrency=8) if quick else \
        _fleet_rows(400, rate_rps=8.0, max_concurrency=96)
    print(table(out["fleet"], list(out["fleet"][0].keys()),
                title="\n[simcore] fleet end-to-end event loop: "
                      "link_core vectorized vs scalar"))

    top = out["single_uplink"][-1]
    big = out["scaling"][-1]
    meets_10x = top["speedup"] >= 10.0
    done_100k = any(r["n_flows"] >= 100_000 and r["completed"]
                    for r in out["scaling"]) if not quick else None
    print(f"\nspeedup at N={top['n_flows']}: {top['speedup']:.1f}x"
          + ("  [acceptance met]" if meets_10x else ""))
    print(f"largest drain: N={big['n_flows']} in {big['vec_wall_s']:.1f}s "
          f"({big['vec_ev_per_s']:.0f} ev/s)")
    save("simcore", {**out,
                     "acceptance": {"speedup_at_max_n": top["speedup"],
                                    "max_n_head_to_head": top["n_flows"],
                                    "meets_10x": meets_10x,
                                    "completed_100k": done_100k}},
         quick=quick)
    return out


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
