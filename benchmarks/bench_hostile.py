"""Hostile-world wireless serving: handoff storms, outages, churn.

Static placement vs. the fleet-wide LP rebalancer on identical request
traces under three worlds:

  - **calm** — a disarmed ``ScenarioTrace`` on both link cores. The
    acceptance bar is *bit-parity*: fingerprints must equal the
    scenario-free fleet's exactly (the hostile machinery must be free
    when unused), asserted in-bench and again in
    ``tests/test_scenarios.py``.
  - **handoff storm** — every device roams onto AP 0 at staggered
    times during a flash-crowd arrival spike. Static placement piles
    the whole fleet onto one uplink; the
    :class:`~repro.serving.scenarios.FleetRebalancer` re-solves the
    Eq. 1 makespan LP at each event (warm-started basis-to-basis) and
    spreads devices back over the reachable APs. Acceptance: rebalanced
    SLO attainment strictly above static.
  - **outage + churn** — an AP blackout window plus a device failure
    mid-trace: in-flight transfers are lost at the boundary (bytes
    re-enter the backlog via the engine's ``StreamLost`` leg), evicted
    requests re-enter admission on surviving devices.

Reported per row: served/shed counts, SLO attainment, p99 TTFT, the
loss/handoff/rebalance telemetry, and LP warm-start hit counts.
"""
from __future__ import annotations

from repro.configs import SparKVConfig, get_config
from repro.core.costs import RunQueueModel
from repro.serving.cluster import ServingCluster
from repro.serving.scenarios import (ChurnEvent, FleetRebalancer,
                                     OutageWindow, ScenarioTrace,
                                     handoff_storm)
from repro.serving.slo import SLOPolicy
from repro.serving.traffic import TrafficProfile, generate_trace

from benchmarks.common import save, table

N_DEVICES = 4
N_APS = 2


def _fingerprint(report):
    """Per-request observables, exactly as produced (no rounding)."""
    return [(r.spec.arrival_s, r.ttft_s, r.ttlt_s, r.energy_j,
             r.uplink_share, r.compute_wait_s, r.bytes_streamed, r.policy,
             tuple(sorted(r.stage_shares.items())))
            for r in report.records]


def _cluster(cfg, spcfg, *, core="vectorized", scenario=None,
             rebalancer=None):
    return ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                          n_devices=N_DEVICES, n_aps=N_APS,
                          run_queue=RunQueueModel(2, "wfq"),
                          max_concurrency=8, slo=SLOPolicy(),
                          link_core=core, scenario=scenario,
                          rebalancer=rebalancer)


def _specs(n_req: int, *, flash: bool = False):
    prof = TrafficProfile(
        rate_rps=1.2, arrival="poisson", n_devices=N_DEVICES,
        max_context=8192,
        slo_mix=(("interactive", 3.5, 0.7), ("batch", None, 0.3)),
        flash_crowds=((0.5, 3.0, 4.0),) if flash else ())
    return generate_trace(prof, n_req, seed=11)


def _row(label: str, rep) -> dict:
    s = rep.summary()
    scen = rep.scenario or {}
    return {
        "world": label,
        "n_served": s["n_done"],
        "n_shed": s["n_shed"],
        "slo_attainment": s["slo_attainment"],
        "ttft_p99_s": s["ttft_p99_s"],
        "n_handoffs": scen.get("n_handoffs", 0),
        "n_streams_lost": scen.get("n_streams_lost", 0),
        "bytes_lost": scen.get("bytes_lost", 0.0),
        "n_churned": scen.get("n_churned", 0),
        "n_replaced": scen.get("n_replaced", 0),
        "n_rebalances": scen.get("n_rebalances", 0),
        "lp_warm_hits": scen.get("n_lp_warm_hits", 0),
    }


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig(scheduler_mode="engine")
    n_req = 10 if quick else 24

    # ---- calm world: disarmed scenario must be bit-identical ----
    calm_specs = _specs(n_req)
    parity = {}
    for core in ("vectorized", "scalar"):
        plain = _cluster(cfg, spcfg, core=core).run(calm_specs)
        disarmed = _cluster(cfg, spcfg, core=core,
                            scenario=ScenarioTrace(),
                            rebalancer=FleetRebalancer()).run(calm_specs)
        parity[core] = _fingerprint(plain) == _fingerprint(disarmed)
        assert parity[core], \
            f"disarmed scenario broke {core} fleet bit-parity"
        assert disarmed.scenario is None, "disarmed run grew telemetry"
    calm_rep = _cluster(cfg, spcfg).run(calm_specs)

    # ---- handoff storm under a flash-crowd arrival spike ----
    storm_specs = _specs(n_req, flash=True)
    storm = ScenarioTrace(handoffs=handoff_storm(
        N_DEVICES, N_APS, t_start_s=0.6, spacing_s=0.25))
    rep_static = _cluster(cfg, spcfg, scenario=storm).run(storm_specs)
    rep_rebal = _cluster(cfg, spcfg, scenario=storm,
                         rebalancer=FleetRebalancer()).run(storm_specs)

    # ---- outage + churn ----
    hostile = ScenarioTrace(
        handoffs=handoff_storm(N_DEVICES, N_APS,
                               t_start_s=1.0, spacing_s=0.4),
        outages=(OutageWindow(ap=0, t_start_s=2.0, t_end_s=6.0),),
        churn=(ChurnEvent(t_s=3.0, device=1),))
    rep_h_static = _cluster(cfg, spcfg, scenario=hostile).run(storm_specs)
    rep_h_rebal = _cluster(cfg, spcfg, scenario=hostile,
                           rebalancer=FleetRebalancer()).run(storm_specs)

    rows = [
        _row("calm", calm_rep),
        _row("storm/static", rep_static),
        _row("storm/rebalanced", rep_rebal),
        _row("outage+churn/static", rep_h_static),
        _row("outage+churn/rebalanced", rep_h_rebal),
    ]
    print(table(rows, list(rows[0].keys()),
                title=f"\n[hostile] {n_req} requests, {N_DEVICES} devices "
                      f"/ {N_APS} APs, WFQ, SLO admission"))

    def att(rep):
        a = rep.summary()["slo_attainment"]
        return a if a is not None else 0.0

    acceptance = {
        "calm_parity_vectorized": parity["vectorized"],
        "calm_parity_scalar": parity["scalar"],
        "storm_attainment_static": att(rep_static),
        "storm_attainment_rebalanced": att(rep_rebal),
        "rebalancer_beats_static": att(rep_rebal) > att(rep_static),
        "hostile_attainment_static": att(rep_h_static),
        "hostile_attainment_rebalanced": att(rep_h_rebal),
        "rebalancer_no_worse_hostile":
            att(rep_h_rebal) >= att(rep_h_static),
    }
    print(f"storm attainment: static {acceptance['storm_attainment_static']:.0%}"
          f" -> rebalanced {acceptance['storm_attainment_rebalanced']:.0%}"
          + ("  [acceptance met]"
             if acceptance["rebalancer_beats_static"] else ""))
    save("hostile", {"rows": rows, "acceptance": acceptance,
                     "config": {"n_requests": n_req,
                                "n_devices": N_DEVICES, "n_aps": N_APS}},
         quick=quick)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    run(quick=ap.parse_args().quick)
