"""Table I: TTFT and energy, wireless KV streaming vs on-device prefill,
across edge platforms (+ the TPU serving profile)."""
from __future__ import annotations

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table

# (device profile, model, context length) mirroring the paper's rows
ROWS = [
    ("redmi-k80", "sparkv-qwen3-4b", 8_192, "campus-wifi"),
    ("laptop-5080", "sparkv-qwen3-4b", 12_288, "campus-wifi"),
    ("jetson-orin", "qwen2.5-3b", 16_384, "campus-wifi"),
    ("jetson-agx", "phi3-medium-14b", 24_576, "campus-wifi"),
    ("tpu-v5e-1chip", "sparkv-qwen3-4b", 16_384, "dcn-25g"),
]


def run(quick: bool = False):
    spcfg = SparKVConfig()
    rows = []
    for profile, arch, ctx, net_name in ROWS[:3 if quick else None]:
        cfg = get_config(arch)
        wl = synthesize(cfg, ctx, DATASETS["triviaqa"])
        net = NETWORKS[net_name]
        # stream-only at the native 5-bit encoding (Table I measures raw
        # streaming, not CacheGen's bitrate ladder)
        stream = B.run_kivi(cfg, wl, profile, net, spcfg, seed=0,
                            bits=spcfg.quant_bits)
        comp = B.run_local_prefill(cfg, wl, profile, net, spcfg, seed=0)
        rows.append({
            "device": profile, "model": arch, "ctx": ctx,
            "stream_ttft_s": stream.ttft_s, "stream_J": stream.energy_j,
            "compute_ttft_s": comp.ttft_s, "compute_J": comp.energy_j,
            "ttft_gain": comp.ttft_s / stream.ttft_s,
            "energy_gain": comp.energy_j / stream.energy_j,
        })
    print(table(rows, list(rows[0].keys()),
                title="\n[Table I] KV streaming vs on-device prefill"))
    save("table1_stream_vs_compute", {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    run()
