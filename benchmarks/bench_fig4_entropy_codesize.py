"""Fig. 4/5: entropy and Huffman code-size distribution of real KV chunks
— a small model's actual KV cache is quantized to 5 bits and entropy
coded; per-(layer, head) entropy spread drives compressed-size spread."""
from __future__ import annotations

import numpy as np
import jax

from repro.compression import huffman
from repro.compression.quantize import quantize
from repro.configs import get_smoke
from repro.models import build_model

from benchmarks.common import save, table


def run(quick: bool = False):
    cfg = get_smoke("sparkv-qwen3-4b", layers=4, d_model=128, heads=8,
                    kv_heads=4, vocab=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    # highly repetitive context (context-reuse workloads are): V vectors of
    # repeated tokens are identical -> low-entropy chunks; K carries RoPE
    # position structure -> higher entropy. Both measured, like the paper.
    from repro.data.workloads import lm_token_batch
    toks = lm_token_batch(rng, cfg.vocab_size, 1, 512, motif_len=128,
                          n_motifs=4)
    _, cache = model.prefill(params, {"tokens": jax.numpy.asarray(toks)})
    k = np.asarray(cache["k"], np.float32)    # (L, 1, S, hkv, hd)
    v = np.asarray(cache["v"], np.float32)

    ents, sizes = [], []
    for tensor in (k, v):
        for l in range(cfg.num_layers):
            for h in range(cfg.num_kv_heads):
                vals = tensor[l, 0, :, h, :]
                qt = quantize(vals, 5, 64)
                e = huffman.entropy_bits(qt.codes, 32)
                enc = huffman.encode(qt.codes, 32, n_streams=32)
                ents.append(e)
                sizes.append(enc.payload_bytes() + qt.header_bytes())
    ents, sizes = np.array(ents), np.array(sizes)
    raw = vals.size * 5 / 8
    rows = [{
        "chunks": len(ents),
        "entropy_min_b": float(ents.min()),
        "entropy_p50_b": float(np.median(ents)),
        "entropy_max_b": float(ents.max()),
        "size_min_KB": float(sizes.min() / 1e3),
        "size_max_KB": float(sizes.max() / 1e3),
        "size_spread_x": float(sizes.max() / sizes.min()),
        "vs_raw5bit": float(np.mean(sizes) / (raw + 16)),
    }]
    print(table(rows, list(rows[0].keys()),
                title="\n[Fig 4/5] KV chunk entropy & Huffman code size "
                      "(real model KV)"))
    save("fig4_entropy_codesize", {"rows": rows,
                                   "entropies": ents.tolist(),
                                   "sizes": sizes.tolist()},
         quick=quick)
    return rows


if __name__ == "__main__":
    run()
