"""Benchmark harness driver: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--quick] [--only fig9,fig13]
                                          [--profile]

``--profile`` stamps a ``_profile`` block into every saved JSON: the
bench's wall-clock plus the simulator-throughput counters (events,
sim wall-clock, events/s) accumulated across its ``ServingCluster.run``
calls — sim throughput becomes a recorded metric alongside the bench's
own numbers.
"""
from __future__ import annotations

import argparse
import time
import traceback

from benchmarks import common

BENCHES = [
    ("table1", "benchmarks.bench_table1_stream_vs_compute"),
    ("table2", "benchmarks.bench_table2_greedy_vs_milp"),
    ("fig3", "benchmarks.bench_fig3_chunk_latency"),
    ("fig4", "benchmarks.bench_fig4_entropy_codesize"),
    ("fig8", "benchmarks.bench_fig8_predictor"),
    ("fig9", "benchmarks.bench_fig9_overall"),
    ("fig13", "benchmarks.bench_fig13_interference"),
    ("fig14", "benchmarks.bench_fig14_concurrency"),
    ("fleet", "benchmarks.bench_fleet_traffic"),
    ("slo", "benchmarks.bench_slo_admission"),
    ("decode", "benchmarks.bench_decode_goodput"),
    ("topology", "benchmarks.bench_topology_tree"),
    ("memory", "benchmarks.bench_kv_memory"),
    ("reuse", "benchmarks.bench_reuse"),
    ("fig15", "benchmarks.bench_fig15_context_scaling"),
    ("fig16", "benchmarks.bench_fig16_breakdown"),
    ("quality", "benchmarks.bench_quality_validation"),
    ("roofline", "benchmarks.bench_roofline"),
    ("simcore", "benchmarks.bench_simcore"),
    ("quant", "benchmarks.bench_quant"),
    ("hostile", "benchmarks.bench_hostile"),
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--only", default=None)
    ap.add_argument("--profile", action="store_true",
                    help="stamp wall-clock + simulator events/s metadata "
                         "into every saved bench JSON")
    args = ap.parse_args()
    common.PROFILE = args.profile
    only = set(args.only.split(",")) if args.only else None
    if only:
        known = [name for name, _ in BENCHES]
        unknown = sorted(only - set(known))
        if unknown:
            raise SystemExit(
                f"unknown benchmark name(s) in --only: {', '.join(unknown)}"
                f"; registered: {', '.join(known)}")

    t_all = time.time()
    results = {}
    for name, module in BENCHES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            import importlib
            mod = importlib.import_module(module)
            common.begin_bench()
            mod.run(quick=args.quick)
            results[name] = f"OK ({time.time() - t0:.0f}s)"
        except Exception as e:  # noqa: BLE001
            traceback.print_exc()
            results[name] = f"FAIL: {type(e).__name__}: {e}"
    print(f"\n=== benchmark summary ({time.time() - t_all:.0f}s) ===")
    if not results:
        known = ", ".join(name for name, _ in BENCHES)
        raise SystemExit(f"no benchmarks matched --only={args.only}; "
                         f"known: {known}")
    width = max(len(k) for k in results)
    failed = 0
    for k, v in results.items():
        print(f"  {k.ljust(width)}  {v}")
        failed += v.startswith("FAIL")
    if failed:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
