"""SLO-aware admission & scheduling: attainment under overload.

Two fleet scenarios, each a Poisson trace with a 70/30
interactive-with-deadline / best-effort-batch class mix, run under four
serving configurations on the same specs:

  - ``fifo``       — the PR 2 baseline: non-preemptive FIFO device
    queue, no admission control (every request is served, deadlines are
    recorded but ignored);
  - ``fifo+shed``  — FIFO queue + the SLO admission layer (predicted
    TTFT violations downgrade the KV stream to coarser quantization or
    shed the request);
  - ``wfq+shed``   — SLO admission + deadline-slack-derived WFQ weight
    classes on the device queue;
  - ``srpt+shed``  — SLO admission + the preemptive-at-chunk-boundary
    SRPT discipline with its deadline floor.

Scenarios:

  - **compute-bound** — sparkv fleet on a capacity-1 device: queueing
    delay dominates, shedding is the main lever;
  - **stream-bound** — strong_hybrid fleet on a capacity-2 device: the
    shared link dominates, so the quantization downgrade ladder carries
    part of the load before shedding kicks in.

Reported per configuration: SLO attainment over served deadline-class
requests (the acceptance bar: FIFO < 90%, SLO-enabled >= 90%),
interactive-class p99 TTFT, shed / downgrade counts, and
goodput-under-SLO (only in-contract completions count).
"""
from __future__ import annotations

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core.costs import RunQueueModel
from repro.serving.cluster import ServingCluster
from repro.serving.slo import SLOPolicy
from repro.serving.traffic import TrafficProfile, generate_trace

from benchmarks.common import save, table

SCENARIOS = {
    # name: (policy, rate_rps, deadline_s, capacity)
    "compute-bound": ("sparkv", 0.7, 8.0, 1),
    "stream-bound": ("strong_hybrid", 0.9, 10.0, 2),
}


def _variants(capacity: int):
    return [
        ("fifo", RunQueueModel(capacity, "fifo"), None),
        ("fifo+shed", RunQueueModel(capacity, "fifo"), SLOPolicy()),
        ("wfq+shed", RunQueueModel(capacity, "wfq"), SLOPolicy()),
        ("srpt+shed", RunQueueModel(capacity, "srpt"), SLOPolicy()),
    ]


def _run_scenario(cfg, spcfg, name: str, n_req: int) -> list[dict]:
    policy, rate, deadline, capacity = SCENARIOS[name]
    prof = TrafficProfile(rate_rps=rate, arrival="poisson",
                          policy_mix=((policy, 1.0),),
                          max_context=8192,
                          slo_mix=(("interactive", deadline, 0.7),
                                   ("batch", None, 0.3)))
    specs = generate_trace(prof, n_req, seed=11)
    rows = []
    for label, run_queue, slo in _variants(capacity):
        rep = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                             max_concurrency=6, run_queue=run_queue,
                             slo=slo).run(specs)
        s = rep.summary()
        ints = [r.ttft_s for r in rep.records if r.deadline_s is not None]
        rows.append({
            "scenario": name,
            "config": label,
            "slo_attainment": s["slo_attainment"],
            # shed requests counted as misses: shows how much of the
            # headline attainment is scheduling gain vs. admission
            # selectivity
            "attainment_arrived": s["slo_attainment_arrived"],
            "interactive_p99_s": float(np.percentile(ints, 99))
            if ints else None,
            "n_served": s["n_done"],
            "n_shed": s["n_shed"],
            "n_downgraded": s["n_downgraded"],
            "goodput_slo_rps": s["goodput_slo_rps"],
            "ttft_p99_s": s["ttft_p99_s"],
        })
    return rows


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig(scheduler_mode="engine")
    n_req = 8 if quick else 14
    all_rows = []
    acceptance = {}
    for name in SCENARIOS:
        rows = _run_scenario(cfg, spcfg, name, n_req)
        all_rows.extend(rows)
        print(table(rows, list(rows[0].keys()),
                    title=f"\n[SLO] {name}: {n_req} Poisson requests, "
                          f"70/30 interactive/batch"))
        att = {r["config"]: r["slo_attainment"] for r in rows}
        slo_atts = [v for k, v in att.items()
                    if k != "fifo" and v is not None]
        # None everywhere = every deadline request was shed (extreme
        # overload): report 0 served-in-contract rather than crashing
        best = max(slo_atts) if slo_atts else 0.0
        acceptance[name] = {"fifo": att["fifo"], "best_slo": best}
        fifo_att = att["fifo"] if att["fifo"] is not None else 0.0
        print(f"attainment: fifo {fifo_att:.0%} -> best SLO config "
              f"{best:.0%}"
              + ("  [acceptance met]" if fifo_att < 0.9 <= best
                 else ""))
    save("slo_admission", {"rows": all_rows, "acceptance": acceptance,
                           "scenarios": {k: dict(zip(
                               ("policy", "rate_rps", "deadline_s",
                                "capacity"), v))
                               for k, v in SCENARIOS.items()}},
         quick=quick)
    return all_rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
