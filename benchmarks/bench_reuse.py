"""Cross-request KV reuse: content-addressed prefix sharing vs. a
no-reuse fleet on a three-hop cloud-egress topology.

Through PR 7 every request streamed its full context from the cloud
origin, even when the fleet had just encoded the same system prompt a
second earlier. This bench arms the content-addressed reuse layer — the
finite :class:`repro.serving.kvstore.CloudKVStore` (cloud hits bypass
the shared egress stage) plus per-device prefix caches (local hits skip
the link entirely) — and measures what sharing is worth:

  - **overlap sweep** — the same Zipf-popular prefix pool at rising
    ``prefix_frac`` (0 → 0.75 of each request's blocks shared): goodput
    and cloud-egress bytes for the store-armed fleet vs. the identical
    trace with the store disabled, with the store's measured hit rate
    as the x-axis;
  - **0%-overlap parity** — at ``prefix_frac=0.0`` (content ids present,
    never two alike) the armed fleet's per-request fingerprints must be
    bit-identical to the disabled fleet: the reuse layer prices misses
    at exactly zero;
  - **multi-turn sessions** — ``session_trace`` chats that re-send their
    whole history each turn: the device prefix cache turns each turn's
    shared head into near-free local hits.

Acceptance: at the top overlap level the store-armed fleet beats the
no-reuse fleet on goodput (tok/s) *and* moves fewer cloud-egress bytes.
"""
from __future__ import annotations

import dataclasses

from repro.configs import SparKVConfig, get_config
from repro.core.costs import KVStoreModel, RunQueueModel
from repro.serving.cluster import ServingCluster
from repro.serving.decode import DecodeConfig
from repro.serving.traffic import TrafficProfile, generate_trace, \
    session_trace

from benchmarks.common import save, table

# shared-prefix popularity: a handful of system prompts / RAG documents
# with Zipf-skewed draw frequency
POOL = 6
ZIPF_A = 1.2
OVERLAPS = (0.0, 0.25, 0.5, 0.75)
OVERLAPS_QUICK = (0.0, 0.75)

# decode so goodput (tok/s) is a meaningful axis, not just TTFT
OUT_LEN_MIX = ((64, 0.6), (192, 0.4))

STORE = KVStoreModel(capacity_bytes=float(4 << 30),
                     device_capacity_bytes=float(8 << 30))


def _cluster(cfg, spcfg, kv):
    # three-hop tree: per-device NICs -> per-AP uplinks -> one shared
    # cloud-egress stage. Cloud store hits replicate to the edge, so
    # they bypass the egress stage — the hop that binds under load.
    return ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                          n_devices=4, nic="device-nic", n_aps=2,
                          egress="cloud-egress",
                          max_concurrency=8,
                          run_queue=RunQueueModel(2, "fifo"),
                          decode=DecodeConfig(max_batch=4),
                          kvstore=kv)


def _egress_bytes(rep) -> float:
    if rep.reuse is not None:
        return rep.reuse["egress_bytes_total"]
    return sum(r.bytes_streamed for r in rep.records)


def _fingerprint(rep):
    return [(r.spec.arrival_s, r.ttft_s, r.ttlt_s, r.energy_j,
             r.bytes_streamed, r.policy)
            for r in rep.records]


def _row(label, overlap, rep) -> dict:
    s = rep.summary()
    reuse = rep.reuse or {}
    store = reuse.get("store", {})
    return {
        "config": label,
        "prefix_frac": overlap,
        "goodput_tok_s": s["goodput_tok_s"],
        "ttft_p50_s": s["ttft_p50_s"],
        "ttft_p99_s": s["ttft_p99_s"],
        "egress_gb": _egress_bytes(rep) / 1e9,
        "store_hit_rate": store.get("hit_rate"),
        "store_evictions": store.get("n_evictions"),
        "local_hits": reuse.get("local_hits_total"),
        "store_hits": reuse.get("store_hits_total"),
        "makespan_s": rep.makespan_s,
    }


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig(scheduler_mode="engine")
    n_req = 10 if quick else 16
    overlaps = OVERLAPS_QUICK if quick else OVERLAPS
    base_prof = TrafficProfile(rate_rps=4.0, arrival="poisson",
                               n_devices=4, max_context=8192,
                               out_len_mix=OUT_LEN_MIX,
                               prefix_pool=POOL, prefix_zipf_a=ZIPF_A)

    rows = []
    parity = None
    print(f"\n[reuse] {n_req} Poisson requests, pool={POOL}, "
          f"zipf_a={ZIPF_A}, overlap sweep {overlaps}")
    for frac in overlaps:
        prof = dataclasses.replace(base_prof, prefix_frac=frac)
        specs = generate_trace(prof, n_req, seed=17)
        off = _cluster(cfg, spcfg, None).run(specs)
        on = _cluster(cfg, spcfg, STORE).run(specs)
        rows.append(_row("no-reuse", frac, off))
        rows.append(_row("store", frac, on))
        if frac == 0.0:
            # content ids present but never two alike: the armed fleet
            # must price every miss at exactly zero
            parity = _fingerprint(off) == _fingerprint(on)
            assert parity, "0%-overlap armed fleet diverged from no-reuse"
        hr = on.reuse["store"]["hit_rate"]
        print(f"overlap {frac:.2f}: hit rate {hr:.2f}, goodput "
              f"{rows[-1]['goodput_tok_s']:.2f} vs "
              f"{rows[-2]['goodput_tok_s']:.2f} tok/s, egress "
              f"{rows[-1]['egress_gb']:.2f} vs "
              f"{rows[-2]['egress_gb']:.2f} GB")

    top = max(overlaps)
    on_top = next(r for r in rows
                  if r["config"] == "store" and r["prefix_frac"] == top)
    off_top = next(r for r in rows
                   if r["config"] == "no-reuse" and r["prefix_frac"] == top)
    acceptance = {
        "overlap": top,
        "store_goodput_tok_s": on_top["goodput_tok_s"],
        "no_reuse_goodput_tok_s": off_top["goodput_tok_s"],
        "store_egress_gb": on_top["egress_gb"],
        "no_reuse_egress_gb": off_top["egress_gb"],
        "store_hit_rate": on_top["store_hit_rate"],
        "zero_overlap_parity": parity,
        "store_wins": (on_top["goodput_tok_s"] > off_top["goodput_tok_s"]
                       and on_top["egress_gb"] < off_top["egress_gb"]),
    }
    print(f"acceptance @ overlap {top}: store "
          f"{on_top['goodput_tok_s']:.2f} tok/s / "
          f"{on_top['egress_gb']:.2f} GB egress vs no-reuse "
          f"{off_top['goodput_tok_s']:.2f} / {off_top['egress_gb']:.2f}"
          + ("  [acceptance met]" if acceptance["store_wins"] else ""))

    # multi-turn sessions: intra-session history reuse via the device
    # prefix cache (turn j's shared head = turn j-1's whole chain)
    n_sess = 3 if quick else 8
    sess_prof = dataclasses.replace(
        base_prof, rate_rps=0.25, prefix_frac=0.5,
        session_turns_mix=((2, 0.5), (4, 0.5)), think_time_s=6.0,
        turn_growth_chunks=1)
    sess = session_trace(sess_prof, n_sess, seed=23)
    s_off = _cluster(cfg, spcfg, None).run(sess)
    s_on = _cluster(cfg, spcfg, STORE).run(sess)
    sess_rows = [_row("sessions-no-reuse", None, s_off),
                 _row("sessions-store", None, s_on)]
    rows += sess_rows
    print(f"sessions ({n_sess} chats, {len(sess)} turns): store "
          f"{sess_rows[1]['goodput_tok_s']:.2f} tok/s, "
          f"{sess_rows[1]['local_hits']} local hits vs no-reuse "
          f"{sess_rows[0]['goodput_tok_s']:.2f} tok/s")

    print(table(rows, list(rows[0].keys()),
                title="\n[reuse] goodput / egress vs. prefix overlap"))
    save("reuse",
         {"rows": rows, "acceptance": acceptance,
          "pool": POOL, "zipf_a": ZIPF_A, "overlaps": list(overlaps),
          "store_capacity_gb": STORE.capacity_bytes / 2 ** 30,
          "n_requests": n_req, "n_sessions": n_sess},
         quick=quick)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
