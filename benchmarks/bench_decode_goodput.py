"""Continuous batched decode: full-response goodput under overload.

The pre-decode fleet ended every request at its first token, so its
"goodput" silently assumed the decode phase was free. This bench runs
the fig14-style overload scenarios with per-request response lengths and
compares, on identical traffic:

  - ``first-token``  — decode disabled (the old accounting): requests
    drop at TTFT, one token each ever reaches the user;
  - ``serial``       — full responses, but decode batch size 1: whole
    responses serialize on the device;
  - ``continuous``   — full responses through the continuous batcher
    (max_batch 8, token-boundary join/leave): co-resident sequences
    share each decode step's weight reads.

Scenarios:

  - **compute-bound** — sparkv fleet on a capacity-1 device: decode
    steps contend with prefill chunks on the FIFO run queue;
  - **stream-bound** — strong_hybrid fleet on a capacity-2 device: the
    shared link throttles context assembly while decode drains batches.

Acceptance: on both scenarios, continuous batching delivers more
tokens/s than the first-token-only fleet ever shipped *and* than serial
decode — batching, not accounting, buys the throughput.
"""
from __future__ import annotations

from repro.configs import SparKVConfig, get_config
from repro.core.costs import RunQueueModel
from repro.serving.cluster import ServingCluster
from repro.serving.decode import DecodeConfig
from repro.serving.traffic import TrafficProfile, generate_trace

from benchmarks.common import save, table

SCENARIOS = {
    # name: (policy, rate_rps, capacity) — rates chosen well past the
    # device's service rate so responses genuinely pile up (decode
    # overlap is what continuous batching monetizes)
    "compute-bound": ("sparkv", 2.5, 1),
    "stream-bound": ("strong_hybrid", 3.0, 2),
}

# chat-reply / long-generation response mix (tokens)
OUT_LEN_MIX = ((32, 0.5), (128, 0.5))


VARIANTS = [
    ("first-token", None),                       # decode off (old account)
    ("serial", DecodeConfig(max_batch=1)),
    ("continuous", DecodeConfig(max_batch=8)),
]


def _run_scenario(cfg, spcfg, name: str, n_req: int) -> list[dict]:
    import dataclasses
    policy, rate, capacity = SCENARIOS[name]
    prof = TrafficProfile(rate_rps=rate, arrival="poisson",
                          policy_mix=((policy, 1.0),),
                          max_context=8192, out_len_mix=OUT_LEN_MIX)
    specs = generate_trace(prof, n_req, seed=23)
    rows = []
    for label, decode in VARIANTS:
        run_specs = specs if decode is not None else [
            dataclasses.replace(s, max_new_tokens=0) for s in specs]
        rep = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                             max_concurrency=6,
                             run_queue=RunQueueModel(capacity, "fifo"),
                             decode=decode).run(run_specs)
        s = rep.summary()
        rows.append({
            "scenario": name,
            "config": label,
            "tokens_out": s["tokens_out_total"],
            "goodput_tok_s": s["goodput_tok_s"],
            "goodput_resp_s": s["goodput_resp_s"],
            "ttft_p99_s": s["ttft_p99_s"],
            "tpot_p50_s": s["tpot_p50_s"],
            "tpot_p99_s": s["tpot_p99_s"],
            "ttlt_p99_s": s["ttlt_p99_s"],
            "energy_per_req_j": s["energy_per_req_j"],
            "makespan_s": rep.makespan_s,
        })
    return rows


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig(scheduler_mode="engine")
    n_req = 6 if quick else 14
    all_rows = []
    acceptance = {}
    for name in SCENARIOS:
        rows = _run_scenario(cfg, spcfg, name, n_req)
        all_rows.extend(rows)
        print(table(rows, list(rows[0].keys()),
                    title=f"\n[decode] {name}: {n_req} Poisson requests, "
                          f"out-len mix {OUT_LEN_MIX}"))
        tok = {r["config"]: r["goodput_tok_s"] for r in rows}
        ok = tok["continuous"] > tok["first-token"] \
            and tok["continuous"] > tok["serial"]
        acceptance[name] = {
            "first_token_tok_s": tok["first-token"],
            "serial_tok_s": tok["serial"],
            "continuous_tok_s": tok["continuous"],
            "continuous_wins": ok,
        }
        print(f"tokens/s: first-token {tok['first-token']:.2f}, "
              f"serial {tok['serial']:.2f}, "
              f"continuous {tok['continuous']:.2f}"
              + ("  [acceptance met]" if ok else ""))
    save("decode_goodput",
         {"rows": all_rows, "acceptance": acceptance,
          "out_len_mix": list(OUT_LEN_MIX),
          "scenarios": {k: dict(zip(("policy", "rate_rps", "capacity"), v))
                        for k, v in SCENARIOS.items()}},
         quick=quick)
    return all_rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
