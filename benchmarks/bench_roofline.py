"""§Roofline table: joins the production dry-run (memory_analysis, compile
proof, HLO-text collectives) with the depth-extrapolated cost calibration
(launch.calibrate) and prints per-(arch x shape) roofline terms.

    compute_s    = flops_per_dev / 197e12        (bf16 peak, v5e)
    memory_s     = bytes_per_dev / 819e9
    collective_s = wire_bytes_per_dev / 50e9     (ring-modeled)

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill) / 2·N·B (decode) and
the useful-compute ratio MODEL_FLOPS / (HLO_flops x chips).
"""
from __future__ import annotations

import glob
import json
import os

from repro.distributed.roofline import HBM_BW, LINK_BW, PEAK_FLOPS

from benchmarks.common import save, table

DRYRUN_DIR = "results/dryrun"
CALIB_DIR = "results/calibration"


def load_cells(mesh_tag: str = "pod16x16"):
    rows = []
    for path in sorted(glob.glob(f"{DRYRUN_DIR}/{mesh_tag}/*.json")):
        dr = json.load(open(path))
        if dr["status"] == "SKIP":
            rows.append({"arch": dr["arch"], "shape": dr["shape"],
                         "status": "SKIP", "note": dr["reason"][:40]})
            continue
        if dr["status"] != "OK":
            rows.append({"arch": dr["arch"], "shape": dr["shape"],
                         "status": dr["status"]})
            continue
        cpath = path.replace(DRYRUN_DIR, CALIB_DIR)
        cal = json.load(open(cpath)) if os.path.exists(cpath) else None
        chips = dr["chips"]
        if cal and cal.get("status") == "OK":
            flops, wire = cal["flops"], cal["coll_wire"]
            src = "calibrated"
        else:
            flops = dr["cost"]["flops_per_dev"]
            wire = dr["collectives"]["wire_bytes"]
            src = "hlo(scan-undercounted)"
        # memory term: analytic HBM-traffic model (HLO bytes-accessed is
        # not HBM traffic — see distributed/analytic.py docstring)
        from repro.configs import SHAPES, get_config
        from repro.distributed.analytic import analytic_bytes
        byts = analytic_bytes(get_config(dr["arch"]), SHAPES[dr["shape"]],
                              chips)["bytes_per_dev"]
        compute_s = flops / PEAK_FLOPS
        memory_s = byts / HBM_BW
        coll_s = wire / LINK_BW
        terms = {"compute": compute_s, "memory": memory_s,
                 "collective": coll_s}
        dom = max(terms, key=terms.get)
        bound = terms[dom]
        mf = dr["model_flops"]
        rows.append({
            "arch": dr["arch"], "shape": dr["shape"], "status": "OK",
            "mem_GiB": dr["memory"]["peak_est_bytes"] / 2**30,
            "compute_ms": compute_s * 1e3,
            "memory_ms": memory_s * 1e3,
            "collective_ms": coll_s * 1e3,
            "dominant": dom,
            "bound_ms": bound * 1e3,
            "roofline_frac": compute_s / bound if bound else 0.0,
            "useful_ratio": mf / (flops * chips) if flops else 0.0,
            "src": src,
        })
    return rows


def run(quick: bool = False, mesh_tag: str = "pod16x16"):
    rows = load_cells(mesh_tag)
    cols = ["arch", "shape", "status", "mem_GiB", "compute_ms",
            "memory_ms", "collective_ms", "dominant", "roofline_frac",
            "useful_ratio", "src"]
    print(table([r for r in rows],
                cols, title=f"\n[Roofline] per-cell terms ({mesh_tag}, "
                            f"v5e: 197TF/s, 819GB/s HBM, 50GB/s link)"))
    save(f"roofline_{mesh_tag}", {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    run()
