"""Fig. 15: TTFT scaling with reusable-context length (10K-38K tokens):
SparKV near-linear; local prefill super-linear; CacheGen bandwidth-bound."""
from __future__ import annotations

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig()
    net = NETWORKS["campus-wifi"]
    rows = []
    lens = [10_240, 18_432, 28_672, 38_912]
    for ctx in lens[:2] if quick else lens:
        wl = synthesize(cfg, ctx, DATASETS["narrativeqa"])
        row = {"ctx_tokens": ctx}
        for pol in ["sparkv", "strong_hybrid", "cachegen",
                    "local_prefill"]:
            r = B.PIPELINES[pol](cfg, wl, "jetson-agx", net, spcfg, seed=0)
            row[f"{pol}_ttft"] = r.ttft_s
        rows.append(row)
    # scaling exponents (log-log slope first->last)
    import numpy as np
    for pol in ["sparkv", "local_prefill"]:
        y = [r[f"{pol}_ttft"] for r in rows]
        x = [r["ctx_tokens"] for r in rows]
        slope = float(np.polyfit(np.log(x), np.log(y), 1)[0])
        print(f"  {pol} TTFT ~ ctx^{slope:.2f}")
    print(table(rows, list(rows[0].keys()),
                title="\n[Fig 15] TTFT vs reusable-context length "
                      "(jetson-agx)"))
    save("fig15_context_scaling", {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    run()
