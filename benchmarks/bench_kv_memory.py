"""KV memory server: goodput under finite device memory budgets.

Through PR 5 device memory was infinite — long-decode overloads kept
every assembled context resident forever, so fleet goodput was blind to
the resource that binds first on real devices. This bench arms the
:class:`repro.serving.memory.KVMemoryServer` on a long-decode sparkv
overload and measures what finiteness actually costs:

  - **budget sweep** — one unbounded tracking run measures the workload's
    true peak residency, then the same trace replays under budgets at
    fractions of that peak: goodput-vs-memory-budget curves;
  - **eviction policies** — at each budget, ``lru`` / ``idle`` /
    ``bits`` (evict-to-lower-bits requantizes the victim in place down
    the quantization ladder instead of suspending it);
  - **reload modes** — the overhead-aware ``planner`` (per chunk, pick
    among disk read / cloud restream / local recompute, seeded with the
    live backlogs) against the single-path ``restream`` and
    ``recompute`` baselines.

Acceptance: at every finite budget the planner's goodput beats *both*
single-path reload baselines — reload time is SparKV's stream-vs-compute
decision re-posed at eviction time, and picking one path in advance
loses to picking per chunk against live contention.
"""
from __future__ import annotations

from repro.configs import SparKVConfig, get_config
from repro.core.costs import MemoryModel, RunQueueModel
from repro.serving.cluster import ServingCluster
from repro.serving.decode import DecodeConfig
from repro.serving.traffic import TrafficProfile, generate_trace

from benchmarks.common import save, table

# long responses: decode-phase KV growth, not just prefill residency,
# drives the device over budget
OUT_LEN_MIX = ((192, 0.5), (384, 0.5))

# fractions of the measured unbounded peak residency
BUDGET_FRACS = (0.6, 0.35)

# (label, policy, reload mode)
VARIANTS = [
    ("planner-lru", "lru", "planner"),
    ("planner-idle", "idle", "planner"),
    ("planner-bits", "bits", "planner"),
    ("restream-lru", "lru", "restream"),
    ("recompute-lru", "lru", "recompute"),
]


def _cluster(cfg, spcfg, memory):
    return ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                          max_concurrency=8,
                          run_queue=RunQueueModel(1, "fifo"),
                          decode=DecodeConfig(max_batch=4),
                          memory=memory)


def _row(label, budget_frac, budget, rep) -> dict:
    s = rep.summary()
    return {
        "config": label,
        "budget_frac": budget_frac,
        "budget_gb": budget / 1e9 if budget is not None else None,
        "tokens_out": s["tokens_out_total"],
        "goodput_tok_s": s["goodput_tok_s"],
        "ttft_p99_s": s["ttft_p99_s"],
        "tpot_p99_s": s["tpot_p99_s"],
        "ttlt_p99_s": s["ttlt_p99_s"],
        "peak_resident_gb": s["peak_resident_bytes"] / 1e9,
        "n_evictions": s["n_evictions"],
        "n_downgrades": s["n_downgrades"],
        "n_reloads": s["n_reloads"],
        "reload_s_total": s["reload_s_total"],
        "makespan_s": rep.makespan_s,
    }


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig(scheduler_mode="engine")
    n_req = 5 if quick else 12
    prof = TrafficProfile(rate_rps=2.0, arrival="poisson",
                          policy_mix=(("sparkv", 1.0),),
                          max_context=8192, out_len_mix=OUT_LEN_MIX)
    specs = generate_trace(prof, n_req, seed=31)

    # unbounded tracking run: measures true peak residency and anchors
    # the budget sweep; bit-identical to a memory-less cluster
    rep0 = _cluster(cfg, spcfg, MemoryModel(capacity_bytes=None)).run(specs)
    peak = rep0.summary()["peak_resident_bytes"]
    rows = [_row("unbounded", None, None, rep0)]
    print(f"\n[memory] {n_req} Poisson long-decode requests, "
          f"unbounded peak residency {peak / 1e9:.2f} GB")

    acceptance = {}
    for frac in BUDGET_FRACS:
        budget = frac * peak
        for label, policy, mode in VARIANTS:
            rep = _cluster(cfg, spcfg,
                           MemoryModel(capacity_bytes=budget,
                                       policy=policy,
                                       reload=mode)).run(specs)
            rows.append(_row(label, frac, budget, rep))
        sweep = {r["config"]: r["goodput_tok_s"] for r in rows
                 if r["budget_frac"] == frac}
        planner_best = max(sweep[k] for k in
                           ("planner-lru", "planner-idle", "planner-bits"))
        ok = planner_best > sweep["restream-lru"] \
            and planner_best > sweep["recompute-lru"]
        acceptance[f"budget_{frac}"] = {
            "planner_best_tok_s": planner_best,
            "restream_tok_s": sweep["restream-lru"],
            "recompute_tok_s": sweep["recompute-lru"],
            "planner_wins": ok,
        }
        print(f"budget {frac:.2f}x peak: planner {planner_best:.2f} tok/s "
              f"vs restream {sweep['restream-lru']:.2f} / "
              f"recompute {sweep['recompute-lru']:.2f}"
              + ("  [acceptance met]" if ok else ""))

    print(table(rows, list(rows[0].keys()),
                title="\n[memory] goodput vs. memory budget"))
    save("kv_memory",
         {"rows": rows, "acceptance": acceptance,
          "peak_resident_bytes": peak,
          "budget_fracs": list(BUDGET_FRACS),
          "out_len_mix": list(OUT_LEN_MIX)},
         quick=quick)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
