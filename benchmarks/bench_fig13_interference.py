"""Fig. 13: robustness to wireless interference — TTFT under increasing
access-point congestion. SparKV's runtime controller migrates starved
streamed chunks to local compute.

Two congestion models:

  - scalar (default, paper-figure parity): each congestion level is a
    different ``NetworkProfile`` (mean bandwidth down, variance up) fed
    to isolated single-request engines;
  - structural (``--multi-device``): N devices each stream through their
    own NIC stage into one shared AP uplink (two-stage ``LinkTopology``)
    — congestion *emerges* from the fair-shared uplink instead of being
    dialed in, and the per-request uplink-share telemetry shows who got
    starved.
"""
from __future__ import annotations

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table


def _scalar_rows(quick: bool, seeds: int):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig()
    wl = synthesize(cfg, 12_288, DATASETS["longchat"])
    rows = []
    nets = ["campus-wifi", "congested-2dev", "congested-5dev"]
    for net_name in nets[:2] if quick else nets:
        net = NETWORKS[net_name]
        agg = {}
        for pol, fn in [("sparkv", B.run_sparkv),
                        ("sparkv_noadapt",
                         lambda *a, **k: B.run_sparkv(*a, adapt=False, **k)),
                        ("strong_hybrid", B.run_strong_hybrid),
                        ("cachegen", B.run_cachegen)]:
            ttfts = [fn(cfg, wl, "jetson-orin", net, spcfg, seed=s).ttft_s
                     for s in range(1 if quick else seeds)]
            agg[pol] = float(np.mean(ttfts))
        rows.append({
            "network": net_name, **{f"{k}_ttft": v for k, v in agg.items()},
            "vs_hybrid_x": agg["strong_hybrid"] / agg["sparkv"],
            "vs_cachegen_x": agg["cachegen"] / agg["sparkv"],
            "adapt_gain_x": agg["sparkv_noadapt"] / agg["sparkv"],
        })
    return rows, "\n[Fig 13] TTFT under wireless interference"


def _multi_device_rows(quick: bool):
    """Structural congestion: n devices, each loading one context through
    its NIC into the shared AP uplink; per-policy fleet TTFT + uplink
    share. The single-device row is the uncongested baseline. Each
    congestion level also runs as the three-hop cloud tree (two APs
    splitting the uplink crowd, one shared cloud-egress stage): the
    second AP relieves the last-metre contention until the egress trunk
    binds — the deeper-topology counterpart of the same study."""
    from repro.serving.cluster import RequestSpec, ServingCluster
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig(scheduler_mode="engine")
    ctx = 4096 if quick else 8192
    levels = [1, 2] if quick else [1, 2, 5]
    variants = [("two-stage", dict())]
    if not quick:
        variants.append(("three-hop", dict(n_aps=2,
                                           egress="cloud-egress")))
    rows = []
    for n_dev in levels:
        for topo, kw in (variants if n_dev > 1 else variants[:1]):
            row = {"n_devices": n_dev, "topology": topo}
            for pol in ("sparkv", "strong_hybrid", "cachegen"):
                specs = [RequestSpec(arrival_s=0.0, context_len=ctx,
                                     policy=pol, seed=i, device=i)
                         for i in range(n_dev)]
                rep = ServingCluster(cfg, spcfg, "jetson-orin",
                                     "campus-wifi",
                                     max_concurrency=n_dev,
                                     n_devices=n_dev, nic="device-nic",
                                     **kw).run(specs)
                s = rep.summary()
                row[f"{pol}_ttft"] = s["ttft_mean_s"]
                row[f"{pol}_share"] = s["uplink_share_p50"]
            row["vs_hybrid_x"] = row["strong_hybrid_ttft"] \
                / row["sparkv_ttft"]
            row["vs_cachegen_x"] = row["cachegen_ttft"] \
                / row["sparkv_ttft"]
            rows.append(row)
    return rows, ("\n[Fig 13] TTFT under AP congestion "
                  "(NIC -> uplink tree, and the three-hop cloud variant)")


def run(quick: bool = False, seeds: int = 3, multi_device: bool = False):
    if multi_device:
        rows, title = _multi_device_rows(quick)
    else:
        rows, title = _scalar_rows(quick, seeds)
    print(table(rows, list(rows[0].keys()), title=title))
    save("fig13_interference" + ("_multi_device" if multi_device else ""),
         {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--multi-device", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick, multi_device=a.multi_device)
