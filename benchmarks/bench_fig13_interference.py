"""Fig. 13: robustness to wireless interference — TTFT under increasing
access-point congestion (mean bandwidth down, variance up). SparKV's
runtime controller migrates starved streamed chunks to local compute."""
from __future__ import annotations

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table


def run(quick: bool = False, seeds: int = 3):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig()
    wl = synthesize(cfg, 12_288, DATASETS["longchat"])
    rows = []
    nets = ["campus-wifi", "congested-2dev", "congested-5dev"]
    for net_name in nets[:2] if quick else nets:
        net = NETWORKS[net_name]
        agg = {}
        for pol, fn in [("sparkv", B.run_sparkv),
                        ("sparkv_noadapt",
                         lambda *a, **k: B.run_sparkv(*a, adapt=False, **k)),
                        ("strong_hybrid", B.run_strong_hybrid),
                        ("cachegen", B.run_cachegen)]:
            ttfts = [fn(cfg, wl, "jetson-orin", net, spcfg, seed=s).ttft_s
                     for s in range(1 if quick else seeds)]
            agg[pol] = float(np.mean(ttfts))
        rows.append({
            "network": net_name, **{f"{k}_ttft": v for k, v in agg.items()},
            "vs_hybrid_x": agg["strong_hybrid"] / agg["sparkv"],
            "vs_cachegen_x": agg["cachegen"] / agg["sparkv"],
            "adapt_gain_x": agg["sparkv_noadapt"] / agg["sparkv"],
        })
    print(table(rows, list(rows[0].keys()),
                title="\n[Fig 13] TTFT under wireless interference"))
    save("fig13_interference", {"rows": rows})
    return rows


if __name__ == "__main__":
    run()
