"""Fig. 3: chunk-level sparse-attention latency heterogeneity — the
ground-truth latency spread across (t, l, h) chunks (paper: 0.13-2.3 ms,
a ~17.7x range)."""
from __future__ import annotations

import numpy as np

from repro.configs import get_config
from repro.core.costs import PROFILES, GroundTruthLatency
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    gt = GroundTruthLatency(PROFILES["jetson-orin"], cfg.resolved_head_dim)
    rng = np.random.default_rng(0)
    rows = []
    for sample in range(2 if quick else 3):
        wl = synthesize(cfg, 11_264, DATASETS["triviaqa"],
                        rng=np.random.default_rng(sample))
        lat = np.array([
            gt.attn_seconds(wl.active_blocks[t, l, h], 0.0, rng)
            for t in range(wl.n_t) for l in range(wl.n_l)
            for h in range(wl.n_h)]) * 1e3
        rows.append({
            "sample": sample,
            "min_ms": float(lat.min()), "p50_ms": float(np.median(lat)),
            "max_ms": float(lat.max()),
            "spread_x": float(lat.max() / lat.min()),
        })
    print(table(rows, list(rows[0].keys()),
                title="\n[Fig 3] chunk compute-latency heterogeneity "
                      "(TriviaQA-like)"))
    save("fig3_chunk_latency", {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    run()
