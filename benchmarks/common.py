"""Shared benchmark utilities: result recording + table printing.

Quick runs (``--quick``) are smoke tests on reduced workloads: their
numbers are not comparable to full runs, so :func:`save` routes them to
``results/benchmarks/quick/`` (git-ignored) — a quick run can never
clobber a checked-in full-run result. Every bench must pass its ``quick``
flag through to ``save`` (enforced by tests/test_benchmark_guard.py).
"""
from __future__ import annotations

import json
import os
import time

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")
QUICK_DIR = os.path.join(RESULTS_DIR, "quick")

# --profile (benchmarks/run.py): stamp a ``_profile`` block into every
# saved JSON with the bench's wall-clock and the simulator-throughput
# counters accumulated by ServingCluster.run since begin_bench().
PROFILE = False
_bench_t0: float | None = None


def begin_bench() -> None:
    """Mark the start of one bench module's run: reset the wall clock
    and the process-wide simulator event counters so the next
    :func:`save` snapshots only this bench's activity."""
    global _bench_t0
    _bench_t0 = time.time()
    try:
        from repro.serving.simcore import STATS
        STATS.reset()
    except ImportError:                         # src not on path
        pass


def _profile_snapshot() -> dict:
    prof: dict = {}
    if _bench_t0 is not None:
        prof["bench_wall_s"] = time.time() - _bench_t0
    try:
        from repro.serving.simcore import STATS
        prof.update(STATS.snapshot())
    except ImportError:
        pass
    return prof


def save(name: str, payload: dict, *, quick: bool = False):
    out_dir = QUICK_DIR if quick else RESULTS_DIR
    os.makedirs(out_dir, exist_ok=True)
    payload = dict(payload, _bench=name, _time=time.time(), _quick=quick)
    if PROFILE:
        payload["_profile"] = _profile_snapshot()
    path = os.path.join(out_dir, f"{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, default=str)
    return path


def table(rows: list[dict], cols: list[str], *, title: str = "",
          fmt: dict | None = None) -> str:
    fmt = fmt or {}
    widths = {c: max(len(c), *(len(_cell(r.get(c), fmt.get(c)))
                               for r in rows)) for c in cols}
    out = []
    if title:
        out.append(title)
    out.append("  ".join(c.ljust(widths[c]) for c in cols))
    out.append("  ".join("-" * widths[c] for c in cols))
    for r in rows:
        out.append("  ".join(
            _cell(r.get(c), fmt.get(c)).ljust(widths[c]) for c in cols))
    return "\n".join(out)


def _cell(v, f) -> str:
    if v is None:
        return "-"
    if f:
        return format(v, f)
    if isinstance(v, float):
        return f"{v:.3f}"
    return str(v)
