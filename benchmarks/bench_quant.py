"""Per-chunk adaptive quantization: quality-vs-TTFT Pareto under
overload.

Through PR 8 the quantization width was one number per request: the SLO
admission ladder and the memory server's ``bits`` eviction both traded
fidelity *uniformly* — every chunk of a victim paid the same downgrade,
including the handful of hot chunks that carry most of the attention
mass. This bench arms the per-chunk allocation stack end to end and
measures what chunk-granular fidelity buys:

  - **slo-overload Pareto** — a Poisson deadline fleet under moderate
    overload, served by (a) the uniform ladder: one fleet per base width
    in ``BITRATE_LEVELS`` with whole-request admission downgrades, and
    (b) per-chunk arms: a saliency-driven allocation schedule plus
    cold-chunk-only admission downgrades (``SLOPolicy.cold_frac``).
    Each arm reports saliency-weighted quality against TTFT — the
    per-chunk arms sit above the uniform ladder's quality/latency
    frontier;
  - **decode-overload memory** — a long-decode fleet over budget with
    ``bits`` eviction, sweeping ``MemoryModel.cold_frac``: downgrading
    only the cold share of a resident (vs the whole resident) frees
    memory at a smaller fidelity cost;
  - **uniform parity** — the default ``alloc_schedule="uniform"`` fleet
    and the armed-but-neutral ``"flat"`` fleet must report identical
    TTFT/byte traces: the per-chunk machinery is byte-exact when it
    allocates the base width everywhere.

Acceptance: at least one per-chunk arm Pareto-dominates at least one
uniform ladder point (weighted quality >= and p99 TTFT <=, one strict),
and the parity check holds bitwise.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.compression.quantize import BITRATE_LEVELS
from repro.configs import SparKVConfig, get_config
from repro.core.costs import MemoryModel, RunQueueModel
from repro.serving.cluster import ServingCluster
from repro.serving.decode import DecodeConfig
from repro.serving.slo import SLOPolicy
from repro.serving.traffic import TrafficProfile, generate_trace

from benchmarks.common import save, table

# (label, alloc_schedule, base_bits, SLOPolicy kwargs)
PARETO_ARMS = [
    *[(f"uniform@{b}", "flat", b, {}) for b in BITRATE_LEVELS],
    ("perchunk-att@5", "attention", 5, {"cold_frac": 0.6}),
    ("perchunk-agg@6", "aggressive", 6, {"cold_frac": 0.6}),
]

MEM_COLD_FRACS = (1.0, 0.5, 0.3)     # 1.0 = legacy whole-resident


def _spcfg(schedule: str, bits: int) -> SparKVConfig:
    return dataclasses.replace(SparKVConfig(scheduler_mode="engine"),
                               alloc_schedule=schedule, quant_bits=bits)


def _slo_specs(n_req: int):
    prof = TrafficProfile(rate_rps=1.1, arrival="poisson",
                          policy_mix=(("sparkv", 1.0),),
                          max_context=8192,
                          slo_mix=(("interactive", 6.0, 0.7),
                                   ("batch", None, 0.3)))
    return generate_trace(prof, n_req, seed=23)


def _slo_fleet(cfg, spcfg, specs, slo):
    return ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                          max_concurrency=6,
                          run_queue=RunQueueModel(1, "fifo"),
                          slo=slo).run(specs)


def _pareto_row(label, base_bits, rep) -> dict:
    s = rep.summary()
    qs = [r.quality for r in rep.records]
    return {
        "config": label,
        "base_bits": base_bits,
        "quality_mean": float(np.mean(qs)) if qs else None,
        "quality_min": float(min(qs)) if qs else None,
        "ttft_p99_s": s["ttft_p99_s"],
        "ttft_mean_s": s["ttft_mean_s"],
        "slo_attainment": s["slo_attainment"],
        "bytes_streamed_gb": sum(r.bytes_streamed
                                 for r in rep.records) / 1e9,
        "n_served": s["n_done"],
        "n_shed": s["n_shed"],
        "n_downgraded": s["n_downgraded"],
    }


def _dominates(a: dict, b: dict) -> bool:
    """Pareto dominance on (quality up, p99 TTFT down)."""
    if a["quality_mean"] is None or b["quality_mean"] is None:
        return False
    ge_q = a["quality_mean"] >= b["quality_mean"] - 1e-12
    le_t = a["ttft_p99_s"] <= b["ttft_p99_s"] + 1e-12
    strict = (a["quality_mean"] > b["quality_mean"] + 1e-9
              or a["ttft_p99_s"] < b["ttft_p99_s"] - 1e-9)
    return ge_q and le_t and strict


def _run_pareto(cfg, n_req: int):
    specs = _slo_specs(n_req)
    rows = []
    for label, schedule, bits, slo_kw in PARETO_ARMS:
        rep = _slo_fleet(cfg, _spcfg(schedule, bits), specs,
                         SLOPolicy(**slo_kw))
        rows.append(_pareto_row(label, bits, rep))
    uniform = [r for r in rows if r["config"].startswith("uniform")]
    perchunk = [r for r in rows if r["config"].startswith("perchunk")]
    wins = {p["config"]: [u["config"] for u in uniform
                          if _dominates(p, u)] for p in perchunk}
    return rows, wins


def _run_parity(cfg, n_req: int) -> dict:
    """uniform (disarmed) vs flat (armed, neutral): bitwise trace
    equality is the guarantee that the per-chunk stack costs nothing
    when it isn't asked for anything."""
    specs = _slo_specs(n_req)
    ru = _slo_fleet(cfg, _spcfg("uniform", 5), specs, SLOPolicy())
    rf = _slo_fleet(cfg, _spcfg("flat", 5), specs, SLOPolicy())
    ok = (len(ru.records) == len(rf.records)
          and all(a.ttft_s == b.ttft_s
                  and a.bytes_streamed == b.bytes_streamed
                  and a.energy_j == b.energy_j
                  for a, b in zip(ru.records, rf.records)))
    return {"bitwise_equal": ok, "n_records": len(ru.records)}


def _run_memory(cfg, n_req: int):
    spcfg = _spcfg("uniform", 5)
    prof = TrafficProfile(rate_rps=2.0, arrival="poisson",
                          policy_mix=(("sparkv", 1.0),),
                          max_context=8192,
                          out_len_mix=((192, 0.5), (384, 0.5)))
    specs = generate_trace(prof, n_req, seed=31)

    def cl(memory):
        return ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                              max_concurrency=8,
                              run_queue=RunQueueModel(1, "fifo"),
                              decode=DecodeConfig(max_batch=4),
                              memory=memory)

    peak = cl(MemoryModel(capacity_bytes=None)).run(specs) \
        .summary()["peak_resident_bytes"]
    # moderate pressure: deep budgets (<0.5x peak) push every resident
    # to the ladder floor regardless of pooling, masking what the cold
    # split preserves
    budget = 0.6 * peak
    rows = []
    for frac in MEM_COLD_FRACS:
        rep = cl(MemoryModel(capacity_bytes=budget, policy="bits",
                             cold_frac=frac)).run(specs)
        s = rep.summary()
        bits = [r.kv_bits for r in rep.records if r.kv_bits > 0]
        rows.append({
            "cold_frac": frac,
            # final resident width of the *hot* pool: cold-share
            # eviction concentrates the fidelity loss on the cold bytes,
            # so the width the decode actually reads stays higher
            "kv_bits_mean": float(np.mean(bits)) if bits else None,
            "goodput_tok_s": s["goodput_tok_s"],
            "tokens_out": s["tokens_out_total"],
            "ttlt_p99_s": s["ttlt_p99_s"],
            "n_evictions": s["n_evictions"],
            "n_downgrades": s["n_downgrades"],
            "n_reloads": s["n_reloads"],
            "reload_s_total": s["reload_s_total"],
        })
    return rows, peak, budget


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    n_req = 6 if quick else 14

    rows, wins = _run_pareto(cfg, n_req)
    print(table(rows, list(rows[0].keys()),
                title=f"\n[quant] slo-overload Pareto: {n_req} Poisson "
                      f"deadline requests"))
    dominated = sorted({u for us in wins.values() for u in us})
    ok_pareto = bool(dominated)
    for p, us in wins.items():
        print(f"{p} dominates: {', '.join(us) if us else '(none)'}")
    print("pareto acceptance " + ("met" if ok_pareto else "NOT met"))

    parity = _run_parity(cfg, n_req)
    print(f"uniform/flat parity: bitwise_equal={parity['bitwise_equal']} "
          f"over {parity['n_records']} records")

    mem_rows, peak, budget = _run_memory(cfg, max(4, n_req // 2))
    print(table(mem_rows, list(mem_rows[0].keys()),
                title=f"\n[quant] bits-eviction cold_frac sweep "
                      f"(budget {budget / 1e9:.2f} GB = 0.6x peak)"))
    by_frac = {r["cold_frac"]: r for r in mem_rows}
    ok_mem = (by_frac[0.5]["kv_bits_mean"] or 0) >= \
        (by_frac[1.0]["kv_bits_mean"] or 0)
    print(f"cold-pool fidelity: kv_bits {by_frac[1.0]['kv_bits_mean']:.2f}"
          f" (whole) -> {by_frac[0.5]['kv_bits_mean']:.2f} (cold 0.5)"
          + ("  [retained]" if ok_mem else ""))

    save("quant",
         {"rows": rows,
          "pareto": {"wins": wins, "dominated_uniform": dominated,
                     "acceptance_met": ok_pareto},
          "parity": parity,
          "memory": {"rows": mem_rows,
                     "peak_resident_bytes": peak,
                     "budget_bytes": budget,
                     "cold_fracs": list(MEM_COLD_FRACS),
                     "fidelity_retained": ok_mem},
          "arms": [list(a[:3]) for a in PARETO_ARMS]},
         quick=quick)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    a = ap.parse_args()
    run(quick=a.quick)
