"""Figs. 9-12: overall TTFT + response quality, SparKV vs baselines.

Fig. 9: across datasets on the laptop profile (Llama-class model);
Fig. 10: Jetson AGX; Fig. 11: across LLM scales; Fig. 12: VLM workloads
(videomme — higher chunk heterogeneity). Select with `scenario`.
"""
from __future__ import annotations

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.costs import NETWORKS
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table

SCENARIOS = {
    "fig9_laptop": {
        "profile": "laptop-5080", "arch": "phi3-medium-14b",
        "datasets": ["repobench-p", "hotpotqa", "triviaqa", "longchat",
                     "govreport", "narrativeqa"],
    },
    "fig10_jetson": {
        "profile": "jetson-agx", "arch": "phi3-medium-14b",
        "datasets": ["triviaqa", "longchat", "narrativeqa"],
    },
    "fig11_llms": {
        "profile": "laptop-5080", "arch": None,   # sweeps archs
        "datasets": ["hotpotqa"],
        "archs": ["sparkv-qwen3-4b", "phi3-medium-14b"],
    },
    "fig12_vlms": {
        "profile": "laptop-5080", "arch": "sparkv-qwen3-4b",
        "datasets": ["videomme"],
    },
}

POLICIES = ["sparkv", "strong_hybrid", "cachegen", "local_prefill"]


def run(quick: bool = False, scenario: str = "fig9_laptop",
        seeds: int = 2):
    sc = SCENARIOS[scenario]
    spcfg = SparKVConfig()
    net = NETWORKS["wifi6-cloud"]
    archs = sc.get("archs") or [sc["arch"]]
    datasets = sc["datasets"][:3] if quick else sc["datasets"]
    rows = []
    for arch in archs:
        cfg = get_config(arch)
        for ds in datasets:
            prof_ds = DATASETS[ds]
            ctx = min(prof_ds.mean_len, 16_384) // 1024 * 1024
            res = {}
            for pol in POLICIES:
                ttfts, es, qs = [], [], []
                for s in range(1 if quick else seeds):
                    wl = synthesize(cfg, ctx, prof_ds,
                                    rng=np.random.default_rng(
                                        prof_ds.seed * 131 + s))
                    r = B.PIPELINES[pol](cfg, wl, sc["profile"], net,
                                         spcfg, seed=s)
                    ttfts.append(r.ttft_s)
                    es.append(r.energy_j)
                    qs.append(r.quality)
                res[pol] = (np.mean(ttfts), np.mean(es), np.mean(qs))
            row = {"arch": arch, "dataset": ds, "ctx": ctx}
            for pol in POLICIES:
                row[f"{pol}_ttft"] = res[pol][0]
                row[f"{pol}_q"] = res[pol][2]
            row["vs_hybrid_x"] = res["strong_hybrid"][0] / res["sparkv"][0]
            row["vs_cachegen_x"] = res["cachegen"][0] / res["sparkv"][0]
            row["vs_local_x"] = res["local_prefill"][0] / res["sparkv"][0]
            rows.append(row)
    cols = (["arch", "dataset", "ctx"]
            + [f"{p}_ttft" for p in POLICIES]
            + ["sparkv_q", "cachegen_q",
               "vs_hybrid_x", "vs_cachegen_x", "vs_local_x"])
    print(table(rows, cols,
                title=f"\n[{scenario}] TTFT (s) + quality, "
                      f"SparKV vs baselines"))
    save(scenario, {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    import sys
    for sc in (sys.argv[1:] or ["fig9_laptop"]):
        run(scenario=sc)
