"""Fleet traffic: N concurrent requests under Poisson/bursty arrivals.

Runs the multi-request serving cluster for each policy and reports fleet
metrics: p50/p99 TTFT, goodput, energy per request, migrations, plus the
per-request device queue-wait and uplink-share breakdowns from the
resource-server layer. ``--discipline fifo|wfq`` switches the device
server from the legacy closed-loop dilation to the explicit run queue.
Also checks the regressions the subsystem exists to express:

  - link contention: aggregate per-request stream time under concurrency
    exceeds the single-request stream time;
  - closed-loop contention: migration counts differ from the static-util
    path (the controller reacts to *actual* in-flight compute);
  - discipline sensitivity: FIFO and WFQ fleets report different tails
    for a weighted interactive class.
"""
from __future__ import annotations

from repro.configs import SparKVConfig, get_config
from repro.core.costs import RunQueueModel
from repro.serving.cluster import ServingCluster
from repro.serving.traffic import TrafficProfile, generate_trace

from benchmarks.common import save, table

POLICIES = ["sparkv", "strong_hybrid", "local_prefill"]


def run(quick: bool = False, discipline: str | None = None):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig(scheduler_mode="engine")
    n_req = 8 if quick else 16
    rate = 1.0 if quick else 0.8
    max_ctx = 4096 if quick else 8192
    run_queue = RunQueueModel(2, discipline) if discipline else None
    mode = f"run-queue/{discipline}" if discipline else "closed-loop"
    rows = []
    contention = {}
    for policy in POLICIES:
        prof = TrafficProfile(rate_rps=rate, arrival="poisson",
                              context_mix=(("longchat", 1.0),),
                              policy_mix=((policy, 1.0),),
                              max_context=max_ctx,
                              weight_mix=((1.0, 0.5), (8.0, 0.5)))
        specs = generate_trace(prof, n_req, seed=7)
        cluster = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                                 max_concurrency=8, closed_loop=True,
                                 run_queue=run_queue)
        rep = cluster.run(specs)
        s = rep.summary()
        # single-request baseline on the same trace for the contention check
        solo = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                              max_concurrency=8, closed_loop=True,
                              run_queue=run_queue).run(specs[:1])
        per_req_stream = s["stream_busy_total_s"] / max(s["n_done"], 1)
        contention[policy] = {
            "fleet_stream_per_req_s": per_req_stream,
            "solo_stream_s": solo.records[0].stream_busy_s,
        }
        rows.append({
            "policy": policy,
            "n": s["n_done"],
            "ttft_p50_s": s["ttft_p50_s"],
            "ttft_p99_s": s["ttft_p99_s"],
            "goodput_rps": s["goodput_rps"],
            "J_per_req": s["energy_per_req_j"],
            "migrations": s["migrations_total"],
            "queue_mean_s": s["queue_mean_s"],
            "qwait_p50_s": s["queue_wait_p50_s"],
            "qwait_p99_s": s["queue_wait_p99_s"],
            "uplink_share_p50": s["uplink_share_p50"],
        })
    print(table(rows, list(rows[0].keys()),
                title=f"\n[fleet] {n_req} Poisson requests, shared link + "
                      f"{mode} contention"))

    # closed-loop vs static-util migration comparison (sparkv only)
    prof = TrafficProfile(rate_rps=rate, arrival="poisson",
                          policy_mix=(("sparkv", 1.0),),
                          max_context=max_ctx)
    specs = generate_trace(prof, n_req, seed=7)
    migr = {}
    for mode, kw in [("closed_loop", dict(closed_loop=True)),
                     ("static_util0", dict(closed_loop=False,
                                           static_util=0.0))]:
        rep = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                             max_concurrency=8, **kw).run(specs)
        migr[mode] = rep.summary()["migrations_total"]
    print(f"\nmigrations closed-loop={migr['closed_loop']} "
          f"vs static util=0: {migr['static_util0']}")
    for pol, c in contention.items():
        print(f"stream-time {pol}: fleet/req {c['fleet_stream_per_req_s']:.3f}s"
              f" vs solo {c['solo_stream_s']:.3f}s")

    save("fleet_traffic" + (f"_{discipline}" if discipline else ""),
         {"rows": rows, "contention": contention, "migrations": migr},
         quick=quick)
    return rows


if __name__ == "__main__":
    import argparse
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true")
    ap.add_argument("--discipline", choices=("fifo", "wfq"), default=None,
                    help="use the explicit device run queue instead of "
                         "closed-loop utilization coupling")
    a = ap.parse_args()
    run(quick=a.quick, discipline=a.discipline)
