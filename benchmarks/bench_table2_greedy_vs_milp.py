"""Table II: potential-aware greedy vs exact MILP (branch & bound over the
in-repo simplex) — scheduling runtime and resulting TTFT.

Exact MILP solving scales poorly (the paper's point), so the exact column
runs on reduced grids; greedy runs on both the reduced and full grids.
"""
from __future__ import annotations

import time

import numpy as np

from repro.configs import SparKVConfig, get_config
from repro.core import baselines as B
from repro.core.chunks import ChunkGrid
from repro.core.costs import NETWORKS, PROFILES, t_stream
from repro.core.milp import MILPProblem, solve_bnb
from repro.core.scheduler import GreedyScheduler
from repro.data.workloads import DATASETS, synthesize

from benchmarks.common import save, table


def _small_instance(cfg, wl, net, spcfg, n_t, n_l):
    """Aggregate a workload down to an (n_t, n_l) grid for the oracle."""
    grid = ChunkGrid(n_t, n_l, 1)
    tt = np.linspace(0, wl.n_t, n_t + 1, dtype=int)
    ll = np.linspace(0, wl.n_l, n_l + 1, dtype=int)
    prof = PROFILES["jetson-orin"]
    ts = np.zeros(grid.size)
    tc = np.zeros(grid.size)
    from repro.core.baselines import _predictor_cache
    pred = _predictor_cache(cfg, "jetson-orin")
    for i, c in enumerate(grid.chunks()):
        byts = wl.chunk_bytes[tt[c.t]:tt[c.t + 1],
                              ll[c.l]:ll[c.l + 1]].sum()
        act = wl.active_blocks[tt[c.t]:tt[c.t + 1],
                               ll[c.l]:ll[c.l + 1]].sum()
        ts[i] = t_stream(byts, net.mean_bw, prof)
        tc[i] = float(pred.t_comp_batch(
            np.array([float(c.t)]), np.array([c.l if c.l < n_l - 1 else 0]),
            np.array([act]), 0.0)[0])
    return grid, ts, tc


def run(quick: bool = False):
    cfg = get_config("sparkv-qwen3-4b")
    spcfg = SparKVConfig()
    net = NETWORKS["campus-wifi"]
    rows = []
    cases = [("longchat", 10_240), ("videomme", 10_240)]
    if not quick:
        cases += [("longchat", 20_480), ("videomme", 20_480)]
    for ds, ctx in cases:
        wl = synthesize(cfg, ctx, DATASETS[ds])
        # --- exact oracle on the reduced grid ---
        grid, ts, tc = _small_instance(cfg, wl, net, spcfg,
                                       n_t=3, n_l=3)
        prob = MILPProblem(grid, ts, tc, n_stages=3)
        t0 = time.time()
        greedy = GreedyScheduler(grid, ts, tc,
                                 stage_budget_s=max(ts.sum(), tc.sum())
                                 / 3).run()
        t_greedy = time.time() - t0
        t0 = time.time()
        exact = solve_bnb(prob, incumbent=greedy.makespan * 1.001,
                          max_nodes=1500)
        t_exact = time.time() - t0
        # --- greedy TTFT on the full engine ---
        res = B.run_sparkv(cfg, wl, "jetson-orin", net, spcfg, seed=0,
                           adapt=False)
        rows.append({
            "dataset": ds, "ctx": ctx,
            "greedy_runtime_s": t_greedy,
            "exact_runtime_s": t_exact,
            "speedup": t_exact / max(t_greedy, 1e-9),
            "greedy_makespan_s": greedy.makespan,
            "exact_makespan_s": exact.objective,
            "gap": (greedy.makespan - exact.objective)
            / max(exact.objective, 1e-9),
            "engine_ttft_s": res.ttft_s,
            "bnb_nodes": exact.nodes,
        })
    print(table(rows, list(rows[0].keys()),
                title="\n[Table II] greedy heuristic vs exact MILP "
                      "(reduced oracle grids)"))
    save("table2_greedy_vs_milp", {"rows": rows}, quick=quick)
    return rows


if __name__ == "__main__":
    run()
