#!/usr/bin/env python3
"""Perf regression gate for quick-run benchmarks (stdlib only).

Compares a quick-run benchmark JSON (``results/benchmarks/quick/``)
against tolerance bands derived from the matching checked-in full-run
JSON (``results/benchmarks/``). Quick runs shrink the workload and CI
machines vary, so the bands are *scale-free where possible* (boolean
acceptance flags, relational metrics) and deliberately wide where a
machine-dependent throughput is all we have — the gate exists to catch
order-of-magnitude regressions (an accidentally quadratic event loop, a
dead reuse layer, a rebalancer that stopped beating static placement),
not single-digit-percent noise.

  python tools/bench_gate.py simcore decode reuse hostile
  python tools/bench_gate.py --list

Check kinds (see GATES):
  bool   — the quick run's acceptance flag at `path` must be true
  ratio  — quick[path] / full[ref or path] must lie in [min, max]
  lt     — quick[path] must be strictly below quick[other]
  gt     — quick[path] must exceed `floor` (default 0)

Exits non-zero listing every violated band. A missing quick JSON is an
error (the smoke step did not run); a missing *full* JSON skips ratio
checks with a warning (new benches land their full run in the same PR,
but the gate must not force ordering within it).
"""
from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
FULL_DIR = REPO / "results" / "benchmarks"
QUICK_DIR = FULL_DIR / "quick"

# bench name -> saved JSON stem (benchmarks.common.save slug)
STEM = {
    "simcore": "simcore",
    "decode": "decode_goodput",
    "reuse": "reuse",
    "hostile": "hostile",
}

GATES: dict[str, list[dict]] = {
    "simcore": [
        # the vectorized core must still beat the scalar reference 10x
        # even at quick scale
        {"kind": "bool", "path": "acceptance/meets_10x"},
        # machine-dependent events/s: only an order-of-magnitude
        # collapse (e.g. the event queue going quadratic) trips this
        {
            "kind": "ratio",
            "path": "_profile/sim_events_per_s",
            "min": 0.02,
            "max": 100.0,
        },
    ],
    "decode": [
        {"kind": "bool", "path": "acceptance/compute-bound/continuous_wins"},
        {"kind": "bool", "path": "acceptance/stream-bound/continuous_wins"},
        # simulated (machine-independent) goodput, workload-scaled:
        # quick runs land within a few x of the full run
        {
            "kind": "ratio",
            "path": "acceptance/compute-bound/continuous_tok_s",
            "min": 0.25,
            "max": 4.0,
        },
        {
            "kind": "ratio",
            "path": "acceptance/stream-bound/continuous_tok_s",
            "min": 0.25,
            "max": 4.0,
        },
    ],
    "reuse": [
        {"kind": "bool", "path": "acceptance/zero_overlap_parity"},
        # the store must still see hits and still cut egress at quick
        # scale (goodput may not separate on tiny request counts)
        {"kind": "gt", "path": "acceptance/store_hit_rate", "floor": 0.0},
        {
            "kind": "lt",
            "path": "acceptance/store_egress_gb",
            "other": "acceptance/no_reuse_egress_gb",
        },
    ],
    "hostile": [
        {"kind": "bool", "path": "acceptance/calm_parity_vectorized"},
        {"kind": "bool", "path": "acceptance/calm_parity_scalar"},
        {"kind": "bool", "path": "acceptance/rebalancer_beats_static"},
        {"kind": "bool", "path": "acceptance/rebalancer_no_worse_hostile"},
    ],
}


def _lookup(doc: dict, path: str):
    node = doc
    for key in path.split("/"):
        if not isinstance(node, dict) or key not in node:
            return None
        node = node[key]
    return node


def check_bench(name: str) -> list[str]:
    """Returns human-readable violation messages for one bench."""
    stem = STEM.get(name, name)
    quick_path = QUICK_DIR / f"{stem}.json"
    if not quick_path.exists():
        return [f"{name}: quick result missing ({quick_path})"]
    quick = json.loads(quick_path.read_text())
    full_path = FULL_DIR / f"{stem}.json"
    full = json.loads(full_path.read_text()) if full_path.exists() else None

    errs = []
    for spec in GATES[name]:
        path = spec["path"]
        got = _lookup(quick, path)
        kind = spec["kind"]
        if kind == "bool":
            if got is not True:
                errs.append(f"{name}: {path} is {got!r}, expected true")
        elif kind == "gt":
            floor = spec.get("floor", 0.0)
            if not (isinstance(got, (int, float)) and got > floor):
                errs.append(f"{name}: {path} = {got!r}, expected > {floor}")
        elif kind == "lt":
            other = _lookup(quick, spec["other"])
            ok = (
                isinstance(got, (int, float))
                and isinstance(other, (int, float))
                and got < other
            )
            if not ok:
                errs.append(
                    f"{name}: expected {path} ({got!r}) < "
                    f"{spec['other']} ({other!r})"
                )
        elif kind == "ratio":
            if full is None:
                print(
                    f"  [warn] {name}: no full-run JSON at {full_path}; "
                    f"skipping ratio band on {path}"
                )
                continue
            ref = _lookup(full, spec.get("ref", path))
            if not isinstance(got, (int, float)) or not isinstance(
                ref, (int, float)
            ):
                errs.append(
                    f"{name}: {path} unavailable (quick={got!r}, "
                    f"full={ref!r})"
                )
                continue
            if ref <= 0:
                errs.append(f"{name}: full-run {path} = {ref!r}, not > 0")
                continue
            ratio = got / ref
            if not (spec["min"] <= ratio <= spec["max"]):
                errs.append(
                    f"{name}: {path} quick/full ratio {ratio:.3g} "
                    f"outside [{spec['min']}, {spec['max']}] "
                    f"(quick={got:.6g}, full={ref:.6g})"
                )
        else:  # pragma: no cover - spec typo guard
            errs.append(f"{name}: unknown check kind {kind!r}")
    return errs


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("benches", nargs="*", help="gated bench names")
    ap.add_argument(
        "--list", action="store_true", help="print gated bench names"
    )
    args = ap.parse_args()
    if args.list:
        print("\n".join(sorted(GATES)))
        return 0
    names = args.benches or sorted(GATES)
    failures = []
    for name in names:
        if name not in GATES:
            # ungated benches pass through: every smoke step can call
            # the gate unconditionally
            print(f"  [gate] {name}: no bands registered, skipping")
            continue
        errs = check_bench(name)
        if errs:
            failures.extend(errs)
        else:
            print(f"  [gate] {name}: OK ({len(GATES[name])} bands)")
    if failures:
        print("\nbench gate FAILED:", file=sys.stderr)
        for msg in failures:
            print(f"  {msg}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
