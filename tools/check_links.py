#!/usr/bin/env python3
"""Markdown link checker for the docs CI job (stdlib only).

Every relative link target in the given markdown files must resolve to
an existing file or directory (URL fragments are stripped; http(s)/
mailto/anchor-only links are skipped). Exits non-zero listing every
broken link.

  python tools/check_links.py README.md docs/*.md
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

# inline links [text](target) — tolerates titles: (target "title").
# Targets with spaces / unescaped parens aren't parsed; use %20.
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
# reference-style definitions: [label]: target
REF_DEF_RE = re.compile(r"^\s*\[[^\]]+\]:\s+(\S+)", re.M)
SKIP_PREFIXES = ("http://", "https://", "mailto:", "#")


def check_file(md: Path) -> tuple[int, list[str]]:
    """Returns (links checked, broken-link messages) for one file."""
    broken = []
    text = md.read_text(encoding="utf-8")
    # strip fenced code blocks so shell snippets aren't parsed for links
    text = re.sub(r"```.*?```", "", text, flags=re.S)
    targets = LINK_RE.findall(text) + REF_DEF_RE.findall(text)
    for target in targets:
        if target.startswith(SKIP_PREFIXES):
            continue
        path = target.split("#", 1)[0]
        if not path:
            continue
        resolved = (md.parent / path).resolve()
        if not resolved.exists():
            broken.append(f"{md}: broken link -> {target}")
    return len(targets), broken


def main(argv: list[str]) -> int:
    files = [Path(a) for a in argv]
    if not files:
        print("usage: check_links.py FILE.md [FILE.md ...]")
        return 2
    missing = [str(f) for f in files if not f.is_file()]
    if missing:
        print("not a file: " + ", ".join(missing))
        return 2
    n_links = 0
    broken: list[str] = []
    for f in files:
        n, b = check_file(f)
        n_links += n
        broken.extend(b)
    for b in broken:
        print(b)
    print(
        f"checked {len(files)} files, {n_links} links, "
        f"{len(broken)} broken"
    )
    return 1 if broken else 0


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
