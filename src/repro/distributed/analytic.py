"""Analytic per-device HBM-traffic model for the roofline memory term.

Neither HLO source is honest about HBM traffic:
  - the production compile's "bytes accessed" under-counts scanned layers
    (a scan body is costed once), and
  - the calibration compile (inner scans disabled so flops are exact)
    materializes full S x S attention scores the production flash path
    never writes, over-counting bytes 10-50x.
XLA bytes-accessed also ignores fusion: every intermediate is charged.

So the memory term uses a documented analytic model (napkin-roofline
standard), per device, per step:

 train:    W x (fwd read + bwd read)            = 2 Wb
           grads (write + read)                 = 2 Wb
           Adam moments m,v (read + write) + p write
                                                = (4 Wm + 1 Wb)
           layer-boundary activations: save fwd + read bwd + recompute
             writes/reads under full remat      ~ 6 x A
           attention KV streaming through VMEM  ~ 2 x KV
 prefill:  W read + 2 x A + KV write
 decode:   W read + KV cache read + tail r/w (per step)

 W  = param bytes (bf16) / chips  (fully sharded: FSDP x TP)
 Wm = moment bytes / chips
 A  = layers x tokens_local x d_model x 2B   (tokens sharded over data,
      and over model too when inter-block activations are SP-sharded)
 KV = context KV bytes / chips
MoE: all expert weights participate in the capacity-buffer matmuls, so W
is the full (not active) parameter set; activations use d_model.
"""
from __future__ import annotations

from repro.configs.base import ModelConfig, ShapeConfig


def _dtype_bytes(name: str) -> int:
    return {"bfloat16": 2, "float32": 4, "float16": 2}[name]


def analytic_bytes(cfg: ModelConfig, shape: ShapeConfig,
                   chips: int = 256) -> dict:
    pb = _dtype_bytes(cfg.param_dtype)
    mb = _dtype_bytes(cfg.moment_dtype)
    W = cfg.param_count() * pb / chips
    Wm = cfg.param_count() * mb / chips

    d = cfg.d_model
    tokens_local = shape.global_batch * shape.seq_len / chips
    if cfg.family == "encdec":
        tokens_local = shape.global_batch * (shape.seq_len
                                             + cfg.dec_len) / chips
    layers = cfg.num_layers + cfg.dec_layers
    A = layers * tokens_local * d * 2

    if cfg.num_kv_heads:
        per_layer_kv = (2 * shape.global_batch * shape.seq_len
                        * cfg.num_kv_heads * cfg.resolved_head_dim * 2)
        n_attn = (cfg.num_layers // cfg.attn_every
                  if cfg.family == "hybrid" else layers)
        kv_global = per_layer_kv * n_attn
    else:
        d_inner = cfg.ssm.expand * d
        nheads = d_inner // cfg.ssm.head_dim
        kv_global = (cfg.num_layers * shape.global_batch * nheads
                     * cfg.ssm.head_dim * cfg.ssm.state_dim * 4)
    KV = kv_global / chips

    if shape.kind == "train":
        total = 2 * W + 2 * W + (4 * Wm + W) + 6 * A + 2 * KV
        parts = {"weights": 4 * W, "optimizer": 4 * Wm + W,
                 "activations": 6 * A, "kv": 2 * KV}
    elif shape.kind == "prefill":
        total = W + 2 * A + KV
        parts = {"weights": W, "activations": 2 * A, "kv": KV}
    else:  # decode: one token over the full cache
        A1 = layers * (shape.global_batch / chips) * d * 2
        total = W + KV + 4 * A1
        parts = {"weights": W, "kv_read": KV, "activations": 4 * A1}
    return {"bytes_per_dev": total, "parts": parts}
