"""Logical-axis sharding resolver with divisibility-aware fallback.

Models annotate every parameter / activation dim with a *logical* name
("embed", "heads", "mlp", ...). A :class:`Rules` object maps logical names to
an ordered tuple of mesh axes; at resolution time the longest prefix of that
tuple whose size product divides the dim is used (otherwise the dim is
replicated). This gives one uniform recipe that survives awkward published
configs (e.g. phi3's 40 heads on a 16-wide model axis → heads replicated,
sequence-parallel attention instead).

The rules are carried in a contextvar so pure-functional model code can call
``constrain(x, *names)`` without threading the mesh everywhere. Outside a
rules context, ``constrain`` is a no-op (single-device smoke tests).
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
from typing import Optional, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class Rules:
    mesh: Mesh
    table: dict[str, tuple[str, ...]]
    weight_stationary: bool = False
    # logical names that were requested but fell back to replication
    # (filled lazily; dict for mutation despite frozen dataclass)
    fallbacks: dict = dataclasses.field(default_factory=dict)

    def axis_size(self, axes: Sequence[str]) -> int:
        s = 1
        for a in axes:
            s *= self.mesh.shape[a]
        return s

    def resolve(self, name: Optional[str], size: int,
                used: set[str]) -> Optional[tuple[str, ...]]:
        """Longest prefix of the candidate axes that divides `size` and does
        not collide with axes already used by other dims of this tensor."""
        if name is None:
            return None
        cand = self.table.get(name, ())
        best: tuple[str, ...] = ()
        for i in range(len(cand), 0, -1):
            prefix = cand[:i]
            if any(a in used for a in prefix):
                continue
            if size % self.axis_size(prefix) == 0 and self.axis_size(prefix) > 1:
                best = prefix
                break
        if not best:
            if cand:
                self.fallbacks.setdefault(name, size)
            return None
        return best

    def spec(self, names: Sequence[Optional[str]],
             shape: Sequence[int]) -> P:
        assert len(names) == len(shape), (names, shape)
        used: set[str] = set()
        parts = []
        for n, s in zip(names, shape):
            r = self.resolve(n, s, used)
            if r is None:
                parts.append(None)
            else:
                used.update(r)
                parts.append(r if len(r) > 1 else r[0])
        return P(*parts)

    def sharding(self, names: Sequence[Optional[str]],
                 shape: Sequence[int]) -> NamedSharding:
        return NamedSharding(self.mesh, self.spec(names, shape))


_RULES: contextvars.ContextVar[Optional[Rules]] = contextvars.ContextVar(
    "repro_sharding_rules", default=None)


@contextlib.contextmanager
def use_rules(rules: Optional[Rules]):
    tok = _RULES.set(rules)
    try:
        yield rules
    finally:
        _RULES.reset(tok)


def current_rules() -> Optional[Rules]:
    return _RULES.get()


def constrain(x: jax.Array, *names: Optional[str]) -> jax.Array:
    """with_sharding_constraint by logical dim names; no-op without rules."""
    rules = _RULES.get()
    if rules is None:
        return x
    spec = rules.spec(list(names), x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def make_rules(cfg, mesh: Mesh, *, sp_activations: bool = False,
               weight_stationary: bool = False) -> Rules:
    """Build the per-arch logical→mesh table.

    Decisions (see DESIGN.md §4):
      - heads/kv_heads/mlp/experts prefer the "model" axis (tensor/expert
        parallelism); divisibility fallback handles awkward head counts.
      - when q-heads do NOT divide the model axis, attention falls back to
        sequence parallelism: the "seq" logical axis maps to "model".
      - batch maps to ("pod", "data"); FSDP ("embed_fsdp") to ("pod", "data").
      - `sp_activations`: additionally shard inter-block activations by seq
        (Megatron-SP; a §Perf lever) — only meaningful with head-sharded attn.
    """
    axes = mesh.axis_names
    data_axes = tuple(a for a in ("pod", "data") if a in axes)
    model_ax = ("model",) if "model" in axes else ()

    msize = mesh.shape["model"] if "model" in axes else 1
    heads_shardable = cfg.num_heads > 0 and cfg.num_heads % max(msize, 1) == 0
    kv_shardable = (cfg.num_kv_heads > 0
                    and cfg.num_kv_heads % max(msize, 1) == 0)
    experts_shardable = (cfg.moe is not None
                         and cfg.moe.num_experts % max(msize, 1) == 0)

    # weight-stationary (decode/serving) layout: weights never travel —
    # the per-step FSDP all-gather of read-only weights dominates decode
    # collectives (measured 27 GB/device/step on qwen3-moe-235b decode_32k,
    # EXPERIMENTS.md §Perf). Instead the d_ff/expert dims spread over
    # model x data and the (tiny at decode) partial activations psum.
    if weight_stationary and cfg.moe is not None:
        # MoE decode: expert weights keep an f@data shard and never move;
        # the token batch (tiny at decode) is gathered instead. Measured
        # 39.9x lower collective wire on qwen3-moe-235b decode_32k. For
        # dense archs both alternatives measured worse overall (d@model
        # psums: 2.9x more wire on phi3; full replication: +14 GiB HBM),
        # so dense decode keeps the FSDP layout — see EXPERIMENTS.md.
        fsdp_axes: tuple = ()
        mlp_axes = data_axes if experts_shardable else model_ax + data_axes
    else:
        fsdp_axes = data_axes
        mlp_axes = () if experts_shardable else model_ax

    table: dict[str, tuple[str, ...]] = {
        "batch": data_axes,
        "vocab": model_ax,
        "embed": (),                 # weight embed dim: see embed_fsdp
        "embed_fsdp": fsdp_axes,     # FSDP shard of weight embed dims
        "heads": model_ax if heads_shardable else (),
        "kv_heads": model_ax if kv_shardable else (),
        "head_dim": (),
        "mlp": mlp_axes,
        "experts": model_ax if experts_shardable else (),
        "expert_cap": data_axes,     # MoE dispatch-buffer capacity dim
        "ssm_pdim": model_ax,        # mamba head_dim channels
        "ssm_heads": (),
        "state": (),
        "conv": (),
        "layers": (),
        # activations
        "seq": () if heads_shardable else model_ax,
        # residual-stream seq dim between blocks (Megatron-SP lever)
        "block_seq": model_ax if (sp_activations or not heads_shardable) else (),
        "act_heads": model_ax if heads_shardable else (),
        "act_kv": model_ax if kv_shardable else (),
        "act_mlp": () if experts_shardable else model_ax,
        "act_vocab": model_ax,
        # KV-cache seq dim: shard over model whenever kv heads cannot —
        # decode attention over a seq-sharded cache is the flash-decoding
        # split-K pattern (GSPMD inserts the softmax-stat all-reduce).
        "kv_seq": () if kv_shardable else model_ax,
    }
    return Rules(mesh=mesh, table=table,
                 weight_stationary=weight_stationary)
