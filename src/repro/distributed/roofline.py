"""Roofline-term extraction from compiled XLA artifacts.

cost_analysis() gives per-device HLO flops/bytes; collective traffic is NOT
in cost_analysis, so we parse the optimized HLO text, classify every
collective op, read its result shape + replica_groups, and model per-device
wire bytes with standard ring-algorithm formulas:

    all-gather       out * (N-1)/N
    reduce-scatter   out * (N-1)
    all-reduce       2 * bytes * (N-1)/N      (RS + AG)
    all-to-all       bytes * (N-1)/N
    collective-permute  bytes

Hardware model (TPU v5e-class, per chip): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.
"""
from __future__ import annotations

import dataclasses
import re
from collections import defaultdict

PEAK_FLOPS = 197e12       # bf16 / chip
HBM_BW = 819e9            # bytes/s / chip
LINK_BW = 50e9            # bytes/s / link

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?P<rtype>\([^)]*\)|\S+)\s+"
    r"(?P<kind>all-reduce|all-gather|reduce-scatter|all-to-all|"
    r"collective-permute)(?P<start>-start)?\(")
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_GROUPS_BRACE_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(text: str) -> list[int]:
    out = []
    for dt, dims, in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        out.append(n * _DTYPE_BYTES[dt])
    return out


def _group_size(line: str) -> int:
    m = _GROUPS_IOTA_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_BRACE_RE.search(line)
    if m:
        return len(m.group(1).split(","))
    return 1


@dataclasses.dataclass
class CollectiveStats:
    wire_bytes: float = 0.0            # per-device, modeled
    payload_bytes: float = 0.0         # per-device result-shape bytes
    count: int = 0
    by_kind: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: [0, 0.0]))

    def as_dict(self):
        return {
            "wire_bytes": self.wire_bytes,
            "payload_bytes": self.payload_bytes,
            "count": self.count,
            "by_kind": {k: {"count": c, "wire_bytes": b}
                        for k, (c, b) in self.by_kind.items()},
        }


def parse_collectives(hlo_text: str) -> CollectiveStats:
    stats = CollectiveStats()
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        kind = m.group("kind")
        sizes = _shape_bytes(m.group("rtype"))
        if not sizes:
            continue
        n = _group_size(line)
        if n <= 1:
            continue
        out_bytes = max(sizes)      # -start tuples: (operand, result)
        res_bytes = sizes[-1] if kind != "all-gather" else max(sizes)
        if kind == "all-gather":
            wire = out_bytes * (n - 1) / n
        elif kind == "reduce-scatter":
            wire = min(sizes) * (n - 1)
        elif kind == "all-reduce":
            wire = 2 * out_bytes * (n - 1) / n
        elif kind == "all-to-all":
            wire = out_bytes * (n - 1) / n
        else:  # collective-permute
            wire = out_bytes
        stats.wire_bytes += wire
        stats.payload_bytes += res_bytes
        stats.count += 1
        ent = stats.by_kind[kind]
        ent[0] += 1
        ent[1] += wire
    return stats


def roofline_terms(flops_per_dev: float, bytes_per_dev: float,
                   coll: CollectiveStats) -> dict:
    compute_t = flops_per_dev / PEAK_FLOPS
    memory_t = bytes_per_dev / HBM_BW
    collective_t = coll.wire_bytes / LINK_BW
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dom = max(terms, key=terms.get)
    bound = max(terms.values())
    return {
        **terms,
        "dominant": dom.replace("_s", ""),
        "step_lower_bound_s": bound,
        # roofline fraction if perfectly overlapped: useful-compute share
        "compute_fraction_of_bound": compute_t / bound if bound else 0.0,
    }
