from repro.distributed.sharding import (  # noqa: F401
    Rules, make_rules, use_rules, constrain, current_rules,
)
