"""Config dataclasses for models, shapes, training, and SparKV.

Every assigned architecture gets one module in this package defining a
``CONFIG`` ModelConfig with the exact published hyperparameters, plus a
``reduced()`` helper that returns a CPU-smoke-testable shrink of the same
family.
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Optional


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m


@dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    experts_per_token: int
    # capacity factor for the sort-based dropping dispatch
    capacity_factor: float = 1.25
    router_aux_coef: float = 0.01


@dataclass(frozen=True)
class SSMConfig:
    """Mamba2 / SSD hyperparameters."""
    state_dim: int           # N (ssm_state)
    head_dim: int = 64       # P
    expand: int = 2          # d_inner = expand * d_model
    chunk_len: int = 256     # SSD chunk length
    conv_width: int = 4


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                  # dense | moe | hybrid | ssm | encdec
    num_layers: int
    d_model: int
    num_heads: int               # query heads (0 for attn-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0            # 0 -> d_model // num_heads
    activation: str = "swiglu"   # swiglu | geglu | gelu
    norm: str = "rmsnorm"        # rmsnorm | layernorm
    qkv_bias: bool = False
    rope_theta: float = 10_000.0
    tie_embeddings: bool = False
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    # hybrid (zamba2): one shared attention block applied every `attn_every`
    # mamba layers, re-using the same shared parameters each time.
    attn_every: int = 0
    # enc-dec (whisper): decoder depth & max decoder length
    dec_layers: int = 0
    dec_len: int = 448
    # modality frontend stub: none | audio_frames | vq_tokens
    frontend: str = "none"
    # True when the architecture's attention cost is sub-quadratic in context
    # (SSM/hybrid archs) — gates the long_500k shape.
    subquadratic: bool = False
    remat: str = "full"          # none | full | dots
    # dtype policy
    param_dtype: str = "bfloat16"
    moment_dtype: str = "float32"
    # chunk sizes of the memory-efficient reference paths. The cost-
    # calibration dry-run sets these to the full sequence so the inner
    # lax.scans disappear (XLA cost_analysis counts a scan body once —
    # see EXPERIMENTS.md §Roofline methodology).
    attn_chunk: int = 1024
    loss_chunk: int = 512
    # unroll factor for the layer scans (calibration sets = num_layers so
    # cost_analysis sees every layer's ops)
    scan_unroll: int = 1

    # ----- derived -----
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        assert self.num_heads > 0
        return self.d_model // self.num_heads

    @property
    def padded_vocab(self) -> int:
        """Vocab padded to /128 so it shards over any mesh axis we use."""
        return _round_up(self.vocab_size, 128)

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    def param_count(self) -> int:
        """Analytic parameter count (for 6ND roofline math)."""
        d = self.d_model
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        per_layer = 0
        if self.family in ("dense", "moe"):
            per_layer += self._attn_params()
            per_layer += self._ffn_params()
            n = self.num_layers * per_layer
        elif self.family == "ssm":
            n = self.num_layers * self._ssm_params()
        elif self.family == "hybrid":
            # mamba layers carry no FFN; one shared attn+FFN block
            n = self.num_layers * self._ssm_params()
            n += self._attn_params() + self._ffn_params()
        elif self.family == "encdec":
            enc = self.num_layers * (self._attn_params() + self._ffn_params())
            dec = self.dec_layers * (2 * self._attn_params() + self._ffn_params())
            n = enc + dec
        else:
            raise ValueError(self.family)
        return n + emb

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.moe is None:
            return self.param_count()
        d = self.d_model
        dense = self.param_count() - self.num_layers * self._ffn_params()
        active_ffn = self.num_layers * self.moe.experts_per_token * (
            3 * d * self.d_ff)
        return dense + active_ffn

    def _attn_params(self) -> int:
        d, hd = self.d_model, self.resolved_head_dim
        q = d * self.num_heads * hd
        kv = 2 * d * self.num_kv_heads * hd
        o = self.num_heads * hd * d
        return q + kv + o

    def _ffn_params(self) -> int:
        d = self.d_model
        gated = self.activation in ("swiglu", "geglu")
        mats = 3 if gated else 2
        per_expert = mats * d * self.d_ff
        if self.moe is not None:
            return self.moe.num_experts * per_expert + d * self.moe.num_experts
        return per_expert

    def _ssm_params(self) -> int:
        """Matches models/ssm.py: B and C are shared across heads
        (ngroups=1), separate x/z/B/C/dt projections + depthwise conv."""
        assert self.ssm is not None
        d, n = self.d_model, self.ssm.state_dim
        d_inner = self.ssm.expand * d
        nheads = d_inner // self.ssm.head_dim
        in_proj = d * (2 * d_inner + 2 * n + nheads)
        out_proj = d_inner * d
        conv = self.ssm.conv_width * (d_inner + 2 * n)
        return in_proj + out_proj + conv + 2 * nheads + d_inner


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    kind: str           # train | prefill | decode
    seq_len: int
    global_batch: int


# The four assigned LM shapes (identical across the 10 archs).
SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}


@dataclass(frozen=True)
class TrainConfig:
    learning_rate: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    weight_decay: float = 0.1
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    grad_clip: float = 1.0
    microbatches: int = 1        # gradient accumulation
    seed: int = 0
    checkpoint_every: int = 50
    checkpoint_dir: str = "/tmp/repro_ckpt"
    keep_checkpoints: int = 3
    grad_compression: str = "none"   # none | int8_ef


@dataclass(frozen=True)
class SparKVConfig:
    """SparKV scheduler / engine knobs (paper §IV)."""
    chunk_tokens: int = 1024
    # kernel block sizes — TPU adaptation: 128x128 MXU-aligned (paper: 128x64)
    q_block: int = 128
    kv_block: int = 128
    attention_mass: float = 0.98      # active-block CDF threshold
    stages: int = 8                   # K decision stages
    stage_budget_s: float = 0.25      # Δt per stage
    quant_bits: int = 5               # streamed-KV quantization (paper: 5-bit)
    quant_group: int = 64
    # runtime controller
    window_s: float = 0.2             # sliding monitor window
    max_migrations_per_stage: int = 32   # per monitor window
    imbalance_threshold: float = 1.15  # path-time ratio that triggers migration
    # priority weights (paper: equal by default)
    w_immediate: float = 1.0
    w_potential: float = 1.0
    scheduler_mode: str = "paper"     # paper (t,l,h) | engine (t,l)
    # per-chunk adaptive quantization: name of a
    # repro.compression.allocate schedule. "uniform" (default) disarms
    # the per-chunk machinery entirely — every trace is bit-identical to
    # a build without it; "flat" arms the per-chunk accounting while
    # still allocating quant_bits everywhere (byte-identical wire);
    # "attention"/"aggressive" spend bits where the saliency is.
    alloc_schedule: str = "uniform"


def reduced(cfg: ModelConfig, *, layers: int = 2, d_model: int = 64,
            heads: int = 4, kv_heads: Optional[int] = None, d_ff: int = 128,
            vocab: int = 512, experts: int = 8, state: int = 16) -> ModelConfig:
    """Shrink an arch config to a CPU-runnable smoke config of the same family."""
    kv = kv_heads if kv_heads is not None else max(1, min(cfg.num_kv_heads, heads))
    kw: dict = dict(
        num_layers=layers, d_model=d_model, d_ff=d_ff, vocab_size=vocab,
        num_heads=heads if cfg.num_heads > 0 else 0,
        num_kv_heads=kv if cfg.num_kv_heads > 0 else 0,
        head_dim=(d_model // heads) if cfg.num_heads > 0 else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = replace(cfg.moe, num_experts=experts,
                            experts_per_token=min(cfg.moe.experts_per_token, 2))
    if cfg.ssm is not None:
        kw["ssm"] = replace(cfg.ssm, state_dim=state, head_dim=16,
                            chunk_len=16)
    if cfg.family == "hybrid":
        kw["attn_every"] = 2
    if cfg.family == "encdec":
        kw["dec_layers"] = 2
        kw["dec_len"] = 16
    return replace(cfg, name=cfg.name + "-smoke", **kw)
