"""The paper's own primary evaluation model: Qwen3-4B [arXiv:2505.09388; hf].

36L d_model=2560 32H (GQA kv=8, head_dim=128) d_ff=9728 vocab=151936.
Used for the paper-faithful SparKV benchmarks (Figs. 9-16, Tables I-II).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="sparkv-qwen3-4b",
    family="dense",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    head_dim=128,
    d_ff=9728,
    vocab_size=151936,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)
