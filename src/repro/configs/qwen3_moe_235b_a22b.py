"""qwen3-moe-235b-a22b [hf:Qwen/Qwen3-30B-A3B family; hf-verified tier].

94L d_model=4096 64H (GQA kv=4) per-expert d_ff=1536 vocab=151936,
MoE 128 experts top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="qwen3-moe-235b-a22b",
    family="moe",
    num_layers=94,
    d_model=4096,
    num_heads=64,
    num_kv_heads=4,
    head_dim=128,
    d_ff=1536,
    vocab_size=151936,
    activation="swiglu",
    norm="rmsnorm",
    rope_theta=1_000_000.0,
    moe=MoEConfig(num_experts=128, experts_per_token=8),
    moment_dtype="bfloat16",   # 235B: fp32 moments do not fit 16 GB/chip at 256 chips
)
