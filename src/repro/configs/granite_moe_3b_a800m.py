"""granite-moe-3b-a800m [hf:ibm-granite/granite-3.0-1b-a400m-base family; hf].

32L d_model=1536 24H (GQA kv=8) per-expert d_ff=512 vocab=49155,
MoE 40 experts top-8.
"""
from repro.configs.base import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    head_dim=64,
    d_ff=512,
    vocab_size=49155,
    activation="swiglu",
    norm="rmsnorm",
    tie_embeddings=True,
    moe=MoEConfig(num_experts=40, experts_per_token=8),
)
