"""whisper-tiny [arXiv:2212.04356; unverified tier].

4L encoder + 4L decoder, d_model=384 6H (kv=6) d_ff=1536 vocab=51865.
Enc-dec; the conv audio frontend is a STUB — input_specs() provides
precomputed frame embeddings (batch, seq, d_model) directly.
Sinusoidal-absolute positions in the original; we feed positionless frame
embeddings (stub responsibility) + learned decoder positions via RoPE-free
attention — backbone only per assignment.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-tiny",
    family="encdec",
    num_layers=4,
    d_model=384,
    num_heads=6,
    num_kv_heads=6,
    head_dim=64,
    d_ff=1536,
    vocab_size=51865,
    activation="gelu",
    norm="layernorm",
    qkv_bias=True,
    tie_embeddings=True,
    dec_layers=4,
    dec_len=448,
    frontend="audio_frames",
)
