"""zamba2-2.7b [arXiv:2411.15242; hf].

54 Mamba2 layers, d_model=2560, plus a *shared* attention block (32H, kv=32,
head_dim=80) applied every 6 mamba layers re-using the same parameters
(Zamba's shared-transformer-block design), d_ff=10240, vocab=32000,
ssm_state=64. Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    head_dim=80,
    d_ff=10240,
    vocab_size=32000,
    activation="gelu",
    norm="rmsnorm",
    ssm=SSMConfig(state_dim=64, head_dim=64, expand=2, chunk_len=128),
    attn_every=6,
    subquadratic=True,
    tie_embeddings=True,
)
