"""Architecture config registry.

``get_config(name)`` returns the full published config; ``get_smoke(name)``
returns a reduced same-family config for CPU smoke tests.
"""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ModelConfig, MoEConfig, SSMConfig, ShapeConfig, TrainConfig, SparKVConfig,
    SHAPES, reduced,
)

_MODULES = {
    "qwen3-moe-235b-a22b": "qwen3_moe_235b_a22b",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "chameleon-34b": "chameleon_34b",
    "starcoder2-3b": "starcoder2_3b",
    "gemma-2b": "gemma_2b",
    "phi3-medium-14b": "phi3_medium_14b",
    "qwen2.5-3b": "qwen2_5_3b",
    "zamba2-2.7b": "zamba2_2_7b",
    "whisper-tiny": "whisper_tiny",
    "mamba2-130m": "mamba2_130m",
    "sparkv-qwen3-4b": "sparkv_qwen3_4b",
}

# The 10 assigned architectures (dry-run / roofline coverage set).
ASSIGNED_ARCHS = [k for k in _MODULES if k != "sparkv-qwen3-4b"]


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def get_smoke(name: str, **kw) -> ModelConfig:
    return reduced(get_config(name), **kw)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether a (arch, shape) cell runs, per assignment rules."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "long_500k skipped: pure full-attention arch (quadratic)"
    return True, ""
