"""mamba2-130m [arXiv:2405.21060; unverified tier].

24L d_model=768 attention-free, vocab=50280, ssm_state=128 — SSD
(state-space duality). Sub-quadratic: runs the long_500k cell.
"""
from repro.configs.base import ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    norm="rmsnorm",
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, chunk_len=256),
    subquadratic=True,
    tie_embeddings=True,
)
