"""chameleon-34b [arXiv:2405.09818; unverified tier].

48L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=65536.
Early-fusion VLM: VQ image tokens share the text vocabulary, so the backbone
is a plain decoder-only LM; the VQ tokenizer frontend is a stub
(input_specs() provides token ids that may include image-token ids).
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="chameleon-34b",
    family="dense",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65536,
    activation="swiglu",
    norm="rmsnorm",
    frontend="vq_tokens",
)
