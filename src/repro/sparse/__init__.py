"""Block-sparse attention masks: block scores + mass-threshold selection."""
