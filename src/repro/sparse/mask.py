"""Block importance estimation -> sparse block lists (SpargeAttention-style,
paper §IV-C: "active blocks ... account for 98% of the total attention
mass").

Mean-pooled q/k block representatives score every (q_block, kv_block)
pair; per q row, blocks are kept in descending-score order until their
(softmax-normalized) cumulative mass reaches `mass`; the diagonal (local)
block and block 0 (attention sink) are always kept. Output is the padded
index-list format the Pallas kernel consumes.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def pool_blocks(x: jax.Array, block: int) -> jax.Array:
    """(bh, s, d) -> (bh, s//block, d) mean pool."""
    bh, s, d = x.shape
    return x.reshape(bh, s // block, block, d).mean(axis=2)


def block_scores(q, k, *, q_block: int, kv_block: int,
                 causal: bool = True) -> jax.Array:
    """(bh, n_qb, n_kb) pooled attention scores; invalid blocks -inf."""
    pq = pool_blocks(q, q_block).astype(jnp.float32)
    pk = pool_blocks(k, kv_block).astype(jnp.float32)
    s = jnp.einsum("bqd,bkd->bqk", pq, pk) * (q.shape[-1] ** -0.5)
    if causal:
        n_qb, n_kb = s.shape[1], s.shape[2]
        # block (qb, kb) is causal-valid if its first q row can see the
        # block's first kv position: qb*q_block + q_block-1 >= kb*kv_block
        qend = (jnp.arange(n_qb) + 1) * q_block - 1
        kstart = jnp.arange(n_kb) * kv_block
        valid = qend[:, None] >= kstart[None, :]
        s = jnp.where(valid[None], s, -jnp.inf)
    return s


def select_blocks(scores: jax.Array, *, mass: float = 0.98,
                  always_keep_diag: bool = True, q_block: int = 128,
                  kv_block: int = 128) -> tuple[jax.Array, jax.Array]:
    """scores: (bh, n_qb, n_kb) -> (block_idx, block_cnt) padded lists.

    Keeps the top blocks whose softmax mass reaches `mass` per row.
    """
    bh, n_qb, n_kb = scores.shape
    p = jax.nn.softmax(scores, axis=-1)
    p = jnp.where(jnp.isfinite(scores), p, 0.0)

    if always_keep_diag:
        diag = jnp.minimum((jnp.arange(n_qb) * q_block) // kv_block, n_kb - 1)
        boost = jax.nn.one_hot(diag, n_kb)[None] \
            + jax.nn.one_hot(jnp.zeros(n_qb, jnp.int32), n_kb)[None]
        p = p + boost                                  # force to the front

    order = jnp.argsort(-p, axis=-1)                   # (bh, n_qb, n_kb)
    p_sorted = jnp.take_along_axis(p, order, axis=-1)
    denom = jnp.maximum(p_sorted.sum(-1, keepdims=True), 1e-9)
    cum = jnp.cumsum(p_sorted, axis=-1) / denom
    # keep k blocks where the mass BEFORE them is < mass and score > 0
    before = jnp.concatenate([jnp.zeros_like(cum[..., :1]),
                              cum[..., :-1]], axis=-1)
    keep = (before < mass) & (p_sorted > 0)
    cnt = keep.sum(-1).astype(jnp.int32)
    max_nnz = int(n_kb)
    idx = jnp.where(keep, order, 0).astype(jnp.int32)
    return idx, cnt


def trim_nnz(block_idx: np.ndarray, block_cnt: np.ndarray,
             multiple: int = 1):
    """Host-side: shrink the padded nnz dimension to max(cnt)."""
    mx = int(max(int(np.max(block_cnt)), 1))
    mx = ((mx + multiple - 1) // multiple) * multiple
    return np.asarray(block_idx)[..., :mx], np.asarray(block_cnt)


def active_block_fraction(block_cnt: jax.Array, n_kb: int,
                          causal: bool = True) -> float:
    """Mean density vs the causal-valid block count (diagnostics)."""
    n_qb = block_cnt.shape[1]
    if causal:
        valid = np.minimum(np.arange(1, n_qb + 1) * (128 // 128), n_kb)
        valid = np.maximum(valid, 1)
        return float(np.mean(np.asarray(block_cnt) / valid[None, :]))
    return float(np.mean(np.asarray(block_cnt) / n_kb))
