"""Discrete-event hybrid execution engine.

Executes a Schedule on a virtual clock with two serial servers:
  - network: consumes a time-varying bandwidth trace (real compressed chunk
    bytes), + per-chunk t_proc (entropy decode + dequant);
  - device: ground-truth block-sparse-attention latencies (nonlinear, load-
    and noise-dependent — the thing the predictor approximates).

The engine is work-conserving: within the scheduled priority order the
compute server starts the first dependency-ready chunk. The runtime
controller (§IV-D) may migrate queued chunks between paths at event
boundaries. TTFT = context completion + first-token decode.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.chunks import Chunk, ChunkGrid, State
from repro.core.controller import RuntimeController
from repro.core.costs import (DeviceProfile, EnergyMeter, GroundTruthLatency,
                              NetworkProfile)
from repro.core.scheduler import Schedule


@dataclasses.dataclass
class EngineResult:
    ttft_s: float
    context_done_s: float
    energy: dict
    n_streamed: int
    n_computed: int
    n_migrations: int
    stream_busy_s: float
    compute_busy_s: float
    proc_busy_s: float
    timeline: list            # (t_start, t_end, path, chunk)
    streamed_set: set
    computed_set: set
    bytes_streamed: float

    def breakdown(self) -> dict:
        return {
            "transmission_s": self.stream_busy_s - self.proc_busy_s,
            "decode_proc_s": self.proc_busy_s,
            "compute_s": self.compute_busy_s,
            "ttft_s": self.ttft_s,
        }


class BandwidthIntegrator:
    """Cumulative-bytes view over a bandwidth trace."""

    def __init__(self, trace: np.ndarray, dt: float):
        self.dt = dt
        self.cum = np.concatenate([[0.0], np.cumsum(trace) * dt])

    def bytes_between(self, t0: float, t1: float) -> float:
        return self._at(t1) - self._at(t0)

    def _at(self, t: float) -> float:
        i = t / self.dt
        i0 = int(np.floor(i))
        if i0 >= len(self.cum) - 1:
            # extrapolate with the mean of the tail
            tail_bw = (self.cum[-1] - self.cum[max(len(self.cum) - 100, 0)]) \
                / (self.dt * min(99, len(self.cum) - 1))
            return self.cum[-1] + (t - (len(self.cum) - 1) * self.dt) * tail_bw
        return self.cum[i0] + (i - i0) * (self.cum[i0 + 1] - self.cum[i0])

    def finish_time(self, t0: float, nbytes: float) -> float:
        """Earliest t where nbytes are delivered starting at t0."""
        target = self._at(t0) + nbytes
        lo, hi = t0, t0 + 1e-3
        while self._at(hi) < target:
            hi = t0 + (hi - t0) * 2
            if hi - t0 > 1e5:
                break
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if self._at(mid) < target:
                lo = mid
            else:
                hi = mid
        return hi


def decode_first_token_seconds(cfg, context_len: int,
                               profile: DeviceProfile) -> float:
    """One-token forward over the assembled cache (memory-bound)."""
    if cfg.num_heads:
        kv_bytes = (2 * context_len * cfg.num_kv_heads
                    * cfg.resolved_head_dim * 2)
    else:
        kv_bytes = 2 * cfg.ssm.state_dim * cfg.d_model * cfg.ssm.expand
    act = cfg.active_param_count()
    per_layer = (kv_bytes / profile.hbm_bw
                 + 2 * (act / max(cfg.num_layers, 1)) / profile.peak_flops)
    return cfg.num_layers * per_layer + 2 * act * 2 / profile.hbm_bw \
        / max(cfg.num_layers, 1)


@dataclasses.dataclass
class HybridEngine:
    grid: ChunkGrid
    chunk_bytes: dict            # Chunk -> compressed bytes
    active_blocks: dict          # Chunk -> ground-truth active blocks
    t_comp_pred: dict            # Chunk -> planner's predicted seconds
    gt: GroundTruthLatency
    profile: DeviceProfile
    bw: BandwidthIntegrator
    cfg_model: object            # ModelConfig (for dense/proj costs)
    util: float = 0.0            # external contention (Fig. 14)
    controller: Optional[RuntimeController] = None
    seed: int = 0

    def _t_comp_actual(self, c: Chunk, rng) -> float:
        if c.l == self.grid.n_l - 1:
            return self.profile.t_proj_s
        t = self.gt.attn_seconds(self.active_blocks[c], self.util, rng)
        return t + self.gt.dense_seconds(self.cfg_model) / max(self.grid.n_h, 1)

    def run(self, schedule: Schedule, *, context_len: int) -> EngineResult:
        rng = np.random.default_rng(self.seed)
        g = self.grid
        state = np.zeros(g.size, np.int8)

        stream_q: list[Chunk] = []
        comp_q: list[Chunk] = []
        stage_of = {}
        for si, st in enumerate(schedule.stages):
            for c in st.stream:
                stream_q.append(c)
                stage_of[c] = si
            for c in st.comp:
                comp_q.append(c)
                stage_of[c] = si

        now = 0.0
        net_free = 0.0
        dev_free = 0.0
        net_busy_until = {}
        done = 0
        total = g.size
        timeline = []
        stream_busy = comp_busy = proc_busy = bytes_streamed = 0.0
        streamed_set, computed_set = set(), set()
        n_migr = 0
        # in-flight: (finish_time, chunk, path)
        inflight: list[tuple[float, Chunk, str]] = []

        def ready_set():
            return {c for c in comp_q if g.compute_ready(c, state)}

        guard = 0
        while done < total:
            guard += 1
            if guard > 50 * total + 1000:
                raise RuntimeError("engine livelock")
            progressed = False
            # start network transfer
            if net_free <= now and stream_q:
                c = stream_q.pop(0)
                nbytes = self.chunk_bytes[c]
                t_proc = self.profile.t_proc(nbytes)
                t_end = self.bw.finish_time(now, nbytes) + t_proc
                net_free = t_end
                inflight.append((t_end, c, "stream"))
                stream_busy += t_end - now
                proc_busy += t_proc
                bytes_streamed += nbytes
                timeline.append((now, t_end, "stream", c))
                progressed = True
            # start compute on first ready chunk in priority order
            if dev_free <= now:
                started = None
                for i, c in enumerate(comp_q):
                    if g.compute_ready(c, state):
                        started = comp_q.pop(i)
                        break
                if started is not None:
                    dt = self._t_comp_actual(started, rng)
                    t_end = now + dt
                    dev_free = t_end
                    inflight.append((t_end, started, "compute"))
                    comp_busy += dt
                    timeline.append((now, t_end, "compute", started))
                    if self.controller:
                        self.controller.record_compute(
                            t_end, dt, self.t_comp_pred[started])
                    progressed = True
            if not inflight:
                if not progressed:
                    if comp_q and not stream_q:
                        # dependency-starved compute chunks (e.g. after a
                        # bad migration): streaming is always feasible
                        stream_q.append(comp_q.pop(0))
                        continue
                    raise RuntimeError("engine stalled")
                continue
            # advance to next completion
            inflight.sort(key=lambda e: e[0])
            t_end, c, path = inflight.pop(0)
            now = max(now, t_end)
            i = g.index(c)
            if path == "stream":
                state[i] = State.STREAMED
                streamed_set.add(c)
                if self.controller:
                    self.controller.record_stream(now, self.chunk_bytes[c])
            else:
                state[i] = State.COMPUTED
                computed_set.add(c)
            done += 1
            # controller migrations at event boundary
            if self.controller is not None:
                migr = self.controller.decide(
                    now, stream_queue=stream_q, comp_queue=comp_q,
                    ready=ready_set() | {cc for cc in stream_q
                                         if g.compute_ready(cc, state)},
                    chunk_bytes=self.chunk_bytes,
                    t_comp_pred=self.t_comp_pred)
                for m in migr:
                    if m.to_path == "compute" and m.chunk in stream_q:
                        stream_q.remove(m.chunk)
                        comp_q.insert(0, m.chunk)
                        n_migr += 1
                    elif m.to_path == "stream" and m.chunk in comp_q:
                        # never strand a compute-assigned dependent: its
                        # layer dep requires this chunk to be *computed*
                        dependent = (m.chunk.l + 1 < g.n_l and
                                     Chunk(m.chunk.t, m.chunk.l + 1,
                                           m.chunk.h) in comp_q)
                        if not dependent:
                            comp_q.remove(m.chunk)
                            stream_q.append(m.chunk)
                            n_migr += 1

        t_first = decode_first_token_seconds(self.cfg_model, context_len,
                                             self.profile)
        ttft = now + t_first
        meter = EnergyMeter(self.profile,
                            compute_busy_s=comp_busy + t_first,
                            nic_busy_s=stream_busy, wall_s=ttft)
        return EngineResult(
            ttft_s=ttft, context_done_s=now, energy=meter.breakdown(),
            n_streamed=len(streamed_set), n_computed=len(computed_set),
            n_migrations=n_migr, stream_busy_s=stream_busy,
            compute_busy_s=comp_busy, proc_busy_s=proc_busy,
            timeline=timeline, streamed_set=streamed_set,
            computed_set=computed_set, bytes_streamed=bytes_streamed)
