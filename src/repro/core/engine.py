"""Discrete-event hybrid execution engine.

Executes a Schedule on a virtual clock with two serial servers:
  - network: consumes a time-varying bandwidth trace (real compressed chunk
    bytes), + per-chunk t_proc (entropy decode + dequant);
  - device: ground-truth block-sparse-attention latencies (nonlinear, load-
    and noise-dependent — the thing the predictor approximates).

The engine is work-conserving: within the scheduled priority order the
compute server starts the first dependency-ready chunk. The runtime
controller (§IV-D) may migrate queued chunks between paths at event
boundaries. TTFT = context completion + first-token decode.

Two driving modes:

  - ``run(schedule)`` — the classic closed loop: this request owns the
    whole ``BandwidthIntegrator`` and the device, and the engine advances
    its own clock (single-request semantics, unchanged). Semantically it
    is a capacity-1 device with an always-idle run queue.
  - ``session(schedule)`` — an event-yielding coroutine stepped by an
    *external* clock (``repro.serving.cluster.ServingCluster``). The
    protocol, per yield:

      * :class:`StreamStart`  — engine asks for a network transfer; the
        driver maps it onto a link server (single arbiter or multi-stage
        :class:`repro.serving.resources.LinkTopology`) and replies None.
      * :class:`ComputeStart` — engine asks for device service. This is a
        *queue-admission* step, not an implied immediate start: the driver
        replies with a :class:`StartAck` whose ``t_start`` is the service
        start time, or ``StartAck(None)`` when the job went into an
        explicit device run queue (``repro.serving.resources.
        DeviceRunQueue``) and will start later. A plain ``None`` reply is
        the legacy immediate-start shorthand (what ``run()`` sends).
      * :class:`Wait` — engine has nothing more to start; the driver must
        resume the generator with this request's next :class:`Completion`
        (whose ``t_start`` is the actual service start, so queue wait is
        observable as ``t_start - submit time``).
      * :class:`DecodeStart` — with ``max_new_tokens > 0`` the engine,
        once its context is assembled, asks for autoregressive decode.
        The driver enrols it in a per-device continuous decode batch
        (``repro.serving.decode.DecodeBatcher``) and delivers tokens as
        :class:`DecodeTick` / :class:`DecodeDone` completions at later
        ``Wait`` yields; TTFT/TTLT/TPOT then come from the batcher's
        token timeline instead of the analytic first-token constant.
        With ``max_new_tokens == 0`` (the default) the decode phase is
        absent and results are bit-identical to pre-decode behaviour.

    Controller bookkeeping follows the ack: an immediate start records the
    compute sample at yield time (bit-compatible with PR 1); a queued
    start defers the record to the completion, stamped with the *actual*
    service interval, and additionally feeds the controller's queue-wait
    telemetry.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import numpy as np

from repro.core.chunks import Chunk, ChunkGrid, State
from repro.core.controller import RuntimeController
from repro.core.costs import (DeviceProfile, EnergyMeter,
                              GroundTruthLatency, KVStoreModel,
                              t_store_miss_encode)
from repro.core.scheduler import Schedule


class LinkStarvedError(RuntimeError):
    """The bandwidth trace (including its tail extrapolation) cannot
    deliver the requested bytes within ``max_horizon_s`` of the start
    time. Raised by :meth:`BandwidthIntegrator.finish_time` instead of
    silently returning a completion time earlier than the actual
    delivery (the pre-fix behaviour when the trace flatlines at ~0)."""


@dataclasses.dataclass
class EngineResult:
    ttft_s: float
    context_done_s: float
    energy: dict
    n_streamed: int
    n_computed: int
    n_migrations: int
    stream_busy_s: float
    compute_busy_s: float
    proc_busy_s: float
    timeline: list            # (t_start, t_end, path, chunk)
    streamed_set: set
    computed_set: set
    bytes_streamed: float
    compute_wait_s: float = 0.0   # total device run-queue wait observed
    n_compute_queued: int = 0     # compute chunks that did not start at once
    # decode phase (max_new_tokens > 0; defaults are the first-token-only
    # accounting: one token, delivered at ttft_s)
    n_tokens_out: int = 1
    ttlt_s: float = 0.0           # last-token time (driver clock)
    tpot_s: float = 0.0           # mean inter-token time after the first
    decode_busy_s: float = 0.0    # this request's share of decode-step time
    token_times: tuple = ()       # absolute per-token delivery times
    # cross-request KV reuse (zeros without a reuse layer — defaults keep
    # pre-reuse results bit-identical)
    n_reused: int = 0             # chunks satisfied by the device prefix cache
    n_store_hits: int = 0         # chunks streamed as cloud-store hits
    bytes_hit_stream: float = 0.0  # streamed bytes that rode the hit leg
    # hostile-world mobility (zeros without scenario events — defaults
    # keep static fleets bit-identical)
    n_lost: int = 0               # in-flight transfers aborted (handoff/outage)
    bytes_lost: float = 0.0       # partially delivered bytes wasted by aborts
    bytes_restreamed: float = 0.0  # bytes re-issued for previously-lost chunks

    def breakdown(self) -> dict:
        return {
            "transmission_s": self.stream_busy_s - self.proc_busy_s,
            "decode_proc_s": self.proc_busy_s,
            "compute_s": self.compute_busy_s,
            "queue_wait_s": self.compute_wait_s,
            "ttft_s": self.ttft_s,
        }


class BandwidthIntegrator:
    """Cumulative-bytes view over a bandwidth trace."""

    def __init__(self, trace: np.ndarray, dt: float):
        self.dt = dt
        self.cum = np.concatenate([[0.0], np.cumsum(trace) * dt])
        self._grid: Optional[np.ndarray] = None   # lazy (at_many only)

    def bytes_between(self, t0: float, t1: float) -> float:
        return self._at(t1) - self._at(t0)

    @property
    def tail_bw(self) -> float:
        """Constant extrapolation rate beyond the trace end (mean of the
        trace tail)."""
        return (self.cum[-1] - self.cum[max(len(self.cum) - 100, 0)]) \
            / (self.dt * min(99, len(self.cum) - 1))

    @property
    def grid_end_s(self) -> float:
        """Last instant covered by the trace itself (extrapolated after)."""
        return (len(self.cum) - 1) * self.dt

    def at_many(self, t: np.ndarray) -> np.ndarray:
        """Vectorized :meth:`_at`: cumulative bytes at each time in `t`,
        with the same piecewise-linear interpolation and tail
        extrapolation (multi-stage link topologies integrate over many
        cell boundaries at once)."""
        if self._grid is None:
            self._grid = np.arange(len(self.cum)) * self.dt
        out = np.interp(t, self._grid, self.cum)
        over = t > self._grid[-1]
        if np.any(over):
            out = np.where(over,
                           self.cum[-1] + (t - self._grid[-1]) * self.tail_bw,
                           out)
        return out

    def _at(self, t: float) -> float:
        i = t / self.dt
        i0 = int(np.floor(i))
        if i0 >= len(self.cum) - 1:
            # extrapolate with the mean of the tail
            return self.cum[-1] + (t - self.grid_end_s) * self.tail_bw
        return self.cum[i0] + (i - i0) * (self.cum[i0 + 1] - self.cum[i0])

    def finish_time(self, t0: float, nbytes: float, *,
                    max_horizon_s: float = 1e5) -> float:
        """Earliest t where nbytes are delivered starting at t0.

        Raises :class:`LinkStarvedError` when the trace cannot deliver
        the bytes within ``max_horizon_s`` seconds of ``t0`` (starved /
        flatlined link) rather than returning an undershooting time.
        """
        if nbytes <= 0:
            return t0
        target = self._at(t0) + nbytes
        lo, hi = t0, t0 + 1e-3
        while self._at(hi) < target:
            hi = t0 + (hi - t0) * 2
            if hi - t0 > max_horizon_s:
                break
        if self._at(hi) < target:
            raise LinkStarvedError(
                f"link starved: {nbytes:.0f} B not deliverable within "
                f"{max_horizon_s:.0f}s of t={t0:.3f} "
                f"(delivered {self._at(hi) - self._at(t0):.0f} B)")
        for _ in range(60):
            mid = 0.5 * (lo + hi)
            if self._at(mid) < target:
                lo = mid
            else:
                hi = mid
        return hi


def _kv_bytes_per_token(cfg, context_len: int) -> float:
    """Per-layer bytes one decode step reads for one sequence: the KV
    cache at `context_len` (bf16 k+v) for attention models, the SSM
    state for state-space models."""
    if cfg.num_heads:
        return 2 * context_len * cfg.num_kv_heads * cfg.resolved_head_dim * 2
    return 2 * cfg.ssm.state_dim * cfg.d_model * cfg.ssm.expand


def context_kv_bytes(cfg, context_len: int) -> float:
    """Device-resident bytes of one request's fully assembled KV context
    at bf16 (all layers): what the serving layer's KV memory server
    charges a request once its prefill completes. SSM models hold a
    fixed-size state per layer instead of a growing cache."""
    return cfg.num_layers * _kv_bytes_per_token(cfg, context_len)


def token_kv_bytes(cfg) -> float:
    """Resident-KV growth of one decoded token (all layers, bf16): the
    per-``DecodeTick`` charge on the KV memory server. Zero for SSM
    models — their state does not grow with decoded tokens."""
    if not cfg.num_heads:
        return 0.0
    return cfg.num_layers * _kv_bytes_per_token(cfg, 1)


def decode_first_token_seconds(cfg, context_len: int,
                               profile: DeviceProfile) -> float:
    """One-token forward over the assembled cache (memory-bound)."""
    kv_bytes = _kv_bytes_per_token(cfg, context_len)
    act = cfg.active_param_count()
    per_layer = (kv_bytes / profile.hbm_bw
                 + 2 * (act / max(cfg.num_layers, 1)) / profile.peak_flops)
    return cfg.num_layers * per_layer + 2 * act * 2 / profile.hbm_bw \
        / max(cfg.num_layers, 1)


def decode_step_seconds(cfg, context_lens, profile: DeviceProfile) -> float:
    """One batched decode step: one token for each of ``len(context_lens)``
    co-resident sequences.

    The batched generalization of :func:`decode_first_token_seconds`
    (identical roofline terms, so a batch of one reproduces the
    first-token cost): per-sequence KV reads sum over the batch, compute
    scales with the batch, but the weight-read term is paid **once per
    step** — the amortization that makes continuous batching raise
    tokens/s without changing any per-sequence work."""
    b = len(context_lens)
    assert b >= 1, "decode step needs at least one sequence"
    act = cfg.active_param_count()
    kv_total = sum(_kv_bytes_per_token(cfg, context_len)
                   for context_len in context_lens)
    return (cfg.num_layers * kv_total / profile.hbm_bw
            + b * 2 * act / profile.peak_flops
            + 2 * act * 2 / profile.hbm_bw / max(cfg.num_layers, 1))


# ---------------------------------------------------------------------------
# Session protocol events (engine <-> external clock)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class StreamStart:
    """Engine requests a network transfer for `chunk` (its net server is
    idle). The driver owns delivery timing; `t_proc` is the on-device
    decode+dequant tail the driver must add after the transfer lands."""
    chunk: Chunk
    nbytes: float
    t_proc: float


@dataclasses.dataclass(frozen=True)
class StoreHit:
    """Engine requests a network transfer for `chunk` whose encoded
    bitstream is cached in the cloud KV store (a content-key hit). Same
    shape as :class:`StreamStart`, but the driver routes the bytes over
    the *cached-egress* leg — the path excluding the shared cloud-egress
    stage (the store's edge replica serves it) — and adds the store's
    ``hit_latency_s`` to the on-device tail. Completion comes back with
    ``path == "stream"``."""
    chunk: Chunk
    nbytes: float
    t_proc: float


@dataclasses.dataclass(frozen=True)
class ComputeStart:
    """Engine requests device service for `chunk`; `duration_s` is the
    ground-truth latency already inflated by the utilization the driver
    supplied via `util_fn` (closed-loop) or the static `util` fallback —
    drivers with an explicit run queue supply util 0 and model contention
    as queueing delay instead. The driver acknowledges with a
    :class:`StartAck` (or None = started now, legacy)."""
    chunk: Chunk
    duration_s: float


@dataclasses.dataclass(frozen=True)
class StartAck:
    """Driver's reply to :class:`ComputeStart`. ``t_start`` is the service
    start time; ``None`` means the job was queued on the device server and
    will start later (the engine learns the actual start from the
    eventual :class:`Completion.t_start`)."""
    t_start: Optional[float]


@dataclasses.dataclass(frozen=True)
class Wait:
    """Engine has nothing more to start; the driver must resume the
    generator with the request's next Completion."""


@dataclasses.dataclass(frozen=True)
class StreamLost:
    """Driver's alternative reply at a ``Wait`` yield: the in-flight
    network transfer for `chunk` was aborted mid-delivery (AP handoff
    re-route, AP outage, device churn). Entropy-coded chunk bitstreams
    are undecodable from a partial prefix, so the ``nbytes_delivered``
    bytes already on the wire are wasted; the engine re-enters the chunk
    at the head of its stream backlog and the next ``StreamStart`` rides
    whatever path the driver now routes (the controller may instead flip
    the chunk to local compute at this boundary — the paper's §IV-D
    runtime refinement applied to a route loss)."""
    chunk: Chunk
    t_s: float                # driver clock at the abort
    nbytes_delivered: float   # bytes delivered (and wasted) before abort


@dataclasses.dataclass(frozen=True)
class Completion:
    path: str                 # "stream" | "compute"
    chunk: Chunk
    t_start: float            # service begin (stream: transfer start)
    t_end: float              # chunk available (stream: incl. t_proc)


@dataclasses.dataclass(frozen=True)
class DecodeStart:
    """Engine's context is fully assembled and it wants ``n_tokens`` of
    autoregressive decode. The driver enrols the request into a per-device
    decode batch (``repro.serving.decode.DecodeBatcher``) and replies
    None; token deliveries arrive as :class:`DecodeTick` /
    :class:`DecodeDone` completions at the engine's subsequent ``Wait``
    yields. ``context_len`` is the KV length the first step reads."""
    context_len: int
    n_tokens: int


@dataclasses.dataclass(frozen=True)
class DecodeTick:
    """One batched-dispatch completion for this request: the dispatch ran
    over ``[t_start, t_end]`` on the device and delivered
    ``token_times`` (absolute clock times, one per generated token).
    ``busy_share_s`` is this request's share of the dispatch's device-busy
    time (step time divided by the co-resident batch at each sub-step) —
    the engine folds it into compute-energy accounting."""
    t_start: float
    t_end: float
    token_times: tuple
    batch_size: int
    busy_share_s: float


@dataclasses.dataclass(frozen=True)
class DecodeDone(DecodeTick):
    """The dispatch that delivers this request's final token (its
    ``token_times`` completes the quota requested via DecodeStart)."""


@dataclasses.dataclass(frozen=True)
class KVReload:
    """A parked session's evicted KV must be restored before its next
    decode dispatch. Emitted by the serving layer's KV memory server on
    behalf of the session (the engine itself stays parked in ``Wait``
    until the reload's legs complete and token deliveries resume — the
    stall lands in TTLT/TPOT through the delayed ``DecodeTick`` s, so no
    engine-side accounting changes). ``nbytes`` is the resident KV to
    restore; ``from_disk`` says whether a demoted copy exists on the
    disk tier (otherwise the KV was dropped and must be restreamed or
    recomputed); ``mode`` is the ``MemoryModel.reload`` policy the
    planner will apply."""
    rid: int
    nbytes: float
    from_disk: bool
    mode: str = "planner"


@dataclasses.dataclass
class HybridEngine:
    grid: ChunkGrid
    chunk_bytes: dict            # Chunk -> compressed bytes
    active_blocks: dict          # Chunk -> ground-truth active blocks
    t_comp_pred: dict            # Chunk -> planner's predicted seconds
    gt: GroundTruthLatency
    profile: DeviceProfile
    bw: BandwidthIntegrator
    cfg_model: object            # ModelConfig (for dense/proj costs)
    util: float = 0.0            # static external contention (Fig. 14)
    controller: Optional[RuntimeController] = None
    seed: int = 0
    max_new_tokens: int = 0      # 0 = first-token-only (legacy behaviour)
    # cross-request KV reuse (all empty/None = pre-reuse behaviour, exactly)
    preloaded: frozenset = frozenset()    # chunks resident before t_start
    store_hits: frozenset = frozenset()   # chunks cached in the cloud store
    store_model: Optional[KVStoreModel] = None

    def _t_comp_actual(self, c: Chunk, rng, util: Optional[float] = None
                       ) -> float:
        if c.l == self.grid.n_l - 1:
            return self.profile.t_proj_s
        u = self.util if util is None else util
        t = self.gt.attn_seconds(self.active_blocks[c], u, rng)
        return t + self.gt.dense_seconds(self.cfg_model) / max(self.grid.n_h, 1)

    # ------------------------------------------------------------------
    # Event-yielding core (steppable by an external clock)
    # ------------------------------------------------------------------
    def session(self, schedule: Schedule, *, context_len: int,
                t_start: float = 0.0,
                util_fn: Optional[Callable[[], float]] = None):
        """Generator form of the execution loop.

        Yields StreamStart / ComputeStart requests (driver replies None)
        and Wait markers (driver replies with this request's next
        Completion). Returns an EngineResult via StopIteration.value;
        times in the result are on the driver's clock (`t_start`-based),
        so `ttft_s`/`context_done_s` are absolute for cluster drivers and
        identical to the classic values when t_start == 0.
        """
        rng = np.random.default_rng(self.seed)
        g = self.grid

        state = np.zeros(g.size, np.int8)
        # prefix-reuse: chunks whose assembled KV is already resident on
        # the device (this session's previous turn, or a co-resident
        # request sharing the prefix). STREAMED — present KV satisfies
        # token deps; hidden states were never materialized, so layer
        # deps stay unmet, exactly the physics of reused KV.
        preloaded = frozenset(self.preloaded)
        store_hits = frozenset(self.store_hits)
        for c in preloaded:
            state[g.index(c)] = State.STREAMED
        stream_q: list[Chunk] = []
        comp_q: list[Chunk] = []
        for st in schedule.stages:
            stream_q.extend(c for c in st.stream if c not in preloaded)
            comp_q.extend(c for c in st.comp if c not in preloaded)

        now = t_start
        net_busy = False
        dev_busy = False
        inflight = 0
        done = len(preloaded)
        n_reused = len(preloaded)
        n_store_hits = 0
        bytes_hit_stream = 0.0
        total = g.size
        timeline = []
        stream_busy = comp_busy = proc_busy = bytes_streamed = 0.0
        streamed_set, computed_set = set(), set()
        n_migr = 0
        compute_wait = 0.0
        n_queued = 0
        submit_t: dict[Chunk, float] = {}     # compute admission times
        deferred: set[Chunk] = set()          # queued: record at completion
        # mobility loss/resume bookkeeping (inert on static fleets)
        n_lost = 0
        bytes_lost = 0.0
        bytes_restreamed = 0.0
        attempted: set[Chunk] = set()         # chunks with a StreamStart issued
        pending_stream = None                 # (chunk, nbytes, t_proc, is_hit)

        def ready_set():
            return {c for c in comp_q if g.compute_ready(c, state)}

        def controller_boundary():
            # controller migrations at an event boundary (completion or
            # route loss) — shared so a loss gets the same §IV-D
            # stream<->compute refinement a completion does
            nonlocal n_migr
            migr = self.controller.decide(
                now, stream_queue=stream_q, comp_queue=comp_q,
                ready=ready_set() | {cc for cc in stream_q
                                     if g.compute_ready(cc, state)},
                chunk_bytes=self.chunk_bytes,
                t_comp_pred=self.t_comp_pred)
            for m in migr:
                if m.to_path == "compute" and m.chunk in stream_q \
                        and m.chunk not in store_hits:
                    stream_q.remove(m.chunk)
                    comp_q.insert(0, m.chunk)
                    n_migr += 1
                elif m.to_path == "stream" and m.chunk in comp_q:
                    # never strand a compute-assigned dependent: its
                    # layer dep requires this chunk to be *computed*
                    dependent = (m.chunk.l + 1 < g.n_l and
                                 Chunk(m.chunk.t, m.chunk.l + 1,
                                       m.chunk.h) in comp_q)
                    if not dependent:
                        comp_q.remove(m.chunk)
                        stream_q.append(m.chunk)
                        n_migr += 1

        guard = 0
        while done < total:
            guard += 1
            if guard > 50 * total + 1000:
                raise RuntimeError("engine livelock")
            progressed = False
            # start network transfer
            if not net_busy and stream_q:
                c = stream_q.pop(0)
                nbytes = self.chunk_bytes[c]
                t_proc = self.profile.t_proc(nbytes)
                is_hit = c in store_hits
                if is_hit:
                    # cached in the cloud store: ride the cached-egress leg
                    yield StoreHit(c, nbytes, t_proc)
                    n_store_hits += 1
                    bytes_hit_stream += nbytes
                else:
                    if self.store_model is not None:
                        # miss: the origin encodes before it streams
                        # (0.0 at the model's defaults — bit-identical)
                        t_proc += t_store_miss_encode(nbytes,
                                                      self.store_model)
                    yield StreamStart(c, nbytes, t_proc)
                net_busy = True
                inflight += 1
                proc_busy += t_proc
                bytes_streamed += nbytes
                if c in attempted:
                    bytes_restreamed += nbytes
                attempted.add(c)
                pending_stream = (c, nbytes, t_proc, is_hit)
                progressed = True
            # start compute on first ready chunk in priority order
            if not dev_busy:
                started = None
                for i, c in enumerate(comp_q):
                    if g.compute_ready(c, state):
                        started = comp_q.pop(i)
                        break
                if started is not None:
                    u = util_fn() if util_fn is not None else None
                    dt = self._t_comp_actual(started, rng, u)
                    ack = yield ComputeStart(started, dt)
                    dev_busy = True
                    inflight += 1
                    comp_busy += dt
                    submit_t[started] = now
                    if isinstance(ack, StartAck) and ack.t_start is None:
                        # queued on the device server: the actual service
                        # interval arrives with the Completion
                        deferred.add(started)
                    elif self.controller:
                        t0 = ack.t_start if isinstance(ack, StartAck) \
                            else now
                        self.controller.record_compute(
                            t0 + dt, dt, self.t_comp_pred[started])
                    progressed = True
            if inflight == 0:
                if not progressed:
                    if comp_q and not stream_q:
                        # dependency-starved compute chunks (e.g. after a
                        # bad migration): streaming is always feasible
                        stream_q.append(comp_q.pop(0))
                        continue
                    raise RuntimeError("engine stalled")
                continue
            # park until the driver delivers this request's next completion
            ev = yield Wait()
            if isinstance(ev, StreamLost):
                # mid-transfer route loss: roll back the optimistic
                # accounting from this attempt's StreamStart (the bytes
                # never arrived, its decode tail is never paid), wasted
                # wire bytes land in bytes_lost, and the chunk re-enters
                # the head of the stream backlog for re-route / flip
                assert pending_stream is not None \
                    and pending_stream[0] == ev.chunk, (pending_stream, ev)
                c, nbytes, t_proc, is_hit = pending_stream
                pending_stream = None
                inflight -= 1
                net_busy = False
                now = max(now, ev.t_s)
                n_lost += 1
                bytes_lost += ev.nbytes_delivered
                bytes_streamed -= nbytes
                proc_busy -= t_proc
                if is_hit:
                    n_store_hits -= 1
                    bytes_hit_stream -= nbytes
                stream_q.insert(0, c)
                if self.controller is not None:
                    self.controller.note_loss(
                        now, nbytes_lost=ev.nbytes_delivered)
                    controller_boundary()
                continue
            assert isinstance(ev, Completion), ev
            inflight -= 1
            now = max(now, ev.t_end)
            c = ev.chunk
            i = g.index(c)
            timeline.append((ev.t_start, ev.t_end, ev.path, c))
            if ev.path == "stream":
                net_busy = False
                pending_stream = None
                stream_busy += ev.t_end - ev.t_start
                state[i] = State.STREAMED
                streamed_set.add(c)
                if self.controller:
                    self.controller.record_stream(now, self.chunk_bytes[c])
            else:
                dev_busy = False
                state[i] = State.COMPUTED
                computed_set.add(c)
                if c in deferred:
                    deferred.discard(c)
                    wait = max(ev.t_start - submit_t.get(c, ev.t_start),
                               0.0)
                    compute_wait += wait
                    n_queued += 1
                    if self.controller:
                        service = max(ev.t_end - ev.t_start, 1e-9)
                        self.controller.record_compute(
                            ev.t_end, service, self.t_comp_pred[c])
                        self.controller.record_queue_wait(
                            ev.t_end, wait, service)
            done += 1
            # controller migrations at event boundary
            if self.controller is not None:
                controller_boundary()

        if self.max_new_tokens <= 0:
            # first-token-only accounting (bit-identical to pre-decode
            # behaviour): TTFT = context completion + analytic one-token
            # forward; the response "ends" at the first token
            t_first = decode_first_token_seconds(self.cfg_model, context_len,
                                                 self.profile)
            ttft = now + t_first
            meter = EnergyMeter(self.profile,
                                compute_busy_s=comp_busy + t_first,
                                nic_busy_s=stream_busy, wall_s=ttft - t_start)
            return EngineResult(
                ttft_s=ttft, context_done_s=now, energy=meter.breakdown(),
                n_streamed=len(streamed_set), n_computed=len(computed_set),
                n_migrations=n_migr, stream_busy_s=stream_busy,
                compute_busy_s=comp_busy, proc_busy_s=proc_busy,
                timeline=timeline, streamed_set=streamed_set,
                computed_set=computed_set, bytes_streamed=bytes_streamed,
                compute_wait_s=compute_wait, n_compute_queued=n_queued,
                ttlt_s=ttft, token_times=(ttft,),
                n_reused=n_reused, n_store_hits=n_store_hits,
                bytes_hit_stream=bytes_hit_stream,
                n_lost=n_lost, bytes_lost=bytes_lost,
                bytes_restreamed=bytes_restreamed)

        # ---- decode phase: the driver owns token timing (batched) ----
        t_ctx_done = now
        yield DecodeStart(context_len=context_len,
                          n_tokens=self.max_new_tokens)
        token_t: list[float] = []
        decode_busy = 0.0
        while len(token_t) < self.max_new_tokens:
            ev = yield Wait()
            assert isinstance(ev, DecodeTick), ev
            token_t.extend(ev.token_times)
            decode_busy += ev.busy_share_s
            now = max(now, ev.t_end)
        assert len(token_t) == self.max_new_tokens, \
            (len(token_t), self.max_new_tokens)
        ttft, ttlt = token_t[0], token_t[-1]
        n_out = len(token_t)
        meter = EnergyMeter(self.profile,
                            compute_busy_s=comp_busy + decode_busy,
                            nic_busy_s=stream_busy, wall_s=ttlt - t_start)
        return EngineResult(
            ttft_s=ttft, context_done_s=t_ctx_done,
            energy=meter.breakdown(),
            n_streamed=len(streamed_set), n_computed=len(computed_set),
            n_migrations=n_migr, stream_busy_s=stream_busy,
            compute_busy_s=comp_busy, proc_busy_s=proc_busy,
            timeline=timeline, streamed_set=streamed_set,
            computed_set=computed_set, bytes_streamed=bytes_streamed,
            compute_wait_s=compute_wait, n_compute_queued=n_queued,
            n_tokens_out=n_out, ttlt_s=ttlt,
            tpot_s=(ttlt - ttft) / max(n_out - 1, 1),
            decode_busy_s=decode_busy, token_times=tuple(token_t),
            n_reused=n_reused, n_store_hits=n_store_hits,
            bytes_hit_stream=bytes_hit_stream,
            n_lost=n_lost, bytes_lost=bytes_lost,
            bytes_restreamed=bytes_restreamed)

    # ------------------------------------------------------------------
    # Classic single-request driver (exclusive link + device)
    # ------------------------------------------------------------------
    def run(self, schedule: Schedule, *, context_len: int) -> EngineResult:
        gen = self.session(schedule, context_len=context_len)
        now = 0.0
        # at most one stream + one compute in flight for a single request
        inflight: list[tuple[float, float, str, Chunk]] = []
        pending_decode: Optional[DecodeDone] = None
        try:
            ev = next(gen)
            while True:
                if isinstance(ev, StreamStart):
                    t_end = self.bw.finish_time(now, ev.nbytes) + ev.t_proc
                    inflight.append((t_end, now, "stream", ev.chunk))
                    ev = gen.send(None)
                elif isinstance(ev, StoreHit):
                    # classic driver has no shared egress stage to bypass;
                    # the hit still pays the store's service latency
                    lat = (self.store_model.hit_latency_s
                           if self.store_model is not None else 0.0)
                    t_end = (self.bw.finish_time(now, ev.nbytes)
                             + ev.t_proc + lat)
                    inflight.append((t_end, now, "stream", ev.chunk))
                    ev = gen.send(None)
                elif isinstance(ev, ComputeStart):
                    inflight.append((now + ev.duration_s, now, "compute",
                                     ev.chunk))
                    ev = gen.send(None)
                elif isinstance(ev, DecodeStart):
                    # exclusive device: serial batch-of-1 decode, one step
                    # per token over the growing context
                    ts, t, busy = [], now, 0.0
                    for i in range(ev.n_tokens):
                        dt = decode_step_seconds(
                            self.cfg_model, [ev.context_len + i],
                            self.profile)
                        t += dt
                        busy += dt
                        ts.append(t)
                    pending_decode = DecodeDone(
                        t_start=now, t_end=t, token_times=tuple(ts),
                        batch_size=1, busy_share_s=busy)
                    ev = gen.send(None)
                elif pending_decode is not None:        # Wait (decoding)
                    now = pending_decode.t_end
                    ev = gen.send(pending_decode)
                    pending_decode = None
                else:                                   # Wait
                    inflight.sort(key=lambda e: e[0])
                    t_end, t_st, path, c = inflight.pop(0)
                    now = max(now, t_end)
                    ev = gen.send(Completion(path, c, t_st, now))
        except StopIteration as stop:
            return stop.value
