"""KV chunk index space and the Transformer dependency structure (Fig. 7).

A chunk is c = (t, l, h): token-block t in [0, T), layer l in [0, L),
head h in [0, H). Two scheduler granularities (DESIGN.md §2):

  mode="paper":  both paths schedule (t, l, h) — the paper's Eq. 2-5 exactly.
  mode="engine": compute units are (t, l) (a layer physically advances all
                 heads at once); streaming stays per-head. Internally the
                 engine grid uses H=1 with per-head costs aggregated.

Dependency rules for *computing* chunk (t, l):
  token dep  : t == 0 or l == L-1  -> free; else (t-1, l) present
               (streamed or computed — induction gives all t' < t present).
  layer dep  : l == 0 -> free; else (t, l-1) locally *computed*
               (the hidden state Y_{l-1}^t only exists on the compute path).
Layer L-1 is a pure projection of Y_{L-2}^t (no horizontal dep).
Streaming a chunk has no dependencies.
"""
from __future__ import annotations

import dataclasses
import hashlib
from enum import IntEnum
from typing import Iterable, NamedTuple, Optional

import numpy as np


class Chunk(NamedTuple):
    t: int
    l: int  # noqa: E741
    h: int

    def __repr__(self):
        return f"c({self.t},{self.l},{self.h})"


class State(IntEnum):
    PENDING = 0
    STREAMED = 1
    COMPUTED = 2


# ---------------------------------------------------------------------------
# Content-addressed chunk identity (cross-request KV reuse)
# ---------------------------------------------------------------------------
#
# With causal attention the KV of token-block t depends only on the prefix
# up to and including t, so two requests share chunk (t, l) KV exactly when
# their token prefixes through block t are identical. Callers therefore
# feed a *prefix-closed* span id: the id of block t must encode the whole
# prefix 0..t (a hash chain — see repro.serving.traffic), not just block
# t's own tokens. The per-chunk content key further binds the model, the
# quantization width and the chunking, because a stored bitstream is only
# reusable for a byte-identical decode: the same token span encoded at
# different bits (or split at a different chunk_tokens) is a different
# artifact and must hash to a distinct key.


def span_content_id(token_bytes: bytes, prev_id: int = 0) -> int:
    """Prefix-closed content id of one token block: hash of the block's
    raw token bytes chained with the id of the preceding block. Stable
    across processes (blake2b, not Python's salted hash)."""
    h = hashlib.blake2b(digest_size=8)
    h.update(prev_id.to_bytes(8, "little", signed=False))
    h.update(token_bytes)
    return int.from_bytes(h.digest(), "little")


def chunk_content_key(span_id: int, layer: int, *, model: str, bits: int,
                      chunk_tokens: int, head: int = 0) -> int:
    """Stable 64-bit content key of one KV chunk artifact: the
    prefix-closed token-span id plus everything that shapes the encoded
    bytes (model config, quantization bits, chunking, head). Equal keys
    <=> byte-identical reusable artifacts."""
    h = hashlib.blake2b(digest_size=8)
    h.update(int(span_id).to_bytes(8, "little", signed=False))
    for v in (layer, head, bits, chunk_tokens):
        h.update(int(v).to_bytes(4, "little", signed=True))
    h.update(model.encode())
    return int.from_bytes(h.digest(), "little")


@dataclasses.dataclass
class ChunkGrid:
    n_t: int
    n_l: int
    n_h: int = 1

    def __post_init__(self):
        assert self.n_t >= 1 and self.n_l >= 1 and self.n_h >= 1

    @property
    def size(self) -> int:
        return self.n_t * self.n_l * self.n_h

    def chunks(self) -> Iterable[Chunk]:
        for t in range(self.n_t):
            for l in range(self.n_l):
                for h in range(self.n_h):
                    yield Chunk(t, l, h)

    def index(self, c: Chunk) -> int:
        return (c.t * self.n_l + c.l) * self.n_h + c.h

    # ---- dependencies ----
    def token_pred(self, c: Chunk) -> Optional[Chunk]:
        """Predecessor whose presence (any path) gates compute; None if free."""
        if c.t == 0 or c.l == self.n_l - 1:
            return None
        return Chunk(c.t - 1, c.l, c.h)

    def layer_pred(self, c: Chunk) -> Optional[Chunk]:
        """Predecessor that must be *computed*; None if free."""
        if c.l == 0:
            return None
        return Chunk(c.t, c.l - 1, c.h)

    def compute_ready(self, c: Chunk, state: np.ndarray) -> bool:
        """state: int array indexed by self.index, values from State."""
        tp = self.token_pred(c)
        if tp is not None and state[self.index(tp)] == State.PENDING:
            return False
        lp = self.layer_pred(c)
        if lp is not None and state[self.index(lp)] != State.COMPUTED:
            return False
        return True

    def enabled_by_stream(self, c: Chunk, state: np.ndarray) -> list[Chunk]:
        """A_s(c): chunks newly compute-ready if c is streamed now."""
        out = []
        # streaming c can only satisfy the token dep of (t+1, l, h)
        if c.t + 1 < self.n_t and c.l < self.n_l - 1:
            succ = Chunk(c.t + 1, c.l, c.h)
            if state[self.index(succ)] == State.PENDING:
                lp = self.layer_pred(succ)
                if lp is None or state[self.index(lp)] == State.COMPUTED:
                    out.append(succ)
        return out

    def enabled_by_compute(self, c: Chunk, state: np.ndarray) -> list[Chunk]:
        """A_c(c): chunks newly compute-ready if c is computed now."""
        out = self.enabled_by_stream(c, state)  # token dep, same successor
        # computing c can satisfy the layer dep of (t, l+1, h)
        if c.l + 1 < self.n_l:
            succ = Chunk(c.t, c.l + 1, c.h)
            if state[self.index(succ)] == State.PENDING:
                tp = self.token_pred(succ)
                if tp is None or state[self.index(tp)] != State.PENDING:
                    out.append(succ)
        return out

    def initial_ready(self) -> list[Chunk]:
        """Only (t=0, l=0, h) are compute-ready at the start (paper §IV-B)."""
        return [Chunk(0, 0, h) for h in range(self.n_h)]

    def validate_schedule(self, events: list[tuple[Chunk, bool]]) -> bool:
        """events: ordered (chunk, is_compute). True iff dependency-legal
        and every chunk processed exactly once."""
        state = np.zeros(self.size, np.int8)
        for c, is_comp in events:
            i = self.index(c)
            if state[i] != State.PENDING:
                return False
            if is_comp and not self.compute_ready(c, state):
                return False
            state[i] = State.COMPUTED if is_comp else State.STREAMED
        return bool((state != State.PENDING).all())
