"""Per-chunk streaming / computation cost models + energy accounting.

Two roles:
 1. *Planning* costs (what the scheduler sees): t_stream from compressed
    chunk bytes and profiled mean bandwidth (paper Eq. under (1)); t_comp
    from the latency predictor (core.predictor).
 2. *Ground truth* (what the simulated device does): a nonlinear
    block-sparse-attention latency function with launch inefficiency,
    utilization slowdown and noise — the thing the MLP learns and the
    analytical roofline baseline fails to capture (paper §IV-C / Fig. 8).

Device profiles: the paper's edge platforms plus a TPU-v5e single-chip
profile (our deployment target).

Shared-resource models (:class:`SharedLinkModel`, :class:`RunQueueModel`)
parameterize the serving layer's resource servers
(``repro.serving.resources``): contention efficiency for fair-shared
links, slot count + discipline for the explicit device run queue.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DeviceProfile:
    name: str
    peak_flops: float            # dense peak
    hbm_bw: float                # bytes/s
    compute_power_w: float       # active compute power
    nic_power_w: float           # active NIC power
    idle_power_w: float
    # block-sparse attention non-idealities (ground truth)
    eff_max: float               # peak fraction attainable by the kernel
    s_half: float                # active-block count at half efficiency
    util_slowdown: float         # slope of contention slowdown
    kernel_overhead_s: float     # fixed per-chunk launch overhead
    proc_fixed_s: float          # fixed per-chunk post-reception overhead
    decode_bw: float             # entropy-decode + dequant throughput (B/s)
    t_proj_s: float              # final-layer projection-only chunk

    def t_proc(self, nbytes: float) -> float:
        """Post-reception decode + dequant time for one chunk."""
        return self.proc_fixed_s + nbytes / self.decode_bw


PROFILES: dict[str, DeviceProfile] = {
    # numbers chosen to land in the paper's measured ranges (Table I, Fig. 3)
    "jetson-orin": DeviceProfile(
        "jetson-orin", peak_flops=20e12, hbm_bw=102e9,
        compute_power_w=25.0, nic_power_w=2.5, idle_power_w=5.0,
        eff_max=0.060, s_half=24.0, util_slowdown=0.65,
        kernel_overhead_s=9e-5, proc_fixed_s=8e-5, decode_bw=250e6,
        t_proj_s=1.2e-4),
    "jetson-agx": DeviceProfile(
        "jetson-agx", peak_flops=40e12, hbm_bw=205e9,
        compute_power_w=30.0, nic_power_w=2.5, idle_power_w=8.0,
        eff_max=0.068, s_half=20.0, util_slowdown=0.60,
        kernel_overhead_s=7e-5, proc_fixed_s=6e-5, decode_bw=350e6,
        t_proj_s=9e-5),
    "laptop-5080": DeviceProfile(
        "laptop-5080", peak_flops=110e12, hbm_bw=640e9,
        compute_power_w=28.0 * 4, nic_power_w=2.0, idle_power_w=15.0,
        eff_max=0.080, s_half=16.0, util_slowdown=0.55,
        kernel_overhead_s=4e-5, proc_fixed_s=3e-5, decode_bw=800e6,
        t_proj_s=5e-5),
    "redmi-k80": DeviceProfile(
        "redmi-k80", peak_flops=8e12, hbm_bw=68e9,
        compute_power_w=9.0, nic_power_w=2.8, idle_power_w=2.0,
        eff_max=0.050, s_half=30.0, util_slowdown=0.75,
        kernel_overhead_s=1.5e-4, proc_fixed_s=1.2e-4, decode_bw=120e6,
        t_proj_s=2e-4),
    "tpu-v5e-1chip": DeviceProfile(
        "tpu-v5e-1chip", peak_flops=197e12, hbm_bw=819e9,
        compute_power_w=170.0, nic_power_w=5.0, idle_power_w=60.0,
        eff_max=0.450, s_half=12.0, util_slowdown=0.45,
        kernel_overhead_s=2.5e-5, proc_fixed_s=1e-5, decode_bw=2e9,
        t_proj_s=3e-5),
}


@dataclasses.dataclass(frozen=True)
class NetworkProfile:
    name: str
    mean_bw: float               # bytes/s
    std_bw: float
    corr_tau_s: float = 0.8      # OU-process correlation time
    floor_bw: float = 2e6

    def trace(self, rng: np.random.Generator, duration_s: float,
              dt: float = 0.01) -> np.ndarray:
        """Ornstein-Uhlenbeck bandwidth trace, clipped at floor."""
        n = int(np.ceil(duration_s / dt)) + 1
        out = np.empty(n)
        x = self.mean_bw
        a = dt / self.corr_tau_s
        sig = self.std_bw * np.sqrt(2 * a)
        for i in range(n):
            out[i] = x
            x = x + a * (self.mean_bw - x) + sig * rng.normal()
        return np.maximum(out, self.floor_bw)


@dataclasses.dataclass(frozen=True)
class SharedLinkModel:
    """Shared last-hop link serving N concurrent KV streams.

    One capacity trace (a ``NetworkProfile``) is fair-shared among active
    flows; contention is not free — per-flow protocol overhead (MAC
    contention, cwnd thrash, header amplification) shaves the *aggregate*
    goodput as flows are added:

        eta(n) = max(min_efficiency, 1 - contention_overhead * (n - 1))
        per-flow share(n) = eta(n) / n

    ``eta(1) == 1`` so a single flow reproduces exclusive-link semantics
    exactly (the serving cluster degenerates to the classic per-request
    engine). Used by ``repro.serving.cluster.SharedLinkArbiter``.
    """
    profile: NetworkProfile
    contention_overhead: float = 0.05
    min_efficiency: float = 0.65

    def aggregate_efficiency(self, n_flows: int) -> float:
        if n_flows <= 1:
            return 1.0
        return max(self.min_efficiency,
                   1.0 - self.contention_overhead * (n_flows - 1))

    def per_flow_fraction(self, n_flows: int) -> float:
        """Fraction of the instantaneous trace capacity one flow gets."""
        if n_flows <= 0:
            return 1.0
        return self.aggregate_efficiency(n_flows) / n_flows


NETWORKS: dict[str, NetworkProfile] = {
    # paper §III: cloud-to-device 850 +- 264 Mbps
    "campus-wifi": NetworkProfile("campus-wifi", 850e6 / 8, 264e6 / 8),
    # paper §VI: Wi-Fi 6 testbed end-to-end 0.64 Gbps
    "wifi6-cloud": NetworkProfile("wifi6-cloud", 640e6 / 8, 200e6 / 8),
    # congested variants for Fig. 13 (scalar stand-ins; the two-stage
    # LinkTopology models the same scenarios structurally)
    "congested-2dev": NetworkProfile("congested-2dev", 760e6 / 8, 330e6 / 8),
    "congested-5dev": NetworkProfile("congested-5dev", 660e6 / 8, 470e6 / 8),
    # per-device NIC / last-metre hop for two-stage topologies: a device
    # radio is steadier than the contended AP uplink but not much faster,
    # so with 1 flow the NIC bottlenecks and with >= 2 flows the shared
    # uplink does — the crossover the Fig. 13 congested-AP study probes
    "device-nic": NetworkProfile("device-nic", 600e6 / 8, 60e6 / 8,
                                 corr_tau_s=1.5),
    # cloud-egress trunk for three-hop trees (NIC -> AP uplink ->
    # egress): a wired hop shared by *all* APs — generously provisioned
    # for a handful of flows, the fleet-wide bottleneck once enough APs
    # pull concurrently (the bench_topology_tree starved-egress study
    # dials the mean down further)
    "cloud-egress": NetworkProfile("cloud-egress", 1.6e9 / 8, 200e6 / 8,
                                   corr_tau_s=0.5),
    # datacenter-ish for the TPU profile
    "dcn-25g": NetworkProfile("dcn-25g", 25e9 / 8, 2e9 / 8, corr_tau_s=0.2),
}


@dataclasses.dataclass(frozen=True)
class RunQueueModel:
    """Configuration of the explicit device run queue (the queueing
    counterpart of :class:`SharedLinkModel`): ``capacity`` parallel
    service slots and a scheduling ``discipline``:

      - ``"fifo"`` — jobs start in global submission order;
      - ``"wfq"``  — weighted fair queueing across request flows (a flow
        with weight w gets a ~w-proportional share of device time under
        backlog);
      - ``"srpt"`` — shortest-remaining-first across flows, preemptive
        at chunk boundaries, with a deadline floor so long flows are
        deferred but never starved past their TTFT deadline
        (``deadline_floor_s``: a queued job whose deadline is within
        this window of now preempts the SRPT order, EDF-first).

    Consumed by ``repro.serving.resources.DeviceRunQueue``. When a
    cluster runs with a RunQueueModel, compute contention is expressed as
    *waiting* (queueing delay) instead of the scalar ``util`` dilation of
    :meth:`GroundTruthLatency.attn_seconds` — the engine then receives
    util 0 for fleet-internal contention."""
    capacity: int = 1
    discipline: str = "fifo"
    deadline_floor_s: float = 0.5

    def __post_init__(self):
        assert self.capacity >= 1, self.capacity
        assert self.discipline in ("fifo", "wfq", "srpt"), self.discipline
        assert self.deadline_floor_s >= 0, self.deadline_floor_s


# ---------------------------------------------------------------------------
# KV memory: disk tier + per-device memory-server configuration
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class DiskTierProfile:
    """Bandwidth/latency profile of the local storage tier backing the
    KV memory server (DRAM -> disk demotion, KVSwap-style). Unlike the
    fluid link stages, disk transfers are modeled as a serial FIFO
    server (``repro.serving.resources.DiskServer``): one transfer at a
    time, each paying a fixed per-op latency plus bytes over the
    direction's sequential bandwidth — the access pattern KV demotion
    and reload actually produce (large sequential extents)."""
    name: str
    read_bw: float               # bytes/s, sequential read
    write_bw: float              # bytes/s, sequential write
    latency_s: float = 1.5e-4    # fixed per-op submission latency


DISK_TIERS: dict[str, DiskTierProfile] = {
    # mobile UFS 3.1 (sequential ~1.8/0.9 GB/s) — the default edge tier
    "ufs-3.1": DiskTierProfile("ufs-3.1", 1.8e9, 0.9e9, 1.5e-4),
    # NVMe on an edge box / laptop
    "nvme-edge": DiskTierProfile("nvme-edge", 3.5e9, 2.5e9, 8e-5),
    # older phones: eMMC 5.1 sequential ~300/150 MB/s
    "emmc-5.1": DiskTierProfile("emmc-5.1", 0.30e9, 0.15e9, 4e-4),
}


def t_disk_read(nbytes: float, disk: DiskTierProfile,
                n_ops: int = 1) -> float:
    """Service time of a disk-tier read (no queueing): per-op latency
    plus bytes over the sequential read bandwidth."""
    return n_ops * disk.latency_s + nbytes / disk.read_bw


def t_disk_write(nbytes: float, disk: DiskTierProfile,
                 n_ops: int = 1) -> float:
    """Service time of a disk-tier write (no queueing)."""
    return n_ops * disk.latency_s + nbytes / disk.write_bw


@dataclasses.dataclass(frozen=True)
class MemoryModel:
    """Configuration of the per-device KV memory server
    (``repro.serving.memory.KVMemoryServer``) — the memory counterpart
    of :class:`SharedLinkModel` / :class:`RunQueueModel`.

    Parameters
    ----------
    capacity_bytes : DRAM budget for resident KV on each device; ``None``
        tracks residency (peak/percentile telemetry) without ever
        evicting — bit-identical traces to a cluster without a memory
        server.
    policy : victim selection under pressure —
        ``"lru"`` (least-recently-used among ready, unpinned residents),
        ``"idle"`` (longest-idle among sequences *outside* the active
        decode batch first — never thrashes a decoding sequence while a
        parked one can pay instead; falls back to LRU when every
        candidate is active), or
        ``"bits"`` (evict-to-lower-bits: requantize the LRU victim's
        resident KV down the ``compression.quantize.BITRATE_LEVELS``
        ladder in place — the sequence keeps decoding at reduced
        fidelity — and only demote/drop once it hits the ladder floor).
    disk : backing tier for demotion — a :class:`DiskTierProfile`, a
        ``DISK_TIERS`` name, or ``None`` (no tier: eviction drops the KV
        outright and reload must restream or recompute).
    reload : how an evicted context is restored —
        ``"planner"`` (per-chunk overhead-aware split across disk read /
        cloud restream / local recompute, greedy LPT over the projected
        path loads — the SparKV decision re-posed at reload time),
        ``"restream"`` / ``"recompute"`` / ``"disk"`` (single-path
        baselines; ``"disk"`` falls back to restream when the KV was
        dropped without a disk copy).
    gate_frac : admission gate — hold a queued arrival while projected
        residency (current + the request's full context) exceeds
        ``gate_frac * capacity_bytes``; ``None`` disables gating. The
        gate never holds an empty device (no deadlock).
    resident_bits : bit-width resident KV is accounted at before any
        evict-to-lower-bits downgrade (16 = bf16, the engine's decode
        cost model assumption).
    cold_frac : the "bits" policy's cold-pool fraction — the share of a
        victim's resident KV (its low-saliency chunks) requantized
        first under pressure; the hot remainder only degrades once the
        cold pool reaches the ladder floor. 1.0 (default) downgrades
        the whole resident at once, exactly the pre-cold-pool behavior.
    """
    capacity_bytes: Optional[float] = None
    policy: str = "lru"
    disk: object = "ufs-3.1"      # DiskTierProfile | name | None
    reload: str = "planner"
    gate_frac: Optional[float] = None
    resident_bits: int = 16
    # fraction of a resident's KV treated as cold (low-saliency) by the
    # "bits" eviction policy: pressure downgrades only the cold pool
    # until it hits the ladder floor, then the hot remainder. 1.0
    # (default) downgrades the whole resident at once — the exact
    # pre-cold-pool behavior.
    cold_frac: float = 1.0

    def __post_init__(self):
        assert self.capacity_bytes is None or self.capacity_bytes > 0
        assert self.policy in ("lru", "idle", "bits"), self.policy
        assert self.reload in ("planner", "restream", "recompute",
                               "disk"), self.reload
        if isinstance(self.disk, str):
            assert self.disk in DISK_TIERS, self.disk
        assert self.gate_frac is None or 0 < self.gate_frac
        assert self.resident_bits > 0
        assert 0.0 < self.cold_frac <= 1.0, self.cold_frac

    @property
    def disk_profile(self) -> Optional[DiskTierProfile]:
        if self.disk is None:
            return None
        return DISK_TIERS[self.disk] if isinstance(self.disk, str) \
            else self.disk


@dataclasses.dataclass(frozen=True)
class KVStoreModel:
    """Configuration of the cloud-side content-addressed KV store
    (``repro.serving.kvstore.CloudKVStore``) and the per-device prefix
    cache — the cross-request reuse counterpart of :class:`MemoryModel`.

    Hit economics: the store caches, per content key, the transfer-ready
    encoded bitstream replicated to the edge of the cloud path. A **hit**
    replaces the encode+stream cost with a per-hit egress cost
    (:func:`t_store_hit`): the cached bytes skip the cloud-side encode
    pipeline and, on tree topologies with a cloud-egress stage, bypass
    that shared stage entirely (the bytes are already at the AP side of
    it). A **miss** is the ordinary origin path — with the default
    ``encode_fixed_s=0`` / ``encode_bw=None`` it is bit-identical to a
    store-less fleet (registration-time artifacts are pre-encoded, the
    pre-reuse semantics); arming the encode knobs charges misses the
    cloud-side quantize+entropy-encode latency before their bytes hit
    the wire. A **device prefix hit** (the requesting device still holds
    the chunk's assembled KV from an earlier turn) costs nothing on the
    link at all.

    Parameters
    ----------
    capacity_bytes : cloud store budget for cached bitstreams; ``None``
        is unbounded. Residency never exceeds this (LRU/LFU eviction on
        insert; an artifact larger than the whole store is refused).
    policy : ``"lru"`` | ``"lfu"`` victim selection.
    hit_latency_s : store lookup + cached read latency added to each hit
        chunk's device-side tail.
    device_capacity_bytes : per-device prefix-cache budget (assembled KV
        a device keeps addressable across turns); ``None`` defers to the
        KV memory server when one is armed, else unbounded.
    encode_fixed_s / encode_bw : per-chunk cloud-side encode launch
        overhead and throughput (bytes/s) charged on a miss. Defaults
        (0.0 / ``None`` = free) keep the miss path bit-identical to a
        store-less fleet.
    """
    capacity_bytes: Optional[float] = None
    policy: str = "lru"
    hit_latency_s: float = 2e-4
    device_capacity_bytes: Optional[float] = None
    encode_fixed_s: float = 0.0
    encode_bw: Optional[float] = None

    def __post_init__(self):
        assert self.capacity_bytes is None or self.capacity_bytes > 0
        assert self.policy in ("lru", "lfu"), self.policy
        assert self.hit_latency_s >= 0 and self.encode_fixed_s >= 0
        assert self.encode_bw is None or self.encode_bw > 0
        assert self.device_capacity_bytes is None \
            or self.device_capacity_bytes > 0


def t_store_hit(chunk_bytes: float, mean_bw: float, profile,
                store: KVStoreModel) -> float:
    """Per-hit egress cost of a cached chunk: store read latency + the
    cached bitstream over the (egress-bypassing) link + the on-device
    decode tail. Replaces encode+stream for content-key hits."""
    return store.hit_latency_s + chunk_bytes / mean_bw \
        + profile.t_proc(chunk_bytes)


def t_store_miss_encode(chunk_bytes: float, store: KVStoreModel) -> float:
    """Cloud-side encode latency a store miss pays before its first byte
    egresses. Exactly 0.0 at the defaults (pre-encoded artifacts), so a
    0%-hit fleet stays bit-identical to a store-less one."""
    if store.encode_bw is None:
        return store.encode_fixed_s
    return store.encode_fixed_s + chunk_bytes / store.encode_bw


# ---------------------------------------------------------------------------
# Ground-truth chunk latency (the simulated device)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class GroundTruthLatency:
    """Nonlinear block-sparse attention latency. Deliberately NOT the
    roofline form: efficiency saturates with active blocks, contention
    multiplies, noise is lognormal."""
    profile: DeviceProfile
    head_dim: int
    q_block: int = 128
    kv_block: int = 128
    chunk_tokens: int = 1024
    dtype_bytes: int = 2
    noise_sigma: float = 0.05

    def block_flops(self) -> float:
        # qk^T + pv per (q_block, kv_block) tile
        return 4.0 * self.q_block * self.kv_block * self.head_dim

    def attn_seconds(self, active_blocks: float, util: float,
                     rng: Optional[np.random.Generator] = None) -> float:
        p = self.profile
        s = max(float(active_blocks), 0.0)
        eff = p.eff_max * s / (s + p.s_half)
        work = self.block_flops() * s
        t = work / (p.peak_flops * max(eff, 1e-3)) + p.kernel_overhead_s
        t *= 1.0 + p.util_slowdown * float(util) / max(1 - 0.9 * float(util),
                                                       0.1)
        if rng is not None:
            t *= float(np.exp(rng.normal(0.0, self.noise_sigma)))
        return t

    def dense_seconds(self, cfg) -> float:
        """Per-chunk non-attention ops (qkv/o proj, norm, FFN) — near-
        constant offset (paper §IV-C)."""
        d = cfg.d_model
        ff = 3 if cfg.activation in ("swiglu", "geglu") else 2
        d_ff_active = (cfg.d_ff if cfg.moe is None
                       else cfg.d_ff * cfg.moe.experts_per_token)
        flops = 2 * self.chunk_tokens * (
            d * (cfg.num_heads + 2 * cfg.num_kv_heads)
            * cfg.resolved_head_dim
            + cfg.num_heads * cfg.resolved_head_dim * d
            + ff * d * d_ff_active)
        return flops / (self.profile.peak_flops * 0.65)

    def roofline_estimate(self, active_blocks: float) -> float:
        """The analytical baseline the paper compares against: ignores
        launch inefficiency, fragmentation and contention."""
        p = self.profile
        s = max(float(active_blocks), 0.0)
        w = self.block_flops() * s
        q = s * self.kv_block * self.head_dim * 2 * self.dtype_bytes \
            + self.chunk_tokens * self.head_dim * self.dtype_bytes
        return max(w / p.peak_flops, q / p.hbm_bw)


# ---------------------------------------------------------------------------
# Streaming cost
# ---------------------------------------------------------------------------


def t_stream(chunk_bytes: float, mean_bw: float, profile) -> float:
    """Paper: t_stream(c) = b_c / bw-bar + t_proc(c)."""
    return chunk_bytes / mean_bw + profile.t_proc(chunk_bytes)


def chunk_bytes_at_bits(nbytes: float, from_bits: float,
                        to_bits: float) -> float:
    """Wire/resident bytes of a chunk re-expressed at another
    quantization width: payload scales linearly in bits (the per-group
    header share is folded in — it is <2% at the measured group sizes).
    The single byte<->bits model every per-chunk-bits consumer (planner
    scaling, SLO cold downgrade, memory requantization) shares, so their
    accounting can never drift apart."""
    return nbytes * to_bits / from_bits


# ---------------------------------------------------------------------------
# Energy accounting
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class EnergyMeter:
    profile: DeviceProfile
    compute_busy_s: float = 0.0
    nic_busy_s: float = 0.0
    wall_s: float = 0.0

    def energy_j(self) -> float:
        p = self.profile
        return (p.compute_power_w * self.compute_busy_s
                + p.nic_power_w * self.nic_busy_s
                + p.idle_power_w * self.wall_s)

    def breakdown(self) -> dict:
        p = self.profile
        return {
            "compute_j": p.compute_power_w * self.compute_busy_s,
            "nic_j": p.nic_power_w * self.nic_busy_s,
            "idle_j": p.idle_power_w * self.wall_s,
            "total_j": self.energy_j(),
        }
