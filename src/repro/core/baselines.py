"""End-to-end context-loading pipelines: SparKV and the paper's baselines.

Every pipeline maps (model cfg, workload stats, device profile, network
profile) -> EngineResult via the shared discrete-event engine, so TTFT and
energy numbers are directly comparable:

  sparkv         potential-aware greedy + runtime controller (§IV)
  strong_hybrid  fixed positional split overlap [25] + same compression
  cachegen       stream-only, bitrate ladder chosen from profiled bw (SLO)
  kivi           stream-only, fixed asymmetric low-bit quantization
  local_prefill  compute-only with block-sparse attention

Quality is reported as a relative response-quality score: computed chunks
are exact; streamed chunks carry the quantization level's fidelity (the
bits->fidelity curve is validated against real-model logit agreement in
benchmarks/bench_quality_validation.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.configs.base import SparKVConfig
from repro.core.chunks import Chunk, ChunkGrid
from repro.core.controller import RuntimeController
from repro.core.costs import (GroundTruthLatency, KVStoreModel,
                              NetworkProfile, PROFILES, chunk_bytes_at_bits,
                              t_store_hit, t_stream)
from repro.core.engine import BandwidthIntegrator, HybridEngine
from repro.core.predictor import LatencyPredictor
from repro.core import scheduler as sched
from repro.data.workloads import WorkloadChunks

# bits -> relative response-quality of streamed KV (validated in
# bench_quality_validation; paper operates at >= 0.9 F1). Total over
# every width in 2..8: per-chunk allocation keys this map by arbitrary
# snapped widths, and totality is the backstop for any pre-snap caller.
QUALITY_OF_BITS = {8: 1.0, 7: 0.9985, 6: 0.997, 5: 0.992, 4: 0.968,
                   3: 0.89, 2: 0.72}


@dataclasses.dataclass
class PipelineResult:
    name: str
    ttft_s: float
    energy_j: float
    quality: float
    engine: object
    extras: dict = dataclasses.field(default_factory=dict)


def _engine_grid(cfg, wl: WorkloadChunks, spcfg: SparKVConfig):
    """Scheduling grid. scheduler_mode="paper" keeps the paper's (t, l, h)
    granularity (per-head streaming heterogeneity is the point — Fig. 4);
    "engine" aggregates heads into physically-computable (t, l) units
    (the concrete serving engine always uses n_h == 1 workloads)."""
    if spcfg.scheduler_mode == "paper" and wl.n_h > 1:
        return _paper_grid(cfg, wl)
    grid = ChunkGrid(n_t=wl.n_t, n_l=wl.n_l, n_h=1)
    bytes_map, active_map = {}, {}
    for t in range(wl.n_t):
        for l in range(wl.n_l):
            c = Chunk(t, l, 0)
            bytes_map[c] = float(wl.chunk_bytes[t, l].sum())
            active_map[c] = float(wl.active_blocks[t, l].sum())
    return grid, bytes_map, active_map


def _paper_grid(cfg, wl: WorkloadChunks):
    grid = ChunkGrid(n_t=wl.n_t, n_l=wl.n_l, n_h=wl.n_h)
    bytes_map, active_map = {}, {}
    for c in grid.chunks():
        bytes_map[c] = float(wl.chunk_bytes[c.t, c.l, c.h])
        active_map[c] = float(wl.active_blocks[c.t, c.l, c.h])
    return grid, bytes_map, active_map


@dataclasses.dataclass
class Planner:
    """Planning costs (what the scheduler believes)."""
    grid: ChunkGrid
    ts: np.ndarray
    tc: np.ndarray
    predictor: LatencyPredictor

    @classmethod
    def build(cls, cfg, grid, bytes_map, active_map, profile_name: str,
              net: NetworkProfile, spcfg: SparKVConfig, *, util: float = 0.0,
              predictor: Optional[LatencyPredictor] = None):
        profile = PROFILES[profile_name]
        pred = predictor or _predictor_cache(cfg, profile_name)
        ts = np.zeros(grid.size)
        tc = np.zeros(grid.size)
        t_idx = np.array([c.t for c in grid.chunks()], float)
        layers = np.array([c.l for c in grid.chunks()])
        act = np.array([active_map[c] for c in grid.chunks()], float)
        tc = pred.t_comp_batch(t_idx, layers, act, util)
        if grid.n_h > 1:
            # per-head units: attn(head blocks) + dense share of the layer
            tc = tc - pred.t_dense * (1 - 1.0 / grid.n_h)
        for i, c in enumerate(grid.chunks()):
            ts[i] = t_stream(bytes_map[c], net.mean_bw, profile)
        return cls(grid=grid, ts=ts, tc=tc, predictor=pred)


_PRED_CACHE: dict = {}


def _predictor_cache(cfg, profile_name: str) -> LatencyPredictor:
    key = (cfg.name, profile_name)
    if key not in _PRED_CACHE:
        p = LatencyPredictor(cfg, PROFILES[profile_name])
        p.fit(4000, epochs=150)
        _PRED_CACHE[key] = p
    return _PRED_CACHE[key]


def _run_engine(cfg, grid, bytes_map, active_map, planner, schedule,
                profile_name, net, spcfg, *, util=0.0, controller=None,
                seed=0, context_len, bw_seed=0):
    profile = PROFILES[profile_name]
    rng = np.random.default_rng(bw_seed)
    total_bytes = sum(bytes_map.values())
    horizon = max(20.0, 4 * total_bytes / net.mean_bw + 10)
    trace = net.trace(rng, horizon)
    bw = BandwidthIntegrator(trace, 0.01)
    gt = GroundTruthLatency(profile, cfg.resolved_head_dim
                            if cfg.num_heads else 64)
    t_pred = {c: planner.tc[i] for i, c in enumerate(grid.chunks())}
    eng = HybridEngine(grid=grid, chunk_bytes=bytes_map,
                       active_blocks=active_map, t_comp_pred=t_pred,
                       gt=gt, profile=profile, bw=bw, cfg_model=cfg,
                       util=util, controller=controller, seed=seed)
    return eng.run(schedule, context_len=context_len)


@dataclasses.dataclass(frozen=True)
class ChunkReuse:
    """Resolved cross-request reuse for one request at admission: `local`
    chunks are already resident on the device (prefix cache — near-free),
    `store` chunks are cloud-store hits (stream the cached bitstream over
    the egress-free leg, costed by :func:`repro.core.costs.t_store_hit`
    under `model`). Disjoint sets; everything else is a miss."""
    local: frozenset = frozenset()
    store: frozenset = frozenset()
    model: Optional[KVStoreModel] = None


@dataclasses.dataclass
class RequestPlan:
    """Everything the engine needs to execute one request under a given
    policy — the planning half of a pipeline, without running it. Used by
    the multi-request cluster (repro.serving.cluster), which drives many
    plans against shared resource servers (link topology + device run
    queues) on one clock instead of calling the closed run_* loops. The
    ``util`` the plan was built with is the predictor's U feature at
    admission — the cluster sources it from live telemetry (queue
    occupancy / in-flight compute), not a hand-set dial."""
    policy: str
    grid: ChunkGrid
    bytes_map: dict
    active_map: dict
    planner: Planner
    schedule: object
    controller: Optional[RuntimeController]
    quality_bits: int
    context_len: int
    # cross-request reuse legs (empty = no reuse layer; defaults keep
    # pre-reuse plans bit-identical)
    reuse_local: frozenset = frozenset()
    reuse_store: frozenset = frozenset()
    store_model: Optional[KVStoreModel] = None
    # per-chunk adaptive quantization (Chunk -> BITRATE_LEVELS width).
    # None = uniform plan, every consumer takes its exact pre-per-chunk
    # path; set by plan_policy when SparKVConfig.alloc_schedule is armed
    # and mutated by the cluster's SLO cold-chunk downgrade.
    chunk_bits: Optional[dict] = None


def chunk_bits_for(wl: WorkloadChunks, grid: ChunkGrid,
                   spcfg: SparKVConfig,
                   base_bits: Optional[int] = None) -> Optional[dict]:
    """Per-chunk bit-widths for `wl` under the config's allocation
    schedule, keyed by `grid` chunks — or None when the schedule is the
    "uniform" sentinel (per-chunk machinery disarmed). The allocation is
    a pure function of the workload's measured signals, so the reuse
    layer's content keys and the planner compute identical widths
    independently."""
    name = getattr(spcfg, "alloc_schedule", "uniform")
    if name == "uniform":
        return None
    from repro.compression.allocate import allocate_bits, schedule_of
    base = spcfg.quant_bits if base_bits is None else base_bits
    act, ent = wl.active_blocks, wl.entropy_bits
    if grid.n_h == 1 and wl.n_h > 1:
        # engine-granularity grid over a per-head workload: pool heads
        act = act.sum(axis=2, keepdims=True)
        ent = ent.mean(axis=1, keepdims=True)
    arr = allocate_bits(act, ent, base, schedule_of(name))
    return {c: int(arr[c.t, c.l, c.h]) for c in grid.chunks()}


def plan_policy(policy: str, cfg, wl: WorkloadChunks, profile_name: str,
                net: NetworkProfile, spcfg: SparKVConfig, *,
                util: float = 0.0, adapt: bool = True,
                slo_s: float = 2.0, kivi_bits: int = 3,
                reuse: Optional[ChunkReuse] = None) -> RequestPlan:
    """Build the schedule/controller for `policy` without executing it.

    `reuse` (resolved hits from the serving layer's content-key lookup)
    bends the planning costs before the scheduler runs: local prefix
    hits cost ~nothing on the stream path (the greedy planner front-loads
    them; the engine then skips them outright), store hits cost
    ``t_store_hit`` instead of the origin ``t_stream``. The third leg
    beside stream/compute."""
    if policy not in PIPELINES:
        raise KeyError(f"unknown policy {policy!r}; have {list(PIPELINES)}")
    grid, bmap, amap = _engine_grid(cfg, wl, spcfg)
    bits = spcfg.quant_bits
    if policy == "cachegen":
        from repro.compression.quantize import BITRATE_LEVELS
        levels = [b for b in BITRATE_LEVELS if QUALITY_OF_BITS[b] >= 0.9]
        bits = levels[0]
        for b in levels:
            scale = b / spcfg.quant_bits
            bits = b
            if sum(bmap.values()) * scale / net.mean_bw <= slo_s:
                break
        bmap = {c: v * bits / spcfg.quant_bits for c, v in bmap.items()}
    elif policy == "kivi":
        bits = kivi_bits
        bmap = {c: v * bits / spcfg.quant_bits for c, v in bmap.items()}
    chunk_bits = chunk_bits_for(wl, grid, spcfg, base_bits=bits)
    if chunk_bits is not None:
        # per-chunk adaptive allocation: re-express each chunk's wire
        # bytes at its allocated width. Chunks held at the base width
        # keep their bytes verbatim — v*b/b is not an exact roundtrip
        # for non-power-of-two widths, and the "flat" schedule must be
        # bit-identical to the uniform plan
        bmap = {c: (v if chunk_bits[c] == bits
                    else chunk_bytes_at_bits(v, bits, chunk_bits[c]))
                for c, v in bmap.items()}
    planner = Planner.build(cfg, grid, bmap, amap, profile_name, net, spcfg,
                            util=util)
    if reuse is not None and (reuse.local or reuse.store):
        # bend the stream-side planning costs: a local prefix hit is
        # near-free (schedule it first, the engine skips it), a store hit
        # costs the cached-egress leg instead of the origin stream
        profile = PROFILES[profile_name]
        for i, c in enumerate(grid.chunks()):
            if c in reuse.local:
                planner.ts[i] = 1e-9   # ~free, nonzero: 1/ts priorities
            elif c in reuse.store and reuse.model is not None:
                planner.ts[i] = t_store_hit(bmap[c], net.mean_bw, profile,
                                            reuse.model)
    controller = None
    if policy == "sparkv":
        schedule = sched.GreedyScheduler(
            grid, planner.ts, planner.tc,
            stage_budget_s=spcfg.stage_budget_s,
            w_immediate=spcfg.w_immediate,
            w_potential=spcfg.w_potential).run()
        if adapt:
            controller = RuntimeController(spcfg, net.mean_bw)
            if reuse is not None and reuse.store:
                controller.set_store_hits(reuse.store)
    elif policy == "strong_hybrid":
        schedule = sched.positional_hybrid(grid, planner.ts, planner.tc)
    elif policy == "local_prefill":
        schedule = sched.compute_only(grid, planner.ts, planner.tc)
    else:                                   # cachegen / kivi: stream-only
        schedule = sched.stream_only(grid, planner.ts, planner.tc)
    return RequestPlan(policy=policy, grid=grid, bytes_map=bmap,
                       active_map=amap, planner=planner, schedule=schedule,
                       controller=controller, quality_bits=bits,
                       context_len=wl.context_len,
                       reuse_local=(reuse.local if reuse else frozenset()),
                       reuse_store=(reuse.store if reuse else frozenset()),
                       store_model=(reuse.model if reuse else None),
                       chunk_bits=chunk_bits)


def _mixed_quality(res, bits: int, *, chunk_bits: Optional[dict] = None,
                   active_map: Optional[dict] = None) -> float:
    """Response-quality score of one executed request.

    Uniform plans (chunk_bits None): the unweighted mix — computed
    chunks exact, streamed/reused chunks at QUALITY_OF_BITS[bits].

    Per-chunk plans: the *saliency-weighted* mix over the whole grid,
    each non-computed chunk at its own width's fidelity, weighted by the
    attention mass actually reading it (`active_map`). The weighting is
    the point of per-chunk allocation: QUALITY_OF_BITS is concave in
    bits, so an unweighted mean always favors uniform widths — but a
    response's fidelity is dominated by the chunks attention reads,
    which is exactly where the allocator spends the bits.
    """
    n_reused = getattr(res, "n_reused", 0)
    if chunk_bits is None:
        # reused chunks carry streamed fidelity: the cached artifact was
        # encoded at the same quantization level as a fresh stream
        n = res.n_streamed + res.n_computed + n_reused
        q_stream = QUALITY_OF_BITS[bits]
        return (res.n_computed * 1.0
                + (res.n_streamed + n_reused) * q_stream) / max(n, 1)
    computed = getattr(res, "computed_set", None) or set()
    wsum = qsum = 0.0
    for c, b in chunk_bits.items():
        w = float(active_map.get(c, 1.0)) if active_map else 1.0
        w = max(w, 1e-9)
        q = 1.0 if c in computed else QUALITY_OF_BITS[b]
        wsum += w
        qsum += w * q
    return qsum / max(wsum, 1e-12)


def _run_plan(plan: RequestPlan, cfg, profile_name, net, spcfg, *,
              util=0.0, seed=0) -> PipelineResult:
    res = _run_engine(cfg, plan.grid, plan.bytes_map, plan.active_map,
                      plan.planner, plan.schedule, profile_name, net, spcfg,
                      util=util, controller=plan.controller, seed=seed,
                      context_len=plan.context_len, bw_seed=seed + 991)
    extras = {}
    if plan.policy == "sparkv":
        extras["migrations"] = res.n_migrations
    elif plan.policy == "cachegen":
        extras["bits"] = plan.quality_bits
    return PipelineResult(plan.policy, res.ttft_s, res.energy["total_j"],
                          _mixed_quality(res, plan.quality_bits,
                                         chunk_bits=plan.chunk_bits,
                                         active_map=plan.active_map),
                          res, extras)


def run_sparkv(cfg, wl: WorkloadChunks, profile_name: str,
               net: NetworkProfile, spcfg: SparKVConfig, *, util=0.0,
               seed=0, adapt: bool = True) -> PipelineResult:
    plan = plan_policy("sparkv", cfg, wl, profile_name, net, spcfg,
                       util=util, adapt=adapt)
    return _run_plan(plan, cfg, profile_name, net, spcfg, util=util,
                     seed=seed)


def run_strong_hybrid(cfg, wl, profile_name, net, spcfg, *, util=0.0,
                      seed=0) -> PipelineResult:
    plan = plan_policy("strong_hybrid", cfg, wl, profile_name, net, spcfg,
                       util=util)
    return _run_plan(plan, cfg, profile_name, net, spcfg, util=util,
                     seed=seed)


def run_local_prefill(cfg, wl, profile_name, net, spcfg, *, util=0.0,
                      seed=0) -> PipelineResult:
    plan = plan_policy("local_prefill", cfg, wl, profile_name, net, spcfg,
                       util=util)
    return _run_plan(plan, cfg, profile_name, net, spcfg, util=util,
                     seed=seed)


def run_cachegen(cfg, wl, profile_name, net, spcfg, *, util=0.0, seed=0,
                 slo_s: float = 2.0) -> PipelineResult:
    """Stream-only with a bitrate ladder: pick the finest level whose
    projected delivery meets the SLO under profiled bandwidth."""
    plan = plan_policy("cachegen", cfg, wl, profile_name, net, spcfg,
                       util=util, slo_s=slo_s)
    return _run_plan(plan, cfg, profile_name, net, spcfg, util=util,
                     seed=seed)


def run_kivi(cfg, wl, profile_name, net, spcfg, *, util=0.0,
             seed=0, bits: int = 3) -> PipelineResult:
    """Stream-only with fixed asymmetric low-bit quantization (KIVI-like):
    2-bit-class keys/values -> small transfers, lower fidelity."""
    plan = plan_policy("kivi", cfg, wl, profile_name, net, spcfg,
                       util=util, kivi_bits=bits)
    return _run_plan(plan, cfg, profile_name, net, spcfg, util=util,
                     seed=seed)


PIPELINES = {
    "sparkv": run_sparkv,
    "strong_hybrid": run_strong_hybrid,
    "cachegen": run_cachegen,
    "kivi": run_kivi,
    "local_prefill": run_local_prefill,
}
