"""Runtime adaptation mechanism (paper §IV-D).

Sliding-window monitors of achieved bandwidth and compute speed drive
bounded chunk migrations between the streaming and computation paths:

  - wireless bandwidth drop  -> stream path is the transient bottleneck:
    compute-ready chunks still queued for streaming are executed locally
    (head of stream queue by compute-priority), plus speculative advance
    into later-stage compute-ready chunks when the GPU idles.
  - edge compute contention  -> compute path is the bottleneck: chunks are
    migrated from the *tail* of the compute order to streaming (tail-first
    minimizes disturbance to imminent work).

Compute contention is observed through two channels: service-time dilation
(actual/predicted per chunk — the scalar-util world) and, when the cluster
runs an explicit device run queue, *queueing delay* (wait/service per
chunk, fed by the engine via ``record_queue_wait``). Queue pressure
inflates the compute-path backlog estimate the same way slowdown does, so
migration decisions respond to waiting work even when service times are
undilated.

Migrations per stage are bounded (spcfg.max_migrations_per_stage) to avoid
oscillation.

Deadline awareness (SLO layer): the serving cluster stamps a request's
absolute TTFT deadline onto its controller (``set_deadline``). When the
remaining slack falls inside the guard window *and* the measured link
bandwidth has degraded below ``congested_frac`` of the planned bandwidth,
compute->stream migrations are suppressed — a near-deadline flow is never
migrated onto a congested link, where the queued bytes would land behind
everyone else's backlog with no time left to recover.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

from repro.core.chunks import Chunk


@dataclasses.dataclass
class Migration:
    chunk: Chunk
    to_path: str          # "stream" | "compute"
    reason: str


@dataclasses.dataclass
class WindowStat:
    window_s: float
    samples: deque = dataclasses.field(default_factory=deque)

    def add(self, t: float, value: float):
        self.samples.append((t, value))
        self.trim(t)

    def trim(self, now: float):
        while self.samples and self.samples[0][0] < now - self.window_s:
            self.samples.popleft()

    def rate(self, now: float) -> Optional[float]:
        """Sum of values in window / window length."""
        self.trim(now)
        if not self.samples:
            return None
        return sum(v for _, v in self.samples) / self.window_s

    def mean_ratio(self, now: float) -> Optional[float]:
        self.trim(now)
        if not self.samples:
            return None
        return float(np.mean([v for _, v in self.samples]))


class RuntimeController:
    def __init__(self, spcfg, plan_bw: float):
        self.cfg = spcfg
        self.plan_bw = plan_bw
        self.bw_win = WindowStat(spcfg.window_s)         # bytes delivered
        self.comp_win = WindowStat(spcfg.window_s)       # actual/predicted
        self.queue_win = WindowStat(spcfg.window_s)      # wait/service
        self.migrations_this_stage = 0
        self.n_migrations = 0
        self.n_losses = 0             # aborted transfers observed (mobility)
        self.bytes_lost = 0.0         # wasted wire bytes across those aborts
        self._last_reset = 0.0
        # SLO deadline (absolute, on the driver's clock); None = no SLO
        self.deadline_s: Optional[float] = None
        self.slack_guard_s = 2.0
        self.congested_frac = 0.6
        # content-key store hits: the third leg beside stream/compute —
        # these chunks ride the cheap cached-egress path, not the
        # congested origin link (empty = pre-reuse behaviour, exactly)
        self.store_hits: frozenset = frozenset()

    def record_stream(self, t: float, nbytes: float):
        self.bw_win.add(t, nbytes)

    def record_compute(self, t: float, actual_s: float, predicted_s: float):
        self.comp_win.add(t, actual_s / max(predicted_s, 1e-9))

    def record_queue_wait(self, t: float, wait_s: float, service_s: float):
        """Device run-queue wait observed for one compute chunk (engine
        calls this when the driver acknowledged a queued start)."""
        self.queue_win.add(t, wait_s / max(service_s, 1e-9))

    def note_loss(self, t: float, *, nbytes_lost: float = 0.0):
        """An in-flight transfer was aborted (handoff re-route, AP
        outage): record a zero-delivery bandwidth sample so the measured
        link rate reflects the wasted wire time — repeated losses drag
        ``measured_bw`` down and create migration pressure toward local
        compute at the very boundary where the lost chunk re-enters the
        backlog."""
        self.bw_win.add(t, 0.0)
        self.n_losses += 1
        self.bytes_lost += float(nbytes_lost)

    def set_deadline(self, t_deadline_s: float, *,
                     slack_guard_s: Optional[float] = None,
                     congested_frac: Optional[float] = None):
        """Arm the deadline guard: an absolute TTFT deadline on the
        driver's clock, the slack window inside which migrations onto a
        degraded link are suppressed, and the measured/planned bandwidth
        ratio below which the link counts as congested (None keeps the
        controller's current values)."""
        self.deadline_s = t_deadline_s
        if slack_guard_s is not None:
            self.slack_guard_s = slack_guard_s
        if congested_frac is not None:
            self.congested_frac = congested_frac

    def set_store_hits(self, chunks) -> None:
        """Arm the store-hit leg: `chunks` are content-key hits served
        from the cloud KV store's edge replica. The controller treats
        them as a third path — their bytes do not load the origin stream
        backlog, and a bandwidth drop never migrates them to compute (a
        cache read is not the congested link)."""
        self.store_hits = frozenset(chunks)

    def _deadline_blocks_stream(self, now: float, bw: float) -> bool:
        """True when this flow is near its deadline and the link is
        congested — to-stream migrations would strand imminent work."""
        if self.deadline_s is None:
            return False
        return (self.deadline_s - now <= self.slack_guard_s
                and bw < self.congested_frac * self.plan_bw)

    def new_stage(self):
        self.migrations_this_stage = 0

    def measured_bw(self, now: float) -> float:
        r = self.bw_win.rate(now)
        return r if r and r > 0 else self.plan_bw

    def compute_slowdown(self, now: float) -> float:
        r = self.comp_win.mean_ratio(now)
        return r if r else 1.0

    def queue_pressure(self, now: float) -> float:
        """Mean wait/service ratio in the window; 0 when the device queue
        is idle (or the driver has no explicit queue)."""
        r = self.queue_win.mean_ratio(now)
        return r if r else 0.0

    def decide(self, now: float, *, stream_queue, comp_queue,
               ready, chunk_bytes, t_comp_pred) -> list[Migration]:
        """Called at event boundaries. Queues are lists of Chunks (stream
        order / compute order); `ready` is the currently compute-ready set.
        Returns bounded migrations."""
        cfg = self.cfg
        # windowed migration budget (paper: bounded per stage to avoid
        # oscillation; the engine has no stage clock, so budgets reset per
        # monitor window)
        if now - self._last_reset >= cfg.window_s:
            self.migrations_this_stage = 0
            self._last_reset = now
        if self.migrations_this_stage >= cfg.max_migrations_per_stage:
            return []
        bw = self.measured_bw(now)
        # queueing delay and service dilation both stretch the compute
        # path; a chunk that waits w and runs s effectively costs s*(1+w/s)
        slow = self.compute_slowdown(now) * (1.0 + self.queue_pressure(now))
        # store-hit chunks ride the cached-egress leg, not the measured
        # origin link: they neither load the stream backlog nor are
        # candidates to pull local when the origin bandwidth drops
        t_s = sum(chunk_bytes[c] for c in stream_queue
                  if c not in self.store_hits) / bw \
            if stream_queue else 0.0
        t_c = sum(t_comp_pred[c] for c in comp_queue) * slow \
            if comp_queue else 0.0

        out: list[Migration] = []
        budget = cfg.max_migrations_per_stage - self.migrations_this_stage
        if t_s > cfg.imbalance_threshold * max(t_c, 1e-9) and stream_queue:
            # network is the bottleneck: pull compute-ready streamed chunks
            # to the local path (cheapest-compute first), enough to
            # restore balance
            cands = [c for c in stream_queue if c in ready
                     and c not in self.store_hits]
            cands.sort(key=lambda c: t_comp_pred[c])
            moved_s = 0.0
            for c in cands[:budget]:
                if t_s - moved_s <= t_c + moved_s:
                    break
                out.append(Migration(c, "compute", "bandwidth_drop"))
                moved_s += chunk_bytes[c] / bw
        elif t_c > cfg.imbalance_threshold * max(t_s, 1e-9) and comp_queue \
                and not self._deadline_blocks_stream(now, bw):
            # compute is the bottleneck: shed the tail of the compute order
            moved_c = 0.0
            for c in list(reversed(comp_queue))[:budget]:
                if t_c - moved_c <= t_s + moved_c:
                    break
                out.append(Migration(c, "stream", "compute_contention"))
                moved_c += t_comp_pred[c] * slow
        self.migrations_this_stage += len(out)
        self.n_migrations += len(out)
        return out
