"""Dense two-phase primal simplex (Bland's rule) — in-repo replacement for
an external LP solver, used by the branch & bound MILP oracle (core.milp).

    minimize c @ x
    s.t.     A_ub @ x <= b_ub
             A_eq @ x == b_eq
             0 <= x
Problem sizes here are a few hundred variables/rows; dense numpy is fine.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class LPResult:
    status: str                # optimal | infeasible | unbounded | maxiter
    x: Optional[np.ndarray]
    fun: float
    # final basic column set (slack columns included). Feed it back as
    # ``warm_basis`` on a structurally identical problem with perturbed
    # data — an event-to-event re-solve (the fleet rebalancer) then skips
    # phase 1 whenever the old basis is still primal-feasible.
    basis: Optional[np.ndarray] = None
    warm_used: bool = False    # True when the warm basis skipped phase 1


def _pivot(T: np.ndarray, basis: np.ndarray, row: int, col: int):
    T[row] /= T[row, col]
    for r in range(T.shape[0]):
        if r != row and T[r, col] != 0.0:
            T[r] -= T[r, col] * T[row]
    basis[row] = col


def _simplex_core(T: np.ndarray, basis: np.ndarray, n_real: int,
                  max_iter: int) -> str:
    """Minimize objective in last row of tableau T. Bland's rule."""
    m = T.shape[0] - 1
    for _ in range(max_iter):
        # entering: lowest index with negative reduced cost
        costs = T[-1, :-1]
        neg = np.nonzero(costs < -1e-9)[0]
        if len(neg) == 0:
            return "optimal"
        col = int(neg[0])
        ratios = np.full(m, np.inf)
        pos = T[:m, col] > 1e-9
        ratios[pos] = T[:m, -1][pos] / T[:m, col][pos]
        if not np.isfinite(ratios).any():
            return "unbounded"
        rmin = ratios.min()
        # leaving: among min ratio, lowest basis index (Bland)
        cand = np.nonzero(ratios <= rmin + 1e-12)[0]
        row = int(cand[np.argmin(basis[cand])])
        _pivot(T, basis, row, col)
    return "maxiter"


def solve_lp(c, A_ub=None, b_ub=None, A_eq=None, b_eq=None,
             max_iter: int = 20000,
             warm_basis=None) -> LPResult:
    c = np.asarray(c, float)
    n = len(c)
    A_ub = np.zeros((0, n)) if A_ub is None else np.asarray(A_ub, float)
    b_ub = np.zeros(0) if b_ub is None else np.asarray(b_ub, float)
    A_eq = np.zeros((0, n)) if A_eq is None else np.asarray(A_eq, float)
    b_eq = np.zeros(0) if b_eq is None else np.asarray(b_eq, float)
    mu, me = len(b_ub), len(b_eq)
    m = mu + me

    # rows: [A_ub | I_slack ; A_eq | 0], then flip rows with b < 0
    A = np.zeros((m, n + mu))
    A[:mu, :n] = A_ub
    A[:mu, n:n + mu] = np.eye(mu)
    A[mu:, :n] = A_eq
    b = np.concatenate([b_ub, b_eq])
    for r in range(m):
        if b[r] < 0:
            A[r] *= -1
            b[r] *= -1

    # warm start: a prior run's basis on a structurally identical problem
    # (same row/column layout, perturbed data). When it is still primal
    # feasible — B nonsingular, B^{-1} b >= 0 — phase 1 is skipped and
    # phase 2 resumes from the old vertex; otherwise fall through to the
    # cold two-phase path (bit-identical results either way: both end at
    # an optimal vertex of the same LP).
    if warm_basis is not None and len(warm_basis) == m:
        wb = np.asarray(warm_basis, int)
        if np.all((wb >= 0) & (wb < n + mu)) and len(np.unique(wb)) == m:
            Bmat = A[:, wb]
            try:
                xb = np.linalg.solve(Bmat, b)
                rows = np.linalg.solve(Bmat, A)
            except np.linalg.LinAlgError:
                xb = None
            if xb is not None and np.all(xb >= -1e-9):
                T2 = np.zeros((m + 1, n + mu + 1))
                T2[:m, :n + mu] = rows
                T2[:m, -1] = np.maximum(xb, 0.0)
                basis = wb.copy()
                T2[-1, :n] = c
                for r in range(m):
                    bcol = basis[r]
                    if T2[-1, bcol] != 0.0:
                        T2[-1] -= T2[-1, bcol] * T2[r]
                st = _simplex_core(T2, basis, n, max_iter)
                if st == "optimal":
                    x = np.zeros(n + mu)
                    for r in range(m):
                        if basis[r] < n + mu:
                            x[basis[r]] = T2[r, -1]
                    return LPResult("optimal", x[:n], float(c @ x[:n]),
                                    basis=basis.copy(), warm_used=True)
                if st == "unbounded":
                    return LPResult(st, None, -np.inf)
                # maxiter from a warm vertex: retry cold below

    # basis: slack where possible, artificial otherwise
    basis = np.full(m, -1, int)
    art_cols = []
    for r in range(m):
        if r < mu and A[r, n + r] == 1.0:
            basis[r] = n + r
        else:
            art_cols.append(r)
    n_art = len(art_cols)
    Afull = np.hstack([A, np.zeros((m, n_art))])
    for i, r in enumerate(art_cols):
        Afull[r, n + mu + i] = 1.0
        basis[r] = n + mu + i
    ncols = n + mu + n_art

    # phase 1
    T = np.zeros((m + 1, ncols + 1))
    T[:m, :ncols] = Afull
    T[:m, -1] = b
    if n_art:
        T[-1, n + mu:ncols] = 1.0
        for r in art_cols:
            T[-1] -= T[r]
        st = _simplex_core(T, basis, n, max_iter)
        if st != "optimal" or T[-1, -1] < -1e-7:
            return LPResult("infeasible", None, np.inf)
        # drive artificials out of the basis if degenerate
        for r in range(m):
            if basis[r] >= n + mu:
                cand = np.nonzero(np.abs(T[r, :n + mu]) > 1e-9)[0]
                if len(cand):
                    _pivot(T, basis, r, int(cand[0]))

    # phase 2
    T2 = np.zeros((m + 1, n + mu + 1))
    T2[:m, :n + mu] = T[:m, :n + mu]
    T2[:m, -1] = T[:m, -1]
    T2[-1, :n] = c
    for r in range(m):
        bcol = basis[r]
        if bcol < n + mu and T2[-1, bcol] != 0.0:
            T2[-1] -= T2[-1, bcol] * T2[r]
    st = _simplex_core(T2, basis, n, max_iter)
    if st != "optimal":
        return LPResult(st, None, np.inf if st != "unbounded" else -np.inf)
    x = np.zeros(n + mu)
    for r in range(m):
        if basis[r] < n + mu:
            x[basis[r]] = T2[r, -1]
    return LPResult("optimal", x[:n], float(T2[-1, -1] * -1.0)
                    if False else float(c @ x[:n]), basis=basis.copy())
