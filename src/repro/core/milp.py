"""Exact MILP oracle for chunk scheduling (paper Eq. 1-5) via in-repo
branch & bound over the LP relaxation (core.lp simplex).

Variable layout (n = 2*C*K + K):
    x_trans[c,k] = v[c*K + k]
    x_comp[c,k]  = v[C*K + c*K + k]
    M[k]         = v[2*C*K + k]      (stage makespans)
Objective: sum_k M_k  (Eq. 1, linearized max via two >= constraints).
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

import numpy as np

from repro.core.chunks import ChunkGrid
from repro.core.lp import solve_lp
from repro.core.scheduler import Schedule, Stage


@dataclasses.dataclass
class MILPProblem:
    grid: ChunkGrid
    t_stream: np.ndarray
    t_comp: np.ndarray
    n_stages: int

    def __post_init__(self):
        self.C = self.grid.size
        self.K = self.n_stages
        self.nbin = 2 * self.C * self.K
        self.n = self.nbin + self.K
        self.chunk_list = list(self.grid.chunks())

    # ---- variable indexing ----
    def ix_t(self, ci: int, k: int) -> int:
        return ci * self.K + k

    def ix_c(self, ci: int, k: int) -> int:
        return self.C * self.K + ci * self.K + k

    def ix_m(self, k: int) -> int:
        return self.nbin + k

    def build(self):
        C, K, n = self.C, self.K, self.n
        g = self.grid
        obj = np.zeros(n)
        obj[self.nbin:] = 1.0

        A_eq, b_eq, A_ub, b_ub = [], [], [], []
        # (2) each chunk processed exactly once
        for ci in range(C):
            row = np.zeros(n)
            for k in range(K):
                row[self.ix_t(ci, k)] = 1.0
                row[self.ix_c(ci, k)] = 1.0
            A_eq.append(row)
            b_eq.append(1.0)
        # (1) linearized stage makespans
        for k in range(K):
            row_s = np.zeros(n)
            row_c = np.zeros(n)
            for ci in range(C):
                row_s[self.ix_t(ci, k)] = self.t_stream[ci]
                row_c[self.ix_c(ci, k)] = self.t_comp[ci]
            row_s[self.ix_m(k)] = -1.0
            row_c[self.ix_m(k)] = -1.0
            A_ub += [row_s, row_c]
            b_ub += [0.0, 0.0]
        # (3)-(5) readiness
        for ci, c in enumerate(self.chunk_list):
            tp = g.token_pred(c)
            lp_ = g.layer_pred(c)
            for k in range(K):
                if tp is not None:
                    pi = g.index(tp)
                    row = np.zeros(n)
                    row[self.ix_c(ci, k)] = 1.0
                    for kk in range(k + 1):
                        row[self.ix_t(pi, kk)] -= 1.0
                        row[self.ix_c(pi, kk)] -= 1.0
                    A_ub.append(row)
                    b_ub.append(0.0)
                if lp_ is not None:
                    qi = g.index(lp_)
                    row = np.zeros(n)
                    row[self.ix_c(ci, k)] = 1.0
                    for kk in range(k + 1):
                        row[self.ix_c(qi, kk)] -= 1.0
                    A_ub.append(row)
                    b_ub.append(0.0)
        # binaries <= 1
        for j in range(self.nbin):
            row = np.zeros(n)
            row[j] = 1.0
            A_ub.append(row)
            b_ub.append(1.0)
        return obj, np.array(A_ub), np.array(b_ub), \
            np.array(A_eq), np.array(b_eq)

    # ---- objective of an integral assignment ----
    def objective(self, assign: dict[int, tuple[str, int]]) -> float:
        ms = np.zeros(self.K)
        mc = np.zeros(self.K)
        for ci, (path, k) in assign.items():
            if path == "s":
                ms[k] += self.t_stream[ci]
            else:
                mc[k] += self.t_comp[ci]
        return float(np.maximum(ms, mc).sum())

    def feasible(self, assign: dict[int, tuple[str, int]]) -> bool:
        g = self.grid
        for ci, c in enumerate(self.chunk_list):
            path, k = assign[ci]
            if path != "c":
                continue
            tp = g.token_pred(c)
            if tp is not None:
                pp, pk = assign[g.index(tp)]
                if pk > k:
                    return False
            lp_ = g.layer_pred(c)
            if lp_ is not None:
                qp, qk = assign[g.index(lp_)]
                if qp != "c" or qk > k:
                    return False
        return True

    def to_schedule(self, assign) -> Schedule:
        stages = [Stage() for _ in range(self.K)]
        for ci, (path, k) in assign.items():
            c = self.chunk_list[ci]
            if path == "s":
                stages[k].stream.append(c)
                stages[k].t_stream += self.t_stream[ci]
            else:
                stages[k].comp.append(c)
                stages[k].t_comp += self.t_comp[ci]
        for st in stages:
            st.comp.sort(key=lambda c: (c.t, c.l, c.h))
        return Schedule(stages=[s for s in stages
                                if s.comp or s.stream], grid=self.grid)


@dataclasses.dataclass
class BnBResult:
    status: str
    objective: float
    assign: Optional[dict]
    nodes: int
    lp_bound: float


def solve_bnb(prob: MILPProblem, *, incumbent: Optional[float] = None,
              max_nodes: int = 4000, tol: float = 1e-6) -> BnBResult:
    obj, A_ub, b_ub, A_eq, b_eq = prob.build()
    n = prob.n

    root = solve_lp(obj, A_ub, b_ub, A_eq, b_eq)
    if root.status != "optimal":
        return BnBResult("infeasible", np.inf, None, 1, np.inf)
    lp_bound = root.fun

    best_obj = np.inf if incumbent is None else incumbent
    best_assign = None
    nodes = 0
    # stack entries: (bound, fixes) where fixes: {var: 0/1}
    stack = [(root.fun, {})]

    while stack and nodes < max_nodes:
        stack.sort(key=lambda e: -e[0])          # explore best bound last
        bound, fixes = stack.pop()
        if bound >= best_obj - tol:
            continue
        nodes += 1
        # apply fixes as equality rows
        ae = [A_eq] if len(A_eq) else []
        be = [b_eq] if len(b_eq) else []
        fr = np.zeros((len(fixes), n))
        fb = np.zeros(len(fixes))
        for i, (j, v) in enumerate(fixes.items()):
            fr[i, j] = 1.0
            fb[i] = v
        Ae = np.vstack(ae + [fr]) if len(fixes) else A_eq
        Be = np.concatenate(be + [fb]) if len(fixes) else b_eq
        res = solve_lp(obj, A_ub, b_ub, Ae, Be)
        if res.status != "optimal" or res.fun >= best_obj - tol:
            continue
        xb = res.x[:prob.nbin]
        frac = np.abs(xb - np.round(xb))
        j = int(np.argmax(frac))
        if frac[j] < 1e-6:
            # integral
            assign = _extract_assign(prob, res.x)
            if assign is not None and prob.feasible(assign):
                val = prob.objective(assign)
                if val < best_obj:
                    best_obj, best_assign = val, assign
            continue
        for v in (0, 1):
            nf = dict(fixes)
            nf[j] = v
            stack.append((res.fun, nf))

    status = "optimal" if nodes < max_nodes else "node_limit"
    return BnBResult(status, best_obj, best_assign, nodes, lp_bound)


def _extract_assign(prob: MILPProblem, x) -> Optional[dict]:
    assign = {}
    for ci in range(prob.C):
        found = None
        for k in range(prob.K):
            if x[prob.ix_t(ci, k)] > 0.5:
                found = ("s", k)
            if x[prob.ix_c(ci, k)] > 0.5:
                found = ("c", k)
        if found is None:
            return None
        assign[ci] = found
    return assign


# ---------------------------------------------------------------------------
# Fleet-level LP relaxation (serving/scenarios.FleetRebalancer)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FleetLP:
    """LP relaxation of the fleet placement problem — Eq. 1's
    stream-vs-compute makespan lifted from one request's chunks to the
    whole fleet's byte demands (the continuous relaxation the
    :class:`~repro.serving.scenarios.FleetRebalancer` re-solves at every
    handoff/outage/churn event).

    Variable layout (n = D*A + D + 1)::

        y[d,a] = v[d*A + a]       bytes device d streams via AP a
        c[d]   = v[D*A + d]       bytes device d prefills locally
        T      = v[D*A + D]       fleet makespan (the objective)

    Constraints: per-device demand conservation (every outstanding byte
    is either streamed through some reachable AP or computed locally),
    per-AP uplink capacity ``sum_d y[d,a] <= bw_a * T``, per-device
    compute capacity ``c_d <= rate_d * T``, and one reachability row per
    (d, a) pair — ``y[d,a] <= 0`` when unreachable, slack (bounded by
    total demand) when reachable, so the row layout is identical across
    solves whatever the reach sets and a previous solve's basis stays
    structurally valid as a warm start. Byte quantities are normalized
    by the peak demand so the simplex works on O(1) numbers whatever
    the context sizes.
    """
    demand: np.ndarray        # (D,) outstanding bytes per device
    ap_bw: np.ndarray         # (A,) effective uplink capacity, bytes/s
    comp_rate: np.ndarray     # (D,) local prefill throughput, bytes/s
    reach: list               # device -> iterable of reachable AP ids

    def __post_init__(self):
        self.demand = np.asarray(self.demand, float)
        self.ap_bw = np.asarray(self.ap_bw, float)
        self.comp_rate = np.asarray(self.comp_rate, float)
        self.D = len(self.demand)
        self.A = len(self.ap_bw)
        assert len(self.comp_rate) == self.D
        assert len(self.reach) == self.D
        self.n = self.D * self.A + self.D + 1
        self._scale = max(float(self.demand.max(initial=0.0)), 1.0)

    def ix_y(self, d: int, a: int) -> int:
        return d * self.A + a

    def ix_c(self, d: int) -> int:
        return self.D * self.A + d

    @property
    def ix_t(self) -> int:
        return self.D * self.A + self.D

    def build(self):
        D, A, n, s = self.D, self.A, self.n, self._scale
        obj = np.zeros(n)
        obj[self.ix_t] = 1.0
        A_eq, b_eq, A_ub, b_ub = [], [], [], []
        for d in range(D):                    # demand conservation
            row = np.zeros(n)
            for a in range(A):
                row[self.ix_y(d, a)] = 1.0
            row[self.ix_c(d)] = 1.0
            A_eq.append(row)
            b_eq.append(self.demand[d] / s)
        for a in range(A):                    # AP uplink capacity
            row = np.zeros(n)
            for d in range(D):
                row[self.ix_y(d, a)] = 1.0
            row[self.ix_t] = -max(self.ap_bw[a], 1e-9) / s
            A_ub.append(row)
            b_ub.append(0.0)
        for d in range(D):                    # local compute capacity
            row = np.zeros(n)
            row[self.ix_c(d)] = 1.0
            row[self.ix_t] = -max(self.comp_rate[d], 1e-9) / s
            A_ub.append(row)
            b_ub.append(0.0)
        tot = float(self.demand.sum()) / s    # slack bound, reachable rows
        for d in range(D):                    # reachability (fixed layout)
            ok = set(self.reach[d])
            for a in range(A):
                row = np.zeros(n)
                row[self.ix_y(d, a)] = 1.0
                A_ub.append(row)
                b_ub.append(tot if a in ok else 0.0)
        return obj, np.array(A_ub), np.array(b_ub), \
            np.array(A_eq), np.array(b_eq)

    def extract(self, x: np.ndarray) -> tuple[dict, np.ndarray, float]:
        """(placement device -> AP carrying its largest streamed share,
        per-device locally-computed fraction, makespan seconds).
        Zero-demand devices keep no placement entry — the caller leaves
        them where they are."""
        placement: dict[int, int] = {}
        local_frac = np.zeros(self.D)
        for d in range(self.D):
            if self.demand[d] <= 0:
                continue
            y = np.array([x[self.ix_y(d, a)] for a in range(self.A)])
            tot = y.sum() + x[self.ix_c(d)]
            if tot <= 0:
                continue
            local_frac[d] = float(x[self.ix_c(d)] / tot)
            if y.max() > 0:
                placement[d] = int(np.argmax(y))
        return placement, local_frac, float(x[self.ix_t])


def brute_force(prob: MILPProblem) -> tuple[float, Optional[dict]]:
    """Exhaustive search for unit tests (tiny instances only)."""
    C, K = prob.C, prob.K
    assert (2 * K) ** C <= 300_000, "instance too large for brute force"
    options = [("s", k) for k in range(K)] + [("c", k) for k in range(K)]
    best, best_assign = np.inf, None
    for combo in itertools.product(options, repeat=C):
        assign = dict(enumerate(combo))
        if not prob.feasible(assign):
            continue
        v = prob.objective(assign)
        if v < best:
            best, best_assign = v, assign
    return best, best_assign
