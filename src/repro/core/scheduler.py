"""Potential-aware greedy KV chunk scheduler (paper §IV-B).

Priority scores combine immediate overhead with the compute potential the
chunk unlocks:

    w_s(c) = a/t_stream(c) + b * sum_{c' in A_s(c)} 1/t_comp(c')
    w_c(c) = a/t_comp(c)   + b * sum_{c' in A_c(c)} 1/t_comp(c')

Each stage has a time budget dt per path; the two paths run overlapped so
stage duration = max(path times). Local compute may chain within a stage
(computing a chunk can unlock its successors immediately); streamed chunks
land at the stage boundary.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.core.chunks import Chunk, ChunkGrid, State


@dataclasses.dataclass
class Stage:
    stream: list[Chunk] = dataclasses.field(default_factory=list)
    comp: list[Chunk] = dataclasses.field(default_factory=list)
    t_stream: float = 0.0
    t_comp: float = 0.0

    @property
    def makespan(self) -> float:
        return max(self.t_stream, self.t_comp)


@dataclasses.dataclass
class Schedule:
    stages: list[Stage]
    grid: ChunkGrid

    @property
    def makespan(self) -> float:
        return sum(s.makespan for s in self.stages)

    def n_computed(self) -> int:
        return sum(len(s.comp) for s in self.stages)

    def n_streamed(self) -> int:
        return sum(len(s.stream) for s in self.stages)

    def events(self) -> list[tuple[Chunk, bool]]:
        ev: list[tuple[Chunk, bool]] = []
        for s in self.stages:
            # within a stage computes happen (chained) before next-stage
            # consumers; streams land at the boundary
            ev.extend((c, True) for c in s.comp)
            ev.extend((c, False) for c in s.stream)
        return ev


class GreedyScheduler:
    def __init__(self, grid: ChunkGrid, t_stream: np.ndarray,
                 t_comp: np.ndarray, *, stage_budget_s: float = 0.25,
                 w_immediate: float = 1.0, w_potential: float = 1.0):
        """t_stream/t_comp: flat arrays indexed by grid.index."""
        self.grid = grid
        self.ts = np.asarray(t_stream, float)
        self.tc = np.asarray(t_comp, float)
        assert self.ts.shape == (grid.size,) == self.tc.shape
        self.dt = stage_budget_s
        self.a = w_immediate
        self.b = w_potential

    # ---- priority scores ----
    def w_stream(self, c: Chunk, state: np.ndarray) -> float:
        """Immediate + potential gain, minus the *opportunity cost* of
        streaming: once (t, l) is streamed, no chunk above it in column t
        can ever be computed (the layer dep needs a locally-computed
        hidden state), so streaming a low-layer chunk destroys the whole
        column's remaining compute potential. Without this term the greedy
        streams cheap low-layer chunks and starves the compute path (see
        EXPERIMENTS.md §Table-II notes)."""
        g = self.grid
        gain = sum(1.0 / self.tc[g.index(cc)]
                   for cc in g.enabled_by_stream(c, state))
        loss = 0.0
        for l2 in range(c.l + 1, g.n_l):
            i = g.index(Chunk(c.t, l2, c.h))
            if state[i] == State.PENDING:
                loss += 1.0 / self.tc[i]
        return (self.a / self.ts[self.grid.index(c)]
                + self.b * (gain - loss))

    def w_comp(self, c: Chunk, state: np.ndarray) -> float:
        gain = sum(1.0 / self.tc[self.grid.index(cc)]
                   for cc in self.grid.enabled_by_compute(c, state))
        return self.a / self.tc[self.grid.index(c)] + self.b * gain

    def run(self, max_stages: int = 10_000) -> Schedule:
        g = self.grid
        state = np.zeros(g.size, np.int8)
        pending = set(g.chunks())
        ready = {c for c in pending if g.compute_ready(c, state)}
        stages: list[Stage] = []

        while pending and len(stages) < max_stages:
            st = Stage()
            # --- compute phase (chains within the stage) ---
            # streamed chunks from earlier stages are already in `state`.
            while ready:
                best = max(ready, key=lambda c: self.w_comp(c, state))
                tbest = self.tc[g.index(best)]
                if st.t_comp + tbest > self.dt and st.comp:
                    break
                ready.discard(best)
                pending.discard(best)
                st.comp.append(best)
                st.t_comp += tbest
                state[g.index(best)] = State.COMPUTED
                for cc in (g.enabled_by_stream(best, state)
                           + g.enabled_by_compute(best, state)):
                    if cc in pending:
                        ready.add(cc)
                if st.t_comp >= self.dt:
                    break
            # --- stream phase ---
            cands = list(pending)
            cands.sort(key=lambda c: -self.w_stream(c, state))
            for c in cands:
                tc = self.ts[g.index(c)]
                if st.t_stream + tc > self.dt and st.stream:
                    break
                st.stream.append(c)
                st.t_stream += tc
                if st.t_stream >= self.dt:
                    break
            # commit streamed at the stage boundary
            for c in st.stream:
                pending.discard(c)
                ready.discard(c)
                state[g.index(c)] = State.STREAMED
            for c in st.stream:
                for cc in g.enabled_by_stream(c, state):
                    if cc in pending:
                        ready.add(cc)
            # refresh readiness (stream landings may enable chains)
            for c in list(pending):
                if c not in ready and g.compute_ready(c, state):
                    ready.add(c)
            if not st.comp and not st.stream:
                raise RuntimeError("scheduler stalled (no progress)")
            stages.append(st)
        return Schedule(stages=stages, grid=g)


def latency_only_greedy(grid: ChunkGrid, t_stream: np.ndarray,
                        t_comp: np.ndarray, **kw) -> Schedule:
    """Ablation: the naive latency-only policy (b = 0), paper §IV-B."""
    return GreedyScheduler(grid, t_stream, t_comp, w_potential=0.0,
                           **kw).run()


def positional_hybrid(grid: ChunkGrid, t_stream: np.ndarray,
                      t_comp: np.ndarray) -> Schedule:
    """'Strong Hybrid' baseline [25]: fixed positional split — early token
    columns computed bottom-up, later columns streamed, split chosen so
    profiled path times balance. One stage per token column (static)."""
    g = grid
    # cumulative compute time per column prefix vs stream time of the rest
    col_comp = np.zeros(g.n_t)
    col_stream = np.zeros(g.n_t)
    for c in g.chunks():
        col_comp[c.t] += t_comp[g.index(c)]
        col_stream[c.t] += t_stream[g.index(c)]
    best_split, best_cost = 0, float("inf")
    for split in range(g.n_t + 1):
        cost = max(col_comp[:split].sum(), col_stream[split:].sum())
        if cost < best_cost:
            best_cost, best_split = cost, split
    st = Stage()
    for c in g.chunks():
        if c.t < best_split:
            st.comp.append(c)
            st.t_comp += t_comp[g.index(c)]
        else:
            st.stream.append(c)
            st.t_stream += t_stream[g.index(c)]
    # order computes dependency-legally: by (t, l)
    st.comp.sort(key=lambda c: (c.t, c.l, c.h))
    return Schedule(stages=[st], grid=g)


def stream_only(grid: ChunkGrid, t_stream: np.ndarray,
                t_comp: np.ndarray) -> Schedule:
    st = Stage()
    st.stream = list(grid.chunks())
    st.t_stream = float(np.sum(t_stream))
    return Schedule(stages=[st], grid=grid)


def compute_only(grid: ChunkGrid, t_stream: np.ndarray,
                 t_comp: np.ndarray) -> Schedule:
    st = Stage()
    st.comp = sorted(grid.chunks(), key=lambda c: (c.t, c.l, c.h))
    st.t_comp = float(np.sum(t_comp))
    return Schedule(stages=[st], grid=grid)
