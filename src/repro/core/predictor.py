"""Computation-latency predictor (paper §IV-C).

A 2-hidden-layer MLP (48, 24 neurons) maps x = <t, s, U> (token-block
index, active attention blocks at 98% mass, device utilization) to the
sparse-attention latency of a non-final-layer chunk. Final layers are a
profiled constant (t_proj); dense ops are a near-constant offset t_dense.

Trained offline with SGD + MSE on 6,000 samples, 80/20 split (paper
settings). The analytical roofline estimator is the baseline it beats.

The U feature's *source* depends on the serving mode: the paper (and the
static Fig. 14 path) hand-sets it; the legacy closed-loop cluster derives
it from concurrently in-flight compute; a cluster with an explicit device
run queue derives it from observed queue occupancy via
:func:`queue_utilization` — the nvidia-smi-style "how busy is the device"
signal that an explicit queue exposes directly.

How the U feature turns into *delay* is learnable too: the serving
cluster records every request's realized device queue wait
(``compute_wait_s``) and per-stage link shares, feeds them back through
:meth:`LatencyPredictor.observe`, and :meth:`LatencyPredictor.refresh`
retrains the contention models online — a least-squares wait model on
(occupancy, backlog) replacing the analytic occupancy-dilation term of
``repro.serving.slo.predict_ttft``, and a link-efficiency estimate
replacing the profiled fair-share fraction. Until the first refresh (or
with no observations) both predictions return ``None`` and callers keep
the analytic fallback, so refresh-off behaviour is bit-identical.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.costs import DeviceProfile, GroundTruthLatency


def queue_utilization(load: int, capacity: int, *,
                      cap: float = 0.95) -> float:
    """Map device run-queue occupancy (in-service + waiting jobs) to the
    predictor's U feature.

    The MLP is trained on fractional utilization in [0, 0.85]; with an
    explicit :class:`repro.serving.resources.DeviceRunQueue` the
    equivalent admission-time signal is occupancy normalized by service
    slots, clipped below 1 so the planning costs stay finite."""
    return min(load / max(capacity, 1), cap)


def backlog_delay_s(backlog_s: float, capacity: int) -> float:
    """Expected extra wait a newly-submitted chunk sees from the device
    server's current service backlog (queued + in-service service
    seconds, ``DeviceRunQueue.backlog_s``): the backlog drains
    ``capacity`` jobs at a time, so a new arrival waits roughly the
    backlog divided by the slot count. Feeds the SLO admission TTFT
    projection (``repro.serving.slo.predict_ttft``)."""
    return backlog_s / max(capacity, 1)


def _init_mlp(rng, sizes=(3, 48, 24, 1)):
    params = []
    keys = jax.random.split(rng, len(sizes) - 1)
    for k, (a, b) in zip(keys, zip(sizes[:-1], sizes[1:])):
        w = jax.random.normal(k, (a, b), jnp.float32) * np.sqrt(2.0 / a)
        params.append({"w": w, "b": jnp.zeros((b,), jnp.float32)})
    return params


def _mlp_apply(params, x):
    h = x
    for i, lyr in enumerate(params):
        h = h @ lyr["w"] + lyr["b"]
        if i < len(params) - 1:
            h = jax.nn.relu(h)
    return h[..., 0]


@dataclasses.dataclass
class FeatureScaler:
    mean: np.ndarray
    std: np.ndarray
    y_scale: float

    def fx(self, x):
        return (x - self.mean) / self.std


@jax.jit
def _sgd_epoch(params, xb, yb, lr):
    def loss_fn(p):
        pred = _mlp_apply(p, xb)
        return jnp.mean((pred - yb) ** 2)

    loss, g = jax.value_and_grad(loss_fn)(params)
    params = jax.tree.map(lambda p, gg: p - lr * gg, params, g)
    return params, loss


class LatencyPredictor:
    """MLP predictor with profiled constants for t_dense / t_proj."""

    def __init__(self, cfg, profile: DeviceProfile, *, seed: int = 0):
        self.cfg = cfg
        self.profile = profile
        self.gt = GroundTruthLatency(profile, cfg.resolved_head_dim
                                     if cfg.num_heads else 64)
        self.t_dense = self.gt.dense_seconds(cfg)
        self.t_proj = profile.t_proj_s
        self.params = _init_mlp(jax.random.PRNGKey(seed))
        self.scaler: FeatureScaler | None = None
        # online contention-refresh state (serving telemetry)
        self.obs_window = 1024               # newest observations kept
        self._wait_obs: list[tuple] = []     # (load, cap, backlog_s, wait_s)
        self._share_obs: list[tuple] = []    # (n_flows, bottleneck share)
        self._wait_coef: np.ndarray | None = None
        self._eta_hat: float | None = None

    # ---- training data from profiling runs ----
    def profile_samples(self, n: int, rng: np.random.Generator,
                        max_t: int = 40, max_blocks: float = 4000.0):
        from repro.data.workloads import sample_profiling_features
        t, s = sample_profiling_features(rng, n, max_t=max_t)
        s = np.minimum(s, max_blocks)
        u = rng.uniform(0.0, 0.85, n)
        y = np.array([self.gt.attn_seconds(si, ui, rng)
                      for si, ui in zip(s, u)])
        x = np.stack([t, s, u], axis=1)
        return x.astype(np.float32), (y * 1e3).astype(np.float32)  # ms

    def fit(self, n_samples: int = 6000, *, epochs: int = 400,
            lr: float = 3e-3, batch: int = 256, seed: int = 0) -> dict:
        rng = np.random.default_rng(seed)
        x, y = self.profile_samples(n_samples, rng)
        n_tr = int(0.8 * n_samples)
        idx = rng.permutation(n_samples)
        tr, te = idx[:n_tr], idx[n_tr:]
        self.scaler = FeatureScaler(x[tr].mean(0), x[tr].std(0) + 1e-6,
                                    1.0)
        xtr = jnp.asarray(self.scaler.fx(x[tr]))
        ytr = jnp.asarray(y[tr])
        params = self.params
        steps = max(1, n_tr // batch)
        for ep in range(epochs):
            perm = rng.permutation(n_tr)
            cur_lr = lr * (0.5 ** (ep // 150))
            for s_i in range(steps):
                sl = perm[s_i * batch:(s_i + 1) * batch]
                params, _ = _sgd_epoch(params, xtr[sl], ytr[sl],
                                       jnp.float32(cur_lr))
        self.params = params
        report = {
            "train": self.evaluate(x[tr], y[tr]),
            "test": self.evaluate(x[te], y[te]),
            "n_samples": n_samples,
        }
        return report

    def evaluate(self, x, y) -> dict:
        pred = self.predict_ms(x)
        roof = np.array([self.gt.roofline_estimate(s) * 1e3
                         for s in x[:, 1]])
        err = np.abs(pred - y)
        rerr = np.abs(roof - y)
        return {
            "mlp_mae_ms": float(err.mean()),
            "mlp_mape": float((err / np.maximum(y, 1e-6)).mean()),
            "roofline_mae_ms": float(rerr.mean()),
            "roofline_mape": float((rerr / np.maximum(y, 1e-6)).mean()),
            "improvement": float(rerr.mean() / max(err.mean(), 1e-12)),
        }

    def predict_ms(self, x: np.ndarray) -> np.ndarray:
        assert self.scaler is not None, "fit() first"
        xs = jnp.asarray(self.scaler.fx(np.asarray(x, np.float32)))
        return np.asarray(_mlp_apply(self.params, xs))

    # ---- scheduler-facing API ----
    def t_comp(self, t_idx: int, layer: int, active_blocks: float,
               util: float) -> float:
        """Seconds for chunk (t, l); final layer is projection-only."""
        if layer == self.cfg.num_layers - 1:
            return self.t_proj
        x = np.array([[t_idx, active_blocks, util]], np.float32)
        return float(self.predict_ms(x)[0]) * 1e-3 + self.t_dense

    def t_comp_batch(self, t_idx: np.ndarray, layers: np.ndarray,
                     active_blocks: np.ndarray,
                     util: float) -> np.ndarray:
        x = np.stack([t_idx, active_blocks,
                      np.full_like(active_blocks, util, dtype=float)],
                     axis=1).astype(np.float32)
        ms = self.predict_ms(x)
        out = ms * 1e-3 + self.t_dense
        out = np.where(layers == self.cfg.num_layers - 1, self.t_proj, out)
        return np.maximum(out, 1e-6)

    # ---- online contention refresh (serving telemetry) ----
    def observe(self, *, load: int, capacity: int, backlog_s: float,
                wait_s: float, n_flows: int | None = None,
                share: float | None = None) -> None:
        """Record one served request's contention outcome: the device
        occupancy / service backlog it was admitted against and the
        queue wait it actually experienced (``EngineResult.
        compute_wait_s``), plus — when it streamed — the flow count at
        admission and the observed bottleneck link share
        (min over ``LinkTopology.stage_shares``). Observations buffer
        until :meth:`refresh`; only the newest ``obs_window`` are kept."""
        self._wait_obs.append((float(load), float(max(capacity, 1)),
                               float(backlog_s), float(max(wait_s, 0.0))))
        del self._wait_obs[:-self.obs_window]
        if n_flows is not None and share is not None:
            self._share_obs.append((float(max(n_flows, 1)),
                                    float(np.clip(share, 0.0, 1.0))))
            del self._share_obs[:-self.obs_window]

    @property
    def refreshed(self) -> bool:
        """True once refresh() has fit at least one contention model —
        the gate ``repro.serving.slo.predict_ttft`` checks before
        preferring the learned terms over the analytic fallback."""
        return self._wait_coef is not None or self._eta_hat is not None

    def refresh(self, *, min_samples: int = 8,
                ridge: float = 1e-3) -> dict | None:
        """Retrain the contention models on the buffered observations.

        Wait model: ridge least-squares from (occupancy/capacity,
        backlog/capacity) to realized queue wait — the learned
        replacement for the analytic max(occupancy dilation, backlog
        drain) of ``slo.predict_ttft``. Share model: the aggregate link
        efficiency ``eta_hat`` solving share ~= eta/n over the observed
        (flow count, bottleneck share) pairs. Either model stays None
        (analytic fallback) below ``min_samples``; returns a fit report
        or None when nothing was trainable."""
        report: dict = {}
        if len(self._wait_obs) >= min_samples:
            obs = np.asarray(self._wait_obs)
            x = self._wait_features(obs[:, 0], obs[:, 1], obs[:, 2])
            y = obs[:, 3]
            gram = x.T @ x + ridge * np.eye(x.shape[1])
            self._wait_coef = np.linalg.solve(gram, x.T @ y)
            pred = np.maximum(x @ self._wait_coef, 0.0)
            report.update(n_wait_obs=len(self._wait_obs),
                          wait_mae_s=float(np.abs(pred - y).mean()))
        if len(self._share_obs) >= min_samples:
            obs = np.asarray(self._share_obs)
            self._eta_hat = float(np.clip((obs[:, 0] * obs[:, 1]).mean(),
                                          0.05, 1.0))
            report.update(n_share_obs=len(self._share_obs),
                          eta_hat=self._eta_hat)
        return report or None

    @staticmethod
    def _wait_features(load, capacity, backlog_s) -> np.ndarray:
        load = np.atleast_1d(np.asarray(load, float))
        cap = np.maximum(np.atleast_1d(np.asarray(capacity, float)), 1.0)
        backlog = np.atleast_1d(np.asarray(backlog_s, float))
        return np.stack([load / cap, backlog / cap,
                         np.ones_like(load)], axis=1)

    def predict_wait_s(self, load: int, capacity: int,
                       backlog_s: float) -> float | None:
        """Learned device queue wait for a request admitted against this
        occupancy/backlog; None before the first successful refresh."""
        if self._wait_coef is None:
            return None
        x = self._wait_features(load, capacity, backlog_s)
        return max(float((x @ self._wait_coef)[0]), 0.0)

    def predict_share(self, n_flows: int) -> float | None:
        """Learned per-flow bottleneck link share with `n_flows` active;
        None before a successful share refresh."""
        if self._eta_hat is None:
            return None
        return min(self._eta_hat / max(n_flows, 1), 1.0)

    def effective_capacity(self, mean_bw: float, n_flows: int = 1) -> float:
        """Aggregate deliverable bandwidth of a fair-shared link carrying
        ``n_flows``: profiled mean scaled by the learned contention
        efficiency ``eta_hat`` (the profiled mean itself when
        unrefreshed). The fleet rebalancer's warm-start capacity estimate
        — its LP sees link capacities the online model has already
        corrected for MAC-contention overhead."""
        share = self.predict_share(max(n_flows, 1))
        if share is None:
            return float(mean_bw)
        return float(mean_bw * share * max(n_flows, 1))
