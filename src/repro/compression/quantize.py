"""Per-group uniform quantization for streamed KV chunks (paper §V: 5-bit
uniform + Huffman; CacheGen-style layer-wise bit allocation supported).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class QuantizedTensor:
    codes: np.ndarray      # uint8 symbols in [0, 2^bits)
    scales: np.ndarray     # (groups,) float32 step = span / (2^bits - 1)
    zeros: np.ndarray      # (groups,) float32
    bits: int
    group: int
    shape: tuple
    dtype: str = "float32"
    # per-group value range hi - lo (clamped). Bit-width independent, so
    # the mixed-bitwidth dequant path can re-derive any width's step as
    # spans / (2^bits - 1) from one shared parameter plane. None on
    # tensors quantized before this field existed.
    spans: np.ndarray = None

    @property
    def n_symbols(self) -> int:
        return 1 << self.bits

    def header_bytes(self) -> int:
        # scales+zeros in fp16 on the wire + small fixed header
        return 2 * 2 * self.scales.size + 16


def quantize(x: np.ndarray, bits: int, group: int) -> QuantizedTensor:
    """Uniform asymmetric per-group quantization. x flattened to groups."""
    shape = x.shape
    flat = np.asarray(x, np.float32).reshape(-1)
    pad = (-len(flat)) % group
    if pad:
        # edge-pad so the tail group's lo/hi come from its real values
        # only (a repeated member never widens min/max); zero-padding
        # biased the tail group's affine params toward 0.0 whenever
        # x.size % group != 0
        flat = np.pad(flat, (0, pad), mode="edge") if len(flat) else \
            np.zeros(pad, np.float32)
    g = flat.reshape(-1, group)
    lo = g.min(axis=1)
    hi = g.max(axis=1)
    span = np.maximum(hi - lo, 1e-8)
    q = (1 << bits) - 1
    scales = span / q
    codes = np.clip(np.round((g - lo[:, None]) / scales[:, None]),
                    0, q).astype(np.uint8)
    return QuantizedTensor(codes=codes.reshape(-1)[:int(np.prod(shape))],
                           scales=scales.astype(np.float32),
                           zeros=lo.astype(np.float32),
                           bits=bits, group=group, shape=tuple(shape),
                           spans=span.astype(np.float32))


def dequantize(qt: QuantizedTensor) -> np.ndarray:
    flat = qt.codes.astype(np.float32)
    pad = (-len(flat)) % qt.group
    if pad:
        flat = np.concatenate([flat, np.zeros(pad, np.float32)])
    g = flat.reshape(-1, qt.group)
    x = g * qt.scales[:, None] + qt.zeros[:, None]
    return x.reshape(-1)[:int(np.prod(qt.shape))].reshape(qt.shape)


def quant_error(x: np.ndarray, bits: int, group: int) -> float:
    qt = quantize(x, bits, group)
    xr = dequantize(qt)
    denom = float(np.sqrt(np.mean(np.square(x))) + 1e-12)
    return float(np.sqrt(np.mean(np.square(xr - x)))) / denom


# CacheGen-style bitrate ladder for adaptive streaming baselines.
BITRATE_LEVELS = (8, 6, 5, 4, 3)


def downgrade_ladder(bits: int) -> tuple[int, ...]:
    """Ladder levels coarser than `bits`, finest first — the quality-
    shedding walk SLO admission takes when a request's predicted TTFT
    misses its deadline (``repro.serving.slo``): fewer bits means fewer
    streamed bytes at a fidelity cost given by
    ``repro.core.baselines.QUALITY_OF_BITS``."""
    return tuple(b for b in BITRATE_LEVELS if b < bits)


def snap_to_ladder(bits: int) -> int:
    """Nearest supported ``BITRATE_LEVELS`` width (ties resolve to the
    finer level). Every consumer keyed on bit-width — the
    ``baselines.QUALITY_OF_BITS`` fidelity map, the memory server's
    3-bit floor, the SLO ladder walk — is total over ladder widths, so
    allocations must land on them."""
    return min(BITRATE_LEVELS, key=lambda b: (abs(b - bits), -b))


def layerwise_bits(level: int, layer: int, num_layers: int,
                   is_key: bool) -> int:
    """Layer-wise sensitivity allocation: keys and shallow layers get more
    bits (CacheGen observation). level indexes BITRATE_LEVELS. The raw
    base + bonus - penalty arithmetic can land off the ladder (7 from
    level 1 + key bonus; 2 from the deep-layer penalty at the floor), so
    the result is snapped to the nearest supported width."""
    base = BITRATE_LEVELS[level]
    bonus = 1 if (is_key and base < 8) else 0
    penalty = 1 if (layer > (2 * num_layers) // 3 and base > 3) else 0
    return snap_to_ladder(base + bonus - penalty)
