"""Canonical Huffman codec with SIMD-style interleaved multi-stream decode.

Real bitstreams (this is what goes over the simulated wire, and roundtrip
exactness is tested). Sequential Huffman decode is unvectorizable, so —
like production entropy coders (interleaved rANS) — we split symbols into S
independent streams decoded in lockstep with numpy gathers: the decode loop
runs max-symbols-per-stream iterations, each vectorized across streams.

Max code length is capped at MAX_LEN (table-driven decode, 2^16 entries);
if the unrestricted Huffman tree exceeds it, counts are flattened toward
uniform until it fits (tiny rate loss, recorded by the caller via actual
encoded size).
"""
from __future__ import annotations

import dataclasses
import heapq

import numpy as np

MAX_LEN = 16


def _code_lengths(counts: np.ndarray) -> np.ndarray:
    """Huffman code lengths per symbol (0 for absent symbols)."""
    n = len(counts)
    active = [int(s) for s in np.nonzero(counts)[0]]
    if not active:
        return np.zeros(n, np.int32)
    if len(active) == 1:
        out = np.zeros(n, np.int32)
        out[active[0]] = 1
        return out
    flat = counts.astype(np.float64)
    for _ in range(32):
        heap = [(float(flat[s]), i, (s,)) for i, s in enumerate(active)]
        heapq.heapify(heap)
        uid = len(heap)
        depth = {s: 0 for s in active}
        while len(heap) > 1:
            c1, _, s1 = heapq.heappop(heap)
            c2, _, s2 = heapq.heappop(heap)
            for s in s1 + s2:
                depth[s] += 1
            heapq.heappush(heap, (c1 + c2, uid, s1 + s2))
            uid += 1
        lens = np.zeros(n, np.int32)
        for s, d in depth.items():
            lens[s] = d
        if lens.max() <= MAX_LEN:
            return lens
        # flatten the distribution and retry
        flat = np.sqrt(flat) * flat.sum() / np.maximum(
            np.sqrt(flat).sum(), 1e-9)
        flat[np.asarray(active)] = np.maximum(flat[np.asarray(active)], 1.0)
    raise RuntimeError("could not limit Huffman code length")


def _canonical_codes(lens: np.ndarray) -> np.ndarray:
    """Canonical code values (uint16) from lengths."""
    n = len(lens)
    codes = np.zeros(n, np.uint16)
    code = 0
    prev_len = 0
    order = sorted((l, s) for s, l in enumerate(lens) if l > 0)
    for l, s in order:
        code <<= (l - prev_len)
        codes[s] = code
        code += 1
        prev_len = l
    return codes


@dataclasses.dataclass
class HuffmanCode:
    lengths: np.ndarray    # (n_symbols,) int32
    codes: np.ndarray      # (n_symbols,) uint16

    @classmethod
    def from_counts(cls, counts: np.ndarray) -> "HuffmanCode":
        lens = _code_lengths(np.asarray(counts))
        return cls(lengths=lens, codes=_canonical_codes(lens))

    def table_bytes(self) -> int:
        return len(self.lengths)  # one length byte per symbol (canonical)

    def decode_table(self):
        """(symbol, length) uint16 arrays indexed by 16-bit window."""
        sym = np.zeros(1 << MAX_LEN, np.uint16)
        ln = np.zeros(1 << MAX_LEN, np.uint16)
        for s, l in enumerate(self.lengths):
            l = int(l)
            if l == 0:
                continue
            prefix = int(self.codes[s]) << (MAX_LEN - l)
            span = 1 << (MAX_LEN - l)
            sym[prefix:prefix + span] = s
            ln[prefix:prefix + span] = l
        return sym, ln


@dataclasses.dataclass
class EncodedChunk:
    streams: np.ndarray        # (S, max_bytes) uint8
    bit_lengths: np.ndarray    # (S,) int64
    n_per_stream: np.ndarray   # (S,) int64 symbol counts
    n_symbols_alphabet: int
    code: HuffmanCode
    n_total: int

    def payload_bytes(self) -> int:
        return int(np.sum((self.bit_lengths + 7) // 8)) \
            + self.code.table_bytes() + 4 * len(self.bit_lengths)


def encode(symbols: np.ndarray, n_alphabet: int,
           n_streams: int = 64) -> EncodedChunk:
    symbols = np.asarray(symbols, np.uint16).reshape(-1)
    n = len(symbols)
    counts = np.bincount(symbols, minlength=n_alphabet)
    code = HuffmanCode.from_counts(counts)

    s = min(n_streams, max(1, n))
    per = -(-n // s)
    pad = s * per - n
    syms = np.concatenate([symbols, np.zeros(pad, np.uint16)])
    syms = syms.reshape(s, per)
    n_per = np.full(s, per, np.int64)
    if pad:
        n_per[-1] -= 0  # padding symbols live in the last rows
        full_rows = n // per
        n_per[:] = per
        n_per[full_rows] = n - full_rows * per if full_rows < s else per
        n_per[full_rows + 1:] = 0

    lens = code.lengths[syms]                                  # (s, per)
    codes = code.codes[syms].astype(np.uint32)

    # valid mask (ignore padding symbols)
    valid = np.arange(per)[None, :] < n_per[:, None]
    lens = np.where(valid, lens, 0)

    bit_lengths = lens.sum(axis=1).astype(np.int64)
    max_bits = int(bit_lengths.max()) if s else 0
    max_bytes = (max_bits + 7) // 8 + 4                        # decode slack
    out = np.zeros((s, max_bytes * 8), np.uint8)

    # vectorized bit placement per stream
    ends = np.cumsum(lens, axis=1)
    starts = ends - lens
    total = int(lens.sum())
    if total:
        row = np.repeat(np.arange(s)[:, None].repeat(per, 1).reshape(-1),
                        lens.reshape(-1))
        off = np.repeat(starts.reshape(-1), lens.reshape(-1))
        intra = (np.arange(total)
                 - np.repeat(np.cumsum(lens.reshape(-1))
                             - lens.reshape(-1), lens.reshape(-1)))
        l_rep = np.repeat(lens.reshape(-1), lens.reshape(-1))
        c_rep = np.repeat(codes.reshape(-1), lens.reshape(-1))
        bits = (c_rep >> (l_rep - 1 - intra)) & 1
        out[row, off + intra] = bits.astype(np.uint8)

    streams = np.packbits(out, axis=1)
    return EncodedChunk(streams=streams, bit_lengths=bit_lengths,
                        n_per_stream=n_per, n_symbols_alphabet=n_alphabet,
                        code=code, n_total=n)


def decode(enc: EncodedChunk) -> np.ndarray:
    sym_t, len_t = enc.code.decode_table()
    s, nbytes = enc.streams.shape
    per = int(enc.n_per_stream.max())
    out = np.zeros((s, per), np.uint16)
    pos = np.zeros(s, np.int64)
    b = enc.streams.astype(np.uint32)
    pad = np.zeros((s, 4), np.uint32)
    b = np.concatenate([b, pad], axis=1)
    rows = np.arange(s)
    active_count = enc.n_per_stream.copy()
    for i in range(per):
        byte_idx = pos >> 3
        shift = (pos & 7).astype(np.uint32)
        w = ((b[rows, byte_idx] << 16)
             | (b[rows, byte_idx + 1] << 8)
             | b[rows, byte_idx + 2])
        w = (w >> (8 - shift)) & 0xFFFF
        sym = sym_t[w]
        ln = len_t[w]
        act = i < active_count
        out[:, i] = np.where(act, sym, 0)
        pos = pos + np.where(act, ln.astype(np.int64), 0)
    flat = []
    for r in range(s):
        flat.append(out[r, :int(enc.n_per_stream[r])])
    return np.concatenate(flat) if flat else np.zeros(0, np.uint16)


def entropy_bits(symbols: np.ndarray, n_alphabet: int) -> float:
    counts = np.bincount(np.asarray(symbols, np.int64).reshape(-1),
                         minlength=n_alphabet).astype(np.float64)
    p = counts / max(counts.sum(), 1)
    nz = p[p > 0]
    return float(-(nz * np.log2(nz)).sum())
