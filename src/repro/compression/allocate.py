"""Per-chunk saliency scoring and declarative bit-allocation schedules.

"Don't Waste Bits"-style allocation: the engine already measures, per
(token-chunk, layer), how much attention mass the chunk carries
(``sparse/mask.block_scores`` pooled into ``WorkloadChunks.
active_blocks``) and how information-dense its quantized KV is
(``huffman.entropy_bits`` -> ``WorkloadChunks.entropy_bits``). This
module turns those two signals into a per-chunk bit-width plan:

  saliency  s(t, l, h) = attention-mass share x entropy factor
  schedule  a declarative list of quantile-band rules mapping saliency
            rank -> ladder shift (finer for hot chunks, coarser for
            cold), every output snapped to ``BITRATE_LEVELS``.

Schedules are recipe-style: a schedule is data (name + rules), not
code, so fleets select one by name (``SparKVConfig.alloc_schedule``)
and new allocation policies are new table rows. The ``"uniform"``
schedule is the arming sentinel — with it, nothing per-chunk is built
anywhere in the stack and every trace is bit-identical to pre-PR runs;
``"flat"`` arms the per-chunk accounting (saliency-weighted quality,
per-chunk keys) while still allocating the base width everywhere, so
uniform-allocation fleets stay byte-identical on the wire.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from repro.compression.quantize import BITRATE_LEVELS, snap_to_ladder


@dataclasses.dataclass(frozen=True)
class AllocationRule:
    """One band of the saliency spectrum: chunks whose saliency
    *quantile rank* falls in [lo_q, hi_q) move ``delta`` rungs along
    ``BITRATE_LEVELS`` from the base width (positive = finer = more
    bits). Bands may not overlap within a schedule; unbanded ranks keep
    the base width."""
    lo_q: float
    hi_q: float
    delta: int

    def __post_init__(self):
        assert 0.0 <= self.lo_q < self.hi_q <= 1.0, (self.lo_q, self.hi_q)


@dataclasses.dataclass(frozen=True)
class AllocationSchedule:
    """Declarative per-chunk bit-allocation recipe (see module doc)."""
    name: str
    rules: tuple = ()

    def __post_init__(self):
        spans = sorted((r.lo_q, r.hi_q) for r in self.rules)
        for (_, hi), (lo, _) in zip(spans, spans[1:]):
            assert hi <= lo, f"{self.name}: overlapping rule bands"

    def shift_for_rank(self, rank: np.ndarray) -> np.ndarray:
        """Ladder shift per chunk from its saliency quantile rank."""
        shift = np.zeros(rank.shape, np.int64)
        for r in self.rules:
            hit = (rank >= r.lo_q) & (rank < r.hi_q)
            shift[hit] = r.delta
        return shift


def ladder_shift(bits: int, delta: int) -> int:
    """Move ``delta`` rungs along BITRATE_LEVELS from ``bits`` (snapped
    first); positive deltas go finer, clamped at the ladder ends."""
    idx = BITRATE_LEVELS.index(snap_to_ladder(bits))
    return BITRATE_LEVELS[int(np.clip(idx - delta, 0,
                                      len(BITRATE_LEVELS) - 1))]


# The recipe table. "uniform" = per-chunk machinery disarmed (sentinel);
# "flat" = armed but allocating base everywhere (byte-identical wire);
# "attention" = the paper-motivated default: the hottest 30% of chunks
# by saliency go one rung finer, the coldest 40% one rung coarser;
# "aggressive" = trade harder: coldest half two rungs down.
SCHEDULES: dict[str, AllocationSchedule] = {
    "uniform": AllocationSchedule("uniform"),
    "flat": AllocationSchedule("flat"),
    "attention": AllocationSchedule("attention", (
        AllocationRule(0.0, 0.4, -1),
        AllocationRule(0.7, 1.0, +1),
    )),
    "aggressive": AllocationSchedule("aggressive", (
        AllocationRule(0.0, 0.5, -2),
        AllocationRule(0.8, 1.0, +1),
    )),
}


def chunk_saliency(active_blocks: np.ndarray,
                   entropy_bits: np.ndarray) -> np.ndarray:
    """Per-chunk saliency from the two measured signals.

    ``active_blocks`` is (n_t, n_l, n_h) attention mass (blocks the
    sparse mask keeps); ``entropy_bits`` is (n_l, n_h) bits/value of the
    quantized KV. Saliency is the normalized attention-mass share scaled
    by a normalized entropy factor: a chunk matters when attention reads
    it a lot *and* its values carry information worth the bits. Output
    is (n_t, n_l, n_h), mean ~1, all entries > 0.
    """
    act = np.asarray(active_blocks, np.float64)
    ent = np.asarray(entropy_bits, np.float64)
    a = act / max(float(act.mean()), 1e-12)
    e = ent / max(float(ent.mean()), 1e-12) if float(ent.sum()) > 0 \
        else np.ones_like(ent)
    # entropy enters sub-linearly: attention mass is the primary signal
    # (Fig. 3's 15-20x spread), entropy tilts within it
    s = a * (0.5 + 0.5 * np.broadcast_to(e, act.shape))
    return np.maximum(s, 1e-9)


def saliency_ranks(saliency: np.ndarray) -> np.ndarray:
    """Quantile rank in [0, 1) of each chunk's saliency (stable order,
    ties broken by flat index so allocation is deterministic)."""
    flat = saliency.reshape(-1)
    order = np.argsort(flat, kind="stable")
    rank = np.empty(flat.size, np.float64)
    rank[order] = np.arange(flat.size, dtype=np.float64) / flat.size
    return rank.reshape(saliency.shape)


def allocate_bits(active_blocks: np.ndarray, entropy_bits: np.ndarray,
                  base_bits: int, schedule: AllocationSchedule
                  ) -> np.ndarray:
    """Per-chunk bit-widths (same shape as ``active_blocks``, int64),
    every entry a ``BITRATE_LEVELS`` width. An empty-rule schedule
    returns the snapped base everywhere."""
    base = snap_to_ladder(base_bits)
    sal = chunk_saliency(active_blocks, entropy_bits)
    shift = schedule.shift_for_rank(saliency_ranks(sal))
    out = np.empty(shift.shape, np.int64)
    for d in np.unique(shift):
        out[shift == d] = ladder_shift(base, int(d))
    return out


def schedule_of(name: str) -> AllocationSchedule:
    if name not in SCHEDULES:
        raise KeyError(f"unknown allocation schedule {name!r}; "
                       f"have {sorted(SCHEDULES)}")
    return SCHEDULES[name]
