"""KV compression: group quantization + multi-stream Huffman coding."""
