"""Training loop, optimizer, and gradient-compression hooks."""
