"""AdamW + LR schedules, pure-pytree implementation (no optax dependency).

Moment dtype is configurable per model (fp32 default; bf16 for the 235B MoE
to fit v5e HBM — see DESIGN.md §4 dtype policy).
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    m: dict
    v: dict
    count: jax.Array


def warmup_cosine(lr: float, warmup: int, total: int, floor: float = 0.1):
    def sched(step):
        step = step.astype(jnp.float32)
        w = jnp.minimum(step / jnp.maximum(warmup, 1), 1.0)
        prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0, 1)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
        return lr * w * (floor + (1 - floor) * cos)
    return sched


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def clip_by_global_norm(tree, max_norm: float):
    gn = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(gn, 1e-9))
    return jax.tree.map(lambda x: (x.astype(jnp.float32) * scale
                                   ).astype(x.dtype), tree), gn


class AdamW:
    def __init__(self, tcfg, moment_dtype: str = "float32"):
        self.cfg = tcfg
        self.sched = warmup_cosine(tcfg.learning_rate, tcfg.warmup_steps,
                                   tcfg.total_steps)
        self.moment_dtype = jnp.dtype(moment_dtype)

    def init(self, params) -> AdamWState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return AdamWState(m=jax.tree.map(zeros, params),
                          v=jax.tree.map(zeros, params),
                          count=jnp.zeros((), jnp.int32))

    def abstract_state(self, abstract_params) -> AdamWState:
        zeros = lambda p: jax.ShapeDtypeStruct(p.shape, self.moment_dtype)
        return AdamWState(m=jax.tree.map(zeros, abstract_params),
                          v=jax.tree.map(zeros, abstract_params),
                          count=jax.ShapeDtypeStruct((), jnp.int32))

    def state_pspecs(self, param_pspecs):
        from jax.sharding import PartitionSpec as P
        return AdamWState(m=param_pspecs, v=param_pspecs, count=P())

    def update(self, grads, state: AdamWState, params):
        c = self.cfg
        grads, gnorm = clip_by_global_norm(grads, c.grad_clip)
        count = state.count + 1
        b1, b2 = c.b1, c.b2
        bc1 = 1 - b1 ** count.astype(jnp.float32)
        bc2 = 1 - b2 ** count.astype(jnp.float32)
        lr = self.sched(count)

        def upd(p, g, m, v):
            gf = g.astype(jnp.float32)
            mf = m.astype(jnp.float32) * b1 + gf * (1 - b1)
            vf = v.astype(jnp.float32) * b2 + gf * gf * (1 - b2)
            step = (mf / bc1) / (jnp.sqrt(vf / bc2) + c.eps)
            step = step + c.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * step
            return (new_p.astype(p.dtype), mf.astype(m.dtype),
                    vf.astype(v.dtype))

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        new_p = jax.tree.map(lambda t: t[0], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda x: isinstance(x, tuple))
        return new_p, AdamWState(new_m, new_v, count), {
            "grad_norm": gnorm, "lr": lr}
