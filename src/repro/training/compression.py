"""Gradient compression for the data-parallel all-reduce: int8 quantized
gradients with error feedback (EF-SGD style). At 1000+ nodes the DP
all-reduce is DCN-bound; int8 cuts wire bytes 4x vs fp32 (2x vs bf16) at
negligible quality cost when the residual is fed back.

Used via shard_map over the data axes: local grads are quantized, psum'd
in int32, dequantized; the quantization residual is carried in the
optimizer state and added to the next step's gradient.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def _quant_int8(g):
    a = jnp.max(jnp.abs(g)) + 1e-12
    scale = a / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def _dequant_int8(q, scale):
    return q.astype(jnp.float32) * scale


def compressed_psum(grads, error_state, data_axes):
    """Inside shard_map: per-leaf int8 quantize -> psum -> dequant, with
    error feedback. Returns (mean grads, new error state)."""
    n = jax.lax.psum(1, data_axes)

    def one(g, e):
        gf = g.astype(jnp.float32) + e
        # shared scale across the group so the int32 reduction is exact
        a = jnp.max(jnp.abs(gf)) + 1e-12
        scale = jax.lax.pmax(a / 127.0, data_axes)
        q = jnp.clip(jnp.round(gf / scale), -127, 127)
        total = jax.lax.psum(q.astype(jnp.int32), data_axes)
        avg = total.astype(jnp.float32) * scale / n
        new_e = gf - q * scale
        return avg.astype(g.dtype), new_e

    flat_g, tree = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(error_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    new_g = jax.tree.unflatten(tree, [o[0] for o in out])
    new_e = jax.tree.unflatten(tree, [o[1] for o in out])
    return new_g, new_e


def init_error_state(params):
    return jax.tree.map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
