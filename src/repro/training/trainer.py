"""Train-step builder: loss -> grads (optionally microbatched / compressed)
-> AdamW update, with sharding-rules context and donation, plus a
supervised training driver with fault injection, checkpoint/restart and
deterministic step-indexed data (see launch/train.py for the CLI).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig, TrainConfig
from repro.distributed.sharding import Rules, use_rules
from repro.models.api import Model
from repro.training.optimizer import AdamW, AdamWState


@dataclasses.dataclass
class TrainState:
    params: dict
    opt: AdamWState
    step: int


def build_train_step(model: Model, tcfg: TrainConfig,
                     rules: Optional[Rules] = None) -> Callable:
    """Returns train_step(params, opt_state, batch) ->
    (params, opt_state, metrics)."""
    opt = AdamW(tcfg, model.cfg.moment_dtype)

    def loss_fn(params, batch):
        with use_rules(rules):
            return model.loss(params, batch)

    def train_step(params, opt_state, batch):
        if tcfg.microbatches > 1:
            mb = tcfg.microbatches
            def reshape(x):
                b = x.shape[0]
                return x.reshape(mb, b // mb, *x.shape[1:])
            batches = jax.tree.map(reshape, batch)

            def acc_fn(carry, mb_batch):
                loss_sum, g_sum = carry
                l, g = jax.value_and_grad(loss_fn)(params, mb_batch)
                g_sum = jax.tree.map(
                    lambda a, b: a + b.astype(jnp.float32), g_sum, g)
                return (loss_sum + l, g_sum), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (loss, grads), _ = jax.lax.scan(acc_fn, (jnp.float32(0), zeros),
                                            batches)
            loss = loss / mb
            grads = jax.tree.map(lambda gg: (gg / mb), grads)
        else:
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        new_p, new_opt, metrics = opt.update(grads, opt_state, params)
        return new_p, new_opt, dict(metrics, loss=loss)

    return train_step, opt


def jit_train_step(model: Model, tcfg: TrainConfig, rules: Rules,
                   batch_pspecs):
    """Fully-sharded jitted train step (what dryrun lowers and train runs)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mesh = rules.mesh
    step_fn, opt = build_train_step(model, tcfg, rules)
    pspecs = model.param_pspecs(rules)
    opt_specs = opt.state_pspecs(pspecs)
    ns = lambda t: jax.tree.map(lambda s: NamedSharding(mesh, s), t)
    metric_specs = {"grad_norm": P(), "lr": P(), "loss": P()}
    return jax.jit(
        step_fn,
        in_shardings=(ns(pspecs), ns(opt_specs), ns(batch_pspecs)),
        out_shardings=(ns(pspecs), ns(opt_specs), ns(metric_specs)),
        donate_argnums=(0, 1)), opt


class FaultInjector:
    """Deterministic simulated node failures for fault-tolerance tests."""

    def __init__(self, fail_steps: tuple[int, ...] = ()):
        self.fail_steps = set(fail_steps)
        self.fired: set[int] = set()

    def maybe_fail(self, step: int):
        if step in self.fail_steps and step not in self.fired:
            self.fired.add(step)
            raise RuntimeError(f"injected node failure at step {step}")


def data_batch(cfg: ModelConfig, tcfg: TrainConfig, step: int,
               batch: int, seq: int) -> dict:
    """Deterministic step-indexed batch: restart-safe without data-loader
    state (the PRNG key is a pure function of (seed, step))."""
    from repro.data.workloads import lm_token_batch
    rng = np.random.default_rng((tcfg.seed, step))
    if cfg.family == "encdec":
        frames = rng.normal(size=(batch, seq, cfg.d_model)) * 0.1
        dec = rng.integers(0, cfg.vocab_size,
                           size=(batch, cfg.dec_len + 1))
        return {"frames": jnp.asarray(frames, jnp.bfloat16),
                "dec_tokens": jnp.asarray(dec, jnp.int32)}
    toks = lm_token_batch(rng, cfg.vocab_size, batch, seq + 1,
                          motif_seed=tcfg.seed)
    return {"tokens": jnp.asarray(toks, jnp.int32)}


def train_loop(model: Model, tcfg: TrainConfig, *, batch: int, seq: int,
               steps: int, rules: Optional[Rules] = None,
               ckpt_manager=None, fault: Optional[FaultInjector] = None,
               log_every: int = 10, resume: bool = True) -> dict:
    """Supervised loop: restores from the last checkpoint if present,
    injects faults if configured (caller catches + restarts), checkpoints
    periodically. Returns final metrics + loss history."""
    step_fn, opt = build_train_step(model, tcfg, rules)
    step_fn = jax.jit(step_fn, donate_argnums=(0, 1))

    start = 0
    params = model.init(jax.random.PRNGKey(tcfg.seed))
    opt_state = opt.init(params)
    if ckpt_manager is not None and resume:
        restored = ckpt_manager.restore_latest(
            like={"params": params, "opt": opt_state})
        if restored is not None:
            state, start = restored
            params, opt_state = state["params"], state["opt"]

    history = []
    t0 = time.time()
    for step in range(start, steps):
        if fault is not None:
            fault.maybe_fail(step)
        batch_data = data_batch(model.cfg, tcfg, step, batch, seq)
        params, opt_state, metrics = step_fn(params, opt_state, batch_data)
        if (step + 1) % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            history.append((step, loss))
        if ckpt_manager is not None and \
                (step + 1) % tcfg.checkpoint_every == 0:
            ckpt_manager.save({"params": params, "opt": opt_state},
                              step + 1)
    if ckpt_manager is not None:
        ckpt_manager.save({"params": params, "opt": opt_state}, steps)
        ckpt_manager.wait()
    return {
        "history": history,
        "final_loss": history[-1][1] if history else None,
        "steps": steps,
        "wall_s": time.time() - t0,
        "params": params,
        "opt": opt_state,
    }
