"""Checkpointing for fault tolerance and elastic scaling.

Design (scaled-down but faithful to how pod-scale JAX checkpointing works):
  - every leaf is written as a separate .npy inside a step directory with
    a JSON index (tree structure + shapes/dtypes + step metadata);
  - writes go to  <dir>/tmp-<step>  and are COMMITTED by atomic rename to
    <dir>/step-<step>; a crash mid-write never corrupts the latest commit;
  - saves run on a background thread (training continues); `wait()` joins;
  - restore targets any mesh: arrays are saved unsharded (gathered), and
    on restore the caller re-shards via jax.device_put with its own
    shardings — elastic scaling 256 -> 512 chips is a restore;
  - retention: keep the last `keep` commits.

At real pod scale the .npy writes become per-host shard files on a
distributed FS; the commit protocol and index are unchanged.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any, Optional

import jax
import numpy as np


def _flatten(tree) -> tuple[list, Any]:
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_save = async_save
        self._thread: Optional[threading.Thread] = None
        os.makedirs(directory, exist_ok=True)

    # ---------- save ----------
    def save(self, state: dict, step: int, *, block: bool = False):
        leaves, treedef = _flatten(state)
        host_leaves = [np.asarray(x) for x in leaves]
        self.wait()
        if self.async_save and not block:
            self._thread = threading.Thread(
                target=self._write, args=(host_leaves, treedef, step),
                daemon=True)
            self._thread.start()
        else:
            self._write(host_leaves, treedef, step)

    def _write(self, leaves, treedef, step: int):
        tmp = os.path.join(self.dir, f"tmp-{step}")
        final = os.path.join(self.dir, f"step-{step}")
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        index = {
            "step": step,
            "time": time.time(),
            "treedef": str(treedef),
            "leaves": [],
        }
        for i, leaf in enumerate(leaves):
            logical = str(leaf.dtype)
            arr = leaf
            if logical not in ("float32", "float64", "int32", "int64",
                               "uint8", "uint16", "uint32", "int8",
                               "int16", "bool", "float16"):
                # bf16 & friends: store as a raw bit view
                arr = leaf.view(np.uint16 if leaf.dtype.itemsize == 2
                                else np.uint8)
            np.save(os.path.join(tmp, f"leaf_{i}.npy"), arr)
            index["leaves"].append({
                "i": i, "shape": list(leaf.shape), "dtype": logical,
            })
        with open(os.path.join(tmp, "index.json"), "w") as f:
            json.dump(index, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)                     # atomic commit
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep] if self.keep else []:
            shutil.rmtree(os.path.join(self.dir, f"step-{s}"),
                          ignore_errors=True)

    def wait(self):
        if self._thread is not None and self._thread.is_alive():
            self._thread.join()
        self._thread = None

    # ---------- restore ----------
    def all_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step-"):
                if os.path.exists(os.path.join(self.dir, name,
                                               "index.json")):
                    out.append(int(name.split("-")[1]))
        return sorted(out)

    def restore(self, step: int, *, like: Optional[dict] = None,
                shardings: Optional[dict] = None):
        """Returns the state pytree. `like` provides the treedef (restores
        into the same structure); `shardings` (same structure) re-shards
        every leaf for the current mesh (elastic restore)."""
        path = os.path.join(self.dir, f"step-{step}")
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
        leaves = [self._load_leaf(path, e) for e in index["leaves"]]
        if like is None:
            raise ValueError("restore requires `like` for the treedef")
        _, treedef = _flatten(like)
        state = jax.tree.unflatten(treedef, leaves)
        if shardings is not None:
            state = jax.tree.map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        else:
            like_leaves = jax.tree.leaves(like)
            state = jax.tree.unflatten(
                treedef,
                [jax.numpy.asarray(x, l.dtype if hasattr(l, "dtype")
                                   else None)
                 for x, l in zip(leaves, like_leaves)])
        return state

    @staticmethod
    def _load_leaf(path: str, entry: dict) -> np.ndarray:
        arr = np.load(os.path.join(path, f"leaf_{entry['i']}.npy"))
        logical = entry["dtype"]
        if str(arr.dtype) != logical:
            import ml_dtypes
            arr = arr.view(np.dtype(getattr(ml_dtypes, logical)))
        return arr

    def restore_latest(self, *, like: Optional[dict] = None,
                       shardings: Optional[dict] = None):
        steps = self.all_steps()
        if not steps:
            return None
        if like is None:
            return self._restore_raw(steps[-1]), steps[-1]
        return self.restore(steps[-1], like=like,
                            shardings=shardings), steps[-1]

    def _restore_raw(self, step: int):
        """Structure-free restore (list of arrays + index) — used by the
        trainer which knows its own structure."""
        path = os.path.join(self.dir, f"step-{step}")
        with open(os.path.join(path, "index.json")) as f:
            index = json.load(f)
        leaves = [np.load(os.path.join(path, f"leaf_{e['i']}.npy"))
                  for e in index["leaves"]]
        return {"_leaves": leaves, "_index": index}
