"""Checkpointing: async save/restore of training state."""
