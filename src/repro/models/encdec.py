"""Whisper-style encoder-decoder backbone [arXiv:2212.04356].

The conv audio frontend is a STUB per the assignment: inputs are precomputed
frame embeddings (batch, seq, d_model). Encoder uses bidirectional attention
(no RoPE — absolute positions are the stub's responsibility); decoder uses
learned positions, causal self-attention, and cross-attention to the encoder
output.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import transformer as T
from repro.models.layers import ParamDef


def param_defs(cfg) -> dict:
    ne, nd = cfg.num_layers, cfg.dec_layers
    return {
        "emb": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed")),
        "pos_emb": ParamDef((cfg.dec_len, cfg.d_model), (None, "embed")),
        "enc_norm": L.norm_defs(cfg, cfg.d_model),
        "dec_norm": L.norm_defs(cfg, cfg.d_model),
        "enc": {
            "attn_norm": L.norm_defs(cfg, cfg.d_model, prefix_shape=(ne,)),
            "mlp_norm": L.norm_defs(cfg, cfg.d_model, prefix_shape=(ne,)),
            "attn": L.attention_defs(cfg, stacked=ne),
            "mlp": L.mlp_defs(cfg, stacked=ne),
        },
        "dec": {
            "self_norm": L.norm_defs(cfg, cfg.d_model, prefix_shape=(nd,)),
            "cross_norm": L.norm_defs(cfg, cfg.d_model, prefix_shape=(nd,)),
            "mlp_norm": L.norm_defs(cfg, cfg.d_model, prefix_shape=(nd,)),
            "self_attn": L.attention_defs(cfg, stacked=nd),
            "cross_attn": L.attention_defs(cfg, stacked=nd),
            "mlp": L.mlp_defs(cfg, stacked=nd),
        },
    }


def encode(cfg, params, frames):
    """frames: (b, s, d) bf16 -> encoder output (b, s, d)."""
    x = frames
    x = constrain(x, "batch", "block_seq", None)

    def body(x, bp):
        h = L.apply_norm(cfg, x, bp["attn_norm"])
        q, k, v = L.attention_qkv(cfg, bp["attn"], h, None, use_rope=False)
        o = L.flash_attention(q, k, v, causal=False,
                              kv_chunk=cfg.attn_chunk)
        x = x + L.attention_out(bp["attn"], o)
        x = constrain(x, "batch", "block_seq", None)
        h = L.apply_norm(cfg, x, bp["mlp_norm"])
        x = x + L.mlp_block(cfg, bp["mlp"], h)
        return constrain(x, "batch", "block_seq", None), None

    body = T._remat(cfg, body)
    x, _ = jax.lax.scan(body, x, params["enc"],
                        unroll=cfg.scan_unroll)
    return L.apply_norm(cfg, x, params["enc_norm"])


def _cross_kv(cfg, params, enc_out):
    """Precompute per-dec-layer cross-attention K/V from encoder output.

    This is the whisper analogue of the SparKV streamable artifact.
    Returns (k, v): (nd, b, s_enc, hkv, hd).
    """
    def proj(x, bp):
        k = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross_attn"]["wk"])
        v = jnp.einsum("bsd,dhk->bshk", enc_out, bp["cross_attn"]["wv"])
        if cfg.qkv_bias:
            k = k + bp["cross_attn"]["bk"]
            v = v + bp["cross_attn"]["bv"]
        return x, (k, v)

    _, kv = jax.lax.scan(proj, 0.0, params["dec"],
                         unroll=cfg.scan_unroll)
    return kv


def _dec_block(cfg, bp, x, positions, cross_kv, *, self_cache=None, pos=None):
    # causal self-attention (RoPE-free; learned positions added at embed)
    h = L.apply_norm(cfg, x, bp["self_norm"])
    q, k, v = L.attention_qkv(cfg, bp["self_attn"], h, None, use_rope=False)
    if self_cache is None:
        o = L.flash_attention(q, k, v, causal=True, kv_chunk=512)
        new_kv = (k, v)
    else:
        ck, cv = self_cache
        ck = jax.lax.dynamic_update_slice(ck, k, (0, pos, 0, 0))
        cv = jax.lax.dynamic_update_slice(cv, v, (0, pos, 0, 0))
        o = L.flash_attention(q, ck, cv, causal=False, kv_len=pos + 1,
                              kv_chunk=512)
        new_kv = (ck, cv)
    x = x + L.attention_out(bp["self_attn"], o)

    # cross-attention to encoder output
    h = L.apply_norm(cfg, x, bp["cross_norm"])
    q = jnp.einsum("bsd,dhk->bshk", h, bp["cross_attn"]["wq"])
    if cfg.qkv_bias:
        q = q + bp["cross_attn"]["bq"]
    ek, ev = cross_kv
    o = L.flash_attention(q, ek, ev, causal=False,
                          kv_chunk=cfg.attn_chunk)
    x = x + L.attention_out(bp["cross_attn"], o)

    h = L.apply_norm(cfg, x, bp["mlp_norm"])
    x = x + L.mlp_block(cfg, bp["mlp"], h)
    return x, new_kv


def decode_train(cfg, params, enc_out, dec_tokens):
    """Teacher-forced decoder. dec_tokens: (b, t)."""
    t = dec_tokens.shape[1]
    x = jnp.take(params["emb"], dec_tokens, axis=0)
    x = x + params["pos_emb"][None, :t, :].astype(x.dtype)
    cross = _cross_kv(cfg, params, enc_out)

    def body(x, xs):
        bp, ckv = xs
        x, _ = _dec_block(cfg, bp, x, None, ckv)
        return x, None

    body = T._remat(cfg, body)
    x, _ = jax.lax.scan(body, x, (params["dec"], cross),
                        unroll=cfg.scan_unroll)
    return L.apply_norm(cfg, x, params["dec_norm"])


def loss_fn(cfg, params, batch):
    frames, dec_tokens = batch["frames"], batch["dec_tokens"]
    inp, labels = dec_tokens[:, :-1], dec_tokens[:, 1:]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    enc_out = encode(cfg, params, frames)
    x = decode_train(cfg, params, enc_out, inp)
    tot = T.softmax_xent(cfg, params, x, labels, mask,
                         chunk=min(cfg.loss_chunk, 128))
    return tot / jnp.maximum(mask.sum(), 1.0)


def prefill(cfg, params, frames):
    """Encoder pass + cross-KV construction (the streamable KV artifact)."""
    enc_out = encode(cfg, params, frames)
    ck, cv = _cross_kv(cfg, params, enc_out)
    return {"cross_k": ck, "cross_v": cv}


def init_cache(cfg, batch: int, enc_len: int, dtype=jnp.bfloat16):
    nd, hkv, hd = cfg.dec_layers, cfg.num_kv_heads, cfg.resolved_head_dim
    return {
        "cross_k": jnp.zeros((nd, batch, enc_len, hkv, hd), dtype),
        "cross_v": jnp.zeros((nd, batch, enc_len, hkv, hd), dtype),
        "self_k": jnp.zeros((nd, batch, cfg.dec_len, hkv, hd), dtype),
        "self_v": jnp.zeros((nd, batch, cfg.dec_len, hkv, hd), dtype),
    }


def cache_axes(cfg):
    kv = ("layers", "batch", "kv_seq", "act_kv", None)
    sf = ("layers", "batch", None, "act_kv", None)
    return {"cross_k": kv, "cross_v": kv, "self_k": sf, "self_v": sf}


def decode_step(cfg, params, cache, token, pos):
    """One decoder token; cross-KV comes from the cache (streamed/computed)."""
    x = jnp.take(params["emb"], token[:, None], axis=0)
    pe = jax.lax.dynamic_slice_in_dim(params["pos_emb"], pos, 1, 0)
    x = x + pe[None].astype(x.dtype)

    def body(carry, xs):
        x, sk, sv, l = carry
        bp, ck, cv = xs
        self_l = (jax.lax.dynamic_index_in_dim(sk, l, 0, keepdims=False),
                  jax.lax.dynamic_index_in_dim(sv, l, 0, keepdims=False))
        x, (nk, nv) = _dec_block(cfg, bp, x, None, (ck, cv),
                                 self_cache=self_l, pos=pos)
        sk = jax.lax.dynamic_update_index_in_dim(sk, nk, l, 0)
        sv = jax.lax.dynamic_update_index_in_dim(sv, nv, l, 0)
        return (x, sk, sv, l + 1), None

    carry = (x, cache["self_k"], cache["self_v"], jnp.int32(0))
    (x, sk, sv, _), _ = jax.lax.scan(
        body, carry, (params["dec"], cache["cross_k"], cache["cross_v"]),
        unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["dec_norm"])
    logits = T.unembed(cfg, params, x)[:, 0, :]
    return logits, dict(cache, self_k=sk, self_v=sv)
