"""Shared model building blocks (pure JAX, GSPMD-friendly).

Conventions:
  - parameters are declared as ParamDef (shape + logical axes + init) trees;
  - activations are bf16 unless noted; softmax/statistics in fp32;
  - the reference attention is a chunked flash implementation (lax.scan over
    KV blocks with running softmax) so 32k-token prefill never materializes
    an S x S score matrix. The Pallas kernels in repro.kernels are the TPU
    hot path; this file is the oracle + dry-run path.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain

# ----------------------------------------------------------------------------
# Parameter declaration
# ----------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple          # logical axis names, len == len(shape)
    init: str = "normal"  # normal | zeros | ones
    scale: float = 0.02
    dtype: str = "bfloat16"

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_def(x) -> bool:
    return isinstance(x, ParamDef)


def abstract_params(defs):
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype)),
        defs, is_leaf=is_def)


def axes_tree(defs):
    return jax.tree.map(lambda d: d.axes, defs, is_leaf=is_def)


def init_params(defs, rng):
    flat, treedef = jax.tree.flatten(defs, is_leaf=is_def)
    keys = jax.random.split(rng, len(flat))
    out = []
    for d, k in zip(flat, keys):
        dt = jnp.dtype(d.dtype)
        if d.init == "zeros":
            out.append(jnp.zeros(d.shape, dt))
        elif d.init == "ones":
            out.append(jnp.ones(d.shape, dt))
        elif d.init == "normal":
            out.append((jax.random.normal(k, d.shape, jnp.float32)
                        * d.scale).astype(dt))
        else:
            raise ValueError(d.init)
    return jax.tree.unflatten(treedef, out)


def pspec_tree(defs, rules):
    return jax.tree.map(lambda d: rules.spec(d.axes, d.shape),
                        defs, is_leaf=is_def)


def sharding_tree(defs, rules):
    return jax.tree.map(lambda d: rules.sharding(d.axes, d.shape),
                        defs, is_leaf=is_def)


# ----------------------------------------------------------------------------
# Norms / activations / RoPE
# ----------------------------------------------------------------------------


def rms_norm(x, scale, eps: float = 1e-6):
    """Statistics in fp32, application in x.dtype. Upcasting the whole
    tensor (flax-style) makes the backward residual-stream cotangent fp32
    at the exact point GSPMD inserts the model-axis combine — measured 2x
    collective bytes on chameleon-34b train_4k (EXPERIMENTS.md §Perf)."""
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1,
                   keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    return x * inv * (1.0 + scale.astype(x.dtype))


def layer_norm(x, scale, bias, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps).astype(x.dtype)
    mu = mu.astype(x.dtype)
    return (x - mu) * inv * (1.0 + scale.astype(x.dtype)) \
        + bias.astype(x.dtype)


def apply_norm(cfg, x, p):
    if cfg.norm == "rmsnorm":
        return rms_norm(x, p["scale"])
    return layer_norm(x, p["scale"], p["bias"])


def norm_defs(cfg, d: int, prefix_shape=()) -> dict:
    defs = {"scale": ParamDef(prefix_shape + (d,),
                              ("layers",) * len(prefix_shape) + ("embed",),
                              init="zeros")}
    if cfg.norm == "layernorm":
        defs["bias"] = ParamDef(prefix_shape + (d,),
                                ("layers",) * len(prefix_shape) + ("embed",),
                                init="zeros")
    return defs


@jax.custom_vjp
def bf16_grad_barrier(x):
    """Identity forward; casts the cotangent to bf16.

    The training residual stream is bf16, but a single fp32 cotangent
    entering it (e.g. from an fp32 loss head) stays fp32 through every
    residual add below (bf16 + f32 promotes), making every model-axis
    backward collective fp32 — 2x wire. This barrier pins the gradient
    dtype at block boundaries (§Perf iteration 4c)."""
    return x


def _bgb_fwd(x):
    return x, None


def _bgb_bwd(_, g):
    return (g.astype(jnp.bfloat16),)


bf16_grad_barrier.defvjp(_bgb_fwd, _bgb_bwd)


def act_fn(name: str):
    if name in ("swiglu", "silu"):
        return jax.nn.silu
    if name in ("geglu", "gelu"):
        return partial(jax.nn.gelu, approximate=True)
    raise ValueError(name)


def rope(x, positions, theta: float):
    """Rotary embedding, llama-style half rotation.

    x: (..., s, h, d); positions: broadcastable to (..., s).
    """
    d = x.shape[-1]
    half = d // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs   # (..., s, half)
    cos = jnp.cos(ang)[..., None, :]                         # (..., s, 1, half)
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------------
# Chunked flash attention (reference / dry-run path)
# ----------------------------------------------------------------------------


def flash_attention(q, k, v, *, causal: bool, q_offset=0,
                    kv_len: Optional[jax.Array] = None,
                    kv_chunk: int = 1024, scale: Optional[float] = None,
                    return_stats: bool = False):
    """Memory-efficient attention with GQA support.

    q: (b, sq, hq, d); k/v: (b, skv, hkv, d), hq % hkv == 0.
    kv_len: optional dynamic valid length (decode); default skv.
    Returns (b, sq, hq, d) in q.dtype.

    GQA is handled by *repeating* kv heads to hq (Megatron convention)
    rather than a (hkv, g) reshape of q — a grouped reshape of a
    model-axis-sharded head dim is not a rectangular resharding and would
    force GSPMD to all-gather the q heads.
    """
    b, sq, hq, d = q.shape
    _, skv, hkv, _ = k.shape
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    if g > 1:
        k = jnp.repeat(k, g, axis=2)
        v = jnp.repeat(v, g, axis=2)
        if sq > 1:
            k = constrain(k, "batch", "seq", "act_heads", None)
            v = constrain(v, "batch", "seq", "act_heads", None)
        else:
            # decode: keep the (possibly seq-sharded) cache layout; q is a
            # single token — forcing head sharding here would all-gather
            # the whole cache instead of the tiny q.
            k = constrain(k, "batch", "kv_seq", None, None)
            v = constrain(v, "batch", "kv_seq", None, None)
    kv_chunk = min(kv_chunk, skv)
    q_pos = q_offset + jnp.arange(sq)

    if kv_len is None:
        kv_len = jnp.asarray(skv, jnp.int32)

    nc = -(-skv // kv_chunk)
    pad = nc * kv_chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    def block(kc, vc, start, m, l, acc):
        # bf16 matmul + explicit upcast: preferred_element_type=f32 makes
        # the *backward* ds->dq/dk dots produce fp32 cotangents that flow
        # into the residual stream and double every model-axis collective
        # (EXPERIMENTS.md §Perf iteration 4b)
        s = jnp.einsum("bqhd,bkhd->bhqk", q, kc).astype(jnp.float32) * scale
        kv_pos = start + jnp.arange(kv_chunk)
        mask = kv_pos[None, :] < kv_len
        if causal:
            mask = mask & (q_pos[:, None] >= kv_pos[None, :])
        s = jnp.where(mask, s, -jnp.inf)                     # (q, k) bcast
        m_new = jnp.maximum(m, s.max(-1))
        # guard fully-masked rows (m_new == -inf)
        m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
        p = jnp.exp(s - m_safe[..., None])
        p = jnp.where(mask, p, 0.0)
        corr = jnp.where(jnp.isinf(m), 0.0, jnp.exp(m - m_safe))
        l_new = l * corr + p.sum(-1)
        pv = jnp.einsum("bhqk,bkhd->bhqd", p.astype(v.dtype), vc,
                        preferred_element_type=jnp.float32)
        acc_new = acc * corr[..., None] + pv
        return m_new, l_new, acc_new

    if nc == 1:
        m0 = jnp.full((b, hq, sq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, hq, sq), jnp.float32)
        a0 = jnp.zeros((b, hq, sq, d), jnp.float32)
        m, l, acc = block(k, v, 0, m0, l0, a0)
    else:
        ks = jnp.moveaxis(k.reshape(b, nc, kv_chunk, hq, d), 1, 0)
        vs = jnp.moveaxis(v.reshape(b, nc, kv_chunk, hq, d), 1, 0)

        def body(carry, xs):
            m, l, acc = carry
            kc, vc, idx = xs
            m, l, acc = block(kc, vc, idx * kv_chunk, m, l, acc)
            return (m, l, acc), None

        init = (jnp.full((b, hq, sq), -jnp.inf, jnp.float32),
                jnp.zeros((b, hq, sq), jnp.float32),
                jnp.zeros((b, hq, sq, d), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(body, init,
                                      (ks, vs, jnp.arange(nc)))

    out = acc / jnp.maximum(l, 1e-37)[..., None]             # (b,hq,sq,d)
    out = jnp.moveaxis(out, 2, 1)
    if return_stats:
        return out.astype(q.dtype), m, l                      # (b,hq,sq)
    return out.astype(q.dtype)


def merge_attention(parts):
    """Combine flash partials [(out, m, l), ...] over disjoint kv sets.

    out: (b, s, h, d); m/l: (b, h, s). The softmax-stats merge — used to
    attend over [static seq-sharded context cache] + [small replicated
    tail of decoded tokens] without dynamic updates into the sharded
    cache (a dynamic-index update on a model-sharded seq dim makes GSPMD
    all-gather the cache every layer; see EXPERIMENTS.md §Perf decode)."""
    ms = jnp.stack([m for _, m, _ in parts])                  # (p,b,h,s)
    m_star = jnp.max(ms, axis=0)
    num = 0.0
    den = 0.0
    for out, m, l in parts:
        w = (l * jnp.exp(m - m_star))                         # (b,h,s)
        num = num + jnp.moveaxis(w, 1, 2)[..., None] \
            * out.astype(jnp.float32)
        den = den + jnp.moveaxis(w, 1, 2)
    out = num / jnp.maximum(den, 1e-37)[..., None]
    return out.astype(parts[0][0].dtype)


# ----------------------------------------------------------------------------
# Attention layer
# ----------------------------------------------------------------------------


def attention_defs(cfg, *, stacked: int = 0, cross: bool = False) -> dict:
    d, hq, hkv, hd = (cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                      cfg.resolved_head_dim)
    pre = (stacked,) if stacked else ()
    pax = ("layers",) if stacked else ()
    defs = {
        "wq": ParamDef(pre + (d, hq, hd), pax + ("embed_fsdp", "heads", "head_dim")),
        "wk": ParamDef(pre + (d, hkv, hd), pax + ("embed_fsdp", "kv_heads", "head_dim")),
        "wv": ParamDef(pre + (d, hkv, hd), pax + ("embed_fsdp", "kv_heads", "head_dim")),
        "wo": ParamDef(pre + (hq, hd, d), pax + ("heads", "head_dim", "embed_fsdp"),
                       scale=0.02 / np.sqrt(2 * max(cfg.num_layers, 1))),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef(pre + (hq, hd), pax + ("heads", "head_dim"), init="zeros")
        defs["bk"] = ParamDef(pre + (hkv, hd), pax + ("kv_heads", "head_dim"), init="zeros")
        defs["bv"] = ParamDef(pre + (hkv, hd), pax + ("kv_heads", "head_dim"), init="zeros")
    return defs


def attention_qkv(cfg, p, x, positions=None, *, use_rope: bool = True):
    """Project to q, k, v (+bias, +rope). x: (b, s, d)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    if cfg.qkv_bias:
        q = q + p["bq"]
        k = k + p["bk"]
        v = v + p["bv"]
    if use_rope and positions is not None:
        q = rope(q, positions, cfg.rope_theta)
        k = rope(k, positions, cfg.rope_theta)
    q = constrain(q, "batch", "seq", "act_heads", None)
    k = constrain(k, "batch", "seq", "act_kv", None)
    v = constrain(v, "batch", "seq", "act_kv", None)
    return q, k, v


def attention_out(p, o):
    """o: (b, s, hq, hd) -> (b, s, d)."""
    return jnp.einsum("bshk,hkd->bsd", o, p["wo"])


# ----------------------------------------------------------------------------
# Dense / gated MLP
# ----------------------------------------------------------------------------


def mlp_defs(cfg, *, stacked: int = 0, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    pre = (stacked,) if stacked else ()
    pax = ("layers",) if stacked else ()
    gated = cfg.activation in ("swiglu", "geglu")
    defs = {
        "w_up": ParamDef(pre + (d, f), pax + ("embed_fsdp", "mlp")),
        "w_down": ParamDef(pre + (f, d), pax + ("mlp", "embed_fsdp"),
                           scale=0.02 / np.sqrt(2 * max(cfg.num_layers, 1))),
    }
    if gated:
        defs["w_gate"] = ParamDef(pre + (d, f), pax + ("embed_fsdp", "mlp"))
    return defs


def mlp_block(cfg, p, x):
    act = act_fn(cfg.activation)
    up = jnp.einsum("bsd,df->bsf", x, p["w_up"])
    if "w_gate" in p:
        gate = jnp.einsum("bsd,df->bsf", x, p["w_gate"])
        h = act(gate) * up
    else:
        h = act(up)
    h = constrain(h, "batch", "seq", "act_mlp")
    return jnp.einsum("bsf,fd->bsd", h, p["w_down"])


# ----------------------------------------------------------------------------
# Mixture of Experts (sort-based, dropping, GShard-capacity)
# ----------------------------------------------------------------------------


def moe_defs(cfg, *, stacked: int = 0) -> dict:
    assert cfg.moe is not None
    d, f, e = cfg.d_model, cfg.d_ff, cfg.moe.num_experts
    pre = (stacked,) if stacked else ()
    pax = ("layers",) if stacked else ()
    down_scale = 0.02 / np.sqrt(2 * max(cfg.num_layers, 1))
    defs = {
        "router": ParamDef(pre + (d, e), pax + ("embed", None)),
        "w_up": ParamDef(pre + (e, d, f), pax + ("experts", "embed_fsdp", "mlp")),
        "w_gate": ParamDef(pre + (e, d, f), pax + ("experts", "embed_fsdp", "mlp")),
        "w_down": ParamDef(pre + (e, f, d), pax + ("experts", "mlp", "embed_fsdp"),
                           scale=down_scale),
    }
    return defs


def moe_block(cfg, p, x):
    """x: (b, s, d) -> (y, aux_loss).

    Dispatches to the shard_map two-stage implementation when a mesh-rules
    context is active (auto-GSPMD partitioning of a global sort/gather
    dispatch replicates the token gather — measured 924 GiB/device on the
    235B config; see EXPERIMENTS.md §Perf). Falls back to the single-device
    sort-based implementation otherwise (smoke tests, oracles).
    """
    from repro.distributed.sharding import current_rules
    rules = current_rules()
    if rules is not None and rules.mesh.devices.size > 1:
        return moe_block_sharded(cfg, p, x, rules)
    return moe_block_local(cfg, p, x)


def moe_block_local(cfg, p, x):
    """Single-device sort-based dispatch with capacity (oracle path)."""
    mo = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = mo.num_experts, mo.experts_per_token
    xf = x.reshape(t, d)
    xf = constrain(xf, "batch", None)

    logits = jnp.einsum("td,de->te", xf, p["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (t, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)               # renormalize

    # load-balancing aux loss (Switch-style)
    me = probs.mean(0)                                        # (e,)
    assign_frac = jnp.zeros(e).at[gate_idx.reshape(-1)].add(1.0) / (t * k)
    aux = e * jnp.sum(me * assign_frac)

    # flatten (t, k) assignments and sort by expert
    tk = t * k
    eids = gate_idx.reshape(tk)
    tok = jnp.repeat(jnp.arange(t, dtype=jnp.int32), k)
    gflat = gate_vals.reshape(tk)
    order = jnp.argsort(eids)
    se, st, sg = eids[order], tok[order], gflat[order]

    counts = jnp.zeros(e, jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    pos = jnp.arange(tk, dtype=jnp.int32) - starts[se]

    cap = int(np.ceil(mo.capacity_factor * tk / e))
    cap = max(4, -(-cap // 4) * 4)
    keep = pos < cap
    pos_c = jnp.where(keep, pos, cap)                         # drop OOB

    buf = jnp.zeros((e, cap + 1, d), x.dtype)
    buf = buf.at[se, pos_c].set(
        jnp.where(keep[:, None], xf[st], 0).astype(x.dtype), mode="drop")
    buf = buf[:, :cap]
    buf = constrain(buf, "experts", "expert_cap", None)

    act = act_fn(cfg.activation)
    up = jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    gate = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])
    h = act(gate) * up
    h = constrain(h, "experts", "expert_cap", "act_mlp")
    out = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    out = constrain(out, "experts", "expert_cap", None)

    vals = out[se, pos_c]                                     # (tk, d)
    vals = jnp.where(keep[:, None], vals, 0)
    y = jnp.zeros((t, d), jnp.float32).at[st].add(
        vals.astype(jnp.float32) * sg[:, None])
    y = constrain(y, "batch", None)
    return y.reshape(b, s, d).astype(x.dtype), aux


def moe_block_sharded(cfg, p, x, rules):
    """Two-stage MoE dispatch under shard_map (Megatron-EP style).

    Tokens are sharded over the data axes and replicated over the model
    axis; each device routes *locally*:
      EP mode (E % model == 0): device (i, j) keeps only assignments whose
        expert lives in model-column j, compacts them into an
        (E_loc, C_loc, d) buffer, runs its expert slices, scatters partial
        outputs back to local token order, and psums over "model".
      TP mode (E not divisible): every device processes all local
        assignments against d_ff-sharded expert weights; the down-proj
        contraction is partial over f and the same psum combines it.

    Two weight layouts (rules.table["embed_fsdp"] decides):
      FSDP (training): expert weights sharded on the embed dim over the
        data axes, all-gathered inside per use (weights travel).
      weight-stationary (decode): weights keep their f-dim shard; the
        *tokens* are all-gathered over the data axes instead (a few MB at
        decode vs ~27 GB/step of weight gathers on the 235B config) and
        the f-partial down-projection psums over data.
    """
    try:
        from jax import shard_map
        sm_kw = {"check_vma": False}
    except ImportError:                      # jax < 0.5: experimental API
        from jax.experimental.shard_map import shard_map
        sm_kw = {"check_rep": False}
    from jax.sharding import PartitionSpec as P

    mo = cfg.moe
    mesh = rules.mesh
    b, s, d = x.shape
    e, k = mo.num_experts, mo.experts_per_token
    data_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    msize = mesh.shape.get("model", 1)
    ep = e % msize == 0 and msize > 1
    e_loc = e // msize if ep else e
    ws = rules.weight_stationary and bool(data_axes)

    t_route = ((b // n_data) * s) if not ws else b * s
    cap = int(np.ceil(mo.capacity_factor * t_route * k / e))
    cap = max(8, -(-cap // 8) * 8)

    # weight specs mirror the rules resolution of moe_defs axes
    w_up_spec = rules.spec(("experts", "embed_fsdp", "mlp"),
                           p["w_up"].shape)
    w_dn_spec = rules.spec(("experts", "mlp", "embed_fsdp"),
                           p["w_down"].shape)
    act = act_fn(cfg.activation)

    def body(xl, router, w_up, w_gate, w_down):
        # xl: (b_loc, s, d); router: (d, e); w_*: local expert slices
        ax_model = "model" if msize > 1 else None
        j = jax.lax.axis_index(ax_model) if ep else 0
        bl = xl.shape[0]
        xf = xl.reshape(bl * s, d)

        if ws:
            # decode: gather the (tiny) token batch; weights stay put
            xf = jax.lax.all_gather(xf, data_axes, axis=0, tiled=True)
        elif data_axes:
            w_up = jax.lax.all_gather(w_up, data_axes, axis=1, tiled=True)
            w_gate = jax.lax.all_gather(w_gate, data_axes, axis=1,
                                        tiled=True)
            w_down = jax.lax.all_gather(w_down, data_axes, axis=2,
                                        tiled=True)
        tl = xf.shape[0]

        logits = jnp.einsum("td,de->te", xf, router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, gate_idx = jax.lax.top_k(probs, k)
        gate_vals = gate_vals / jnp.maximum(
            gate_vals.sum(-1, keepdims=True), 1e-9)

        me = probs.mean(0)
        frac = jnp.zeros(e).at[gate_idx.reshape(-1)].add(1.0) / (tl * k)
        aux = e * jnp.sum(me * frac)

        tk = tl * k
        eids = gate_idx.reshape(tk)
        tok = jnp.repeat(jnp.arange(tl, dtype=jnp.int32), k)
        gflat = gate_vals.reshape(tk)

        if ep:
            mine = (eids // e_loc) == j
            local_eid = jnp.where(mine, eids - j * e_loc, e_loc)
        else:
            mine = jnp.ones(tk, bool)
            local_eid = eids
        order = jnp.argsort(jnp.where(mine, local_eid, e_loc + 1))
        se, st, sg = local_eid[order], tok[order], gflat[order]
        valid = se < e_loc if ep else jnp.ones(tk, bool)

        counts = jnp.zeros(e_loc + 2, jnp.int32).at[se].add(1)
        starts = jnp.concatenate([jnp.zeros(1, jnp.int32),
                                  jnp.cumsum(counts)[:-1]])
        pos = jnp.arange(tk, dtype=jnp.int32) - starts[se]
        keep = valid & (pos < cap)
        pos_c = jnp.where(keep, pos, cap)
        se_c = jnp.minimum(se, e_loc - 1)

        buf = jnp.zeros((e_loc, cap + 1, d), xl.dtype)
        buf = buf.at[se_c, pos_c].set(
            jnp.where(keep[:, None], xf[st], 0).astype(xl.dtype),
            mode="drop")
        buf = buf[:, :cap]

        up = jnp.einsum("ecd,edf->ecf", buf, w_up)
        gate = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        if ws:
            # f-dim sharded over data: every data shard holds the same
            # (gathered) tokens, so the partial down-projection psums
            up = act(gate) * up
            out = jnp.einsum("ecf,efd->ecd", up, w_down)
            out = jax.lax.psum(out, data_axes)
        else:
            h = act(gate) * up
            out = jnp.einsum("ecf,efd->ecd", h, w_down)

        vals = out[se_c, pos_c]
        vals = jnp.where(keep[:, None], vals, 0)
        y = jnp.zeros((tl, d), jnp.float32).at[st].add(
            vals.astype(jnp.float32) * sg[:, None])
        if msize > 1:
            y = jax.lax.psum(y, "model")
        if data_axes:
            aux = jax.lax.pmean(aux, data_axes)
        if ws:
            # take back this shard's own token rows
            didx = 0
            for a in data_axes:
                didx = didx * mesh.shape[a] + jax.lax.axis_index(a)
            y = jax.lax.dynamic_slice_in_dim(y, didx * (bl * s), bl * s, 0)
        return y.reshape(bl, s, d).astype(xl.dtype), aux

    y, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(data_axes, None, None), P(None, None),
                  w_up_spec, w_up_spec, w_dn_spec),
        out_specs=(P(data_axes, None, None), P()),
        **sm_kw,
    )(x, p["router"], p["w_up"], p["w_gate"], p["w_down"])
    return y, aux
