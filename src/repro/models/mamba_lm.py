"""Pure-SSM LM (mamba2-130m): scanned Mamba2 blocks, no attention."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import ssm
from repro.models import transformer as T
from repro.models.layers import ParamDef


def param_defs(cfg) -> dict:
    n = cfg.num_layers
    return {
        "emb": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed")),
        "final_norm": L.norm_defs(cfg, cfg.d_model),
        "blocks": {
            "norm": L.norm_defs(cfg, cfg.d_model, prefix_shape=(n,)),
            "ssm": ssm.ssm_defs(cfg, stacked=n),
        },
    }


def forward(cfg, params, tokens, *, collect_state: bool = False):
    x = jnp.take(params["emb"], tokens, axis=0)
    x = constrain(x, "batch", "block_seq", None)

    def body(x, bp):
        h = L.apply_norm(cfg, x, bp["norm"])
        y, cache = ssm.ssm_block(cfg, bp["ssm"], h,
                                 return_state=collect_state)
        x = x + y
        x = constrain(x, "batch", "block_seq", None)
        return x, cache

    body = T._remat(cfg, body)
    x, caches = jax.lax.scan(body, x, params["blocks"],
                             unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["final_norm"])
    return x, caches


def loss_fn(cfg, params, batch):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    x, _ = forward(cfg, params, inp)
    tot = T.softmax_xent(cfg, params, x, labels, mask)
    return tot / jnp.maximum(mask.sum(), 1.0)


def prefill(cfg, params, tokens):
    x, caches = forward(cfg, params, tokens, collect_state=True)
    logits = T.unembed(cfg, params, x[:, -1:, :])[:, 0, :]
    return logits, caches


def init_cache(cfg, batch: int, capacity: int = 0, dtype=jnp.bfloat16):
    del capacity  # SSM state is O(1) in context length
    n_l = cfg.num_layers
    d_inner, h, p, n = ssm.ssm_dims(cfg)
    ch = ssm.conv_cache_channels(cfg)
    return {
        "conv": jnp.zeros((n_l, batch, cfg.ssm.conv_width - 1, ch), dtype),
        "state": jnp.zeros((n_l, batch, h, p, n), jnp.float32),
    }


def cache_axes(cfg):
    return {
        "conv": ("layers", "batch", None, None),
        "state": ("layers", "batch", "ssm_heads", "ssm_pdim", "state"),
    }


def decode_step(cfg, params, cache, token, pos):
    del pos  # recurrent state carries position implicitly
    x = jnp.take(params["emb"], token[:, None], axis=0)

    def body(carry, bp):
        x, conv_c, state_c, l = carry
        cache_l = {
            "conv": jax.lax.dynamic_index_in_dim(conv_c, l, 0, keepdims=False),
            "state": jax.lax.dynamic_index_in_dim(state_c, l, 0, keepdims=False),
        }
        h = L.apply_norm(cfg, x, bp["norm"])
        y, new_c = ssm.ssm_block(cfg, bp["ssm"], h, cache=cache_l)
        x = x + y
        conv_c = jax.lax.dynamic_update_index_in_dim(
            conv_c, new_c["conv"].astype(conv_c.dtype), l, 0)
        state_c = jax.lax.dynamic_update_index_in_dim(
            state_c, new_c["state"].astype(state_c.dtype), l, 0)
        return (x, conv_c, state_c, l + 1), None

    (x, conv_c, state_c, _), _ = jax.lax.scan(
        body, (x, cache["conv"], cache["state"], jnp.int32(0)),
        params["blocks"], unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = T.unembed(cfg, params, x)[:, 0, :]
    return logits, {"conv": conv_c, "state": state_c}
