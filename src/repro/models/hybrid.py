"""Zamba2-style hybrid: Mamba2 backbone + one *shared* attention block
applied every `attn_every` layers (re-using the same parameters, separate KV
per application) [arXiv:2411.15242].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models import ssm
from repro.models import transformer as T
from repro.models.layers import ParamDef


def n_attn_apps(cfg) -> int:
    return cfg.num_layers // cfg.attn_every


def param_defs(cfg) -> dict:
    n = cfg.num_layers
    assert n % cfg.attn_every == 0
    return {
        "emb": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed")),
        "final_norm": L.norm_defs(cfg, cfg.d_model),
        "blocks": {
            "norm": L.norm_defs(cfg, cfg.d_model, prefix_shape=(n,)),
            "ssm": ssm.ssm_defs(cfg, stacked=n),
        },
        "shared": {
            "attn_norm": L.norm_defs(cfg, cfg.d_model),
            "mlp_norm": L.norm_defs(cfg, cfg.d_model),
            "attn": L.attention_defs(cfg),
            "mlp": L.mlp_defs(cfg),
        },
    }


def _group_params(cfg, params):
    """Reshape stacked (L, ...) mamba params to (groups, attn_every, ...)."""
    g, k = n_attn_apps(cfg), cfg.attn_every
    return jax.tree.map(lambda a: a.reshape((g, k) + a.shape[1:]),
                        params["blocks"])


def _shared_attn(cfg, sp, x, positions, *, kv_cache=None, pos=None):
    h = L.apply_norm(cfg, x, sp["attn_norm"])
    q, k, v = L.attention_qkv(cfg, sp["attn"], h, positions)
    if kv_cache is None:
        o = L.flash_attention(q, k, v, causal=True,
                              kv_chunk=cfg.attn_chunk)
        new_kv = (k, v)
    else:
        # static context + replicated tail (see transformer.DECODE_TAIL)
        ctx_k, ctx_v, tail_k, tail_v = kv_cache
        o, tail_k, tail_v = T.decode_attention(
            cfg, sp["attn"], q, k, v, ctx_k, ctx_v, tail_k, tail_v,
            pos - ctx_k.shape[1])
        new_kv = (tail_k, tail_v)
    x = x + L.attention_out(sp["attn"], o)
    x = constrain(x, "batch", "block_seq", None)
    h = L.apply_norm(cfg, x, sp["mlp_norm"])
    x = x + L.mlp_block(cfg, sp["mlp"], h)
    return constrain(x, "batch", "block_seq", None), new_kv


def forward(cfg, params, tokens, *, collect: bool = False):
    x = jnp.take(params["emb"], tokens, axis=0)
    x = constrain(x, "batch", "block_seq", None)
    positions = jnp.arange(tokens.shape[1])
    gp = _group_params(cfg, params)
    sp = params["shared"]

    def inner(x, bp):
        h = L.apply_norm(cfg, x, bp["norm"])
        y, cache = ssm.ssm_block(cfg, bp["ssm"], h, return_state=collect)
        x = x + y
        return constrain(x, "batch", "block_seq", None), cache

    inner = T._remat(cfg, inner)
    # the shared attention block must be rematerialized too: un-rematted,
    # its per-kv-chunk softmax residuals dominate train memory (~34 GiB/dev
    # measured on zamba2 train_4k — EXPERIMENTS.md §Perf).
    shared_attn = T._remat(cfg, lambda x: _shared_attn(cfg, sp, x, positions))

    def group(x, bp_g):
        x, ssm_caches = jax.lax.scan(inner, x, bp_g,
                                      unroll=cfg.scan_unroll)
        x, kv = shared_attn(x)
        ys = (ssm_caches, kv) if collect else None
        return x, ys

    x, caches = jax.lax.scan(group, x, gp, unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["final_norm"])
    return x, caches


def loss_fn(cfg, params, batch):
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    x, _ = forward(cfg, params, inp)
    tot = T.softmax_xent(cfg, params, x, labels, mask)
    return tot / jnp.maximum(mask.sum(), 1.0)


def prefill(cfg, params, tokens):
    x, caches = forward(cfg, params, tokens, collect=True)
    ssm_caches, kvs = caches
    logits = T.unembed(cfg, params, x[:, -1:, :])[:, 0, :]
    # ssm_caches leaves: (groups, attn_every, b, ...) -> flatten layer dims
    flat = jax.tree.map(
        lambda a: a.reshape((-1,) + a.shape[2:]), ssm_caches)
    return logits, {"ssm": flat, "attn_k": kvs[0], "attn_v": kvs[1]}


def init_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16):
    n_l, g = cfg.num_layers, n_attn_apps(cfg)
    d_inner, h, p, n = ssm.ssm_dims(cfg)
    ch = ssm.conv_cache_channels(cfg)
    return {
        "ssm": {
            "conv": jnp.zeros((n_l, batch, cfg.ssm.conv_width - 1, ch), dtype),
            "state": jnp.zeros((n_l, batch, h, p, n), jnp.float32),
        },
        "attn_k": jnp.zeros((g, batch, capacity, cfg.num_kv_heads,
                             cfg.resolved_head_dim), dtype),
        "attn_v": jnp.zeros((g, batch, capacity, cfg.num_kv_heads,
                             cfg.resolved_head_dim), dtype),
        "attn_tail_k": jnp.zeros((g, batch, T.DECODE_TAIL,
                                  cfg.num_kv_heads,
                                  cfg.resolved_head_dim), dtype),
        "attn_tail_v": jnp.zeros((g, batch, T.DECODE_TAIL,
                                  cfg.num_kv_heads,
                                  cfg.resolved_head_dim), dtype),
    }


def cache_axes(cfg):
    kv = ("layers", "batch", "kv_seq", "act_kv", None)
    tl = ("layers", "batch", None, "act_kv", None)
    return {
        "ssm": {
            "conv": ("layers", "batch", None, None),
            "state": ("layers", "batch", "ssm_heads", "ssm_pdim", "state"),
        },
        "attn_k": kv, "attn_v": kv,
        "attn_tail_k": tl, "attn_tail_v": tl,
    }


def decode_step(cfg, params, cache, token, pos):
    x = jnp.take(params["emb"], token[:, None], axis=0)
    positions = pos + jnp.zeros((1,), jnp.int32)
    gp = _group_params(cfg, params)
    sp = params["shared"]
    k_per = cfg.attn_every

    def inner(carry, bp):
        x, conv_c, state_c, l = carry
        cache_l = {
            "conv": jax.lax.dynamic_index_in_dim(conv_c, l, 0, keepdims=False),
            "state": jax.lax.dynamic_index_in_dim(state_c, l, 0, keepdims=False),
        }
        h = L.apply_norm(cfg, x, bp["norm"])
        y, nc = ssm.ssm_block(cfg, bp["ssm"], h, cache=cache_l)
        x = x + y
        conv_c = jax.lax.dynamic_update_index_in_dim(
            conv_c, nc["conv"].astype(conv_c.dtype), l, 0)
        state_c = jax.lax.dynamic_update_index_in_dim(
            state_c, nc["state"].astype(state_c.dtype), l, 0)
        return (x, conv_c, state_c, l + 1), None

    def group(carry, xs):
        x, conv_c, state_c, tk, tv, gi, l = carry
        bp_g, ctx_k, ctx_v = xs
        (x, conv_c, state_c, l), _ = jax.lax.scan(
            inner, (x, conv_c, state_c, l), bp_g,
            unroll=cfg.scan_unroll)
        kv_g = (ctx_k, ctx_v,
                jax.lax.dynamic_index_in_dim(tk, gi, 0, keepdims=False),
                jax.lax.dynamic_index_in_dim(tv, gi, 0, keepdims=False))
        x, (nk, nv) = _shared_attn(cfg, sp, x, positions,
                                   kv_cache=kv_g, pos=pos)
        tk = jax.lax.dynamic_update_index_in_dim(tk, nk, gi, 0)
        tv = jax.lax.dynamic_update_index_in_dim(tv, nv, gi, 0)
        return (x, conv_c, state_c, tk, tv, gi + 1, l), None

    carry = (x, cache["ssm"]["conv"], cache["ssm"]["state"],
             cache["attn_tail_k"], cache["attn_tail_v"],
             jnp.int32(0), jnp.int32(0))
    (x, conv_c, state_c, tk, tv, _, _), _ = jax.lax.scan(
        group, carry, (gp, cache["attn_k"], cache["attn_v"]),
        unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = T.unembed(cfg, params, x)[:, 0, :]
    return logits, dict(cache,
                        ssm={"conv": conv_c, "state": state_c},
                        attn_tail_k=tk, attn_tail_v=tv)
