"""Decoder-only transformer LM (dense and MoE families).

Layers are scanned (stacked params, lax.scan) so the 94-layer MoE compiles
fast; each layer body is rematerialized per cfg.remat.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L
from repro.models.layers import ParamDef


def param_defs(cfg) -> dict:
    n = cfg.num_layers
    defs = {
        "emb": ParamDef((cfg.padded_vocab, cfg.d_model), ("vocab", "embed")),
        "final_norm": L.norm_defs(cfg, cfg.d_model),
        "blocks": {
            "attn_norm": L.norm_defs(cfg, cfg.d_model, prefix_shape=(n,)),
            "mlp_norm": L.norm_defs(cfg, cfg.d_model, prefix_shape=(n,)),
            "attn": L.attention_defs(cfg, stacked=n),
        },
    }
    if cfg.moe is not None:
        defs["blocks"]["moe"] = L.moe_defs(cfg, stacked=n)
    else:
        defs["blocks"]["mlp"] = L.mlp_defs(cfg, stacked=n)
    if not cfg.tie_embeddings:
        defs["unemb"] = ParamDef((cfg.d_model, cfg.padded_vocab),
                                 ("embed_fsdp", "vocab"))
    return defs


def _remat(cfg, fn):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots" else None)
    return jax.checkpoint(fn, policy=policy)


# capacity of the replicated decode tail buffer (newly generated tokens).
# The *context* cache stays read-only and seq-sharded: a dynamic-index
# update into a model-sharded seq dim makes GSPMD all-gather the cache
# every layer (measured 8.6 GB/device/layer — EXPERIMENTS.md §Perf).
DECODE_TAIL = 128


def decode_attention(cfg, bp_attn, q, k, v, ctx_k, ctx_v, tail_k, tail_v,
                     tail_pos):
    """Attend a single new token over [static context] + [tail buffer].

    ctx_*: (b, cap, hkv, hd) read-only, possibly seq-sharded;
    tail_*: (b, DECODE_TAIL, hkv, hd) replicated; the new (k, v) is first
    written at tail_pos (local update). Returns (o, tail_k, tail_v)."""
    tail_k = jax.lax.dynamic_update_slice(tail_k, k, (0, tail_pos, 0, 0))
    tail_v = jax.lax.dynamic_update_slice(tail_v, v, (0, tail_pos, 0, 0))
    p1 = L.flash_attention(q, ctx_k, ctx_v, causal=False,
                           kv_chunk=max(cfg.attn_chunk, 2048),
                           return_stats=True)
    p2 = L.flash_attention(q, tail_k, tail_v, causal=False,
                           kv_len=tail_pos + 1, kv_chunk=DECODE_TAIL,
                           return_stats=True)
    o = L.merge_attention([p1, p2])
    return o, tail_k, tail_v


def _block(cfg, bp, x, positions, *, causal=True, kv_cache=None, pos=None):
    """One transformer block. Returns (x, (k, v) | tail update, aux).

    kv_cache: optional (ctx_k, ctx_v, tail_k, tail_v) for decode; `pos`
    is the *global* position (tail_pos = pos - ctx capacity). When
    kv_cache is None the block runs self-attention over its own sequence
    (train/prefill)."""
    h = L.apply_norm(cfg, x, bp["attn_norm"])
    q, k, v = L.attention_qkv(cfg, bp["attn"], h, positions)
    if kv_cache is None:
        o = L.flash_attention(q, k, v, causal=causal,
                              kv_chunk=cfg.attn_chunk)
        new_kv = (k, v)
    else:
        ctx_k, ctx_v, tail_k, tail_v = kv_cache
        tail_pos = pos - ctx_k.shape[1]
        o, tail_k, tail_v = decode_attention(
            cfg, bp["attn"], q, k, v, ctx_k, ctx_v, tail_k, tail_v,
            tail_pos)
        new_kv = (tail_k, tail_v)
    y = constrain(L.attention_out(bp["attn"], o),
                  "batch", "block_seq", None)
    x = constrain(x + y, "batch", "block_seq", None)

    h = L.apply_norm(cfg, x, bp["mlp_norm"])
    if cfg.moe is not None:
        y, aux = L.moe_block(cfg, bp["moe"], h)
    else:
        y, aux = L.mlp_block(cfg, bp["mlp"], h), 0.0
    y = constrain(y, "batch", "block_seq", None)
    x = constrain(x + y, "batch", "block_seq", None)
    x = L.bf16_grad_barrier(x)
    return x, new_kv, aux


def forward(cfg, params, tokens, *, collect_kv: bool = False):
    """Full causal forward. tokens: (b, s) int32.

    Returns (x_final, kv_stack | None, aux_sum). x_final is post-final-norm.
    """
    x = jnp.take(params["emb"], tokens, axis=0)
    x = constrain(x, "batch", "block_seq", None)
    positions = jnp.arange(tokens.shape[1])

    def body(carry, bp):
        x, aux = carry
        x, kv, a = _block(cfg, bp, x, positions)
        ys = kv if collect_kv else None
        return (x, aux + a), ys

    body = _remat(cfg, body)
    (x, aux), kvs = jax.lax.scan(body, (x, 0.0), params["blocks"],
                                 unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["final_norm"])
    return x, kvs, aux


def unembed(cfg, params, x):
    w = params["emb"].T if cfg.tie_embeddings else params["unemb"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return constrain(logits, "batch", "seq", "act_vocab")


def softmax_xent(cfg, params, x, labels, mask, *, chunk: int = 0):
    """Chunked cross-entropy over the (sharded) vocab; O(chunk*V) memory."""
    chunk = chunk or cfg.loss_chunk
    b, s, d = x.shape
    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        mask = jnp.pad(mask, ((0, 0), (0, pad)))
    xs = jnp.moveaxis(x.reshape(b, nc, chunk, d), 1, 0)
    ls = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    ms = jnp.moveaxis(mask.reshape(b, nc, chunk), 1, 0)

    @jax.checkpoint
    def body(tot, args):
        xc, lc, mc = args
        logits = unembed(cfg, params, xc).astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        lab = jnp.take_along_axis(logits, lc[..., None].astype(jnp.int32),
                                  axis=-1)[..., 0]
        return tot + jnp.sum((lse - lab) * mc), None

    tot, _ = jax.lax.scan(body, jnp.float32(0.0), (xs, ls, ms))
    return tot


def loss_fn(cfg, params, batch):
    """batch: {"tokens": (b, s+1)} -> scalar mean xent (+ MoE aux)."""
    tokens = batch["tokens"]
    inp, labels = tokens[:, :-1], tokens[:, 1:]
    mask = (labels >= 0).astype(jnp.float32)
    labels = jnp.maximum(labels, 0)
    x, _, aux = forward(cfg, params, inp)
    tot = softmax_xent(cfg, params, x, labels, mask)
    loss = tot / jnp.maximum(mask.sum(), 1.0)
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_coef * aux / cfg.num_layers
    return loss


def prefill(cfg, params, tokens):
    """Returns (last-position logits (b, v), kv cache stack (L,b,s,hkv,hd) x2)."""
    x, kvs, _ = forward(cfg, params, tokens, collect_kv=True)
    logits = unembed(cfg, params, x[:, -1:, :])[:, 0, :]
    return logits, {"k": kvs[0], "v": kvs[1]}


def init_cache(cfg, batch: int, capacity: int, dtype=jnp.bfloat16):
    """capacity = context length (read-only, seq-shardable); newly decoded
    tokens live in the replicated DECODE_TAIL buffer."""
    shape = (cfg.num_layers, batch, capacity, cfg.num_kv_heads,
             cfg.resolved_head_dim)
    tail = (cfg.num_layers, batch, DECODE_TAIL, cfg.num_kv_heads,
            cfg.resolved_head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype),
            "tail_k": jnp.zeros(tail, dtype),
            "tail_v": jnp.zeros(tail, dtype)}


def cache_axes(cfg):
    ax = ("layers", "batch", "kv_seq", "act_kv", None)
    tl = ("layers", "batch", None, "act_kv", None)
    return {"k": ax, "v": ax, "tail_k": tl, "tail_v": tl}


def decode_step(cfg, params, cache, token, pos):
    """One decode step (serve_step). token: (b,) int32; pos: scalar int32
    global position (pos >= context capacity; the new token is written to
    the tail buffer). Returns (logits, cache)."""
    x = jnp.take(params["emb"], token[:, None], axis=0)      # (b, 1, d)
    positions = pos + jnp.zeros((1,), jnp.int32)

    def body(carry, xs):
        x, tail_k, tail_v, l = carry
        bp, ctx_k, ctx_v = xs
        tk_l = jax.lax.dynamic_index_in_dim(tail_k, l, 0, keepdims=False)
        tv_l = jax.lax.dynamic_index_in_dim(tail_v, l, 0, keepdims=False)
        x, (nk, nv), _ = _block(cfg, bp, x, positions,
                                kv_cache=(ctx_k, ctx_v, tk_l, tv_l),
                                pos=pos)
        tail_k = jax.lax.dynamic_update_index_in_dim(tail_k, nk, l, 0)
        tail_v = jax.lax.dynamic_update_index_in_dim(tail_v, nv, l, 0)
        return (x, tail_k, tail_v, l + 1), None

    body = _remat(cfg, body)
    (x, tk, tv, _), _ = jax.lax.scan(
        body, (x, cache["tail_k"], cache["tail_v"], jnp.int32(0)),
        (params["blocks"], cache["k"], cache["v"]),
        unroll=cfg.scan_unroll)
    x = L.apply_norm(cfg, x, params["final_norm"])
    logits = unembed(cfg, params, x)[:, 0, :]
    return logits, dict(cache, tail_k=tk, tail_v=tv)
