"""Unified model facade: one object per architecture family exposing

  init / abstract_params / param_pspecs
  loss(params, batch)            -- train_step target
  prefill(params, inputs)        -- inference-prefill target
  decode_step(params, cache, token, pos)  -- serve_step target
  input_specs(shape) / input_pspecs(shape, rules)

so the launcher, dry-run, trainer and serving engine are family-agnostic.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import layers as L
from repro.models import transformer, mamba_lm, hybrid, encdec

# The decode context cache is exactly seq_len (read-only, seq-shardable);
# newly generated tokens live in the replicated tail buffer
# (transformer.DECODE_TAIL). Historical note, kept for the §Perf log: an
# earlier +8 margin made capacity 32776, silently breaking kv_seq
# sharding (divisibility fallback -> 48 GiB/device replicated caches).


@dataclasses.dataclass
class Model:
    cfg: ModelConfig
    _mod: Any

    # ---- params ----
    def param_defs(self):
        return self._mod.param_defs(self.cfg)

    def abstract_params(self):
        return L.abstract_params(self.param_defs())

    def init(self, rng):
        return L.init_params(self.param_defs(), rng)

    def param_pspecs(self, rules):
        return L.pspec_tree(self.param_defs(), rules)

    def param_shardings(self, rules):
        return L.sharding_tree(self.param_defs(), rules)

    # ---- compute ----
    def loss(self, params, batch):
        return self._mod.loss_fn(self.cfg, params, batch)

    def prefill(self, params, inputs):
        if self.cfg.family == "encdec":
            return self._mod.prefill(self.cfg, params, inputs["frames"])
        return self._mod.prefill(self.cfg, params, inputs["tokens"])

    def decode_step(self, params, cache, token, pos):
        return self._mod.decode_step(self.cfg, params, cache, token, pos)

    def init_cache(self, batch: int, capacity: int):
        return self._mod.init_cache(self.cfg, batch, capacity)

    def cache_axes(self):
        return self._mod.cache_axes(self.cfg)

    # ---- abstract inputs for dry-run ----
    def input_specs(self, shape: ShapeConfig) -> dict:
        cfg = self.cfg
        b, s = shape.global_batch, shape.seq_len
        if cfg.family == "encdec":
            if shape.kind == "train":
                return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                       jnp.bfloat16),
                        "dec_tokens": jax.ShapeDtypeStruct(
                            (b, cfg.dec_len + 1), jnp.int32)}
            if shape.kind == "prefill":
                return {"frames": jax.ShapeDtypeStruct((b, s, cfg.d_model),
                                                       jnp.bfloat16)}
            # decode: cross-KV over s encoder states + self cache
            cache = jax.eval_shape(
                lambda: self._mod.init_cache(cfg, b, s))
            return {"cache": cache,
                    "token": jax.ShapeDtypeStruct((b,), jnp.int32),
                    "pos": jax.ShapeDtypeStruct((), jnp.int32)}
        if shape.kind == "train":
            return {"tokens": jax.ShapeDtypeStruct((b, s + 1), jnp.int32)}
        if shape.kind == "prefill":
            return {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        cache = jax.eval_shape(lambda: self._mod.init_cache(cfg, b, s))
        return {"cache": cache,
                "token": jax.ShapeDtypeStruct((b,), jnp.int32),
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    def input_pspecs(self, shape: ShapeConfig, rules) -> dict:
        from jax.sharding import PartitionSpec as P
        cfg = self.cfg
        specs = self.input_specs(shape)
        out: dict = {}
        for name, v in specs.items():
            if name == "tokens":
                out[name] = rules.spec(("batch", None), v.shape)
            elif name == "dec_tokens":
                out[name] = rules.spec(("batch", None), v.shape)
            elif name == "frames":
                out[name] = rules.spec(("batch", "block_seq", None), v.shape)
            elif name == "token":
                out[name] = rules.spec(("batch",), v.shape)
            elif name == "pos":
                out[name] = P()
            elif name == "cache":
                axes = self.cache_axes()
                out[name] = jax.tree.map(
                    lambda sds, ax: rules.spec(ax, sds.shape), v, axes,
                    is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))
            else:
                raise KeyError(name)
        return out

    def train_batch_shape(self, shape: ShapeConfig) -> dict:
        return self.input_specs(shape)


_FAMILY_MODULES = {
    "dense": transformer,
    "moe": transformer,
    "hybrid": hybrid,
    "ssm": mamba_lm,
    "encdec": encdec,
}


def build_model(cfg: ModelConfig) -> Model:
    return Model(cfg=cfg, _mod=_FAMILY_MODULES[cfg.family])
