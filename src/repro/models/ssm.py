"""Mamba2 / SSD (state-space duality) blocks [arXiv:2405.21060].

Chunked SSD algorithm in matmul form (MXU-friendly): intra-chunk outputs via
masked score matmuls, inter-chunk recurrence via a lax.scan over chunk
boundary states. This *is* the TPU-adapted algorithm (the paper's Triton
kernel maps onto the same chunked matmuls); a Pallas kernel for the
intra-chunk part lives in repro.kernels.ssd_scan.

Projections are kept as separate named weights (x, z, B, C, dt) rather than
one fused in_proj so each can carry its own sharding axes (ssm_pdim over the
model axis — head counts of published configs do not divide 16, P=64 does).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.layers import ParamDef


def ssm_dims(cfg):
    s = cfg.ssm
    d_inner = s.expand * cfg.d_model
    nheads = d_inner // s.head_dim
    return d_inner, nheads, s.head_dim, s.state_dim


def ssm_defs(cfg, *, stacked: int = 0) -> dict:
    d = cfg.d_model
    d_inner, h, p, n = ssm_dims(cfg)
    cw = cfg.ssm.conv_width
    pre = (stacked,) if stacked else ()
    pax = ("layers",) if stacked else ()
    return {
        "wx": ParamDef(pre + (d, h, p), pax + ("embed_fsdp", "ssm_heads", "ssm_pdim")),
        "wz": ParamDef(pre + (d, h, p), pax + ("embed_fsdp", "ssm_heads", "ssm_pdim")),
        "wB": ParamDef(pre + (d, n), pax + ("embed_fsdp", "state")),
        "wC": ParamDef(pre + (d, n), pax + ("embed_fsdp", "state")),
        "wdt": ParamDef(pre + (d, h), pax + ("embed_fsdp", "ssm_heads")),
        "dt_bias": ParamDef(pre + (h,), pax + ("ssm_heads",), init="zeros"),
        "A_log": ParamDef(pre + (h,), pax + ("ssm_heads",), init="zeros"),
        "D": ParamDef(pre + (h,), pax + ("ssm_heads",), init="ones"),
        # depthwise causal conv over x channels (h*p) and B, C (n each)
        "conv_x": ParamDef(pre + (cw, h, p), pax + ("conv", "ssm_heads", "ssm_pdim"),
                           scale=0.5),
        "conv_B": ParamDef(pre + (cw, n), pax + ("conv", "state"), scale=0.5),
        "conv_C": ParamDef(pre + (cw, n), pax + ("conv", "state"), scale=0.5),
        "norm": ParamDef(pre + (h, p), pax + ("ssm_heads", "ssm_pdim"),
                         init="zeros"),
        "wo": ParamDef(pre + (h, p, d), pax + ("ssm_heads", "ssm_pdim", "embed_fsdp"),
                       scale=0.02 / np.sqrt(2 * max(cfg.num_layers, 1))),
    }


def _causal_conv(x, w):
    """Depthwise causal conv. x: (b, s, c), w: (cw, c)."""
    cw = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (cw - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(cw):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * \
            w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def _segsum(dA):
    """Cumulative log-decay matrix: out[..., i, j] = sum_{j<k<=i} dA[..., k].

    dA: (..., cl); returns (..., cl, cl), -inf above diagonal.
    """
    cl = dA.shape[-1]
    cs = jnp.cumsum(dA, axis=-1)
    diff = cs[..., :, None] - cs[..., None, :]                # i row, j col
    ii = jnp.arange(cl)
    mask = ii[:, None] >= ii[None, :]
    return jnp.where(mask, diff, -jnp.inf)


def ssd_chunked(x, dt, A, B, C, *, chunk_len: int,
                init_state: Optional[jax.Array] = None,
                return_state: bool = False):
    """Chunked SSD scan.

    x:  (b, s, h, p)   inputs (already conv'd / activated)
    dt: (b, s, h)      positive step sizes
    A:  (h,)           negative decay rates
    B:  (b, s, n), C: (b, s, n)   (ngroups=1, shared across heads)
    Returns y: (b, s, h, p) (+ final state (b, h, p, n) if return_state).
    """
    b, s, h, p = x.shape
    n = B.shape[-1]
    cl = min(chunk_len, s)
    nc = -(-s // cl)
    pad = nc * cl - s
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        dt = jnp.pad(dt, ((0, 0), (0, pad), (0, 0)))
        B = jnp.pad(B, ((0, 0), (0, pad), (0, 0)))
        C = jnp.pad(C, ((0, 0), (0, pad), (0, 0)))

    xc = x.reshape(b, nc, cl, h, p)
    dtc = dt.reshape(b, nc, cl, h).astype(jnp.float32)
    Bc = B.reshape(b, nc, cl, n)
    Cc = C.reshape(b, nc, cl, n)

    cdt = x.dtype                                             # compute dtype
    dA = dtc * A.astype(jnp.float32)                          # (b,nc,cl,h) <= 0
    dA_cum = jnp.cumsum(dA, axis=2)                           # within chunk

    # ---- intra-chunk (quadratic within cl, matmul form) ----
    scores = jnp.einsum("bzin,bzjn->bzij", Cc, Bc,
                        preferred_element_type=jnp.float32)   # (b,nc,cl,cl)
    Ldec = _segsum(jnp.moveaxis(dA, 3, 2))                    # (b,nc,h,cl,cl)
    # form the masked-decay score matrix directly in compute dtype: the
    # (b,nc,h,cl,cl) buffers dominate SSD memory (measured 166 GiB/dev on
    # zamba2 train_4k in fp32 at cl=256 — see EXPERIMENTS.md §Perf).
    M = scores.astype(cdt)[:, :, None] * jnp.exp(Ldec).astype(cdt)
    M = M * dtc.astype(cdt).transpose(0, 1, 3, 2)[:, :, :, None, :]
    y_intra = jnp.einsum("bzhij,bzjhp->bzihp", M, xc,
                         preferred_element_type=jnp.float32)

    # ---- chunk boundary states ----
    # state contribution of chunk z: sum_j exp(dA_cum[last]-dA_cum[j]) dt_j B_j x_j
    decay_to_end = jnp.exp(dA_cum[:, :, -1:, :] - dA_cum)     # (b,nc,cl,h)
    xw = xc * (dtc * decay_to_end).astype(cdt)[..., None]     # (b,nc,cl,h,p)
    S = jnp.einsum("bzjn,bzjhp->bzhpn", Bc, xw,
                   preferred_element_type=jnp.float32)        # (b,nc,h,p,n)

    # ---- inter-chunk recurrence over nc ----
    chunk_decay = jnp.exp(dA_cum[:, :, -1, :])                # (b,nc,h)
    if init_state is None:
        init_state = jnp.zeros((b, h, p, n), jnp.float32)

    def body(state, inp):
        S_z, dec_z = inp                                      # (b,h,p,n),(b,h)
        out = state
        state = state * dec_z[:, :, None, None] + S_z
        return state, out

    Ss = jnp.moveaxis(S, 1, 0)
    decs = jnp.moveaxis(chunk_decay, 1, 0)
    final_state, states_before = jax.lax.scan(
        body, init_state.astype(jnp.float32), (Ss, decs))
    states_before = jnp.moveaxis(states_before, 0, 1)         # (b,nc,h,p,n)

    # ---- inter-chunk outputs ----
    # decay factors out of the n-contraction:
    #   y[i,h,p] = exp(dA_cum[i,h]) * sum_n C[i,n] state[h,p,n]
    y_inter = jnp.einsum("bzin,bzhpn->bzihp", Cc,
                         states_before.astype(cdt),
                         preferred_element_type=jnp.float32)
    y_inter = y_inter * jnp.exp(dA_cum)[:, :, :, :, None]

    y = (y_intra + y_inter).reshape(b, nc * cl, h, p)[:, :s]
    y = y.astype(x.dtype)
    if return_state:
        return y, final_state
    return y


def ssm_block(cfg, p, x, *, cache=None, return_state=False):
    """Full Mamba2 block: proj -> conv -> SSD -> gated norm -> out proj.

    x: (b, s, d). cache (decode): {"conv": (b, cw-1, ch), "state": (b,h,p,n)}.
    Returns (y, new_cache_or_None).
    """
    s_cfg = cfg.ssm
    d_inner, h, pd, n = ssm_dims(cfg)
    b, s, _ = x.shape

    xi = jnp.einsum("bsd,dhp->bshp", x, p["wx"])
    z = jnp.einsum("bsd,dhp->bshp", x, p["wz"])
    Bv = jnp.einsum("bsd,dn->bsn", x, p["wB"])
    Cv = jnp.einsum("bsd,dn->bsn", x, p["wC"])
    dt = jnp.einsum("bsd,dh->bsh", x, p["wdt"]) + p["dt_bias"]
    dt = jax.nn.softplus(dt.astype(jnp.float32))
    A = -jnp.exp(p["A_log"].astype(jnp.float32))

    xi = constrain(xi, "batch", "seq", None, "ssm_pdim")

    xflat = xi.reshape(b, s, h * pd)
    convw = p["conv_x"].reshape(s_cfg.conv_width, h * pd)
    new_cache = None
    if cache is None:
        xconv = _causal_conv(xflat, convw)
        Bconv = _causal_conv(Bv, p["conv_B"])
        Cconv = _causal_conv(Cv, p["conv_C"])
        if return_state:
            cw = s_cfg.conv_width
            tail = jnp.concatenate(
                [xflat, Bv, Cv], axis=-1)[:, -(cw - 1):, :]
            if s < cw - 1:
                tail = jnp.pad(tail, ((0, 0), (cw - 1 - s, 0), (0, 0)))
            new_cache = {"conv": tail}
    else:
        # decode: s == 1; shift conv window
        cat = jnp.concatenate([xflat, Bv, Cv], axis=-1)       # (b,1,ch)
        win = jnp.concatenate([cache["conv"], cat], axis=1)   # (b,cw,ch)
        allw = jnp.concatenate(
            [convw, p["conv_B"], p["conv_C"]], axis=-1)       # (cw,ch)
        conv_out = jnp.sum(win.astype(jnp.float32) *
                           allw.astype(jnp.float32)[None], axis=1,
                           keepdims=True).astype(x.dtype)     # (b,1,ch)
        xconv = conv_out[..., :h * pd]
        Bconv = conv_out[..., h * pd:h * pd + n]
        Cconv = conv_out[..., h * pd + n:]
        new_cache = {"conv": win[:, 1:, :]}

    xact = jax.nn.silu(xconv.astype(jnp.float32)).astype(x.dtype)
    xact = xact.reshape(b, s, h, pd)
    Bact = jax.nn.silu(Bconv.astype(jnp.float32)).astype(x.dtype)
    Cact = jax.nn.silu(Cconv.astype(jnp.float32)).astype(x.dtype)

    if cache is None:
        out = ssd_chunked(xact, dt, A, Bact, Cact,
                          chunk_len=s_cfg.chunk_len,
                          return_state=return_state)
        y, final_state = out if return_state else (out, None)
        if return_state:
            new_cache["state"] = final_state
    else:
        # single-step recurrence
        dA = jnp.exp(dt[:, 0, :] * A)                          # (b,h)
        dBx = jnp.einsum("bn,bhp->bhpn", (Bact[:, 0] * 1.0),
                         xact[:, 0] * dt[:, 0, :, None].astype(x.dtype))
        state = cache["state"] * dA[:, :, None, None] + dBx
        y = jnp.einsum("bhpn,bn->bhp", state.astype(x.dtype), Cact[:, 0])
        y = y[:, None]                                         # (b,1,h,p)
        new_cache["state"] = state

    y = y + xact * p["D"].astype(x.dtype)[None, None, :, None]
    # gated RMSNorm (mamba2 style): norm(y * silu(z))
    g = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(g * g, axis=-1, keepdims=True)
    g = g * jax.lax.rsqrt(var + 1e-6)
    g = (g * (1.0 + p["norm"].astype(jnp.float32))).astype(x.dtype)
    out = jnp.einsum("bshp,hpd->bsd", g, p["wo"])
    return out, new_cache


def conv_cache_channels(cfg) -> int:
    d_inner, h, pd, n = ssm_dims(cfg)
    return h * pd + 2 * n
