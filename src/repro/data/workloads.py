"""Context-reuse workload synthesis.

Produces per-chunk statistics with the heterogeneity the paper measures:
  - attention sparsity per (t, l, h): heads draw a *pattern type*
    (diagonal / block-local / global / mixed — Fig. 2), giving active-block
    counts with a 15-20x spread (Fig. 3);
  - KV value entropy per (l, h): 0-4 bits/value spread -> compressed chunk
    sizes varying by several x (Fig. 4/5).

Dataset profiles mirror the paper's evaluation set (Table III): mean
context length and modality mix shift the sparsity/entropy distributions
(video workloads are denser + higher-entropy, code is more repetitive).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class DatasetProfile:
    name: str
    mean_len: int                 # tokens
    quality_metric: str
    sparsity_scale: float = 1.0   # multiplies active-block fraction
    entropy_shift: float = 0.0    # shifts per-head entropy (bits)
    seed: int = 0


DATASETS: dict[str, DatasetProfile] = {
    "repobench-p": DatasetProfile("repobench-p", 10_000, "edit_sim",
                                  sparsity_scale=0.8, entropy_shift=-0.5,
                                  seed=1),
    "hotpotqa": DatasetProfile("hotpotqa", 11_000, "f1", seed=2),
    "triviaqa": DatasetProfile("triviaqa", 11_000, "f1", seed=3),
    "longchat": DatasetProfile("longchat", 12_000, "accuracy", seed=4),
    "govreport": DatasetProfile("govreport", 13_000, "rouge_l",
                                sparsity_scale=1.1, seed=5),
    "narrativeqa": DatasetProfile("narrativeqa", 18_000, "f1", seed=6),
    "academic": DatasetProfile("academic", 28_000, "accuracy",
                               sparsity_scale=1.05, seed=7),
    "financial": DatasetProfile("financial", 49_000, "accuracy",
                                sparsity_scale=0.9, seed=8),
    "videomme": DatasetProfile("videomme", 23_000, "accuracy",
                               sparsity_scale=1.35, entropy_shift=0.6,
                               seed=9),
}

_PATTERNS = ("diagonal", "block", "global", "mixed")
_PATTERN_FRACS = {
    # base fraction of causal-valid kv blocks that are active per q row
    # (calibrated so sparse attention gives the paper's ~2.5x over full)
    "diagonal": 0.10, "block": 0.25, "global": 0.62, "mixed": 0.38,
}


@dataclasses.dataclass
class WorkloadChunks:
    """Per-chunk statistics for one request context."""
    n_t: int
    n_l: int
    n_h: int
    active_blocks: np.ndarray     # (n_t, n_l, n_h) float — per 1024-chunk
    entropy_bits: np.ndarray      # (n_l, n_h) float bits/value
    chunk_bytes: np.ndarray       # (n_t, n_l, n_h) float compressed size
    head_pattern: np.ndarray      # (n_l, n_h) int index into _PATTERNS
    context_len: int
    chunk_tokens: int

    def total_bytes(self) -> float:
        return float(self.chunk_bytes.sum())


def synthesize(cfg, context_len: int, dataset: DatasetProfile,
               *, chunk_tokens: int = 1024, kv_block: int = 128,
               quant_bits: int = 5, rng=None) -> WorkloadChunks:
    """Generate chunk stats for a context of `context_len` tokens."""
    rng = rng or np.random.default_rng(dataset.seed * 7919 + context_len)
    n_t = max(1, context_len // chunk_tokens)
    n_l = cfg.num_layers
    n_h = max(cfg.num_kv_heads, 1)
    hd = cfg.resolved_head_dim if cfg.num_heads else 64

    # head pattern assignment: shallow layers lean local, deep lean global
    pat = np.empty((n_l, n_h), np.int64)
    for l in range(n_l):
        depth = l / max(n_l - 1, 1)
        probs = np.array([
            0.45 - 0.25 * depth,          # diagonal
            0.30,                         # block
            0.05 + 0.30 * depth,          # global
            0.20 - 0.05 * depth,
        ])
        probs /= probs.sum()
        pat[l] = rng.choice(4, size=n_h, p=probs)

    # per-head multiplicative jitter, stable across t (head identity)
    head_jitter = np.exp(rng.normal(0, 0.35, size=(n_l, n_h)))

    # active blocks per chunk: fraction of causal-valid kv blocks
    blocks_per_chunk_row = chunk_tokens // 128   # q rows of 128
    active = np.zeros((n_t, n_l, n_h))
    for t in range(n_t):
        valid_kv_blocks = ((t + 1) * chunk_tokens) // kv_block
        for p_idx, p_name in enumerate(_PATTERNS):
            mask = pat == p_idx
            if not mask.any():
                continue
            frac = _PATTERN_FRACS[p_name] * dataset.sparsity_scale
            base = frac * valid_kv_blocks * blocks_per_chunk_row
            local_floor = blocks_per_chunk_row * min(
                8, valid_kv_blocks)     # always-kept local/sink blocks
            vals = base * head_jitter[mask] * np.exp(
                rng.normal(0, 0.10, mask.sum()))
            active[t][mask] = np.maximum(vals, local_floor)
    # cap at fully-dense
    for t in range(n_t):
        dense = ((t + 1) * chunk_tokens // kv_block) * blocks_per_chunk_row
        active[t] = np.minimum(active[t], dense)

    # entropy per (l, h): bimodal-ish 0-4 bits (Fig. 4), video shifted up
    base_e = np.clip(rng.normal(2.2 + dataset.entropy_shift, 0.9,
                                size=(n_l, n_h)), 0.05, quant_bits - 0.2)
    flat = rng.random((n_l, n_h)) < 0.12      # near-constant heads
    entropy = np.where(flat, rng.uniform(0.02, 0.3, (n_l, n_h)), base_e)

    # compressed bytes per chunk: tokens * hd * 2 (K and V) * e/8 + header
    values = chunk_tokens * hd * 2
    chunk_bytes = np.broadcast_to(
        values * entropy / 8.0, (n_t, n_l, n_h)).copy()
    chunk_bytes *= np.exp(rng.normal(0, 0.05, chunk_bytes.shape))
    chunk_bytes += 2 * 2 * (values // 64) + 64      # group scales + header

    return WorkloadChunks(n_t=n_t, n_l=n_l, n_h=n_h,
                          active_blocks=active, entropy_bits=entropy,
                          chunk_bytes=chunk_bytes, head_pattern=pat,
                          context_len=n_t * chunk_tokens,
                          chunk_tokens=chunk_tokens)


def sample_profiling_features(rng: np.random.Generator, n: int,
                              *, max_t: int = 40, chunk_tokens: int = 1024,
                              kv_block: int = 128):
    """(t, active_blocks) pairs drawn from the same generative family as
    synthesize() — the latency predictor's offline profiling distribution
    must match deployment workloads (paper §IV-C trains on real profiling
    runs)."""
    t = rng.integers(0, max_t, n).astype(np.float64)
    rows = chunk_tokens // 128
    fracs = np.array(list(_PATTERN_FRACS.values()))
    pick = fracs[rng.integers(0, len(fracs), n)]
    jitter = np.exp(rng.normal(0, 0.37, n))
    valid = (t + 1) * chunk_tokens / kv_block
    s = np.minimum(pick * jitter * valid * rows, valid * rows)
    floor = rows * np.minimum(8, valid)
    s = np.maximum(s, floor)
    return t, s


def lm_token_batch(rng: np.random.Generator, vocab: int, batch: int,
                   seq: int, *, motif_len: int = 64,
                   n_motifs: int = 32,
                   motif_seed: Optional[int] = None) -> np.ndarray:
    """Synthetic LM training data with repeated motifs (compressible,
    non-trivial loss curve).

    ``motif_seed`` pins the motif bank independently of ``rng``: a training
    loop that draws a fresh ``rng`` per step must pass it, otherwise every
    step sees brand-new motifs and the only learnable structure is the
    (uniform) unigram distribution — loss then never improves.
    """
    motif_rng = (np.random.default_rng(motif_seed)
                 if motif_seed is not None else rng)
    motifs = motif_rng.integers(0, vocab, size=(n_motifs, motif_len))
    out = np.empty((batch, seq), np.int64)
    for b in range(batch):
        pos = 0
        while pos < seq:
            if rng.random() < 0.7:
                m = motifs[rng.integers(n_motifs)]
                take = min(motif_len, seq - pos)
                out[b, pos:pos + take] = m[:take]
                pos += take
            else:
                take = min(int(rng.integers(8, 32)), seq - pos)
                out[b, pos:pos + take] = rng.integers(0, vocab, take)
                pos += take
    return out
