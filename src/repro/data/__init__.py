"""Workload synthesis and dataset profiles (paper Table III mixes)."""
