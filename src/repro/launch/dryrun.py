import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the real step function (train_step with AdamW
update / prefill / decode serve_step), lowers it with in/out shardings on
the production mesh, compiles, and records memory_analysis, cost_analysis,
and the parsed collective schedule into a JSON file for the roofline
analysis (EXPERIMENTS.md reads these).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all \
      --mesh both --out results/dryrun [--skip-existing]
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import (ASSIGNED_ARCHS, SHAPES, TrainConfig, get_config,
                           shape_applicable)
from repro.distributed.roofline import parse_collectives, roofline_terms
from repro.distributed.sharding import make_rules, use_rules
from repro.launch.mesh import make_production_mesh
from repro.models import build_model
from repro.training.optimizer import AdamW


# per-arch gradient-accumulation factors for train_4k (see EXPERIMENTS.md
# §Perf iteration 7): divides activation residuals + MoE dispatch buffers
MICROBATCHES = {
    "qwen3-moe-235b-a22b": 16,
    "chameleon-34b": 8,
    "granite-moe-3b-a800m": 4,
    "phi3-medium-14b": 4,
    "gemma-2b": 2,
}


def _prefill_out_axes(model):
    fam = model.cfg.family
    logits = ("batch", "act_vocab")
    ca = model.cache_axes()
    if fam in ("dense", "moe"):
        return (logits, {"k": ca["k"], "v": ca["v"]})
    if fam == "ssm":
        return (logits, ca)
    if fam == "hybrid":
        return (logits, {"ssm": ca["ssm"], "attn_k": ca["attn_k"],
                         "attn_v": ca["attn_v"]})
    if fam == "encdec":
        return {"cross_k": ca["cross_k"], "cross_v": ca["cross_v"]}
    raise ValueError(fam)


def _spec_of_axes(rules, axes_tree, shape_tree):
    return jax.tree.map(
        lambda ax, sds: rules.spec(ax, sds.shape),
        axes_tree, shape_tree,
        is_leaf=lambda x: (isinstance(x, tuple)
                           and all(isinstance(e, (str, type(None)))
                                   for e in x)))


def build_cell(arch: str, shape_name: str, mesh, *, sp_activations=None,
               attn_kv_chunk=None):
    """Returns (fn, example_args, in_shardings, out_shardings, donate)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    model = build_model(cfg)
    if sp_activations is None:
        # Megatron-SP inter-block activations by default for training:
        # layer-boundary remat residuals are L x (b,s,d) per device and do
        # not fit HBM replicated over the model axis (§Perf iteration 1).
        sp_activations = shape.kind == "train"
    # decode: weight-stationary layout — per-step FSDP weight gathers
    # dominate serve_step collectives otherwise (§Perf iteration 5)
    rules = make_rules(cfg, mesh, sp_activations=sp_activations,
                       weight_stationary=shape.kind == "decode")

    param_specs = model.param_pspecs(rules)
    abstract_params = model.abstract_params()
    inputs = model.input_specs(shape)
    input_specs = model.input_pspecs(shape, rules)
    ns = lambda tree: jax.tree.map(lambda s: NamedSharding(mesh, s), tree)

    if shape.kind == "train":
        # gradient accumulation for the cells whose remat residuals +
        # MoE buffers exceed 16 GiB/chip at global batch 256 (semantics
        # preserved — equivalence tested in test_training)
        micro = MICROBATCHES.get(arch, 1)
        # each microbatch must still shard over the data axes
        n_data = 1
        for a in ("pod", "data"):
            if a in mesh.axis_names:
                n_data *= mesh.shape[a]
        while micro > 1 and (shape.global_batch // micro) % n_data:
            micro //= 2
        tcfg = TrainConfig(microbatches=micro)
        opt = AdamW(tcfg, cfg.moment_dtype)
        abs_opt = opt.abstract_state(abstract_params)
        opt_specs = opt.state_pspecs(param_specs)

        from repro.training.trainer import build_train_step
        train_step, opt = build_train_step(model, tcfg, rules)

        args = (abstract_params, abs_opt, inputs)
        in_sh = (ns(param_specs), ns(opt_specs), ns(input_specs))
        metric_specs = {"grad_norm": P(), "lr": P(), "loss": P()}
        out_sh = (ns(param_specs), ns(opt_specs), ns(metric_specs))
        return train_step, args, in_sh, out_sh, (0, 1)

    if shape.kind == "prefill":
        def prefill_step(params, inputs):
            with use_rules(rules):
                return model.prefill(params, inputs)

        args = (abstract_params, inputs)
        in_sh = (ns(param_specs), ns(input_specs))
        out_shapes = jax.eval_shape(prefill_step, *args)
        out_axes = _prefill_out_axes(model)
        out_sh = ns(_spec_of_axes(rules, out_axes, out_shapes))
        return prefill_step, args, in_sh, out_sh, ()

    # decode / serve_step
    def serve_step(params, cache, token, pos):
        with use_rules(rules):
            return model.decode_step(params, cache, token, pos)

    args = (abstract_params, inputs["cache"], inputs["token"], inputs["pos"])
    in_sh = (ns(param_specs), ns(input_specs["cache"]),
             ns(input_specs["token"]), NamedSharding(mesh, P()))
    logits_spec = rules.spec(("batch", "act_vocab"),
                             (shape.global_batch, cfg.padded_vocab))
    out_sh = (NamedSharding(mesh, logits_spec), ns(input_specs["cache"]))
    return serve_step, args, in_sh, out_sh, (1,)


def run_cell(arch: str, shape_name: str, multi_pod: bool, out_dir: str,
             *, skip_existing=False, verbose=True, sp_activations=None):
    mesh_tag = "pod2x16x16" if multi_pod else "pod16x16"
    os.makedirs(os.path.join(out_dir, mesh_tag), exist_ok=True)
    path = os.path.join(out_dir, mesh_tag, f"{arch}__{shape_name}.json")
    if skip_existing and os.path.exists(path):
        with open(path) as f:
            return json.load(f)

    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    rec: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_tag,
                 "kind": shape.kind}
    if not ok:
        rec.update(status="SKIP", reason=why)
        with open(path, "w") as f:
            json.dump(rec, f, indent=2)
        return rec

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    try:
        t0 = time.time()
        fn, args, in_sh, out_sh, donate = build_cell(
            arch, shape_name, mesh, sp_activations=sp_activations)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        lowered = jitted.lower(*args)
        t_lower = time.time() - t0
        t0 = time.time()
        compiled = lowered.compile()
        t_compile = time.time() - t0

        ma = compiled.memory_analysis()
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):    # jax < 0.5 returns [dict]
            ca = ca[0] if ca else {}
        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        flops = float(ca.get("flops", 0.0))
        bytes_acc = float(ca.get("bytes accessed", 0.0))
        rl = roofline_terms(flops, bytes_acc, coll)

        n_par = cfg.param_count()
        n_act = cfg.active_param_count()
        if shape.kind == "train":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 6 * n_act * tokens
        elif shape.kind == "prefill":
            tokens = shape.global_batch * shape.seq_len
            model_flops = 2 * n_act * tokens
        else:
            model_flops = 2 * n_act * shape.global_batch

        rec.update(
            status="OK",
            chips=chips,
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            memory={
                "argument_bytes": ma.argument_size_in_bytes,
                "output_bytes": ma.output_size_in_bytes,
                "temp_bytes": ma.temp_size_in_bytes,
                "generated_code_bytes": ma.generated_code_size_in_bytes,
                "peak_est_bytes": (ma.argument_size_in_bytes
                                   + ma.output_size_in_bytes
                                   + ma.temp_size_in_bytes),
            },
            cost={"flops_per_dev": flops, "bytes_per_dev": bytes_acc},
            collectives=coll.as_dict(),
            roofline=rl,
            model_flops=model_flops,
            useful_flops_ratio=(model_flops / (flops * chips)
                                if flops else 0.0),
            params=n_par,
            active_params=n_act,
        )
        if verbose:
            print(f"[{mesh_tag}] {arch} x {shape_name}: compile "
                  f"{t_compile:.1f}s")
            print("  memory_analysis:", rec["memory"])
            print("  cost_analysis: flops/dev=%.3e bytes/dev=%.3e"
                  % (flops, bytes_acc))
            print("  collectives:", json.dumps(coll.as_dict()["by_kind"]))
            print("  roofline:", {k: (round(v, 6) if isinstance(v, float)
                                      else v) for k, v in rl.items()})
    except Exception as e:  # noqa: BLE001 - record failures as results
        rec.update(status="FAIL", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[{mesh_tag}] {arch} x {shape_name}: FAIL {e}")

    with open(path, "w") as f:
        json.dump(rec, f, indent=2)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="both",
                    choices=["single", "multi", "both"])
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--sp-activations", default="auto",
                    choices=["auto", "on", "off"],
                    help="Megatron-SP inter-block activations "
                         "(auto = on for train shapes)")
    args = ap.parse_args()
    sp = None if args.sp_activations == "auto" else args.sp_activations == "on"

    archs = ASSIGNED_ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for multi in meshes:
        for arch in archs:
            for shape in shapes:
                rec = run_cell(arch, shape, multi, args.out,
                               skip_existing=args.skip_existing,
                               sp_activations=sp)
                st = rec["status"]
                n_ok += st == "OK"
                n_fail += st == "FAIL"
                n_skip += st == "SKIP"
    print(f"\ndry-run complete: {n_ok} OK, {n_skip} SKIP, {n_fail} FAIL")
    if n_fail:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
