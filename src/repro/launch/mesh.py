"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state. The single-pod mesh is 16x16 = 256 chips
(data, model); the multi-pod mesh adds a leading pod axis: 2x16x16 = 512.
"""
from __future__ import annotations

import jax

try:                                        # jax >= 0.5 only
    from jax.sharding import AxisType

    def _axis_kw(n: int) -> dict:
        return {"axis_types": (AxisType.Auto,) * n}
except ImportError:                         # older jax: Auto is implicit

    def _axis_kw(n: int) -> dict:
        return {}


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))


def make_local_mesh(shape=None, axes=("data", "model")):
    """Mesh over whatever devices exist (tests / single host)."""
    n = len(jax.devices())
    if shape is None:
        shape = (1, n)
    return jax.make_mesh(shape, axes, **_axis_kw(len(axes)))
