"""Entry points: train / serve / calibrate / dry-run mesh tools."""
