"""Serving launcher: spin up a SparKVServer on a reduced config, register
reusable contexts, and serve batches of requests under each loading
policy, reporting TTFT / energy / response-fidelity.

  PYTHONPATH=src python -m repro.launch.serve --arch sparkv-qwen3-4b \
      --requests 4 --context-chunks 6 --policies sparkv,local_prefill
"""
from __future__ import annotations

import argparse


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="sparkv-qwen3-4b")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--context-chunks", type=int, default=6)
    ap.add_argument("--chunk-tokens", type=int, default=64)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--policies",
                    default="sparkv,strong_hybrid,cachegen,local_prefill")
    ap.add_argument("--profile", default="jetson-orin")
    ap.add_argument("--network", default="campus-wifi")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    import numpy as np
    import jax
    from repro.configs import SparKVConfig, get_smoke
    from repro.models import build_model
    from repro.serving.engine import SparKVServer

    cfg = get_smoke(args.arch, layers=4, d_model=64, heads=4, d_ff=128,
                    vocab=512)
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))
    spcfg = SparKVConfig(chunk_tokens=args.chunk_tokens,
                         q_block=min(32, args.chunk_tokens),
                         kv_block=min(32, args.chunk_tokens),
                         quant_group=32)
    srv = SparKVServer(model, params, spcfg, profile=args.profile,
                       network=args.network,
                       chunk_tokens=args.chunk_tokens, seed=args.seed)

    rng = np.random.default_rng(args.seed)
    ctx = rng.integers(0, cfg.vocab_size,
                       size=(1, args.context_chunks * args.chunk_tokens))
    cid = srv.register_context(ctx)
    print(f"registered context {cid}: {ctx.shape[1]} tokens, "
          f"{srv.contexts[cid].n_chunks} chunks, "
          f"{srv.contexts[cid].wl.total_bytes() / 1e6:.2f} MB compressed")

    for policy in args.policies.split(","):
        ttfts, agrees, kls, energies = [], [], [], []
        for r in range(args.requests):
            prompt = rng.integers(0, cfg.vocab_size, size=4)
            res = srv.generate(cid, prompt, max_new=args.max_new,
                               policy=policy, seed=args.seed + r)
            ttfts.append(res.ttft_s)
            agrees.append(res.top1_agreement)
            kls.append(res.mean_kl)
            energies.append(res.energy_j)
        print(f"{policy:14s} TTFT={np.mean(ttfts):7.3f}s  "
              f"energy={np.mean(energies):8.1f}J  "
              f"top1-fidelity={np.mean(agrees):.3f}  "
              f"KL={np.mean(kls):.4f}")


if __name__ == "__main__":
    main()
