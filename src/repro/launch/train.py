"""Training launcher with supervision: run a model config on the current
devices (or the production mesh in dry-run mode), checkpoint periodically,
and on (injected or real) failure restart from the last commit.

Examples:
  PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b --smoke \
      --steps 50 --batch 8 --seq 128
  PYTHONPATH=src python -m repro.launch.train --arch mamba2-130m --smoke \
      --steps 30 --inject-fault 12
"""
from __future__ import annotations

import argparse
import sys


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced same-family config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--inject-fault", type=int, default=None,
                    help="simulate a node failure at this step")
    ap.add_argument("--max-restarts", type=int, default=3)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    from repro.configs import TrainConfig, get_config, get_smoke
    from repro.models import build_model
    from repro.checkpoint.manager import CheckpointManager
    from repro.training.trainer import FaultInjector, train_loop

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    tcfg = TrainConfig(learning_rate=args.lr, total_steps=args.steps,
                       warmup_steps=max(args.steps // 10, 1),
                       microbatches=args.microbatches, seed=args.seed,
                       checkpoint_every=args.ckpt_every,
                       checkpoint_dir=args.ckpt_dir)
    cm = CheckpointManager(args.ckpt_dir)
    fault = FaultInjector((args.inject_fault,)) \
        if args.inject_fault is not None else None

    restarts = 0
    while True:
        try:
            out = train_loop(model, tcfg, batch=args.batch, seq=args.seq,
                             steps=args.steps, ckpt_manager=cm, fault=fault,
                             log_every=max(args.steps // 20, 1))
            break
        except RuntimeError as e:
            restarts += 1
            print(f"[supervisor] failure: {e} — restart {restarts}")
            if restarts > args.max_restarts:
                print("[supervisor] giving up")
                sys.exit(1)

    print(f"\ntrained {args.steps} steps in {out['wall_s']:.1f}s "
          f"({restarts} restarts)")
    for step, loss in out["history"]:
        print(f"  step {step:5d}  loss {loss:.4f}")
    first = out["history"][0][1]
    last = out["final_loss"]
    print(f"loss {first:.4f} -> {last:.4f} "
          f"({'improved' if last < first else 'NOT improved'})")
    return out


if __name__ == "__main__":
    main()
