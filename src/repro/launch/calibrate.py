import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=512")

"""Cost calibration for the roofline table.

XLA's cost_analysis() counts a lax.scan body ONCE (verified: a 10-trip
scanned matmul reports 1x its flops), so the production compile — which
scans over layers, kv chunks, and loss chunks — under-reports flops,
bytes, and collective traffic by large, shape-dependent factors.

Method: compile the SAME cell at two reduced depths (L=a and L=b) with
every inner scan disabled (attn_chunk/loss_chunk = full sequence: the
flash/xent scans collapse to a single block; the SSD boundary-state scan
carries only negligible flops), then extrapolate linearly in depth:

    per_layer = (cost(b) - cost(a)) / (b - a)
    total     = cost(a) + per_layer * (L_full - a)

Depth units per family: layers (dense/moe/ssm), groups of
(attn_every mamba + 1 shared attn) for hybrid, (enc+dec) layer pairs for
encdec. Collectives are extrapolated the same way. memory_analysis still
comes from the full-depth production compile (launch.dryrun).
"""
import argparse
import dataclasses
import json

import jax

from repro.configs import SHAPES, get_config, shape_applicable
from repro.distributed.roofline import parse_collectives
from repro.launch.dryrun import build_cell
from repro.launch.mesh import make_production_mesh


def _depth_points(cfg):
    if cfg.family == "hybrid":
        return cfg.attn_every, 2 * cfg.attn_every, \
            cfg.num_layers // cfg.attn_every, cfg.attn_every
    return 2, 4, cfg.num_layers, 1


def _reduced(cfg, n_layers: int, seq_len: int):
    kw = dict(num_layers=n_layers, attn_chunk=max(seq_len, 2048),
              loss_chunk=max(seq_len, 2048), remat="none",
              scan_unroll=max(n_layers, 8))
    if cfg.family == "encdec":
        kw["dec_layers"] = n_layers
    return dataclasses.replace(cfg, **kw)


def _measure(arch_cfg, shape_name: str, mesh, sp_activations):
    """Compile one reduced cell, return (flops, bytes, coll_wire, coll_by_kind)."""
    import repro.launch.dryrun as dr
    import repro.configs as C

    # temporarily register the reduced config under the arch name
    name = arch_cfg.name
    orig = C.get_config

    def patched(n):
        if n == name:
            return arch_cfg
        return orig(n)

    C.get_config = patched
    dr.get_config = patched
    saved_micro = dict(dr.MICROBATCHES)
    dr.MICROBATCHES.clear()   # accumulation scans would re-hide flops
    try:
        fn, args, in_sh, out_sh, donate = build_cell(
            name, shape_name, mesh, sp_activations=sp_activations)
        jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=donate)
        compiled = jitted.lower(*args).compile()
        ca = compiled.cost_analysis() or {}
        coll = parse_collectives(compiled.as_text())
        return (float(ca.get("flops", 0.0)),
                float(ca.get("bytes accessed", 0.0)),
                coll.wire_bytes, dict(coll.by_kind))
    finally:
        C.get_config = orig
        dr.get_config = orig
        dr.MICROBATCHES.update(saved_micro)


def calibrate_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
                   sp_activations=None) -> dict:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"status": "SKIP", "reason": why}
    if sp_activations is None:
        sp_activations = shape.kind == "train"
    mesh = make_production_mesh(multi_pod=multi_pod)
    a, b, full_units, per_unit_layers = _depth_points(cfg)

    fa = _measure(_reduced(cfg, a, shape.seq_len), shape_name, mesh,
                  sp_activations)
    fb = _measure(_reduced(cfg, b, shape.seq_len), shape_name, mesh,
                  sp_activations)

    ua, ub = a // per_unit_layers, b // per_unit_layers
    out = {}
    for i, key in enumerate(("flops", "bytes", "coll_wire")):
        per_unit = (fb[i] - fa[i]) / (ub - ua)
        out[key] = fa[i] + per_unit * (full_units - ua)
        out[key + "_per_unit"] = per_unit
    out["points"] = {"a_layers": a, "b_layers": b,
                     "a": {"flops": fa[0], "bytes": fa[1],
                           "coll_wire": fa[2]},
                     "b": {"flops": fb[0], "bytes": fb[1],
                           "coll_wire": fb[2]}}
    out["status"] = "OK"
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--out", default="results/calibration")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    from repro.configs import ASSIGNED_ARCHS
    archs = ASSIGNED_ARCHS if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    multi = args.mesh == "multi"
    tag = "pod2x16x16" if multi else "pod16x16"
    os.makedirs(os.path.join(args.out, tag), exist_ok=True)
    for arch in archs:
        for shape in shapes:
            path = os.path.join(args.out, tag, f"{arch}__{shape}.json")
            if args.skip_existing and os.path.exists(path):
                continue
            try:
                rec = calibrate_cell(arch, shape, multi_pod=multi)
            except Exception as e:  # noqa: BLE001
                rec = {"status": "FAIL", "error": f"{type(e).__name__}: {e}"}
            rec.update(arch=arch, shape=shape, mesh=tag)
            with open(path, "w") as f:
                json.dump(rec, f, indent=2)
            st = rec["status"]
            extra = "" if st != "OK" else \
                f" flops={rec['flops']:.3e} bytes={rec['bytes']:.3e} " \
                f"coll={rec['coll_wire']:.3e}"
            print(f"[{tag}] {arch} x {shape}: {st}{extra}")


if __name__ == "__main__":
    main()
