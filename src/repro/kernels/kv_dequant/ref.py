"""Oracle for fused KV dequantization: uint8 codes + per-group scale/zero
-> bf16, matching repro.compression.quantize semantics."""
from __future__ import annotations

import jax.numpy as jnp


def kv_dequant_ref(codes, scales, zeros, *, group: int,
                   out_dtype=jnp.bfloat16):
    """codes: (n, g*group) uint8 laid out as g groups of `group` values per
    row; scales/zeros: (n, g) float32. Returns (n, g*group) out_dtype."""
    n, width = codes.shape
    g = width // group
    c = codes.astype(jnp.float32).reshape(n, g, group)
    x = c * scales[..., None] + zeros[..., None]
    return x.reshape(n, width).astype(out_dtype)
