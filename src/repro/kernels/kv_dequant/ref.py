"""Oracle for fused KV dequantization: uint8 codes + per-group scale/zero
-> bf16, matching repro.compression.quantize semantics."""
from __future__ import annotations

import jax.numpy as jnp


def kv_dequant_ref(codes, scales, zeros, *, group: int,
                   out_dtype=jnp.bfloat16):
    """codes: (n, g*group) uint8 laid out as g groups of `group` values per
    row; scales/zeros: (n, g) float32. Returns (n, g*group) out_dtype."""
    n, width = codes.shape
    g = width // group
    c = codes.astype(jnp.float32).reshape(n, g, group)
    x = c * scales[..., None] + zeros[..., None]
    return x.reshape(n, width).astype(out_dtype)


def kv_dequant_mixed_ref(codes, spans, zeros, bits, *, group: int,
                         out_dtype=jnp.bfloat16):
    """Mixed-bitwidth oracle: per-row `bits` (n, 1) int32 selects the
    scale interpretation spans / (2^bits - 1); otherwise identical to
    kv_dequant_ref."""
    n, width = codes.shape
    g = width // group
    c = codes.astype(jnp.float32).reshape(n, g, group)
    q = ((1 << bits.astype(jnp.int32)) - 1).astype(jnp.float32)
    step = spans / q
    x = c * step[..., None] + zeros[..., None]
    return x.reshape(n, width).astype(out_dtype)
