"""Wrapper: QuantizedTensor (wire format) -> device KV tensor via the
fused Pallas dequant kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.quantize import QuantizedTensor
from repro.kernels.kv_dequant.kernel import kv_dequant, kv_dequant_mixed


def dequantize_chunk(qt: QuantizedTensor, *, interpret: bool | None = None,
                     out_dtype=jnp.bfloat16):
    """Dequantize a streamed KV chunk on-device. Returns qt.shape array."""
    n_vals = int(np.prod(qt.shape))
    group = qt.group
    g_total = qt.scales.shape[0]
    codes = np.zeros(g_total * group, np.uint8)
    codes[:n_vals] = qt.codes
    # row layout: pack whole groups per row, <= 8 groups/row
    gpr = max(1, min(8, g_total))
    rows = -(-g_total // gpr)
    pad_g = rows * gpr - g_total
    codes = codes.reshape(g_total, group)
    scales, zeros = qt.scales, qt.zeros
    if pad_g:
        codes = np.concatenate([codes, np.zeros((pad_g, group), np.uint8)])
        scales = np.concatenate([scales, np.ones(pad_g, np.float32)])
        zeros = np.concatenate([zeros, np.zeros(pad_g, np.float32)])
    codes = codes.reshape(rows, gpr * group)
    scales = scales.reshape(rows, gpr)
    zeros = zeros.reshape(rows, gpr)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    out = kv_dequant(jnp.asarray(codes), jnp.asarray(scales),
                     jnp.asarray(zeros), group=group, interpret=interp,
                     out_dtype=out_dtype)
    return out.reshape(-1)[:n_vals].reshape(qt.shape)


def _spans_of(qt: QuantizedTensor) -> np.ndarray:
    if qt.spans is not None:
        return qt.spans
    # pre-spans tensors: reconstruct (scales were span / (2^bits - 1))
    return (qt.scales * np.float32((1 << qt.bits) - 1)).astype(np.float32)


def dequantize_chunks_mixed(qts: list, *, interpret: bool | None = None,
                            out_dtype=jnp.bfloat16) -> list:
    """Dequantize many streamed KV chunks of heterogeneous bit-widths in
    ONE kernel launch (per-chunk adaptive quantization's fast path: the
    assembly loop would otherwise launch once per bits bucket). All
    chunks must share the quantization group size; each chunk's groups
    are packed into rows carrying that chunk's bit-width in the per-row
    bits plane. Returns one qt.shape array per input, each exactly equal
    (in fp32) to its per-chunk `dequantize_chunk` launch."""
    assert qts, "empty chunk list"
    group = qts[0].group
    assert all(q.group == group for q in qts), "heterogeneous group size"
    gpr = max(1, min(8, max(q.scales.shape[0] for q in qts)))
    codes_rows, span_rows, zero_rows, bits_rows = [], [], [], []
    for qt in qts:
        g_total = qt.scales.shape[0]
        n_vals = int(np.prod(qt.shape))
        codes = np.zeros(g_total * group, np.uint8)
        codes[:n_vals] = qt.codes
        rows = -(-g_total // gpr)
        pad_g = rows * gpr - g_total
        codes = codes.reshape(g_total, group)
        spans, zeros = _spans_of(qt), qt.zeros
        if pad_g:
            codes = np.concatenate(
                [codes, np.zeros((pad_g, group), np.uint8)])
            spans = np.concatenate([spans, np.ones(pad_g, np.float32)])
            zeros = np.concatenate([zeros, np.zeros(pad_g, np.float32)])
        codes_rows.append(codes.reshape(rows, gpr * group))
        span_rows.append(spans.reshape(rows, gpr))
        zero_rows.append(zeros.reshape(rows, gpr))
        bits_rows.append(np.full((rows, 1), qt.bits, np.int32))
    starts = np.cumsum([0] + [b.shape[0] for b in codes_rows])
    codes_all = np.concatenate(codes_rows)
    spans_all = np.concatenate(span_rows).astype(np.float32)
    zeros_all = np.concatenate(zero_rows).astype(np.float32)
    bits_all = np.concatenate(bits_rows)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    out = kv_dequant_mixed(jnp.asarray(codes_all), jnp.asarray(spans_all),
                           jnp.asarray(zeros_all), jnp.asarray(bits_all),
                           group=group, interpret=interp,
                           out_dtype=out_dtype)
    out = np.asarray(out)
    results = []
    for i, qt in enumerate(qts):
        n_vals = int(np.prod(qt.shape))
        rows = out[starts[i]:starts[i + 1]]
        results.append(rows.reshape(-1)[:n_vals].reshape(qt.shape))
    return results
