"""Wrapper: QuantizedTensor (wire format) -> device KV tensor via the
fused Pallas dequant kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression.quantize import QuantizedTensor
from repro.kernels.kv_dequant.kernel import kv_dequant


def dequantize_chunk(qt: QuantizedTensor, *, interpret: bool | None = None,
                     out_dtype=jnp.bfloat16):
    """Dequantize a streamed KV chunk on-device. Returns qt.shape array."""
    n_vals = int(np.prod(qt.shape))
    group = qt.group
    g_total = qt.scales.shape[0]
    codes = np.zeros(g_total * group, np.uint8)
    codes[:n_vals] = qt.codes
    # row layout: pack whole groups per row, <= 8 groups/row
    gpr = max(1, min(8, g_total))
    rows = -(-g_total // gpr)
    pad_g = rows * gpr - g_total
    codes = codes.reshape(g_total, group)
    scales, zeros = qt.scales, qt.zeros
    if pad_g:
        codes = np.concatenate([codes, np.zeros((pad_g, group), np.uint8)])
        scales = np.concatenate([scales, np.ones(pad_g, np.float32)])
        zeros = np.concatenate([zeros, np.zeros(pad_g, np.float32)])
    codes = codes.reshape(rows, gpr * group)
    scales = scales.reshape(rows, gpr)
    zeros = zeros.reshape(rows, gpr)
    interp = (jax.default_backend() != "tpu") if interpret is None \
        else interpret
    out = kv_dequant(jnp.asarray(codes), jnp.asarray(scales),
                     jnp.asarray(zeros), group=group, interpret=interp,
                     out_dtype=out_dtype)
    return out.reshape(-1)[:n_vals].reshape(qt.shape)
