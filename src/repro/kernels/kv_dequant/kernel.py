"""Pallas TPU fused KV-chunk dequantization.

Streamed chunks arrive as uint8 symbol planes (post entropy decode) plus
per-group fp32 scales/zeros; this kernel fuses dequantize + cast to bf16
on-chip so the host never materializes an fp32 copy (on the paper's edge
path this was the PCIe-attached "device transfer" slice of Fig. 16 — on
TPU the dequant runs where the cache lives).

Rows are tiled in VMEM-sized blocks; the group dimension stays inside a
row so a (rows_blk, width) tile always holds whole groups.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(c_ref, s_ref, z_ref, o_ref, *, group: int):
    rows, width = c_ref.shape
    g = width // group
    c = c_ref[...].astype(jnp.float32).reshape(rows, g, group)
    x = c * s_ref[...][..., None] + z_ref[...][..., None]
    o_ref[...] = x.reshape(rows, width).astype(o_ref.dtype)


def _mixed_kernel(c_ref, s_ref, z_ref, b_ref, o_ref, *, group: int):
    rows, width = c_ref.shape
    g = width // group
    c = c_ref[...].astype(jnp.float32).reshape(rows, g, group)
    # per-row bits plane selects the scale interpretation: s_ref holds
    # the bit-width-independent per-group value SPAN (hi - lo), and the
    # row's width turns it into the affine step span / (2^bits - 1) —
    # one launch dequantizes rows of heterogeneous widths
    q = ((1 << b_ref[...].astype(jnp.int32)) - 1).astype(jnp.float32)
    step = s_ref[...] / q                       # (rows, g) / (rows, 1)
    x = c * step[..., None] + z_ref[...][..., None]
    o_ref[...] = x.reshape(rows, width).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("group", "rows_blk", "interpret",
                                    "out_dtype"))
def kv_dequant_mixed(codes, spans, zeros, bits, *, group: int = 64,
                     rows_blk: int = 256, interpret: bool = True,
                     out_dtype=jnp.bfloat16):
    """Mixed-bitwidth variant: rows may carry different quantization
    widths. codes: (n, width) uint8, width % group == 0; spans/zeros:
    (n, width//group) float32 per-group value range / offset; bits:
    (n, 1) int32 per-row widths. A row's step is spans / (2^bits - 1) —
    computed in fp32, so a uniform-bits launch is bit-identical to
    `kv_dequant` fed the host-computed scales (same IEEE division)."""
    n, width = codes.shape
    g = width // group
    rows_blk = min(rows_blk, n)
    grid = (-(-n // rows_blk),)
    kern = functools.partial(_mixed_kernel, group=group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_blk, width), lambda i: (i, 0)),
            pl.BlockSpec((rows_blk, g), lambda i: (i, 0)),
            pl.BlockSpec((rows_blk, g), lambda i: (i, 0)),
            pl.BlockSpec((rows_blk, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_blk, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, width), out_dtype),
        interpret=interpret,
    )(codes, spans, zeros, bits)


@functools.partial(jax.jit,
                   static_argnames=("group", "rows_blk", "interpret",
                                    "out_dtype"))
def kv_dequant(codes, scales, zeros, *, group: int = 64,
               rows_blk: int = 256, interpret: bool = True,
               out_dtype=jnp.bfloat16):
    """codes: (n, width) uint8, width % group == 0;
    scales/zeros: (n, width//group) float32 -> (n, width) out_dtype."""
    n, width = codes.shape
    g = width // group
    rows_blk = min(rows_blk, n)
    grid = (-(-n // rows_blk),)
    kern = functools.partial(_kernel, group=group)
    return pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((rows_blk, width), lambda i: (i, 0)),
            pl.BlockSpec((rows_blk, g), lambda i: (i, 0)),
            pl.BlockSpec((rows_blk, g), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((rows_blk, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, width), out_dtype),
        interpret=interpret,
    )(codes, scales, zeros)
