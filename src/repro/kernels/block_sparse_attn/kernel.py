"""Pallas TPU block-sparse flash attention (SpargeAttention, TPU-adapted).

GPU original skips work at warp granularity; the TPU adaptation tiles
q x kv in 128x128 MXU-aligned blocks, walks a per-(head, q-block) list of
active kv-block indices delivered via scalar prefetch (so the DMA pipeline
can fetch the right K/V tiles ahead of compute), and keeps the flash
running-softmax state (m, l, acc) in VMEM scratch across the innermost
grid dimension.

Grid: (batch*q_heads, n_q_blocks, max_active_blocks). TPU grid iteration
is sequential over the last dimension, which makes the scratch-carried
softmax recurrence legal; `interpret=True` preserves those semantics on
CPU for validation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(idx_ref, cnt_ref,                      # scalar prefetch
            q_ref, k_ref, v_ref,                   # VMEM blocks
            o_ref,                                 # output block
            m_ref, l_ref, acc_ref,                 # VMEM scratch
            *, causal: bool, q_block: int, kv_block: int, scale: float,
            max_nnz: int):
    bh = pl.program_id(0)
    qb = pl.program_id(1)
    j = pl.program_id(2)
    cnt = cnt_ref[bh, qb]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j < cnt)
    def _compute():
        kb = idx_ref[bh, qb, j]
        q = q_ref[0].astype(jnp.float32)           # (bq, d)
        k = k_ref[0].astype(jnp.float32)           # (bk, d)
        v = v_ref[0]                                # (bk, d)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())),
            preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qb * q_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 0)
            kpos = kb * kv_block + jax.lax.broadcasted_iota(
                jnp.int32, (q_block, kv_block), 1)
            s = jnp.where(qpos >= kpos, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(j == max_nnz - 1)
    def _finalize():
        any_row = m_ref[...] > NEG_INF / 2
        l_safe = jnp.where(l_ref[...] > 0, l_ref[...], 1.0)
        out = acc_ref[...] / l_safe[:, None]
        out = jnp.where(any_row[:, None], out, 0.0)
        o_ref[0] = out.astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("causal", "q_block", "kv_block", "scale",
                              "kv_group", "interpret"))
def block_sparse_attention(q, k, v, block_idx, block_cnt, *,
                           causal: bool = True, q_block: int = 128,
                           kv_block: int = 128, scale: float | None = None,
                           kv_group: int = 1, interpret: bool = True):
    """q: (bh, sq, d); k/v: (bh_kv, skv, d) with bh == bh_kv * kv_group
    (GQA: q row bh reads kv row bh // kv_group).
    block_idx: (bh, n_qb, max_nnz) int32; block_cnt: (bh, n_qb) int32.
    """
    bh, sq, d = q.shape
    skv = k.shape[1]
    n_qb = sq // q_block
    max_nnz = block_idx.shape[-1]
    scale = scale if scale is not None else d ** -0.5

    grid = (bh, n_qb, max_nnz)
    kern = functools.partial(_kernel, causal=causal, q_block=q_block,
                             kv_block=kv_block, scale=scale,
                             max_nnz=max_nnz)
    return pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, q_block, d),
                             lambda bh, qb, j, idx, cnt: (bh, qb, 0)),
                pl.BlockSpec((1, kv_block, d),
                             lambda bh, qb, j, idx, cnt:
                             (bh // kv_group, idx[bh, qb, j], 0)),
                pl.BlockSpec((1, kv_block, d),
                             lambda bh, qb, j, idx, cnt:
                             (bh // kv_group, idx[bh, qb, j], 0)),
            ],
            out_specs=pl.BlockSpec((1, q_block, d),
                                   lambda bh, qb, j, idx, cnt: (bh, qb, 0)),
            scratch_shapes=[
                pltpu.VMEM((q_block,), jnp.float32),       # running max
                pltpu.VMEM((q_block,), jnp.float32),       # running sum
                pltpu.VMEM((q_block, d), jnp.float32),     # accumulator
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((bh, sq, d), q.dtype),
        interpret=interpret,
    )(block_idx, block_cnt, q, k, v)
