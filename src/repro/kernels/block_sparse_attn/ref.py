"""Pure-jnp oracle for block-sparse flash attention.

Semantics: for each (batch*head, q_block) row, attention is restricted to
the kv blocks listed in block_idx[:block_cnt]; causal masking applies
inside blocks by absolute position. Rows with zero active blocks output 0.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def block_mask_dense(block_idx: jax.Array, block_cnt: jax.Array,
                     n_qb: int, n_kb: int) -> jax.Array:
    """(bh, n_qb, max_nnz) lists -> (bh, n_qb, n_kb) boolean mask."""
    bh, nq, mx = block_idx.shape
    valid = jnp.arange(mx)[None, None, :] < block_cnt[..., None]
    idx = jnp.where(valid, block_idx, n_kb)            # OOB -> dropped
    mask = jnp.zeros((bh, nq, n_kb + 1), bool)
    mask = mask.at[
        jnp.arange(bh)[:, None, None],
        jnp.arange(nq)[None, :, None],
        idx].set(valid, mode="drop")
    return mask[..., :n_kb]


def block_sparse_attention_ref(q, k, v, block_idx, block_cnt, *,
                               causal: bool = True, q_block: int = 128,
                               kv_block: int = 128,
                               scale: float | None = None):
    """q: (bh, sq, d); k/v: (bh, skv, d) (kv already head-mapped);
    block_idx/cnt: (bh, n_qb, max_nnz) / (bh, n_qb)."""
    bh, sq, d = q.shape
    skv = k.shape[1]
    n_qb = sq // q_block
    n_kb = skv // kv_block
    scale = scale if scale is not None else d ** -0.5

    bmask = block_mask_dense(block_idx, block_cnt, n_qb, n_kb)
    # expand to token resolution
    tok_mask = jnp.repeat(jnp.repeat(bmask, q_block, axis=1),
                          kv_block, axis=2)            # (bh, sq, skv)
    if causal:
        cm = jnp.arange(sq)[:, None] >= jnp.arange(skv)[None, :]
        tok_mask = tok_mask & cm

    s = jnp.einsum("bqd,bkd->bqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    s = jnp.where(tok_mask, s, -jnp.inf)
    row_any = tok_mask.any(-1)
    m = jnp.max(jnp.where(tok_mask, s, -jnp.inf), axis=-1)
    m = jnp.where(row_any, m, 0.0)
    p = jnp.exp(s - m[..., None])
    p = jnp.where(tok_mask, p, 0.0)
    l = p.sum(-1)
    o = jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32))
    o = o / jnp.maximum(l, 1e-30)[..., None]
    o = jnp.where(row_any[..., None], o, 0.0)
    return o.astype(q.dtype)
