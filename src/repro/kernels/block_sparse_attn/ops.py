"""jit'd public wrapper around the block-sparse attention kernel.

`sparse_prefill_attention` is the full pipeline the serving engine uses
for locally-computed chunks: estimate block importance -> select blocks at
98% mass -> run the Pallas kernel (interpret=True on CPU, compiled on
TPU). The pure-jnp oracle lives in ref.py.
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.kernels.block_sparse_attn.kernel import block_sparse_attention
from repro.kernels.block_sparse_attn.ref import block_sparse_attention_ref
from repro.sparse.mask import block_scores, select_blocks


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def sparse_prefill_attention(q, k, v, *, mass: float = 0.98,
                             q_block: int = 128, kv_block: int = 128,
                             causal: bool = True,
                             use_ref: bool = False,
                             interpret: bool | None = None):
    """q: (b, s, hq, d); k/v: (b, s, hkv, d). Returns ((b, s, hq, d),
    block_cnt) — the per-row active-block counts feed the latency
    predictor's `s` feature."""
    b, s, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qf = q.transpose(0, 2, 1, 3).reshape(b * hq, s, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * hkv, s, d)

    # scores need q rows matched to their kv head
    kf_rep = jnp.repeat(kf, g, axis=0) if g > 1 else kf
    scores = block_scores(qf, kf_rep, q_block=q_block, kv_block=kv_block,
                          causal=causal)
    idx, cnt = select_blocks(scores, mass=mass, q_block=q_block,
                             kv_block=kv_block)
    if use_ref:
        vf_rep = jnp.repeat(vf, g, axis=0) if g > 1 else vf
        o = block_sparse_attention_ref(qf, kf_rep, vf_rep, idx, cnt,
                                       causal=causal, q_block=q_block,
                                       kv_block=kv_block)
    else:
        interp = (not _on_tpu()) if interpret is None else interpret
        o = block_sparse_attention(qf, kf, vf, idx, cnt, causal=causal,
                                   q_block=q_block, kv_block=kv_block,
                                   kv_group=g, interpret=interp)
    o = o.reshape(b, hq, s, d).transpose(0, 2, 1, 3)
    return o, cnt.reshape(b, hq, s // q_block)
