"""Oracle for GQA flash-decode: one query token vs a long KV cache."""
from __future__ import annotations

import jax.numpy as jnp


def decode_attention_ref(q, k, v, kv_len, *, scale=None):
    """q: (b, hq, d); k/v: (b, skv, hkv, d); kv_len: valid cache length."""
    b, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    kr = jnp.repeat(k, g, axis=2)
    vr = jnp.repeat(v, g, axis=2)
    s = jnp.einsum("bhd,bkhd->bhk", q.astype(jnp.float32),
                   kr.astype(jnp.float32)) * scale
    mask = jnp.arange(skv)[None, None, :] < kv_len
    s = jnp.where(mask, s, -jnp.inf)
    m = s.max(-1, keepdims=True)
    e = jnp.exp(s - m)
    e = jnp.where(mask, e, 0.0)
    o = jnp.einsum("bhk,bkhd->bhd", e, vr.astype(jnp.float32))
    return (o / jnp.maximum(e.sum(-1)[..., None], 1e-30)).astype(q.dtype)
