"""Pallas TPU flash-decode (GQA, single token vs long KV cache).

Grid: (batch, kv_heads, n_kv_blocks); the q heads of one kv group
(g = hq/hkv rows) ride in one VMEM tile so the MXU does a (g, d) x
(d, bk) matmul per block — at g>=8 this keeps the MXU busy instead of
degrading to vector ops. Running softmax state lives in VMEM scratch
across the sequential innermost dimension; masked tail blocks are skipped
by comparing block start to kv_len (scalar prefetch).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref,                               # scalar prefetch
            q_ref, k_ref, v_ref, o_ref,
            m_ref, l_ref, acc_ref,
            *, kv_block: int, scale: float, n_blocks: int):
    j = pl.program_id(2)
    kv_len = len_ref[0]

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    @pl.when(j * kv_block < kv_len)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # (g, d)
        k = k_ref[0, :, 0, :].astype(jnp.float32)      # (bk, d)
        v = v_ref[0, :, 0, :]                          # (bk, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = j * kv_block + jax.lax.broadcasted_iota(
            jnp.int32, s.shape, 1)
        s = jnp.where(kpos < kv_len, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        p = jnp.where(kpos < kv_len, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        pv = jax.lax.dot_general(p.astype(v.dtype), v,
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv
        m_ref[...] = m_new

    @pl.when(j == n_blocks - 1)
    def _finalize():
        l_safe = jnp.where(l_ref[...] > 0, l_ref[...], 1.0)
        o_ref[0, 0] = (acc_ref[...] / l_safe[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("kv_block", "scale", "interpret"))
def decode_attention(q, k, v, kv_len, *, kv_block: int = 256,
                     scale: float | None = None, interpret: bool = True):
    """q: (b, hq, d); k/v: (b, skv, hkv, d); kv_len: int32 scalar.
    Returns (b, hq, d)."""
    b, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = scale if scale is not None else d ** -0.5
    n_blocks = -(-skv // kv_block)
    qg = q.reshape(b, hkv, g, d)
    kv_len_arr = jnp.asarray([kv_len], jnp.int32)

    kern = functools.partial(_kernel, kv_block=kv_block, scale=scale,
                             n_blocks=n_blocks)
    out = pl.pallas_call(
        kern,
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=(b, hkv, n_blocks),
            in_specs=[
                pl.BlockSpec((1, 1, g, d),
                             lambda bi, hi, j, ln: (bi, hi, 0, 0)),
                pl.BlockSpec((1, kv_block, 1, d),
                             lambda bi, hi, j, ln: (bi, j, hi, 0)),
                pl.BlockSpec((1, kv_block, 1, d),
                             lambda bi, hi, j, ln: (bi, j, hi, 0)),
            ],
            out_specs=pl.BlockSpec((1, 1, g, d),
                                   lambda bi, hi, j, ln: (bi, hi, 0, 0)),
            scratch_shapes=[
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g,), jnp.float32),
                pltpu.VMEM((g, d), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(kv_len_arr, qg, k, v)
    return out.reshape(b, hq, d)
