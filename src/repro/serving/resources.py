"""Generic discrete-event resource servers for the serving layer.

Both shared resources of the cluster — the wireless link(s) and the
accelerator — are expressed as *servers* with one driving protocol:

    submit work        add(key, demand) / submit(key, duration, t)
    peek next event    next_completion() -> (t, key) | None
    advance the clock  advance(t)   (fluid servers integrate deliveries)
    retire work        complete(key[, t])

Two families:

- :class:`LinkTopology` — a *fluid* server network: flows drain
  byte-demands through a path of :class:`LinkStage` s (each a bandwidth
  trace fair-shared under a ``repro.core.costs.SharedLinkModel``).  A
  flow's instantaneous rate is the minimum of its per-stage shares, so a
  per-device NIC feeding a congested AP uplink (the paper's Fig. 13
  scenario) is two stages on the flow's path, and the cloud-egress tree
  (:func:`tree_topology`: NICs -> per-AP uplinks -> one egress stage
  shared by *all* APs) is three.  A single-stage topology is exactly
  PR 1's ``SharedLinkArbiter`` (which is now a subclass).

  :class:`LinkTopology` is the **vectorized event core**: flow state
  lives in struct-of-arrays (remaining bytes, path-group id, telemetry
  accumulators are dense numpy rows), flows are bucketed by their path
  tuple into *path groups* — every flow in a group crosses the same
  stages, so it drains at the same rate — and ``advance()`` integrates
  the whole fleet with one delivered-integral per *group* (one
  ``at_many`` per stage) instead of one per flow.  ``next_completion()``
  searches one candidate per group (the min-remaining flow provably
  finishes first within its group) and caches the result between
  active-set changes.  :class:`ScalarLinkTopology` preserves the
  per-flow dict/loop reference implementation; both share the exact
  same integration and bisection helpers, so at any N the two cores are
  arithmetically in lockstep (the parity suite drives them side by
  side).

- :class:`DeviceRunQueue` — a *slotted* server: compute jobs occupy one
  of ``capacity`` service slots for a fixed duration; excess jobs wait in
  an explicit queue under a FIFO, weighted-fair (WFQ), or deadline-floored
  shortest-remaining-first (SRPT) discipline — SRPT preempts at chunk
  boundaries only, since chunks are the atomic service unit.  This
  replaces the scalar ``util`` dilation: concurrent chunks *wait*, they
  don't mutually stretch.  Queue depth / waits / service backlog are the
  telemetry that feeds the latency predictor's U feature, the SLO
  admission layer (``repro.serving.slo``), and the runtime controller.

All servers are deterministic given their inputs; time is the cluster's
virtual clock (seconds).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Sequence

import numpy as np

from repro.core.costs import DiskTierProfile, SharedLinkModel
from repro.core.engine import BandwidthIntegrator, LinkStarvedError


# ---------------------------------------------------------------------------
# Fluid link servers
# ---------------------------------------------------------------------------


class LinkStage:
    """One arbitrated hop: a bandwidth trace fair-shared among the flows
    currently crossing it, with contention efficiency ``eta(n)`` from the
    link model (``None`` -> ideal fair sharing)."""

    def __init__(self, name: str, integrator: BandwidthIntegrator,
                 link: Optional[SharedLinkModel] = None):
        self.name = name
        self.bw = integrator
        self.link = link
        self.active: set = set()

    def fraction(self) -> float:
        """Per-flow fraction of the instantaneous trace capacity."""
        n = len(self.active)
        if n == 0:
            return 1.0
        eta = self.link.aggregate_efficiency(n) if self.link else 1.0
        return eta / n


# Completion search bounds shared by both topology cores: the doubling
# phase gives up (LinkStarvedError) past _MAX_HORIZON_S of sim time, and
# the bisection early-exits once the bracket is tighter than
# _BISECT_TOL_S (sub-nanosecond sim time — far below any event spacing
# the cluster produces, and the resolution the rtol<=1e-9 parity
# contract is stated against).
_MAX_HORIZON_S = 1e5
_BISECT_TOL_S = 1e-9
_BISECT_MAX_ITERS = 64


def _delivered_on(sts: list, t0: float, t1: float,
                  at_cache: Optional[dict] = None) -> float:
    """Bytes a flow crossing stages `sts` drains over [t0, t1] with the
    *current* active sets. Exact: per-stage rates are constant within
    each trace cell, so the min-rate is integrated cell by cell; beyond
    the last stage grid every stage extrapolates at a constant rate, so
    the tail is integrated analytically (never enumerated — a starved
    link searched out to the 1e5 s horizon must stay cheap).

    ``at_cache`` memoizes per-stage ``at_many`` rows within one caller
    pass (keyed by integrator identity and the clipped upper bound —
    ``t0`` and ``dt`` are fixed within a pass, so the cell bounds, and
    hence the row, are fully determined). Reusing the row is bitwise
    neutral: it is the identical array the stage would recompute.
    """
    if len(sts) == 1:
        return sts[0].bw.bytes_between(t0, t1) * sts[0].fraction()
    fr = np.array([s.fraction() for s in sts])
    dt = sts[0].bw.dt
    t_gmax = max(s.bw.grid_end_s for s in sts)
    total = 0.0
    if t1 > t_gmax:
        tail_span = t1 - max(t0, t_gmax)
        total += tail_span * min(s.bw.tail_bw * f
                                 for s, f in zip(sts, fr))
        t1 = max(t0, t_gmax)
    if t1 > t0:
        k0, k1 = int(np.floor(t0 / dt)), int(np.ceil(t1 / dt))
        bounds = None
        rows = []
        for s in sts:
            ck = (id(s.bw), t1) if at_cache is not None else None
            row = at_cache.get(ck) if ck is not None else None
            if row is None:
                if bounds is None:
                    bounds = np.unique(np.concatenate(
                        [[t0, t1], np.arange(k0 + 1, k1) * dt]))
                    bounds = bounds[(bounds >= t0) & (bounds <= t1)]
                row = s.bw.at_many(bounds)
                if ck is not None:
                    at_cache[ck] = row
            rows.append(row)
        per_stage = np.stack(rows)                              # (S, B)
        deliv = np.diff(per_stage, axis=1) * fr[:, None]        # (S, B-1)
        total += float(np.min(deliv, axis=0).sum())
    return total


def _finish_on(sts: list, t0: float, rem: float, names: tuple) -> float:
    """Finish time of a `rem`-byte demand crossing stages `sts` from
    `t0`, under the current active sets.

    Single-stage paths defer to the integrator's closed-form search;
    multi-stage paths bracket the root by doubling (each delivered
    integral evaluated once — the starvation check reuses the loop's
    last value instead of re-integrating) and then bisect with an
    early exit once the bracket is tighter than ``_BISECT_TOL_S``.
    """
    if rem <= 0:
        return t0
    if len(sts) == 1:
        return sts[0].bw.finish_time(t0, rem / sts[0].fraction())
    lo, hi = t0, t0 + 1e-3
    got = _delivered_on(sts, t0, hi)
    while got < rem and hi - t0 <= _MAX_HORIZON_S:
        hi = t0 + (hi - t0) * 2
        got = _delivered_on(sts, t0, hi)
    if got < rem:
        raise LinkStarvedError(
            f"link starved on path {tuple(names)}: {rem:.0f} B not "
            f"deliverable within {_MAX_HORIZON_S:.0f}s of t={t0:.3f}")
    for _ in range(_BISECT_MAX_ITERS):
        if hi - lo <= _BISECT_TOL_S:
            break
        mid = 0.5 * (lo + hi)
        if _delivered_on(sts, t0, mid) < rem:
            lo = mid
        else:
            hi = mid
    return hi


class LinkTopology:
    """Composable multi-stage link server (fluid-flow approximation),
    vectorized over flows.

    Every flow carries a byte demand along a fixed ``path`` of stages;
    within an interval where the active sets are constant the flow drains
    at ``min_s(trace_s(t) * fraction_s)`` — the bottleneck stage governs.
    The cluster guarantees piecewise-constant membership by always
    advancing to the earliest of (next heap event, earliest completion).

    With one stage per path this reduces *exactly* to the PR 1 shared-link
    arbiter: same cumulative-trace integral, same fair share, same
    completion search.  Per-flow share telemetry on the **last** stage of
    the path (the shared uplink by convention) is accumulated for fleet
    reporting (:meth:`mean_share`); pass ``telemetry=False`` to skip all
    share accumulation (``mean_share`` then reports 1.0 and
    :meth:`stage_shares` ``{}``) when the driver never reads it.

    **Struct-of-arrays layout.** Flow state lives in dense row-indexed
    numpy arrays (``_rem_a`` remaining bytes, ``_gid_a`` path-group id,
    ``_share_a`` / ``_active_a`` / ``_stage_a`` telemetry accumulators);
    ``complete()`` keeps rows dense by swapping the last row in.  Flows
    are bucketed into *path groups* by their path tuple: every flow in a
    group crosses the same stages with the same fractions, so all of
    them drain at the identical rate.  ``advance()`` therefore evaluates
    one delivered integral per live group (memoizing ``at_many`` rows
    across groups that share cell bounds — one ``at_many`` per *stage*
    when traces share a horizon) and applies it to all member rows in
    one vectorized pass.  ``next_completion()`` generalizes the
    arbiter-era fast path to every group: within a group the
    min-remaining flow provably finishes first (equal drain rates), so
    only one candidate per group is bisected; the result is cached and
    reused until the active set changes (``add`` / ``complete``) — the
    earliest absolute finish time is invariant under ``advance`` within
    a membership epoch.

    The dict-shaped views ``_rem`` / ``_path`` and the telemetry getters
    materialize lazily from the arrays, so the scalar-era API (and the
    scalar reference core, :class:`ScalarLinkTopology`) is preserved
    verbatim.
    """

    def __init__(self, stages: dict[str, LinkStage],
                 default_path: Optional[Sequence[str]] = None,
                 *, telemetry: bool = True):
        assert stages, "topology needs at least one stage"
        dts = {st.bw.dt for st in stages.values()}
        assert len(dts) == 1, f"stage traces must share one dt, got {dts}"
        self.stages = stages
        self.default_path = tuple(default_path) if default_path \
            else (next(iter(stages)),)
        self.telemetry = telemetry
        self.t = 0.0
        # struct-of-arrays flow state (dense rows; swap-with-last on
        # complete)
        self._n = 0
        self._keys: list = []                # row -> flow key
        self._row: dict = {}                 # flow key -> row
        cap = 16
        self._plen_max = max(1, len(self.default_path))
        self._rem_a = np.zeros(cap)
        self._gid_a = np.zeros(cap, dtype=np.intp)
        self._share_a = np.zeros(cap)        # last-stage share * time
        self._active_a = np.zeros(cap)       # active time
        self._stage_a = np.zeros((cap, self._plen_max))  # per path position
        self._adv_a = np.zeros(cap, dtype=bool)  # row saw >=1 advance
        # path groups (persist for the topology's lifetime)
        self._gid_of: dict = {}              # path tuple -> gid
        self._gpath: list = []               # gid -> path tuple
        self._gstages: list = []             # gid -> [LinkStage, ...]
        self._gcount: list = []              # gid -> live flow count
        # telemetry of completed flows (never cleared — the scalar-era
        # contract; re-adding a key seeds its rows from here so repeated
        # activations keep one continuous accumulation)
        self._done_tele: dict = {}           # key -> (share, active, {stage})
        self._seeded: dict = {}              # key -> stage names seeded
        self._off: dict = {}                 # key -> off-path carryover
        # next_completion cache, valid between active-set changes
        self._nc: Optional[tuple] = None
        self._nc_valid = False

    # ---- dict-shaped views (scalar-era API; tests and tools use them) ----
    @property
    def _rem(self) -> dict:
        """Flow key -> remaining bytes, materialized from the array."""
        return {k: float(self._rem_a[self._row[k]]) for k in self._keys}

    def remaining(self, key) -> Optional[float]:
        """Bytes still undelivered for an active flow as of the last
        ``advance``; ``None`` when the key has no in-flight transfer
        (mobility drivers use this to size the loss when aborting)."""
        row = self._row.get(key)
        return None if row is None else float(self._rem_a[row])

    @property
    def _path(self) -> dict:
        """Flow key -> path tuple, materialized from the group registry."""
        return {k: self._gpath[int(self._gid_a[self._row[k]])]
                for k in self._keys}

    # ---- membership ----
    def n_active(self) -> int:
        return self._n

    def _group_of(self, p: tuple) -> int:
        gid = self._gid_of.get(p)
        if gid is None:
            gid = len(self._gpath)
            self._gid_of[p] = gid
            self._gpath.append(p)
            self._gstages.append([self.stages[s] for s in p])
            self._gcount.append(0)
            if len(p) > self._plen_max:
                self._plen_max = len(p)
                ns = np.zeros((self._stage_a.shape[0], self._plen_max))
                ns[:, :self._stage_a.shape[1]] = self._stage_a
                self._stage_a = ns
        return gid

    def _grow_rows(self) -> None:
        cap = 2 * len(self._rem_a)

        def g(a):
            new = np.zeros(cap, dtype=a.dtype)
            new[:len(a)] = a
            return new

        self._rem_a = g(self._rem_a)
        self._gid_a = g(self._gid_a)
        self._share_a = g(self._share_a)
        self._active_a = g(self._active_a)
        self._adv_a = g(self._adv_a)
        ns = np.zeros((cap, self._stage_a.shape[1]))
        ns[:self._stage_a.shape[0]] = self._stage_a
        self._stage_a = ns

    def add(self, key, nbytes: float,
            path: Optional[Sequence[str]] = None) -> None:
        assert key not in self._row, f"flow {key} already active"
        p = tuple(path) if path else self.default_path
        for s in p:
            self.stages[s].active.add(key)
        gid = self._group_of(p)
        self._gcount[gid] += 1
        row = self._n
        if row == len(self._rem_a):
            self._grow_rows()
        self._keys.append(key)
        self._row[key] = row
        self._rem_a[row] = float(nbytes)
        self._gid_a[row] = gid
        self._share_a[row] = 0.0
        self._active_a[row] = 0.0
        self._stage_a[row, :] = 0.0
        self._adv_a[row] = False
        if self.telemetry:
            # a re-added key (reload restreams, per-chunk stream flows)
            # continues its accumulation exactly where it left off: seed
            # the fresh rows with the folded totals so every later `+=`
            # extends the same running sums the scalar dicts would hold
            base = self._done_tele.pop(key, None)
            if base is not None:
                share0, active0, by0 = base
                self._share_a[row] = share0
                self._active_a[row] = active0
                seeded, off = [], {}
                for name, v in by0.items():
                    if name in p:
                        self._stage_a[row, p.index(name)] = v
                        seeded.append(name)
                    else:
                        off[name] = v
                if seeded:
                    self._seeded[key] = tuple(seeded)
                if off:
                    self._off[key] = off
        self._n += 1
        self._nc_valid = False

    def _gather_tele(self, key, row: int) -> tuple:
        """(share_time, active_time, {stage: share_time}) for a live row,
        including any carryover from earlier activations of the key."""
        p = self._gpath[int(self._gid_a[row])]
        by = dict(self._off.get(key, {}))
        seeded = self._seeded.get(key, ())
        adv = bool(self._adv_a[row])
        for i, name in enumerate(p):
            # a stage appears once the flow lived through an advance (the
            # scalar core's setdefault point) or was seeded from a prior
            # activation; zero-span activations contribute no entries
            if adv or name in seeded:
                by[name] = float(self._stage_a[row, i])
        return float(self._share_a[row]), float(self._active_a[row]), by

    def complete(self, key) -> None:
        row = self._row.pop(key)
        gid = int(self._gid_a[row])
        for s in self._gpath[gid]:
            self.stages[s].active.discard(key)
        self._gcount[gid] -= 1
        if self.telemetry:
            self._done_tele[key] = self._gather_tele(key, row)
            self._seeded.pop(key, None)
            self._off.pop(key, None)
        last = self._n - 1
        if row != last:                      # keep rows dense
            mkey = self._keys[last]
            self._keys[row] = mkey
            self._row[mkey] = row
            self._rem_a[row] = self._rem_a[last]
            self._gid_a[row] = self._gid_a[last]
            self._share_a[row] = self._share_a[last]
            self._active_a[row] = self._active_a[last]
            self._stage_a[row, :] = self._stage_a[last, :]
            self._adv_a[row] = self._adv_a[last]
        self._keys.pop()
        self._n = last
        self._nc_valid = False

    # ---- integration ----
    def _live_gids(self) -> list:
        return [g for g, c in enumerate(self._gcount) if c > 0]

    def advance(self, t: float) -> None:
        """Integrate all flows over [self.t, t] (constant active sets):
        one delivered integral per path group, applied to every member
        row in a single vectorized pass."""
        if t <= self.t:
            return
        span = t - self.t
        n = self._n
        if n:
            live = self._live_gids()
            got = np.zeros(len(self._gcount))
            at_cache: dict = {}
            for g in live:
                got[g] = _delivered_on(self._gstages[g], self.t, t,
                                       at_cache)
            gid = self._gid_a[:n]
            self._rem_a[:n] = np.maximum(self._rem_a[:n] - got[gid], 0.0)
            if self.telemetry:
                frac = {name: st.fraction()
                        for name, st in self.stages.items()}
                lastf = np.zeros(len(self._gcount))
                gfrac = np.zeros((len(self._gcount), self._plen_max))
                for g in live:
                    p = self._gpath[g]
                    lastf[g] = frac[p[-1]]
                    for i, s in enumerate(p):
                        gfrac[g, i] = frac[s]
                self._share_a[:n] += lastf[gid] * span
                self._active_a[:n] += span
                self._stage_a[:n, :] += gfrac[gid] * span
                self._adv_a[:n] = True
        self.t = t

    # ---- completion search ----
    def next_completion(self) -> Optional[tuple]:
        """(t_done, key) of the earliest flow to finish if the active sets
        stay fixed.

        One bisection per *group*: all flows in a group drain at the same
        rate, so the min-remaining flow (ties to the smallest key, the
        scalar core's order) finishes first within its group — the
        arbiter-era single-stage fast path, generalized.  The result is
        cached until the next ``add``/``complete``: within a membership
        epoch the absolute finish times are invariant under ``advance``
        (a flow's remaining bytes at any interior time equal exactly the
        integral still to run), so the cache is a pure memo."""
        if self._n == 0:
            return None
        if self._nc_valid:
            return self._nc
        n = self._n
        rem = self._rem_a[:n]
        gid = self._gid_a[:n]
        live = self._live_gids()
        best = None
        if len(live) == 1:
            cand_iter = [(live[0], rem.min(), None)]
        else:
            minrem = np.full(len(self._gcount), np.inf)
            np.minimum.at(minrem, gid, rem)
            cand_iter = [(g, minrem[g], gid) for g in live]
        for g, m, gsel in cand_iter:
            tied = np.nonzero(rem == m)[0] if gsel is None \
                else np.nonzero((gsel == g) & (rem == m))[0]
            key = self._keys[tied[0]] if len(tied) == 1 \
                else min(self._keys[i] for i in tied)
            t_fin = _finish_on(self._gstages[g], self.t, float(m),
                               self._gpath[g])
            cand = (t_fin, key)
            if best is None or cand < best:
                best = cand
        self._nc = best
        self._nc_valid = True
        return best

    # ---- telemetry ----
    def mean_share(self, key) -> float:
        """Time-averaged fraction of the flow's last-stage (uplink)
        capacity it received while active; 1.0 if it never waited on a
        shared interval (or with ``telemetry=False``)."""
        if not self.telemetry:
            return 1.0
        row = self._row.get(key)
        if row is not None:
            share, at = float(self._share_a[row]), float(self._active_a[row])
        else:
            share, at, _ = self._done_tele.get(key, (0.0, 0.0, {}))
        if at <= 0:
            return 1.0
        return share / at

    def stage_shares(self, key) -> dict[str, float]:
        """Time-averaged fraction the flow received on *every* stage of
        its path while active, keyed by stage name ({} if it never ran a
        shared interval, or with ``telemetry=False``). The minimum entry
        is the flow's observed bottleneck share — the signal the
        predictor refresh trains on."""
        if not self.telemetry:
            return {}
        row = self._row.get(key)
        if row is not None:
            _, at, by = self._gather_tele(key, row)
        else:
            _, at, by = self._done_tele.get(key, (0.0, 0.0, {}))
        if at <= 0:
            return {}
        return {s: v / at for s, v in by.items()}


class ScalarLinkTopology:
    """The per-flow dict/loop reference implementation of
    :class:`LinkTopology` (the pre-vectorization core): ``advance()``
    integrates one delivered integral per *flow* and
    ``next_completion()`` searches every flow.  Kept as the parity
    oracle — it shares :func:`_delivered_on` / :func:`_finish_on` (and
    the completion cache) with the vectorized core, so the two are
    arithmetically in lockstep and the property suite can drive them
    side by side on identical traces.  API-identical; select it in the
    cluster with ``ServingCluster(link_core="scalar")``."""

    def __init__(self, stages: dict[str, LinkStage],
                 default_path: Optional[Sequence[str]] = None,
                 *, telemetry: bool = True):
        assert stages, "topology needs at least one stage"
        dts = {st.bw.dt for st in stages.values()}
        assert len(dts) == 1, f"stage traces must share one dt, got {dts}"
        self.stages = stages
        self.default_path = tuple(default_path) if default_path \
            else (next(iter(stages)),)
        self.telemetry = telemetry
        self.t = 0.0
        self._rem: dict = {}                 # flow key -> bytes left
        self._path: dict = {}                # flow key -> tuple[str, ...]
        # share telemetry (never cleared on complete): key -> sums
        self._share_time: dict = {}
        self._active_time: dict = {}
        self._stage_share_time: dict = {}    # key -> {stage: share * dt sum}
        self._nc: Optional[tuple] = None
        self._nc_valid = False

    def remaining(self, key) -> Optional[float]:
        """Bytes still undelivered for an active flow as of the last
        ``advance``; ``None`` when the key has no in-flight transfer."""
        rem = self._rem.get(key)
        return None if rem is None else float(rem)

    # ---- membership ----
    def n_active(self) -> int:
        return len(self._rem)

    def add(self, key, nbytes: float,
            path: Optional[Sequence[str]] = None) -> None:
        assert key not in self._rem, f"flow {key} already active"
        p = tuple(path) if path else self.default_path
        for s in p:
            self.stages[s].active.add(key)
        self._rem[key] = float(nbytes)
        self._path[key] = p
        self._nc_valid = False

    def complete(self, key) -> None:
        for s in self._path.pop(key):
            self.stages[s].active.discard(key)
        del self._rem[key]
        self._nc_valid = False

    # ---- integration ----
    def _delivered(self, path: tuple, t0: float, t1: float) -> float:
        return _delivered_on([self.stages[s] for s in path], t0, t1)

    def advance(self, t: float) -> None:
        """Integrate all flows over [self.t, t] (constant active sets)."""
        if t <= self.t:
            return
        span = t - self.t
        for key in self._rem:
            got = self._delivered(self._path[key], self.t, t)
            self._rem[key] = max(self._rem[key] - got, 0.0)
            if not self.telemetry:
                continue
            last = self.stages[self._path[key][-1]]
            self._share_time[key] = self._share_time.get(key, 0.0) \
                + last.fraction() * span
            self._active_time[key] = self._active_time.get(key, 0.0) + span
            per_stage = self._stage_share_time.setdefault(key, {})
            for s in self._path[key]:
                per_stage[s] = per_stage.get(s, 0.0) \
                    + self.stages[s].fraction() * span
        self.t = t

    # ---- completion search ----
    def _finish(self, key) -> float:
        rem, path = self._rem[key], self._path[key]
        return _finish_on([self.stages[s] for s in path], self.t, rem,
                          path)

    def next_completion(self) -> Optional[tuple]:
        """(t_done, key) of the earliest flow to finish if the active sets
        stay fixed. Cached between active-set changes (finish times are
        invariant under ``advance`` within a membership epoch)."""
        if not self._rem:
            return None
        if self._nc_valid:
            return self._nc
        paths = set(self._path.values())
        if len(paths) == 1 and len(next(iter(paths))) == 1:
            # all flows share one single-stage path -> equal shares, so
            # the min-remaining flow provably finishes first: one search
            # instead of one per flow (the arbiter-era fast path)
            key = min(self._rem, key=lambda k: (self._rem[k], k))
            best = (self._finish(key), key)
        else:
            # keys must be mutually orderable (the cluster uses int rids)
            best = min((self._finish(k), k) for k in self._rem)
        self._nc = best
        self._nc_valid = True
        return best

    # ---- telemetry ----
    def mean_share(self, key) -> float:
        """Time-averaged fraction of the flow's last-stage (uplink)
        capacity it received while active; 1.0 if it never waited on a
        shared interval (or with ``telemetry=False``)."""
        at = self._active_time.get(key, 0.0)
        if at <= 0:
            return 1.0
        return self._share_time[key] / at

    def stage_shares(self, key) -> dict[str, float]:
        """Time-averaged fraction the flow received on *every* stage of
        its path while active, keyed by stage name ({} if it never ran a
        shared interval, or with ``telemetry=False``)."""
        at = self._active_time.get(key, 0.0)
        if at <= 0:
            return {}
        return {s: v / at
                for s, v in self._stage_share_time.get(key, {}).items()}


def single_link(integrator: BandwidthIntegrator,
                link: Optional[SharedLinkModel] = None,
                name: str = "uplink", *, cls: Optional[type] = None,
                telemetry: bool = True) -> LinkTopology:
    """The degenerate one-stage topology (== PR 1 SharedLinkArbiter).
    ``cls`` selects the core (:class:`LinkTopology` by default,
    :class:`ScalarLinkTopology` for the reference path)."""
    cls = cls if cls is not None else LinkTopology
    return cls({name: LinkStage(name, integrator, link)},
               default_path=(name,), telemetry=telemetry)


def nic_uplink_topology(nic_integrators: Sequence[BandwidthIntegrator],
                        uplink_integrator: BandwidthIntegrator,
                        uplink_link: Optional[SharedLinkModel] = None,
                        nic_link: Optional[SharedLinkModel] = None,
                        *, cls: Optional[type] = None,
                        telemetry: bool = True
                        ) -> LinkTopology:
    """Two-stage tree: per-device NIC stages feeding one shared AP
    uplink. Device d's flows take path ("nic{d}", "uplink"). The
    degenerate (egress-free, single-AP) case of :func:`tree_topology`."""
    return tree_topology(nic_integrators, [uplink_integrator],
                         [0] * len(nic_integrators),
                         uplink_link=uplink_link, nic_link=nic_link,
                         cls=cls, telemetry=telemetry)


def tree_topology(nic_integrators: Optional[
                      Sequence[BandwidthIntegrator]],
                  uplink_integrators: Sequence[BandwidthIntegrator],
                  ap_of_device: Sequence[int],
                  egress_integrator: Optional[BandwidthIntegrator] = None,
                  *, uplink_link: Optional[SharedLinkModel] = None,
                  nic_link: Optional[SharedLinkModel] = None,
                  egress_link: Optional[SharedLinkModel] = None,
                  cls: Optional[type] = None,
                  telemetry: bool = True
                  ) -> LinkTopology:
    """Full cloud-egress tree: per-device NIC stages feeding per-AP
    uplink stages feeding one cloud-egress stage shared by *all* APs.

    ``ap_of_device[d]`` assigns device ``d`` to its access point. Stage
    names follow :func:`tree_path`: ``nic{d}`` (one per device, omitted
    when ``nic_integrators`` is None), ``uplink`` with a single AP /
    ``uplink{a}`` with several (so the single-AP tree keeps the exact
    two-stage stage names and trace), and ``egress`` when an egress
    integrator is given. A tree with one AP and no egress is therefore
    *identical* to :func:`nic_uplink_topology`; an unconstrained egress
    stage (capacity far above every per-flow share) leaves the two-stage
    trace bit-for-bit unchanged, since the bottleneck min ignores it.
    ``cls`` selects the topology core (vectorized default).
    """
    n_aps = len(uplink_integrators)
    assert n_aps >= 1, "tree needs at least one AP uplink"
    assert all(0 <= a < n_aps for a in ap_of_device), \
        f"ap_of_device entries out of range [0, {n_aps})"
    stages: dict[str, LinkStage] = {}
    if nic_integrators is not None:
        assert len(nic_integrators) == len(ap_of_device), \
            "one NIC integrator per device"
        for d, bw in enumerate(nic_integrators):
            stages[f"nic{d}"] = LinkStage(f"nic{d}", bw, nic_link)
    for a, bw in enumerate(uplink_integrators):
        name = uplink_stage_name(a, n_aps)
        stages[name] = LinkStage(name, bw, uplink_link)
    if egress_integrator is not None:
        stages["egress"] = LinkStage("egress", egress_integrator,
                                     egress_link)
    cls = cls if cls is not None else LinkTopology
    return cls(stages, default_path=(uplink_stage_name(0, n_aps),),
               telemetry=telemetry)


def uplink_stage_name(ap: int, n_aps: int) -> str:
    """Stage name of AP `ap`'s uplink ("uplink" when there is only one,
    so single-AP trees keep the two-stage naming)."""
    return "uplink" if n_aps == 1 else f"uplink{ap}"


def tree_path(device: int, ap: int, n_aps: int, *, has_nic: bool,
              has_egress: bool) -> tuple:
    """Path of stage names a flow from `device` (attached to AP `ap`)
    takes through a :func:`tree_topology`."""
    path = (f"nic{device}",) if has_nic else ()
    path += (uplink_stage_name(ap, n_aps),)
    if has_egress:
        path += ("egress",)
    return path


# ---------------------------------------------------------------------------
# Slotted device server
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class _QueuedJob:
    key: object
    duration_s: float
    flow: object
    weight: float
    t_submit: float
    seq: int
    remaining_s: float = 0.0          # flow's est. remaining service (srpt)
    deadline_s: Optional[float] = None   # absolute deadline (srpt floor)


class DeviceRunQueue:
    """Explicit accelerator run queue with ``capacity`` service slots.

    Jobs (compute chunks) are submitted with a fixed service duration; a
    job either starts immediately (a slot is free) or waits. Disciplines:

    - ``"fifo"``  — global submit order;
    - ``"wfq"``   — weighted fair queueing: among queued jobs, start the
      one whose *flow* has the least weight-normalized attained service.
      On submit a flow's attained service is floored to a few quanta
      behind the least-served *active* flow, so a newcomer (or a flow
      returning from a long idle/streaming stretch) competes from now
      instead of replaying its absence as credit and starving veterans;
      the grace margin is wide enough that a continuously-competing
      flow's earned advantage (bounded by ~one quantum) is never clawed
      back. A flow with weight w receives a ~w-proportional share of
      device time under backlog (capped by the engine's one-outstanding-
      chunk-per-request protocol at capacity/(capacity+1)-ish shares);
      ties break by submit order.
    - ``"srpt"``  — shortest-remaining-processing-time, preemptive at
      chunk boundaries: chunks are the atomic service unit, so a running
      chunk is never interrupted, but at every dispatch the queued job
      whose *flow* has the least estimated remaining service
      (``remaining_s``, supplied by the driver from its plan minus
      attained service) starts next. A **deadline floor** bounds the
      starvation SRPT would otherwise inflict on long flows: any queued
      job whose absolute ``deadline_s`` is within ``deadline_floor_s``
      of now preempts the SRPT order, earliest deadline first — a long
      flow is deferred by shorter ones only until its deadline approaches,
      never past it while the server has a dispatch to give.

    The protocol mirrors the fluid servers: ``submit`` returns the start
    time (or ``None`` if queued), ``complete(key, t)`` frees the slot and
    returns the jobs that start as a result. ``next_completion()`` is the
    earliest in-service finish. ``load()`` / ``depth()`` / ``backlog_s()``
    / ``waits`` are the telemetry surface (predictor U feature, SLO
    admission prediction, controller pressure, fleet reports).
    """

    def __init__(self, capacity: int = 1, discipline: str = "fifo", *,
                 deadline_floor_s: float = 0.5):
        assert capacity >= 1
        assert discipline in ("fifo", "wfq", "srpt"), discipline
        self.capacity = capacity
        self.discipline = discipline
        self.deadline_floor_s = deadline_floor_s
        self._queue: list[_QueuedJob] = []
        self._running: dict = {}             # key -> (t_end, job)
        self._attained: dict = {}            # flow -> attained service
        self._vtime = 0.0                    # SFQ virtual time (start tags)
        self._seq = 0
        self.waits: list[float] = []         # per-job start - submit
        self.busy_s = 0.0

    # ---- telemetry ----
    def depth(self) -> int:
        """Jobs waiting (not in service)."""
        return len(self._queue)

    def in_service(self) -> int:
        return len(self._running)

    def load(self) -> int:
        """Occupancy: in-service + waiting jobs."""
        return len(self._queue) + len(self._running)

    def backlog_s(self) -> float:
        """Service seconds committed to the server: queued plus
        in-service job durations (in-service jobs count in full — a
        conservative bound, since the clock-free queue cannot know how
        much of a running chunk has elapsed). The SLO admission layer
        drains this by ``capacity`` to project a new request's wait."""
        return (sum(j.duration_s for j in self._queue)
                + sum(job.duration_s for _, job in self._running.values()))

    # ---- protocol ----
    def submit(self, key, duration_s: float, t: float, *,
               flow=None, weight: float = 1.0,
               remaining_s: Optional[float] = None,
               deadline_s: Optional[float] = None) -> Optional[float]:
        """Returns the start time if the job enters service now, else
        None (it waits; the driver learns the start via complete()).
        ``remaining_s`` (srpt) is the flow's estimated remaining service
        including this job (defaults to the job's own duration);
        ``deadline_s`` (srpt) is the flow's absolute deadline for the
        anti-starvation floor."""
        assert weight > 0
        f = key if flow is None else flow
        if self.discipline == "wfq":
            # fairness floor: re-enter no more than ~3 quanta behind the
            # least-served active flow, so idle time is not banked as
            # credit (an unfloored newcomer would monopolize the server
            # until it caught up with the veterans' attained service)
            floor = (self._active_min_norm()
                     - 3.0 * float(duration_s) / weight) * weight
            self._attained[f] = max(self._attained.get(f, 0.0), floor)
        job = _QueuedJob(key=key, duration_s=float(duration_s),
                         flow=f, weight=weight, t_submit=t, seq=self._seq,
                         remaining_s=float(duration_s if remaining_s is None
                                           else max(remaining_s, duration_s)),
                         deadline_s=deadline_s)
        self._seq += 1
        self._queue.append(job)
        started = self._dispatch(t)
        for k, t0, _ in started:
            if k == key:
                return t0
        return None

    def _active_min_norm(self) -> float:
        """Least weight-normalized attained service among flows with a
        job queued or in service; the last dispatch's level when idle."""
        jobs = list(self._queue) + [job for _, job in self._running.values()]
        if not jobs:
            return self._vtime
        return min(self._attained.get(j.flow, 0.0) / j.weight
                   for j in jobs)

    def _pick(self, t: float) -> int:
        if self.discipline == "fifo":
            return 0                         # queue is in submit order
        if self.discipline == "srpt":
            # deadline floor: jobs whose deadline is within the floor of
            # now override SRPT order, earliest deadline first — a long
            # flow never starves past its deadline
            urgent = [i for i, j in enumerate(self._queue)
                      if j.deadline_s is not None
                      and j.deadline_s - t <= self.deadline_floor_s]
            if urgent:
                return min(urgent, key=lambda i: (
                    self._queue[i].deadline_s, self._queue[i].seq))
            return min(range(len(self._queue)), key=lambda i: (
                self._queue[i].remaining_s, self._queue[i].seq))
        return min(range(len(self._queue)), key=lambda i: (
            self._attained.get(self._queue[i].flow, 0.0)
            / self._queue[i].weight,
            self._queue[i].seq))

    def _dispatch(self, t: float) -> list[tuple]:
        """Fill free slots; returns [(key, t_start, duration_s), ...]."""
        started = []
        while self._queue and len(self._running) < self.capacity:
            job = self._queue.pop(self._pick(t))
            self.waits.append(t - job.t_submit)
            self._vtime = max(self._vtime,
                              self._attained.get(job.flow, 0.0)
                              / job.weight)
            self._attained[job.flow] = \
                self._attained.get(job.flow, 0.0) + job.duration_s
            self._running[job.key] = (t + job.duration_s, job)
            self.busy_s += job.duration_s
            started.append((job.key, t, job.duration_s))
        return started

    def next_completion(self) -> Optional[tuple]:
        if not self._running:
            return None
        key = min(self._running,
                  key=lambda k: (self._running[k][0],
                                 self._running[k][1].seq))
        return self._running[key][0], key

    def complete(self, key, t: float) -> list[tuple]:
        """Retire an in-service job; returns newly started jobs."""
        del self._running[key]
        return self._dispatch(t)

    def cancel(self, key, t: float) -> list[tuple]:
        """Abort a job wherever it is (in service or still queued) —
        device churn kills work mid-flight. Frees the slot without
        recording attained service beyond what ``_dispatch`` already
        charged, and returns the jobs that start as a result (a vacated
        slot dispatches the queue exactly like a completion). No-op
        (returns []) when the key is unknown — the job may already have
        completed at the abort's event time."""
        if key in self._running:
            del self._running[key]
            return self._dispatch(t)
        for i, job in enumerate(self._queue):
            if job.key == key:
                del self._queue[i]
                break
        return []


# ---------------------------------------------------------------------------
# Serial disk-tier server (KV memory backing store)
# ---------------------------------------------------------------------------


class DiskServer:
    """Serial FIFO transfer server for the KV memory server's disk tier.

    One transfer at a time, busy-until semantics: a submitted transfer
    starts when every earlier one has drained (demotion *writes* and
    reload *reads* share the one device, so a reload issued during an
    eviction storm genuinely queues behind the writes), and occupies the
    device for ``n_ops * latency + bytes / bw`` of its direction
    (:func:`repro.core.costs.t_disk_read` / ``t_disk_write``). Unlike
    the fluid link stages there is no fair sharing — storage queues
    serially at these transfer sizes — so ``submit`` can return the
    completion time immediately and the driver schedules it as a heap
    event. ``backlog_s(now)`` (time until the device drains) is the
    telemetry the reload planner seeds its disk-path load with.
    """

    def __init__(self, profile: DiskTierProfile):
        self.profile = profile
        self.free_at = 0.0
        self.busy_s = 0.0
        self.bytes_read = 0.0
        self.bytes_written = 0.0
        self.n_reads = 0
        self.n_writes = 0
        self.waits: list[float] = []         # per-transfer start - submit

    def backlog_s(self, now: float) -> float:
        """Seconds until the device drains everything already queued."""
        return max(self.free_at - now, 0.0)

    def submit(self, nbytes: float, t: float, *, op: str = "read",
               n_ops: int = 1) -> float:
        """Queue one transfer; returns its completion time."""
        assert op in ("read", "write"), op
        p = self.profile
        bw = p.read_bw if op == "read" else p.write_bw
        dur = n_ops * p.latency_s + nbytes / bw
        t0 = max(t, self.free_at)
        self.waits.append(t0 - t)
        self.free_at = t0 + dur
        self.busy_s += dur
        if op == "read":
            self.bytes_read += nbytes
            self.n_reads += 1
        else:
            self.bytes_written += nbytes
            self.n_writes += 1
        return self.free_at
