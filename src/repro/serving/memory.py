"""Per-device KV memory as a first-class resource server.

The cluster's compute (``DeviceRunQueue``) and network (``LinkTopology``)
are explicit servers, but through PR 5 device memory was infinite: every
assembled context stayed resident forever, so long-decode and
high-concurrency overloads were physically dishonest — at production
batch sizes memory, not the link, binds first. This module closes that
gap with a :class:`KVMemoryServer` per device:

  - **residency tracking** — a request is charged per assembled prefill
    chunk (stream or compute completion) and per decoded token
    (``DecodeTick`` growth, ``repro.core.engine.token_kv_bytes``); bytes
    are released when the request finalizes. With
    ``MemoryModel.capacity_bytes=None`` the server is a passive meter
    (peak / time-weighted percentile telemetry) and traces are
    bit-identical to a cluster without one.
  - **tiered backing store** — DRAM in front of an optional disk tier
    (``repro.core.costs.DiskTierProfile`` via a serial
    :class:`repro.serving.resources.DiskServer`): eviction *demotes* a
    victim's KV to disk (a write occupies the disk server, so reloads
    queue behind demotion storms, KVSwap-style) or *drops* it when no
    tier is configured.
  - **pressure-triggered eviction** — when a charge pushes residency
    over capacity, victims are selected among ready (fully assembled),
    unpinned residents: ``"lru"`` by last use, ``"idle"`` preferring
    sequences parked outside the active decode batch, or ``"bits"``
    (evict-to-lower-bits): the victim's resident KV is requantized down
    the ``compression.quantize.BITRATE_LEVELS`` ladder *in place* —
    shrinking without suspending the sequence — and only demoted or
    dropped at the ladder floor. With ``MemoryModel.cold_frac < 1`` the
    requantization is cold-pool-first: only the victim's low-saliency
    share of resident KV walks the ladder until it floors; the hot
    remainder (what attention actually reads at decode) degrades last.
    Assembling requests are never victims;
    when no victim fits the server over-commits rather than deadlock.
  - **reload planning** — an evicted sequence that reaches its next
    decode dispatch emits a ``repro.core.engine.KVReload`` and
    :func:`plan_reload` re-poses SparKV's overhead-aware stream-vs-
    compute decision at reload time ("Compute Or Load KV Cache? Why Not
    Both?"): per chunk, pick among **disk read**, **cloud restream**
    (the plan's compressed wire bytes over the projected bottleneck
    share) and **local recompute** (the plan's per-chunk compute
    predictions), greedy-LPT across the three paths seeded with their
    live backlogs — the paths overlap exactly like the prefill
    scheduler's stream/compute stages. The cluster executes each leg on
    the real servers, so reload time is contention, not a formula.

Conservation ledger (the hypothesis-tested invariant): every byte ever
charged is exactly one of resident, on disk, dropped, or freed::

    charged_total == resident + disk + dropped_total + freed_total

Downgrades move bytes resident -> freed, demotions resident -> disk,
reloads disk -> resident (a dropped context's restore is a fresh
charge), releases resident -> freed.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.compression.quantize import BITRATE_LEVELS
from repro.core.costs import MemoryModel, t_disk_read
from repro.core.engine import KVReload
from repro.serving.resources import DiskServer

# Link-topology flow keys for reload restreams: offset into a namespace
# disjoint from request rids (the topology orders keys, so they must stay
# plain ints, mutually comparable with rids).
RELOAD_FLOW_BASE = 1 << 30


@dataclasses.dataclass(frozen=True)
class EvictionEvent:
    """One eviction step the server performed under pressure. ``action``
    is ``"downgrade"`` (in-place requantization; the sequence keeps
    running at ``bits``), ``"demote"`` (resident KV moved to the disk
    tier; the sequence must reload before decoding) or ``"drop"`` (no
    tier: KV discarded; reload must restream/recompute)."""
    rid: int
    action: str
    freed_bytes: float
    bits: int
    t: float


@dataclasses.dataclass
class _Resident:
    rid: int
    bytes: float = 0.0            # DRAM-resident KV (hot + cold pools)
    bits: int = 16                # hot-pool quantization width
    disk_bytes: float = 0.0       # demoted copy on the disk tier
    evicted_bytes: float = 0.0    # resident bytes at demotion/drop time
    t_last_use: float = 0.0
    ready: bool = False           # context fully assembled (evictable)
    evicted: bool = False         # demoted/dropped: needs reload
    reloading: bool = False
    parked: bool = False          # finalized; kept for prefix reuse
    # cold-pool split for the "bits" policy with cold_frac < 1: the
    # low-saliency share of the resident KV, downgraded first
    cold_bytes: float = 0.0
    cold_bits: int = 16
    split: bool = False           # cold pool carved out yet


class KVMemoryServer:
    """Per-device KV residency server (see module docstring).

    Protocol with the cluster::

        admit(rid, t)                           # request enters service
        evs = charge(rid, nbytes, t, ...)       # chunk / token growth
        mark_ready(rid, t)                      # prefill assembled
        touch(rid, t)                           # decode step used the KV
        if needs_reload(rid): ev = begin_reload(rid, t)
        evs = finish_reload(rid, t, ...)        # all legs landed
        release(rid, t)                         # request finalized

    Every ``charge`` / ``finish_reload`` may return eviction events the
    cluster must act on (suspend demoted/dropped sequences in the decode
    batcher). ``pinned`` rids are never victims (members of an in-flight
    dispatch, the request being charged); ``idle`` rids are the
    ``"idle"`` policy's preferred victims (enrolled but outside the
    active decode batch).
    """

    def __init__(self, model: MemoryModel):
        self.model = model
        self.capacity = model.capacity_bytes
        prof = model.disk_profile
        self.disk: Optional[DiskServer] = \
            DiskServer(prof) if prof is not None else None
        self._res: dict[int, _Resident] = {}
        # conservation ledger
        self.charged_total = 0.0
        self.freed_total = 0.0
        self.dropped_total = 0.0
        self.resident_total = 0.0
        self.disk_total = 0.0
        # counters
        self.n_evictions = 0          # demote + drop (suspending steps)
        self.n_downgrades = 0
        self.n_demotions = 0
        self.n_drops = 0
        self.n_reloads = 0
        self.reload_bytes = 0.0
        self.n_retired = 0            # parked prefix segments reclaimed
        # residency history for peak / time-weighted percentiles
        self.peak_resident = 0.0
        self._hist_t: list[float] = [0.0]
        self._hist_v: list[float] = [0.0]

    # ---- ledger ----
    def ledger_balance(self) -> float:
        """``charged - (resident + disk + dropped + freed)`` — zero (to
        float tolerance) at every point of every legal interleaving."""
        return self.charged_total - (self.resident_total + self.disk_total
                                     + self.dropped_total
                                     + self.freed_total)

    def _record(self, t: float) -> None:
        self.peak_resident = max(self.peak_resident, self.resident_total)
        self._hist_t.append(t)
        self._hist_v.append(self.resident_total)

    # ---- telemetry ----
    def resident_bytes(self) -> float:
        return self.resident_total

    def pressure(self) -> float:
        """Resident bytes over capacity (0.0 when unbounded)."""
        if self.capacity is None:
            return 0.0
        return self.resident_total / self.capacity

    def resident_percentile(self, q: float) -> float:
        """Time-weighted percentile of resident bytes over the run so
        far (instantaneous samples weighted by how long they held)."""
        if len(self._hist_t) < 2:
            return float(self._hist_v[-1])
        ts = np.asarray(self._hist_t)
        vs = np.asarray(self._hist_v)
        durs = np.diff(ts)
        vals = vs[:-1]
        total = float(durs.sum())
        if total <= 0:
            return float(vs[-1])
        order = np.argsort(vals, kind="stable")
        cum = np.cumsum(durs[order])
        idx = int(np.searchsorted(cum, q / 100.0 * total))
        return float(vals[order][min(idx, len(vals) - 1)])

    def telemetry(self) -> dict:
        out = {
            "capacity_bytes": self.capacity,
            "peak_resident_bytes": self.peak_resident,
            "resident_p99_bytes": self.resident_percentile(99),
            "n_evictions": self.n_evictions,
            "n_downgrades": self.n_downgrades,
            "n_demotions": self.n_demotions,
            "n_drops": self.n_drops,
            "n_reloads": self.n_reloads,
            "reload_bytes": self.reload_bytes,
            "n_retired": self.n_retired,
            "charged_bytes_total": self.charged_total,
        }
        if self.disk is not None:
            out.update(disk_bytes_written=self.disk.bytes_written,
                       disk_bytes_read=self.disk.bytes_read,
                       disk_busy_s=self.disk.busy_s)
        return out

    def bits_of(self, rid: int) -> int:
        r = self._res.get(rid)
        return r.bits if r is not None else self.model.resident_bits

    # ---- residency protocol ----
    def admit(self, rid: int, t: float) -> None:
        assert rid not in self._res, f"rid {rid} already tracked"
        self._res[rid] = _Resident(rid=rid, bits=self.model.resident_bits,
                                   t_last_use=t)

    def touch(self, rid: int, t: float) -> None:
        r = self._res.get(rid)
        if r is not None:
            r.t_last_use = t

    def mark_ready(self, rid: int, t: float) -> None:
        r = self._res[rid]
        r.ready = True
        r.t_last_use = t

    def needs_reload(self, rid: int) -> bool:
        r = self._res.get(rid)
        return r is not None and r.evicted and not r.reloading

    def charge(self, rid: int, nbytes: float, t: float, *,
               pinned: frozenset = frozenset(),
               idle: frozenset = frozenset()) -> list[EvictionEvent]:
        """Charge `nbytes` of new resident KV to `rid` (prefill chunk or
        decode-token growth) and enforce capacity. Growth lands at the
        request's *current* resident bit-width, so a bits-downgraded
        sequence keeps growing at its reduced footprint."""
        r = self._res[rid]
        nbytes = float(nbytes) * r.bits / self.model.resident_bits
        if nbytes > 0:
            r.bytes += nbytes
            r.t_last_use = t
            self.charged_total += nbytes
            self.resident_total += nbytes
            self._record(t)
        return self._enforce(t, pinned=pinned | {rid}, idle=idle)

    def release(self, rid: int, t: float) -> None:
        """Request finalized: free its resident KV; any disk copy is
        discarded (counted dropped — those bytes never returned)."""
        r = self._res.pop(rid)
        if r.bytes > 0:
            self.freed_total += r.bytes
            self.resident_total -= r.bytes
        if r.disk_bytes > 0:
            self.dropped_total += r.disk_bytes
            self.disk_total -= r.disk_bytes
        self._record(t)

    # ---- prefix-reuse parking (radix-cache-style retained segments) ----
    def park(self, rid: int, t: float) -> bool:
        """Request finalized, but its assembled prefix KV stays
        addressable for cross-request reuse (the device prefix cache
        indexes it by content key). Parked segments remain resident and
        fully evictable — they are the *preferred* victims under
        pressure, and eviction retires them outright (``"retire"``
        action: the cluster must invalidate the prefix-cache keys)
        instead of demoting a session nobody will resume. Returns False
        (caller should ``release`` instead) when there is nothing worth
        parking: the KV is evicted/reloading or empty."""
        r = self._res[rid]
        if r.evicted or r.reloading or r.bytes <= 0:
            return False
        r.parked = True
        r.ready = True
        r.t_last_use = t
        self._record(t)
        return True

    def parked_rids(self) -> list[int]:
        return [r.rid for r in self._res.values() if r.parked]

    def retire(self, rid: int, t: float) -> None:
        """Explicitly reclaim a parked segment (cluster-side
        invalidation, e.g. end of run): resident bytes -> freed, any
        disk copy -> dropped, tracking removed."""
        r = self._res.pop(rid)
        assert r.parked, f"rid {rid} is not parked"
        if r.bytes > 0:
            self.freed_total += r.bytes
            self.resident_total -= r.bytes
        if r.disk_bytes > 0:
            self.dropped_total += r.disk_bytes
            self.disk_total -= r.disk_bytes
        self.n_retired += 1
        self._record(t)

    # ---- reload protocol ----
    def begin_reload(self, rid: int, t: float) -> KVReload:
        r = self._res[rid]
        assert r.evicted and not r.reloading, (rid, r)
        r.reloading = True
        return KVReload(rid=rid, nbytes=r.evicted_bytes,
                        from_disk=r.disk_bytes > 0,
                        mode=self.model.reload)

    def finish_reload(self, rid: int, t: float, *,
                      pinned: frozenset = frozenset(),
                      idle: frozenset = frozenset()
                      ) -> list[EvictionEvent]:
        """All reload legs landed: the KV is resident again at its
        pre-eviction size and width. A disk copy is consumed (transfer
        back to DRAM); a dropped context's restore is a fresh charge.
        Recharging may itself evict someone else — the reloaded rid is
        pinned so the server never evicts what it just restored."""
        r = self._res[rid]
        assert r.reloading, rid
        restore = r.evicted_bytes
        if r.disk_bytes > 0:
            self.disk_total -= r.disk_bytes
            fresh = restore - r.disk_bytes
            r.disk_bytes = 0.0
        else:
            fresh = restore
        self.charged_total += max(fresh, 0.0)
        r.bytes += restore
        self.resident_total += restore
        r.evicted_bytes = 0.0
        r.evicted = False
        r.reloading = False
        r.t_last_use = t
        self.n_reloads += 1
        self.reload_bytes += restore
        self._record(t)
        return self._enforce(t, pinned=pinned | {rid}, idle=idle)

    # ---- eviction ----
    def _candidates(self, pinned: frozenset) -> list[_Resident]:
        return [r for r in self._res.values()
                if r.ready and not r.evicted and not r.reloading
                and r.bytes > 0 and r.rid not in pinned]

    def _pick_victim(self, pinned: frozenset,
                     idle: frozenset) -> Optional[_Resident]:
        cands = self._candidates(pinned)
        if not cands:
            return None
        # parked prefix segments are speculative value; live sequences
        # are committed work — reclaim speculation first (LRU among the
        # parked, regardless of policy)
        parked = [r for r in cands if r.parked]
        if parked:
            return min(parked, key=lambda r: (r.t_last_use, r.rid))
        if self.model.policy == "idle":
            parked = [r for r in cands if r.rid in idle]
            if parked:
                cands = parked
        if self.model.policy == "bits":
            # spread the ladder: downgrade the widest resident first (LRU
            # tie-break), so every sequence degrades a level before any
            # one is crushed to the floor and demoted
            return min(cands, key=lambda r: (-r.bits, r.t_last_use, r.rid))
        return min(cands, key=lambda r: (r.t_last_use, r.rid))

    def _evict_step(self, r: _Resident, t: float) -> EvictionEvent:
        if r.parked:
            # retire the parked segment outright: no session resumes it,
            # so demotion/downgrade would spend tier bandwidth on bytes
            # whose only value was being DRAM-resident
            freed = r.bytes
            bits = r.bits
            self.retire(r.rid, t)
            return EvictionEvent(r.rid, "retire", freed, bits, t)
        if self.model.policy == "bits":
            frac = getattr(self.model, "cold_frac", 1.0)
            if frac >= 1.0:
                # whole-resident downgrade (the pre-cold-pool behavior,
                # kept verbatim for bit-parity at the default)
                lower = [b for b in BITRATE_LEVELS if b < r.bits]
                if lower:
                    new_bits = lower[0]
                    new_bytes = r.bytes * new_bits / r.bits
                    freed = r.bytes - new_bytes
                    r.bytes = new_bytes
                    r.bits = new_bits
                    self.freed_total += freed
                    self.resident_total -= freed
                    self.n_downgrades += 1
                    self._record(t)
                    return EvictionEvent(r.rid, "downgrade", freed,
                                         new_bits, t)
            else:
                # cold-pool-first requantization: carve the resident
                # into hot/cold at the model's cold fraction once, then
                # walk only the cold pool down the ladder; the hot pool
                # (the chunks attention actually reads) degrades only
                # after the cold pool hits the floor
                if not r.split:
                    r.cold_bytes = r.bytes * frac
                    r.cold_bits = r.bits
                    r.split = True
                lower = [b for b in BITRATE_LEVELS if b < r.cold_bits]
                if lower and r.cold_bytes > 0:
                    new_bits = lower[0]
                    new_cold = r.cold_bytes * new_bits / r.cold_bits
                    freed = r.cold_bytes - new_cold
                    r.cold_bytes = new_cold
                    r.cold_bits = new_bits
                    r.bytes -= freed
                    self.freed_total += freed
                    self.resident_total -= freed
                    self.n_downgrades += 1
                    self._record(t)
                    return EvictionEvent(r.rid, "downgrade", freed,
                                         new_bits, t)
                lower = [b for b in BITRATE_LEVELS if b < r.bits]
                if lower:
                    new_bits = lower[0]
                    hot = r.bytes - r.cold_bytes
                    new_hot = hot * new_bits / r.bits
                    freed = hot - new_hot
                    r.bits = new_bits
                    r.bytes -= freed
                    self.freed_total += freed
                    self.resident_total -= freed
                    self.n_downgrades += 1
                    self._record(t)
                    return EvictionEvent(r.rid, "downgrade", freed,
                                         new_bits, t)
        freed = r.bytes
        r.evicted_bytes = r.bytes
        r.bytes = 0.0
        r.cold_bytes = 0.0
        r.cold_bits = r.bits
        r.split = False
        r.evicted = True
        self.resident_total -= freed
        self.n_evictions += 1
        if self.disk is not None:
            r.disk_bytes = freed
            self.disk_total += freed
            self.disk.submit(freed, t, op="write")
            self.n_demotions += 1
            action = "demote"
        else:
            self.dropped_total += freed
            self.n_drops += 1
            action = "drop"
        self._record(t)
        return EvictionEvent(r.rid, action, freed, r.bits, t)

    def _enforce(self, t: float, *, pinned: frozenset,
                 idle: frozenset) -> list[EvictionEvent]:
        if self.capacity is None:
            return []
        evs: list[EvictionEvent] = []
        while self.resident_total > self.capacity:
            victim = self._pick_victim(pinned, idle)
            if victim is None:
                break                 # over-commit: nothing evictable
            evs.append(self._evict_step(victim, t))
        return evs


# ---------------------------------------------------------------------------
# Reload planning (stream vs. compute vs. disk, per chunk)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ReloadPlan:
    """Per-path aggregation of one reload's chunk assignment. The
    cluster turns each non-empty leg into real server work: a link flow
    of ``stream_bytes`` (plus the on-device ``stream_proc_s`` dequant
    tail), one run-queue job of ``comp_s``, and a disk read of
    ``disk_bytes`` in ``n_disk_ops`` extents. ``makespan_s`` is the
    planner's own projection (max over the seeded path loads) — used
    only for plan comparison, never for scheduling."""
    mode: str
    n_stream: int
    n_comp: int
    n_disk: int
    stream_bytes: float
    stream_proc_s: float
    comp_s: float
    disk_bytes: float
    makespan_s: float


def plan_reload(chunks, *, mode: str, profile, stream_bw: float,
                comp_wait_s: float = 0.0, disk=None,
                disk_backlog_s: float = 0.0,
                has_disk_copy: bool = False) -> ReloadPlan:
    """Assign each evicted chunk to disk / restream / recompute.

    ``chunks`` is ``[(wire_bytes, resident_bytes, comp_s), ...]`` — the
    plan's compressed wire bytes, the chunk's share of the resident KV
    on disk, and the planner's predicted compute seconds. Per-chunk path
    costs are the core cost models evaluated at reload time:

      - restream: ``wire / stream_bw + profile.t_proc(wire)``
        (:func:`repro.core.costs.t_stream` at the projected bottleneck
        share);
      - recompute: the chunk's predicted compute seconds;
      - disk: :func:`repro.core.costs.t_disk_read` of its resident
        bytes (only when a demoted copy exists).

    Paths run concurrently (stream on the NIC, compute on the device,
    disk on the storage controller), so the planner list-schedules
    greedily: chunks longest-first (LPT), each onto the path whose
    seeded load + cost is least. Seeds are the live backlogs — the
    device queue's projected wait (``comp_wait_s``, the PR 5 online
    predictor when refreshed) and the disk server's drain time — so a
    path that is already busy wins fewer chunks. ``mode`` restricts the
    path set for the single-path baselines."""
    assert mode in ("planner", "restream", "recompute", "disk"), mode
    have_disk = disk is not None and has_disk_copy
    paths = {"stream": 0.0,
             "comp": float(comp_wait_s)}
    if have_disk:
        paths["disk"] = float(disk_backlog_s)
    if mode == "restream":
        allowed = ("stream",)
    elif mode == "recompute":
        allowed = ("comp",)
    elif mode == "disk":
        allowed = ("disk",) if have_disk else ("stream",)
    else:
        allowed = tuple(paths)

    def cost(path: str, chunk) -> float:
        wire, res, comp_s = chunk
        if path == "stream":
            return wire / stream_bw + profile.t_proc(wire)
        if path == "comp":
            return float(comp_s)
        return t_disk_read(res, disk.profile if isinstance(disk, DiskServer)
                           else disk)

    order = sorted(chunks, key=lambda c: min(cost(p, c) for p in allowed),
                   reverse=True)
    assign: dict[str, list] = {p: [] for p in paths}
    for c in order:
        best = min(allowed, key=lambda p: paths[p] + cost(p, c))
        paths[best] += cost(best, c)
        assign[best].append(c)

    stream_bytes = sum(c[0] for c in assign["stream"])
    stream_proc = sum(profile.t_proc(c[0]) for c in assign["stream"])
    comp_s = sum(float(c[2]) for c in assign["comp"])
    disk_bytes = sum(c[1] for c in assign.get("disk", []))
    used = [p for p in allowed if assign[p]]
    return ReloadPlan(
        mode=mode,
        n_stream=len(assign["stream"]),
        n_comp=len(assign["comp"]),
        n_disk=len(assign.get("disk", [])),
        stream_bytes=stream_bytes,
        stream_proc_s=stream_proc,
        comp_s=comp_s,
        disk_bytes=disk_bytes,
        makespan_s=max((paths[p] for p in used), default=0.0))


def predicted_reload_stall_s(cluster, device: int,
                             add_bytes: float) -> float:
    """Admission-time projection of the reload stall a new request would
    suffer: the residency overflow its full context would create on the
    device, drained at the combined reload bandwidth (disk read + the
    projected bottleneck stream share). Zero whenever the cluster has no
    armed finite-capacity memory server — the bit-parity guarantee for
    ``slo.predict_ttft`` / ``predict_tpot``."""
    server_fn = getattr(cluster, "memory_server", None)
    if server_fn is None:
        return 0.0
    m = server_fn(device)
    if m is None or m.capacity is None:
        return 0.0
    overflow = m.resident_total + float(add_bytes) - m.capacity
    if overflow <= 0:
        return 0.0
    bw = cluster.net.mean_bw * cluster.projected_flow_frac(device)
    nic_bw = cluster.nic_mean_bw(device)
    if nic_bw is not None:
        bw = min(bw, nic_bw)
    if m.disk is not None:
        bw += m.disk.profile.read_bw
    return overflow / max(bw, 1.0)
