"""Fleet traffic generator: arrival processes + request mixes.

Produces ``RequestSpec`` lists for ``repro.serving.cluster``:

  - **poisson**: memoryless arrivals at ``rate_rps`` (the open-loop
    baseline for p50/p99 TTFT under load);
  - **bursty**: a two-state Markov-modulated Poisson process — an "on"
    state multiplies the base rate by ``burst_factor`` (flash crowds /
    synchronized app wakeups), "off" drops to the base rate;
  - **uniform**: deterministic equal spacing (useful for regression
    tests where arrival jitter is noise).

Any base process can additionally be shaped onto an inhomogeneous rate
by the ``diurnal_*`` / ``flash_crowds`` knobs — a deterministic
time-rescaling (no extra randomness) that compresses arrivals where the
modulated rate exceeds the base rate; disarmed knobs are bit-identical.

Request mixes draw context lengths per dataset profile (rounded to whole
chunks) and policies from a weighted table, so one trace can interleave
sparkv / strong_hybrid / local_prefill requests the way a real fleet
mixes device capabilities. For the resource-server cluster, traces can
also spread requests over ``n_devices`` (round-robin, or weighted via
``device_mix`` for asymmetric-NIC fleets — the NIC/uplink/egress link
tree routes per device, and the cluster's ``ap_of_device`` assigns each
device to its access point) and draw per-request WFQ weights from
``weight_mix`` (interactive vs. background service classes).

SLO classes: ``slo_mix`` draws a named service class per request, each
carrying a TTFT deadline (or ``None`` for best-effort) — e.g. a 70/30
interactive/batch split where only interactive requests have deadlines.
4-tuple entries add a per-token TPOT SLO for the decode phase. The
cluster's SLO admission layer (``repro.serving.slo``) consumes the
deadlines; the class name is the reporting bucket for per-class
attainment in the ``FleetReport``.

Decode: ``out_len_mix`` draws a response length per request (chat
replies vs. long generations), setting ``RequestSpec.max_new_tokens`` so
the fleet's continuous decode batches carry a realistic length mix; an
empty mix keeps every spec first-token-only.

Cross-request KV reuse: with ``prefix_pool > 0`` every request carries
prefix-closed span content ids (``repro.core.chunks.span_content_id``
hash chains). The leading ``prefix_frac`` of each request's token blocks
comes from a Zipf-popular pool of shared prefixes (system prompts / RAG
documents — rank ``r`` drawn with probability ∝ ``1/r^prefix_zipf_a``),
the tail is request-unique. Reuse draws come from a **separate** rng
stream (``seed + REUSE_SEED_SALT``), so arming the knobs never perturbs
the base trace — every other spec field is bit-identical to
``prefix_pool=0``. ``prefix_frac=0.0`` is the 0%-overlap configuration:
content ids present (the store counts misses) but never two alike.
:func:`session_trace` generates multi-turn chat sessions instead: each
turn re-sends the whole history, so turn ``j``'s content chain is turn
``j-1``'s plus ``turn_growth_chunks`` fresh blocks — the on-device
prefix-reuse workload (same device the whole session, think-time gaps
between turns).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.core.chunks import span_content_id
from repro.data.workloads import DATASETS
from repro.serving.cluster import RequestSpec

# offset of the reuse rng stream from the trace seed: reuse draws never
# consume from the base stream, so prefix_pool=0 vs >0 traces share every
# non-reuse field bit-for-bit
REUSE_SEED_SALT = 104729


@dataclasses.dataclass(frozen=True)
class TrafficProfile:
    rate_rps: float = 0.5
    arrival: str = "poisson"            # poisson | bursty | uniform
    # bursty (MMPP) knobs
    burst_factor: float = 6.0           # rate multiplier while "on"
    mean_on_s: float = 4.0
    mean_off_s: float = 12.0
    # request mix
    context_mix: tuple = (("longchat", 1.0),)     # (dataset, weight)
    policy_mix: tuple = (("sparkv", 1.0),)        # (policy, weight)
    context_jitter: float = 0.25        # lognormal sigma on dataset mean_len
    min_context: int = 2048
    max_context: int = 16384
    chunk_tokens: int = 1024
    # resource-server routing
    n_devices: int = 1                  # round-robin device assignment
    # (device, draw weight) — overrides round-robin when non-empty, so
    # asymmetric-NIC fleets can skew load toward fast-NIC devices (the
    # cluster's ap_of_device then maps each device to its access point)
    device_mix: tuple = ()
    weight_mix: tuple = ((1.0, 1.0),)   # (wfq weight, draw weight)
    # SLO classes: (class name, ttft deadline_s | None, draw weight) or
    # (class name, ttft deadline_s | None, tpot_slo_s | None, draw weight)
    slo_mix: tuple = ()                 # empty = no deadlines
    # decode: response-length classes (n output tokens, draw weight);
    # empty = first-token-only fleets (max_new_tokens 0 on every spec)
    out_len_mix: tuple = ()
    # cross-request KV reuse: prefix_pool > 0 arms content-id generation
    # (0 keeps every spec anonymous — bit-identical to pre-reuse traces).
    # The leading prefix_frac of each request's token blocks is drawn
    # from a pool of prefix_pool shared chains with Zipf popularity
    # (p ∝ 1/rank^prefix_zipf_a); the tail is request-unique.
    prefix_pool: int = 0
    prefix_zipf_a: float = 1.1
    prefix_frac: float = 0.5
    # multi-turn sessions (session_trace only): (n turns, draw weight)
    # mix, mean exponential think time between turns, and how many fresh
    # chunk-sized blocks each turn appends to the re-sent history
    session_turns_mix: tuple = ((3, 1.0),)
    think_time_s: float = 8.0
    turn_growth_chunks: int = 1
    # hostile-world arrival shaping: a deterministic time-rescaling of
    # the base arrival process to an inhomogeneous rate (no extra rng —
    # disarmed knobs return the base times object untouched, so traces
    # stay bit-identical). diurnal_amp in [0, 1) modulates the rate by
    # 1 + amp*sin(2π t / period + phase); flash_crowds entries
    # (t_start_s, t_end_s, rate_multiplier) multiply the rate inside
    # their window (synchronized wakeups / stadium crowds).
    diurnal_amp: float = 0.0
    diurnal_period_s: float = 60.0
    diurnal_phase: float = 0.0
    flash_crowds: tuple = ()


def _arrival_times(profile: TrafficProfile, n: int,
                   rng: np.random.Generator) -> np.ndarray:
    if profile.arrival == "uniform":
        return np.arange(n) / max(profile.rate_rps, 1e-9)
    if profile.arrival == "poisson":
        gaps = rng.exponential(1.0 / profile.rate_rps, n)
        gaps[0] = 0.0
        return np.cumsum(gaps)
    if profile.arrival == "bursty":
        # two-state MMPP, exponential sojourn in each state
        times = np.empty(n)
        t, state_end, on = 0.0, rng.exponential(profile.mean_off_s), False
        for i in range(n):
            rate = profile.rate_rps * (profile.burst_factor if on else 1.0)
            t += rng.exponential(1.0 / rate) if i else 0.0
            while t > state_end:
                on = not on
                state_end += rng.exponential(
                    profile.mean_on_s if on else profile.mean_off_s)
            times[i] = t
        return times
    raise ValueError(f"unknown arrival process {profile.arrival!r}")


def _shape_arrivals(profile: TrafficProfile,
                    times: np.ndarray) -> np.ndarray:
    """Warp base arrival times onto an inhomogeneous rate profile.

    Standard time-rescaling: if the base process has arrivals at
    cumulative unit-time ``u``, the shaped process places them at
    ``Λ⁻¹(u)`` where ``Λ(t) = ∫₀ᵗ m(s) ds`` and ``m`` is the rate
    multiplier (diurnal sinusoid × flash-crowd windows). Arrivals
    compress where ``m > 1`` and stretch where ``m < 1``; the inversion
    is deterministic, so disarmed knobs return ``times`` unchanged and
    armed ones consume no randomness."""
    if (profile.diurnal_amp <= 0.0 and not profile.flash_crowds) \
            or len(times) == 0:
        return times
    assert 0.0 <= profile.diurnal_amp < 1.0, profile.diurnal_amp
    dt = profile.diurnal_period_s / 256 if profile.diurnal_amp > 0 else 1.0
    for t0, t1, _ in profile.flash_crowds:
        assert t1 > t0, (t0, t1)
        dt = min(dt, (t1 - t0) / 16)
    dt = max(dt, 1e-4)
    n = 1024
    while True:
        grid = np.arange(n) * dt
        m = np.ones(n)
        if profile.diurnal_amp > 0:
            m += profile.diurnal_amp * np.sin(
                2 * np.pi * grid / profile.diurnal_period_s
                + profile.diurnal_phase)
        for t0, t1, mult in profile.flash_crowds:
            m[(grid >= t0) & (grid < t1)] *= mult
        lam = np.concatenate([[0.0],
                              np.cumsum((m[:-1] + m[1:]) * dt / 2)])
        if lam[-1] >= times[-1] or n >= 1 << 24:
            break
        n *= 2
    return np.interp(times, lam, grid)


def _weighted(table: tuple, rng: np.random.Generator) -> str:
    names = [k for k, _ in table]
    w = np.array([v for _, v in table], float)
    return names[rng.choice(len(names), p=w / w.sum())]


def _zipf_pmf(n: int, a: float) -> np.ndarray:
    """Explicit truncated-Zipf pmf: p(rank r) ∝ 1/r^a, r in 1..n."""
    p = 1.0 / np.arange(1, n + 1, dtype=float) ** a
    return p / p.sum()


def _content_chain(n_blocks: int, n_prefix: int, prefix_id: int,
                   unique_tag: str, *, base: tuple = ()) -> tuple:
    """Prefix-closed span-id chain: shared head, request-unique tail.

    Block ``j < n_prefix`` hashes ``prefix:<id>:<j>`` so every request
    drawing the same pool entry produces byte-identical leading ids (and
    therefore identical content keys — the store/prefix-cache hit path);
    later blocks hash ``<unique_tag>:<j>`` so tails never collide. When
    ``base`` is non-empty the chain continues from it instead (multi-turn
    history extension: ``base`` is the previous turn's full chain).
    """
    ids = list(base)
    prev = ids[-1] if ids else 0
    for j in range(len(ids), n_blocks):
        if j < n_prefix:
            tok = f"prefix:{prefix_id}:{j}".encode()
        else:
            tok = f"{unique_tag}:{j}".encode()
        prev = span_content_id(tok, prev)
        ids.append(prev)
    return tuple(ids)


def generate_trace(profile: TrafficProfile, n_requests: int,
                   *, seed: int = 0,
                   rng: Optional[np.random.Generator] = None
                   ) -> list[RequestSpec]:
    """Draw `n_requests` specs: arrival times + per-request mix."""
    rng = rng or np.random.default_rng(seed)
    arrivals = _shape_arrivals(profile,
                               _arrival_times(profile, n_requests, rng))
    wfq_weights = [w for w, _ in profile.weight_mix]
    wfq_p = np.array([v for _, v in profile.weight_mix], float)
    wfq_p /= wfq_p.sum()
    slo_p = None
    if profile.slo_mix:
        slo_p = np.array([e[-1] for e in profile.slo_mix], float)
        slo_p /= slo_p.sum()
    out_lens = [int(n) for n, _ in profile.out_len_mix]
    out_p = None
    if profile.out_len_mix:
        out_p = np.array([w for _, w in profile.out_len_mix], float)
        out_p /= out_p.sum()
    devices = [int(d) for d, _ in profile.device_mix]
    dev_p = None
    if profile.device_mix:
        assert all(0 <= d < max(profile.n_devices, 1) for d in devices), \
            f"device_mix entries out of range [0, {profile.n_devices})"
        dev_p = np.array([w for _, w in profile.device_mix], float)
        dev_p /= dev_p.sum()
    # reuse draws live on their own stream so arming prefix_pool never
    # shifts the base draw sequence (dataset/ctx/wfq/slo/out_len/device)
    reuse_rng = None
    zipf_p = None
    if profile.prefix_pool > 0:
        reuse_rng = np.random.default_rng(seed + REUSE_SEED_SALT)
        zipf_p = _zipf_pmf(profile.prefix_pool, profile.prefix_zipf_a)
    specs = []
    for i, t in enumerate(arrivals):
        ds_name = _weighted(profile.context_mix, rng)
        ds = DATASETS[ds_name]
        raw = ds.mean_len * np.exp(rng.normal(0.0, profile.context_jitter))
        raw = float(np.clip(raw, profile.min_context, profile.max_context))
        ctx = max(profile.chunk_tokens,
                  int(raw // profile.chunk_tokens) * profile.chunk_tokens)
        wfq_w = float(wfq_weights[rng.choice(len(wfq_weights), p=wfq_p)])
        slo_class, deadline, tpot_slo = "default", None, None
        if slo_p is not None:
            entry = profile.slo_mix[
                rng.choice(len(profile.slo_mix), p=slo_p)]
            if len(entry) == 4:          # (name, ttft, tpot, weight)
                slo_class, deadline, tpot_slo, _ = entry
            else:                        # legacy (name, ttft, weight)
                slo_class, deadline, _ = entry
        max_new = 0
        if out_p is not None:
            max_new = out_lens[rng.choice(len(out_lens), p=out_p)]
        dev = i % max(profile.n_devices, 1) if dev_p is None \
            else devices[rng.choice(len(devices), p=dev_p)]
        content_ids = None
        if reuse_rng is not None:
            n_blocks = max(ctx // profile.chunk_tokens, 1)
            n_prefix = min(int(round(profile.prefix_frac * n_blocks)),
                           n_blocks)
            pool_idx = int(reuse_rng.choice(profile.prefix_pool, p=zipf_p))
            content_ids = _content_chain(
                n_blocks, n_prefix, pool_idx, f"req:{seed}:{i}")
        specs.append(RequestSpec(
            arrival_s=float(t), context_len=ctx, dataset=ds_name,
            policy=_weighted(profile.policy_mix, rng), seed=seed + i,
            device=dev, weight=wfq_w,
            deadline_s=deadline, slo_class=slo_class,
            max_new_tokens=max_new, tpot_slo_s=tpot_slo,
            content_ids=content_ids))
    return specs


def session_trace(profile: TrafficProfile, n_sessions: int,
                  *, seed: int = 0) -> list[RequestSpec]:
    """Multi-turn chat sessions with cross-turn KV reuse.

    Each session pins one device (session affinity), opens with a
    context drawn like :func:`generate_trace`, and re-sends its whole
    history every turn: turn ``j``'s content chain is turn ``j-1``'s
    plus ``turn_growth_chunks`` fresh blocks, with exponential think
    time between turns. When ``prefix_pool > 0`` the opening turn's
    leading blocks come from the shared Zipf pool, so sessions also
    share cross-session prefixes; otherwise chains are session-unique
    (pure intra-session reuse). Specs carry ``session=<idx>`` so the
    report can group turns.
    """
    rng = np.random.default_rng(seed)
    reuse_rng = np.random.default_rng(seed + REUSE_SEED_SALT)
    zipf_p = (_zipf_pmf(profile.prefix_pool, profile.prefix_zipf_a)
              if profile.prefix_pool > 0 else None)
    starts = _arrival_times(profile, n_sessions, rng)
    turn_counts = [int(n) for n, _ in profile.session_turns_mix]
    turn_p = np.array([w for _, w in profile.session_turns_mix], float)
    turn_p /= turn_p.sum()
    max_blocks = max(profile.max_context // profile.chunk_tokens, 1)
    specs = []
    req_idx = 0
    for s, t0 in enumerate(starts):
        dev = s % max(profile.n_devices, 1)
        n_turns = turn_counts[rng.choice(len(turn_counts), p=turn_p)]
        ds_name = _weighted(profile.context_mix, rng)
        ds = DATASETS[ds_name]
        raw = ds.mean_len * np.exp(rng.normal(0.0, profile.context_jitter))
        raw = float(np.clip(raw, profile.min_context, profile.max_context))
        n_blocks = max(int(raw // profile.chunk_tokens), 1)
        n_prefix = 0
        pool_idx = 0
        if zipf_p is not None:
            n_prefix = min(int(round(profile.prefix_frac * n_blocks)),
                           n_blocks)
            pool_idx = int(reuse_rng.choice(profile.prefix_pool, p=zipf_p))
        ids: tuple = ()
        t = float(t0)
        for turn in range(n_turns):
            if turn > 0:
                t += float(rng.exponential(profile.think_time_s))
                n_blocks = min(n_blocks + profile.turn_growth_chunks,
                               max_blocks)
            ids = _content_chain(
                n_blocks, n_prefix, pool_idx,
                f"sess:{seed}:{s}:t{turn}", base=ids)
            specs.append(RequestSpec(
                arrival_s=t,
                context_len=n_blocks * profile.chunk_tokens,
                dataset=ds_name,
                policy=_weighted(profile.policy_mix, rng),
                seed=seed + req_idx, device=dev,
                content_ids=ids, session=s))
            req_idx += 1
    specs.sort(key=lambda sp: sp.arrival_s)
    return specs


def poisson_trace(n_requests: int, rate_rps: float, *,
                  policy: str = "sparkv", dataset: str = "longchat",
                  max_context: int = 8192, seed: int = 0
                  ) -> list[RequestSpec]:
    """Shorthand: homogeneous Poisson trace with a single policy."""
    prof = TrafficProfile(rate_rps=rate_rps, arrival="poisson",
                          context_mix=((dataset, 1.0),),
                          policy_mix=((policy, 1.0),),
                          max_context=max_context)
    return generate_trace(prof, n_requests, seed=seed)
