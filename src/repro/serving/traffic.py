"""Fleet traffic generator: arrival processes + request mixes.

Produces ``RequestSpec`` lists for ``repro.serving.cluster``:

  - **poisson**: memoryless arrivals at ``rate_rps`` (the open-loop
    baseline for p50/p99 TTFT under load);
  - **bursty**: a two-state Markov-modulated Poisson process — an "on"
    state multiplies the base rate by ``burst_factor`` (flash crowds /
    synchronized app wakeups), "off" drops to the base rate;
  - **uniform**: deterministic equal spacing (useful for regression
    tests where arrival jitter is noise).

Request mixes draw context lengths per dataset profile (rounded to whole
chunks) and policies from a weighted table, so one trace can interleave
sparkv / strong_hybrid / local_prefill requests the way a real fleet
mixes device capabilities. For the resource-server cluster, traces can
also spread requests over ``n_devices`` (round-robin, or weighted via
``device_mix`` for asymmetric-NIC fleets — the NIC/uplink/egress link
tree routes per device, and the cluster's ``ap_of_device`` assigns each
device to its access point) and draw per-request WFQ weights from
``weight_mix`` (interactive vs. background service classes).

SLO classes: ``slo_mix`` draws a named service class per request, each
carrying a TTFT deadline (or ``None`` for best-effort) — e.g. a 70/30
interactive/batch split where only interactive requests have deadlines.
4-tuple entries add a per-token TPOT SLO for the decode phase. The
cluster's SLO admission layer (``repro.serving.slo``) consumes the
deadlines; the class name is the reporting bucket for per-class
attainment in the ``FleetReport``.

Decode: ``out_len_mix`` draws a response length per request (chat
replies vs. long generations), setting ``RequestSpec.max_new_tokens`` so
the fleet's continuous decode batches carry a realistic length mix; an
empty mix keeps every spec first-token-only.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np

from repro.data.workloads import DATASETS
from repro.serving.cluster import RequestSpec


@dataclasses.dataclass(frozen=True)
class TrafficProfile:
    rate_rps: float = 0.5
    arrival: str = "poisson"            # poisson | bursty | uniform
    # bursty (MMPP) knobs
    burst_factor: float = 6.0           # rate multiplier while "on"
    mean_on_s: float = 4.0
    mean_off_s: float = 12.0
    # request mix
    context_mix: tuple = (("longchat", 1.0),)     # (dataset, weight)
    policy_mix: tuple = (("sparkv", 1.0),)        # (policy, weight)
    context_jitter: float = 0.25        # lognormal sigma on dataset mean_len
    min_context: int = 2048
    max_context: int = 16384
    chunk_tokens: int = 1024
    # resource-server routing
    n_devices: int = 1                  # round-robin device assignment
    # (device, draw weight) — overrides round-robin when non-empty, so
    # asymmetric-NIC fleets can skew load toward fast-NIC devices (the
    # cluster's ap_of_device then maps each device to its access point)
    device_mix: tuple = ()
    weight_mix: tuple = ((1.0, 1.0),)   # (wfq weight, draw weight)
    # SLO classes: (class name, ttft deadline_s | None, draw weight) or
    # (class name, ttft deadline_s | None, tpot_slo_s | None, draw weight)
    slo_mix: tuple = ()                 # empty = no deadlines
    # decode: response-length classes (n output tokens, draw weight);
    # empty = first-token-only fleets (max_new_tokens 0 on every spec)
    out_len_mix: tuple = ()


def _arrival_times(profile: TrafficProfile, n: int,
                   rng: np.random.Generator) -> np.ndarray:
    if profile.arrival == "uniform":
        return np.arange(n) / max(profile.rate_rps, 1e-9)
    if profile.arrival == "poisson":
        gaps = rng.exponential(1.0 / profile.rate_rps, n)
        gaps[0] = 0.0
        return np.cumsum(gaps)
    if profile.arrival == "bursty":
        # two-state MMPP, exponential sojourn in each state
        times = np.empty(n)
        t, state_end, on = 0.0, rng.exponential(profile.mean_off_s), False
        for i in range(n):
            rate = profile.rate_rps * (profile.burst_factor if on else 1.0)
            t += rng.exponential(1.0 / rate) if i else 0.0
            while t > state_end:
                on = not on
                state_end += rng.exponential(
                    profile.mean_on_s if on else profile.mean_off_s)
            times[i] = t
        return times
    raise ValueError(f"unknown arrival process {profile.arrival!r}")


def _weighted(table: tuple, rng: np.random.Generator) -> str:
    names = [k for k, _ in table]
    w = np.array([v for _, v in table], float)
    return names[rng.choice(len(names), p=w / w.sum())]


def generate_trace(profile: TrafficProfile, n_requests: int,
                   *, seed: int = 0,
                   rng: Optional[np.random.Generator] = None
                   ) -> list[RequestSpec]:
    """Draw `n_requests` specs: arrival times + per-request mix."""
    rng = rng or np.random.default_rng(seed)
    arrivals = _arrival_times(profile, n_requests, rng)
    wfq_weights = [w for w, _ in profile.weight_mix]
    wfq_p = np.array([v for _, v in profile.weight_mix], float)
    wfq_p /= wfq_p.sum()
    slo_p = None
    if profile.slo_mix:
        slo_p = np.array([e[-1] for e in profile.slo_mix], float)
        slo_p /= slo_p.sum()
    out_lens = [int(n) for n, _ in profile.out_len_mix]
    out_p = None
    if profile.out_len_mix:
        out_p = np.array([w for _, w in profile.out_len_mix], float)
        out_p /= out_p.sum()
    devices = [int(d) for d, _ in profile.device_mix]
    dev_p = None
    if profile.device_mix:
        assert all(0 <= d < max(profile.n_devices, 1) for d in devices), \
            f"device_mix entries out of range [0, {profile.n_devices})"
        dev_p = np.array([w for _, w in profile.device_mix], float)
        dev_p /= dev_p.sum()
    specs = []
    for i, t in enumerate(arrivals):
        ds_name = _weighted(profile.context_mix, rng)
        ds = DATASETS[ds_name]
        raw = ds.mean_len * np.exp(rng.normal(0.0, profile.context_jitter))
        raw = float(np.clip(raw, profile.min_context, profile.max_context))
        ctx = max(profile.chunk_tokens,
                  int(raw // profile.chunk_tokens) * profile.chunk_tokens)
        wfq_w = float(wfq_weights[rng.choice(len(wfq_weights), p=wfq_p)])
        slo_class, deadline, tpot_slo = "default", None, None
        if slo_p is not None:
            entry = profile.slo_mix[
                rng.choice(len(profile.slo_mix), p=slo_p)]
            if len(entry) == 4:          # (name, ttft, tpot, weight)
                slo_class, deadline, tpot_slo, _ = entry
            else:                        # legacy (name, ttft, weight)
                slo_class, deadline, _ = entry
        max_new = 0
        if out_p is not None:
            max_new = out_lens[rng.choice(len(out_lens), p=out_p)]
        dev = i % max(profile.n_devices, 1) if dev_p is None \
            else devices[rng.choice(len(devices), p=dev_p)]
        specs.append(RequestSpec(
            arrival_s=float(t), context_len=ctx, dataset=ds_name,
            policy=_weighted(profile.policy_mix, rng), seed=seed + i,
            device=dev, weight=wfq_w,
            deadline_s=deadline, slo_class=slo_class,
            max_new_tokens=max_new, tpot_slo_s=tpot_slo))
    return specs


def poisson_trace(n_requests: int, rate_rps: float, *,
                  policy: str = "sparkv", dataset: str = "longchat",
                  max_context: int = 8192, seed: int = 0
                  ) -> list[RequestSpec]:
    """Shorthand: homogeneous Poisson trace with a single policy."""
    prof = TrafficProfile(rate_rps=rate_rps, arrival="poisson",
                          context_mix=((dataset, 1.0),),
                          policy_mix=((policy, 1.0),),
                          max_context=max_context)
    return generate_trace(prof, n_requests, seed=seed)
