"""Continuous batched decoding for the serving fleet.

The pre-decode fleet dropped every request the moment its KV context was
assembled ("first-token-only" accounting): goodput counted a response as
done at TTFT and the decode tail never touched the device. This module
models the decode phase as a **per-device continuous batch**:

  - after a request's context is assembled, its engine session yields
    :class:`repro.core.engine.DecodeStart` and the cluster enrols it in
    the device's :class:`DecodeBatcher`;
  - the batcher runs **dispatches** — batched decode steps over the
    co-resident sequences (one token per member per step, step cost from
    :func:`repro.core.engine.decode_step_seconds`: KV reads sum over the
    batch, weight reads amortize once per step);
  - membership changes only at token boundaries (continuous batching):
    joiners wait for the in-flight dispatch to retire, members leave the
    moment their token quota completes, capacity is ``max_batch``;
  - each dispatch is one *job* on the device: in run-queue mode the
    cluster submits it to the :class:`repro.serving.resources.
    DeviceRunQueue`, so decode steps genuinely contend with in-flight
    prefill chunks under the FIFO/WFQ/SRPT discipline (the
    ``tokens_per_dispatch`` knob trades decode/prefill interleaving
    granularity against per-job overhead — 1 yields the device to
    queued prefill work at every token boundary).

The batcher is deterministic and clock-free: it *plans* dispatches
(durations + per-member token offsets relative to service start) and the
cluster owns actual start times (immediate or queued).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.core.engine import decode_step_seconds


@dataclasses.dataclass(frozen=True)
class DecodeConfig:
    """Continuous-batching knobs for the per-device decode batch.

    Parameters
    ----------
    max_batch : co-resident sequences per device batch; joiners beyond it
        wait at token boundaries for a slot.
    tokens_per_dispatch : tokens generated per run-queue job (the
        chunked-prefill interleave knob): 1 = finest interleave with
        queued prefill chunks, larger values let decode hold the device
        for several token steps per dispatch.
    weight : WFQ weight of the device's decode flow when dispatches run
        through a weighted run queue.
    """
    max_batch: int = 8
    tokens_per_dispatch: int = 1
    weight: float = 1.0

    def __post_init__(self):
        assert self.max_batch >= 1, self.max_batch
        assert self.tokens_per_dispatch >= 1, self.tokens_per_dispatch
        assert self.weight > 0, self.weight


@dataclasses.dataclass
class _Member:
    rid: int
    context_len: int                  # KV length the next token reads
    remaining: int                    # tokens still owed
    deadline_s: Optional[float] = None   # absolute TTFT deadline (EDF floor)


@dataclasses.dataclass
class Dispatch:
    """One planned batched-decode job: ``duration_s`` of device service
    delivering ``token_offsets[rid]`` (offsets from service start) to each
    member. ``finished`` lists members whose quota completes with this
    dispatch; ``busy_share`` splits the device-busy time across the
    co-resident members for per-request energy accounting."""
    seq: int
    duration_s: float
    token_offsets: dict               # rid -> tuple[float, ...]
    busy_share: dict                  # rid -> seconds
    finished: tuple                   # rids leaving at this boundary
    batch_size: int


class DecodeBatcher:
    """Per-device continuous decode batch (see module docstring).

    Protocol with the cluster::

        enroll(rid, context_len, n_tokens)     # DecodeStart arrived
        d = next_dispatch()                    # plan a job (or None)
        ... cluster serves d.duration_s on the device ...
        d = dispatch_done()                    # retire, promote joiners

    ``next_dispatch`` commits the planned tokens to member state, so
    exactly one dispatch is in flight per device at a time.
    """

    def __init__(self, cfg_model, profile, dcfg: DecodeConfig):
        self.cfg = cfg_model
        self.profile = profile
        self.dcfg = dcfg
        self.active: dict[int, _Member] = {}
        self.waiting: deque[_Member] = deque()
        self.inflight: Optional[Dispatch] = None
        # rids whose KV was evicted by the memory server: they keep their
        # batch slot (continuous-batching membership is the contract) but
        # are excluded from dispatches until the cluster resumes them
        # after the reload lands
        self.suspended: set = set()
        self._seq = 0
        self.tokens_dispatched = 0
        self.busy_s = 0.0

    # ---- telemetry ----
    def occupancy(self) -> int:
        """Sequences decoding or waiting to join (admission telemetry:
        the batch size a newcomer should expect to share a step with)."""
        return len(self.active) + len(self.waiting)

    def idle(self) -> bool:
        return self.inflight is None and not self.active and not self.waiting

    def remaining_service_s(self) -> float:
        """Estimated decode service left on this device (drives the run
        queue's SRPT ordering): steps to drain the longest member at the
        current batch composition's step cost."""
        members = [*self.active.values(), *self.waiting]
        if not members:
            return 0.0
        steps_left = max(m.remaining for m in members)
        lens = [m.context_len for m in members[:self.dcfg.max_batch]]
        return steps_left * decode_step_seconds(self.cfg, lens or [1],
                                                self.profile)

    def min_deadline(self) -> Optional[float]:
        """Earliest member deadline (arms the SRPT queue's EDF floor for
        the decode flow)."""
        ds = [m.deadline_s for m in self.active.values()
              if m.deadline_s is not None]
        return min(ds) if ds else None

    # ---- KV eviction protocol (memory server) ----
    def suspend(self, rid: int) -> None:
        """Exclude an enrolled member from future dispatches (its KV was
        demoted/dropped); it keeps its batch slot until resumed."""
        self.suspended.add(rid)

    def resume(self, rid: int) -> None:
        """Reload landed: the member decodes again from the next
        dispatch boundary."""
        self.suspended.discard(rid)

    def suspended_active(self) -> list[int]:
        """Suspended members currently holding a batch slot — the rids
        whose KV must be reloaded for the batch to make progress (the
        cluster starts a reload for each before planning a dispatch)."""
        return sorted(r for r in self.active if r in self.suspended)

    # ---- protocol ----
    def enroll(self, rid: int, context_len: int, n_tokens: int, *,
               deadline_s: Optional[float] = None) -> None:
        assert n_tokens >= 1, n_tokens
        assert rid not in self.active, f"rid {rid} already decoding"
        m = _Member(rid=rid, context_len=context_len, remaining=n_tokens,
                    deadline_s=deadline_s)
        if self.inflight is None and len(self.active) < self.dcfg.max_batch:
            self.active[rid] = m
        else:
            # token-boundary join: wait for the in-flight dispatch (or a
            # free batch slot) — continuous batching, not stop-the-world
            self.waiting.append(m)

    def next_dispatch(self) -> Optional[Dispatch]:
        """Plan the next batched job; None when a dispatch is already in
        flight or nothing is decoding. Token counts/lengths are committed
        here (membership is frozen for the dispatch)."""
        if self.inflight is not None or not self.active:
            return None
        live = [self.active[r] for r in sorted(self.active)
                if r not in self.suspended]
        if not live:
            return None               # every slot-holder awaits a reload
        offs: dict[int, list] = {m.rid: [] for m in live}
        busy = {m.rid: 0.0 for m in live}
        t = 0.0
        for _ in range(self.dcfg.tokens_per_dispatch):
            if not live:
                break
            lens = [m.context_len for m in live]
            dt = decode_step_seconds(self.cfg, lens, self.profile)
            t += dt
            share = dt / len(live)
            for m in live:
                offs[m.rid].append(t)
                busy[m.rid] += share
                m.context_len += 1
                m.remaining -= 1
                self.tokens_dispatched += 1
            live = [m for m in live if m.remaining > 0]
        d = Dispatch(seq=self._seq, duration_s=t,
                     token_offsets={r: tuple(v) for r, v in offs.items()},
                     busy_share=busy,
                     # offs iterates in rid order (live is rid-sorted)
                     finished=tuple(r for r in offs
                                    if self.active[r].remaining == 0),
                     batch_size=len(offs))
        self._seq += 1
        self.busy_s += t
        self.inflight = d
        return d

    def dispatch_done(self) -> Dispatch:
        """Retire the in-flight dispatch at its completion boundary: drop
        finished members, promote waiting joiners into free batch slots,
        and return the dispatch for token delivery."""
        d = self.inflight
        assert d is not None, "no dispatch in flight"
        self.inflight = None
        for rid in d.finished:
            del self.active[rid]
        while self.waiting and len(self.active) < self.dcfg.max_batch:
            m = self.waiting.popleft()
            self.active[m.rid] = m
        return d
