"""Multi-request serving cluster on explicit resource servers.

The single-request engine (`repro.core.engine.HybridEngine.run`) models a
device that owns the whole NIC and sees contention only as a static `util`
scalar. This module runs **N concurrent context loads** against shared
resource *servers* (``repro.serving.resources``) on one discrete-event
clock:

  - **link servers** — a :class:`LinkTopology` drains each request's
    transfers through its path of fair-shared stages. The default is the
    single shared uplink (PR 1's :class:`SharedLinkArbiter`, now the
    degenerate one-stage topology); with ``n_devices > 1`` and a ``nic``
    profile the topology is the paper's Fig. 13 shape — per-device NIC
    stages feeding one congested AP uplink, the bottleneck stage governing
    each flow's rate. ``nic`` may be a per-device sequence (asymmetric
    NIC fleets), and with ``n_aps > 1`` / an ``egress`` profile the tree
    deepens to the full three-hop cloud path: NICs -> per-AP uplinks ->
    one cloud-egress stage shared by *all* APs
    (``resources.tree_topology``).
  - **device servers** — compute contention has two modes. Legacy
    closed-loop: in-flight compute dilates everyone's service time
    (``util = n_other_computing / capacity`` into
    ``GroundTruthLatency.attn_seconds``). Run-queue mode (pass a
    ``repro.core.costs.RunQueueModel``): chunks are admitted to an
    explicit per-device :class:`DeviceRunQueue` (FIFO or WFQ) and *wait*
    when the ``capacity`` service slots are busy — attn_seconds no longer
    consumes a fleet-contention util; queueing delay is the contention.
    The engine observes admission through the session protocol's
    :class:`StartAck` and reports per-request queue waits.
  - **telemetry** — the latency predictor's U feature at admission comes
    from the live device server (queue occupancy via
    ``predictor.queue_utilization`` in run-queue mode, in-flight compute
    in closed-loop mode); the runtime controller additionally receives
    per-chunk queue waits and folds them into migration decisions.
  - **admission queue** — at most ``max_concurrency`` requests are in
    service; arrivals beyond that wait FIFO. Per-request policy comes
    from the :class:`RequestSpec`, or from a ``policy_fn`` override at
    admission — :func:`telemetry_policy` is the default telemetry-driven
    chooser (sparkv vs. local_prefill from live link share and queue
    depth).
  - **SLO admission** — with an ``repro.serving.slo.SLOPolicy`` and
    per-request TTFT deadlines, admission projects each request's TTFT
    against the live servers; predicted violations are downgraded to
    coarser stream quantization (the bitrate ladder) or shed, deadline
    slack selects the WFQ weight class, and near-deadline flows are
    guarded against migration onto congested links. Attainment,
    shed/downgrade counts, and goodput-under-SLO land in the
    :class:`FleetReport`.
  - **continuous batched decode** — a request with
    ``RequestSpec.max_new_tokens > 0`` does not end at its first token:
    once its context is assembled it joins the device's
    :class:`repro.serving.decode.DecodeBatcher` (join/leave at token
    boundaries, ``DecodeConfig.max_batch`` co-resident sequences) and
    batched decode *dispatches* flow through the same device run queue
    as prefill chunks — decode and prefill genuinely contend for device
    time under the FIFO/WFQ/SRPT discipline. Per-request token
    timelines yield TPOT/TTLT, the :class:`FleetReport` gains tokens/s
    and full-response goodput, and energy covers the decode tail.
    Requests with ``max_new_tokens == 0`` keep first-token-only
    accounting, bit-identical to the pre-decode fleet.

Protocol with the engine: each admitted request holds an
``HybridEngine.session`` generator; the cluster resumes a session only at
that request's own completion events. Sessions yield ``StreamStart`` /
``ComputeStart`` requests which the cluster maps onto the link topology
and the device servers, acknowledging compute admissions with
``StartAck`` (immediate or queued). See ``repro.core.engine``.

Fleet metrics: p50/p99 TTFT (arrival -> first token), goodput, energy per
request, migrations, and per-request queue-wait / uplink-share breakdowns.

Typical use::

    specs = poisson_trace(...)                      # repro.serving.traffic
    cluster = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi",
                             run_queue=RunQueueModel(2, "wfq"))
    report = cluster.run(specs)
    print(report.summary())
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

import numpy as np

from repro.core import baselines as B
from repro.core.chunks import Chunk, ChunkGrid, chunk_content_key
from repro.core.costs import (GroundTruthLatency, KVStoreModel, MemoryModel,
                              NetworkProfile, PROFILES,
                              NETWORKS, RunQueueModel, SharedLinkModel,
                              chunk_bytes_at_bits)
from repro.core.engine import (BandwidthIntegrator, Completion, ComputeStart,
                               DecodeDone, DecodeStart, DecodeTick,
                               HybridEngine, StartAck, StoreHit, StreamLost,
                               StreamStart, Wait, context_kv_bytes,
                               token_kv_bytes)
from repro.core.predictor import (LatencyPredictor, backlog_delay_s,
                                  queue_utilization)
from repro.data.workloads import DATASETS, WorkloadChunks, synthesize
from repro.serving.decode import DecodeBatcher, DecodeConfig
from repro.serving.kvstore import CloudKVStore, DevicePrefixCache
from repro.serving.memory import (KVMemoryServer, RELOAD_FLOW_BASE,
                                  plan_reload)
from repro.serving.resources import (DeviceRunQueue, LinkStage, LinkTopology,
                                     ScalarLinkTopology, single_link,
                                     tree_path, tree_topology,
                                     uplink_stage_name)
from repro.serving.scenarios import (FleetState, FleetRebalancer,
                                     ScenarioTrace, apply_outages)
from repro.serving.simcore import STATS as SIM_STATS
from repro.serving.simcore import EventKind, EventQueue
from repro.serving.slo import (SLOPolicy, decide_admission,
                               plan_compute_seconds)


# ---------------------------------------------------------------------------
# Shared-link bandwidth arbiter (degenerate one-stage topology)
# ---------------------------------------------------------------------------


class SharedLinkArbiter(LinkTopology):
    """Fair-share scheduler over one cumulative-bandwidth trace — PR 1's
    arbiter, now the single-stage case of :class:`LinkTopology`: active
    flows split the instantaneous capacity equally, scaled by the link
    model's aggregate efficiency ``eta(n)``. Kept as a named class for
    callers that want exactly one shared hop."""

    def __init__(self, integrator: BandwidthIntegrator,
                 link: Optional[SharedLinkModel] = None):
        super().__init__({"uplink": LinkStage("uplink", integrator, link)},
                         default_path=("uplink",))
        self.bw = integrator
        self.link = link


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestSpec:
    """One job for the cluster: when it arrives, what it loads, and the
    service class it belongs to (WFQ weight / TTFT deadline)."""
    arrival_s: float
    context_len: int = 8192
    dataset: str = "longchat"
    policy: str = "sparkv"
    seed: int = 0
    wl: Optional[WorkloadChunks] = None     # overrides synthesis if given
    device: int = 0                         # which device serves it
    weight: float = 1.0                     # WFQ share of device time
    deadline_s: Optional[float] = None      # TTFT SLO, relative to arrival
    slo_class: str = "default"              # reporting bucket for SLO stats
    max_new_tokens: int = 0                 # 0 = first-token-only (legacy)
    tpot_slo_s: Optional[float] = None      # per-token latency SLO (decode)
    # cross-request KV reuse: prefix-closed span content ids, one per
    # token block (repro.core.chunks.span_content_id chains); None keeps
    # the request anonymous — no lookups, no caching, bit-identical
    content_ids: Optional[tuple] = None
    session: Optional[int] = None           # multi-turn session identity


@dataclasses.dataclass
class RequestRecord:
    """Per-request outcome row of a :class:`FleetReport`: identity and
    policy, the TTFT decomposition (admission queue, device queue, link
    share), energy/quality, and the SLO verdict (deadline, whether it was
    met, and any admission-time quantization downgrade)."""
    rid: int
    spec: RequestSpec
    policy: str
    admit_s: float
    context_done_s: float                   # all chunks assembled
    done_s: float                           # context assembled + first token
    ttft_s: float                           # done_s - arrival_s (incl. queue)
    queue_s: float                          # admission-queue wait
    energy_j: float
    quality: float
    n_streamed: int
    n_computed: int
    n_migrations: int
    stream_busy_s: float
    compute_busy_s: float
    bytes_streamed: float
    compute_wait_s: float = 0.0             # device run-queue wait (total)
    n_compute_queued: int = 0
    uplink_share: float = 1.0               # mean uplink fraction received
    # SLO verdict (None deadline = no SLO applied to this request)
    slo_class: str = "default"
    deadline_s: Optional[float] = None
    slo_met: Optional[bool] = None
    quant_bits: int = 0                     # effective stream quant bits
    downgraded: bool = False                # admission walked the ladder
    # decode phase (first-token-only accounting when max_new_tokens == 0:
    # one token, ttlt == ttft, no inter-token time)
    n_tokens_out: int = 1
    ttlt_s: float = 0.0                     # last token - arrival
    tpot_s: float = 0.0                     # mean inter-token time
    tpot_slo_s: Optional[float] = None
    # mean share received on every stage of the flow's path (NIC, AP
    # uplink, cloud egress) — the per-stage breakdown behind uplink_share
    stage_shares: dict = dataclasses.field(default_factory=dict)
    # KV memory server outcome (zeros without an armed memory server —
    # defaults keep pre-memory records bit-identical)
    reload_s: float = 0.0                   # total decode stall on reloads
    n_evictions: int = 0                    # times this KV was demoted/dropped
    n_reloads: int = 0                      # reloads completed
    kv_bits: int = 0                        # final resident bits (0=untracked)
    # cross-request KV reuse outcome (zeros without a reuse layer)
    n_local_hits: int = 0                   # chunks satisfied on-device
    n_store_hits: int = 0                   # chunks served as store hits
    bytes_hit_stream: float = 0.0           # streamed bytes off the egress


@dataclasses.dataclass
class ShedRecord:
    """A request rejected at admission: its predicted TTFT violated the
    deadline even at the coarsest quantization ladder level, or its
    predicted per-token latency violated the TPOT SLO (``reason``)."""
    rid: int
    spec: RequestSpec
    t_shed_s: float                         # when admission rejected it
    pred_ttft_s: float                      # the TTFT prediction
    reason: str = "ttft"                    # which SLO leg shed ("tpot"?)
    pred_tpot_s: Optional[float] = None     # the violating TPOT prediction


@dataclasses.dataclass
class _ActiveRequest:
    rid: int
    spec: RequestSpec
    plan: B.RequestPlan
    gen: object                             # engine session generator
    admit_s: float
    # in-flight stream bookkeeping (one per request at a time)
    stream_chunk: Optional[Chunk] = None
    stream_t0: float = 0.0
    stream_t_proc: float = 0.0
    stream_nbytes: float = 0.0
    # hostile-world bookkeeping: the chunk computing on the device (churn
    # cancellation), whether decode started (churn spares decoders), and
    # the context bytes still to assemble (rebalancer demand signal)
    comp_chunk: Optional[Chunk] = None
    decoding: bool = False
    bytes_left: float = 0.0
    # SLO / scheduling state
    weight: float = 1.0                     # effective WFQ weight
    deadline_abs: Optional[float] = None    # arrival + deadline_s
    comp_total_s: float = 0.0               # planned compute seconds
    comp_done_s: float = 0.0                # attained compute service
    downgraded: bool = False
    pred_ttft_s: Optional[float] = None
    # admission-time contention snapshot (predictor refresh features)
    obs_load: int = 0
    obs_backlog_s: float = 0.0
    obs_n_flows: int = 0
    # KV memory server state (memory-armed clusters only)
    kv_chunk_bytes: float = 0.0             # resident KV per prefill chunk
    reload_s: float = 0.0
    n_evictions: int = 0
    n_reloads: int = 0
    # cross-request reuse: Chunk -> 64-bit content key (empty when the
    # request is anonymous or the store is unarmed)
    key_of: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class FleetReport:
    """Fleet-level outcome of one :meth:`ServingCluster.run`: per-request
    records, requests shed at admission, and aggregate summary metrics
    (tail TTFT, goodput, energy, queue/link breakdowns, SLO attainment)."""
    records: list[RequestRecord]
    makespan_s: float
    n_arrived: int
    shed: list = dataclasses.field(default_factory=list)
    # fleet-aggregated KV memory-server telemetry (None when the cluster
    # ran without one — summary() then omits the memory block entirely,
    # keeping pre-memory summaries bit-identical)
    memory: Optional[dict] = None
    # cross-request reuse telemetry (None without an armed kvstore — the
    # summary() block is then absent, keeping no-reuse summaries
    # bit-identical)
    reuse: Optional[dict] = None
    # hostile-world scenario telemetry (None when the run had no armed
    # ScenarioTrace — static-fleet summaries stay bit-identical)
    scenario: Optional[dict] = None

    def ttfts(self) -> np.ndarray:
        return np.array([r.ttft_s for r in self.records])

    def summary(self) -> dict:
        t = self.ttfts()
        done = len(self.records)
        nan = float("nan")

        def pct(vals, q):
            return float(np.percentile(np.asarray(vals), q)) if done else nan

        waits = [r.compute_wait_s for r in self.records]
        shares = [r.uplink_share for r in self.records]
        return {
            "n_done": done,
            "ttft_p50_s": pct(t, 50),
            "ttft_p99_s": pct(t, 99),
            "ttft_mean_s": float(t.mean()) if done else nan,
            "goodput_rps": done / self.makespan_s if self.makespan_s else 0.0,
            "energy_per_req_j": float(np.mean([r.energy_j
                                               for r in self.records]))
            if done else nan,
            "migrations_total": sum(r.n_migrations for r in self.records),
            "stream_busy_total_s": sum(r.stream_busy_s
                                       for r in self.records),
            "queue_mean_s": float(np.mean([r.queue_s for r in self.records]))
            if done else nan,
            # device run-queue wait + uplink share breakdowns (per request)
            "queue_wait_p50_s": pct(waits, 50),
            "queue_wait_p99_s": pct(waits, 99),
            "queue_wait_mean_s": float(np.mean(waits)) if done else nan,
            "uplink_share_p50": pct(shares, 50),
            "uplink_share_p99": pct(shares, 99),
            **self._decode_summary(),
            **self._slo_summary(),
            **self._memory_summary(),
            **self._reuse_summary(),
            **self._scenario_summary(),
        }

    def _scenario_summary(self) -> dict:
        """Hostile-world block of :meth:`summary` — present only when
        the run armed a :class:`~repro.serving.scenarios.ScenarioTrace`
        (handoff/loss/churn/outage/rebalance counters from the run)."""
        if self.scenario is None:
            return {}
        return dict(self.scenario)

    def _reuse_summary(self) -> dict:
        """Cross-request reuse block of :meth:`summary` — present only
        when the cluster ran a :class:`CloudKVStore`.
        ``egress_bytes_total`` is the streamed bytes that actually
        crossed the cloud origin (store hits served from the edge
        replica are excluded) — the wide-area cost reuse exists to cut;
        ``store_hit_rate`` is over every content-key lookup the store
        answered."""
        if self.reuse is None:
            return {}
        store = self.reuse["store"]
        return {
            "store_hit_rate": store["hit_rate"],
            "store_evictions": store["n_evictions"],
            "local_hits_total": self.reuse["local_hits_total"],
            "store_hits_total": self.reuse["store_hits_total"],
            "egress_bytes_total": self.reuse["egress_bytes_total"],
            "bytes_hit_stream_total": self.reuse["bytes_hit_stream_total"],
        }

    def _decode_summary(self) -> dict:
        """Decode-aware goodput block of :meth:`summary`.

        ``goodput_tok_s`` counts every delivered token over the makespan
        (first-token-only fleets deliver exactly one token per request —
        the accounting fiction the decode phase replaces);
        ``goodput_resp_s`` counts completed *full responses* per second
        (== ``goodput_rps``, but over a makespan that now includes the
        decode tail when decoding is on). TPOT stats cover requests that
        actually decoded (> 1 token); ``None`` (not NaN, which would
        poison ``==`` parity checks) when nothing decoded."""
        toks = sum(r.n_tokens_out for r in self.records)
        tpots = [r.tpot_s for r in self.records if r.n_tokens_out > 1]
        ttlts = [r.ttlt_s for r in self.records]

        def pct(vals, q):
            return float(np.percentile(np.asarray(vals), q)) if vals else None

        return {
            "tokens_out_total": toks,
            "goodput_tok_s": toks / self.makespan_s
            if self.makespan_s else 0.0,
            "goodput_resp_s": len(self.records) / self.makespan_s
            if self.makespan_s else 0.0,
            "tpot_p50_s": pct(tpots, 50),
            "tpot_p99_s": pct(tpots, 99),
            "ttlt_p99_s": pct(ttlts, 99),
        }

    def _memory_summary(self) -> dict:
        """KV memory block of :meth:`summary` — present only when the
        cluster ran a memory server (``self.memory`` aggregated across
        devices at end of run), so memory-less summaries stay
        bit-identical to pre-memory fleets. Peak/p99 resident bytes are
        the fleet-wide maxima; eviction/reload counters sum devices; the
        request-level stall totals come from the records (so SLO misses
        caused by reload stalls are attributable)."""
        if self.memory is None:
            return {}
        return {
            "peak_resident_bytes": self.memory["peak_resident_bytes"],
            "resident_p99_bytes": self.memory["resident_p99_bytes"],
            "n_evictions": self.memory["n_evictions"],
            "n_downgrades": self.memory["n_downgrades"],
            "n_reloads": self.memory["n_reloads"],
            "reload_s_total": sum(r.reload_s for r in self.records),
            "reload_p99_s": float(np.percentile(
                [r.reload_s for r in self.records], 99))
            if self.records else None,
        }

    def _slo_summary(self) -> dict:
        """SLO attainment / shedding block of :meth:`summary`.

        ``slo_attainment`` is over *served* requests that carried a
        deadline (None when the trace had none) — the contract the
        admission layer offers for work it accepts.
        ``slo_attainment_arrived`` divides by every deadline request
        that *arrived* (shed ones count as misses), so an admission
        policy cannot inflate the headline number by shedding
        aggressively; compare the two to see how much attainment is
        scheduling gain vs. admission selectivity. ``goodput_slo_rps``
        counts only requests that met their deadline (deadline-less
        requests always count) — the throughput the fleet delivered
        within contract."""
        dl = [r for r in self.records if r.slo_met is not None]
        met = [r for r in dl if r.slo_met]
        n_dl_shed = sum(1 for s in self.shed
                        if s.spec.deadline_s is not None
                        or s.spec.tpot_slo_s is not None)
        by_class: dict = {}
        for r in dl:
            by_class.setdefault(r.slo_class, []).append(r)
        useful = len(self.records) - len(dl) + len(met)
        return {
            "slo_attainment": len(met) / len(dl) if dl else None,
            "slo_attainment_arrived": len(met) / (len(dl) + n_dl_shed)
            if dl or n_dl_shed else None,
            "slo_attainment_by_class": {
                k: sum(r.slo_met for r in v) / len(v)
                for k, v in sorted(by_class.items())},
            "n_shed": len(self.shed),
            "n_downgraded": sum(r.downgraded for r in self.records),
            "goodput_slo_rps": useful / self.makespan_s
            if self.makespan_s else 0.0,
        }


# ---------------------------------------------------------------------------
# Telemetry-driven admission policy
# ---------------------------------------------------------------------------


def telemetry_policy(spec: RequestSpec, cluster: "ServingCluster",
                     *, bw_floor_frac: float = 0.4,
                     decode_busy_frac: float = 1.0,
                     memory_ceiling: float = 0.9,
                     full_set: bool = False,
                     cachegen_floor_frac: float = 0.15) -> str:
    """Default ``policy_fn``: pick sparkv vs. local_prefill from the live
    resource servers at admission time.

    The hybrid planner's advantage evaporates when its streaming path is
    a fiction: if the projected per-flow share across the shared stages
    of this device's path (its AP uplink, and the cloud egress when the
    topology has one — profiled stage mean x fair-share fraction with
    this flow added, the bottleneck stage governing) falls below
    ``bw_floor_frac`` of the exclusive-link bandwidth *and* the device
    server still has slack for this request's compute, loading locally
    dominates. Otherwise run the sparkv planner, which keeps migrating
    at runtime anyway.

    Two further live signals veto the local-prefill switch (both
    inactive on clusters without decode batches / a memory server, so
    the pre-decode behaviour is unchanged):

      - **decode occupancy** — a device whose decode batch is at or past
        ``decode_busy_frac`` of ``max_batch`` has no compute slack the
        run-queue load can see (decode dispatches are one job however
        many sequences they carry), so forcing a full local prefill onto
        it starves token generation;
      - **memory pressure** — local prefill assembles the *whole*
        context resident with no partial-stream escape hatch; above
        ``memory_ceiling`` of the device's KV budget the stream path is
        preferable since evictions would immediately claw back whatever
        compute time local prefill saved.

    ``full_set=True`` extends the chooser to the full policy set for
    hostile-world fleets: the projected share is additionally deflated
    by the device's live AP outage health (``cluster.uplink_health``),
    and a link starved below ``cachegen_floor_frac`` whose device has
    *no* compute slack falls back to the ``cachegen`` bitrate ladder —
    streaming fewer bytes at graded fidelity is the only lever left
    when neither the uplink nor the device has headroom. The default
    ``full_set=False`` is bit-identical to the two-policy chooser.
    """
    frac = cluster.projected_flow_frac(spec.device)
    if full_set:
        frac *= cluster.uplink_health(spec.device)
    link_starved = frac < bw_floor_frac
    device_slack = cluster.device_load(spec.device) < cluster.capacity
    dcfg = cluster.decode_cfg if cluster.decode_cfg is not None \
        else DecodeConfig()
    decode_slack = cluster.decode_occupancy(spec.device) \
        < decode_busy_frac * dcfg.max_batch
    memory_ok = cluster.memory_pressure(spec.device) < memory_ceiling
    if link_starved and device_slack and decode_slack and memory_ok:
        return "local_prefill"
    if full_set and frac < cachegen_floor_frac:
        return "cachegen"
    return "sparkv"


# ---------------------------------------------------------------------------
# The cluster
# ---------------------------------------------------------------------------


class ServingCluster:
    """Discrete-event loop running N concurrent context loads.

    Parameters
    ----------
    cfg, spcfg : model / SparKV configs shared by all requests.
    profile, network : device profile name and network profile (name or
        ``NetworkProfile``) — the shared uplink trace is drawn from
        ``network``.
    capacity : compute slots per device. Run-queue mode serves at most
        this many chunks concurrently per device; closed-loop mode uses
        it to normalize utilization.
    max_concurrency : admission limit; excess arrivals queue FIFO.
    run_queue : a ``RunQueueModel`` switches the device server to the
        explicit run queue (FIFO/WFQ; ``run_queue.capacity`` overrides
        ``capacity``). Fleet compute contention is then queueing delay —
        ``attn_seconds`` receives only ``static_util`` (external, non-
        fleet load), never a fleet-derived scalar.
    closed_loop : (legacy mode, ignored under ``run_queue``) couple
        compute latency to actual in-flight compute; when False every
        request sees the hand-set ``static_util`` (Fig. 14 static mode).
    link : ``SharedLinkModel`` for uplink contention overhead; ``None``
        selects the default ``SharedLinkModel(network)`` (5%-per-flow
        overhead). For ideal overhead-free fair sharing pass
        ``SharedLinkModel(net, contention_overhead=0.0)`` explicitly.
    n_devices, nic, nic_link : with ``nic`` set (a ``NetworkProfile`` or
        name), each device gets its own NIC stage feeding the shared
        uplink (two-stage topology); requests route via
        ``RequestSpec.device``. ``nic`` may also be a sequence of
        profiles/names, one per device — asymmetric NIC fleets; a
        sequence of identical profiles is bit-for-bit the symmetric
        path. ``n_devices == 1`` with ``nic=None`` is the single-stage
        PR 1 semantics, bit-for-bit.
    n_aps, ap_of_device : number of access points and the device -> AP
        assignment (default round-robin ``d % n_aps``). Each AP owns its
        own uplink stage (AP 0 keeps the cluster's main uplink trace;
        further APs draw fresh traces from the same network profile), so
        a multi-AP fleet splits uplink contention structurally.
    egress, egress_link : a ``NetworkProfile`` (or name) arms the
        third hop — one cloud-egress stage crossed by *every* flow,
        whatever its AP. ``egress_link=None`` means ideal fair sharing
        (a wired cloud trunk has no MAC contention overhead); pass a
        ``SharedLinkModel`` to model per-flow egress overhead. An
        unconstrained egress (mean far above per-flow demand) leaves
        two-stage traces unchanged — the bottleneck min ignores it.
    predictor, refresh_every : a ``repro.core.predictor.
        LatencyPredictor`` arms the online contention refresh: every
        finalized request feeds its admission-time occupancy/backlog
        snapshot, realized queue wait and observed per-stage link
        shares to ``predictor.observe``, and every ``refresh_every``
        completions the cluster calls ``predictor.refresh()`` — after
        which SLO admission (``slo.predict_ttft``/``predict_tpot``)
        prefers the learned wait/share models over the analytic
        occupancy-dilation term. ``refresh_every=0`` never refreshes
        (observations still accumulate for an explicit ``refresh()``
        between runs); ``predictor=None`` is bit-identical to the
        analytic path.
    slo : an ``repro.serving.slo.SLOPolicy`` arms deadline-aware
        admission for requests that carry ``RequestSpec.deadline_s``:
        predicted-violation requests are downgraded to coarser stream
        quantization or shed, deadline slack maps to WFQ weight classes,
        and the per-request controller receives the deadline so
        near-deadline flows are not migrated onto congested links.
        Requests without a deadline are untouched (bit-identical to
        ``slo=None``).
    decode : a ``repro.serving.decode.DecodeConfig`` tuning the
        per-device continuous decode batch (max batch, tokens per
        dispatch, WFQ weight of the decode flow). Decoding itself is
        armed per request by ``RequestSpec.max_new_tokens > 0`` — a
        trace with ``max_new_tokens == 0`` everywhere is bit-identical
        to pre-decode behaviour whether or not ``decode`` is set; a
        decoding trace with ``decode=None`` uses ``DecodeConfig()``
        defaults.
    kvstore : a ``repro.core.costs.KVStoreModel`` arms cross-request KV
        reuse: one fleet-wide :class:`repro.serving.kvstore.CloudKVStore`
        of encoded chunk bitstreams plus a per-device
        :class:`~repro.serving.kvstore.DevicePrefixCache`. Requests that
        carry ``RequestSpec.content_ids`` resolve their chunks at
        admission — device prefix hits are preloaded (no link bytes, no
        compute), cloud store hits stream over the cached-egress leg
        (the flow path *without* the shared cloud-egress stage, plus the
        store's ``hit_latency_s``), misses stream the origin path and
        populate the store on completion. ``kvstore=None``, or a trace
        with ``content_ids=None`` everywhere at the model's cost
        defaults, is bit-identical to the no-reuse fleet.
    link_core : ``"vectorized"`` (default) drives the struct-of-arrays
        :class:`repro.serving.resources.LinkTopology`; ``"scalar"``
        selects the per-flow reference core
        (:class:`~repro.serving.resources.ScalarLinkTopology`) — the
        parity oracle the vectorized core is locked against.
    link_telemetry : ``False`` skips per-flow share accumulation in the
        link server (``RequestRecord.uplink_share`` reports 1.0 and
        ``stage_shares`` ``{}``); default ``True`` preserves current
        reports. Fleets that never read share telemetry save the
        per-event accumulation entirely.
    scenario : a ``repro.serving.scenarios.ScenarioTrace`` arms the
        hostile-world machinery: mid-stream AP handoffs (in-flight
        transfers lost, chunks re-enter the backlog via the engine's
        ``StreamLost`` leg), AP outage windows (uplink traces masked to
        the outage floor; in-flight streams through the AP aborted at
        window start; SLO admission sees the degraded health), and
        device churn (still-prefilling requests re-placed through
        admission on a live device; decoders finish locally). A trace
        with no events — or ``scenario=None`` — pushes zero extra
        events and is bit-identical to the static fleet.
    rebalancer : a ``repro.serving.scenarios.FleetRebalancer`` re-solves
        placement + policy fleet-wide (LP relaxation of the Eq. 1
        makespan split, warm-started basis-to-basis and through the
        online predictor's contention model) at every scenario event;
        AP moves are applied as handoffs and policy hints steer future
        admissions. Requires an armed ``scenario`` to ever fire.
    bw_trace / bw_dt : optional explicit uplink trace (otherwise an OU
        trace is drawn from the network profile with ``bw_seed``).
    """

    def __init__(self, cfg, spcfg, profile: str = "jetson-orin",
                 network="campus-wifi", *, capacity: int = 8,
                 max_concurrency: int = 8, closed_loop: bool = True,
                 static_util: float = 0.0,
                 link: Optional[SharedLinkModel] = None,
                 run_queue: Optional[RunQueueModel] = None,
                 n_devices: int = 1, nic=None,
                 nic_link: Optional[SharedLinkModel] = None,
                 n_aps: int = 1, ap_of_device=None,
                 egress=None,
                 egress_link: Optional[SharedLinkModel] = None,
                 slo: Optional["SLOPolicy"] = None,
                 policy_fn: Optional[Callable] = None,
                 decode: Optional[DecodeConfig] = None,
                 predictor: Optional[LatencyPredictor] = None,
                 refresh_every: int = 0,
                 memory: Optional[MemoryModel] = None,
                 memory_budget: Optional[float] = None,
                 kvstore: Optional[KVStoreModel] = None,
                 link_core: str = "vectorized",
                 link_telemetry: bool = True,
                 scenario: Optional[ScenarioTrace] = None,
                 rebalancer: Optional[FleetRebalancer] = None,
                 bw_trace: Optional[np.ndarray] = None, bw_dt: float = 0.01,
                 bw_seed: int = 991, seed: int = 0):
        self.cfg = cfg
        self.spcfg = spcfg
        self.profile_name = profile
        self.profile = PROFILES[profile]
        self.net: NetworkProfile = (NETWORKS[network]
                                    if isinstance(network, str) else network)
        self.capacity = run_queue.capacity if run_queue else capacity
        self.max_concurrency = max_concurrency
        self.closed_loop = closed_loop
        self.static_util = static_util
        self.link = link if link is not None else SharedLinkModel(self.net)
        self.run_queue = run_queue
        self.n_devices = n_devices
        if nic is None or isinstance(nic, (str, NetworkProfile)):
            self.nic: Optional[NetworkProfile] = (
                NETWORKS[nic] if isinstance(nic, str) else nic)
            self._nic_profiles = (None if self.nic is None
                                  else [self.nic] * n_devices)
        else:                                # per-device (asymmetric) NICs
            self._nic_profiles = [NETWORKS[p] if isinstance(p, str) else p
                                  for p in nic]
            assert len(self._nic_profiles) == n_devices, \
                "one NIC profile per device"
            self.nic = self._nic_profiles[0]
        self.nic_link = nic_link
        assert n_aps >= 1, n_aps
        self.n_aps = n_aps
        self.ap_of_device = tuple(ap_of_device) if ap_of_device is not None \
            else tuple(d % n_aps for d in range(n_devices))
        assert len(self.ap_of_device) == n_devices, \
            "one AP assignment per device"
        assert all(0 <= a < n_aps for a in self.ap_of_device), \
            f"AP assignment out of range [0, {n_aps})"
        self.egress: Optional[NetworkProfile] = (
            NETWORKS[egress] if isinstance(egress, str) else egress)
        self.egress_link = egress_link
        self.slo = slo
        self.policy_fn = policy_fn
        self.decode_cfg = decode
        self.predictor = predictor
        self.refresh_every = refresh_every
        if memory is None and memory_budget is not None:
            memory = MemoryModel(capacity_bytes=float(memory_budget))
        self.memory_model = memory
        self.kvstore_model = kvstore
        assert link_core in ("vectorized", "scalar"), link_core
        self.link_core = link_core
        self.link_telemetry = link_telemetry
        # hostile-world scenario: a ScenarioTrace with no events (or
        # None) pushes zero extra events and leaves the fleet
        # bit-identical to a scenario-free run
        self.scenario = scenario
        self.rebalancer = rebalancer
        self._ap_now: Optional[list] = None     # live AP map during run()
        self._outage_now: set = set()           # APs inside an outage
        self._policy_hints: dict = {}           # rebalancer policy picks
        self.bw_trace = bw_trace
        self.bw_dt = bw_dt
        self.bw_seed = bw_seed
        self.seed = seed
        # live-server handles (populated by run(); telemetry surface for
        # policy_fn callbacks)
        self._link_server: Optional[LinkTopology] = None
        self._run_queues: dict[int, DeviceRunQueue] = {}
        self._computing: dict[int, set] = {}
        self._batchers: dict[int, DecodeBatcher] = {}
        self._memory: dict[int, KVMemoryServer] = {}
        self._kvstore: Optional[CloudKVStore] = None
        self._prefix: dict[int, DevicePrefixCache] = {}
        self._n_finalized = 0                # predictor refresh cadence
        # events / wall-clock of the most recent run() (simcore profiling)
        self.last_sim_stats: Optional[dict] = None

    # ---- telemetry surface (valid during run()) ----
    @property
    def link_server(self) -> Optional[LinkTopology]:
        return self._link_server

    def active_flows(self) -> int:
        return self._link_server.n_active() if self._link_server else 0

    def device_load(self, device: int = 0) -> int:
        """In-service + waiting compute jobs on `device` (run-queue mode)
        or in-flight computing requests (closed-loop mode)."""
        if self.run_queue is not None:
            rq = self._run_queues.get(device)
            return rq.load() if rq else 0
        return len(self._computing.get(device, ()))

    def device_backlog_s(self, device: int = 0) -> float:
        """Service seconds committed to `device` (run-queue mode; 0.0 in
        closed-loop mode, where contention is already folded into the
        admission-time util and dilated service times)."""
        if self.run_queue is not None:
            rq = self._run_queues.get(device)
            return rq.backlog_s() if rq else 0.0
        return 0.0

    def decode_occupancy(self, device: int = 0) -> int:
        """Sequences decoding (or waiting to join the batch) on `device`
        — the batch a newly admitted request should expect to share its
        decode steps with (TPOT admission telemetry)."""
        bat = self._batchers.get(device)
        return bat.occupancy() if bat else 0

    def memory_server(self, device: int = 0) -> Optional[KVMemoryServer]:
        """The device's live KV memory server (None outside run() or on
        a cluster without a ``memory``/``memory_budget``)."""
        return self._memory.get(device)

    def memory_pressure(self, device: int = 0) -> float:
        """Resident KV over the device's capacity (0.0 unbounded or no
        memory server) — the signal :func:`telemetry_policy` and SLO
        admission fold in."""
        m = self._memory.get(device)
        return m.pressure() if m is not None else 0.0

    def _ap_of(self, device: int) -> int:
        """`device`'s *current* AP: the live handoff map while a
        scenario is armed, the static assignment otherwise."""
        if self._ap_now is not None:
            return self._ap_now[device]
        return self.ap_of_device[device] \
            if device < len(self.ap_of_device) else 0

    def uplink_health(self, device: int = 0) -> float:
        """Fraction of its nominal uplink bandwidth `device`'s current
        AP retains right now: the scenario's outage floor while the AP
        sits inside an outage window, 1.0 otherwise (always 1.0 on a
        scenario-free cluster — callers can multiply unconditionally).
        SLO admission (``slo.predict_ttft``) and the full-set
        :func:`telemetry_policy` fold this in."""
        if self.scenario is not None and self._outage_now \
                and self._ap_of(device) in self._outage_now:
            return self.scenario.outage_floor_frac
        return 1.0

    def _shared_stages(self, device: int) -> tuple:
        """(stage name, profiled mean bw, link model) for every *shared*
        stage of `device`'s path — its AP uplink, plus the cloud egress
        when the topology has one. Per-device NIC stages are excluded:
        they are exclusive, so their projection is the profile mean."""
        ap = self._ap_of(device)
        out = ((uplink_stage_name(ap, self.n_aps), self.net.mean_bw,
                self.link),)
        if self.egress is not None:
            out += (("egress", self.egress.mean_bw, self.egress_link),)
        return out

    def projected_flow_frac(self, device: int = 0) -> float:
        """Fraction of the exclusive profiled uplink bandwidth a new
        flow admitted on `device` should expect: for each shared stage
        of its path, stage mean x the fair share with this flow added,
        normalized by the uplink profile mean — the bottleneck stage
        governs. On single-uplink topologies this is exactly
        ``link.per_flow_fraction(n_active + 1)``. Telemetry for
        :func:`telemetry_policy` and ``slo.predict_ttft``."""
        best = 1.0
        for name, mean_bw, lm in self._shared_stages(device):
            st = self._link_server.stages.get(name) \
                if self._link_server is not None else None
            n = (len(st.active) if st is not None else 0) + 1
            frac = lm.per_flow_fraction(n) if lm else 1.0 / n
            best = min(best, frac * mean_bw / self.net.mean_bw)
        return best

    def projected_hit_frac(self, device: int = 0) -> float:
        """Like :meth:`projected_flow_frac`, but for a store-hit flow:
        the cached-egress leg skips the shared cloud-egress stage, so
        only the remaining shared stages (the AP uplink) bound it. On
        egress-free topologies this equals ``projected_flow_frac``."""
        best = 1.0
        for name, mean_bw, lm in self._shared_stages(device):
            if name == "egress":
                continue
            st = self._link_server.stages.get(name) \
                if self._link_server is not None else None
            n = (len(st.active) if st is not None else 0) + 1
            frac = lm.per_flow_fraction(n) if lm else 1.0 / n
            best = min(best, frac * mean_bw / self.net.mean_bw)
        return best

    def nic_mean_bw(self, device: int = 0) -> Optional[float]:
        """Profiled mean bandwidth of `device`'s own NIC stage (None
        without NIC stages) — the exclusive-stage cap on its projected
        stream rate."""
        if self._nic_profiles is None:
            return None
        return self._nic_profiles[device].mean_bw

    def observed_bottleneck_share(self, rid) -> Optional[float]:
        """Realized bottleneck fraction of the exclusive uplink
        bandwidth a finished flow received: min over the shared stages
        of its path of (mean stage share x stage mean / uplink mean).
        None when the flow never streamed. The predictor refresh's
        share observation."""
        if self._link_server is None:
            return None
        shares = self._link_server.stage_shares(rid)
        out = None
        for name, share in shares.items():
            if name.startswith("nic"):
                continue
            mean_bw = self.egress.mean_bw \
                if name == "egress" and self.egress is not None \
                else self.net.mean_bw
            v = share * mean_bw / self.net.mean_bw
            out = v if out is None else min(out, v)
        return out

    # ---- contention signals ----
    def _coupled_util(self, device: int) -> float:
        """Legacy dilation signal fed to attn_seconds while computing."""
        if self.run_queue is not None:
            # explicit queueing replaces fleet-internal dilation entirely;
            # static_util stays available for external (non-fleet) load
            return self.static_util
        if not self.closed_loop:
            return self.static_util
        return min(len(self._computing.get(device, ()))
                   / max(self.capacity, 1), 0.95)

    def _admission_util(self, device: int) -> float:
        """The predictor's U feature for planning at admission time."""
        if self.run_queue is not None:
            return queue_utilization(self.device_load(device), self.capacity)
        return self._coupled_util(device)

    # ---- topology construction ----
    def _build_link_server(self, integrator: BandwidthIntegrator
                           ) -> LinkTopology:
        """Materialize the link tree: AP 0's uplink is the cluster's
        main trace (`integrator`), further APs draw fresh traces from
        the same network profile, each device's NIC stage draws from
        its own profile, and the egress stage (when armed) from the
        egress profile — all on deterministic per-stage seeds, so the
        single-AP egress-free tree is bit-for-bit the two-stage (or,
        without NICs, single-stage) topology of earlier PRs."""
        topo_cls = ScalarLinkTopology if self.link_core == "scalar" \
            else LinkTopology
        if self._nic_profiles is None and self.n_aps == 1 \
                and self.egress is None:
            return single_link(integrator, self.link, cls=topo_cls,
                               telemetry=self.link_telemetry)
        horizon_s = (len(integrator.cum) - 1) * integrator.dt

        def draw(profile: NetworkProfile, seed: int) -> BandwidthIntegrator:
            rng = np.random.default_rng(seed)
            return BandwidthIntegrator(profile.trace(rng, horizon_s,
                                                     self.bw_dt),
                                       self.bw_dt)

        nics = None
        if self._nic_profiles is not None:
            nics = [draw(p, self.bw_seed + 7919 * (d + 1))
                    for d, p in enumerate(self._nic_profiles)]

        def draw_uplink(a: int) -> BandwidthIntegrator:
            # same rng stream as the scenario-free draw; outage windows
            # only mask the already-drawn samples (apply_outages returns
            # the input untouched when no window names this AP)
            rng = np.random.default_rng(self.bw_seed + 60013 * a)
            tr = self.net.trace(rng, horizon_s, self.bw_dt)
            scen = self.scenario
            if scen is not None and scen.outages:
                tr = apply_outages(tr, self.bw_dt, scen.outages, a,
                                   scen.outage_floor_frac)
            return BandwidthIntegrator(tr, self.bw_dt)

        uplinks = [integrator] + [draw_uplink(a)
                                  for a in range(1, self.n_aps)]
        egress = None if self.egress is None \
            else draw(self.egress, self.bw_seed + 15485863)
        return tree_topology(nics, uplinks, self.ap_of_device, egress,
                             uplink_link=self.link,
                             nic_link=self.nic_link,
                             egress_link=self.egress_link,
                             cls=topo_cls,
                             telemetry=self.link_telemetry)

    def _flow_path(self, device: int) -> tuple:
        return tree_path(device, self._ap_of(device), self.n_aps,
                         has_nic=self._nic_profiles is not None,
                         has_egress=self.egress is not None)

    def _hit_path(self, device: int) -> tuple:
        """Path of a cloud-store hit: the store's edge replica sits
        below the cloud-egress stage, so the cached bytes cross the
        device's NIC and its AP uplink but never the shared egress."""
        return tree_path(device, self._ap_of(device), self.n_aps,
                         has_nic=self._nic_profiles is not None,
                         has_egress=False)

    # ---- main loop ----
    def run(self, specs: list[RequestSpec]) -> FleetReport:
        specs = sorted(specs, key=lambda s: s.arrival_s)
        assert all(0 <= s.device < self.n_devices for s in specs), \
            f"request device out of range [0, {self.n_devices})"
        wls = [s.wl if s.wl is not None
               else synthesize(self.cfg, s.context_len,
                               DATASETS[s.dataset],
                               chunk_tokens=self.spcfg.chunk_tokens,
                               quant_bits=self.spcfg.quant_bits)
               for s in specs]

        total_bytes = sum(w.total_bytes() for w in wls)
        if self.bw_trace is None:
            horizon = max(20.0, 6 * total_bytes / self.net.mean_bw + 10
                          + (specs[-1].arrival_s if specs else 0.0))
            rng = np.random.default_rng(self.bw_seed)
            trace = self.net.trace(rng, horizon, self.bw_dt)
        else:
            trace = self.bw_trace
        # hostile-world scenario: arm only when it carries events — an
        # empty ScenarioTrace (or None) must leave the run bit-identical
        scen = self.scenario if (self.scenario is not None
                                 and self.scenario.armed()) else None
        if scen is not None and scen.outages:
            trace = apply_outages(trace, self.bw_dt, scen.outages, 0,
                                  scen.outage_floor_frac)
        integrator = BandwidthIntegrator(trace, self.bw_dt)
        link_server = self._build_link_server(integrator)
        self._link_server = link_server
        self._n_finalized = 0
        self._computing = {d: set() for d in range(self.n_devices)}
        self._run_queues = {
            d: DeviceRunQueue(
                self.capacity, self.run_queue.discipline,
                deadline_floor_s=self.run_queue.deadline_floor_s)
            for d in range(self.n_devices)} if self.run_queue else {}

        decode_cfg = self.decode_cfg if self.decode_cfg is not None \
            else DecodeConfig()
        self._batchers = {}
        self._decode_free: dict[int, float] = {}    # closed-loop serializer
        pending_decode: dict = {}     # queued dispatch key -> Dispatch
        self._memory = {d: KVMemoryServer(self.memory_model)
                        for d in range(self.n_devices)} \
            if self.memory_model is not None else {}
        # rid -> [outstanding reload legs, t_begin, stream dequant tail]
        reloads: dict[int, list] = {}

        # ---- cross-request KV reuse servers ----
        if self.kvstore_model is not None:
            self._kvstore = CloudKVStore(self.kvstore_model)
            self._prefix = {
                d: DevicePrefixCache(self.kvstore_model.device_capacity_bytes)
                for d in range(self.n_devices)}
        else:
            self._kvstore = None
            self._prefix = {}
        # content-key bookkeeping: which rids back each prefix-cache key
        # (copy semantics — several residents may hold the same prefix),
        # and each rid's registered keys (persists while parked)
        prefix_owners: dict[int, dict[int, set]] = {
            d: {} for d in range(self.n_devices)}
        rid_keys: dict[int, set] = {}

        active: dict[int, _ActiveRequest] = {}
        queue: list[tuple[int, RequestSpec]] = []
        records: list[RequestRecord] = []
        shed: list[ShedRecord] = []
        # typed event heap (repro.serving.simcore): the whole arrival
        # trace loads in one batched heapify; pushes carry EventKind ints
        # so the dispatch below is an int compare, not a string compare
        events = EventQueue()
        events.push_many((s.arrival_s, EventKind.ARRIVAL, rid, s)
                         for rid, s in enumerate(specs))
        arrival_s = {rid: s.arrival_s for rid, s in enumerate(specs)}
        now = 0.0
        makespan = 0.0
        n_link_events = 0
        t_wall0 = time.perf_counter()

        # ---- hostile-world state (inert on scenario-free runs) ----
        n_scen_events = 0
        reach_of: Optional[list] = None
        dead_devices: set[int] = set()
        dead_rids: set[int] = set()
        scen_tele = {"n_handoffs": 0, "n_handoff_noop": 0,
                     "n_streams_lost": 0, "bytes_lost": 0.0,
                     "n_churned": 0, "n_replaced": 0, "n_outages": 0,
                     "n_rebalances": 0}
        if scen is not None:
            self._ap_now = list(self.ap_of_device)
            self._outage_now = set()
            self._policy_hints = {}
            reach_of = [(a,) for a in self.ap_of_device]
            for h in scen.handoffs:
                events.push(h.t_s, EventKind.HANDOFF, h.device, h)
            for ce in scen.churn:
                events.push(ce.t_s, EventKind.CHURN, ce.device, ce)
            for w in scen.outages:
                events.push(w.t_start_s, EventKind.OUTAGE_START, w.ap, w)
                events.push(w.t_end_s, EventKind.OUTAGE_END, w.ap, w)
            n_scen_events = (len(scen.handoffs) + len(scen.churn)
                             + 2 * len(scen.outages))

        def push_compute(rid: int, chunk: Chunk, t0: float, dur: float):
            events.push(t0 + dur, EventKind.COMPUTE_DONE, rid, (chunk, t0))

        def batcher(dev: int) -> DecodeBatcher:
            if dev not in self._batchers:
                self._batchers[dev] = DecodeBatcher(self.cfg, self.profile,
                                                    decode_cfg)
            return self._batchers[dev]

        def start_jobs(dev: int, started):
            """Jobs entering run-queue service: prefill chunks, decode
            dispatches or reload recompute legs, told apart by key
            shape."""
            for key, t0, dur in started:
                if key[0] == "decode":
                    d = pending_decode.pop(key)
                    events.push(t0 + dur, EventKind.DECODE_DONE, key[1],
                                (d, t0))
                elif key[0] == "kvreload":
                    events.push(t0 + dur, EventKind.RELOAD_COMPUTE_DONE,
                                key[1], None)
                else:
                    push_compute(key[0], key[1], t0, dur)

        def submit_decode(dev: int):
            """Plan the device's next decode dispatch (if any) and put it
            on the device: through the run queue — where it competes with
            queued prefill chunks under the discipline — or back-to-back
            on the closed-loop decode serializer. Suspended (evicted)
            batch members trigger their KV reload here — the lazy
            "needed at next dispatch" point of the reload protocol."""
            bat = self._batchers.get(dev)
            if bat is None:
                return
            if self._memory:
                m = self._memory[dev]
                for r in bat.suspended_active():
                    if m.needs_reload(r):
                        start_reload(r)
            d = bat.next_dispatch()
            if d is None:
                return
            key = ("decode", dev, d.seq)
            if self.run_queue is not None:
                t0 = self._run_queues[dev].submit(
                    key, d.duration_s, now, flow=("decode", dev),
                    weight=decode_cfg.weight,
                    remaining_s=max(bat.remaining_service_s(),
                                    d.duration_s),
                    deadline_s=bat.min_deadline())
                if t0 is None:
                    pending_decode[key] = d
                    return
            else:
                t0 = max(now, self._decode_free.get(dev, 0.0))
                self._decode_free[dev] = t0 + d.duration_s
            events.push(t0 + d.duration_s, EventKind.DECODE_DONE, dev,
                        (d, t0))

        # ---- KV memory server wiring (all no-ops when unarmed) ----
        def pinned_rids(dev: int) -> set:
            """Rids the memory server must not evict: members of the
            device's in-flight decode dispatch (their KV is being read
            this very service interval)."""
            bat = self._batchers.get(dev)
            if bat is not None and bat.inflight is not None:
                return set(bat.inflight.token_offsets)
            return set()

        def idle_rids(dev: int) -> set:
            """Sequences enrolled but parked outside the active decode
            batch — the "idle" eviction policy's preferred victims."""
            bat = self._batchers.get(dev)
            return {mm.rid for mm in bat.waiting} if bat is not None \
                else set()

        def apply_evictions(dev: int, evs):
            """Act on the server's eviction events: demoted/dropped
            sequences are suspended in the batcher until their reload
            lands; in-place bits downgrades need no suspension."""
            bat = self._batchers.get(dev)
            for ev in evs:
                if ev.action == "downgrade":
                    continue
                if ev.action == "retire":
                    # a parked prefix segment was reclaimed: its keys
                    # stop being device-addressable, nothing to suspend
                    prefix_unindex(dev, ev.rid, forget=True)
                    continue
                vst = active.get(ev.rid)
                if vst is not None:
                    vst.n_evictions += 1
                if self._kvstore is not None:
                    # demoted/dropped KV is not addressable until reload
                    prefix_unindex(dev, ev.rid)
                if bat is not None:
                    bat.suspend(ev.rid)

        def charge_kv(st: _ActiveRequest, nbytes: float):
            dev = st.spec.device
            evs = self._memory[dev].charge(st.rid, nbytes, now,
                                           pinned=pinned_rids(dev),
                                           idle=idle_rids(dev))
            apply_evictions(dev, evs)

        # ---- cross-request KV reuse wiring (all no-ops when unarmed) ----
        def reuse_view(rid: int, spec: RequestSpec, wl):
            """Resolve the request's chunks against the reuse servers at
            admission: content keys from its prefix-closed span ids,
            device prefix matches first (near-free local hits), cloud
            store lookups for the rest (counted hits/misses). Returns
            (Chunk -> key, ChunkReuse) — (empty, None) for anonymous
            requests or unarmed stores."""
            if self._kvstore is None or spec.content_ids is None:
                return {}, None
            n_h = wl.n_h if (getattr(self.spcfg, "scheduler_mode", "engine")
                             == "paper" and wl.n_h > 1) else 1
            grid = ChunkGrid(n_t=wl.n_t, n_l=wl.n_l, n_h=n_h)
            ids = spec.content_ids
            # per-chunk allocation folds into the content key: a chunk
            # cached at 6 bits is a different artifact than the same
            # span at 4 — chunk_bits_for is pure on the workload's
            # measured signals, so it lands on exactly the widths
            # plan_policy will allocate (None when disarmed: every key
            # uses the uniform width, the pre-per-chunk keys verbatim)
            cb = B.chunk_bits_for(wl, grid, self.spcfg)
            key_of = {c: chunk_content_key(
                ids[c.t], c.l, model=self.cfg.name,
                bits=(cb[c] if cb is not None else self.spcfg.quant_bits),
                chunk_tokens=self.spcfg.chunk_tokens, head=c.h)
                for c in grid.chunks() if c.t < len(ids)}
            local_keys = self._prefix[spec.device].match(key_of.values())
            local, store = set(), set()
            for c, key in key_of.items():
                if key in local_keys:
                    local.add(c)
                elif self._kvstore.lookup(key, now):
                    store.add(c)
            return key_of, B.ChunkReuse(local=frozenset(local),
                                        store=frozenset(store),
                                        model=self.kvstore_model)

        def prefix_add(dev: int, rid: int, key: int, nbytes: float):
            """Register `rid` as a backer of prefix `key`; first backer
            makes the key resident in the device prefix cache."""
            rid_keys.setdefault(rid, set()).add(key)
            owners = prefix_owners[dev].setdefault(key, set())
            if rid not in owners:
                owners.add(rid)
                # a standalone-bounded cache may evict keys to make room:
                # drop their owner index (backers keep their rid_keys
                # entries — re-registration would simply re-insert)
                for evicted in self._prefix[dev].insert(key, nbytes, now):
                    prefix_owners[dev].pop(evicted, None)

        def prefix_unindex(dev: int, rid: int, *, forget: bool = False):
            """`rid`'s KV left device DRAM (demote/drop/retire/release):
            keys it backed lose one owner; orphaned keys leave the
            prefix cache. ``forget`` additionally drops the rid's key
            set (final — no reload will re-register)."""
            for key in rid_keys.get(rid, ()):
                owners = prefix_owners[dev].get(key)
                if owners is None:
                    continue
                owners.discard(rid)
                if not owners:
                    del prefix_owners[dev][key]
                    self._prefix[dev].remove(key)
            if forget:
                rid_keys.pop(rid, None)

        def prefix_reindex(dev: int, rid: int, nbytes: float):
            """`rid`'s KV is resident again (reload landed): re-register
            every key it had assembled."""
            for key in list(rid_keys.get(rid, ())):
                prefix_add(dev, rid, key, nbytes)

        def register_chunk(st: _ActiveRequest, chunk: Chunk, *,
                           streamed: bool):
            """One chunk of `st` finished assembling on the device: its
            content key becomes prefix-addressable, and a freshly
            streamed miss populates the cloud store (computed KV never
            reached the cloud encoder, so it cannot be cached there)."""
            key = st.key_of.get(chunk)
            if key is None:
                return
            if streamed and chunk not in st.plan.reuse_store:
                self._kvstore.insert(key, st.plan.bytes_map[chunk], now)
            prefix_add(st.spec.device, st.rid, key, st.kv_chunk_bytes)

        def start_reload(rid: int):
            """Plan and launch an evicted context's reload on the real
            servers: the stream leg as a link-topology flow, the
            recompute leg as a device run-queue job, the disk leg on the
            serial disk server — overlapping paths, exactly like the
            prefill scheduler's stream/compute stages."""
            st = active[rid]
            dev = st.spec.device
            m = self._memory[dev]
            ev = m.begin_reload(rid, now)
            plan = st.plan
            n_chunks = max(plan.grid.size, 1)
            res_per_chunk = ev.nbytes / n_chunks
            chunks = [(plan.bytes_map[c], res_per_chunk,
                       float(plan.planner.tc[plan.grid.index(c)]))
                      for c in plan.grid.chunks()]
            bw = self.net.mean_bw * self.projected_flow_frac(dev)
            nic_bw = self.nic_mean_bw(dev)
            if nic_bw is not None:
                bw = min(bw, nic_bw)
            pred = self.predictor
            if pred is not None and not pred.refreshed:
                pred = None
            wait = pred.predict_wait_s(self.device_load(dev), self.capacity,
                                       self.device_backlog_s(dev)) \
                if pred is not None else None
            if wait is None:
                wait = backlog_delay_s(self.device_backlog_s(dev),
                                       self.capacity)
            # a recompute leg occupies the same device the decode batch
            # needs: seed the comp path with the batch's outstanding
            # service too (the run-queue backlog can't see dispatches not
            # yet submitted), so the planner only recomputes when the
            # device is genuinely the cheap path
            bat = self._batchers.get(dev)
            if bat is not None:
                wait += bat.remaining_service_s()
            rp = plan_reload(chunks, mode=self.memory_model.reload,
                             profile=self.profile, stream_bw=max(bw, 1.0),
                             comp_wait_s=wait, disk=m.disk,
                             disk_backlog_s=m.disk.backlog_s(now)
                             if m.disk is not None else 0.0,
                             has_disk_copy=ev.from_disk)
            legs = 0
            if rp.stream_bytes > 0:
                link_server.add(RELOAD_FLOW_BASE + rid, rp.stream_bytes,
                                path=self._flow_path(dev))
                legs += 1
            if rp.comp_s > 0:
                key = ("kvreload", rid)
                if self.run_queue is not None:
                    t0 = self._run_queues[dev].submit(
                        key, rp.comp_s, now, flow=rid, weight=st.weight,
                        remaining_s=rp.comp_s, deadline_s=st.deadline_abs)
                    if t0 is not None:
                        events.push(t0 + rp.comp_s,
                                    EventKind.RELOAD_COMPUTE_DONE, rid,
                                    None)
                else:
                    self._computing[dev].add(key)
                    events.push(now + rp.comp_s,
                                EventKind.RELOAD_COMPUTE_DONE, rid, None)
                legs += 1
            if rp.disk_bytes > 0:
                t_done = m.disk.submit(rp.disk_bytes, now, op="read",
                                       n_ops=max(rp.n_disk, 1))
                events.push(t_done, EventKind.RELOAD_DISK_DONE, rid, None)
                legs += 1
            if legs == 0:            # zero-byte restore (degenerate)
                events.push(now, EventKind.RELOAD_DISK_DONE, rid, None)
                legs = 1
            reloads[rid] = [legs, now, rp.stream_proc_s]

        def reload_leg_done(rid: int):
            """One leg landed; when the last one does, the KV is resident
            again: recharge (pinned), resume the batcher member, account
            the stall, and let the batch dispatch."""
            state = reloads[rid]
            state[0] -= 1
            if state[0] > 0:
                return
            t_begin = state[1]
            del reloads[rid]
            st = active[rid]
            dev = st.spec.device
            evs = self._memory[dev].finish_reload(
                rid, now, pinned=pinned_rids(dev) | {rid},
                idle=idle_rids(dev))
            apply_evictions(dev, evs)
            if self._kvstore is not None:
                prefix_reindex(dev, rid, st.kv_chunk_bytes)
            st.reload_s += now - t_begin
            st.n_reloads += 1
            bat = self._batchers.get(dev)
            if bat is not None:
                bat.resume(rid)
            submit_decode(dev)

        def gated(rid: int, spec: RequestSpec) -> bool:
            """Admission gate on projected residency: hold a request
            while current + its full context would exceed ``gate_frac``
            of the device budget. Never gates an empty fleet, so the
            queue always drains."""
            mm = self.memory_model
            if not self._memory or mm is None or mm.gate_frac is None \
                    or mm.capacity_bytes is None or not active:
                return False
            need = context_kv_bytes(
                self.cfg, wls[rid].n_t * self.spcfg.chunk_tokens) \
                * mm.resident_bits / 16.0
            m = self._memory[spec.device]
            return m.resident_total + need > mm.gate_frac * m.capacity

        def drive(st: _ActiveRequest, reply=None, *, prime: bool = False):
            """Advance one session until it parks (Wait) or finishes.
            Returns the EngineResult when the session completed, else None."""
            dev = st.spec.device
            try:
                ev = next(st.gen) if prime else st.gen.send(reply)
                while True:
                    if isinstance(ev, StreamStart):
                        st.stream_chunk = ev.chunk
                        st.stream_t0 = now
                        st.stream_t_proc = ev.t_proc
                        st.stream_nbytes = ev.nbytes
                        link_server.add(st.rid, ev.nbytes,
                                        path=self._flow_path(dev))
                        ev = st.gen.send(None)
                    elif isinstance(ev, StoreHit):
                        # cloud-store hit: the cached bitstream rides the
                        # egress-free leg; the store's service latency
                        # lands in the on-device tail
                        st.stream_chunk = ev.chunk
                        st.stream_t0 = now
                        st.stream_t_proc = ev.t_proc \
                            + st.plan.store_model.hit_latency_s
                        st.stream_nbytes = ev.nbytes
                        link_server.add(st.rid, ev.nbytes,
                                        path=self._hit_path(dev))
                        ev = st.gen.send(None)
                    elif isinstance(ev, ComputeStart):
                        st.comp_chunk = ev.chunk
                        if self.run_queue is not None:
                            t0 = self._run_queues[dev].submit(
                                (st.rid, ev.chunk), ev.duration_s, now,
                                flow=st.rid, weight=st.weight,
                                remaining_s=max(st.comp_total_s
                                                - st.comp_done_s,
                                                ev.duration_s),
                                deadline_s=st.deadline_abs)
                            if t0 is not None:
                                push_compute(st.rid, ev.chunk, t0,
                                             ev.duration_s)
                            ev = st.gen.send(StartAck(t0))
                        else:
                            self._computing[dev].add(st.rid)
                            push_compute(st.rid, ev.chunk, now,
                                         ev.duration_s)
                            ev = st.gen.send(StartAck(now))
                    elif isinstance(ev, DecodeStart):
                        # context assembled: join the device's continuous
                        # decode batch (token-boundary join)
                        st.decoding = True
                        if self._memory:
                            # fully assembled == evictable from here on
                            self._memory[dev].mark_ready(st.rid, now)
                        batcher(dev).enroll(st.rid, ev.context_len,
                                            ev.n_tokens,
                                            deadline_s=st.deadline_abs)
                        submit_decode(dev)
                        ev = st.gen.send(None)
                    else:
                        assert isinstance(ev, Wait)
                        return None
            except StopIteration as stop:
                return stop.value

        def admit(rid: int, spec: RequestSpec) -> bool:
            """Admit one request (possibly quality-downgraded); returns
            False when the SLO layer shed it instead."""
            if spec.device in dead_devices:
                # churned target: re-place onto the least-loaded live
                # device (shed when the whole fleet is gone)
                live = [d for d in range(self.n_devices)
                        if d not in dead_devices]
                if not live:
                    shed.append(ShedRecord(rid=rid, spec=spec, t_shed_s=now,
                                           pred_ttft_s=float("inf"),
                                           reason="churn"))
                    return False
                spec = dataclasses.replace(
                    spec, device=min(live, key=self.device_load))
                scen_tele["n_replaced"] += 1
            policy = spec.policy
            if self.policy_fn is not None:
                policy = self.policy_fn(spec, self)
            elif self._policy_hints:
                # fleet rebalancer's per-device policy pick (only ever
                # populated while a scenario is armed with a rebalancer)
                policy = self._policy_hints.get(spec.device, policy)
            key_of, reuse = reuse_view(rid, spec, wls[rid])
            plan = B.plan_policy(policy, self.cfg, wls[rid],
                                 self.profile_name, self.net, self.spcfg,
                                 util=self._admission_util(spec.device),
                                 reuse=reuse)
            deadline_abs = (spec.arrival_s + spec.deadline_s
                            if spec.deadline_s is not None else None)
            weight = spec.weight
            downgraded = False
            pred_ttft = None
            if self.slo is not None and (spec.deadline_s is not None
                                         or spec.tpot_slo_s is not None):
                dec = decide_admission(self.slo, plan, self, spec, now)
                pred_ttft = dec.pred_ttft_s
                if dec.action == "shed":
                    shed.append(ShedRecord(rid=rid, spec=spec, t_shed_s=now,
                                           pred_ttft_s=dec.pred_ttft_s,
                                           reason=dec.reason,
                                           pred_tpot_s=dec.pred_tpot_s))
                    return False
                if dec.bits < plan.quality_bits:
                    cold = dec.cold_chunks
                    if cold is None and plan.chunk_bits is not None:
                        # whole-request downgrade of a per-chunk plan:
                        # same per-chunk arithmetic, cold set = everyone
                        cold = frozenset(plan.chunk_bits)
                    if cold is not None:
                        # cold-chunk downgrade: only the low-saliency
                        # chunks drop to dec.bits (never upward); hot
                        # chunks keep their width and their fidelity
                        cb = dict(plan.chunk_bits) if plan.chunk_bits \
                            else {c: plan.quality_bits
                                  for c in plan.grid.chunks()}
                        bmap = dict(plan.bytes_map)
                        for c in cold:
                            b_c = cb.get(c, plan.quality_bits)
                            nb = min(b_c, dec.bits)
                            if nb < b_c:
                                bmap[c] = chunk_bytes_at_bits(
                                    bmap[c], b_c, nb)
                                cb[c] = nb
                        plan.bytes_map = bmap
                        plan.chunk_bits = cb
                    else:
                        # coarser stream quantization: fewer bytes on
                        # the wire at QUALITY_OF_BITS[dec.bits] fidelity
                        scale = dec.bits / plan.quality_bits
                        plan.bytes_map = {c: v * scale
                                          for c, v in
                                          plan.bytes_map.items()}
                        plan.quality_bits = dec.bits
                    downgraded = True
                if (self.run_queue is not None
                        and self.run_queue.discipline == "wfq"
                        and deadline_abs is not None
                        and weight == 1.0):
                    weight = self.slo.weight_for_slack(deadline_abs - now)
            if self.slo is not None and deadline_abs is not None \
                    and plan.controller is not None:
                # deadline-aware migration guard is part of the SLO layer:
                # without slo=, deadlines are recorded but never acted on,
                # so no-SLO baselines keep exact pre-SLO behavior
                plan.controller.set_deadline(deadline_abs)
            gt = GroundTruthLatency(
                self.profile, self.cfg.resolved_head_dim
                if self.cfg.num_heads else 64)
            t_pred = {c: plan.planner.tc[i]
                      for i, c in enumerate(plan.grid.chunks())}
            eng = HybridEngine(
                grid=plan.grid, chunk_bytes=plan.bytes_map,
                active_blocks=plan.active_map, t_comp_pred=t_pred,
                gt=gt, profile=self.profile, bw=integrator,
                cfg_model=self.cfg, util=self.static_util,
                controller=plan.controller,
                seed=self.seed + spec.seed,
                max_new_tokens=spec.max_new_tokens,
                preloaded=plan.reuse_local, store_hits=plan.reuse_store,
                store_model=plan.store_model)
            comp_total = plan_compute_seconds(plan)
            st = _ActiveRequest(rid=rid, spec=spec, plan=plan,
                                gen=eng.session(
                                    plan.schedule,
                                    context_len=plan.context_len,
                                    t_start=now,
                                    util_fn=lambda d=spec.device:
                                        self._coupled_util(d)),
                                admit_s=now, weight=weight,
                                deadline_abs=deadline_abs,
                                comp_total_s=comp_total,
                                downgraded=downgraded,
                                pred_ttft_s=pred_ttft,
                                obs_load=self.device_load(spec.device),
                                obs_backlog_s=self.device_backlog_s(
                                    spec.device),
                                obs_n_flows=self.active_flows(),
                                key_of=key_of)
            # context bytes still to assemble (preloaded prefix chunks
            # never move) — the rebalancer's per-device demand signal
            st.bytes_left = sum(v for c, v in plan.bytes_map.items()
                                if c not in plan.reuse_local)
            if self._memory:
                self._memory[spec.device].admit(rid, now)
                # resident bytes each assembled chunk adds (full-precision
                # context KV split evenly across the plan's chunk grid,
                # scaled to the server's resident storage width)
                st.kv_chunk_bytes = (
                    context_kv_bytes(self.cfg, plan.context_len)
                    * self.memory_model.resident_bits / 16.0
                    / max(plan.grid.size, 1))
            if self._kvstore is not None and key_of:
                if not st.kv_chunk_bytes:
                    # no memory server: prefix-cache accounting still
                    # needs the chunk's resident footprint
                    st.kv_chunk_bytes = (
                        context_kv_bytes(self.cfg, plan.context_len)
                        / max(plan.grid.size, 1))
                if plan.reuse_local:
                    # copy semantics: the new request materializes its
                    # own copy of each preloaded prefix chunk. Charge
                    # them now — no completion events ever fire for
                    # preloaded chunks — and co-own their prefix keys.
                    if self._memory:
                        charge_kv(st, len(plan.reuse_local)
                                  * st.kv_chunk_bytes)
                    for c in plan.reuse_local:
                        prefix_add(spec.device, rid, key_of[c],
                                   st.kv_chunk_bytes)
            active[rid] = st
            res = drive(st, prime=True)
            if res is not None:
                finalize(st, res)
            return True

        def finalize(st: _ActiveRequest, res):
            nonlocal makespan
            active.pop(st.rid)
            self._computing[st.spec.device].discard(st.rid)
            kv_bits = 0
            if self._memory:
                m = self._memory[st.spec.device]
                kv_bits = m.bits_of(st.rid)
                parked = False
                if self._kvstore is not None and st.key_of:
                    # keep the assembled prefix addressable for the next
                    # request sharing it (radix-cache-style parking; the
                    # segment is the preferred eviction victim)
                    parked = m.park(st.rid, now)
                if not parked:
                    m.release(st.rid, now)
                    if self._kvstore is not None:
                        prefix_unindex(st.spec.device, st.rid, forget=True)
            quality = B._mixed_quality(res, st.plan.quality_bits,
                                       chunk_bits=st.plan.chunk_bits,
                                       active_map=st.plan.active_map)
            ttft = res.ttft_s - arrival_s[st.rid]
            ttlt = res.ttlt_s - arrival_s[st.rid]
            met = None
            if st.spec.deadline_s is not None \
                    or st.spec.tpot_slo_s is not None:
                met = True
                if st.spec.deadline_s is not None:
                    met = met and ttft <= st.spec.deadline_s
                if st.spec.tpot_slo_s is not None and res.n_tokens_out > 1:
                    met = met and res.tpot_s <= st.spec.tpot_slo_s
            records.append(RequestRecord(
                rid=st.rid, spec=st.spec, policy=st.plan.policy,
                admit_s=st.admit_s, context_done_s=res.context_done_s,
                done_s=res.ttft_s,
                ttft_s=ttft,
                queue_s=st.admit_s - arrival_s[st.rid],
                energy_j=res.energy["total_j"], quality=quality,
                n_streamed=res.n_streamed, n_computed=res.n_computed,
                n_migrations=res.n_migrations,
                stream_busy_s=res.stream_busy_s,
                compute_busy_s=res.compute_busy_s,
                bytes_streamed=res.bytes_streamed,
                compute_wait_s=res.compute_wait_s,
                n_compute_queued=res.n_compute_queued,
                uplink_share=link_server.mean_share(st.rid),
                slo_class=st.spec.slo_class,
                deadline_s=st.spec.deadline_s,
                slo_met=met,
                quant_bits=st.plan.quality_bits,
                downgraded=st.downgraded,
                n_tokens_out=res.n_tokens_out, ttlt_s=ttlt,
                tpot_s=res.tpot_s, tpot_slo_s=st.spec.tpot_slo_s,
                stage_shares=link_server.stage_shares(st.rid),
                reload_s=st.reload_s, n_evictions=st.n_evictions,
                n_reloads=st.n_reloads, kv_bits=kv_bits,
                n_local_hits=res.n_reused, n_store_hits=res.n_store_hits,
                bytes_hit_stream=res.bytes_hit_stream))
            if self.predictor is not None:
                share = self.observed_bottleneck_share(st.rid)
                self.predictor.observe(
                    load=st.obs_load, capacity=self.capacity,
                    backlog_s=st.obs_backlog_s,
                    wait_s=res.compute_wait_s,
                    n_flows=None if share is None else st.obs_n_flows + 1,
                    share=share)
                self._n_finalized += 1
                if self.refresh_every \
                        and self._n_finalized % self.refresh_every == 0:
                    self.predictor.refresh()
            # decode-off: res.ttlt_s == res.ttft_s, so the makespan is
            # unchanged from first-token accounting
            makespan = max(makespan, res.ttlt_s)
            while queue:
                if gated(*queue[0]):
                    break           # re-checked at the next finalize
                if admit(*queue.pop(0)):
                    break

        # ---- hostile-world event machinery (reachable only when armed) --
        def abort_stream(st: _ActiveRequest) -> bool:
            """Kill `st`'s in-flight transfer (handoff / outage onset):
            partially delivered bytes are wasted — an entropy-coded
            chunk bitstream is undecodable from a prefix — and the chunk
            re-enters the session's backlog via ``StreamLost`` (the
            controller may flip it to local compute). False when nothing
            was in flight, or the transfer already finished and only its
            on-device dequant tail (STREAM_AVAIL) is pending."""
            if st.stream_chunk is None:
                return False
            rem = link_server.remaining(st.rid)
            if rem is None:
                return False
            delivered = max(st.stream_nbytes - rem, 0.0)
            link_server.complete(st.rid)
            chunk = st.stream_chunk
            st.stream_chunk = None
            scen_tele["n_streams_lost"] += 1
            scen_tele["bytes_lost"] += delivered
            res = drive(st, StreamLost(chunk, now, delivered))
            if res is not None:
                finalize(st, res)
            return True

        def do_handoff(dev: int, new_ap: int) -> None:
            """Re-associate `dev` with `new_ap`: flip the live AP map
            *first* (re-issued streams must ride the new path), then
            abort its in-flight transfers. Same-AP handoffs are counted
            no-ops; reload flows stay on the old path (a roaming reload
            keeps draining — finite outages recover, so it cannot
            starve)."""
            if dev in dead_devices:
                return
            if self._ap_now[dev] == new_ap:
                scen_tele["n_handoff_noop"] += 1
                return
            scen_tele["n_handoffs"] += 1
            self._ap_now[dev] = new_ap
            for st in list(active.values()):
                if st.spec.device == dev:
                    abort_stream(st)

        def do_churn(ce) -> None:
            """Device failure: every still-prefilling request on it
            loses its in-flight work and is re-placed through admission
            on a live device (same arrival time — TTFT includes the
            lost work); decoding requests finish locally (decode needs
            no uplink and their context is already resident)."""
            dev = ce.device
            if dev in dead_devices:
                return
            dead_devices.add(dev)
            scen_tele["n_churned"] += 1
            victims = [st for st in active.values()
                       if st.spec.device == dev and not st.decoding]
            for st in victims:
                rid = st.rid
                # the device is gone: silently drop its link flow and
                # queued/in-service compute; the session is dead — no
                # StreamLost, just close the generator
                if st.stream_chunk is not None \
                        and link_server.remaining(rid) is not None:
                    link_server.complete(rid)
                if st.comp_chunk is not None:
                    if self.run_queue is not None:
                        start_jobs(dev, self._run_queues[dev].cancel(
                            (rid, st.comp_chunk), now))
                    else:
                        self._computing[dev].discard(rid)
                st.gen.close()
                dead_rids.add(rid)
                if self._memory:
                    self._memory[dev].release(rid, now)
                if self._kvstore is not None:
                    prefix_unindex(dev, rid, forget=True)
                active.pop(rid)
                target = ce.new_device
                if target is None or target in dead_devices:
                    live = [d for d in range(self.n_devices)
                            if d not in dead_devices]
                    target = min(live, key=self.device_load) \
                        if live else None
                if target is None:
                    shed.append(ShedRecord(
                        rid=rid, spec=st.spec, t_shed_s=now,
                        pred_ttft_s=float("inf"), reason="churn"))
                    continue
                # re-admit as a fresh rid so the replacement rides the
                # normal admission path (SLO ladder, reuse, policy fn)
                new_rid = len(wls)
                wls.append(wls[rid])
                arrival_s[new_rid] = arrival_s[rid]
                scen_tele["n_replaced"] += 1
                events.push(now, EventKind.ARRIVAL, new_rid,
                            dataclasses.replace(st.spec, device=target))

        def rebalance(reason: str) -> bool:
            """Snapshot the fleet and let the rebalancer re-solve
            placement + policy fleet-wide; apply AP moves as handoffs
            (aborting in-flight streams on moved devices) and stash the
            policy hints for future admissions. False when there is no
            rebalancer or it declined to act."""
            if self.rebalancer is None or self._ap_now is None:
                return False
            demand = np.zeros(self.n_devices)
            rate_obs: dict[int, list] = {}
            for st in active.values():
                d = st.spec.device
                demand[d] += max(st.bytes_left, 0.0)
                tot_t = float(np.sum(st.plan.planner.tc))
                if tot_t > 0:
                    rate_obs.setdefault(d, []).append(
                        sum(st.plan.bytes_map.values()) / tot_t)
            comp_rate = np.array(
                [float(np.mean(rate_obs[d])) if d in rate_obs
                 else self.net.mean_bw for d in range(self.n_devices)])
            ap_health = np.ones(self.n_aps)
            for a in self._outage_now:
                ap_health[a] = scen.outage_floor_frac
            ap_flows = np.zeros(self.n_aps)
            for a in range(self.n_aps):
                stg = link_server.stages.get(
                    uplink_stage_name(a, self.n_aps))
                if stg is not None:
                    ap_flows[a] = len(stg.active)
            dec = self.rebalancer.decide(FleetState(
                now=now, demand=demand, ap_of_device=list(self._ap_now),
                ap_health=ap_health, ap_flows=ap_flows,
                mean_bw=self.net.mean_bw, comp_rate=comp_rate,
                reach=list(reach_of), dead=frozenset(dead_devices)))
            if dec is None:
                return False
            scen_tele["n_rebalances"] += 1
            for d, a in sorted(dec.placement.items()):
                do_handoff(d, a)
            self._policy_hints = dict(dec.policy_hint)
            return True

        guard = 0
        limit = 1000 + 200 * sum(w.n_t * w.n_l * max(w.n_h, 1) for w in wls) \
            + 50 * sum(s.max_new_tokens for s in specs)
        if self.memory_model is not None \
                and self.memory_model.capacity_bytes is not None:
            # evict/reload cycles add events per token under pressure
            limit *= 6
        if scen is not None:
            # loss/re-stream cycles, churn re-admissions and rebalance
            # handoffs add events per scenario event; the guard stays a
            # livelock net, not a budget
            limit = limit * 4 + 200 * n_scen_events
        while events or link_server.n_active():
            guard += 1
            if guard > limit:
                raise RuntimeError("cluster livelock")
            nc = link_server.next_completion()
            if nc is not None and nc[0] <= events.peek_t():
                n_link_events += 1
                t_done, rid = nc
                link_server.advance(t_done)
                link_server.complete(rid)
                now = t_done
                if isinstance(rid, int) and rid >= RELOAD_FLOW_BASE:
                    # reload restream leg landed: on-device dequant tail,
                    # then the leg counts down like the others
                    r = rid - RELOAD_FLOW_BASE
                    events.push(t_done + reloads[r][2],
                                EventKind.RELOAD_STREAM_DONE, r, None)
                    continue
                st = active[rid]
                # decode+dequant tail happens on-device after the transfer
                events.push(t_done + st.stream_t_proc,
                            EventKind.STREAM_AVAIL, rid,
                            (st.stream_chunk, st.stream_t0))
                continue
            if not events:
                break
            ev = events.pop()
            t, kind, rid, payload = ev.t, ev.kind, ev.rid, ev.payload
            link_server.advance(t)
            now = t
            if dead_rids and rid in dead_rids and kind in (
                    EventKind.COMPUTE_DONE, EventKind.STREAM_AVAIL,
                    EventKind.RELOAD_STREAM_DONE,
                    EventKind.RELOAD_DISK_DONE,
                    EventKind.RELOAD_COMPUTE_DONE):
                continue        # stale event for a churned request
            if kind == EventKind.ARRIVAL:
                if len(active) < self.max_concurrency and not queue \
                        and not gated(rid, payload):
                    admit(rid, payload)
                else:
                    queue.append((rid, payload))
            elif kind == EventKind.COMPUTE_DONE:
                chunk, t0 = payload
                st = active[rid]
                st.comp_done_s += t - t0
                st.comp_chunk = None
                st.bytes_left -= st.plan.bytes_map[chunk]
                if self.run_queue is not None:
                    started = self._run_queues[st.spec.device].complete(
                        (rid, chunk), t)
                    start_jobs(st.spec.device, started)
                else:
                    self._computing[st.spec.device].discard(rid)
                if self._memory:
                    charge_kv(st, st.kv_chunk_bytes)
                if self._kvstore is not None:
                    register_chunk(st, chunk, streamed=False)
                res = drive(st, Completion("compute", chunk, t0, t))
                if res is not None:
                    finalize(st, res)
            elif kind == EventKind.DECODE_DONE:
                dev = rid                      # decode events carry the
                d, t0 = payload                # device in the rid slot
                bat = self._batchers[dev]
                started = self._run_queues[dev].complete(
                    ("decode", dev, d.seq), t) \
                    if self.run_queue is not None else []
                bat.dispatch_done()
                start_jobs(dev, started)
                members = sorted(d.token_offsets)   # one sort per dispatch
                if self._memory:
                    # the dispatch read every member's KV and grew it by
                    # one token per generated token
                    m = self._memory[dev]
                    tkb = token_kv_bytes(self.cfg)
                    for r in members:
                        m.touch(r, now)
                        if tkb > 0:
                            charge_kv(active[r],
                                      len(d.token_offsets[r]) * tkb)
                # deliver this dispatch's tokens to every member session
                for r in members:
                    st = active[r]
                    times = tuple(t0 + off for off in d.token_offsets[r])
                    cls = DecodeDone if r in d.finished else DecodeTick
                    res = drive(st, cls(
                        t_start=t0, t_end=t, token_times=times,
                        batch_size=d.batch_size,
                        busy_share_s=d.busy_share[r]))
                    if res is not None:
                        finalize(st, res)
                submit_decode(dev)
            elif kind == EventKind.STREAM_AVAIL:
                chunk, t0 = payload
                st = active[rid]
                st.stream_chunk = None
                st.bytes_left -= st.stream_nbytes
                if self._memory:
                    charge_kv(st, st.kv_chunk_bytes)
                if self._kvstore is not None:
                    register_chunk(st, chunk, streamed=True)
                res = drive(st, Completion("stream", chunk, t0, t))
                if res is not None:
                    finalize(st, res)
            elif kind in (EventKind.RELOAD_STREAM_DONE,
                          EventKind.RELOAD_DISK_DONE):
                reload_leg_done(rid)
            elif kind == EventKind.RELOAD_COMPUTE_DONE:
                dev = active[rid].spec.device
                if self.run_queue is not None:
                    started = self._run_queues[dev].complete(
                        ("kvreload", rid), t)
                    start_jobs(dev, started)
                else:
                    self._computing[dev].discard(("kvreload", rid))
                reload_leg_done(rid)
            elif kind == EventKind.HANDOFF:
                h = payload
                if h.reachable is not None:
                    # soft handoff: the rebalancer may place the device
                    # on any reachable AP (and move others); without
                    # one, the roam lands on the event's new_ap
                    reach_of[h.device] = tuple(h.reachable)
                    if not rebalance("handoff"):
                        do_handoff(h.device, h.new_ap)
                else:
                    reach_of[h.device] = (h.new_ap,)
                    do_handoff(h.device, h.new_ap)
                    rebalance("handoff")
            elif kind == EventKind.CHURN:
                do_churn(payload)
                rebalance("churn")
            elif kind == EventKind.OUTAGE_START:
                w = payload
                scen_tele["n_outages"] += 1
                self._outage_now.add(w.ap)
                # rebalance first — devices it moves off the dying AP
                # lose their streams via the handoff path; stragglers
                # left behind lose theirs here
                rebalance("outage")
                for st in list(active.values()):
                    if self._ap_of(st.spec.device) == w.ap:
                        abort_stream(st)
            elif kind == EventKind.OUTAGE_END:
                self._outage_now.discard(payload.ap)
                rebalance("outage_end")
        wall_s = time.perf_counter() - t_wall0
        n_events = events.n_popped + n_link_events
        SIM_STATS.record(n_events, wall_s)
        self.last_sim_stats = {
            "n_events": n_events,
            "n_heap_events": events.n_popped,
            "n_link_completions": n_link_events,
            "wall_s": wall_s,
            "events_per_s": n_events / wall_s if wall_s > 0 else None,
        }
        assert not active and not queue, "cluster finished with stuck work"
        assert all(b.idle() for b in self._batchers.values()), \
            "cluster finished with undrained decode batches"
        assert not reloads, "cluster finished with in-flight reloads"
        mem_summary = None
        if self._memory:
            tele = [m.telemetry() for m in self._memory.values()]
            caps = [t["capacity_bytes"] for t in tele]
            mem_summary = {
                "capacity_bytes": (None if any(c is None for c in caps)
                                   else sum(caps)),
                "peak_resident_bytes": max(
                    t["peak_resident_bytes"] for t in tele),
                "resident_p99_bytes": max(
                    t["resident_p99_bytes"] for t in tele),
            }
            for k in ("n_evictions", "n_downgrades", "n_demotions",
                      "n_drops", "n_reloads", "reload_bytes", "n_retired",
                      "charged_bytes_total", "disk_bytes_written",
                      "disk_bytes_read", "disk_busy_s"):
                vals = [t[k] for t in tele if k in t]
                if vals:
                    mem_summary[k] = type(vals[0])(sum(vals))
        reuse_summary = None
        if self._kvstore is not None:
            prefix_tele = [p.telemetry() for p in self._prefix.values()]
            reuse_summary = {
                "store": self._kvstore.telemetry(),
                "local_hits_total": sum(r.n_local_hits for r in records),
                "store_hits_total": sum(r.n_store_hits for r in records),
                "bytes_hit_stream_total": sum(r.bytes_hit_stream
                                              for r in records),
                # bytes that actually crossed the cloud origin: streamed
                # minus the store-hit bytes served from the edge replica
                "egress_bytes_total": sum(r.bytes_streamed
                                          - r.bytes_hit_stream
                                          for r in records),
                "prefix_lookups": sum(t["n_lookups"] for t in prefix_tele),
                "prefix_hits": sum(t["n_hits"] for t in prefix_tele),
            }
        scen_summary = None
        if scen is not None:
            if self.rebalancer is not None:
                scen_tele["n_lp_solves"] = self.rebalancer.n_solves
                scen_tele["n_lp_warm_hits"] = self.rebalancer.n_warm_hits
            scen_summary = dict(scen_tele)
        # clear the whole telemetry surface so a reused cluster never
        # exposes one run's end-state to the next run's policy_fn
        self._link_server = None
        self._run_queues = {}
        self._computing = {}
        self._batchers = {}
        self._memory = {}
        self._kvstore = None
        self._prefix = {}
        self._ap_now = None
        self._outage_now = set()
        self._policy_hints = {}
        return FleetReport(records=sorted(records, key=lambda r: r.rid),
                           makespan_s=makespan, n_arrived=len(specs),
                           shed=sorted(shed, key=lambda s: s.rid),
                           memory=mem_summary, reuse=reuse_summary,
                           scenario=scen_summary)
