"""Multi-request serving cluster: shared-link arbitration + contention
coupling on one discrete-event clock.

The single-request engine (`repro.core.engine.HybridEngine.run`) models a
device that owns the whole NIC and sees contention only as a static `util`
scalar. This module runs **N concurrent context loads** against shared
resources:

  - :class:`SharedLinkArbiter` — fair-shares one ``BandwidthIntegrator``
    trace across all in-flight streams. Per-flow goodput is
    ``trace(t) * eta(n) / n`` (``repro.core.costs.SharedLinkModel``), so
    two concurrent streams measurably slow each other; with one flow the
    arbiter reproduces exclusive-link semantics bit-for-bit.
  - **closed-loop utilization** — each request's ground-truth compute
    latency is inflated by the *actual* number of in-flight compute chunks
    (``util = n_other_computing / capacity``), replacing the hand-set
    `util` scalar; the same figure feeds the latency predictor's U feature
    at admission time. SparKV's runtime controller therefore observes real
    contention and migrates accordingly.
  - **admission queue** — at most ``max_concurrency`` requests are in
    service; arrivals beyond that wait FIFO. Per-request policy comes from
    the :class:`RequestSpec` (or a ``policy_fn`` override at admission).

Protocol with the engine: each admitted request holds an
``HybridEngine.session`` generator. The cluster resumes a session only at
that request's own completion events; sessions yield ``StreamStart`` /
``ComputeStart`` requests which the cluster maps onto the arbiter and the
event heap. See ``repro.core.engine`` for the event dataclasses.

Fleet metrics: p50/p99 TTFT (arrival -> first token), goodput (completed
requests per second of makespan), energy per request, migration counts.

Typical use::

    specs = poisson_trace(...)                      # repro.serving.traffic
    cluster = ServingCluster(cfg, spcfg, "jetson-orin", "campus-wifi")
    report = cluster.run(specs)
    print(report.summary())
"""
from __future__ import annotations

import dataclasses
import heapq
from typing import Callable, Optional

import numpy as np

from repro.core import baselines as B
from repro.core.chunks import Chunk
from repro.core.costs import (GroundTruthLatency, NetworkProfile, PROFILES,
                              NETWORKS, SharedLinkModel)
from repro.core.engine import (BandwidthIntegrator, Completion, ComputeStart,
                               HybridEngine, StreamStart, Wait,
                               decode_first_token_seconds)
from repro.data.workloads import DATASETS, WorkloadChunks, synthesize


# ---------------------------------------------------------------------------
# Shared-link bandwidth arbiter
# ---------------------------------------------------------------------------


class SharedLinkArbiter:
    """Fair-share scheduler over one cumulative-bandwidth trace.

    Active flows split the instantaneous link capacity equally, scaled by
    the aggregate contention efficiency ``eta(n)`` of the link model. The
    active set is piecewise-constant between cluster events: the cluster
    always advances time to the earliest of (heap event, earliest flow
    completion), so :meth:`advance` only ever integrates over intervals
    with a fixed membership.
    """

    def __init__(self, integrator: BandwidthIntegrator,
                 link: Optional[SharedLinkModel] = None):
        self.bw = integrator
        self.link = link
        self.t = 0.0
        self._rem: dict[int, float] = {}      # flow key -> bytes left

    def n_active(self) -> int:
        return len(self._rem)

    def _fraction(self) -> float:
        n = len(self._rem)
        if n == 0:
            return 1.0
        eta = self.link.aggregate_efficiency(n) if self.link else 1.0
        return eta / n

    def advance(self, t: float) -> None:
        """Integrate deliveries over [self.t, t] (constant active set)."""
        if t <= self.t:
            return
        if self._rem:
            share = self.bw.bytes_between(self.t, t) * self._fraction()
            for k in self._rem:
                self._rem[k] = max(self._rem[k] - share, 0.0)
        self.t = t

    def add(self, key: int, nbytes: float) -> None:
        assert key not in self._rem, f"flow {key} already active"
        self._rem[key] = float(nbytes)

    def complete(self, key: int) -> None:
        del self._rem[key]

    def next_completion(self) -> Optional[tuple[float, int]]:
        """(t_done, key) of the earliest flow to finish if the active set
        stays fixed — with equal shares that is the min-remaining flow."""
        if not self._rem:
            return None
        key, rem = min(self._rem.items(), key=lambda kv: (kv[1], kv[0]))
        need_on_link = rem / self._fraction()
        return self.bw.finish_time(self.t, need_on_link), key


# ---------------------------------------------------------------------------
# Requests
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class RequestSpec:
    """One job for the cluster: when it arrives and what it loads."""
    arrival_s: float
    context_len: int = 8192
    dataset: str = "longchat"
    policy: str = "sparkv"
    seed: int = 0
    wl: Optional[WorkloadChunks] = None     # overrides synthesis if given


@dataclasses.dataclass
class RequestRecord:
    rid: int
    spec: RequestSpec
    policy: str
    admit_s: float
    context_done_s: float                   # all chunks assembled
    done_s: float                           # context assembled + first token
    ttft_s: float                           # done_s - arrival_s (incl. queue)
    queue_s: float
    energy_j: float
    quality: float
    n_streamed: int
    n_computed: int
    n_migrations: int
    stream_busy_s: float
    compute_busy_s: float
    bytes_streamed: float


@dataclasses.dataclass
class _ActiveRequest:
    rid: int
    spec: RequestSpec
    plan: B.RequestPlan
    gen: object                             # engine session generator
    admit_s: float
    # in-flight stream bookkeeping (one per request at a time)
    stream_chunk: Optional[Chunk] = None
    stream_t0: float = 0.0
    stream_t_proc: float = 0.0


@dataclasses.dataclass
class FleetReport:
    records: list[RequestRecord]
    makespan_s: float
    n_arrived: int

    def ttfts(self) -> np.ndarray:
        return np.array([r.ttft_s for r in self.records])

    def summary(self) -> dict:
        t = self.ttfts()
        done = len(self.records)
        return {
            "n_done": done,
            "ttft_p50_s": float(np.percentile(t, 50)) if done else float("nan"),
            "ttft_p99_s": float(np.percentile(t, 99)) if done else float("nan"),
            "ttft_mean_s": float(t.mean()) if done else float("nan"),
            "goodput_rps": done / self.makespan_s if self.makespan_s else 0.0,
            "energy_per_req_j": float(np.mean([r.energy_j
                                               for r in self.records]))
            if done else float("nan"),
            "migrations_total": sum(r.n_migrations for r in self.records),
            "stream_busy_total_s": sum(r.stream_busy_s
                                       for r in self.records),
            "queue_mean_s": float(np.mean([r.queue_s for r in self.records]))
            if done else float("nan"),
        }


# ---------------------------------------------------------------------------
# The cluster
# ---------------------------------------------------------------------------


class ServingCluster:
    """Discrete-event loop running N concurrent context loads.

    Parameters
    ----------
    cfg, spcfg : model / SparKV configs shared by all requests.
    profile, network : device profile name and network profile (name or
        ``NetworkProfile``) — one shared device, one shared link.
    capacity : compute slots used to normalize closed-loop utilization
        (``util = n_other_inflight_compute / capacity``).
    max_concurrency : admission limit; excess arrivals queue FIFO.
    closed_loop : couple compute latency to actual in-flight compute; when
        False every request sees the hand-set ``static_util`` (the legacy
        Fig. 14 mode).
    link : ``SharedLinkModel`` for contention overhead; ``None`` disables
        the overhead term but still fair-shares the trace.
    bw_trace / bw_dt : optional explicit bandwidth trace (otherwise an OU
        trace is drawn from the network profile with ``bw_seed``).
    """

    def __init__(self, cfg, spcfg, profile: str = "jetson-orin",
                 network="campus-wifi", *, capacity: int = 8,
                 max_concurrency: int = 8, closed_loop: bool = True,
                 static_util: float = 0.0,
                 link: Optional[SharedLinkModel] = None,
                 policy_fn: Optional[Callable] = None,
                 bw_trace: Optional[np.ndarray] = None, bw_dt: float = 0.01,
                 bw_seed: int = 991, seed: int = 0):
        self.cfg = cfg
        self.spcfg = spcfg
        self.profile_name = profile
        self.profile = PROFILES[profile]
        self.net: NetworkProfile = (NETWORKS[network]
                                    if isinstance(network, str) else network)
        self.capacity = capacity
        self.max_concurrency = max_concurrency
        self.closed_loop = closed_loop
        self.static_util = static_util
        self.link = link if link is not None else SharedLinkModel(self.net)
        self.policy_fn = policy_fn
        self.bw_trace = bw_trace
        self.bw_dt = bw_dt
        self.bw_seed = bw_seed
        self.seed = seed

    # ---- closed-loop contention ----
    def _coupled_util(self) -> float:
        if not self.closed_loop:
            return self.static_util
        return min(len(self._computing) / max(self.capacity, 1), 0.95)

    # ---- main loop ----
    def run(self, specs: list[RequestSpec]) -> FleetReport:
        specs = sorted(specs, key=lambda s: s.arrival_s)
        wls = [s.wl if s.wl is not None
               else synthesize(self.cfg, s.context_len,
                               DATASETS[s.dataset],
                               chunk_tokens=self.spcfg.chunk_tokens,
                               quant_bits=self.spcfg.quant_bits)
               for s in specs]

        total_bytes = sum(w.total_bytes() for w in wls)
        if self.bw_trace is None:
            horizon = max(20.0, 6 * total_bytes / self.net.mean_bw + 10
                          + (specs[-1].arrival_s if specs else 0.0))
            rng = np.random.default_rng(self.bw_seed)
            trace = self.net.trace(rng, horizon, self.bw_dt)
        else:
            trace = self.bw_trace
        integrator = BandwidthIntegrator(trace, self.bw_dt)
        arbiter = SharedLinkArbiter(integrator, self.link)

        self._computing: set[int] = set()
        active: dict[int, _ActiveRequest] = {}
        queue: list[tuple[int, RequestSpec]] = []
        records: list[RequestRecord] = []
        # heap: (t, seq, kind, rid, payload)
        heap: list = []
        seq = 0
        for rid, s in enumerate(specs):
            heapq.heappush(heap, (s.arrival_s, seq, "arrival", rid, s))
            seq += 1
        arrival_s = {rid: s.arrival_s for rid, s in enumerate(specs)}
        now = 0.0
        makespan = 0.0

        def drive(st: _ActiveRequest, reply=None, *, prime: bool = False):
            """Advance one session until it parks (Wait) or finishes.
            Returns the EngineResult when the session completed, else None."""
            nonlocal seq
            try:
                ev = next(st.gen) if prime else st.gen.send(reply)
                while True:
                    if isinstance(ev, StreamStart):
                        st.stream_chunk = ev.chunk
                        st.stream_t0 = now
                        st.stream_t_proc = ev.t_proc
                        arbiter.add(st.rid, ev.nbytes)
                        ev = st.gen.send(None)
                    elif isinstance(ev, ComputeStart):
                        self._computing.add(st.rid)
                        heapq.heappush(heap, (now + ev.duration_s, seq,
                                              "compute_done", st.rid,
                                              (ev.chunk, now)))
                        seq += 1
                        ev = st.gen.send(None)
                    else:
                        assert isinstance(ev, Wait)
                        return None
            except StopIteration as stop:
                return stop.value

        def admit(rid: int, spec: RequestSpec):
            nonlocal seq
            policy = spec.policy
            if self.policy_fn is not None:
                policy = self.policy_fn(spec, self)
            plan = B.plan_policy(policy, self.cfg, wls[rid],
                                 self.profile_name, self.net, self.spcfg,
                                 util=self._coupled_util())
            gt = GroundTruthLatency(
                self.profile, self.cfg.resolved_head_dim
                if self.cfg.num_heads else 64)
            t_pred = {c: plan.planner.tc[i]
                      for i, c in enumerate(plan.grid.chunks())}
            eng = HybridEngine(
                grid=plan.grid, chunk_bytes=plan.bytes_map,
                active_blocks=plan.active_map, t_comp_pred=t_pred,
                gt=gt, profile=self.profile, bw=integrator,
                cfg_model=self.cfg, util=self.static_util,
                controller=plan.controller,
                seed=self.seed + spec.seed)
            st = _ActiveRequest(rid=rid, spec=spec, plan=plan,
                                gen=eng.session(
                                    plan.schedule,
                                    context_len=plan.context_len,
                                    t_start=now,
                                    util_fn=self._coupled_util),
                                admit_s=now)
            active[rid] = st
            res = drive(st, prime=True)
            if res is not None:
                finalize(st, res)

        def finalize(st: _ActiveRequest, res):
            nonlocal makespan
            active.pop(st.rid)
            self._computing.discard(st.rid)
            quality = B._mixed_quality(res, st.plan.quality_bits)
            records.append(RequestRecord(
                rid=st.rid, spec=st.spec, policy=st.plan.policy,
                admit_s=st.admit_s, context_done_s=res.context_done_s,
                done_s=res.ttft_s,
                ttft_s=res.ttft_s - arrival_s[st.rid],
                queue_s=st.admit_s - arrival_s[st.rid],
                energy_j=res.energy["total_j"], quality=quality,
                n_streamed=res.n_streamed, n_computed=res.n_computed,
                n_migrations=res.n_migrations,
                stream_busy_s=res.stream_busy_s,
                compute_busy_s=res.compute_busy_s,
                bytes_streamed=res.bytes_streamed))
            makespan = max(makespan, res.ttft_s)
            if queue:
                admit(*queue.pop(0))

        guard = 0
        limit = 1000 + 200 * sum(w.n_t * w.n_l * max(w.n_h, 1) for w in wls)
        while heap or arbiter.n_active():
            guard += 1
            if guard > limit:
                raise RuntimeError("cluster livelock")
            nc = arbiter.next_completion()
            t_heap = heap[0][0] if heap else float("inf")
            if nc is not None and nc[0] <= t_heap:
                t_done, rid = nc
                arbiter.advance(t_done)
                arbiter.complete(rid)
                now = t_done
                st = active[rid]
                # decode+dequant tail happens on-device after the transfer
                heapq.heappush(heap, (t_done + st.stream_t_proc, seq,
                                      "stream_avail", rid,
                                      (st.stream_chunk, st.stream_t0)))
                seq += 1
                continue
            if not heap:
                break
            t, _, kind, rid, payload = heapq.heappop(heap)
            arbiter.advance(t)
            now = t
            if kind == "arrival":
                if len(active) < self.max_concurrency:
                    admit(rid, payload)
                else:
                    queue.append((rid, payload))
            elif kind == "compute_done":
                chunk, t0 = payload
                self._computing.discard(rid)
                st = active[rid]
                res = drive(st, Completion("compute", chunk, t0, t))
                if res is not None:
                    finalize(st, res)
            elif kind == "stream_avail":
                chunk, t0 = payload
                st = active[rid]
                st.stream_chunk = None
                res = drive(st, Completion("stream", chunk, t0, t))
                if res is not None:
                    finalize(st, res)
        assert not active and not queue, "cluster finished with stuck work"
        return FleetReport(records=sorted(records, key=lambda r: r.rid),
                           makespan_s=makespan, n_arrived=len(specs))
