"""Cross-request KV reuse servers: the cloud content-addressed store and
the per-device prefix cache.

At fleet scale much of the prefill work is redundant — shared system
prompts, multi-turn chats re-sending their whole history, RAG over common
documents ("Compute Or Load KV Cache? Why Not Both?"). This module gives
the reuse layer its two residency servers:

  - :class:`CloudKVStore` — one per fleet, cloud-side. Caches the
    transfer-ready encoded bitstream per content key
    (``repro.core.chunks.chunk_content_key``: prefix-closed token span +
    model + bits + chunking). Capacity-bound with LRU or LFU eviction;
    every lookup is counted (hit/miss), every insert either lands or is
    refused (an artifact larger than the whole store). A hit's economics
    are :func:`repro.core.costs.t_store_hit` — the cached bytes skip the
    cloud-side encode and bypass the shared cloud-egress stage.
  - :class:`DevicePrefixCache` — one per device. Content keys of chunks
    whose *assembled KV* is still addressable on the device (this
    session's previous turn, or another resident request sharing the
    prefix). A match satisfies the chunk locally: no link bytes, no
    compute — the near-free local hit. When the cluster runs a finite
    ``KVMemoryServer``, residency of parked prefix segments is governed
    there (``park``/retire) and this cache only indexes them; standalone
    it bounds itself with ``device_capacity_bytes``.

Byte-conservation ledger (the hypothesis-tested invariant): every byte
ever accepted by ``insert`` is exactly one of resident or evicted::

    inserted_total == resident_bytes + evicted_total

and counter consistency: ``n_lookups == n_hits + n_misses`` under any
interleaving, with residency never exceeding capacity after any call.
"""
from __future__ import annotations

from typing import Optional

from repro.core.costs import KVStoreModel


class CloudKVStore:
    """Capacity-bound content-addressed bitstream cache (cloud side).

    Protocol::

        if store.lookup(key, t):      # counted hit (refreshes recency)
            ... serve via t_store_hit ...
        else:                         # counted miss
            ... origin path; on stream completion:
            store.insert(key, nbytes, t)

    Deterministic: recency/insertion order is a monotone sequence number
    (no wall-clock ties), so eviction order is reproducible.
    """

    def __init__(self, model: Optional[KVStoreModel] = None):
        self.model = model if model is not None else KVStoreModel()
        self.capacity = self.model.capacity_bytes
        self._res: dict[int, float] = {}        # key -> bytes
        self._seq: dict[int, int] = {}          # key -> last-use seq (LRU)
        self._freq: dict[int, int] = {}         # key -> use count (LFU)
        self._clock = 0
        # counters
        self.n_lookups = 0
        self.n_hits = 0
        self.n_misses = 0
        self.n_inserts = 0
        self.n_evictions = 0
        self.n_refused = 0                      # oversized artifacts
        # byte-conservation ledger
        self.inserted_total = 0.0
        self.evicted_total = 0.0
        self.resident_bytes = 0.0

    def __contains__(self, key: int) -> bool:
        return key in self._res

    def __len__(self) -> int:
        return len(self._res)

    def _touch(self, key: int) -> None:
        self._clock += 1
        self._seq[key] = self._clock
        self._freq[key] = self._freq.get(key, 0) + 1

    # ---- ledger ----
    def ledger_balance(self) -> float:
        """``inserted - (resident + evicted)`` — zero at every point of
        every legal interleaving (to float tolerance)."""
        return self.inserted_total - (self.resident_bytes
                                      + self.evicted_total)

    # ---- protocol ----
    def lookup(self, key: int, t: float = 0.0) -> bool:
        """Is the artifact cached? Counts the outcome; a hit refreshes
        recency/frequency (the read keeps it hot)."""
        self.n_lookups += 1
        if key in self._res:
            self.n_hits += 1
            self._touch(key)
            return True
        self.n_misses += 1
        return False

    def insert(self, key: int, nbytes: float, t: float = 0.0) -> list[int]:
        """Cache an artifact of `nbytes`; returns the keys evicted to
        make room. Re-inserting a resident key refreshes it (no ledger
        movement). An artifact larger than the whole store is refused
        (counted, no state change) — residency never exceeds capacity."""
        nbytes = float(nbytes)
        assert nbytes >= 0, nbytes
        if key in self._res:
            self._touch(key)
            return []
        if self.capacity is not None and nbytes > self.capacity:
            self.n_refused += 1
            return []
        self._res[key] = nbytes
        self._touch(key)
        self.n_inserts += 1
        self.inserted_total += nbytes
        self.resident_bytes += nbytes
        return self._enforce(exclude=key)

    def remove(self, key: int) -> None:
        """Invalidate an entry (counted as evicted — the bytes left
        residency). No-op for absent keys."""
        nbytes = self._res.pop(key, None)
        if nbytes is None:
            return
        self._seq.pop(key, None)
        self._freq.pop(key, None)
        self.resident_bytes -= nbytes
        self.evicted_total += nbytes
        self.n_evictions += 1

    def _victim(self, exclude: int) -> Optional[int]:
        cands = [k for k in self._res if k != exclude]
        if not cands:
            return None
        if self.model.policy == "lfu":
            return min(cands, key=lambda k: (self._freq[k], self._seq[k]))
        return min(cands, key=lambda k: self._seq[k])

    def _enforce(self, exclude: int) -> list[int]:
        if self.capacity is None:
            return []
        out = []
        while self.resident_bytes > self.capacity:
            victim = self._victim(exclude)
            if victim is None:
                break
            self.remove(victim)
            out.append(victim)
        return out

    # ---- telemetry ----
    def hit_rate(self) -> Optional[float]:
        return self.n_hits / self.n_lookups if self.n_lookups else None

    def telemetry(self) -> dict:
        return {
            "capacity_bytes": self.capacity,
            "policy": self.model.policy,
            "resident_bytes": self.resident_bytes,
            "n_entries": len(self._res),
            "n_lookups": self.n_lookups,
            "n_hits": self.n_hits,
            "n_misses": self.n_misses,
            "n_inserts": self.n_inserts,
            "n_evictions": self.n_evictions,
            "n_refused": self.n_refused,
            "inserted_bytes_total": self.inserted_total,
            "evicted_bytes_total": self.evicted_total,
            "hit_rate": self.hit_rate(),
        }


class DevicePrefixCache(CloudKVStore):
    """Content-key index of chunks whose assembled KV is addressable on
    one device (LRU residency; same accounting/ledger as the cloud
    store). ``capacity_bytes=None`` when a ``KVMemoryServer`` governs
    residency — entries are then retired via :meth:`remove` when the
    memory server evicts the backing segment."""

    def __init__(self, capacity_bytes: Optional[float] = None):
        super().__init__(KVStoreModel(capacity_bytes=capacity_bytes,
                                      policy="lru"))

    def match(self, keys) -> set:
        """Resident subset of `keys` — counted lookups, matches touched
        (the prefix read keeps the segment hot)."""
        return {k for k in keys if self.lookup(k)}
