"""SparKV serving engine — the end-to-end inference driver.

Context-reuse serving: a reusable context is registered once ("cloud"
side: exact KV + per-chunk quantized+Huffman bitstreams + chunk stats);
each request then *loads* that context through a policy pipeline
(sparkv / strong_hybrid / cachegen / local_prefill):

  - timing & energy come from the discrete-event engine (virtual clock,
    real compressed bytes, ground-truth compute latencies);
  - the KV cache content is assembled *concretely*: streamed chunks are
    entropy-decoded + dequantized (Pallas kv_dequant kernel), computed
    chunks take the exact local values — so response-quality numbers are
    real logit comparisons, not a proxy table.

The device-utilization signal the paper reads from nvidia-smi is exposed
here as `utilization()` (active requests / capacity) and feeds the
latency predictor's U feature. For *timing under concurrency* that static
signal is superseded by `serve_fleet()`, which submits registered
contexts into `repro.serving.cluster.ServingCluster`: N loads share the
link through the bandwidth arbiter and couple compute latencies through
closed-loop utilization.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.compression import huffman
from repro.compression.quantize import quantize
from repro.configs.base import SparKVConfig
from repro.core import baselines as B
from repro.core.chunks import Chunk
from repro.core.costs import NETWORKS
from repro.data.workloads import WorkloadChunks
from repro.kernels.kv_dequant.ops import (dequantize_chunk,
                                          dequantize_chunks_mixed)
from repro.models.api import Model


@dataclasses.dataclass
class StoredContext:
    tokens: np.ndarray                 # (1, S)
    exact_k: np.ndarray                # (L, 1, S, hkv, hd)
    exact_v: np.ndarray
    encoded: dict                      # Chunk(t,l,0) -> (enc_k, enc_v, qt_k, qt_v)
    wl: WorkloadChunks
    n_chunks: int


@dataclasses.dataclass
class ServeResult:
    ttft_s: float
    energy_j: float
    tokens: np.ndarray
    top1_agreement: float
    mean_kl: float
    n_streamed: int
    n_computed: int
    migrations: int
    wall_s: float


class SparKVServer:
    def __init__(self, model: Model, params, spcfg: SparKVConfig,
                 *, profile: str = "jetson-orin",
                 network: str = "campus-wifi", capacity: int = 8,
                 chunk_tokens: Optional[int] = None, seed: int = 0):
        self.model = model
        self.params = params
        self.spcfg = spcfg
        self.profile = profile
        self.network = network
        self.capacity = capacity
        self.chunk_tokens = chunk_tokens or spcfg.chunk_tokens
        self.seed = seed
        self.contexts: dict[int, StoredContext] = {}
        self.active_requests = 0
        self._next_id = 0
        self._decode_step = jax.jit(self.model.decode_step,
                                    donate_argnums=(1,))

    def utilization(self) -> float:
        return min(self.active_requests / self.capacity, 1.0)

    # ---------------- cloud side ----------------
    def register_context(self, tokens: np.ndarray) -> int:
        """Precompute exact KV + compressed chunk artifacts (cloud).

        With ``spcfg.alloc_schedule`` armed, the artifacts are encoded
        at per-chunk widths: a first base-width quantization pass
        measures the entropy signal (``huffman.entropy_bits`` of the
        real code planes — this also populates the workload's
        ``entropy_bits``, which was a zero placeholder), the allocator
        turns (attention mass x entropy) saliency into per-chunk bits,
        and any chunk allocated off the base width is re-quantized at
        its own width before entropy coding. The "uniform" sentinel
        takes the single-pass path unchanged."""
        cfg = self.model.cfg
        assert tokens.shape[0] == 1, "one context per registration"
        s = tokens.shape[1]
        ct = self.chunk_tokens
        assert s % ct == 0, f"context length must be a multiple of {ct}"
        _, cache = self.model.prefill(self.params,
                                      {"tokens": jnp.asarray(tokens)})
        k = np.asarray(cache["k"], np.float32)      # (L, 1, S, hkv, hd)
        v = np.asarray(cache["v"], np.float32)
        n_t, n_l = s // ct, cfg.num_layers

        # pass 1: base-width quantization + the measured entropy signal
        quant = {}
        ent = np.zeros((n_l, 1))
        for t in range(n_t):
            for l in range(n_l):
                kc = k[l, 0, t * ct:(t + 1) * ct]
                vc = v[l, 0, t * ct:(t + 1) * ct]
                qk = quantize(kc, self.spcfg.quant_bits, self.spcfg.quant_group)
                qv = quantize(vc, self.spcfg.quant_bits, self.spcfg.quant_group)
                quant[Chunk(t, l, 0)] = (qk, qv)
                ent[l, 0] += (huffman.entropy_bits(qk.codes, 1 << qk.bits)
                              + huffman.entropy_bits(qv.codes, 1 << qv.bits)
                              ) / (2 * n_t)

        # per-chunk allocation: re-quantize off-base chunks at their own
        # width (the "flat" schedule allocates base everywhere, so the
        # artifacts stay byte-identical to an unarmed registration)
        active = self._measure_active_blocks(tokens, n_t, n_l)
        if getattr(self.spcfg, "alloc_schedule", "uniform") != "uniform":
            from repro.compression.allocate import (allocate_bits,
                                                    schedule_of)
            bits_arr = allocate_bits(
                active, ent, self.spcfg.quant_bits,
                schedule_of(self.spcfg.alloc_schedule))
            for c, (qk, qv) in list(quant.items()):
                b = int(bits_arr[c.t, c.l, 0])
                if b != self.spcfg.quant_bits:
                    kc = k[c.l, 0, c.t * ct:(c.t + 1) * ct]
                    vc = v[c.l, 0, c.t * ct:(c.t + 1) * ct]
                    quant[c] = (quantize(kc, b, self.spcfg.quant_group),
                                quantize(vc, b, self.spcfg.quant_group))

        encoded = {}
        chunk_bytes = np.zeros((n_t, n_l, 1))
        for c, (qk, qv) in quant.items():
            ek = huffman.encode(qk.codes, 1 << qk.bits, n_streams=64)
            ev = huffman.encode(qv.codes, 1 << qv.bits, n_streams=64)
            encoded[c] = (ek, ev, qk, qv)
            chunk_bytes[c.t, c.l, 0] = (ek.payload_bytes()
                                        + ev.payload_bytes()
                                        + qk.header_bytes()
                                        + qv.header_bytes())

        # measured chunk stats drive the scheduler (real bytes; active
        # blocks from the block-importance mask on the real q/k; real
        # code-plane entropy feeds the bit allocator's saliency)
        wl = WorkloadChunks(
            n_t=n_t, n_l=n_l, n_h=1, active_blocks=active,
            entropy_bits=ent, chunk_bytes=chunk_bytes,
            head_pattern=np.zeros((n_l, 1), np.int64),
            context_len=s, chunk_tokens=ct)
        cid = self._next_id
        self._next_id += 1
        self.contexts[cid] = StoredContext(
            tokens=tokens, exact_k=k, exact_v=v, encoded=encoded, wl=wl,
            n_chunks=n_t * n_l)
        return cid

    def _measure_active_blocks(self, tokens, n_t, n_l) -> np.ndarray:
        """Per-(t, l) active kv blocks from pooled block scores."""
        from repro.sparse.mask import block_scores, select_blocks
        cfg = self.model.cfg
        ct = self.chunk_tokens
        qb = min(self.spcfg.q_block, ct)
        kb = min(self.spcfg.kv_block, ct)
        # use embeddings as a cheap q/k surrogate at serving time
        emb = np.asarray(
            jnp.take(self.params["emb"], jnp.asarray(tokens), axis=0),
            np.float32)[0]                                    # (S, d)
        x = emb[None]                                         # (1, S, d)
        sc = block_scores(jnp.asarray(x), jnp.asarray(x), q_block=qb,
                          kv_block=kb, causal=True)
        _, cnt = select_blocks(sc, mass=self.spcfg.attention_mass,
                               q_block=qb, kv_block=kb)
        cnt = np.asarray(cnt[0], np.float64)                  # (n_qb,)
        rows_per_chunk = ct // qb
        per_t = cnt.reshape(n_t, rows_per_chunk).sum(axis=1)
        out = np.broadcast_to(per_t[:, None, None],
                              (n_t, n_l, 1)).copy()
        # deeper layers tend denser (observed in the measurement study)
        depth = np.linspace(0.8, 1.2, n_l)[None, :, None]
        return out * depth

    # ---------------- edge side ----------------
    def load_context(self, cid: int, *, policy: str = "sparkv",
                     util: Optional[float] = None, seed: Optional[int] = None):
        """Run the loading pipeline; returns (cache jnp, PipelineResult)."""
        st = self.contexts[cid]
        cfg = self.model.cfg
        spcfg = self.spcfg
        u = self.utilization() if util is None else util
        net = NETWORKS[self.network]
        res = B.PIPELINES[policy](cfg, st.wl, self.profile, net, spcfg,
                                  util=u, seed=seed or self.seed)
        eng = res.engine
        # concrete assembly
        k = st.exact_k.copy()
        v = st.exact_v.copy()
        ct = self.chunk_tokens
        streamed = sorted(getattr(eng, "streamed_set", set()))
        decoded = []
        for c in streamed:
            ek, ev, qk, qv = st.encoded[c]
            dk = huffman.decode(ek)
            dv = huffman.decode(ev)
            assert np.array_equal(dk, qk.codes), "bitstream corruption"
            qk2 = dataclasses.replace(qk, codes=dk.astype(np.uint8))
            qv2 = dataclasses.replace(qv, codes=dv.astype(np.uint8))
            decoded.append((c, qk2, qv2))
        if len({q.bits for _, qk2, qv2 in decoded
                for q in (qk2, qv2)}) > 1:
            # per-chunk adaptive widths: one mixed-bitwidth launch over
            # every streamed chunk (exact-parity-tested against the
            # per-chunk path, so policy never changes the assembled KV)
            outs = dequantize_chunks_mixed(
                [q for _, qk2, qv2 in decoded for q in (qk2, qv2)],
                out_dtype=jnp.float32)
            for (c, _, _), kd, vd in zip(decoded, outs[0::2], outs[1::2]):
                k[c.l, 0, c.t * ct:(c.t + 1) * ct] = np.asarray(kd)
                v[c.l, 0, c.t * ct:(c.t + 1) * ct] = np.asarray(vd)
        else:
            for c, qk2, qv2 in decoded:
                kd = np.asarray(dequantize_chunk(qk2, out_dtype=jnp.float32))
                vd = np.asarray(dequantize_chunk(qv2, out_dtype=jnp.float32))
                k[c.l, 0, c.t * ct:(c.t + 1) * ct] = kd
                v[c.l, 0, c.t * ct:(c.t + 1) * ct] = vd
        cache = {"k": jnp.asarray(k, jnp.bfloat16),
                 "v": jnp.asarray(v, jnp.bfloat16)}
        return cache, res

    def generate(self, cid: int, prompt: np.ndarray, max_new: int = 8,
                 *, policy: str = "sparkv", compare_exact: bool = True,
                 seed: Optional[int] = None) -> ServeResult:
        """Serve one request: load context via `policy`, feed the prompt,
        decode max_new tokens greedily; quality vs the exact cache."""
        t_wall = time.time()
        self.active_requests += 1
        try:
            st = self.contexts[cid]
            cache, res = self.load_context(cid, policy=policy, seed=seed)
            toks, logits_seq = self._decode(st, cache, prompt, max_new)
            if compare_exact:
                exact_cache = {"k": jnp.asarray(st.exact_k, jnp.bfloat16),
                               "v": jnp.asarray(st.exact_v, jnp.bfloat16)}
                etoks, elogits = self._decode(st, exact_cache, prompt,
                                              max_new)
                agree = float(np.mean(toks == etoks))
                kl = float(np.mean([_kl(e, a) for e, a
                                    in zip(elogits, logits_seq)]))
            else:
                agree, kl = 1.0, 0.0
            eng = res.engine
            return ServeResult(
                ttft_s=res.ttft_s, energy_j=res.energy_j, tokens=toks,
                top1_agreement=agree, mean_kl=kl,
                n_streamed=eng.n_streamed, n_computed=eng.n_computed,
                migrations=getattr(eng, "n_migrations", 0),
                wall_s=time.time() - t_wall)
        finally:
            self.active_requests -= 1

    def serve_fleet(self, jobs: list[tuple[int, float, str]], *,
                    closed_loop: bool = True, static_util: float = 0.0,
                    max_concurrency: Optional[int] = None,
                    link=None, run_queue=None, policy_fn=None,
                    slo=None, deadline_s: Optional[float] = None,
                    max_new_tokens: int = 0, decode=None,
                    tpot_slo_s: Optional[float] = None,
                    bw_seed: int = 991):
        """Serve many registered contexts concurrently on one clock.

        jobs: (cid, arrival_s, policy) triples over contexts previously
        created with register_context(). Timing/energy come from the
        multi-request cluster (link topology + device servers); KV
        content for any request can still be assembled afterwards with
        load_context(). Pass a ``repro.core.costs.RunQueueModel`` as
        ``run_queue`` to serve compute through the explicit
        FIFO/WFQ/SRPT device queue, and/or a ``policy_fn`` (e.g.
        ``repro.serving.cluster.telemetry_policy``) to pick policies from
        live telemetry at admission. An ``repro.serving.slo.SLOPolicy``
        as ``slo`` (with ``deadline_s`` applied to every job) arms
        deadline-aware admission: downgrade-or-shed on predicted TTFT
        violation. ``max_new_tokens > 0`` keeps every request alive past
        its first token: responses decode through the per-device
        continuous batch (tune it with a
        ``repro.serving.decode.DecodeConfig`` as ``decode``; an optional
        ``tpot_slo_s`` arms per-token admission under ``slo``). Returns
        a FleetReport.
        """
        from repro.serving.cluster import RequestSpec, ServingCluster
        specs = []
        for i, (cid, arrival_s, policy) in enumerate(jobs):
            st = self.contexts[cid]
            specs.append(RequestSpec(
                arrival_s=arrival_s, context_len=st.wl.context_len,
                policy=policy, seed=i, wl=st.wl, deadline_s=deadline_s,
                max_new_tokens=max_new_tokens, tpot_slo_s=tpot_slo_s))
        cluster = ServingCluster(
            self.model.cfg, self.spcfg, self.profile, self.network,
            capacity=self.capacity,
            max_concurrency=max_concurrency or self.capacity,
            closed_loop=closed_loop, static_util=static_util,
            link=link, run_queue=run_queue, policy_fn=policy_fn,
            slo=slo, decode=decode, bw_seed=bw_seed, seed=self.seed)
        return cluster.run(specs)

    def _decode(self, st: StoredContext, cache, prompt, max_new):
        cfg = self.model.cfg
        s = st.tokens.shape[1]
        # context cache is exactly s (read-only); prompt + generated
        # tokens go to the replicated decode tail buffer
        full = self.model.init_cache(1, s)
        full["k"] = cache["k"][:, :, :s].astype(full["k"].dtype)
        full["v"] = cache["v"][:, :, :s].astype(full["v"].dtype)
        toks = []
        logits_list = []
        cur = None
        pos = s
        feed = list(prompt) + [None] * max_new
        for tok in feed:
            if tok is None:
                tok = cur
            logits, full = self._decode_step(
                self.params, full, jnp.asarray([tok], jnp.int32),
                jnp.int32(pos))
            pos += 1
            lf = np.asarray(logits[0], np.float32)
            cur = int(lf[:cfg.vocab_size].argmax())
            toks.append(cur)
            logits_list.append(lf)
        return np.asarray(toks[len(prompt):]), \
            logits_list[len(prompt):]


def _kl(p_logits: np.ndarray, q_logits: np.ndarray) -> float:
    p = p_logits - p_logits.max()
    q = q_logits - q_logits.max()
    lp = p - np.log(np.exp(p).sum())
    lq = q - np.log(np.exp(q).sum())
    return float(np.sum(np.exp(lp) * (lp - lq)))
