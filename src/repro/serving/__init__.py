"""Serving layer: single-request server, multi-request cluster, traffic.

- ``engine``    — SparKVServer: concrete context registration + per-request
  loading/decoding (real compression round-trip, real logit checks).
- ``resources`` — generic discrete-event resource servers: fluid link
  stages/topologies (per-device NIC -> shared uplink) and the explicit
  FIFO/WFQ/SRPT device run queue.
- ``cluster``   — ServingCluster: N concurrent loads on one clock, driving
  the resource servers (link topology + per-device run queues or the
  legacy closed-loop utilization coupling).
- ``decode``    — continuous batched decoding: per-device DecodeBatcher
  whose batched token dispatches share the device run queue with
  in-flight prefill chunks (full-response goodput, TPOT/TTLT metrics).
- ``traffic``   — arrival processes, request mixes, device routing, WFQ
  weight classes and SLO deadline classes for fleet runs.
- ``slo``       — SLO-aware admission: TTFT prediction against the live
  servers, quality shedding down the quantization bitrate ladder,
  deadline-derived WFQ weights.
"""
