"""Serving layer: single-request server, multi-request cluster, traffic.

- ``engine``    — SparKVServer: concrete context registration + per-request
  loading/decoding (real compression round-trip, real logit checks).
- ``resources`` — generic discrete-event resource servers: fluid link
  stages/topologies (per-device NIC -> shared uplink) and the explicit
  FIFO/WFQ device run queue.
- ``cluster``   — ServingCluster: N concurrent loads on one clock, driving
  the resource servers (link topology + per-device run queues or the
  legacy closed-loop utilization coupling).
- ``traffic``   — arrival processes, request mixes, device routing and
  WFQ weight classes for fleet runs.
"""
