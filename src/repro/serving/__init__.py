"""Serving layer: single-request server, multi-request cluster, traffic.

- ``engine``  — SparKVServer: concrete context registration + per-request
  loading/decoding (real compression round-trip, real logit checks).
- ``cluster`` — ServingCluster: N concurrent loads on one clock with a
  shared-link bandwidth arbiter and closed-loop compute contention.
- ``traffic`` — arrival processes and request mixes for fleet runs.
"""
