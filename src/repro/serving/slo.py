"""SLO-aware admission for the serving cluster (deadline scheduling layer).

Requests carry per-class TTFT deadlines (``RequestSpec.deadline_s``,
relative to arrival). This module supplies the three admission-time
mechanisms the cluster composes on top of the resource servers:

  - **TTFT prediction** — :func:`predict_ttft` projects a request's TTFT
    from its plan (per-chunk predicted stream/compute costs) and the live
    resource servers: the bottleneck fair share across the shared stages
    of the device's path (AP uplink, cloud egress) with this flow added,
    and the device run queue's service backlog. With a refreshed online
    predictor on the cluster, the learned wait/share models replace the
    analytic contention terms.
  - **Quality shedding** — :func:`decide_admission` compares the
    prediction against the deadline. A predicted violation first walks
    the request's KV stream down the quantization bitrate ladder
    (``repro.compression.quantize.downgrade_ladder``: fewer bits, fewer
    bytes, lower fidelity — the "don't waste bits" degradation lever);
    with ``SLOPolicy.cold_frac > 0`` the ladder applies to only the
    request's *cold* (low-attention-mass) chunks first, so the hot
    chunks the response actually depends on keep their fidelity. If
    even the coarsest level misses, the request is shed (rejected)
    instead of poisoning everyone's tail.
  - **Deadline-derived WFQ weights** — :meth:`SLOPolicy.weight_for_slack`
    maps deadline slack at admission to the ``DeviceRunQueue`` weight
    classes, so "interactive vs. background" falls out of the deadlines
    instead of hand-set weights.

With continuous batched decoding armed (``RequestSpec.max_new_tokens >
0``), a request may additionally carry a **TPOT SLO**
(``RequestSpec.tpot_slo_s``): :func:`predict_tpot` projects the batched
per-token latency against the device's live decode occupancy, and a
predicted violation sheds at admission — the quantization ladder cannot
help there, since decode-step cost is independent of streamed bitrate.

Requests without a deadline (TTFT or TPOT) bypass all mechanisms: a
cluster with ``slo=SLOPolicy()`` but no deadlines in the trace is
bit-identical to one without the policy (tested in tests/test_cluster.py).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.compression.quantize import downgrade_ladder
from repro.core.costs import t_store_hit
from repro.core.costs import t_stream as chunk_stream_seconds
from repro.core.engine import (context_kv_bytes,
                               decode_first_token_seconds,
                               decode_step_seconds)
from repro.core.predictor import backlog_delay_s
from repro.serving.memory import predicted_reload_stall_s


@dataclasses.dataclass(frozen=True)
class SLOPolicy:
    """Admission-control policy knobs.

    Parameters
    ----------
    downgrade : try coarser stream quantization before rejecting.
    shed : reject requests whose predicted TTFT misses the deadline even
        at the coarsest ladder level (False = admit best-effort at the
        coarsest level instead).
    ladder : explicit downgrade bit-widths (finest first); ``None`` uses
        every ``BITRATE_LEVELS`` entry coarser than the plan's bits.
    headroom : safety multiplier on the prediction (1.1 = require 10%
        slack; admission uses ``pred * headroom <= deadline``).
    weight_bins : ``((slack_le_s, weight), ...)`` sorted by slack — the
        deadline-to-WFQ-weight mapping; a request whose admission-time
        slack is <= the first threshold gets that weight, etc. The
        mapping applies only to deadline-carrying requests still at the
        default weight 1.0: a hand-set ``RequestSpec.weight`` != 1.0
        always wins (weight 1.0 *is* the "unset" sentinel — a trace
        that hand-assigns exactly 1.0 and also wants deadline weights
        untouched should disable the mapping with ``weight_bins=()``).
    base_weight : weight for requests with slack beyond every bin (and
        the effective weight of deadline-less requests).
    cold_frac : fraction of a request's chunks (coldest by attention
        mass) the downgrade ladder applies to before touching the rest:
        a predicted violation first walks the ladder over only the cold
        set — the hot chunks the response actually depends on keep
        their width — and falls back to the whole-request walk when
        even cold-only at the coarsest level misses. 0.0 (default) is
        the legacy whole-request downgrade, bit-identical.
    """
    downgrade: bool = True
    shed: bool = True
    ladder: Optional[tuple] = None
    headroom: float = 1.0
    weight_bins: tuple = ((2.0, 8.0), (5.0, 4.0))
    base_weight: float = 1.0
    cold_frac: float = 0.0

    def weight_for_slack(self, slack_s: float) -> float:
        """WFQ weight class for a request with `slack_s` of deadline
        slack left at admission (tighter deadline -> heavier weight)."""
        for thresh, weight in self.weight_bins:
            if slack_s <= thresh:
                return float(weight)
        return float(self.base_weight)


@dataclasses.dataclass(frozen=True)
class AdmissionDecision:
    action: str                 # "admit" | "shed"
    bits: int                   # effective stream quantization bits
    pred_ttft_s: float          # the prediction that justified `action`
    downgraded: bool = False
    reason: str = "ttft"        # which SLO leg decided ("ttft" | "tpot")
    pred_tpot_s: Optional[float] = None
    # chunks the downgrade applies to (cold-chunk admission); None =
    # whole-request downgrade, the legacy semantics
    cold_chunks: Optional[frozenset] = None


def plan_compute_seconds(plan) -> float:
    """Total planned compute seconds of a request plan (the scheduler's
    per-chunk predictions over the compute-assigned chunks). Shared by
    the admission TTFT projection and the cluster's SRPT remaining-work
    bookkeeping so the two never drift."""
    return sum(float(plan.planner.tc[plan.grid.index(c)])
               for stage in plan.schedule.stages for c in stage.comp)


def predict_ttft(plan, cluster, spec, now: float, *,
                 bits: Optional[int] = None,
                 cold: Optional[frozenset] = None) -> float:
    """Projected TTFT (arrival -> first token) if `spec` is admitted now.

    The projection is the planner's own cost model evaluated against the
    *live* servers rather than an idle device and exclusive link:

      - stream path: planned stream bytes (scaled to `bits` when
        downgrading) over the projected per-flow bandwidth — the
        bottleneck across the shared stages of the device's path
        (``cluster.projected_flow_frac``: its AP uplink fair share with
        this flow added, and the cloud-egress share on three-hop trees),
        capped by the device's own NIC mean (the exclusive stage) —
        plus the on-device decode/dequant tails;
      - compute path: planned per-chunk compute predictions, with the
        contention wait modeled as the max of two regimes — occupancy
        dilation (the engine keeps one chunk outstanding per request, so
        every one of this request's chunks competes with ~``load`` other
        flows for ``capacity`` slots over its whole lifetime) and the
        drain of the service seconds already committed to the device
        (:func:`repro.core.predictor.backlog_delay_s`, which dominates
        when a few long jobs rather than many flows hold the queue).
        The two regimes count the same queued chunks, so they are
        max-combined, never summed;
      - plus elapsed admission-queue wait and the first-token decode.

    Both contention terms are **analytic fallbacks**: when the cluster
    carries a refreshed ``repro.core.predictor.LatencyPredictor``
    (``ServingCluster(predictor=..., refresh_every=...)``), the learned
    models replace them — ``predict_share`` (observed bottleneck link
    efficiency) supplants the profiled fair-share fraction and
    ``predict_wait_s`` (least-squares on realized queue waits) supplants
    the occupancy-dilation/backlog max. An unrefreshed or absent
    predictor leaves this function bit-identical to the analytic form.

    The two paths overlap in the engine, so the context time is their
    max — the same fluid approximation the offline planner uses. The
    plan's per-chunk predictions already carry the admission-time U
    feature, so the projection errs conservative under load: admitted
    deadline-class requests should actually meet their deadlines.
    """
    chunk_bits = getattr(plan, "chunk_bits", None)

    def _factor(c) -> float:
        """Byte scaling of chunk `c` under the candidate downgrade:
        `cold` restricts the downgrade to the cold set (hot chunks keep
        their width), per-chunk plans downgrade each chunk from its OWN
        width (never upward). The legacy projection — uniform plan,
        whole-request downgrade — reduces to bits / plan.quality_bits
        exactly."""
        if bits is None:
            return 1.0
        if cold is not None and c not in cold:
            return 1.0
        b_c = chunk_bits.get(c, plan.quality_bits) if chunk_bits \
            else plan.quality_bits
        return min(b_c, bits) / b_c

    pred = getattr(cluster, "predictor", None)
    if pred is not None and not getattr(pred, "refreshed", False):
        pred = None
    n_flows = cluster.active_flows()
    share = pred.predict_share(n_flows + 1) if pred is not None else None
    frac = share if share is not None \
        else cluster.projected_flow_frac(spec.device)
    # hostile-world scenarios: an AP inside an outage window delivers
    # only the scenario's floor fraction — admission must see the dead
    # link, not the profiled mean (1.0 on scenario-less clusters, so the
    # projection is unchanged there)
    health_fn = getattr(cluster, "uplink_health", None)
    if health_fn is not None:
        frac *= health_fn(spec.device)
    bw_eff = cluster.net.mean_bw * frac
    nic_bw = cluster.nic_mean_bw(spec.device)
    if nic_bw is not None:
        # NIC-staged topology: the flow drains at the slower of its NIC
        # and its shared-stage bottleneck — ignoring the NIC would
        # over-admit exactly when the NIC is the bottleneck
        bw_eff = min(bw_eff, nic_bw)
    # cross-request reuse folds into the projection the same way it
    # bends the plan: local prefix hits cost nothing on the wire, store
    # hits ride the cached-egress leg at its own (egress-free) fair
    # share. Empty sets / missing attributes = the pre-reuse projection,
    # bit-identical.
    reuse_local = getattr(plan, "reuse_local", frozenset())
    reuse_store = getattr(plan, "reuse_store", frozenset())
    store_model = getattr(plan, "store_model", None)
    bw_hit = bw_eff
    if reuse_store and store_model is not None:
        hit_frac_fn = getattr(cluster, "projected_hit_frac", None)
        hit_frac = hit_frac_fn(spec.device) if hit_frac_fn is not None \
            else frac
        if hit_frac_fn is not None and health_fn is not None:
            # the hit leg still crosses the (possibly dead) AP uplink
            hit_frac *= health_fn(spec.device)
        bw_hit = cluster.net.mean_bw * hit_frac
        if nic_bw is not None:
            bw_hit = min(bw_hit, nic_bw)
    t_stream = 0.0
    for stage in plan.schedule.stages:
        for c in stage.stream:
            if c in reuse_local:
                continue
            if c in reuse_store and store_model is not None:
                t_stream += t_store_hit(plan.bytes_map[c] * _factor(c),
                                        bw_hit, cluster.profile,
                                        store_model)
                continue
            # the planner's own per-chunk stream cost, at the projected
            # bottleneck bandwidth (keeps admission in lockstep with
            # planning if the stream cost model evolves)
            t_stream += chunk_stream_seconds(
                plan.bytes_map[c] * _factor(c), bw_eff, cluster.profile)
    t_comp = plan_compute_seconds(plan)
    wait = pred.predict_wait_s(cluster.device_load(spec.device),
                               cluster.capacity,
                               cluster.device_backlog_s(spec.device)) \
        if pred is not None else None
    if wait is not None:
        t_comp = t_comp + wait
    else:
        dilation = 1.0 + cluster.device_load(spec.device) \
            / max(cluster.capacity, 1)
        t_comp = max(t_comp * dilation,
                     t_comp + backlog_delay_s(
                         cluster.device_backlog_s(spec.device),
                         cluster.capacity))
    t_first = decode_first_token_seconds(cluster.cfg, plan.context_len,
                                         cluster.profile)
    # memory-armed clusters: admitting this context may push the device
    # over its KV budget, and the induced evict/reload churn lands
    # squarely in this request's first-token path (zero when the cluster
    # has no finite memory server — the bit-parity guarantee)
    t_stall = predicted_reload_stall_s(
        cluster, spec.device,
        context_kv_bytes(cluster.cfg, plan.context_len))
    return (now - spec.arrival_s) + max(t_stream, t_comp) + t_first + t_stall


def predict_tpot(cluster, spec, context_len: int) -> float:
    """Projected per-token decode latency if `spec` is admitted now: one
    batched decode step over the device's current decode occupancy plus
    this request, every sequence at this request's mid-response context
    length. Conservative in the same way batching is: joiners raise the
    step cost only through their KV reads, the weight-read term stays
    amortized. Quality downgrades do not enter — decode-step cost is
    independent of the streamed bitrate, so a TPOT violation cannot be
    downgraded away, only shed."""
    from repro.serving.decode import DecodeConfig
    dcfg = getattr(cluster, "decode_cfg", None) or DecodeConfig()
    b = min(cluster.decode_occupancy(spec.device) + 1, dcfg.max_batch)
    mid_len = context_len + max(spec.max_new_tokens, 1) // 2
    step = decode_step_seconds(cluster.cfg, [mid_len] * b, cluster.profile)
    # evict/reload stalls amortize across the whole response: a sequence
    # parked for a reload delivers no tokens while the stall runs, which
    # is exactly a per-token latency hit of stall / n_tokens (zero on
    # memory-less clusters)
    stall = predicted_reload_stall_s(
        cluster, spec.device, context_kv_bytes(cluster.cfg, context_len))
    return step + stall / max(spec.max_new_tokens, 1)


def decide_admission(policy: SLOPolicy, plan, cluster, spec,
                     now: float) -> AdmissionDecision:
    """Admit / downgrade / shed `spec` against its TTFT deadline and —
    when the request decodes under a ``tpot_slo_s`` — its TPOT SLO.

    TTFT leg: walks the quantization ladder finest-first; the first
    bit-width whose predicted TTFT (with `policy.headroom`) meets the
    deadline wins. When none does, the request is shed (``policy.shed``)
    or admitted best-effort at the coarsest level. TPOT leg: a predicted
    per-token violation sheds outright (coarser bits don't speed decode).
    """
    assert spec.deadline_s is not None or spec.tpot_slo_s is not None, \
        "decide_admission needs a TTFT deadline or a TPOT SLO"

    if spec.deadline_s is None:
        dec = AdmissionDecision("admit", plan.quality_bits,
                                predict_ttft(plan, cluster, spec, now))
    else:
        dec = _decide_ttft(policy, plan, cluster, spec, now)
    if (dec.action == "admit" and policy.shed
            and spec.tpot_slo_s is not None and spec.max_new_tokens > 0):
        pred_tpot = predict_tpot(cluster, spec, plan.context_len)
        if pred_tpot * policy.headroom > spec.tpot_slo_s:
            return dataclasses.replace(dec, action="shed", reason="tpot",
                                       pred_tpot_s=pred_tpot)
        dec = dataclasses.replace(dec, pred_tpot_s=pred_tpot)
    return dec


def cold_chunk_set(plan, frac: float) -> frozenset:
    """The coldest `frac` of the plan's chunks by attention mass — the
    chunks a quality downgrade hurts least, since attention barely
    reads them. Deterministic (mass, chunk-id) order."""
    chunks = sorted(plan.active_map,
                    key=lambda c: (plan.active_map[c], c))
    return frozenset(chunks[:int(len(chunks) * frac)])


def _decide_ttft(policy: SLOPolicy, plan, cluster, spec,
                 now: float) -> AdmissionDecision:
    deadline = spec.deadline_s

    pred = predict_ttft(plan, cluster, spec, now)
    if pred * policy.headroom <= deadline:
        return AdmissionDecision("admit", plan.quality_bits, pred)

    ladder = policy.ladder if policy.ladder is not None \
        else downgrade_ladder(plan.quality_bits)
    if policy.downgrade:
        if policy.cold_frac > 0.0:
            # cold-chunk admission: walk the ladder over only the
            # low-saliency chunks first — the hot chunks the response
            # depends on keep their width
            cold = cold_chunk_set(plan, policy.cold_frac)
            if cold:
                for bits in ladder:
                    pred = predict_ttft(plan, cluster, spec, now,
                                        bits=bits, cold=cold)
                    if pred * policy.headroom <= deadline:
                        return AdmissionDecision("admit", bits, pred,
                                                 downgraded=True,
                                                 cold_chunks=cold)
        for bits in ladder:
            pred = predict_ttft(plan, cluster, spec, now, bits=bits)
            if pred * policy.headroom <= deadline:
                return AdmissionDecision("admit", bits, pred,
                                         downgraded=True)
    if policy.shed:
        return AdmissionDecision("shed", plan.quality_bits, pred)
    if policy.downgrade and ladder:
        return AdmissionDecision("admit", ladder[-1], pred,
                                 downgraded=True)
    return AdmissionDecision("admit", plan.quality_bits, pred)
